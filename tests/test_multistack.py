"""Multi-stack NoM: two-level topology, per-stack CCU authorities, and
cross-stack circuits (the ``docs/multistack.md`` contract).

Covers: degenerate single-stack mesh geometries, StackedTopology
addressing and link routing, the single-stack bit-identity of
FabricCluster, the structural invariants of committed cross-stack
circuits, the two-phase-commit rollback guarantee (a far-side conflict
leaks no near-side slot-table state), persistent rounds-backend link
reservations across flushes, the repaired ``shard_owners`` ownership
mapping, and the stack-aware serving placement (lease pinning,
``BankPool.migrate``, ``Engine.migrate_tenant``)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.reshard import cross_stack_reshard_plan, shard_owners
from repro.core.fabric import FabricCluster, NomFabric
from repro.core.scheduler import ScheduleReport, TransferRequest
from repro.core.slot_alloc import CopyRequest, TdmAllocator
from repro.core.topology import (Mesh3D, PORT_LOCAL, StackedTopology,
                                 make_topology)
from repro.serving.engine import Engine
from repro.serving.placement import BankPool, LeafSpec

MESH = Mesh3D(4, 4, 2)
N_SLOTS = 16


def _copy_stream(seed: int, n: int, n_nodes: int, nbytes=256):
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        s, d = rng.integers(n_nodes, size=2)
        while s == d:
            d = rng.integers(n_nodes)
        reqs.append(TransferRequest(src=int(s), dst=int(d), nbytes=nbytes))
    return reqs


# --------------------------------------------------------------------------
# Satellite: degenerate Mesh3D geometries
# --------------------------------------------------------------------------
def test_mesh_degenerate_x1_allocates():
    m = Mesh3D(1, 4, 2, vault_span_y=2)
    alloc = TdmAllocator(m, N_SLOTS)
    res = alloc.allocate(m.node_id(0, 0, 0), m.node_id(0, 3, 1), 512, cycle=0)
    c = res.circuit
    assert c is not None
    slots = [h[2] for h in c.hops]
    for a, b in zip(slots, slots[1:]):
        assert (a + 1) % N_SLOTS == b


def test_mesh_degenerate_z1_allocates():
    m = Mesh3D(4, 4, 1, vault_span_y=2)
    alloc = TdmAllocator(m, N_SLOTS)
    res = alloc.allocate(m.node_id(0, 0, 0), m.node_id(3, 3, 0), 512, cycle=0)
    assert res.circuit is not None
    assert res.circuit.hops[-1][1] == PORT_LOCAL


def test_mesh_invalid_geometry_raises_cleanly():
    with pytest.raises(ValueError, match="vault_span_y"):
        Mesh3D(4, 3, 2, vault_span_y=2)     # Y not divisible by span
    with pytest.raises(ValueError):
        Mesh3D(0, 4, 2)
    with pytest.raises(ValueError):
        Mesh3D(4, 4, -1)
    with pytest.raises(ValueError):
        Mesh3D(4, 4, 2, vault_span_y=0)


# --------------------------------------------------------------------------
# StackedTopology: addressing + link graph
# --------------------------------------------------------------------------
def test_make_topology_single_stack_is_bare_mesh():
    m = make_topology(1, mesh=(4, 4, 2))
    assert isinstance(m, Mesh3D) and m == MESH
    assert isinstance(make_topology(2, mesh=MESH), StackedTopology)


def test_stacked_validation():
    with pytest.raises(ValueError):
        StackedTopology(0, MESH)
    with pytest.raises(ValueError):
        StackedTopology(2, MESH, link="star")
    with pytest.raises(ValueError):
        StackedTopology(3, MESH, meshes=(MESH, MESH))
    with pytest.raises(ValueError):
        StackedTopology(2, MESH, link_bytes=0)


@settings(max_examples=30)
@given(st.integers(0, 3 * MESH.n_nodes - 1))
def test_addressing_roundtrip(gid):
    topo = StackedTopology(3, MESH)
    stack, node = topo.locate(gid)
    assert topo.global_id(stack, node) == gid
    assert topo.stack_of(gid) == stack
    assert 0 <= node < topo.stacks[stack].n_nodes


def test_link_graph_ring_and_full():
    ring = StackedTopology(4, MESH, link="ring")
    assert len(ring.links) == 4 and ring.n_channels == 8
    # Shortest ring direction, wrap included; ties go +1.
    assert ring.stack_route(0, 3) == [(0, 3)]
    assert ring.stack_route(0, 2) == [(0, 1), (1, 2)]
    assert ring.route_channels(0, 1) == [ring.channel(0, 1)]
    # Non-adjacent stacks have no direct channel under "ring".
    with pytest.raises(ValueError):
        ring.channel(0, 2)
    full = StackedTopology(4, MESH, link="full")
    assert len(full.links) == 6
    assert full.stack_route(0, 2) == [(0, 2)]
    per_hop = 1 + full.link_latency
    assert full.route_cycles(0, 2) == per_hop
    assert ring.route_cycles(0, 2) == 2 * per_hop
    # Directed channels are distinct per direction.
    assert ring.channel(0, 1) != ring.channel(1, 0)
    assert ring.is_cross(0, ring.global_id(1, 0))
    assert not ring.is_cross(0, 1)


# --------------------------------------------------------------------------
# FabricCluster: n_stacks=1 bit-identity
# --------------------------------------------------------------------------
def test_single_stack_cluster_bit_identical():
    reqs = _copy_stream(3, 24, MESH.n_nodes)
    reqs.append(TransferRequest(src=5, dst=5, nbytes=2048, op="init"))
    fab = NomFabric(mesh=MESH, n_slots=N_SLOTS)
    clu = FabricCluster(topology=StackedTopology(1, MESH), n_slots=N_SLOTS)
    for _ in range(2):                      # session behavior, not one-shot
        res_f, rep_f = fab.schedule(reqs)
        res_c, rep_c = clu.schedule(reqs)
        assert rep_f == rep_c
        for a, b in zip(res_f, res_c):
            assert a.circuit == b.circuit
            assert a.searched_cycle == b.searched_cycle
    assert clu.fabrics[0].clock == fab.clock
    assert rep_c.n_cross_stack == 0


# --------------------------------------------------------------------------
# Cross-stack circuits: structure
# --------------------------------------------------------------------------
def test_cross_stack_circuit_invariants():
    topo = StackedTopology(2, MESH, link_latency=5, link_bytes=4)
    clu = FabricCluster(topology=topo, n_slots=N_SLOTS)
    src, dst = (0, MESH.node_id(2, 3, 1)), (1, MESH.node_id(3, 1, 1))
    nbytes = 96
    c = clu.segmented.allocate(src, dst, nbytes, cycle=0)
    assert c is not None and c.cross_stack
    n = N_SLOTS
    # Near leg: increasing slots source -> bridge, arriving at slot a.
    slots = [h[2] for h in c.near_hops]
    for a, b in zip(slots, slots[1:]):
        assert (a + 1) % n == b
    a = slots[-1]
    assert c.near_hops[-1][0] == topo.bridge_of(0)
    # SerDes leg: first channel slot (a+1)%n, each hop advances 1+latency.
    chans = topo.route_channels(0, 1)
    assert [ch for ch, _s in c.link_slots] == chans
    s = (a + 1) % n
    for (_ch, sl), lat in zip(c.link_slots,
                              (topo.links[ch // 2].latency for ch in chans)):
        assert sl == s
        s = (s + 1 + lat) % n
    # Far leg: injection pinned at (a + T) % n, increasing to the sink.
    T = topo.route_cycles(0, 1)
    far_slots = [h[2] for h in c.far_hops]
    assert far_slots[0] == (a + T) % n
    for x, y in zip(far_slots, far_slots[1:]):
        assert (x + 1) % n == y
    assert c.far_hops[0][0] == topo.bridge_of(1)
    assert c.far_hops[-1][1] == PORT_LOCAL
    # Streaming rate: the bottleneck width sets the window count.
    bw = clu.segmented.bottleneck_bytes(0, 1)
    assert bw == 4 and c.n_windows == -(-nbytes // bw)
    assert c.distance == len(c.near_hops) - 1 + T + len(c.far_hops) - 1


def test_same_stack_requests_never_take_cluster_path():
    topo = StackedTopology(2, MESH)
    clu = FabricCluster(topology=topo, n_slots=N_SLOTS)
    reqs = [TransferRequest(src=(0, 1), dst=(0, 9), nbytes=256),
            TransferRequest(src=(1, 4), dst=(1, 20), nbytes=256)]
    _res, rep = clu.schedule(reqs)
    assert rep.n_scheduled == 2
    assert rep.n_cross_stack == 0 and clu.cross_requests == 0
    assert clu.segmented.link_windows == 0


def test_cross_stack_init_rejected():
    clu = FabricCluster(topology=StackedTopology(2, MESH))
    with pytest.raises(ValueError, match="init"):
        clu.schedule([TransferRequest(src=(0, 3), dst=(1, 3), nbytes=64,
                                      op="init")])


# --------------------------------------------------------------------------
# Two-phase commit: far-side conflict rolls back near-side state
# --------------------------------------------------------------------------
def _saturate(alloc):
    """Mark every port slot of a stack busy far into the future."""
    ports = alloc.table._ports
    ports.expiry[:] = 1 << 40
    ports._recompute(ports.window)


def test_far_conflict_rolls_back_near_reservations():
    topo = StackedTopology(2, MESH)
    clu = FabricCluster(topology=topo, n_slots=N_SLOTS)
    seg = clu.segmented
    _saturate(seg.allocators[1])
    near = seg.allocators[0].table._ports
    near_before = near.expiry.copy()
    links_before = seg.links.expiry.copy()
    c = seg.allocate((0, 10), (1, 21), 512, cycle=0)
    assert c is None
    assert seg.rollbacks >= 1 and seg.denied == 1
    np.testing.assert_array_equal(near.expiry, near_before)
    np.testing.assert_array_equal(seg.links.expiry, links_before)


@settings(max_examples=15)
@given(st.integers(0, 2**31 - 1), st.integers(1, 40))
def test_two_phase_commit_leaks_nothing(seed, n_far_circuits):
    """Property: whatever local traffic congests the far stack, a denied
    cross-stack request leaves near-side and link slot tables exactly as
    it found them, and a committed one reserves on both sides."""
    rng = np.random.default_rng(seed)
    topo = StackedTopology(2, MESH)
    clu = FabricCluster(topology=topo, n_slots=N_SLOTS)
    seg = clu.segmented
    # Congest stack 1 with its own local circuits (through its own CCU).
    local = _copy_stream(seed % 997, n_far_circuits, MESH.n_nodes, nbytes=512)
    clu.fabrics[1].schedule(local, cycle=0)
    near = seg.allocators[0].table._ports
    far = seg.allocators[1].table._ports
    near_before = near.expiry.copy()
    links_before = seg.links.expiry.copy()
    far_before = far.expiry.copy()
    s = int(rng.integers(MESH.n_nodes))
    d = int(rng.integers(MESH.n_nodes))
    c = seg.allocate((0, s), (1, d), int(rng.integers(16, 2048)), cycle=0)
    if c is None:
        np.testing.assert_array_equal(near.expiry, near_before)
        np.testing.assert_array_equal(seg.links.expiry, links_before)
        np.testing.assert_array_equal(far.expiry, far_before)
    else:
        assert (near.expiry != near_before).sum() == len(c.near_hops)
        assert (seg.links.expiry != links_before).sum() == len(c.link_slots)
        assert (far.expiry != far_before).sum() == len(c.far_hops)


# --------------------------------------------------------------------------
# Satellite: rounds-backend link reservations persist across flushes
# --------------------------------------------------------------------------
def test_rounds_busy_persists_across_anchored_flushes():
    mk = lambda: NomFabric(shape=(8,), torus=True)
    reqs = [TransferRequest(src=(i,), dst=((i + 1) % 8,), nbytes=4096)
            for i in range(8)]
    # Two flushes re-anchored at the same cycle share the session's link
    # reservations: the second batch must pack AROUND the first.
    fab = mk()
    plan1, _ = fab.schedule(reqs, cycle=0)
    plan2, _ = fab.schedule(reqs, cycle=0)
    fresh_plan, _ = mk().schedule(reqs, cycle=0)
    assert plan1.n_rounds == fresh_plan.n_rounds
    starts = lambda p: sorted(p.starts)
    assert starts(plan2) != starts(fresh_plan)   # contention is visible
    # Sequential (un-anchored) batches advance the clock past the drain,
    # so each plan is bit-identical to a fresh session's.
    seq = mk()
    p1, _ = seq.schedule(reqs)
    p2, _ = seq.schedule(reqs)
    assert starts(p1) == starts(p2) == starts(fresh_plan)


# --------------------------------------------------------------------------
# ScheduleReport: the cross-stack counter merges
# --------------------------------------------------------------------------
def test_report_merge_accumulates_cross_stack():
    a = ScheduleReport(backend="tdm", n_requests=2, n_scheduled=2,
                       n_windows=1, max_inflight=1, avg_inflight=1.0,
                       n_cross_stack=1)
    b = ScheduleReport(backend="tdm", n_requests=3, n_scheduled=3,
                       n_windows=1, max_inflight=1, avg_inflight=1.0,
                       n_cross_stack=2)
    assert a.merge(b).n_cross_stack == 3


# --------------------------------------------------------------------------
# Satellite: shard_owners implements its documented mapping
# --------------------------------------------------------------------------
def test_shard_owners_partitions_exactly():
    owners = shard_owners((8, 6), ("x", None), (4, 2), ("x", "y"))
    assert len(owners) == 8
    assert owners[(0, 0)] == ((0, 2), (0, 6))
    assert owners[(3, 1)] == ((6, 8), (0, 6))
    # Sharded dim: the 4 x-slices tile [0, 8) exactly; replicated dim is
    # the full extent everywhere.
    xs = sorted({r[0] for r in owners.values()})
    assert xs == [(0, 2), (2, 4), (4, 6), (6, 8)]
    assert all(r[1] == (0, 6) for r in owners.values())


def test_shard_owners_validates():
    with pytest.raises(ValueError, match="unknown mesh axis"):
        shard_owners((8,), ("q",), (4,), ("x",))
    with pytest.raises(ValueError, match="divisible"):
        shard_owners((9,), ("x",), (4,), ("x",))
    with pytest.raises(ValueError, match="reused"):
        shard_owners((8, 8), ("x", "x"), (4,), ("x",))
    with pytest.raises(ValueError):
        shard_owners((8,), ("x", None), (4,), ("x",))   # rank mismatch


def test_cross_stack_reshard_plan_moves_between_stacks():
    topo = make_topology(3, mesh=(4, 4, 2))
    res, rep = cross_stack_reshard_plan(
        {f"p{i}": 256 for i in range(9)}, topo, (0, 1, 2), (0,))
    assert rep.n_cross_stack > 0
    assert rep.n_scheduled == rep.n_requests    # uncontended: all commit
    with pytest.raises(ValueError):
        cross_stack_reshard_plan({"p": 1}, topo, (0,), (5,))


# --------------------------------------------------------------------------
# Stack-aware serving placement
# --------------------------------------------------------------------------
def _leaves(n=3):
    return [LeafSpec(f"l{i}", step_bytes=64, lease_bytes=256, ring_slots=4)
            for i in range(n)]


def test_pool_lease_pins_to_stacks():
    pool = BankPool(make_topology(3, mesh=(4, 4, 2)))
    for ls in pool.lease("a", _leaves(), stacks={1}):
        assert pool.stack_of(ls.home) == 1
        assert pool.stack_of(ls.staging) == 1   # staging never crosses
    assert pool.stack_load() == {1: 3}
    with pytest.raises(ValueError):
        pool.lease("b", _leaves(), stacks={7})


def test_pool_migrate_moves_only_off_stack_leases():
    pool = BankPool(make_topology(2, mesh=(4, 4, 2)))
    held = pool.lease("a", _leaves(4))
    on_dst = [ls for ls in held if pool.stack_of(ls.home) == 1]
    old, fresh = pool.migrate("a", 1)
    assert len(old) == len(fresh) == 4 - len(on_dst)
    assert all(pool.stack_of(ls.home) == 1 for ls in pool.leases("a"))
    # Kept leases stayed put; vacated homes are free; no old/fresh overlap
    # (a teardown scrub must never hit a live home).
    assert {ls.home for ls in on_dst} <= {ls.home for ls in pool.leases("a")}
    assert not {ls.home for ls in old} & {ls.home for ls in fresh}
    assert all(ls.home not in pool._owner for ls in old)
    assert pool.migrate("a", 1) == ([], [])     # idempotent


def test_pool_migrate_rolls_back_on_exhaustion():
    pool = BankPool(make_topology(2, mesh=(2, 2, 2)))
    pool.lease("big", [LeafSpec(f"x{i}", 8) for i in range(4)], stacks={1})
    pool.lease("t", [LeafSpec("y", 8)], stacks={0})
    snap = (dict(pool._owner), {k: list(v) for k, v in pool._leased.items()})
    assert pool.migrate("t", 1) == ([], [])
    assert dict(pool._owner) == snap[0]
    assert {k: list(v) for k, v in pool._leased.items()} == snap[1]


def test_partition_groups_never_span_stacks():
    pool = BankPool(make_topology(2, mesh=(4, 4, 2)), policy="partition")
    pool.lease("t0", _leaves(), stacks={0})
    pool.lease("t1", _leaves(), stacks={1})
    g0 = {c for c, t in pool._col_owner.items() if t == "t0"}
    g1 = {c for c, t in pool._col_owner.items() if t == "t1"}
    assert g0 and g1 and not g0 & g1
    assert all(pool._group_stack(g) == 0 for g in g0)
    assert all(pool._group_stack(g) == 1 for g in g1)


class _CacheStub:
    def init_caches(self, batch, max_len):
        return {"kv": jnp.zeros((batch, max_len, 8), jnp.int8),
                "state": jnp.zeros((batch, 16), jnp.int8)}

    def decode_step(self, params, token, caches, pos):
        return jnp.zeros((token.shape[0], 1, 7)), caches


def test_engine_migrate_tenant_cross_stack():
    eng = Engine(model=_CacheStub(), cfg=None, max_len=16,
                 cache_mesh=make_topology(2, mesh=(4, 4, 2)), ring_slots=4)
    assert isinstance(eng.fabric, FabricCluster)
    eng.open_tenant("t0", 2)
    eng.migrate_tenant("t0", 0)                 # pin everything to stack 0
    rep = eng.migrate_tenant("t0", 1)
    assert rep is not None
    assert rep.n_cross_stack >= 1               # the COPY leg crosses
    assert rep.n_init >= 1                      # vacated homes are scrubbed
    assert all(eng.pool.stack_of(ls.home) == 1
               for ls in eng.pool.leases("t0"))
    # The tenant keeps streaming after the move; telemetry counts it.
    assert eng.schedule_tick(["t0"]) is not None
    tel = eng.transfer_telemetry()
    assert tel["migrations"] >= 1 and tel["cross_stack"] >= 1
    assert eng.migrate_tenant("t0", 1) is None  # already there
    eng.close_tenant("t0")
    with pytest.raises(ValueError):
        eng.migrate_tenant("t0", 0)


def test_engine_single_stack_unchanged():
    eng = Engine(model=_CacheStub(), cfg=None, max_len=16,
                 cache_mesh=Mesh3D(2, 2, 2), ring_slots=4)
    assert isinstance(eng.fabric, NomFabric)
    eng.open_tenant("a", 1)
    assert eng.migrate_tenant("a", 0) is None   # one stack: no-op
    rep = eng.schedule_tick(["a"])
    assert rep.n_cross_stack == 0
    eng.close_tenant("a")
