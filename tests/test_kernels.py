"""Per-kernel interpret-mode sweeps against the pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.slot_alloc import TdmAllocator
from repro.core.topology import Mesh3D

RNG = np.random.default_rng(42)


# --- slot_alloc -------------------------------------------------------------
@pytest.mark.parametrize("mesh_dims,n_slots", [((8, 8, 4), 16),
                                               ((4, 4, 2), 8),
                                               ((8, 8, 4), 32)])
def test_slot_alloc_kernel_vs_ref(mesh_dims, n_slots):
    from repro.kernels.slot_alloc.ops import wavefront_search_pallas_batch
    from repro.kernels.slot_alloc.ref import wavefront_search_ref_batch
    mesh = Mesh3D(*mesh_dims)
    alloc = TdmAllocator(mesh, n_slots)
    for i in range(12):
        s, d = RNG.integers(mesh.n_nodes, size=2)
        if s != d:
            alloc.allocate(int(s), int(d), 256, cycle=i * 3)
    occ = alloc.table.busy_masks(window=0)
    B = 8
    srcs = RNG.integers(mesh.n_nodes, size=B)
    dsts = (srcs + 1 + RNG.integers(mesh.n_nodes - 1, size=B)) % mesh.n_nodes
    inits = RNG.integers(0, 4, size=B).astype(np.uint32)
    got = np.asarray(wavefront_search_pallas_batch(
        occ, srcs, dsts, inits, mesh=mesh, n_slots=n_slots))
    want = wavefront_search_ref_batch(occ, srcs, dsts, inits, mesh=mesh,
                                      n_slots=n_slots)
    np.testing.assert_array_equal(got, want)


# --- flash attention -------------------------------------------------------
@pytest.mark.parametrize("b,sq,sk,hq,hkv,d,causal,window,dtype,tol", [
    (2, 256, 256, 4, 2, 64, True, None, jnp.float32, 2e-5),
    (1, 200, 200, 4, 1, 64, True, 64, jnp.float32, 2e-5),
    (2, 128, 384, 8, 8, 128, False, None, jnp.float32, 2e-5),
    (1, 256, 256, 2, 2, 64, True, None, jnp.bfloat16, 2e-2),
    (1, 96, 96, 4, 4, 32, True, 32, jnp.float32, 2e-5),
])
def test_flash_attention_sweep(b, sq, sk, hq, hkv, d, causal, window,
                               dtype, tol):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    q = jnp.asarray(RNG.standard_normal((b, sq, hq, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, sk, hkv, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, sk, hkv, d)), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window)
    want = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), causal=causal,
                         window=window).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < tol, err


# --- ssd scan ----------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,hd,n,chunk,dtype,tol", [
    (2, 256, 3, 32, 16, 128, jnp.float32, 1e-4),
    (1, 384, 2, 64, 128, 128, jnp.float32, 1e-4),
    (1, 256, 2, 32, 64, 64, jnp.float32, 1e-4),
    (1, 256, 2, 32, 16, 128, jnp.bfloat16, 5e-2),
])
def test_ssd_scan_sweep(b, s, h, hd, n, chunk, dtype, tol):
    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_ref
    x = jnp.asarray(RNG.standard_normal((b, s, h, hd)), dtype)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, s, n)) * 0.3, dtype)
    C = jnp.asarray(RNG.standard_normal((b, s, n)) * 0.3, dtype)
    A = jnp.asarray(-np.exp(RNG.uniform(-1, 1, (h,))), jnp.float32)
    got = ssd_scan(x, dt, B, C, A, chunk=chunk)
    xr = x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    dtr = dt.transpose(0, 2, 1).reshape(b * h, s, 1)
    Br = jnp.broadcast_to(B[:, None], (b, h, s, n)).reshape(b * h, s, n)
    Cr = jnp.broadcast_to(C[:, None], (b, h, s, n)).reshape(b * h, s, n)
    Ar = jnp.broadcast_to(A[None, :], (b, h)).reshape(b * h, 1)
    want = ssd_ref(xr, dtr, Br, Cr, Ar).reshape(b, h, s, hd
                                                ).transpose(0, 2, 1, 3)
    rel = (float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - want.astype(jnp.float32))))
           / (float(jnp.max(jnp.abs(want.astype(jnp.float32)))) + 1e-9))
    assert rel < tol, rel


# --- rglru scan --------------------------------------------------------------
@pytest.mark.parametrize("b,s,w,chunk,dtype,tol", [
    (2, 200, 128, 128, jnp.float32, 1e-5),
    (1, 512, 256, 128, jnp.float32, 1e-5),
    (1, 130, 128, 64, jnp.bfloat16, 2e-2),
])
def test_rglru_scan_sweep(b, s, w, chunk, dtype, tol):
    from repro.kernels.rglru_scan.ops import rglru_scan
    from repro.kernels.rglru_scan.ref import rglru_ref
    a = jnp.asarray(RNG.uniform(0.7, 0.999, (b, s, w)), dtype)
    bb = jnp.asarray(RNG.standard_normal((b, s, w)) * 0.1, dtype)
    got = rglru_scan(a, bb, chunk=chunk)
    want = rglru_ref(a, bb)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < tol, err


# --- windowed attention (XLA twin of the kernel's block skipping) -------------
@pytest.mark.parametrize("s,window,heads,kv", [(700, 37, 4, 2),
                                               (2048, 256, 4, 4),
                                               (513, 100, 2, 1)])
def test_windowed_attention_matches_dense(s, window, heads, kv, mesh1=None):
    import jax
    import jax.numpy as jnp
    from repro.models.attention import Attention, AttentionConfig, _mask
    cfg = AttentionConfig(d_model=64, n_heads=heads, n_kv=kv, head_dim=16,
                          window=window, causal=True)
    attn = Attention(cfg)
    p = attn.init(jax.random.PRNGKey(0))
    x = jnp.asarray(RNG.standard_normal((2, s, 64)), jnp.float32)
    pos = jnp.arange(s)[None].repeat(2, 0)
    q, k, v = attn._qkv(p, x, None, pos, pos)
    dense = attn._attend_dense(q, k, v, _mask(pos[0], pos[0], cfg))
    wind = attn._attend_windowed(q, k, v, pos[0], pos[0])
    err = float(jnp.max(jnp.abs(dense - wind)))
    assert err < 2e-5, err
