"""Minimal seeded-random stand-in for ``hypothesis`` (offline container).

The real package is not installable here (no network), so conftest.py
installs this module under ``sys.modules["hypothesis"]`` when the import
fails.  Only the surface this repo's property tests use is provided:

* ``strategies.integers(lo, hi)`` / ``lists(elem, min_size, max_size)`` /
  ``tuples(*elems)``
* ``@given(*strategies)`` — runs the test body over ``max_examples``
  deterministic samples (seeded from the test's qualified name, so runs
  are reproducible and order-independent)
* ``@settings(max_examples=..., deadline=...)`` — only ``max_examples``
  is honoured; the rest is accepted and ignored.

No shrinking, no database, no assume(): failures report the offending
example index + values so the case can be replayed by seed.
"""
from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

__version__ = "0.0-repro-shim"

_DEFAULT_MAX_EXAMPLES = 50


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _tuples(*elems: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))


def _lists(elem: _Strategy, *, min_size: int = 0,
           max_size: int = 10) -> _Strategy:
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elem.example(rng) for _ in range(size)]
    return _Strategy(draw)


strategies = types.SimpleNamespace(integers=_integers, tuples=_tuples,
                                   lists=_lists)


def given(*strats: _Strategy):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            for i in range(n):
                rng = np.random.default_rng((seed, i))
                example = tuple(s.example(rng) for s in strats)
                try:
                    fn(*args, *example, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__qualname__} failed on example {i}/{n}: "
                        f"{example!r}") from e
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        # Hide the example parameters from pytest's fixture resolution: the
        # strategies fill every positional arg, so the collected signature
        # must only expose whatever leading fixture args remain (none in
        # this repo's tests).
        params = list(inspect.signature(fn).parameters.values())
        wrapper.__signature__ = inspect.Signature(params[:-len(strats)]
                                                  if strats else params)
        return wrapper
    return decorate


def settings(**kwargs):
    max_examples = kwargs.get("max_examples", _DEFAULT_MAX_EXAMPLES)

    def decorate(fn):
        fn._shim_max_examples = max_examples
        return fn
    return decorate
