"""Property suite for the SLO serving harness and admission strategies.

Pins the contracts ``benchmarks/bench_serving_slo.py`` and
``benchmarks/bench_engine_scale.py`` measure: load-generator determinism
under a fixed seed, the per-tick conservation invariant
``arrivals == admitted + shed + expired + waiting (+ retrying)``, the
strictest-deadline-first dominance over FIFO on deadline-miss rate, and
``Engine.migrate_tenant`` mid-burst preserving tenant state and
telemetry.  Plus the admission-layer regressions: stable FIFO
tie-breaking under permuted queue order, the exactly-once terminal
``waiter_callback`` event (``admitted`` xor ``expired`` xor ``shed``)
even after a partial idle-lease reclaim, and — for the vectorized
control plane — the differential harness asserting every registered
strategy's batched order, and the whole vector engine's observable
behavior, is byte-identical to the scalar reference across mixes,
seeds, and permuted queue states.
"""
import collections

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.topology import make_topology
from repro.serving.admission import (HYBRID_SLACK, STALL_PRESSURE,
                                     AdmissionContext, AdmissionTicket,
                                     TicketColumns, get_admission,
                                     register_admission,
                                     registered_admissions,
                                     unregister_admission)
from repro.serving.engine import CONTROL_PLANES, Engine
from repro.serving.loadgen import (MIXES, CacheStub, LoadGen, drive,
                                   get_mix, make_slo_engine)

STRATEGIES = ("fifo", "deadline", "priority", "hybrid", "stall_aware")


def _trace(mix, seed, ticks):
    gen = LoadGen(get_mix(mix), seed)
    return [[(a.name, a.klass, a.priority, a.deadline, a.lifetime)
             for a in gen.arrivals(t)] for t in range(ticks)]


# -- load generator ----------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_loadgen_deterministic_under_fixed_seed(seed):
    for mix in MIXES:
        assert _trace(mix, seed, 30) == _trace(mix, seed, 30)


def test_loadgen_seeds_and_mixes_decorrelate():
    assert _trace("poisson", 0, 40) != _trace("poisson", 1, 40)
    assert _trace("poisson", 0, 40) != _trace("bursty", 0, 40)


def test_loadgen_enforces_tick_order():
    gen = LoadGen(get_mix("poisson"), seed=0)
    gen.arrivals(0), gen.arrivals(1)
    with pytest.raises(ValueError, match="tick order"):
        gen.arrivals(1)


def test_loadgen_diurnal_ramp_modulates_rate():
    gen = LoadGen(get_mix("poisson"), seed=0)
    period = get_mix("poisson").diurnal_period
    peak = gen.rate_at(period // 4)        # sin = +1
    trough = gen.rate_at(3 * period // 4)  # sin = -1
    assert peak > gen.mix.rate > trough >= 0.0


def test_get_mix_unknown_lists_builtins():
    with pytest.raises(ValueError, match="poisson"):
        get_mix("nope")


# -- conservation + dominance (the benchmark's gates) ------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_conservation_invariant_every_tick(strategy):
    eng = make_slo_engine(strategy)
    stats = drive(eng, "deadline_heavy", ticks=40, seed=3, trace=True)
    for row in stats["per_tick"]:
        assert row["arrivals"] == (row["admitted"] + row["shed"]
                                   + row["expired"] + row["waiting"]), row
    assert stats["arrivals"] == (stats["admitted"] + stats["shed"]
                                 + stats["expired"] + stats["waiting"])
    assert stats["strategy"] == strategy
    assert stats["arrivals"] > 0 and stats["admitted"] > 0


def test_deadline_strategy_dominates_fifo_on_miss_rate():
    runs = {s: drive(make_slo_engine(s), "deadline_heavy", ticks=60, seed=3)
            for s in ("fifo", "deadline")}
    assert runs["deadline"]["deadline_arrivals"] > 0
    assert runs["deadline"]["miss_rate"] < runs["fifo"]["miss_rate"]


def test_drive_restores_prior_waiter_callback():
    seen = []
    prior = lambda name, ev: seen.append((name, ev))   # noqa: E731
    eng = make_slo_engine("deadline")
    eng.waiter_callback = prior
    drive(eng, "deadline_heavy", ticks=20, seed=0)
    assert eng.waiter_callback is prior
    assert seen, "prior callback must keep observing during a drive"


# -- admission strategies ----------------------------------------------------

def test_all_builtin_strategies_registered_and_selectable():
    assert set(STRATEGIES) <= set(registered_admissions())
    for s in STRATEGIES:
        assert make_slo_engine(s).admission_strategy == s
    assert get_admission("fifo").head_blocking
    assert not get_admission("deadline").head_blocking


def test_unknown_strategy_fails_at_engine_construction():
    with pytest.raises(ValueError, match="fifo"):
        make_slo_engine("nope")
    with pytest.raises(ValueError, match="nope"):
        get_admission("nope")


def test_register_and_unregister_custom_strategy():
    @register_admission("lifo_test")
    def lifo(waiters, ctx):
        return sorted(range(len(waiters)),
                      key=lambda i: -waiters[i][1].seq)
    try:
        assert "lifo_test" in registered_admissions()
        with pytest.raises(ValueError, match="already"):
            register_admission("lifo_test")(lambda w, c: [])
        eng = make_slo_engine("lifo_test")
        stats = drive(eng, "bursty", ticks=24, seed=1)
        assert stats["admitted"] > 0
    finally:
        unregister_admission("lifo_test")
    assert "lifo_test" not in registered_admissions()
    with pytest.raises(ValueError, match="built-in"):
        unregister_admission("fifo")


def test_malformed_strategy_permutation_is_rejected():
    @register_admission("broken_test")
    def broken(waiters, ctx):
        return [0] * len(waiters)
    try:
        eng = make_slo_engine("broken_test", tenant_queue_depth=4)
        active = _fill_pool(eng)
        for k in range(2):
            assert eng.open_tenant(f"w{k}", batch=1) is None   # queued
        with pytest.raises(ValueError, match="permutation"):
            eng.close_tenant(active[0])      # drain consults the strategy
    finally:
        unregister_admission("broken_test")


def test_hybrid_prefers_urgent_deadline_over_priority():
    fn = get_admission("hybrid")
    ctx = AdmissionContext(tick=10, klass_admits={"bulk": 50})
    # High-priority, frequently-admitted class vs a low-priority waiter
    # whose deadline is inside the urgency window: urgency wins.
    waiters = [
        (0, AdmissionTicket("rich", 1, klass="bulk", priority=9.0, seq=0)),
        (0, AdmissionTicket("urgent", 1, priority=0.1,
                            deadline=10 + HYBRID_SLACK, seq=1)),
    ]
    assert list(fn(waiters, ctx))[0] == 1


# -- S1: stable FIFO tie-breaking under permuted queue order -----------------

def _fill_pool(eng, prefix="fill"):
    """Open tenants until the pool is exhausted; returns the admitted
    names (the exhaustion probe is dequeued again, so the tenant queue
    is left empty)."""
    names = []
    while True:
        name = f"{prefix}{len(names)}"
        if eng.open_tenant(name, batch=1) is None:
            eng.tenant_queue.items[:] = [
                (at, tk) for at, tk in eng.tenant_queue.items
                if tk.name != name]
            return names
        names.append(name)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_equal_utility_waiters_admit_in_fifo_order(seed):
    for strategy in STRATEGIES:
        eng = make_slo_engine(strategy, tenant_queue_depth=8,
                              deadline_ticks=0)
        active = _fill_pool(eng)
        # Four waiters with identical deadline/priority/klass: only
        # arrival order may decide.  Shuffle the queue's backing list as
        # a stand-in for any dict/set iteration-order dependence.
        for k in range(4):
            assert eng.open_tenant(f"w{k}", batch=1, deadline=100,
                                   priority=2.0, klass="tie") is None
        rng = np.random.default_rng(seed)
        perm = rng.permutation(len(eng.tenant_queue.items))
        eng.tenant_queue.items[:] = [eng.tenant_queue.items[i]
                                     for i in perm]
        admitted = []
        eng.waiter_callback = (lambda name, ev: admitted.append(name)
                               if ev == "admitted" else None)
        for name in active:
            eng.close_tenant(name)
        assert admitted == [f"w{k}" for k in range(4)], strategy


# -- differential: vectorized control plane == scalar reference --------------

def _random_waiters(rng, n):
    """A permuted queue of n tickets with random annotations (seqs
    unique, list order scrambled — any strategy must ignore it)."""
    waiters = [(int(rng.integers(0, 64)), AdmissionTicket(
        name=f"d{i}", batch=int(rng.integers(1, 9)),
        klass=f"k{int(rng.integers(0, 5))}",
        priority=float(rng.choice([0.25, 1.0, 2.0, 4.0])),
        deadline=(None if rng.random() < 0.3
                  else int(rng.integers(0, 200))),
        seq=i)) for i in range(n)]
    return [waiters[int(i)] for i in rng.permutation(n)]


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_vector_order_matches_scalar_for_every_strategy(seed):
    rng = np.random.default_rng(seed)
    waiters = _random_waiters(rng, int(rng.integers(1, 90)))
    cols = TicketColumns()
    cols.rebuild(waiters)
    admits = {f"k{i}": int(rng.integers(0, 20)) for i in range(5)}
    tick = int(rng.integers(0, 200))
    for fab in ({}, {"stall_cycles": 10 * int(STALL_PRESSURE) + 999,
                     "scheduled": 10}):
        for name in registered_admissions():
            fn = get_admission(name)
            if fn.vector is None:
                continue
            ref = list(fn(waiters, AdmissionContext(tick, admits,
                                                    fabric=dict(fab))))
            vec = [int(x) for x in fn.vector(
                cols, AdmissionContext(tick, admits, fabric=dict(fab)))]
            assert vec == ref, (name, fab)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_vector_engine_behavior_identical_to_scalar(strategy):
    for mix in ("deadline_heavy", "bursty"):
        for seed in (0, 9):
            runs = {}
            for plane in CONTROL_PLANES:
                eng = make_slo_engine(strategy, control_plane=plane)
                runs[plane] = (drive(eng, mix, ticks=40, seed=seed,
                                     trace=True),
                               eng.transfer_telemetry())
            vec_stats, vec_tel = runs["vector"]
            sca_stats, sca_tel = runs["scalar"]
            assert vec_stats == sca_stats, (strategy, mix, seed)
            vec_tel.pop("control_plane"), sca_tel.pop("control_plane")
            assert vec_tel == sca_tel, (strategy, mix, seed)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_permuted_queue_drain_identical_across_planes(seed):
    for strategy in STRATEGIES:
        orders = {}
        for plane in CONTROL_PLANES:
            eng = make_slo_engine(strategy, tenant_queue_depth=16,
                                  deadline_ticks=0, control_plane=plane)
            active = _fill_pool(eng)
            rng = np.random.default_rng(seed)   # same draws per plane
            for k in range(10):
                assert eng.open_tenant(
                    f"w{k}", batch=1,
                    deadline=(int(rng.integers(1, 60))
                              if rng.random() < 0.7 else None),
                    priority=float(rng.choice([0.5, 1.0, 2.0])),
                    klass=f"k{int(rng.integers(0, 3))}") is None
            perm = rng.permutation(len(eng.tenant_queue.items))
            eng.tenant_queue.items[:] = [eng.tenant_queue.items[i]
                                         for i in perm]
            admitted = []
            eng.waiter_callback = (lambda n, ev, a=admitted:
                                   a.append(n) if ev == "admitted" else None)
            for name in active:
                eng.close_tenant(name)
            orders[plane] = admitted
        assert orders["vector"] == orders["scalar"], strategy


def test_unknown_control_plane_rejected_at_construction():
    with pytest.raises(ValueError, match="control plane"):
        make_slo_engine("fifo", control_plane="simd")


def test_custom_scalar_strategy_runs_on_vector_plane():
    # A registered strategy without a vector form must still drive a
    # vector-plane engine (scalar fallback inside _drain_order).
    @register_admission("lifo_vecless")
    def lifo(waiters, ctx):
        return sorted(range(len(waiters)),
                      key=lambda i: -waiters[i][1].seq)
    try:
        eng = make_slo_engine("lifo_vecless", control_plane="vector")
        stats = drive(eng, "bursty", ticks=24, seed=1)
        assert stats["admitted"] > 0
    finally:
        unregister_admission("lifo_vecless")


# -- stall_aware: telemetry-coupled admission --------------------------------

def test_stall_aware_goes_lightest_first_only_under_stall():
    fn = get_admission("stall_aware")
    waiters = [(0, AdmissionTicket("heavy", 8, deadline=5, seq=0)),
               (0, AdmissionTicket("light", 1, deadline=50, seq=1))]
    cols = TicketColumns()
    cols.rebuild(waiters)
    healthy = {"stall_cycles": 0, "scheduled": 10}
    stalled = {"stall_cycles": 100, "scheduled": 10}
    assert list(fn(waiters, AdmissionContext(0, {}, fabric=healthy))) \
        == [0, 1]                      # deadline order while healthy
    assert list(fn(waiters, AdmissionContext(0, {}, fabric=stalled))) \
        == [1, 0]                      # lightest-first once stalling
    assert [int(x) for x in fn.vector(
        cols, AdmissionContext(0, {}, fabric=stalled))] == [1, 0]


def test_admission_context_resolves_fabric_telemetry_lazily():
    calls = []

    def telemetry():
        calls.append(1)
        return {"stall_cycles": 4, "scheduled": 2}

    ctx = AdmissionContext(0, {}, fabric=telemetry)
    assert not calls, "telemetry must not be pulled before first access"
    assert ctx.stall_pressure() == 2.0
    assert ctx.stall_pressure() == 2.0
    assert calls == [1], "telemetry snapshot must resolve exactly once"
    assert AdmissionContext(0, {}).stall_pressure() == 0.0


# -- closed-loop clients: retry with seeded backoff --------------------------

def test_closed_loop_retries_conserve_and_reduce_final_sheds():
    base = drive(make_slo_engine("deadline"), "deadline_heavy",
                 ticks=80, seed=5)
    loop = drive(make_slo_engine("deadline"), "deadline_heavy",
                 ticks=80, seed=5, trace=True, retry_budget=3)
    assert loop["arrivals"] == base["arrivals"], \
        "enabling retries must not perturb the arrival trace"
    assert loop["retry_budget"] == 3
    assert loop["retries"] > 0 and loop["retry_admitted"] > 0
    assert loop["backoff_ticks"] >= loop["retries"]
    assert loop["shed"] < base["shed"]
    for row in loop["per_tick"]:
        assert row["arrivals"] == (row["admitted"] + row["shed"]
                                   + row["expired"] + row["waiting"]
                                   + row["retrying"]), row
    again = drive(make_slo_engine("deadline"), "deadline_heavy",
                  ticks=80, seed=5, trace=True, retry_budget=3)
    assert again == loop, "closed-loop drive must be seed-deterministic"


def test_open_loop_drive_reports_zero_retry_ledger():
    stats = drive(make_slo_engine("fifo"), "poisson", ticks=30, seed=2,
                  trace=True)
    assert stats["retries"] == stats["retry_admitted"] == 0
    assert stats["backoff_ticks"] == stats["retrying"] == 0
    assert all(row["retrying"] == 0 for row in stats["per_tick"])


# -- S2: exactly one terminal event ------------------------------------------

class _WideStub:
    """One in-place leaf per ``width`` unit — a tenant that can be sized
    to need more banks than the whole pool holds."""

    def __init__(self, width):
        self.width = width

    def init_caches(self, batch, max_len):
        return {f"s{i}": jnp.zeros((batch, 8), jnp.int8)
                for i in range(self.width)}


def test_shed_after_partial_reclaim_emits_single_terminal_event():
    # Pool: 16 leasable banks.  Fill with 8 idle 2-bank tenants, then ask
    # for a 20-bank tenant: reclaim evicts every idle tenant (partial
    # lease recovery) and the lease STILL fails -> exactly one "shed".
    events = []
    eng = Engine(model=CacheStub(), cfg=None, max_len=16,
                 cache_mesh=make_topology(mesh=(4, 4, 2)),
                 idle_evict_ticks=1, deadline_ticks=4, admission="queue",
                 tenant_queue_depth=0,    # always-full queue: shed path
                 waiter_callback=lambda n, ev: events.append((n, ev)))
    filled = [f"t{k}" for k in range(8)]
    for name in filled:
        assert eng.open_tenant(name, batch=1) is not None
    eng.schedule_tick([])                   # advance the clock: all idle
    eng.model = _WideStub(20)
    eng._leaf_cache.clear()                 # model swapped: re-probe leaves
    assert eng.open_tenant("big", batch=1) is None
    assert eng.n_idle_evictions == 8, "reclaim should have run to empty"
    assert events == [("big", "shed")]
    # Aging afterwards must not re-report the shed stream as expired.
    for _ in range(6):
        eng.schedule_tick([])
    assert [e for e in events if e[0] == "big"] == [("big", "shed")]
    assert eng.n_queue_expired == 0


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_terminal_events_are_exactly_once_per_stream(strategy):
    eng = make_slo_engine(strategy, tenant_queue_depth=6)
    events = []
    eng.waiter_callback = lambda n, ev: events.append((n, ev))
    drive(eng, "bursty", ticks=40, seed=5)
    terminal = collections.Counter(n for n, ev in events
                                   if ev in ("admitted", "expired", "shed"))
    dupes = {n: c for n, c in terminal.items() if c > 1}
    assert not dupes, f"streams with multiple terminal events: {dupes}"


def test_ticket_deadline_expires_even_without_global_aging():
    eng = make_slo_engine("fifo", deadline_ticks=0, tenant_queue_depth=4)
    events = []
    eng.waiter_callback = lambda n, ev: events.append((n, ev))
    _fill_pool(eng)
    assert eng.open_tenant("slo", batch=1, deadline=2) is None
    for _ in range(2):
        eng.schedule_tick()
    assert ("slo", "expired") not in events     # tick 2 == deadline: keep
    eng.schedule_tick()                         # tick 3 > deadline
    assert ("slo", "expired") in events
    assert eng.transfer_telemetry()["deadline_misses"] == 1
    assert eng.n_queue_expired == 1


def test_stale_deadline_admission_counts_as_late():
    eng = make_slo_engine("fifo", deadline_ticks=0, tenant_queue_depth=4)
    active = _fill_pool(eng)
    eng.schedule_tick(), eng.schedule_tick()    # engine tick -> 2
    # A client-supplied absolute deadline already in the past: the stream
    # still queues, and its eventual admission is a counted miss.
    assert eng.open_tenant("stale", batch=1, deadline=1) is None
    events = []
    eng.waiter_callback = lambda n, ev: events.append((n, ev))
    eng.close_tenant(active[0])                 # frees room -> late admit
    assert events == [("stale", "admitted")]
    assert eng.n_admitted_late == 1
    assert eng.n_deadline_misses == 1
    assert "stale" in eng.tenants()


# -- per-class telemetry -----------------------------------------------------

def test_per_class_telemetry_buckets_outcomes():
    eng = make_slo_engine("deadline")
    drive(eng, "deadline_heavy", ticks=40, seed=3)
    tel = eng.transfer_telemetry()
    classes = tel["admission_classes"]
    assert set(classes) == {"urgent", "bulk"}
    for klass, stats in classes.items():
        waiting = sum(1 for _at, tk in eng.tenant_queue.items
                      if tk.klass == klass)
        assert stats["arrivals"] == (stats["admitted"] + stats["shed"]
                                     + stats["expired"] + waiting)
    assert tel["deadline_misses"] == sum(
        c["deadline_misses"] for c in classes.values())
    assert tel["admission_wait_p99"] >= tel["admission_wait_p50"] >= 0.0
    assert tel["admission_strategy"] == "deadline"


# -- migrate_tenant mid-burst ------------------------------------------------

def test_migrate_tenant_mid_burst_preserves_state_and_telemetry():
    eng = Engine(model=CacheStub(), cfg=None, max_len=16,
                 cache_mesh=make_topology(2, mesh=(4, 4, 2)),
                 ring_slots=4, idle_evict_ticks=0, admission="queue",
                 admission_strategy="deadline", deadline_ticks=12,
                 tenant_queue_depth=16)
    mix = get_mix("bursty")
    gen = LoadGen(mix, seed=2)
    opened = []
    for t in range(mix.burst_every + 1):    # run into the second burst
        for a in gen.arrivals(t):
            if eng.open_tenant(a.name, a.batch, deadline=a.deadline,
                               priority=a.priority, klass=a.klass):
                opened.append(a.name)
        eng.schedule_tick()
    name = next(n for n in opened if n in eng.tenants())
    pos_before = eng._tenants[name].pos
    classes_before = eng.transfer_telemetry()["admission_classes"]
    dst = 1 - eng.pool.stack_of(eng.pool.leases(name)[0].home)
    # Guarantee room on the destination: park the queue (so closes do
    # not backfill) and retire other tenants until the stack can fit.
    eng.tenant_queue.items.clear()
    cap = (eng.pool.free_banks()
           + sum(eng.pool.stack_load().values())) // 2
    others = [n for n in eng.tenants() if n != name]
    while cap - eng.pool.stack_load().get(dst, 0) < 2 and others:
        eng.close_tenant(others.pop())
    report = eng.migrate_tenant(name, dst)
    assert report is not None and report.n_cross_stack > 0
    assert eng.n_migrations == 1
    # Tenant state survives: still active, same write position, homes on
    # the destination stack; the admission ledger is untouched.
    assert name in eng.tenants()
    assert eng._tenants[name].pos == pos_before
    assert all(eng.pool.stack_of(ls.home) == dst
               for ls in eng.pool.leases(name))
    assert eng.transfer_telemetry()["admission_classes"] == classes_before
    # The stream keeps scheduling after the move, mid-burst.
    eng.schedule_tick([name])
    assert eng._tenants[name].pos == pos_before + 1


# -- soak (deselected in tier-1; run with -m soak) ---------------------------

@pytest.mark.soak
@pytest.mark.parametrize("mix", sorted(MIXES))
def test_soak_long_runs_conserve_and_stay_bounded(mix):
    # 8x the PR-7 tick budget: the vectorized control plane and the
    # O(events) drive loop made the longer horizon affordable.
    eng = make_slo_engine("hybrid")
    stats = drive(eng, mix, ticks=12000, seed=11, trace=True)
    for row in stats["per_tick"]:
        assert row["arrivals"] == (row["admitted"] + row["shed"]
                                   + row["expired"] + row["waiting"]
                                   + row["retrying"]), row
    assert stats["arrivals"] > 10000
    assert len(eng.reports) <= eng.keep_reports
    assert len(eng.tenant_queue.wait_samples) <= eng.tenant_queue.keep_waits


@pytest.mark.soak
def test_soak_closed_loop_retries_conserve_at_length():
    eng = make_slo_engine("stall_aware")
    stats = drive(eng, "deadline_heavy", ticks=8000, seed=13, trace=True,
                  retry_budget=4)
    for row in stats["per_tick"]:
        assert row["arrivals"] == (row["admitted"] + row["shed"]
                                   + row["expired"] + row["waiting"]
                                   + row["retrying"]), row
    assert stats["retries"] > 0
    assert stats["arrivals"] > 10000
