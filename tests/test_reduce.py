"""Compute-class reduce: fan-in circuits, dwell occupancy, bit-identity
across commit paths, cross-stack trees, memsim timing/energy, and the
host-side collective planners."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.fabric import FabricCluster, NomFabric, ReduceTree
from repro.core.nom_collectives import nom_allreduce_banks, nom_reduce
from repro.core.scheduler import (ScheduleReport, TransferRequest,
                                  reduce_request)
from repro.core.slot_alloc import (CopyRequest, TdmAllocator,
                                   TdmAllocatorLight)
from repro.core.topology import Mesh3D, PORT_LOCAL, make_topology
from repro.memsim.energy import EnergyParams, energy_pj
from repro.memsim.simulator import SimParams, simulate
from repro.memsim.workloads import (Op, Request, WorkloadSpec, generate,
                                    traffic_breakdown)

MESH = Mesh3D(8, 8, 4)
N_SLOTS = 16


def _mixed_stream(seed: int, n: int, reduce_every: int = 3):
    """Random stream of copies with a fan-in reduce every few requests."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        if i % reduce_every == 0:
            k = int(rng.integers(2, 6))
            banks = rng.choice(MESH.n_nodes, size=k + 1, replace=False)
            reqs.append(CopyRequest(
                int(banks[0]), int(banks[-1]), int(rng.integers(64, 1024)),
                op="reduce", srcs=tuple(int(b) for b in banks[:-1])))
        else:
            s, d = rng.integers(MESH.n_nodes, size=2)
            while s == d:
                d = rng.integers(MESH.n_nodes)
            reqs.append(CopyRequest(int(s), int(d),
                                    int(rng.integers(64, 1024))))
    return reqs


# --- fan-in circuit structure and occupancy ---------------------------------
def test_fanin_circuit_structure_and_dwell_occupancy():
    """A k-way fan-in holds k arrival slots plus (k-1)*reduce_dwell
    ALU-dwell slots on the destination's LOCAL port — recounted from the
    circuit's own hop list (the oracle) and from the live slot table."""
    alloc = TdmAllocator(MESH, N_SLOTS)
    srcs = [MESH.node_id(1, 1, 0), MESH.node_id(5, 2, 1),
            MESH.node_id(2, 6, 2), MESH.node_id(7, 7, 3)]
    dst = MESH.node_id(4, 4, 1)
    res = alloc.allocate_batch(
        [CopyRequest(srcs[0], dst, 512, op="reduce", srcs=tuple(srcs))],
        cycle=0)[0]
    c = res.circuit
    assert c is not None and c.srcs == tuple(srcs)
    k, dwell = len(srcs), alloc.reduce_dwell
    local = [h for h in c.hops if h[0] == dst and h[1] == PORT_LOCAL]
    assert len(local) == k + (k - 1) * dwell
    # All reservation entries of the bundle are pairwise distinct.
    assert len(set(c.hops)) == len(c.hops)
    # The first route starts at srcs[0]: the fixed summation tree roots
    # the accumulator at the first-listed operand.
    assert c.hops[0][0] == srcs[0]
    # Live-table recount: the busy mask at the start window carries
    # exactly the bundle's LOCAL-port slots.
    occ = alloc.table._ports.masks_at(c.start_cycle // N_SLOTS)
    busy = bin(int(occ[dst, PORT_LOCAL])).count("1")
    assert busy == k + (k - 1) * dwell


def test_dwell_knob_scales_local_port_occupancy():
    srcs = (MESH.node_id(0, 0, 0), MESH.node_id(3, 0, 0),
            MESH.node_id(0, 3, 0))
    dst = MESH.node_id(2, 2, 0)
    for dwell in (0, 1, 3):
        alloc = TdmAllocator(MESH, N_SLOTS)
        alloc.reduce_dwell = dwell
        c = alloc.allocate_batch(
            [CopyRequest(srcs[0], dst, 64, op="reduce", srcs=srcs)],
            cycle=0)[0].circuit
        local = [h for h in c.hops if h[0] == dst and h[1] == PORT_LOCAL]
        assert len(local) == 3 + 2 * dwell, dwell


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_fanin_routes_are_slot_disjoint_property(seed):
    """Guarantee (1) extended to fan-ins: across a committed mixed batch
    no (router, port, slot) is claimed twice — reduce bundles included,
    checked from the circuits themselves."""
    alloc = TdmAllocator(MESH, N_SLOTS)
    results = alloc.allocate_batch(_mixed_stream(seed, 24), cycle=0)
    claimed = set()
    n_reduce = 0
    for res in results:
        if res.circuit is None:
            continue
        n_reduce += bool(res.circuit.srcs)
        for hop in res.circuit.hops:
            assert hop not in claimed, hop
            claimed.add(hop)
    assert n_reduce >= 1


def test_fanin_route_obeys_increasing_slot_invariant():
    """Each per-source route inside the bundle advances one slot per
    hop (guarantee 2); dwell entries continue the rotation after the
    arrival slot."""
    alloc = TdmAllocator(MESH, N_SLOTS)
    srcs = (MESH.node_id(1, 0, 0), MESH.node_id(0, 2, 0))
    dst = MESH.node_id(3, 3, 0)
    c = alloc.allocate_batch(
        [CopyRequest(srcs[0], dst, 64, op="reduce", srcs=srcs)],
        cycle=0)[0].circuit
    routes, cur = [], []
    for node, port, slot in c.hops:
        cur.append((node, port, slot))
        if node == dst and port == PORT_LOCAL and \
                (not routes or len(cur) > 1):
            routes.append(cur)
            cur = []
    for route in routes[:2]:   # the two operand routes
        slots = [s for _n, _p, s in route]
        for a, b in zip(slots, slots[1:]):
            assert (a + 1) % N_SLOTS == b


# --- bit-identity across commit paths ---------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_reduce_serial_vs_batch_bit_identical(seed):
    """A mixed copy+reduce stream committed one request at a time equals
    the batched commit bit for bit — circuits, hop lists, and the final
    slot table."""
    reqs = _mixed_stream(seed, 30)
    serial, batched = TdmAllocator(MESH, N_SLOTS), TdmAllocator(MESH, N_SLOTS)
    want = [serial.allocate_batch([r], cycle=0)[0] for r in reqs]
    got = batched.allocate_batch(reqs, cycle=0)
    for i, (w, g) in enumerate(zip(want, got)):
        assert (w.circuit is None) == (g.circuit is None), i
        if w.circuit is not None:
            assert w.circuit.start_cycle == g.circuit.start_cycle, i
            assert w.circuit.hops == g.circuit.hops, i
            assert w.circuit.srcs == g.circuit.srcs, i
    np.testing.assert_array_equal(serial.table.expiry, batched.table.expiry)


@pytest.mark.parametrize("seed", [3, 4])
def test_reduce_host_vs_fused_backend_bit_identical(seed):
    """The compiled commit pipeline and the host path schedule identical
    fan-ins (the reduce prepare is scalar on both backends by
    construction; the surrounding copies exercise the fused waves)."""
    reqs = _mixed_stream(seed, 48)
    host = TdmAllocator(MESH, N_SLOTS, backend="host")
    fused = TdmAllocator(MESH, N_SLOTS, backend="fused")
    rh = host.allocate_batch(list(reqs), cycle=0)
    rf = fused.allocate_batch(list(reqs), cycle=0)
    for i, (h, f) in enumerate(zip(rh, rf)):
        assert (h.circuit is None) == (f.circuit is None), i
        if h.circuit is not None:
            assert h.circuit.hops == f.circuit.hops, i
            assert h.circuit.srcs == f.circuit.srcs, i
    np.testing.assert_array_equal(host.table.expiry, fused.table.expiry)


def test_fixed_tree_is_reproducible_and_source_ordered():
    """Two fresh allocators produce byte-identical fan-ins; reversing
    the source list roots the tree at the other end."""
    srcs = (MESH.node_id(0, 0, 0), MESH.node_id(7, 7, 3))
    dst = MESH.node_id(4, 4, 2)
    circs = []
    for order in (srcs, srcs, srcs[::-1]):
        alloc = TdmAllocator(MESH, N_SLOTS)
        circs.append(alloc.allocate_batch(
            [CopyRequest(order[0], dst, 128, op="reduce", srcs=order)],
            cycle=0)[0].circuit)
    assert circs[0].hops == circs[1].hops
    assert circs[2].hops[0][0] == srcs[1]   # reversed order, other root


# --- request validation and backend contracts --------------------------------
def test_reduce_request_validation():
    with pytest.raises(ValueError):
        reduce_request([], 3)
    with pytest.raises(ValueError):
        reduce_request([1, 1], 3)
    with pytest.raises(ValueError):
        reduce_request([1, 3], 3)        # dst among sources
    r = reduce_request([1, 2], 3, nbytes=64)
    assert r.op == "reduce" and r.srcs == (1, 2)


def test_rounds_backend_rejects_reduce():
    ring = NomFabric(shape=(8,), torus=True)
    with pytest.raises(ValueError, match="nom_allreduce"):
        ring.schedule([reduce_request([(1,), (2,)], (0,), nbytes=64)])


def test_nom_light_rejects_cross_layer_sources():
    light = TdmAllocatorLight(MESH, N_SLOTS)
    srcs = (MESH.node_id(1, 1, 0), MESH.node_id(2, 2, 3))  # two layers
    with pytest.raises(ValueError, match="same-layer"):
        light.allocate_batch(
            [CopyRequest(srcs[0], MESH.node_id(4, 4, 0), 64,
                         op="reduce", srcs=srcs)], cycle=0)


def test_fabric_session_counts_and_policy_context_fanin():
    fab = NomFabric(mesh=make_topology(1, mesh=(4, 4, 2)))
    seen = {}

    from repro.core.fabric import register_policy, unregister_policy

    @register_policy("probe_fanin")
    def probe(reqs, ctx):
        seen["fanin"] = ctx.fanin
        seen["dist"] = ctx.distances
        return list(range(len(reqs)))

    try:
        _res, rep = fab.schedule(
            [reduce_request([1, 2, 3, 9], 0, nbytes=128),
             TransferRequest(src=5, dst=6, nbytes=128)],
            policy="probe_fanin")
    finally:
        unregister_policy("probe_fanin")
    assert rep.n_reduce == 1 and rep.n_scheduled == 2
    assert seen["fanin"] == (4, 1)
    mesh = fab.mesh
    assert seen["dist"][0] == max(mesh.manhattan(s, 0) for s in (1, 2, 3, 9))
    assert fab.telemetry()["reduce_requests"] == 1


# --- cross-stack reduce trees -------------------------------------------------
def _cluster():
    return FabricCluster(topology=make_topology(2, mesh=(4, 4, 2)))


def test_cross_stack_reduce_builds_tree():
    cluster = _cluster()
    t = reduce_request([(0, 5), (0, 9), (1, 6), (1, 10)], (0, 2), nbytes=256)
    (res,), rep = cluster.schedule([t])
    tree = res.circuit
    assert isinstance(tree, ReduceTree) and tree.cross_stack
    assert len(tree.legs) == 1          # one SerDes leg for stack 1
    assert len(tree.partials) == 1      # stack 1 partial at its bridge
    assert tree.local is not None       # stack-0 operands fan in locally
    # Store-and-forward: the leg cannot inject before its partial drains.
    assert tree.legs[0].start_cycle >= tree.partials[0].end_cycle
    assert rep.n_reduce == 1
    tel = cluster.telemetry()
    assert tel["cross_reduce_trees"] == 1 and tel["reduce_rollbacks"] == 0


def test_cross_stack_reduce_rollback_is_byte_identical():
    """Saturate the destination bank's LOCAL port so the tree's local
    fan-in cannot commit: the whole tree must roll back leaving every
    slot table and the SerDes link state untouched."""
    cluster = _cluster()
    mesh0 = cluster.topology.stacks[0]
    dst = 2
    # 16 long same-stack copies into dst fill all LOCAL-port slots for
    # hundreds of windows past cycle 0, far beyond the search wave.
    fill = [TransferRequest(src=(s + 3) % mesh0.n_nodes, dst=dst,
                            nbytes=8 * N_SLOTS * 256,
                            src_stack=0, dst_stack=0)
            for s in range(N_SLOTS + 8)]
    cluster.schedule(fill, cycle=0)
    saved, link_windows = cluster._tree_snapshot()
    before = [exp.copy() for _pe, exp in saved]
    # One stack-1 partial + SerDes leg commit first; the local fan-in at
    # the saturated destination then fails, unwinding both.  Pinning the
    # anchor at cycle 0 stops the tree from sliding past the fill.
    t = reduce_request([(1, 5), (1, 9), (0, 6)], (0, dst), nbytes=256)
    (res,), _rep = cluster.schedule([t], cycle=0)
    assert res.circuit is None
    assert cluster.telemetry()["reduce_rollbacks"] == 1
    after, after_links = cluster._tree_snapshot()
    for (pe, _), exp in zip(after, before):
        np.testing.assert_array_equal(pe.expiry, exp)
    assert after_links == link_windows


def test_same_stack_reduce_localizes_to_stack_fabric():
    cluster = _cluster()
    t = reduce_request([(1, 5), (1, 9)], (1, 2), nbytes=128)
    (res,), rep = cluster.schedule([t])
    c = res.circuit
    assert not isinstance(c, ReduceTree) and c.srcs == (5, 9)
    assert rep.n_reduce == 1 and rep.n_cross_stack == 0
    assert cluster.telemetry()["cross_reduce_trees"] == 0


# --- memsim: timing, backpressure, energy ------------------------------------
def test_gradagg_breakdown_has_reduce_share():
    reqs = generate(WorkloadSpec("gradAgg40", n_requests=4000))
    mix = traffic_breakdown(reqs)
    assert abs(mix["reduce"] - 0.40) < 0.05
    assert any(r.op == Op.REDUCE and len(r.src_banks) == 4 for r in reqs)


def test_memsim_reduce_elems_and_energy():
    """Every fan-in merges (k-1) * nbytes/8 elements at the destination
    ALU; the energy model charges e_reduce_elem per element on the nom
    config and nothing on configs that never engage the fabric ALU."""
    reqs = [Request(Op.REDUCE, 3, 0, 40, 1, nbytes=4096,
                    src_banks=(3, 17, 25, 33)),
            Request(Op.REDUCE, 5, 2, 80, 3, nbytes=4096,
                    src_banks=(5, 50))]
    res = simulate(reqs, SimParams(config="nom"))
    want = 3 * (4096 // 8) + 1 * (4096 // 8)
    assert res.extra["nom_reduce_elems"] == want
    e = energy_pj(res)
    assert e["reduce_alu"] == pytest.approx(
        want * EnergyParams().e_reduce_elem)
    conv = simulate(reqs, SimParams(config="conventional"))
    assert conv.extra.get("nom_reduce_elems", 0) == 0
    assert energy_pj(conv)["reduce_alu"] == 0.0
    # Instruction/byte accounting is config-independent: (k+1) lines
    # touched per line of payload, k operand pages moved.
    assert res.instructions == conv.instructions
    assert res.copy_bytes == conv.copy_bytes == 4096 * 4 + 4096 * 2


def test_memsim_busy_alu_backpressures_second_fanin():
    """Two immediate fan-ins at one destination: the second arrives
    while the first still owns the ALU (transfer + dwell windows) and
    must wait — visible as nom_reduce_stalls."""
    reqs = [Request(Op.REDUCE, 3, 0, 40, 1, nbytes=4096,
                    src_banks=(3, 17, 25, 33)),
            Request(Op.REDUCE, 5, 2, 40, 3, nbytes=4096,
                    src_banks=(5, 50, 66, 70))]
    res = simulate(reqs, SimParams(config="nom"))
    assert res.extra["nom_reduce_stalls"] >= 1
    far = [Request(Op.REDUCE, 3, 0, 40, 1, nbytes=4096,
                   src_banks=(3, 17, 25, 33)),
           Request(Op.REDUCE, 5, 2, 90, 3, nbytes=4096,
                   src_banks=(5, 50, 66, 70))]
    res2 = simulate(far, SimParams(config="nom"))
    assert res2.extra["nom_reduce_stalls"] == 0   # distinct destinations


def test_memsim_nom_beats_conventional_on_gradagg():
    spec = WorkloadSpec("gradAgg40", n_requests=1200)
    reqs = generate(spec)
    ipc = {cfg: simulate(reqs, SimParams(config=cfg)).ipc
           for cfg in ("conventional", "rowclone", "nom")}
    assert ipc["nom"] > ipc["rowclone"] > ipc["conventional"]


# --- host-side collective planners -------------------------------------------
def test_nom_reduce_planner_roundtrip():
    fab = NomFabric(mesh=make_topology(1, mesh=(4, 4, 2)))
    res, rep = nom_reduce(fab, srcs=[1, 2, 3], dst=0, nbytes=256)
    assert rep.n_reduce == 1 and res.circuit.srcs == (1, 2, 3)


def test_nom_allreduce_banks_window_accounting():
    """len(banks) scatter fan-ins + len(banks)*(len(banks)-1) gather
    copies, all through one session; every bank both reduces its shard
    and receives every peer's reduced shard."""
    fab = NomFabric(mesh=make_topology(1, mesh=(4, 4, 2)))
    banks = [0, 5, 10, 15]
    results, rep = nom_allreduce_banks(fab, banks, nbytes=4096)
    n = len(banks)
    assert len(results) == n + n * (n - 1)
    assert rep.n_reduce == n
    assert rep.n_scheduled == n + n * (n - 1)
    # Shards partition the vector: ceil(nbytes / n) bytes per fan-in.
    shard = -(-4096 // n)
    scatter = results[:n]
    for res in scatter:
        assert res.circuit.srcs and len(res.circuit.srcs) == n - 1
        assert res.circuit.n_windows >= fab.allocator.n_windows_for(shard)
    assert fab.telemetry()["reduce_requests"] == n
    with pytest.raises(ValueError):
        nom_allreduce_banks(fab, [1, 1, 2], nbytes=64)
    with pytest.raises(ValueError):
        nom_allreduce_banks(fab, [1], nbytes=64)


def _tdm_report(n: int, stall: int, conflicts: int) -> ScheduleReport:
    return ScheduleReport(backend="tdm", n_requests=n, n_scheduled=n,
                          n_windows=1, max_inflight=n, avg_inflight=1.0,
                          stall_cycles=stall, conflicts=conflicts)


def test_auto_policy_learns_extra_slots():
    """Satellite: the auto policy's slot-budget tuner grows
    ``nom_extra_slots`` on stall-heavy, conflict-free flush reports
    (capped at half the TDM frame), shrinks it back under commit
    conflicts, and the live session actually applies the learned budget
    to bare copies that did not ask for a wider one."""
    fab = NomFabric(mesh=make_topology(1, mesh=(4, 4, 2)), policy="auto")
    assert fab.telemetry()["nom_extra_slots"] == 0
    cap = fab.n_slots // 2 - 1
    # Grow regime: stalls past a full frame per request, clean commits.
    for _ in range(cap + 3):
        fab._auto_extra_slots(
            _tdm_report(4, stall=4 * (fab.n_slots + 1), conflicts=0))
    assert fab.telemetry()["nom_extra_slots"] == cap
    # The learned budget widens a bare copy on an idle corridor: the
    # `_schedule_tdm` path rewrites max_extra_slots before allocation.
    (res,), _rep = fab.schedule(
        [TransferRequest(src=20, dst=23, nbytes=1 << 14)])
    assert res.circuit.slots_per_window > 1
    # Shrink regime: conflict rate over a quarter of the batch backs off
    # one step per flush, never below zero.
    for _ in range(cap + 2):
        fab._auto_extra_slots(_tdm_report(4, stall=0, conflicts=2))
    assert fab.telemetry()["nom_extra_slots"] == 0
    # Quiet flushes leave the budget untouched.
    fab._auto_extra_slots(_tdm_report(4, stall=0, conflicts=0))
    assert fab.telemetry()["nom_extra_slots"] == 0
