"""TDM slot-allocation invariants (the paper's Section 2.1 guarantees)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import schedule_transfers
from repro.core.slot_alloc import (CopyRequest, TdmAllocator,
                                   TdmAllocatorLight)
from repro.core.topology import Mesh3D, PORT_LOCAL

MESH = Mesh3D(8, 8, 4)
N_SLOTS = 16


def _random_stream(seed: int, n: int, with_extras: bool = True):
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        s, d = rng.integers(MESH.n_nodes, size=2)
        while s == d:
            d = rng.integers(MESH.n_nodes)
        reqs.append(CopyRequest(
            int(s), int(d), int(rng.integers(64, 4096)),
            max_extra_slots=int(rng.integers(0, 4)) if with_extras else 0))
    return reqs


def test_basic_circuit_structure():
    alloc = TdmAllocator(MESH, N_SLOTS)
    src, dst = MESH.node_id(0, 0, 0), MESH.node_id(5, 3, 2)
    c = alloc.allocate(src, dst, 4096, cycle=0).circuit
    assert c is not None
    dist = MESH.manhattan(src, dst)
    assert len(c.hops) == dist + 1
    assert c.hops[0][0] == src
    assert c.hops[-1] == (dst, PORT_LOCAL, c.hops[-1][2])
    # Guarantee (2): increasingly-numbered slots along the path.
    slots = [h[2] for h in c.hops]
    for a, b in zip(slots, slots[1:]):
        assert (a + 1) % N_SLOTS == b
    # 3-cycle setup: injection cannot precede t+3 (paper Section 2.2).
    assert c.start_cycle >= 3
    assert c.start_cycle % N_SLOTS == slots[0]


@settings(max_examples=25, deadline=None)
@given(st.integers(0, MESH.n_nodes - 1), st.integers(0, MESH.n_nodes - 1),
       st.integers(0, 10))
def test_no_double_booking_property(src, dst, n_extra):
    """Guarantee (1): no slot of a link is shared by two circuits — the
    SlotTable asserts on double-booking, so allocating a random request
    stream must never trip it."""
    if src == dst:
        return
    alloc = TdmAllocator(MESH, N_SLOTS)
    rng = np.random.default_rng(src * 1000 + dst)
    alloc.allocate(src, dst, 512, cycle=0, max_extra_slots=n_extra % 4)
    for i in range(10):
        s, d = rng.integers(MESH.n_nodes, size=2)
        if s != d:
            alloc.allocate(int(s), int(d), 512, cycle=i * 2,
                           max_extra_slots=i % 3)


def test_saturation_and_rejection():
    alloc = TdmAllocator(MESH, N_SLOTS)
    src, dst = 0, 1
    got = 0
    for i in range(N_SLOTS + 4):
        if alloc.allocate(src, dst, 8 * N_SLOTS * 100, cycle=i).circuit:
            got += 1
    # one-hop pair: exactly n_slots circuits fit, further requests fail
    assert got == N_SLOTS


def test_nom_light_same_layer_matches_full():
    full = TdmAllocator(MESH, N_SLOTS)
    light = TdmAllocatorLight(MESH, N_SLOTS)
    src, dst = MESH.node_id(1, 1, 2), MESH.node_id(6, 4, 2)
    cf = full.allocate(src, dst, 1024, 0).circuit
    cl = light.allocate(src, dst, 1024, 0).circuit
    assert cf.start_cycle == cl.start_cycle
    assert len(cf.hops) == len(cl.hops)


def test_nom_light_uses_bus_across_layers():
    light = TdmAllocatorLight(MESH, N_SLOTS)
    src, dst = MESH.node_id(1, 1, 0), MESH.node_id(4, 2, 3)
    c = light.allocate(src, dst, 1024, 0).circuit
    assert c.uses_bus and c.bus_column >= 0
    # vertical bus: one slot regardless of layer count (single-cycle
    # multi-hop, Section 2.3) => distance = XY hops + 1
    assert c.distance == abs(4 - 1) + abs(2 - 1) + 1


def test_bus_contention_serializes():
    light = TdmAllocatorLight(MESH, N_SLOTS)
    col_src = MESH.node_id(2, 2, 0)
    # saturate the (2,2) column's bus with long transfers
    starts = []
    for i in range(N_SLOTS):
        c = light.allocate(col_src, MESH.node_id(2, 2, 3),
                           8 * N_SLOTS * 64, cycle=0).circuit
        if c is None:
            break
        starts.append(c.start_cycle)
    assert len(set(starts)) == len(starts)  # all distinct slots
    # bus fully reserved now
    res = light.allocate(col_src, MESH.node_id(2, 2, 1), 64, cycle=0)
    assert res.circuit is None


# --- concurrent batched scheduler -------------------------------------------
@pytest.mark.parametrize("cls", [TdmAllocator, TdmAllocatorLight])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_batched_equals_serial_on_identical_stream(cls, seed):
    """allocate_batch must be bit-identical to servicing the same stream
    through allocate() one request at a time (same circuits, same hops,
    same final table state) — losers of a stale search round are retried
    against fresh state, so no divergence is possible."""
    reqs = _random_stream(seed, 48)
    serial, batched = cls(MESH, N_SLOTS), cls(MESH, N_SLOTS)
    want = [serial.allocate(r.src, r.dst, r.nbytes, 0, r.max_extra_slots)
            for r in reqs]
    got = batched.allocate_batch(reqs, cycle=0)
    for i, (w, g) in enumerate(zip(want, got)):
        assert (w.circuit is None) == (g.circuit is None), i
        if w.circuit is not None:
            assert w.circuit.start_cycle == g.circuit.start_cycle, i
            assert w.circuit.hops == g.circuit.hops, i
            assert w.circuit.n_windows == g.circuit.n_windows, i
    np.testing.assert_array_equal(serial.table.expiry, batched.table.expiry)
    np.testing.assert_array_equal(serial.table.bus_expiry,
                                  batched.table.bus_expiry)
    rep = batched.last_report
    assert rep.n_committed + rep.n_denied == len(reqs)
    # the whole point: far fewer vectorized passes than requests
    assert rep.search_rounds < len(reqs)


@pytest.mark.parametrize("cls", [TdmAllocator, TdmAllocatorLight])
def test_batched_circuits_are_slot_disjoint(cls):
    """Invariant: no two circuits committed for one window share a
    (router, port, slot) — checked from the circuits themselves, not the
    table bookkeeping."""
    alloc = cls(MESH, N_SLOTS)
    results = alloc.allocate_batch(_random_stream(7, 64), cycle=0)
    claimed: set[tuple[int, int, int]] = set()
    committed = 0
    for res in results:
        if res.circuit is None:
            continue
        committed += 1
        for hop in res.circuit.hops:
            assert hop not in claimed, hop
            claimed.add(hop)
    assert committed > 1   # the schedule is actually concurrent


def test_batched_scheduler_unified_entry_reports_concurrency():
    alloc = TdmAllocator(MESH, N_SLOTS)
    results, report = schedule_transfers(_random_stream(3, 32),
                                         allocator=alloc, cycle=0)
    assert report.backend == "tdm"
    assert report.n_scheduled == sum(r.circuit is not None for r in results)
    assert report.max_inflight > 1       # concurrent circuits per window
    assert report.search_rounds < report.n_requests


def test_batch_respects_per_request_cycle_anchor():
    alloc = TdmAllocator(MESH, N_SLOTS)
    reqs = [CopyRequest(0, 5, 256), CopyRequest(8, 13, 256, cycle=40)]
    r0, r1 = alloc.allocate_batch(reqs, cycle=0)
    assert r0.circuit.start_cycle >= 3
    assert r1.circuit.start_cycle >= 43   # anchored request injects later


def test_anchored_request_reserved_through_streaming_interval():
    """Regression: a cycle-anchored request must hold its slots for its
    actual streaming interval (anchored at its own window, as serial
    allocate would), not the batch window — otherwise a later allocation
    can double-book the still-live circuit."""
    alloc = TdmAllocator(MESH, N_SLOTS)
    (_r0, r1) = alloc.allocate_batch(
        [CopyRequest(3, 9, 64), CopyRequest(0, 5, 2048, cycle=80)], cycle=0)
    serial = TdmAllocator(MESH, N_SLOTS)
    want = serial.allocate(0, 5, 2048, cycle=80).circuit
    c = r1.circuit
    w_res = 83 // N_SLOTS
    for node, port, slot in c.hops:
        assert alloc.table.expiry[node, port, slot] == w_res + c.n_windows
    assert want.n_windows == c.n_windows
    # a copy requested while the circuit is still streaming must not be
    # granted any of its hops (the reserve() assert would also trip)
    mid = (w_res + c.n_windows - 1) * N_SLOTS
    res = alloc.allocate(0, 5, 64, cycle=mid)
    if res.circuit is not None:
        assert not set(res.circuit.hops) & set(c.hops)


def test_memsim_inflight_cap_binds():
    from repro.memsim import SimParams, WorkloadSpec, generate, simulate
    reqs = generate(WorkloadSpec("fileCopy60", n_requests=400, seed=2))
    free = simulate(reqs, SimParams(config="nom", window=64))
    capped = simulate(reqs, SimParams(config="nom", window=64,
                                      nom_max_inflight=2))
    assert free.extra["nom_inflight_max"] > 2
    assert capped.extra["nom_inflight_max"] <= 2


def test_memsim_reports_concurrent_inflight_circuits():
    """The headline property end-to-end: on the TSV-conflict workload the
    simulator must keep more than one NoM circuit in flight per TDM window
    (and the allocator's own asserts guarantee slot-disjointness)."""
    from repro.memsim import SimParams, WorkloadSpec, generate, simulate
    reqs = generate(WorkloadSpec("fileCopy60", n_requests=800, seed=2))
    r = simulate(reqs, SimParams(config="nom", window=64))
    assert r.extra["nom_inflight_avg"] > 1.0, r.extra
    assert r.extra["nom_inflight_max"] >= 2


def test_windows_expire_and_slots_recycle():
    alloc = TdmAllocator(MESH, N_SLOTS)
    c1 = alloc.allocate(0, 3, 64, cycle=0).circuit   # short: few windows
    much_later = (c1.n_windows + 2) * N_SLOTS
    c2 = alloc.allocate(0, 3, 64, cycle=much_later).circuit
    assert c2 is not None
    assert c2.hops[0][2] in range(N_SLOTS)
