"""TDM slot-allocation invariants (the paper's Section 2.1 guarantees)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.slot_alloc import TdmAllocator, TdmAllocatorLight
from repro.core.topology import Mesh3D, PORT_LOCAL

MESH = Mesh3D(8, 8, 4)
N_SLOTS = 16


def test_basic_circuit_structure():
    alloc = TdmAllocator(MESH, N_SLOTS)
    src, dst = MESH.node_id(0, 0, 0), MESH.node_id(5, 3, 2)
    c = alloc.allocate(src, dst, 4096, cycle=0).circuit
    assert c is not None
    dist = MESH.manhattan(src, dst)
    assert len(c.hops) == dist + 1
    assert c.hops[0][0] == src
    assert c.hops[-1] == (dst, PORT_LOCAL, c.hops[-1][2])
    # Guarantee (2): increasingly-numbered slots along the path.
    slots = [h[2] for h in c.hops]
    for a, b in zip(slots, slots[1:]):
        assert (a + 1) % N_SLOTS == b
    # 3-cycle setup: injection cannot precede t+3 (paper Section 2.2).
    assert c.start_cycle >= 3
    assert c.start_cycle % N_SLOTS == slots[0]


@settings(max_examples=25, deadline=None)
@given(st.integers(0, MESH.n_nodes - 1), st.integers(0, MESH.n_nodes - 1),
       st.integers(0, 10))
def test_no_double_booking_property(src, dst, n_extra):
    """Guarantee (1): no slot of a link is shared by two circuits — the
    SlotTable asserts on double-booking, so allocating a random request
    stream must never trip it."""
    if src == dst:
        return
    alloc = TdmAllocator(MESH, N_SLOTS)
    rng = np.random.default_rng(src * 1000 + dst)
    alloc.allocate(src, dst, 512, cycle=0, max_extra_slots=n_extra % 4)
    for i in range(10):
        s, d = rng.integers(MESH.n_nodes, size=2)
        if s != d:
            alloc.allocate(int(s), int(d), 512, cycle=i * 2,
                           max_extra_slots=i % 3)


def test_saturation_and_rejection():
    alloc = TdmAllocator(MESH, N_SLOTS)
    src, dst = 0, 1
    got = 0
    for i in range(N_SLOTS + 4):
        if alloc.allocate(src, dst, 8 * N_SLOTS * 100, cycle=i).circuit:
            got += 1
    # one-hop pair: exactly n_slots circuits fit, further requests fail
    assert got == N_SLOTS


def test_nom_light_same_layer_matches_full():
    full = TdmAllocator(MESH, N_SLOTS)
    light = TdmAllocatorLight(MESH, N_SLOTS)
    src, dst = MESH.node_id(1, 1, 2), MESH.node_id(6, 4, 2)
    cf = full.allocate(src, dst, 1024, 0).circuit
    cl = light.allocate(src, dst, 1024, 0).circuit
    assert cf.start_cycle == cl.start_cycle
    assert len(cf.hops) == len(cl.hops)


def test_nom_light_uses_bus_across_layers():
    light = TdmAllocatorLight(MESH, N_SLOTS)
    src, dst = MESH.node_id(1, 1, 0), MESH.node_id(4, 2, 3)
    c = light.allocate(src, dst, 1024, 0).circuit
    assert c.uses_bus and c.bus_column >= 0
    # vertical bus: one slot regardless of layer count (single-cycle
    # multi-hop, Section 2.3) => distance = XY hops + 1
    assert c.distance == abs(4 - 1) + abs(2 - 1) + 1


def test_bus_contention_serializes():
    light = TdmAllocatorLight(MESH, N_SLOTS)
    col_src = MESH.node_id(2, 2, 0)
    # saturate the (2,2) column's bus with long transfers
    starts = []
    for i in range(N_SLOTS):
        c = light.allocate(col_src, MESH.node_id(2, 2, 3),
                           8 * N_SLOTS * 64, cycle=0).circuit
        if c is None:
            break
        starts.append(c.start_cycle)
    assert len(set(starts)) == len(starts)  # all distinct slots
    # bus fully reserved now
    res = light.allocate(col_src, MESH.node_id(2, 2, 1), 64, cycle=0)
    assert res.circuit is None


def test_windows_expire_and_slots_recycle():
    alloc = TdmAllocator(MESH, N_SLOTS)
    c1 = alloc.allocate(0, 3, 64, cycle=0).circuit   # short: few windows
    much_later = (c1.n_windows + 2) * N_SLOTS
    c2 = alloc.allocate(0, 3, 64, cycle=much_later).circuit
    assert c2 is not None
    assert c2.hops[0][2] in range(N_SLOTS)
