"""MoE bucketing properties, data-pipeline determinism, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import DataConfig, batch_at
from repro.models.moe import MoE, MoEConfig, bucket_by
from repro.optim.compression import (compress_with_feedback, dequantize_int8,
                                     init_residuals, quantize_int8)

KEY = jax.random.PRNGKey(0)


# --- bucket_by ---------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=64),
       st.integers(1, 16))
def test_bucket_by_properties(ids, cap):
    ids_a = jnp.asarray(ids, jnp.int32)
    pos, keep = bucket_by(ids_a, 8, cap)
    pos, keep = np.asarray(pos), np.asarray(keep)
    for b in range(8):
        sel = [p for p, i in zip(pos, ids) if i == b]
        # order-preserving, consecutive from 0 within each bucket
        assert sel == list(range(len(sel)))
        kept = [k for k, i in zip(keep, ids) if i == b]
        # exactly the first `cap` fit
        assert sum(kept) == min(len(sel), cap)


def test_moe_einsum_grad_finite(mesh1):
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=4, top_k=2,
                    dispatch="einsum")
    moe = MoE(cfg)
    p = moe.init(KEY)
    x = jax.random.normal(KEY, (2, 8, 16))

    def loss(p):
        y, aux = moe.apply(p, x)
        return jnp.mean(jnp.square(y)) + aux
    g = jax.grad(loss)(p)
    assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
    # router must receive gradient (through the combine weights)
    assert float(jnp.abs(g["router"]).sum()) > 0


# --- data pipeline ------------------------------------------------------------
def test_data_deterministic_and_restartable():
    cfg = DataConfig(vocab=512, batch=8, seq=64, seed=3)
    a = batch_at(cfg, 7)["tokens"]
    b = batch_at(cfg, 7)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = batch_at(cfg, 8)["tokens"]
    assert not np.array_equal(a, c)


def test_data_host_sharding_partitions_batch():
    full = batch_at(DataConfig(vocab=64, batch=8, seq=16, seed=0), 3)
    h0 = batch_at(DataConfig(vocab=64, batch=8, seq=16, seed=0,
                             n_hosts=2, host_id=0), 3)
    assert h0["tokens"].shape == (4, 16)


def test_learnable_structure_exists():
    cfg = DataConfig(vocab=512, batch=4, seq=64, seed=0)
    t = batch_at(cfg, 0)["tokens"]
    np.testing.assert_array_equal(t[:, 1::2], (t[:, 0::2] * 7 + 13) % 512)


# --- gradient compression -------------------------------------------------------
def test_quantize_roundtrip_error_bounded():
    x = jnp.asarray(np.random.RandomState(0).randn(256) * 3)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) / 2 + 1e-9


def test_error_feedback_residual_bounded():
    """With error feedback the residual stays bounded (contraction), so the
    compressed stream tracks the true gradient sum."""
    rng = np.random.RandomState(1)
    res = jnp.zeros((128,))
    true_sum = np.zeros((128,))
    deq_sum = np.zeros((128,))
    for i in range(50):
        g = jnp.asarray(rng.randn(128))
        q, s, res = compress_with_feedback(g, res)
        deq_sum += np.asarray(dequantize_int8(q, s))
        true_sum += np.asarray(g)
        assert float(jnp.abs(res).max()) < 3.0   # bounded residual
    # accumulated compressed stream tracks the true sum
    assert np.abs(deq_sum - true_sum).max() < 3.0
