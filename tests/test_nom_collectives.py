"""NOM-scheduled collectives: equivalence with lax references on a real
8-device mesh (subprocess) + planner properties (hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.nom_collectives import (Transfer, a2a_link_chunks,
                                        plan_transfers, ring_offsets)

from conftest import run_multidevice


def test_ring_offsets_cover_all_distances():
    for n in (2, 3, 4, 5, 8, 16):
        offs = ring_offsets(n)
        dests = sorted({o % n for o in offs})
        assert dests == list(range(1, n)), (n, offs)
        assert len(offs) == n - 1   # each distance exactly once


def test_a2a_link_chunks_beats_bus():
    for n in (4, 8, 16):
        c = a2a_link_chunks(n)
        assert c["nom_right"] + c["nom_left"] < c["bus_serialized"]


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3),
                          st.integers(0, 3), st.integers(0, 3)),
                min_size=1, max_size=24))
def test_plan_transfers_rounds_are_link_disjoint(pairs):
    transfers = [Transfer((a, b), (c, d)) for a, b, c, d in pairs
                 if (a, b) != (c, d)]
    if not transfers:
        return
    plan = plan_transfers((4, 4), transfers)
    for rnd in plan.rounds():
        hops = [h for _i, h in rnd]
        assert len(hops) == len(set(hops))
    # increasing-slot invariant: hop i of transfer t runs in round start+i
    for s, path in zip(plan.starts, plan.paths):
        assert s >= 0 and len(path) <= 4 + 4  # torus shortest <= diam


@pytest.mark.slow
def test_collectives_match_lax_on_8_devices():
    out = run_multidevice("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core import nom_all_to_all, nom_all_gather, nom_reduce_scatter
mesh = make_mesh((8,), ("x",))
xs = jnp.arange(8*8*4, dtype=jnp.float32).reshape(64, 4)
f = shard_map(lambda x: nom_all_to_all(x, "x"), mesh=mesh,
              in_specs=P("x", None), out_specs=P("x", None))
ref = shard_map(lambda x: jax.lax.all_to_all(x, "x", 0, 0), mesh=mesh,
                in_specs=P("x", None), out_specs=P("x", None))
assert np.allclose(f(xs), ref(xs))
rs = shard_map(lambda x: nom_reduce_scatter(x, "x")[None], mesh=mesh,
               in_specs=P("x", None), out_specs=P("x", None))
xr = jnp.asarray(np.random.RandomState(0).randn(64, 4), jnp.float32)
want = np.asarray(xr).reshape(8, 8, 4).sum(axis=0)
assert np.allclose(np.asarray(rs(xr)), want, atol=1e-5)
g = shard_map(lambda x: nom_all_gather(x[0], "x").reshape(-1, 4), mesh=mesh,
              in_specs=P("x", None), out_specs=P("x", None))
xg = jnp.arange(8*4, dtype=jnp.float32).reshape(8, 4)
got = np.asarray(g(xg)).reshape(8, 8, 4)
assert all(np.allclose(got[i], np.asarray(xg)) for i in range(8))
print("MULTIDEV_OK")
""")
    assert "MULTIDEV_OK" in out


@pytest.mark.slow
def test_moe_nom_vs_xla_dispatch_on_8_devices():
    out = run_multidevice("""
import jax, numpy as np, jax.numpy as jnp
from repro.models.moe import MoE, MoEConfig
from repro.launch.mesh import make_mesh, set_ambient_mesh
mesh = make_mesh((1, 8), ("data", "model"))
set_ambient_mesh(mesh)
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (2, 16, 32), jnp.float32)
outs = {}
for disp in ("nom", "xla", "einsum"):
    cfg = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                    dispatch=disp, capacity_factor=8.0)
    moe = MoE(cfg)
    p = moe.init(key)
    y, aux = moe.apply(p, x)
    outs[disp] = np.asarray(y, np.float32)
assert np.allclose(outs["nom"], outs["xla"], atol=1e-5), \
    np.abs(outs["nom"] - outs["xla"]).max()
assert np.allclose(outs["nom"], outs["einsum"], atol=1e-4), \
    np.abs(outs["nom"] - outs["einsum"]).max()
print("MOE_OK")
""")
    assert "MOE_OK" in out
