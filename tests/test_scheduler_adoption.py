"""Scheduler-everywhere: the serving engine and the MoE dispatch planner
must route their transfer sets through `schedule_transfers`, and the
memsim CCU must behave as a bounded, backpressuring request queue."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Mesh3D, TdmAllocator, TransferRequest
from repro.core.scheduler import schedule_transfers
from repro.memsim import SimParams, WorkloadSpec, generate, simulate

KEY = jax.random.PRNGKey(0)


# --- TransferRequest through both backends -----------------------------------
def test_transfer_request_bank_level():
    alloc = TdmAllocator(Mesh3D(4, 4, 2), 16)
    reqs = [TransferRequest(src=0, dst=9, nbytes=512, tag="a"),
            TransferRequest(src=1, dst=14, nbytes=512, tag="b",
                            max_extra_slots=2)]
    results, rep = schedule_transfers(reqs, allocator=alloc, cycle=0)
    assert rep.backend == "tdm"
    assert rep.n_scheduled == 2
    assert results[1].circuit.slots_per_window >= 1
    assert rep.stall_cycles >= 0


def test_transfer_request_device_level_promotes_int_coords():
    reqs = [TransferRequest(src=0, dst=3, nbytes=64, tag="x"),
            TransferRequest(src=(2,), dst=(5,), nbytes=64)]
    plan, rep = schedule_transfers(reqs, shape=(8,), torus=True)
    assert rep.backend == "rounds"
    assert rep.n_scheduled == 2
    assert plan.transfers[0].src == (0,)


# --- engine telemetry ---------------------------------------------------------
def test_engine_generate_populates_schedule_telemetry(mesh1):
    from repro.configs import get_config
    from repro.models import make_model
    from repro.serving import Engine

    cfg = get_config("qwen1.5-4b", smoke=True)
    model = make_model(cfg)
    params = model.init(KEY)
    eng = Engine(model, cfg, max_len=64)
    prompt = jax.random.randint(KEY, (2, 4), 0, cfg.vocab)
    out = eng.generate(params, prompt, n_new=6)
    assert out.shape == (2, 10)
    # one report per prefill/decode step that moved cache bytes, plus the
    # tenant-teardown INIT batch
    assert len(eng.reports) == 4 + 5 + 1
    agg = eng.last_report
    assert agg is not None and agg.backend == "tdm"
    assert agg.n_scheduled == agg.n_requests > 0
    assert agg.n_init > 0          # teardown scrubs rode the scheduler
    tel = eng.transfer_telemetry()
    assert tel["steps"] == len(eng.reports)
    assert tel["max_inflight"] >= 1
    assert tel["batch_avg"] >= 1.0
    assert tel["init_requests"] == agg.n_init
    assert tel["active_tenants"] == 0 and tel["peak_tenants"] == 1


def test_engine_opt_out(mesh1):
    from repro.configs import get_config
    from repro.models import make_model
    from repro.serving import Engine

    cfg = get_config("qwen1.5-4b", smoke=True)
    model = make_model(cfg)
    params = model.init(KEY)
    eng = Engine(model, cfg, max_len=64, track_transfers=False)
    out = eng.generate(params, jax.random.randint(KEY, (1, 3), 0, cfg.vocab),
                       n_new=4)
    assert out.shape == (1, 7)
    assert eng.reports == [] and eng.last_report is None


# --- MoE dispatch plan --------------------------------------------------------
@pytest.fixture(scope="module")
def moe_plan():
    from repro.models.moe import MoE, MoEConfig
    cfg = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                    dispatch="nom", capacity_factor=2.0)
    moe = MoE(cfg)
    p = moe.init(KEY)
    x = jax.random.normal(KEY, (2, 16, 32))
    plan, report = moe.plan_dispatch(p, x, ep=4)
    return moe, plan, report


def test_moe_dispatch_plans_both_directions(moe_plan):
    moe, plan, report = moe_plan
    assert report.backend == "rounds"
    assert report.n_scheduled == report.n_requests > 0
    tags = {t.tag[0] for t in plan.transfers}
    assert tags == {"dispatch", "combine"}
    assert moe.last_dispatch_report is report


def test_moe_dispatch_rounds_are_link_disjoint(moe_plan):
    """The paper's invariant, on the EP ring: within a round every directed
    link carries at most one chunk."""
    _moe, plan, report = moe_plan
    for k, rnd in enumerate(plan.rounds()):
        hops = [hop for _i, hop in rnd]
        assert len(hops) == len(set(hops)), (k, hops)
    assert report.max_inflight > 1   # dispatch is actually concurrent


def test_moe_plan_dispatch_rejects_tracers():
    from repro.models.moe import MoE, MoEConfig
    moe = MoE(MoEConfig(d_model=8, d_ff=16, n_experts=4, top_k=1))
    p = moe.init(KEY)

    def traced(x):
        moe.plan_dispatch(p, x, ep=2)
        return x

    with pytest.raises(TypeError, match="concrete"):
        jax.jit(traced)(jnp.zeros((1, 4, 8)))


# --- bounded CCU queue --------------------------------------------------------
def test_ccu_queue_backpressures_and_latency_monotone_in_depth():
    """Queue-full stalls appear at shallow depth and vanish as the queue
    deepens; IPC (inverse copy latency) is monotone non-decreasing."""
    reqs = generate(WorkloadSpec("fileCopy60", n_requests=700, seed=1))
    hi = {d: simulate(reqs, SimParams(config="nom", nom_ccu_queue_depth=d,
                                      compute_gap=1, window=64))
          for d in (1, 16)}
    assert hi[1].extra["nom_ccu_full_stalls"] > 0
    assert hi[1].extra["nom_ccu_stall_cycles"] > 0
    assert (hi[16].extra["nom_ccu_stall_cycles"]
            < hi[1].extra["nom_ccu_stall_cycles"])

    ipcs = [simulate(reqs, SimParams(config="nom",
                                     nom_ccu_queue_depth=d)).ipc
            for d in (1, 4, 16)]
    assert ipcs[0] <= ipcs[1] <= ipcs[2], ipcs


def test_ccu_queue_depth_clamped_by_inflight_cap():
    """Calibration: the queue never buffers more than the router in-flight
    budget admits."""
    reqs = generate(WorkloadSpec("fileCopy60", n_requests=300, seed=2))
    r = simulate(reqs, SimParams(config="nom", nom_ccu_queue_depth=8,
                                 nom_max_inflight=2))
    assert r.extra["nom_ccu_queue_depth"] == 2
    assert r.extra["nom_ccu_peak_queue"] <= 2
    assert r.extra["nom_inflight_max"] <= 2


def test_ccu_queue_batches_concurrent_setups():
    """The queue still realizes the paper's concurrent circuit
    establishment: batched setups > 1 request on copy-heavy streams."""
    reqs = generate(WorkloadSpec("fileCopy60", n_requests=700, seed=1))
    r = simulate(reqs, SimParams(config="nom"))
    assert r.extra["nom_batch_avg"] > 1.2
    assert r.extra["nom_inflight_avg"] > 1.0
