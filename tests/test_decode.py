"""Decode-path correctness: step-by-step decode with caches must reproduce
the full-forward logits — this exercises KV caches (incl. the sliding-window
ring buffer), SSM states, RG-LRU states and enc-dec cross attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import make_model

KEY = jax.random.PRNGKey(1)
# one representative per cache mechanism
ARCHS = ["qwen2.5-32b",          # plain KV
         "gemma3-27b",           # window ring buffer + sandwich norms
         "mamba2-130m",          # SSD state
         "recurrentgemma-9b",    # RG-LRU + window MQA
         "whisper-small"]        # enc-dec cross attention


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, mesh1):
    cfg = get_config(arch, smoke=True)
    model = make_model(cfg)
    params = model.init(KEY)
    b, s = 2, 24
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    if cfg.arch_type == "encdec":
        enc = jax.random.normal(KEY, (b, cfg.enc_seq, cfg.d_model))
        full_logits, _ = model.apply(params, enc, toks, remat=False)
        memory = model.encode(params, enc, remat=False)
        caches = model.init_caches(b, s)
        outs = []
        for i in range(s):
            lg, caches = model.decode_step(params, toks[:, i:i + 1], caches,
                                           jnp.int32(i), memory)
            outs.append(lg)
    else:
        full_logits, _ = model.apply(params, toks, remat=False)
        caches = model.init_caches(b, s)
        outs = []
        for i in range(s):
            lg, caches = model.decode_step(params, toks[:, i:i + 1], caches,
                                           jnp.int32(i))
            outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    a = jax.nn.log_softmax(full_logits.astype(jnp.float32), axis=-1)
    c = jax.nn.log_softmax(dec_logits.astype(jnp.float32), axis=-1)
    err = float(jnp.max(jnp.abs(a - c)))
    # recurrence archs accumulate bf16 order-of-operations noise between
    # the chunk-parallel and strictly-sequential paths; attention archs
    # recompute identically. Greedy decisions must agree in all cases.
    tol = 5e-2 if cfg.family in ("dense", "audio", "vlm") else 1.5
    assert err < tol, f"{arch}: decode/forward divergence {err}"
    agree = float((a.argmax(-1) == c.argmax(-1)).mean())
    assert agree > 0.95, f"{arch}: greedy tokens diverge ({agree})"


def test_generate_engine(mesh1):
    from repro.serving import Engine
    cfg = get_config("qwen1.5-4b", smoke=True)
    model = make_model(cfg)
    params = model.init(KEY)
    eng = Engine(model, cfg, max_len=64)
    prompt = jax.random.randint(KEY, (2, 5), 0, cfg.vocab)
    out = eng.generate(params, prompt, n_new=8)
    assert out.shape == (2, 13)
    assert bool((out[:, :5] == prompt).all())
