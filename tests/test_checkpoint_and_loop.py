"""Fault tolerance: atomic checkpoints, crash/restart determinism, NaN
guard, straggler monitor, elastic reshard plan."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.checkpoint.reshard import reshard_plan
from repro.configs import get_config
from repro.data import DataConfig, batch_at
from repro.models import make_model
from repro.optim.adamw import AdamWConfig
from repro.train import LoopConfig, StragglerMonitor, TrainState, \
    make_train_step, train_loop

KEY = jax.random.PRNGKey(0)


def _setup(tmp):
    cfg = get_config("qwen1.5-4b", smoke=True)
    model = make_model(cfg)
    params = model.init(KEY)
    state = TrainState.create(params)
    step = jax.jit(make_train_step(model, cfg, AdamWConfig(lr=3e-3,
                                                           warmup_steps=5)),
                   donate_argnums=(0,))
    dcfg = DataConfig(vocab=cfg.vocab, batch=4, seq=32, seed=7)
    lcfg = LoopConfig(total_steps=24, ckpt_every=8,
                      ckpt_dir=os.path.join(tmp, "ck"), log_every=100)
    return cfg, model, state, step, dcfg, lcfg


def test_loss_decreases_and_checkpoints(tmp_path, mesh1):
    cfg, model, state, step, dcfg, lcfg = _setup(str(tmp_path))
    state, hist = train_loop(step, state, dcfg, lcfg, log=lambda *_: None)
    first = np.mean([h["loss"] for h in hist[:4]])
    last = np.mean([h["loss"] for h in hist[-4:]])
    assert last < first, (first, last)
    assert ckpt.latest_step(lcfg.ckpt_dir) == 24


def test_crash_restart_bit_identical(tmp_path, mesh1):
    """A killed run resumed from checkpoint reaches the same final loss as
    an uninterrupted run (deterministic data + state restore)."""
    cfg, model, state0, step, dcfg, lcfg = _setup(str(tmp_path / "a"))
    s_ref, hist_ref = train_loop(step, state0, dcfg, lcfg,
                                 log=lambda *_: None)

    cfg, model, state1, step2, dcfg, lcfg2 = _setup(str(tmp_path / "b"))
    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop(step2, state1, dcfg, lcfg2, fail_at_step=17,
                   log=lambda *_: None)
    # restart: resumes from step-16 checkpoint automatically
    cfgb = get_config("qwen1.5-4b", smoke=True)
    modelb = make_model(cfgb)
    state2 = TrainState.create(modelb.init(KEY))
    s_resumed, hist2 = train_loop(step2, state2, dcfg, lcfg2,
                                  log=lambda *_: None)
    np.testing.assert_allclose(hist_ref[-1]["loss"], hist2[-1]["loss"],
                               rtol=1e-5)


def test_nan_guard_keeps_params(mesh1):
    cfg = get_config("qwen1.5-4b", smoke=True)
    model = make_model(cfg)
    params = model.init(KEY)
    # poison a parameter every forward pass uses -> loss/grads non-finite
    params["final_norm"]["scale"] = params["final_norm"]["scale"].at[0].set(
        jnp.inf)
    state = TrainState.create(params)
    step = jax.jit(make_train_step(model, cfg, AdamWConfig()))
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab)}
    new_state, metrics = step(state, batch)
    assert int(metrics["finite"]) == 0
    # every *finite* param must be unchanged (update skipped)
    same = jax.tree.map(lambda a, b: bool(jnp.all((a == b)
                                                  | ~jnp.isfinite(a))),
                        new_state.params, state.params)
    assert all(jax.tree.leaves(same))
    assert int(new_state.step) == 1   # step counter still advances


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(alpha=0.5, ratio=2.0)
    for i in range(10):
        assert not mon.observe(i, 0.1)
    assert mon.observe(10, 0.5)
    assert mon.flagged and mon.flagged[0][0] == 10


def test_checkpoint_atomicity_and_prune(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": {"w": jnp.ones((4, 4))}, "b": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, tree)
    ckpt.prune(d, keep=2)
    assert ckpt.latest_step(d) == 4
    # a stale tmp dir must be ignored by restore
    os.makedirs(os.path.join(d, "step_00000099.tmp"), exist_ok=True)
    tree2, manifest = ckpt.restore(d)
    assert manifest["step"] == 4
    np.testing.assert_array_equal(np.asarray(tree2["a"]["w"]),
                                  np.ones((4, 4)))


def test_elastic_restore_roundtrip(tmp_path, mesh1):
    """Save from one 'mesh', restore under explicit shardings (the elastic
    path used when the device set changes)."""
    d = str(tmp_path / "ck")
    cfg = get_config("mamba2-130m", smoke=True)
    model = make_model(cfg)
    params = model.init(KEY)
    ckpt.save(d, 5, {"params": params})
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("x",))
    shard = jax.tree.map(lambda _: NamedSharding(mesh, P()), {"params": params})
    tree, manifest = ckpt.restore(d, shardings=shard)
    flat_a = jax.tree.leaves(tree["params"])
    flat_b = jax.tree.leaves(params)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_reshard_plan_conflict_free():
    plan = reshard_plan({f"p{i}": 1024 for i in range(40)},
                        old_mesh=(4, 4), new_mesh=(2, 4))
    for rnd in plan.rounds():
        hops = [h for _i, h in rnd]
        assert len(hops) == len(set(hops))
    assert plan.n_rounds >= 1
