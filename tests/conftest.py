import os
import subprocess
import sys

# Offline container: vendor the minimal hypothesis shim when the real
# package is unavailable (must run before test modules import hypothesis).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_shim as _shim
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies

import pytest  # noqa: E402

from repro.launch.mesh import make_mesh, set_ambient_mesh  # noqa: E402


@pytest.fixture(scope="session")
def mesh1():
    """1x1 ('data','model') mesh installed as ambient for shard_map code."""
    mesh = make_mesh((1, 1), ("data", "model"))
    set_ambient_mesh(mesh)
    return mesh


def run_multidevice(script: str, n_devices: int = 8) -> str:
    """Run a python snippet in a subprocess with N fake devices (the only
    way to get >1 device after jax initialized in-process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=500)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture
def multidevice_run():
    """Fixture spelling of :func:`run_multidevice` for the ``multidevice``
    lane (``pytest -m multidevice``, its own ci.sh stage): re-execs the
    given snippet under ``XLA_FLAGS=--xla_force_host_platform_device_count``
    and returns its stdout."""
    return run_multidevice
