import os
import subprocess
import sys

import jax
import pytest


@pytest.fixture(scope="session")
def mesh1():
    """1x1 ('data','model') mesh installed as ambient for shard_map code."""
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    jax.sharding.set_mesh(mesh)
    return mesh


def run_multidevice(script: str, n_devices: int = 8) -> str:
    """Run a python snippet in a subprocess with N fake devices (the only
    way to get >1 device after jax initialized in-process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=500)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout
