"""Per-arch reduced-config smoke tests: forward + one train step on CPU,
asserting output shapes and finiteness (the assignment's smoke contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import count_params, lm_loss, make_model
from repro.optim.adamw import AdamWConfig
from repro.train.state import TrainState, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.arch_type == "encdec":
        batch["enc_emb"] = jax.random.normal(KEY, (b, cfg.enc_seq,
                                                   cfg.d_model))
    if cfg.arch_type == "vlm":
        batch["prefix_emb"] = jax.random.normal(KEY, (b, cfg.enc_seq,
                                                      cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward(arch, mesh1):
    cfg = get_config(arch, smoke=True)
    model = make_model(cfg)
    params = model.init(KEY)
    assert count_params(params) > 0
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    if cfg.arch_type == "encdec":
        logits, aux = model.apply(params, batch["enc_emb"], batch["tokens"])
    elif cfg.arch_type == "vlm":
        logits, aux = model.apply(params, batch["tokens"],
                                  prefix_emb=batch["prefix_emb"])
    else:
        logits, aux = model.apply(params, batch["tokens"])
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, _ = lm_loss(logits, batch["tokens"], aux)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(arch, mesh1):
    cfg = get_config(arch, smoke=True)
    model = make_model(cfg)
    params = model.init(KEY)
    state = TrainState.create(params)
    step = jax.jit(make_train_step(model, cfg, AdamWConfig(lr=1e-3)))
    batch = _batch(cfg)
    state, metrics = step(state, batch)
    assert int(metrics["finite"]) == 1
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state.step) == 1
    # params actually moved
    delta = jax.tree.reduce(
        lambda acc, x: acc + float(jnp.abs(x).sum()),
        jax.tree.map(lambda a, b: a.astype(jnp.float32)
                     - b.astype(jnp.float32), state.params, params), 0.0)
    assert delta > 0


def test_param_count_estimates_are_sane():
    """6N sanity: analytic estimate within 2x of actual counted params."""
    for arch in ("qwen1.5-4b", "mamba2-130m"):
        cfg = get_config(arch, smoke=True)
        model = make_model(cfg)
        n = count_params(model.init(KEY))
        est = cfg.param_count_estimate()
        assert 0.3 < est / n < 3.0, (arch, est, n)
