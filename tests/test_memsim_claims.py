"""The paper's headline claims, reproduced by the simulator (Section 3):

* NoM vs conventional 3D DRAM:   ~3.8x IPC  (band 2.5x - 6x geomean)
* NoM vs RowClone:               ~1.75x     (band 1.3x - 2.3x)
* NoM-Light within 5-20% of NoM
* sublinear degradation under link-frequency scaling
* NoM-Light TSV-conflict motivation: low conflict probability
"""
import numpy as np
import pytest

from repro.memsim import (SimParams, WorkloadSpec, generate, simulate,
                          traffic_breakdown)

WORKLOADS = ("fork", "fileCopy20", "fileCopy40", "fileCopy60")


@pytest.fixture(scope="module")
def results():
    out = {}
    for wl in WORKLOADS:
        reqs = generate(WorkloadSpec(wl, n_requests=900, seed=1))
        out[wl] = {cfg: simulate(reqs, SimParams(config=cfg), name=wl)
                   for cfg in ("conventional", "rowclone", "nom",
                               "nom_light")}
    return out


def _gm(xs):
    return float(np.exp(np.mean(np.log(xs))))


def test_traffic_mix_matches_fig3():
    for wl, want in [("fileCopy20", 0.20), ("fileCopy40", 0.40),
                     ("fileCopy60", 0.60)]:
        reqs = generate(WorkloadSpec(wl, n_requests=1200, seed=0))
        mix = traffic_breakdown(reqs)
        assert abs(mix["inter_bank_copy"] - want) < 0.08, (wl, mix)


def test_ordering_nom_beats_rowclone_beats_conventional(results):
    for wl, r in results.items():
        assert r["nom"].ipc > r["rowclone"].ipc > r["conventional"].ipc, wl


def test_speedup_vs_conventional_in_band(results):
    ratios = [r["nom"].ipc / r["conventional"].ipc for r in results.values()]
    assert 2.5 < _gm(ratios) < 6.5, ratios   # paper: 3.8x average


def test_speedup_vs_rowclone_in_band(results):
    ratios = [r["nom"].ipc / r["rowclone"].ipc for r in results.values()]
    assert 1.25 < _gm(ratios) < 2.4, ratios  # paper: 1.75x average


def test_nom_light_gap_in_band(results):
    for wl, r in results.items():
        gap = 1 - r["nom_light"].ipc / r["nom"].ipc
        assert 0.0 <= gap <= 0.25, (wl, gap)  # paper: 5-20%


def test_link_frequency_scaling_sublinear():
    reqs = generate(WorkloadSpec("fileCopy60", n_requests=700, seed=1))
    base = simulate(reqs, SimParams(config="nom", nom_link_ratio=1.0)).ipc
    rc = simulate(reqs, SimParams(config="rowclone")).ipc
    for ratio in (0.75, 0.5):
        ipc = simulate(reqs, SimParams(config="nom",
                                       nom_link_ratio=ratio)).ipc
        degradation = 1 - ipc / base
        assert degradation < (1 - ratio) * 1.1, (ratio, degradation)
        assert ipc > rc      # paper: still beats RowClone at half speed


def test_tsv_conflict_rate_low():
    """The NoM-Light motivation: dedicated-Z beats rarely coincide with TSV
    activity (paper: 0.45% low load, 7.1% high load)."""
    reqs = generate(WorkloadSpec("fileCopy60", n_requests=700, seed=1))
    r = simulate(reqs, SimParams(config="nom"))
    assert r.tsv_conflict_frac < 0.10, r.tsv_conflict_frac


def test_slot_bundling_monotone():
    """Beyond-paper ablation invariant: more bundled slots per copy never
    hurts IPC (capacity is only additive)."""
    reqs = generate(WorkloadSpec("fileCopy60", n_requests=500, seed=3))
    ipcs = [simulate(reqs, SimParams(config="nom", nom_extra_slots=e)).ipc
            for e in (0, 3, 7)]
    assert ipcs[0] < ipcs[1] < ipcs[2], ipcs
