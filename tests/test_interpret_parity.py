"""Compiled vs interpret parity for every Pallas kernel in the repo.

Each kernel family (slot_alloc wavefront + slot scoring, flash_attention,
ssd_scan, rglru_scan) is run twice on identical inputs — once with
``interpret=True`` and once with ``interpret=False`` — and the outputs
must match bit-for-bit.  On backends where compiled Pallas is not
available (CPU raises ``ValueError: Only interpret mode is supported on
CPU backend.``), the parity half SKIPS with the refusal recorded in the
skip reason, so a CI log always shows *why* compiled mode wasn't proven.

The module also pins the backend-aware ``interpret`` defaults
(``kernels/interpret.py``): every public kernel entry point now takes
``interpret: bool | None = None`` and resolves ``None`` to interpreter
mode exactly when the default backend is CPU — calling a kernel with no
``interpret`` argument must never crash on the shipped backend.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.slot_alloc import TdmAllocator, wavefront_search_batch
from repro.core.topology import Mesh3D
from repro.kernels.interpret import default_interpret, resolve_interpret
from repro.kernels.slot_alloc import fused as fused_mod
from repro.kernels.slot_alloc.ops import wavefront_search_pallas_batch

MESH = Mesh3D(4, 4, 2, vault_span_y=1)
N_SLOTS = 8


def _compiled(label, fn, *args, **kwargs):
    """Run ``fn`` with interpret=False; skip (recording the backend's
    refusal) where compiled Pallas is unsupported."""
    try:
        return fn(*args, interpret=False, **kwargs)
    except ValueError as e:
        if "interpret mode" in str(e):
            pytest.skip(f"{label}: compiled Pallas unavailable on "
                        f"backend={jax.default_backend()!r}: {e}")
        raise


# --- the backend-aware default ------------------------------------------------
def test_default_interpret_tracks_backend():
    assert default_interpret() == (jax.default_backend() == "cpu")
    assert resolve_interpret(None) == default_interpret()
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


def test_kernels_run_with_no_interpret_argument():
    """Every public entry point works with the resolved default — no
    caller may need to know the backend to call a kernel."""
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.rglru_scan.ops import rglru_scan
    from repro.kernels.ssd_scan.ops import ssd_scan

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 8, 2, 16)), jnp.float32)
    kv = jnp.asarray(rng.standard_normal((1, 8, 2, 16)), jnp.float32)
    assert flash_attention(q, kv, kv, block_q=8, block_k=8).shape == q.shape

    a = jnp.full((1, 16, 8), 0.5, jnp.float32)
    b = jnp.ones((1, 16, 8), jnp.float32)
    assert rglru_scan(a, b, chunk=16).shape == a.shape

    x = jnp.asarray(rng.standard_normal((1, 16, 2, 8)), jnp.float32)
    dt = jnp.full((1, 16, 2), 0.1, jnp.float32)
    B = jnp.asarray(rng.standard_normal((1, 16, 4)), jnp.float32)
    A = jnp.full((2,), -1.0, jnp.float32)
    assert ssd_scan(x, dt, B, B, A, chunk=16).shape == x.shape

    occ = np.zeros((MESH.n_nodes, 7), np.uint32)
    srcs, dsts = np.asarray([0, 3]), np.asarray([9, 21])
    init = np.zeros(2, np.uint32)
    out = wavefront_search_pallas_batch(occ, srcs, dsts, init, mesh=MESH,
                                        n_slots=N_SLOTS)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(wavefront_search_batch(occ, srcs, dsts, init, mesh=MESH,
                                          n_slots=N_SLOTS)))


# --- per-kernel compiled/interpret parity ------------------------------------
def _warm_occupancy():
    rng = np.random.default_rng(3)
    warm = TdmAllocator(MESH, N_SLOTS)
    for _ in range(24):
        s, d = (int(v) for v in rng.integers(MESH.n_nodes, size=2))
        if s != d:
            warm.allocate(s, d, 512, cycle=0)
    return warm.table.busy_masks(0)


def test_slot_alloc_wavefront_parity():
    occ = _warm_occupancy()
    rng = np.random.default_rng(4)
    B = 16
    srcs = rng.integers(MESH.n_nodes, size=B)
    dsts = (srcs + 1 + rng.integers(MESH.n_nodes - 1, size=B)) % MESH.n_nodes
    init = np.zeros(B, np.uint32)
    interp = np.asarray(wavefront_search_pallas_batch(
        occ, srcs, dsts, init, mesh=MESH, n_slots=N_SLOTS, interpret=True))
    comp = np.asarray(_compiled(
        "slot_alloc/wavefront", wavefront_search_pallas_batch,
        occ, srcs, dsts, init, mesh=MESH, n_slots=N_SLOTS))
    np.testing.assert_array_equal(comp, interp)


def test_slot_alloc_slot_score_parity():
    rng = np.random.default_rng(5)
    avail = jnp.asarray(rng.integers(0, 2**N_SLOTS, size=24), jnp.uint32)
    planes = fused_mod.unpack_bits(avail, N_SLOTS)
    dists = jnp.asarray(rng.integers(0, 9, size=24), jnp.int32)
    t = jnp.asarray(rng.integers(0, 30, size=24), jnp.int32)
    interp = np.asarray(fused_mod.slot_score_planes(
        planes, dists, t, n_slots=N_SLOTS, interpret=True))
    comp = np.asarray(_compiled(
        "slot_alloc/slot_score", fused_mod.slot_score_planes,
        planes, dists, t, n_slots=N_SLOTS))
    np.testing.assert_array_equal(comp, interp)


def test_flash_attention_parity():
    from repro.kernels.flash_attention.ops import flash_attention
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.standard_normal((2, 24, 4, 16)), jnp.float32)
    kv = jnp.asarray(rng.standard_normal((2, 24, 2, 16)), jnp.float32)
    kw = dict(causal=True, block_q=8, block_k=8)
    interp = np.asarray(flash_attention(q, kv, kv, interpret=True, **kw))
    comp = np.asarray(_compiled("flash_attention", flash_attention,
                                q, kv, kv, **kw))
    np.testing.assert_array_equal(comp, interp)


def test_ssd_scan_parity():
    from repro.kernels.ssd_scan.ops import ssd_scan
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, 32, 2, 8)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (2, 32, 2)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((2, 32, 4)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((2, 32, 4)), jnp.float32)
    A = jnp.asarray(rng.uniform(-2.0, -0.5, 2), jnp.float32)
    interp = np.asarray(ssd_scan(x, dt, B, C, A, chunk=16, interpret=True))
    comp = np.asarray(_compiled("ssd_scan", ssd_scan, x, dt, B, C, A,
                                chunk=16))
    np.testing.assert_array_equal(comp, interp)


def test_rglru_scan_parity():
    from repro.kernels.rglru_scan.ops import rglru_scan
    rng = np.random.default_rng(8)
    a = jnp.asarray(rng.uniform(0.2, 0.99, (2, 32, 8)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((2, 32, 8)), jnp.float32)
    interp = np.asarray(rglru_scan(a, b, chunk=16, interpret=True))
    comp = np.asarray(_compiled("rglru_scan", rglru_scan, a, b, chunk=16))
    np.testing.assert_array_equal(comp, interp)


def test_fused_prepare_program_parity():
    """The whole fused program under kernel="pallas": interpret on/off."""
    occ = _warm_occupancy()
    rng = np.random.default_rng(9)
    B = 16
    srcs = rng.integers(MESH.n_nodes, size=B).astype(np.int64)
    dsts = (srcs + 1 + rng.integers(MESH.n_nodes - 1, size=B)) % MESH.n_nodes
    t = rng.integers(3, 20, size=B).astype(np.int64)

    def run(interpret):
        return fused_mod.fused_prepare(occ, srcs, dsts, t, mesh=MESH,
                                       n_slots=N_SLOTS, kernel="pallas",
                                       interpret=interpret)

    interp = run(True)
    comp = _compiled("slot_alloc/fused", lambda *, interpret: run(interpret))
    for field in ("starts", "arr", "dists", "denied", "ok",
                  "hop_n", "hop_p", "hop_s"):
        np.testing.assert_array_equal(getattr(comp, field),
                                      getattr(interp, field), err_msg=field)
