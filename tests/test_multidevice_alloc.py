"""The ``multidevice`` lane: allocator + cluster behavior on 8 faked XLA
devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

Deselected from tier-1 (see pytest.ini addopts) and run as its own
``scripts/ci.sh`` stage with ``pytest -m multidevice``.  Each test
re-execs a snippet through the ``multidevice_run`` conftest fixture —
device count is fixed at process start, so in-process tests cannot fake
it.  What the lane proves:

* the fused prepare program is device-placement invariant: dispatching
  the same wave on each of the 8 devices returns bit-identical outputs;
* a *sharded* allocator run — the request batch split across per-device
  programs — commits bit-identically to the single-device fused batch;
* ``FabricCluster`` schedules same-stack + cross-stack traffic with its
  per-stack allocators' device state spread over the faked devices, and
  the backend-split telemetry survives the trip.
"""
import pytest

pytestmark = pytest.mark.multidevice


def test_fused_prepare_placement_invariant(multidevice_run):
    out = multidevice_run("""
import jax, numpy as np
from repro.core.slot_alloc import CopyRequest, TdmAllocator
from repro.core.topology import Mesh3D
from repro.kernels.slot_alloc import fused
assert jax.device_count() == 8, jax.devices()
mesh = Mesh3D(8, 8, 4)
rng = np.random.default_rng(0)
warm = TdmAllocator(mesh, 16)
for _ in range(48):
    s, d = rng.integers(mesh.n_nodes, size=2)
    if s != d:
        warm.allocate(int(s), int(d), 512, cycle=0)
occ = warm.table.busy_masks(0)
B = 64
srcs = rng.integers(mesh.n_nodes, size=B)
dsts = (srcs + 1 + rng.integers(mesh.n_nodes - 1, size=B)) % mesh.n_nodes
t = np.full(B, 3)
outs = []
for dev in jax.devices():
    occ_d = jax.device_put(occ, dev)
    fp = fused.fused_prepare(occ_d, srcs, dsts, t, mesh=mesh, n_slots=16)
    outs.append(fp)
ref = outs[0]
for fp in outs[1:]:
    np.testing.assert_array_equal(fp.starts, ref.starts)
    np.testing.assert_array_equal(fp.denied, ref.denied)
    np.testing.assert_array_equal(fp.hop_n, ref.hop_n)
    np.testing.assert_array_equal(fp.hop_p, ref.hop_p)
    np.testing.assert_array_equal(fp.hop_s, ref.hop_s)
print("PLACEMENT_OK", len(outs))
""")
    assert "PLACEMENT_OK 8" in out


def test_sharded_allocator_matches_single_device(multidevice_run):
    """Split one wave's search across the 8 devices (each device runs the
    fused program on its shard of the requests against the same
    occupancy snapshot), reassemble, and check the per-row outputs are
    bit-identical to the unsharded program — the device axis is a pure
    throughput axis, invisible in the results."""
    out = multidevice_run("""
import jax, numpy as np
from repro.core.slot_alloc import TdmAllocator
from repro.core.topology import Mesh3D
from repro.kernels.slot_alloc import fused
assert jax.device_count() == 8
mesh = Mesh3D(8, 8, 4)
rng = np.random.default_rng(1)
warm = TdmAllocator(mesh, 16)
for _ in range(32):
    s, d = rng.integers(mesh.n_nodes, size=2)
    if s != d:
        warm.allocate(int(s), int(d), 512, cycle=0)
occ = warm.table.busy_masks(0)
B = 64
srcs = rng.integers(mesh.n_nodes, size=B)
dsts = (srcs + 1 + rng.integers(mesh.n_nodes - 1, size=B)) % mesh.n_nodes
t = np.full(B, 3)
whole = fused.fused_prepare(occ, srcs, dsts, t, mesh=mesh, n_slots=16)
shard = B // 8
for i, dev in enumerate(jax.devices()):
    sl = slice(i * shard, (i + 1) * shard)
    part = fused.fused_prepare(jax.device_put(occ, dev), srcs[sl], dsts[sl],
                               t[sl], mesh=mesh, n_slots=16)
    np.testing.assert_array_equal(part.starts, whole.starts[sl])
    np.testing.assert_array_equal(part.arr, whole.arr[sl])
    np.testing.assert_array_equal(part.denied, whole.denied[sl])
    np.testing.assert_array_equal(part.hop_n, whole.hop_n[sl])
    np.testing.assert_array_equal(part.hop_s, whole.hop_s[sl])
print("SHARDED_OK")
""")
    assert "SHARDED_OK" in out


def test_fused_batch_matches_serial_on_8_devices(multidevice_run):
    """The end-to-end differential property (fused batch == serial
    stream) holds unchanged when jax exposes 8 devices."""
    out = multidevice_run("""
import jax, numpy as np
from repro.core.slot_alloc import CopyRequest, TdmAllocator
from repro.core.topology import Mesh3D
assert jax.device_count() == 8
mesh = Mesh3D(8, 8, 4)
rng = np.random.default_rng(2)
reqs = []
for _ in range(128):
    s, d = rng.integers(mesh.n_nodes, size=2)
    while s == d:
        d = rng.integers(mesh.n_nodes)
    reqs.append(CopyRequest(int(s), int(d), 512))
a_f = TdmAllocator(mesh, 16, backend="fused")
a_s = TdmAllocator(mesh, 16)
rf = a_f.allocate_batch(reqs, cycle=0)
rs = [a_s.allocate(r.src, r.dst, r.nbytes, 0) for r in reqs]
def key(c):
    return None if c is None else (c.src, c.dst, c.start_cycle,
                                   c.n_windows, tuple(c.hops), c.distance)
assert all(key(f.circuit) == key(s.circuit) for f, s in zip(rf, rs))
assert (a_f.table.expiry == a_s.table.expiry).all()
assert a_f.last_report.fused_waves > 0
print("DIFF_OK", a_f.last_report.fused_waves)
""")
    assert "DIFF_OK" in out


def test_fabric_cluster_on_8_devices(multidevice_run):
    """FabricCluster with per-stack allocators whose device occupancy is
    pinned round-robin over the faked devices: same-stack and
    cross-stack traffic schedules, and the fused/host wave telemetry
    survives aggregation."""
    out = multidevice_run("""
import jax, numpy as np
from repro.core.fabric import FabricCluster
from repro.core.scheduler import TransferRequest
from repro.core.slot_alloc import TdmAllocator
from repro.core.topology import Mesh3D, make_topology
assert jax.device_count() == 8
mesh = Mesh3D(4, 4, 2)
topo = make_topology(4, mesh)
allocs = [TdmAllocator(m, 16, backend="auto") for m in topo.stacks]
# Pin each stack's device-resident occupancy to its own fake device.
for i, a in enumerate(allocs):
    dev = jax.devices()[i % jax.device_count()]
    masks = a.table.busy_masks(0)
    a.table._dev = jax.device_put(masks.copy(), dev)
    a.table._dev_version = a.table._ports.version
cluster = FabricCluster(topology=topo, allocators=allocs)
rng = np.random.default_rng(3)
reqs = []
for _ in range(96):
    s = (int(rng.integers(4)), int(rng.integers(mesh.n_nodes)))
    d = (int(rng.integers(4)), int(rng.integers(mesh.n_nodes)))
    if s != d:
        reqs.append(TransferRequest(src=s, dst=d, nbytes=256))
results, rep = cluster.schedule(reqs)
committed = sum(r.circuit is not None for r in results)
tel = cluster.telemetry()
assert committed > 0
assert rep.n_cross_stack > 0
assert tel["fused_waves"] + tel["host_waves"] >= 1
assert len(tel["stacks"]) == 4
print("CLUSTER_OK", committed, tel["fused_waves"], tel["host_waves"])
""")
    assert "CLUSTER_OK" in out


def test_nom_allreduce_matches_psum_on_8_devices(multidevice_run):
    """Compute-class satellite: the device-level ``nom_allreduce``
    (reduce-scatter + all-gather ring rounds) equals the axis sum on the
    8-device lane — including a ragged shape that forces internal
    padding — and is bitwise-reproducible across runs (fixed ring
    summation order)."""
    out = multidevice_run("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core import nom_allreduce
assert jax.device_count() == 8
mesh = make_mesh((8,), ("x",))
f = shard_map(lambda v: nom_allreduce(v[0], "x")[None], mesh=mesh,
              in_specs=P("x", None), out_specs=P("x", None))
x = jnp.asarray(np.random.RandomState(7).randn(8, 6), jnp.float32)
got = np.asarray(f(x))
want = np.asarray(x).sum(axis=0)
assert all(np.allclose(got[i], want, atol=1e-5) for i in range(8))
# Ragged per-device shape: 5 elements pad to 8 internally.
xr = jnp.asarray(np.random.RandomState(8).randn(8, 5), jnp.float32)
got_r = np.asarray(f(xr))
assert np.allclose(got_r[0], np.asarray(xr).sum(axis=0), atol=1e-5)
# Fixed ring order: a second evaluation is bit-identical.
again = np.asarray(f(x))
np.testing.assert_array_equal(got, again)
print("ALLREDUCE_OK")
""")
    assert "ALLREDUCE_OK" in out
