"""Differential harness for the fused CCU prepare program (PR 8).

The allocator's fused backend runs wavefront search + slot choice +
trace-back as ONE compiled program per wave and commits through a
struct-of-arrays pipeline.  Correctness story, in three layers:

* **kernel vs oracle** — ``fused_prepare`` (the compiled program) is
  bit-identical to ``ref.fused_prepare_ref`` (scalar host twin) on random
  occupancies: starts, arrival slots, denials, hop arrays, distances;
* **pipeline vs serial** — ``allocate_batch`` under ``backend="fused"``
  is bit-identical to feeding the same stream through serial
  ``allocate`` one request at a time: circuits (every field), the final
  slot-table expiry state, and commit/deny counts — swept over
  randomized topologies, wave sizes, conflict densities, and copy/init
  op mixes (hypothesis-shim driven);
* **telemetry** — ``fused_waves`` / ``host_waves`` track which backend
  served each prepare round, agree between ``BatchReport``,
  ``ScheduleReport`` and fabric/memsim telemetry, and the host/fused
  reports agree with *each other* on every shared counter.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.slot_alloc import CopyRequest, TdmAllocator
from repro.core.topology import Mesh3D
from repro.kernels.slot_alloc import fused as fused_mod
from repro.kernels.slot_alloc import ref as ref_mod

# Small topology/slot grid: every (mesh, n_slots, padded wave) combo is
# one XLA compile, so the sweep reuses these rather than free-ranging.
MESHES = {
    "tall": Mesh3D(4, 4, 2, vault_span_y=1),
    "wide": Mesh3D(8, 8, 4),
}
N_SLOTS = {"tall": 8, "wide": 16}


def _stream(rng, mesh, n, *, contended=False, init_frac=0.0,
            extra_frac=0.0):
    """Random request stream; ``contended=True`` funnels endpoints through
    a single mesh column so within-wave conflicts actually happen."""
    reqs = []
    for _ in range(n):
        if contended:
            s = mesh.node_id(0, int(rng.integers(mesh.Y)), 0)
            d = mesh.node_id(mesh.X - 1, int(rng.integers(mesh.Y)),
                             int(rng.integers(mesh.Z)))
        else:
            s, d = (int(v) for v in rng.integers(mesh.n_nodes, size=2))
        while s == d:
            d = int(rng.integers(mesh.n_nodes))
        op = "init" if rng.random() < init_frac else "copy"
        extra = int(rng.integers(1, 3)) if (op == "copy" and
                                            rng.random() < extra_frac) else 0
        nbytes = int(rng.integers(1, 4096))
        reqs.append(CopyRequest(s, d if op == "copy" else s, nbytes,
                                max_extra_slots=extra, op=op))
    return reqs


def _circuit_key(c):
    if c is None:
        return None
    return (c.src, c.dst, c.start_cycle, c.n_windows, tuple(c.hops),
            c.slots_per_window, c.uses_bus, c.bus_column, c.distance)


def _assert_stream_identical(mesh, n_slots, reqs, *, wave=None,
                             cycle=0) -> TdmAllocator:
    """The batch under the fused backend == the serial allocate stream:
    circuits, final expiry table, commit/deny counts.  Returns the fused
    allocator (callers inspect its report)."""
    a_f = TdmAllocator(mesh, n_slots, backend="fused")
    a_s = TdmAllocator(mesh, n_slots)
    if wave is not None:
        a_f.search_wave = wave
        a_s.search_wave = wave
    res_f = a_f.allocate_batch(reqs, cycle=cycle)
    res_s = [a_s.allocate_batch([r], cycle=cycle)[0] for r in reqs]
    for i, (f, s) in enumerate(zip(res_f, res_s)):
        assert _circuit_key(f.circuit) == _circuit_key(s.circuit), (
            f"request {i} diverged: fused={f.circuit} serial={s.circuit}")
    np.testing.assert_array_equal(a_f.table.expiry, a_s.table.expiry)
    np.testing.assert_array_equal(a_f.table.bus_expiry, a_s.table.bus_expiry)
    rep = a_f.last_report
    n_committed = sum(r.circuit is not None for r in res_s)
    assert rep.n_committed == n_committed
    assert rep.n_denied == len(reqs) - n_committed
    return a_f


# --- kernel vs host oracle ---------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31), st.integers(0, 1))
def test_fused_prepare_matches_ref_oracle(seed, mesh_pick):
    """The compiled program's outputs are bit-identical to the numpy
    oracle on random occupancies and random request rows (denials from
    saturated destinations included)."""
    name = ("tall", "wide")[mesh_pick]
    mesh, n = MESHES[name], N_SLOTS[name]
    rng = np.random.default_rng(seed)
    # Random pre-existing circuits fill the table -> non-trivial occupancy.
    warm = TdmAllocator(mesh, n)
    warm.allocate_batch(_stream(rng, mesh, 48), cycle=0)
    occ = warm.table.busy_masks((0 + 3) // n)
    B = 32
    srcs = rng.integers(mesh.n_nodes, size=B).astype(np.int64)
    dsts = (srcs + 1 + rng.integers(mesh.n_nodes - 1, size=B)) % mesh.n_nodes
    t_readys = rng.integers(3, 50, size=B).astype(np.int64)
    got = fused_mod.fused_prepare(occ, srcs, dsts, t_readys, mesh=mesh,
                                  n_slots=n)
    want = ref_mod.fused_prepare_ref(occ, srcs, dsts, t_readys, mesh=mesh,
                                     n_slots=n)
    np.testing.assert_array_equal(got.denied, want.denied)
    np.testing.assert_array_equal(got.dists, want.dists)
    np.testing.assert_array_equal(got.starts, want.starts)
    np.testing.assert_array_equal(got.arr[~got.denied], want.arr[~want.denied])
    np.testing.assert_array_equal(got.ok, want.ok)
    live = ~got.denied & got.ok
    np.testing.assert_array_equal(got.hop_n[live], want.hop_n[live])
    np.testing.assert_array_equal(got.hop_p[live], want.hop_p[live])
    np.testing.assert_array_equal(got.hop_s[live], want.hop_s[live])


def test_slot_score_ref_matches_jnp():
    rng = np.random.default_rng(7)
    n = 16
    avail = rng.integers(0, 2**n, size=24, dtype=np.uint32)
    dists = rng.integers(0, 12, size=24)
    t = rng.integers(0, 40, size=24)
    import jax.numpy as jnp
    got = np.asarray(fused_mod._score_jnp(
        jnp.asarray(avail), jnp.asarray(dists, jnp.int32),
        jnp.asarray(t, jnp.int32), n))
    np.testing.assert_array_equal(got, ref_mod.slot_score_ref(avail, dists,
                                                              t, n))


# --- pipeline vs serial: the property sweep ----------------------------------
@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31), st.integers(0, 1), st.integers(0, 2))
def test_fused_batch_matches_serial_random(seed, mesh_pick, density):
    """Random streams over both topologies at three conflict densities
    (uniform, contended column, contended + op/extra mix)."""
    name = ("tall", "wide")[mesh_pick]
    mesh, n = MESHES[name], N_SLOTS[name]
    rng = np.random.default_rng(seed)
    if density == 0:
        reqs = _stream(rng, mesh, 96)
    elif density == 1:
        reqs = _stream(rng, mesh, 96, contended=True)
    else:
        reqs = _stream(rng, mesh, 96, contended=True, init_frac=0.2,
                       extra_frac=0.2)
    _assert_stream_identical(mesh, n, reqs)


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31), st.integers(0, 2))
def test_fused_batch_matches_serial_wave_sizes(seed, wave_pick):
    """Bit-identity holds for every search-wave size (the wave only
    changes snapshot staleness, which the conflict path must absorb)."""
    wave = (5, 16, 64)[wave_pick]
    mesh, n = MESHES["tall"], N_SLOTS["tall"]
    rng = np.random.default_rng(seed)
    reqs = _stream(rng, mesh, 40, contended=seed % 2 == 0)
    _assert_stream_identical(mesh, n, reqs, wave=wave)


def test_fused_conflicts_actually_exercised():
    """The contended sweep isn't vacuous: a contended wide-mesh stream
    drives stale-snapshot conflicts through the fused commit, and the
    result still matches serial."""
    mesh, n = MESHES["wide"], N_SLOTS["wide"]
    reqs = _stream(np.random.default_rng(1), mesh, 128, contended=True)
    a_f = _assert_stream_identical(mesh, n, reqs)
    assert a_f.last_report.conflicts > 0
    assert a_f.last_report.fused_waves > 0


def test_fused_denials_match_serial():
    """A saturated mesh denies the same requests under both paths."""
    mesh = MESHES["tall"]
    reqs = _stream(np.random.default_rng(5), mesh, 160, contended=True)
    a_f = _assert_stream_identical(mesh, 4, reqs)
    assert a_f.last_report.n_denied > 0


# --- telemetry ----------------------------------------------------------------
def test_backend_reports_agree_and_split_waves():
    """host and fused backends produce the same BatchReport (the wave
    structure is shared, only who serves each round differs) and the
    fused/host wave counters partition search_rounds."""
    mesh, n = MESHES["wide"], N_SLOTS["wide"]
    reqs = _stream(np.random.default_rng(11), mesh, 192)
    a_h = TdmAllocator(mesh, n, backend="host")
    a_f = TdmAllocator(mesh, n, backend="fused")
    a_h.allocate_batch(reqs, cycle=0)
    a_f.allocate_batch(reqs, cycle=0)
    rh, rf = a_h.last_report, a_f.last_report
    for field in ("n_requests", "n_committed", "n_denied", "search_rounds",
                  "conflicts", "n_searched"):
        assert getattr(rh, field) == getattr(rf, field), field
    assert rh.fused_waves == 0
    assert rh.host_waves == rh.search_rounds
    assert rf.fused_waves + rf.host_waves == rf.search_rounds
    assert rf.fused_waves >= 3                  # full waves went compiled
    np.testing.assert_array_equal(a_h.table.expiry, a_f.table.expiry)


def test_fabric_telemetry_carries_backend_split():
    from repro.core.fabric import NomFabric
    from repro.core.scheduler import TransferRequest
    mesh, n = MESHES["wide"], N_SLOTS["wide"]
    reqs = [TransferRequest(src=r.src, dst=r.dst, nbytes=r.nbytes)
            for r in _stream(np.random.default_rng(13), mesh, 128)]
    fab = NomFabric(mesh=mesh, n_slots=n, alloc_backend="auto")
    _res, rep = fab.schedule(reqs)
    assert rep.fused_waves + rep.host_waves == rep.search_rounds
    assert rep.fused_waves > 0
    tel = fab.telemetry()
    assert tel["fused_waves"] == rep.fused_waves
    assert tel["host_waves"] == rep.host_waves
    host = NomFabric(mesh=mesh, n_slots=n, alloc_backend="host")
    host.schedule(reqs)
    assert host.telemetry()["fused_waves"] == 0
    assert host.telemetry()["host_waves"] > 0


def test_allocator_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        TdmAllocator(MESHES["tall"], 8, backend="gpu")
