"""Sharding-rule unit tests (priority assignment, fallbacks, caches)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (default_rules, spec_for_cache,
                                     spec_for_param)


@pytest.fixture(scope="module")
def mesh():
    # shape (1,1) but named like production; rule logic only reads names +
    # sizes, so use a fake 16x16 via Mesh of devices? sizes matter for
    # divisibility -> build an abstract mesh (via the version-portable
    # helper: the AbstractMesh constructor changed between 0.4.x and 0.5+).
    from repro.launch.mesh import abstract_mesh
    return abstract_mesh((16, 16), ("data", "model"))


def test_vocab_and_heads_prefer_model(mesh):
    rules = default_rules(mesh, fsdp=True)
    # embedding (vocab, embed): model->vocab, data->embed (fsdp)
    assert spec_for_param(("vocab", "embed"), (151936, 4096), rules,
                          mesh) == P("model", "data")
    # attention q (embed, heads, head_dim), 64 heads divisible
    assert spec_for_param(("embed", "heads", "head_dim"),
                          (4096, 64, 128), rules, mesh) \
        == P("data", "model", None)


def test_non_divisible_heads_fall_back(mesh):
    rules = default_rules(mesh, fsdp=False)
    # 40 heads don't divide 16 -> model axis unused (CP attention handles
    # the compute); embed unsharded without fsdp
    assert spec_for_param(("embed", "heads", "head_dim"),
                          (5120, 40, 128), rules, mesh) == P(None, None,
                                                             None)


def test_experts_claim_model_before_mlp(mesh):
    rules = default_rules(mesh, fsdp=True)
    spec = spec_for_param(("experts", "embed", "mlp"), (128, 4096, 1536),
                          rules, mesh)
    assert spec == P("model", "data", None)


def test_no_axis_used_twice(mesh):
    rules = default_rules(mesh, fsdp=True)
    spec = spec_for_param(("vocab", "mlp"), (32000, 4096), rules, mesh)
    used = [s for s in spec if s is not None]
    assert len(used) == len(set(used))


def test_cache_spec_kv_seq(mesh):
    rules = default_rules(mesh, fsdp=False, kv_seq_axis="data")
    spec = spec_for_cache(("batch", "kv_seq", "kv_heads", "head_dim"),
                          (1, 524288, 16, 128), rules, mesh)
    # batch=1 not divisible -> dropped; seq on data; kv_heads on model
    assert spec == P(None, "data", "model", None)


def test_cache_spec_drops_non_divisible(mesh):
    rules = default_rules(mesh, fsdp=False, kv_seq_axis="model")
    spec = spec_for_cache(("batch", "kv_seq", "kv_heads", "head_dim"),
                          (128, 32768, 8, 128), rules, mesh)
    assert spec[1] == "model"      # seq claims model
    assert spec[2] is None         # kv=8 can't take it (already used)
