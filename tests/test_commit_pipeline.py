"""The vectorized CCU commit pipeline (PR 5 invariants).

* incremental ``SlotTable`` busy masks == a from-scratch expiry recompute
  across random reserve/expire sequences (property, hypothesis shim);
* conflict-scoped re-search commits bit-identically to the serial
  ``allocate`` stream — the same contract the old tail-wide re-search
  satisfied — for every search-wave size;
* memsim saturation raises ``FabricOverflow`` (with telemetry) instead of
  an ``assert`` that vanishes under ``python -O``;
* ``window_inflight`` pruning bounds the map without changing telemetry;
* engine tenant-queue aging: ``deadline_ticks`` sheds expired waiters,
  ``waiter_callback`` observes admit/expire/shed.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.fabric import FabricOverflow, NomFabric
from repro.core.scheduler import TransferRequest
from repro.core.slot_alloc import (Circuit, CopyRequest, SlotTable,
                                   TdmAllocator, TdmAllocatorLight)
from repro.core.topology import Mesh3D

MESH = Mesh3D(8, 8, 4)
N_SLOTS = 16


def _reference_masks(table: SlotTable, window: int) -> np.ndarray:
    """From-scratch expiry reduction — the old ``busy_masks`` spelling."""
    busy = table.expiry > window
    weights = np.uint32(1) << np.arange(table.n_slots, dtype=np.uint32)
    return (busy * weights).sum(axis=2).astype(np.uint32)


def _reference_bus_masks(table: SlotTable, window: int) -> np.ndarray:
    busy = table.bus_expiry > window
    weights = np.uint32(1) << np.arange(table.n_slots, dtype=np.uint32)
    return (busy * weights).sum(axis=1).astype(np.uint32)


# --- incremental slot table --------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31))
def test_incremental_masks_match_recompute_property(seed):
    """Random interleavings of reserve / bus-reserve / window queries
    (forward advances and occasional backward jumps, re-reservation of
    expired slots included) keep the incremental packed masks equal to a
    from-scratch recompute of the expiry arrays."""
    rng = np.random.default_rng(seed)
    mesh = Mesh3D(4, 4, 2)
    table = SlotTable(mesh, 8)
    window = 0
    for _ in range(60):
        roll = rng.random()
        if roll < 0.45:       # reserve a free (node, port, slot) bundle
            free = np.argwhere(table.expiry <= window)
            if len(free):
                pick = free[rng.integers(len(free))]
                circ = Circuit(src=int(pick[0]), dst=int(pick[0]),
                               start_cycle=0,
                               n_windows=int(rng.integers(1, 6)),
                               hops=[tuple(int(v) for v in pick)])
                table.reserve(circ, window)
        elif roll < 0.6:      # reserve a free bus (column, slot)
            free = np.argwhere(table.bus_expiry <= window)
            if len(free):
                col, slot = (int(v) for v in free[rng.integers(len(free))])
                table.reserve_bus(col, slot, window,
                                  int(rng.integers(1, 6)))
        elif roll < 0.9:      # advance the query window
            window += int(rng.integers(0, 4))
        else:                 # backward jump (re-anchored batch)
            window = max(0, window - int(rng.integers(1, 5)))
        np.testing.assert_array_equal(table.busy_masks(window),
                                      _reference_masks(table, window))
        np.testing.assert_array_equal(table.bus_busy_masks(window),
                                      _reference_bus_masks(table, window))
        np.testing.assert_array_equal(
            np.asarray(table.device_busy_masks(window)),
            _reference_masks(table, window))


def _rand_reqs(rng, n, with_extras=True):
    reqs = []
    for _ in range(n):
        s, d = rng.integers(MESH.n_nodes, size=2)
        while s == d:
            d = rng.integers(MESH.n_nodes)
        reqs.append(CopyRequest(
            int(s), int(d), int(rng.integers(64, 4096)),
            max_extra_slots=int(rng.integers(0, 4)) if with_extras else 0))
    return reqs


# --- conflict-scoped re-search ----------------------------------------------
@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31), st.integers(8, 80))
def test_scoped_researche_matches_serial_property(seed, n):
    """The conflict-scoped pipeline must yield exactly what the old
    tail-wide re-search yielded — both are defined by bit-identity with
    the serial allocate stream — on randomized contended batches."""
    reqs = _rand_reqs(np.random.default_rng(seed), n)
    for cls in (TdmAllocator, TdmAllocatorLight):
        serial, batched = cls(MESH, N_SLOTS), cls(MESH, N_SLOTS)
        want = [serial.allocate(r.src, r.dst, r.nbytes, 0, r.max_extra_slots)
                for r in reqs]
        got = batched.allocate_batch(reqs, cycle=0)
        for w, g in zip(want, got):
            assert (w.circuit is None) == (g.circuit is None)
            if w.circuit is not None:
                assert w.circuit.start_cycle == g.circuit.start_cycle
                assert w.circuit.hops == g.circuit.hops
        np.testing.assert_array_equal(serial.table.expiry,
                                      batched.table.expiry)
        np.testing.assert_array_equal(serial.table.bus_expiry,
                                      batched.table.bus_expiry)


@pytest.mark.parametrize("wave", [4, 16, 64, 1024])
def test_results_invariant_under_search_wave(wave):
    """The wave split is a scheduling detail: any wave size commits the
    same circuits (all bit-identical to serial)."""
    reqs = _rand_reqs(np.random.default_rng(11), 48)
    ref_alloc = TdmAllocator(MESH, N_SLOTS)
    ref = ref_alloc.allocate_batch(reqs, cycle=0)
    alloc = TdmAllocator(MESH, N_SLOTS)
    alloc.search_wave = wave
    got = alloc.allocate_batch(reqs, cycle=0)
    for r, g in zip(ref, got):
        assert (r.circuit is None) == (g.circuit is None)
        if r.circuit is not None:
            assert r.circuit.hops == g.circuit.hops
    np.testing.assert_array_equal(ref_alloc.table.expiry, alloc.table.expiry)


def test_single_conflict_searches_only_the_conflictor():
    """One contended pair ahead of a disjoint tail: exactly one extra
    search beyond the wave passes, however long the tail."""
    extras = {}
    for tail in (7, 28):
        reqs = [CopyRequest(MESH.node_id(0, 0, 0), MESH.node_id(1, 0, 0), 256),
                CopyRequest(MESH.node_id(0, 0, 0), MESH.node_id(1, 0, 0), 256)]
        lanes = [(y, z) for z in range(MESH.Z) for y in range(1, MESH.Y)]
        for y, z in lanes[:tail]:
            reqs.append(CopyRequest(MESH.node_id(0, y, z),
                                    MESH.node_id(MESH.X - 1, y, z), 256))
        alloc = TdmAllocator(MESH, N_SLOTS)
        res = alloc.allocate_batch(reqs, cycle=0)
        rep = alloc.last_report
        assert all(r.circuit is not None for r in res)
        assert rep.conflicts == 1
        waves = -(-len(reqs) // alloc.search_wave)
        extras[tail] = (rep.search_rounds - waves, rep.n_searched - len(reqs))
    # one conflict == one extra (round, request-search), tail-independent
    assert extras[7] == extras[28] == (1, 1)


def test_report_n_searched_flows_to_fabric_telemetry():
    fab = NomFabric(mesh=MESH, n_slots=N_SLOTS)
    reqs = [TransferRequest(src=r.src, dst=r.dst, nbytes=r.nbytes)
            for r in _rand_reqs(np.random.default_rng(5), 24, False)]
    _res, rep = fab.schedule(reqs)
    assert rep.n_searched >= rep.n_requests
    merged = rep.merge(rep)
    assert merged.n_searched == 2 * rep.n_searched
    assert fab.telemetry()["searched_requests"] == rep.n_searched


# --- memsim: FabricOverflow + window_inflight pruning ------------------------
def _saturating_items():
    from repro.memsim.workloads import Op, Request
    # 16 slots on the 0->1 link hold ~64KB transfers for thousands of
    # windows; the 17th request cannot find a circuit within 64 retry
    # windows -> the mesh is persistently saturated.
    r = Request(op=Op.COPY, src_bank=0, src_row=0, dst_bank=1, dst_row=1,
                nbytes=1 << 16)
    return [(i, r) for i in range(N_SLOTS + 1)]


def test_memsim_saturation_raises_fabric_overflow():
    from repro.memsim import SimParams
    from repro.memsim.simulator import MemorySystem
    sys_ = MemorySystem(SimParams(config="nom"))
    with pytest.raises(FabricOverflow) as exc:
        sys_.copy_nom_batch(_saturating_items())
    err = exc.value
    assert err.retries == 64
    assert err.request.nbytes == 1 << 16
    assert err.telemetry["table_utilization"] > 0
    assert "saturated" in str(err)


def test_window_inflight_pruning_keeps_telemetry_exact():
    from repro.memsim import SimParams, WorkloadSpec, generate, simulate
    from repro.memsim.simulator import MemorySystem
    reqs = generate(WorkloadSpec("fileCopy60", n_requests=600, seed=3))
    pruned = simulate(reqs, SimParams(config="nom", window=64))
    unpruned_prune = MemorySystem._prune_inflight
    try:
        MemorySystem._prune_inflight = lambda self, horizon: None
        full = simulate(reqs, SimParams(config="nom", window=64))
    finally:
        MemorySystem._prune_inflight = unpruned_prune
    assert pruned.extra["nom_inflight_avg"] == full.extra["nom_inflight_avg"]
    assert pruned.extra["nom_inflight_max"] == full.extra["nom_inflight_max"]
    assert pruned.ipc == full.ipc


def test_window_inflight_map_stays_bounded():
    from repro.memsim import SimParams
    from repro.memsim.simulator import MemorySystem
    from repro.memsim.workloads import Op, Request
    sys_ = MemorySystem(SimParams(config="nom"))
    at = 0
    for i in range(200):
        r = Request(op=Op.COPY, src_bank=(2 * i) % 250,
                    src_row=0, dst_bank=(2 * i) % 250 + 1, dst_row=1,
                    nbytes=4096)
        sys_.copy_nom_batch([(at, r)])
        at += 600      # long quiet gaps: old code kept every window forever
    stats = sys_.inflight_stats()
    assert stats[0] > 0 and stats[1] >= 1
    # live map only holds windows at/past the last pickup horizon
    assert len(sys_.window_inflight) < 200


# --- engine tenant-queue aging ----------------------------------------------
class _CacheStub:
    def init_caches(self, batch, max_len):
        return {"kv": jnp.zeros((batch, max_len, 8), jnp.int8),
                "state": jnp.zeros((batch, 16), jnp.int8)}


def _engine(**kw):
    from repro.serving import Engine
    return Engine(model=_CacheStub(), cfg=None, max_len=16,
                  cache_mesh=Mesh3D(2, 2, 2), ring_slots=4, **kw)


def test_deadline_ticks_sheds_expired_waiters():
    events = []
    eng = _engine(admission="queue", idle_evict_ticks=0, deadline_ticks=2,
                  waiter_callback=lambda name, ev: events.append((name, ev)))
    eng.open_tenant("a", batch=1)
    eng.open_tenant("b", batch=1)
    assert eng.open_tenant("c", batch=1) is None          # parked
    eng.schedule_tick()
    assert len(eng.tenant_queue.items) == 1               # still waiting
    eng.schedule_tick()                                   # age 2 -> expired
    assert len(eng.tenant_queue.items) == 0
    tel = eng.transfer_telemetry()
    assert tel["tenant_queue_expired"] == 1
    assert ("c", "expired") in events
    # the expired waiter is gone: closing "a" admits nobody
    eng.close_tenant("a")
    assert sorted(eng.tenants()) == ["b"]


def test_waiter_callback_sees_admission_and_shed():
    events = []
    eng = _engine(admission="queue", idle_evict_ticks=0, deadline_ticks=0,
                  tenant_queue_depth=1,
                  waiter_callback=lambda name, ev: events.append((name, ev)))
    eng.open_tenant("a", batch=1)
    eng.open_tenant("b", batch=1)
    eng.open_tenant("c", batch=1)          # queued (no event yet)
    eng.open_tenant("d", batch=1)          # queue full -> shed
    assert events == [("d", "shed")]
    eng.close_tenant("a")                  # frees capacity -> c admitted
    assert ("c", "admitted") in events
    assert "c" in eng.tenants()


def test_deadline_zero_never_expires():
    eng = _engine(admission="queue", idle_evict_ticks=0, deadline_ticks=0)
    eng.open_tenant("a", batch=1)
    eng.open_tenant("b", batch=1)
    eng.open_tenant("c", batch=1)
    for _ in range(6):
        eng.schedule_tick()
    assert len(eng.tenant_queue.items) == 1
    assert eng.transfer_telemetry()["tenant_queue_expired"] == 0
