"""Multi-tenant placement + eviction-as-INIT: zero-hop INIT circuits,
BankPool lease discipline, per-policy isolation properties, the engine's
tenant lifecycle, memsim INIT accounting, and the MoE single-router
invariant (traced-routing reuse)."""
import jax
import pytest

from repro.core import Mesh3D, TdmAllocator, TransferRequest
from repro.core.scheduler import schedule_transfers
from repro.core.topology import PORT_LOCAL
from repro.memsim import SimParams, WorkloadSpec, generate, simulate
from repro.serving import (BankPool, LeafSpec, step_requests,
                           teardown_requests)

from conftest import run_multidevice

KEY = jax.random.PRNGKey(0)

LEAVES = [LeafSpec(tag=f"leaf{i}", step_bytes=128, lease_bytes=2048,
                   ring_slots=4 if i % 2 == 0 else 0) for i in range(4)]


# --- INIT-class requests through the scheduler ---------------------------------
def test_init_is_zero_hop_and_reported():
    alloc = TdmAllocator(Mesh3D(4, 4, 2), 16)
    reqs = [TransferRequest(src=20, dst=20, nbytes=16384, op="init",
                            tag="scrub"),
            TransferRequest(src=0, dst=21, nbytes=512, tag="copy")]
    results, rep = schedule_transfers(reqs, allocator=alloc, cycle=0)
    assert rep.n_scheduled == 2 and rep.n_init == 1
    c = results[0].circuit
    # zero-hop: only the bank's LOCAL port, no mesh links, no streaming
    assert c.distance == 0
    assert c.hops == [(20, PORT_LOCAL, c.hops[0][2])]
    # occupancy is row-granular (in-DRAM zeroing), not byte-streaming
    assert c.n_windows == -(-16384 // alloc.init_row_bytes)
    assert results[1].circuit.distance > 0


def test_init_requires_src_eq_dst():
    alloc = TdmAllocator(Mesh3D(4, 4, 2), 16)
    with pytest.raises(ValueError, match="src == dst"):
        schedule_transfers([TransferRequest(src=0, dst=1, op="init")],
                           allocator=alloc)


def test_init_merge_accumulates():
    alloc = TdmAllocator(Mesh3D(4, 4, 2), 16)
    _r1, a = schedule_transfers([TransferRequest(16, 16, 64, op="init")],
                                allocator=alloc, cycle=0)
    _r2, b = schedule_transfers([TransferRequest(17, 17, 64, op="init")],
                                allocator=alloc, cycle=64)
    assert a.merge(b).n_init == 2


# --- BankPool lease discipline --------------------------------------------------
def test_bankpool_never_double_leases():
    pool = BankPool(Mesh3D(4, 4, 2), policy="spread")
    homes = []
    for k in range(4):
        homes += [ls.home for ls in pool.lease(f"t{k}", LEAVES)]
    assert len(homes) == len(set(homes)) == 16
    assert pool.free_banks() == 0
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.lease("overflow", LEAVES[:1])
    freed = pool.release("t0")
    assert len(freed) == 4 and pool.free_banks() == 4
    again = pool.lease("t4", LEAVES)     # freed banks are re-leasable
    assert {ls.home for ls in again} == {ls.home for ls in freed}


def test_bankpool_lease_rolls_back_on_exhaustion():
    """A failed admission must not shrink the pool: partially-granted
    banks (and partition groups) are returned on the way out."""
    for policy in ("spread", "partition"):
        pool = BankPool(Mesh3D(4, 4, 2), policy=policy)
        pool.lease("t0", LEAVES * 3)         # 12 of 16 banks
        free = pool.free_banks()
        with pytest.raises(RuntimeError, match="exhausted"):
            pool.lease("t1", LEAVES * 2)     # needs 8, only 4 left
        assert pool.free_banks() == free     # nothing leaked
        assert pool.leases("t1") == []
        assert len(pool.lease("t1", LEAVES)) == 4   # retry at fitting size


def test_schedule_transfers_accepts_generator_input():
    alloc = TdmAllocator(Mesh3D(4, 4, 2), 16)
    results, rep = schedule_transfers(
        (TransferRequest(src=i, dst=16 + i, nbytes=64) for i in range(3)),
        allocator=alloc, cycle=0)
    assert rep.n_requests == 3 and rep.n_scheduled == 3


def test_bankpool_rejects_unknown_policy():
    with pytest.raises(ValueError, match="policy"):
        BankPool(Mesh3D(4, 4, 2), policy="roulette")


def test_partition_tenants_are_link_disjoint():
    """The partition policy's isolation guarantee: within one scheduled
    window, circuits of different tenants share no (router, port)."""
    mesh = Mesh3D(8, 8, 4)
    pool = BankPool(mesh, policy="partition")
    alloc = TdmAllocator(mesh, 16)
    reqs = []
    for k in range(3):
        reqs += step_requests(pool.lease(f"t{k}", LEAVES), pos=5)
    results, rep = schedule_transfers(reqs, allocator=alloc, cycle=0)
    assert rep.n_scheduled == rep.n_requests
    assert rep.n_init == 3 * 2           # wrapped ring leaves per tenant
    used: dict[str, set] = {}
    for rq, res in zip(reqs, results):
        tenant = rq.tag[0]
        used.setdefault(tenant, set()).update(
            (node, port) for node, port, _slot in res.circuit.hops)
    tenants = sorted(used)
    for a in tenants:
        for b in tenants:
            if a < b:
                assert not (used[a] & used[b]), (a, b, used[a] & used[b])


def test_partition_tenants_are_link_disjoint_single_layer():
    """On a single-layer mesh circuits run horizontally from the row's
    edge staging bank, so the partition policy isolates by *row*."""
    mesh = Mesh3D(4, 4, 1)
    pool = BankPool(mesh, policy="partition")
    alloc = TdmAllocator(mesh, 16)
    reqs = []
    for k in range(2):
        reqs += step_requests(pool.lease(f"t{k}", LEAVES), pos=0)
    results, rep = schedule_transfers(reqs, allocator=alloc, cycle=0)
    assert rep.n_scheduled == rep.n_requests
    used: dict[str, set] = {}
    for rq, res in zip(reqs, results):
        used.setdefault(rq.tag[0], set()).update(
            (node, port) for node, port, _slot in res.circuit.hops)
    assert not (used["t0"] & used["t1"]), used["t0"] & used["t1"]


def test_stall_feedback_repack_moves_homes_and_scrubs():
    pool = BankPool(Mesh3D(4, 4, 2), policy="stall_feedback")
    old = pool.lease("t", LEAVES)
    # below threshold: no-op
    assert pool.repack("t", stall_cycles=3, threshold=10) == ([], [])
    evicted, fresh = pool.repack("t", stall_cycles=500, threshold=10)
    assert [ls.leaf for ls in evicted] == [ls.leaf for ls in fresh]
    assert {ls.home for ls in evicted} == {ls.home for ls in old}
    assert not ({ls.home for ls in fresh} & {ls.home for ls in evicted})
    # the vacated homes become INIT scrubs covering the full footprint
    scrubs = teardown_requests(evicted)
    assert all(r.op == "init" and r.src == r.dst
               and r.nbytes == 2048 for r in scrubs)


def test_repack_reverts_when_no_better_homes_exist():
    """Under pool pressure the 'least-loaded' order would hand back the
    just-vacated banks; repack must revert instead of scrubbing homes
    that are still live."""
    pool = BankPool(Mesh3D(4, 4, 2), policy="stall_feedback")
    pool.lease("hog", LEAVES * 3)        # 12 of 16 banks
    before = {ls.home for ls in pool.lease("t", LEAVES)}
    assert pool.repack("t", stall_cycles=1000, threshold=0) == ([], [])
    assert {ls.home for ls in pool.leases("t")} == before
    assert pool.free_banks() == 0


def test_repack_is_noop_under_partition():
    pool = BankPool(Mesh3D(4, 4, 2), policy="partition")
    pool.lease("t", LEAVES)
    assert pool.repack("t", stall_cycles=10**6, threshold=0) == ([], [])


# --- engine tenant lifecycle ----------------------------------------------------
def test_engine_ring_wrap_and_teardown_emit_init(mesh1):
    from repro.configs import get_config
    from repro.models import make_model
    from repro.serving import Engine

    cfg = get_config("qwen1.5-4b", smoke=True)
    model = make_model(cfg)
    params = model.init(KEY)
    eng = Engine(model, cfg, max_len=64, ring_slots=3)
    prompt = jax.random.randint(KEY, (1, 4), 0, cfg.vocab)
    out = eng.generate(params, prompt, n_new=5)
    assert out.shape == (1, 9)
    tel = eng.transfer_telemetry()
    # KV leaves wrap from step 3 on (positions 3..7) and every lease is
    # scrubbed at teardown -> INITs well beyond the leaf count
    per_step = [r.n_init for r in eng.reports]
    assert sum(per_step[:3]) == 0                # before the wrap
    assert any(n > 0 for n in per_step[3:-1])    # wrapped steps evict
    assert per_step[-1] > 0                      # teardown scrub batch
    assert tel["init_requests"] == sum(per_step)
    assert tel["scheduled"] == tel["requests"]
    assert tel["active_tenants"] == 0            # lease released


def test_engine_two_streams_share_pool_without_double_lease(mesh1):
    from repro.configs import get_config
    from repro.models import make_model
    from repro.serving import Engine

    cfg = get_config("qwen1.5-4b", smoke=True)
    model = make_model(cfg)
    params = model.init(KEY)
    eng = Engine(model, cfg, max_len=64)
    a = eng.open_tenant("a", batch=1)
    b = eng.open_tenant("b", batch=1)
    assert not ({ls.home for ls in a} & {ls.home for ls in b})
    rep = eng.schedule_tick()            # both tenants in one batch
    assert rep.n_requests == len(a) + len(b)
    with pytest.raises(ValueError, match="already active"):
        eng.open_tenant("a", batch=1)
    eng.close_tenant("a")
    eng.close_tenant("b")
    with pytest.raises(ValueError, match="not active"):
        eng.close_tenant("a")                # double close is an error
    assert eng.transfer_telemetry()["peak_tenants"] == 2
    assert eng.pool.free_banks() == len(eng.pool._pool)


# --- memsim INIT accounting -----------------------------------------------------
def test_memsim_accounts_init_in_ccu_queue():
    reqs = generate(WorkloadSpec("fork", n_requests=600, seed=1))
    r = simulate(reqs, SimParams(config="nom"))
    assert r.extra["nom_ccu_init_reqs"] > 0
    assert r.extra["nom_ccu_init_peak"] >= 1
    assert r.extra["nom_ccu_init_windows"] >= r.extra["nom_ccu_init_reqs"]
    # INITs share the bounded queue: total peak covers them too
    assert r.extra["nom_ccu_peak_queue"] >= r.extra["nom_ccu_init_peak"]


def test_memsim_init_still_ordered_across_configs():
    """Routing INIT through the CCU must not break the paper's config
    ordering on an init-heavy mix."""
    reqs = generate(WorkloadSpec("fork", n_requests=600, seed=2))
    ipc = {cfg: simulate(reqs, SimParams(config=cfg)).ipc
           for cfg in ("conventional", "rowclone", "nom")}
    assert ipc["nom"] > ipc["rowclone"] > ipc["conventional"]


# --- MoE: traced-routing reuse (single router invocation) -----------------------
@pytest.mark.slow
def test_moe_eager_apply_runs_router_once_on_8_devices():
    out = run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.models.moe import MoE, MoEConfig
from repro.launch.mesh import make_mesh, set_ambient_mesh
mesh = make_mesh((1, 8), ("data", "model"))
set_ambient_mesh(mesh)
calls = []
orig = MoE._route
MoE._route = lambda self, rw, x: (calls.append(1), orig(self, rw, x))[1]
cfg = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                dispatch="nom", capacity_factor=4.0)
moe = MoE(cfg)
p = moe.init(jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
y, aux = moe.apply(p, x)
assert len(calls) == 1, calls      # routed once, inside the traced body
traced = moe.last_dispatch_report
assert traced is not None and traced.n_requests > 0
# the traced-blocks plan matches the host-side re-route exactly
MoE._route = orig
plan_host, host = moe.plan_dispatch(p, x)
assert host.n_requests == traced.n_requests
assert host.n_scheduled == traced.n_scheduled
assert host.n_windows == traced.n_windows
print("ROUTER_ONCE_OK")
""")
    assert "ROUTER_ONCE_OK" in out
