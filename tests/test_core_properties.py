"""Property tests on core invariants (bitvec algebra, topology, routing)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bitvec import (bit_is_free, free_slots, full_mask, rotl_np,
                               rotr_np)
from repro.core.topology import Mesh3D, PORT_LOCAL, port_for


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 32), st.integers(0, 2**32 - 1))
def test_rotr_rotl_inverse(n_slots, v):
    v = np.uint32(v & full_mask(n_slots))
    assert rotl_np(rotr_np(v, n_slots), n_slots) == v
    assert rotr_np(rotl_np(v, n_slots), n_slots) == v


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 32), st.integers(0, 2**32 - 1))
def test_rotr_preserves_popcount(n_slots, v):
    v = np.uint32(v & full_mask(n_slots))
    assert bin(int(rotr_np(v, n_slots))).count("1") == bin(int(v)).count("1")


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 255), st.integers(0, 255))
def test_dor_path_validity(a, b):
    mesh = Mesh3D(8, 8, 4)
    if a == b:
        return
    path = mesh.dor_path(a, b)
    assert len(path) == mesh.manhattan(a, b) + 1
    assert path[0][0] == a and path[-1] == (b, PORT_LOCAL)
    # every hop moves to an adjacent node through the named port
    for (n1, p1), (n2, _p2) in zip(path, path[1:]):
        assert mesh.neighbor(n1, p1) == n2


def test_vault_partition_is_exact():
    mesh = Mesh3D(8, 8, 4)
    seen = set()
    for v in range(mesh.n_vaults):
        banks = mesh.banks_of_vault(v)
        assert len(banks) == 8                      # HMC: 8 banks per vault
        for b in banks:
            assert mesh.vault_of(b) == v
            seen.add(b)
    assert seen == set(range(mesh.n_nodes))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 31), st.integers(0, 2**31))
def test_free_slots_consistent(n_slots, v):
    v = int(v) & full_mask(n_slots)
    fs = free_slots(v, n_slots)
    for s in range(n_slots):
        assert (s in fs) == bit_is_free(v, s)


def test_rope_is_rotation():
    """RoPE preserves pairwise norms and relative-position inner products."""
    import jax
    import jax.numpy as jnp
    from repro.models.common import apply_rope
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 16, 2, 32)), jnp.float32)
    pos = jnp.arange(16)[None]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # shift covariance: <R(p)q, R(p+d)k> == <R(0)q, R(d)k>
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
    def dot_at(pq, pk):
        qq = apply_rope(q, jnp.asarray([[pq]]))
        kk = apply_rope(k, jnp.asarray([[pk]]))
        return float((qq * kk).sum())
    np.testing.assert_allclose(dot_at(3, 7), dot_at(10, 14), rtol=1e-4)


def test_softcap_bounds():
    import jax.numpy as jnp
    from repro.models.common import softcap
    x = jnp.asarray(np.linspace(-1e4, 1e4, 101), jnp.float32)
    y = np.asarray(softcap(x, 50.0))
    assert np.all(np.abs(y) <= 50.0 + 1e-3)
    assert np.all(np.diff(y) >= 0)   # monotone
