"""NomFabric: policy registry, admission control, auto-tuning, the
deprecated shim, engine tenant admission, and the INIT-row calibration."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Mesh3D, NomFabric, TransferRequest
from repro.core.fabric import (AdmissionQueue, FabricOverflow, get_policy,
                               register_policy, registered_policies,
                               unregister_policy)
from repro.core.scheduler import schedule_transfers
from repro.core.slot_alloc import TdmAllocator
from repro.memsim import (EnergyParams, SimParams, WorkloadSpec, energy_pj,
                          generate, init_energy_per_row, simulate)
from repro.memsim.simulator import MemorySystem

MESH = Mesh3D(4, 4, 2)


def _bank_reqs(n=6, nbytes=256):
    return [TransferRequest(src=i, dst=16 + (i * 3) % 16, nbytes=nbytes,
                            tag=f"r{i}") for i in range(n)]


# The two bench mixes with *different* static winners (see
# benchmarks/bench_fabric_autotune.py): skewed MoE a2a -> "arrival",
# serving edge fan-out -> "longest_first".
def _moe_mix():
    rng = np.random.default_rng(7)
    ep, reqs = 8, []
    for r in range(ep):
        for q in range(ep):
            if r == q:
                continue
            nbytes = int(rng.integers(1, 9)) * (3 if q < 2 else 1) * 512
            reqs.append(TransferRequest((r,), (q,), nbytes))
            reqs.append(TransferRequest((q,), (r,), nbytes))
    return (ep,), True, reqs


def _serving_mix():
    return (8, 4), False, [
        TransferRequest((0, i % 4), ((1 + (i * 3) % 7), i % 4),
                        nbytes=(i % 3 + 1) * 2048) for i in range(24)]


# --- policy registry ----------------------------------------------------------
def test_unknown_policy_raises_with_registry_listing():
    with pytest.raises(ValueError, match="arrival"):
        get_policy("roulette")
    with pytest.raises(ValueError, match="unknown policy"):
        NomFabric(shape=(4,), policy="roulette")
    fab = NomFabric(shape=(4,))
    with pytest.raises(ValueError, match="unknown policy"):
        fab.schedule([TransferRequest((0,), (1,))], policy="roulette")


def test_custom_policy_roundtrip():
    @register_policy("widest_first")
    def widest_first(reqs, ctx):
        return sorted(range(len(reqs)), key=lambda i: -reqs[i].nbytes)

    try:
        assert "widest_first" in registered_policies()
        with pytest.raises(ValueError, match="already registered"):
            register_policy("widest_first")(widest_first)
        fab = NomFabric(shape=(8,), policy="widest_first")
        reqs = [TransferRequest((i,), ((i + 1) % 8,), nbytes=1 << i)
                for i in range(6)]
        _plan, rep = fab.schedule(reqs)
        assert rep.n_scheduled == 6
    finally:
        unregister_policy("widest_first")
    assert "widest_first" not in registered_policies()
    with pytest.raises(ValueError, match="not registered"):
        unregister_policy("widest_first")
    with pytest.raises(ValueError, match="built-in"):
        unregister_policy("arrival")


def test_policy_must_return_permutation():
    @register_policy("broken")
    def broken(reqs, ctx):
        return [0] * len(reqs)

    try:
        with pytest.raises(ValueError, match="permutation"):
            NomFabric(shape=(4,), policy="broken").schedule(
                [TransferRequest((0,), (1,)), TransferRequest((1,), (2,))])
    finally:
        unregister_policy("broken")


def test_exactly_one_backend():
    with pytest.raises(ValueError, match="exactly one"):
        NomFabric()
    with pytest.raises(ValueError, match="exactly one"):
        NomFabric(mesh=MESH, shape=(4,))


# --- the deprecated shim ------------------------------------------------------
def test_shim_warns_and_matches_fabric():
    reqs = _bank_reqs()
    with pytest.warns(DeprecationWarning, match="NomFabric"):
        legacy, rep_l = schedule_transfers(reqs,
                                           allocator=TdmAllocator(MESH, 16),
                                           cycle=0)
    results, rep_f = NomFabric(mesh=MESH, n_slots=16).schedule(reqs, cycle=0)
    assert [r.circuit.hops for r in legacy] == \
        [r.circuit.hops for r in results]
    assert rep_l == rep_f

    with pytest.warns(DeprecationWarning):
        plan_l, rrep_l = schedule_transfers(
            [TransferRequest((0,), (3,)), TransferRequest((2,), (5,))],
            shape=(8,), policy="longest_first")
    plan_f, rrep_f = NomFabric(shape=(8,), policy="longest_first").schedule(
        [TransferRequest((0,), (3,)), TransferRequest((2,), (5,))])
    assert plan_l.starts == plan_f.starts and rrep_l == rrep_f


def test_longest_first_matches_legacy_plan_transfers():
    """The registered policy reproduces plan_transfers' built-in sort
    exactly (stable ties included)."""
    from repro.core.nom_collectives import Transfer, plan_transfers
    rng = np.random.default_rng(3)
    transfers = []
    for _ in range(30):
        s = (int(rng.integers(4)), int(rng.integers(4)))
        d = (int(rng.integers(4)), int(rng.integers(4)))
        transfers.append(Transfer(src=s, dst=d, nbytes=64))
    legacy = plan_transfers((4, 4), transfers, policy="longest_first")
    plan, _rep = NomFabric(shape=(4, 4), policy="longest_first").schedule(
        transfers)
    assert plan.starts == legacy.starts


# --- admission queue: shed / block / raise ------------------------------------
def test_overflow_shed_drops_and_counts():
    fab = NomFabric(mesh=MESH, queue_depth=2, overflow="shed")
    admitted = [fab.submit(r) for r in _bank_reqs(5)]
    assert admitted == [True, True, False, False, False]
    assert fab.telemetry()["shed"] == 3 and fab.pending == 2
    _results, rep = fab.flush()
    assert rep.n_requests == 2
    assert fab.flush() is None          # queue drained


def test_overflow_block_flushes_inline_and_stalls():
    fab = NomFabric(mesh=MESH, queue_depth=2, overflow="block")
    for r in _bank_reqs(5):
        assert fab.submit(r)
    tel = fab.telemetry()
    assert tel["full_stalls"] == 2 and tel["flushes"] == 2
    assert tel["queue_stall_cycles"] > 0     # pickup-pipeline backpressure
    assert fab.pending == 1


def test_overflow_raise():
    fab = NomFabric(mesh=MESH, queue_depth=1, overflow="raise")
    assert fab.submit(_bank_reqs(1)[0])
    with pytest.raises(FabricOverflow):
        fab.submit(_bank_reqs(2)[1])


def test_admission_queue_rejects_unknown_overflow():
    with pytest.raises(ValueError, match="overflow"):
        AdmissionQueue(depth=2, overflow="explode")


def test_flush_models_pickup_pipeline():
    fab = NomFabric(mesh=MESH, queue_depth=8)
    for r in _bank_reqs(4):
        fab.submit(r, at=10)
    fab.flush()
    # 3-cycle fill + 1/request after the head's arrival
    assert fab.queue.busy_until == 10 + 3 + 3


# --- telemetry ----------------------------------------------------------------
def test_session_telemetry_accumulates():
    fab = NomFabric(mesh=MESH)
    fab.schedule(_bank_reqs(4))
    fab.schedule([TransferRequest(src=20, dst=20, nbytes=8192, op="init")])
    tel = fab.telemetry()
    assert tel["flushes"] == 2 and tel["requests"] == 5
    assert tel["init_requests"] == 1 and tel["scheduled"] == 5
    assert len(fab.history) == 2
    assert fab.report.n_requests == 5
    # the second batch anchored after the first drained
    assert fab.clock > 0 and fab.last_cycle > 0


def test_init_requires_src_eq_dst_in_fabric():
    fab = NomFabric(mesh=MESH)
    with pytest.raises(ValueError, match="src == dst"):
        fab.schedule([TransferRequest(src=0, dst=1, op="init")])


# --- auto-tuning --------------------------------------------------------------
def test_auto_is_deterministic():
    def run():
        shape, torus, reqs = _moe_mix()
        fab = NomFabric(shape=shape, torus=torus, policy="auto")
        for _ in range(6):
            fab.schedule(reqs)
        return fab.telemetry(), [r.stall_cycles for r in fab.history]
    assert run() == run()


@pytest.mark.parametrize("mix,winner", [(_moe_mix, "arrival"),
                                        (_serving_mix, "longest_first")])
def test_auto_adapts_policy_to_the_mix(mix, winner):
    """After probing, auto settles on the static winner of each mix and
    its steady-state per-flush cost matches it; the session total never
    loses to the *worst* static by more than the 5% acceptance bound."""
    shape, torus, reqs = mix()
    n_flushes = 8

    def cost(rep):
        return rep.stall_cycles + rep.n_windows

    static = {}
    for policy in ("arrival", "longest_first"):
        fab = NomFabric(shape=shape, torus=torus, policy=policy)
        static[policy] = sum(cost(fab.schedule(reqs)[1])
                             for _ in range(n_flushes))
    assert min(static, key=static.get) == winner, static

    auto = NomFabric(shape=shape, torus=torus, policy="auto")
    costs = [cost(auto.schedule(reqs)[1]) for _ in range(n_flushes)]
    assert auto.effective_policy == winner
    # steady state (post-probe) == the winner's per-flush cost
    assert costs[-1] == static[winner] / n_flushes
    assert sum(costs) <= max(static.values()) * 1.05


def test_auto_queue_depth_grows_on_backpressure_and_shrinks_when_calm():
    fab = NomFabric(mesh=MESH, n_slots=16, policy="auto", queue_depth=2,
                    overflow="block")
    assert fab.effective_queue_depth == 2
    for _ in range(3):                       # bursts overflow the queue
        for r in _bank_reqs(12):
            fab.submit(r)
        fab.flush()
    grown = fab.effective_queue_depth
    assert grown > 2
    for _ in range(12):                      # trickle: under-filled drains
        fab.submit(_bank_reqs(1)[0])
        fab.flush()
    assert fab.effective_queue_depth < grown


def test_static_policy_fabric_never_retunes():
    fab = NomFabric(mesh=MESH, policy="arrival", queue_depth=4)
    for _ in range(6):
        fab.schedule(_bank_reqs(2))
    assert fab.effective_policy == "arrival"
    assert fab.telemetry()["policy_switches"] == 0
    assert fab.effective_queue_depth == 4    # depth tuning is auto-only


# --- engine tenant admission --------------------------------------------------
class _CacheStub:
    """Two leaves per stream -> two banks per tenant; Mesh3D(2, 2, 2)'s
    leasable pool is 4 banks, so the third tenant exhausts it."""

    def init_caches(self, batch, max_len):
        return {"kv": jnp.zeros((batch, max_len, 8), jnp.int8),
                "state": jnp.zeros((batch, 16), jnp.int8)}


def _engine(**kw):
    from repro.serving import Engine
    return Engine(model=_CacheStub(), cfg=None, max_len=16,
                  cache_mesh=Mesh3D(2, 2, 2), ring_slots=4, **kw)


def test_open_tenant_queues_on_exhaustion_and_admits_on_close():
    eng = _engine(admission="queue", idle_evict_ticks=0)
    assert eng.open_tenant("a", batch=1) is not None
    assert eng.open_tenant("b", batch=1) is not None
    assert eng.open_tenant("c", batch=1) is None      # parked, not raised
    eng.schedule_tick()
    assert eng.transfer_telemetry()["queued_tenants"] == 1
    assert sorted(eng.tenants()) == ["a", "b"]
    eng.close_tenant("a")                             # frees 2 banks -> admit c
    assert sorted(eng.tenants()) == ["b", "c"]
    assert eng.transfer_telemetry()["queued_tenants"] == 0
    eng.schedule_tick()                               # c's traffic schedules
    eng.close_tenant("b")
    eng.close_tenant("c")
    assert eng.pool.free_banks() == 4


def test_open_tenant_sheds_when_configured():
    eng = _engine(admission="shed", idle_evict_ticks=0)
    eng.open_tenant("a", batch=1)
    eng.open_tenant("b", batch=1)
    assert eng.open_tenant("c", batch=1) is None
    assert eng.open_tenant("d", batch=1) is None
    eng.schedule_tick()
    tel = eng.transfer_telemetry()
    assert tel["shed_tenants"] == 2 and tel["queued_tenants"] == 0


def test_open_tenant_raise_mode_keeps_legacy_error():
    eng = _engine(admission="raise", idle_evict_ticks=0)
    eng.open_tenant("a", batch=1)
    eng.open_tenant("b", batch=1)
    with pytest.raises(RuntimeError, match="exhausted"):
        eng.open_tenant("c", batch=1)


def test_exhaustion_reclaims_idle_leases_first():
    eng = _engine(admission="queue", idle_evict_ticks=2)
    eng.open_tenant("idle", batch=1)
    eng.open_tenant("busy", batch=1)
    for _ in range(3):
        eng.schedule_tick(["busy"])       # "idle" never ticks
    fresh = eng.open_tenant("fresh", batch=1)
    assert fresh is not None              # admitted by evicting "idle"
    tel = eng.transfer_telemetry()
    assert tel["idle_evictions"] == 1
    assert sorted(eng.tenants()) == ["busy", "fresh"]
    assert tel["init_requests"] > 0       # the reclaim scrubbed the homes


def test_double_open_still_rejected():
    eng = _engine()
    eng.open_tenant("a", batch=1)
    with pytest.raises(ValueError, match="already active"):
        eng.open_tenant("a", batch=1)


def test_queued_name_cannot_queue_twice():
    """A name parked on the admission queue must not be queueable again
    (a duplicate would later double-lease under one tenant record and
    leave the first grant's homes unscrubbed at close)."""
    eng = _engine(admission="queue", idle_evict_ticks=0)
    eng.open_tenant("a", batch=1)
    eng.open_tenant("b", batch=1)
    assert eng.open_tenant("c", batch=1) is None      # parked
    with pytest.raises(ValueError, match="already queued"):
        eng.open_tenant("c", batch=1)
    eng.close_tenant("a")                             # admits the single c
    assert "c" in eng.tenants()
    eng.close_tenant("b")
    eng.close_tenant("c")
    assert eng.pool.free_banks() == 4


def test_idle_evicted_handle_stays_usable():
    """The evicted owner's handle goes inert, not invalid: its ticks are
    skipped and its close is a quiet no-op."""
    eng = _engine(admission="queue", idle_evict_ticks=2)
    eng.open_tenant("idle", batch=1)
    eng.open_tenant("busy", batch=1)
    for _ in range(3):
        eng.schedule_tick(["busy"])
    assert eng.open_tenant("fresh", batch=1) is not None  # evicts "idle"
    rep = eng.schedule_tick(["idle", "busy"])         # skipped, not raised
    assert rep is not None and rep.n_requests > 0
    assert eng.close_tenant("idle") is None           # quiet no-op
    with pytest.raises(ValueError, match="not active"):
        eng.close_tenant("idle")                      # double close still errs
    eng.close_tenant("busy")
    eng.close_tenant("fresh")


def test_blocked_submit_stall_does_not_grow_with_session_age():
    """flush() advances the fabric clock past its drain, so a blocked
    submit is charged only the pickup-pipeline wait — not the whole
    session's elapsed time."""
    fab = NomFabric(mesh=MESH, queue_depth=2, overflow="block")
    for r in _bank_reqs(12, nbytes=64):
        fab.submit(r)
    tel = fab.telemetry()
    assert tel["full_stalls"] == 5
    # each overflow waits <= one pickup pipeline (3 + depth-1 = 4 cycles)
    assert tel["queue_stall_cycles"] <= tel["full_stalls"] * 4


def test_generate_sheds_tracking_when_pool_is_full(mesh1):
    """`generate` on an exhausted pool streams tokens untracked instead
    of raising (the stream is counted as shed)."""
    import jax
    from repro.configs import get_config
    from repro.models import make_model

    cfg = get_config("qwen1.5-4b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.serving import Engine
    eng = Engine(model, cfg, max_len=32, cache_mesh=Mesh3D(2, 2, 2),
                 idle_evict_ticks=0)
    n_leaves = len(eng._leaf_specs(1))
    hogs = 0
    while eng.pool.free_banks() >= n_leaves:
        eng.open_tenant(f"hog{hogs}", batch=1)
        hogs += 1
    before = eng.n_sched_steps
    out = eng.generate(params, jax.random.randint(
        jax.random.PRNGKey(1), (1, 3), 0, cfg.vocab), n_new=3)
    assert out.shape == (1, 6)                       # tokens still stream
    assert eng.n_sched_steps == before               # but nothing scheduled
    assert eng.tenant_queue.n_shed == 1
    assert sorted(eng.tenants()) == sorted(f"hog{i}" for i in range(hogs))


# --- memsim calibration + INIT energy ----------------------------------------
def test_init_row_bytes_calibrated_to_rowclone_timing():
    p = SimParams(config="nom", mesh=Mesh3D(4, 4, 2))
    sys = MemorySystem(p)
    t = p.timing
    per_row = -(-t.rowclone_fpm // p.n_slots)
    assert sys.init_windows_per_row == per_row > 1
    assert sys.alloc.init_row_bytes == -(-t.row_bytes // per_row)
    # a one-row INIT circuit now holds its LOCAL port for the zeroing time
    results, _rep = sys.fabric.schedule(
        [TransferRequest(src=20, dst=20, nbytes=t.row_bytes, op="init")],
        cycle=0)
    assert results[0].circuit.n_windows == per_row


def test_memsim_counts_init_rows_and_energy_charges_them():
    reqs = generate(WorkloadSpec("fork", n_requests=400, seed=3))
    r = simulate(reqs, SimParams(config="nom"))
    assert r.extra["init_rows"] > 0
    e = energy_pj(r)
    assert e["dram_init"] == r.extra["init_rows"] * EnergyParams().e_init_row
    assert e["dram_init"] > 0 and e["total"] > e["dram_init"]
    assert init_energy_per_row() == EnergyParams().e_init_row
    # no double charge: the zeroed bytes are excluded from the per-line
    # column-I/O term (in-DRAM zeroing moves nothing through the mats)
    from repro.memsim.workloads import LINE
    lines = (r.copy_bytes - r.extra["init_bytes"]) // LINE
    assert e["dram"] == pytest.approx(
        (lines + max(r.reqs, 1))
        * (EnergyParams().e_act_pre * 0.3 + EnergyParams().e_rd_wr))
    conv = simulate(reqs, SimParams(config="conventional"))
    assert "init_rows" not in conv.extra             # pays via stores instead
    assert energy_pj(conv)["dram_init"] == 0


def test_memsim_ccu_is_a_fabric_admission_queue():
    p = SimParams(config="nom", mesh=Mesh3D(4, 4, 2))
    sys = MemorySystem(p)
    assert sys.ccu is sys.fabric.queue               # one implementation
    assert isinstance(sys.ccu, AdmissionQueue)


# --- the API gate -------------------------------------------------------------
def test_check_api_gate_passes_and_detects_violations(tmp_path):
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "scripts"))
    try:
        import check_api
    finally:
        sys.path.pop(0)
    assert check_api.violations(
        pathlib.Path(__file__).parent.parent) == []
    bad = tmp_path / "src" / "repro" / "serving"
    bad.mkdir(parents=True)
    (bad / "rogue.py").write_text(
        "from repro.core.scheduler import schedule_transfers\n"
        "def f(reqs, alloc):\n"
        "    return schedule_transfers(reqs, allocator=alloc)  # no!\n")
    hits = check_api.violations(tmp_path)
    assert len(hits) == 1 and "rogue.py:3" in hits[0]
