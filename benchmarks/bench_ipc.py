"""Fig. 4 — IPC of NoM / NoM-Light vs RowClone vs conventional 3D DRAM.

Reports the paper's headline ratios: NoM ~3.8x conventional, ~1.75x
RowClone, NoM-Light within 5-20% of NoM.
"""
import time

import numpy as np

from repro.memsim import SimParams, WorkloadSpec, generate, simulate

WORKLOADS = ("fork", "fileCopy20", "fileCopy40", "fileCopy60")
CONFIGS = ("conventional", "rowclone", "nom", "nom_light")


def run(n_requests: int = 1200):
    rows = []
    ipc = {}
    for wl in WORKLOADS:
        reqs = generate(WorkloadSpec(wl, n_requests=n_requests, seed=1))
        for cfg in CONFIGS:
            t0 = time.perf_counter()
            r = simulate(reqs, SimParams(config=cfg), name=wl)
            us = (time.perf_counter() - t0) * 1e6
            ipc[(wl, cfg)] = r.ipc
            rows.append((f"ipc/{wl}/{cfg}", us, f"ipc={r.ipc:.4f}"))
    gm = lambda xs: float(np.exp(np.mean(np.log(xs))))
    vs_conv = gm([ipc[(w, "nom")] / ipc[(w, "conventional")]
                  for w in WORKLOADS])
    vs_rc = gm([ipc[(w, "nom")] / ipc[(w, "rowclone")] for w in WORKLOADS])
    gaps = [1 - ipc[(w, "nom_light")] / ipc[(w, "nom")] for w in WORKLOADS]
    rows.append(("ipc/summary/nom_vs_conventional", 0,
                 f"{vs_conv:.2f}x (paper 3.8x)"))
    rows.append(("ipc/summary/nom_vs_rowclone", 0,
                 f"{vs_rc:.2f}x (paper 1.75x)"))
    rows.append(("ipc/summary/nom_light_gap", 0,
                 f"{min(gaps)*100:.0f}-{max(gaps)*100:.0f}%% (paper 5-20%%)"))
    return rows
