"""Control-plane scale sweep: vectorized vs scalar serving engine.

PR 7's SLO harness proved the admission *semantics*; this benchmark
measures whether the control plane itself can serve the ROADMAP's
millions-of-tenants regime.  For each tenant-queue scale it drives the
same four phases through an `Engine(control_plane="vector")` and the
scalar reference plane:

* **open** — N ``open_tenant`` calls against an exhausted pool (the
  duplicate check + queue push; scalar pays an O(queue) name scan per
  open, the vector plane an indexed lookup);
* **admit** — capacity-freeing ``close_tenant`` calls, each triggering
  one strategy drain over the ~N-deep queue (scalar: ``sorted`` with a
  Python key per waiter; vector: one numpy lexsort);
* **tick** — control-plane-only ``schedule_tick([])`` heartbeats
  (scalar: a Python expiry scan of the queue; vector: one boolean
  mask);
* **close** — a mass expiry past the aging horizon plus teardown of the
  remaining active tenants (terminal accounting is per-ticket Python on
  both planes, so this phase is reported but not gated).

The scalar plane is measured up to ``SCALAR_CAP`` tenants (it is
quadratic in the open phase — the point of the PR); the vector plane
continues to the 1M-tenant soak.  ``run()`` writes
``BENCH_engine_scale.json``: the per-size throughput grid with
vector/scalar speedups, the soak record for the largest vector size,
and the ``differential`` section asserting every registered strategy's
vector form returns the byte-identical admission order as its scalar
reference (healthy and stalled fabric).  ``scripts/ci.sh`` gates the
schema, the differential, and vector >= 10x scalar on open/admit/tick
at 10k+ tenants; ``run(quick=True)`` downsizes to {1k, 10k} but keeps
both planes so the dominance gate is always exercised.
"""
import json
import pathlib
import time

import numpy as np

from repro.serving.admission import (AdmissionContext, AdmissionTicket,
                                     TicketColumns, get_admission,
                                     registered_admissions)
from repro.serving.loadgen import make_slo_engine

RECORD_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_engine_scale.json"

SIZES = (1_000, 10_000, 100_000, 1_000_000)
SIZES_QUICK = (1_000, 10_000)
SCALAR_CAP = 10_000      # the scalar plane is quadratic in the open phase
STRATEGY = "deadline"
AGE = 1 << 20            # deadline_ticks horizon, beyond every phase
DRAINS = 16
TICKS = 8
DIFF_N = 512
GATE_MIN_SPEEDUP = 10.0
GATE_MIN_SIZE = 10_000


def _measure(plane: str, n: int) -> dict:
    eng = make_slo_engine(STRATEGY, tenant_queue_depth=n,
                          deadline_ticks=AGE, control_plane=plane)
    wall0 = time.perf_counter()
    t0 = time.perf_counter()
    for i in range(n):
        eng.open_tenant(f"t{i}", 1,
                        deadline=2 * AGE if i % 2 else None,
                        priority=float(1 + i % 3), klass=f"k{i % 4}")
    t_open = time.perf_counter() - t0
    waiting = len(eng.tenant_queue.items)
    t0 = time.perf_counter()
    for _ in range(DRAINS):
        eng.close_tenant(eng.tenants()[0])   # frees capacity -> one drain
    t_admit = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(TICKS):
        eng.schedule_tick([])                # ages the queue, moves nothing
    t_tick = time.perf_counter() - t0
    remaining = len(eng.tenant_queue.items)
    active = len(eng.tenants())
    t0 = time.perf_counter()
    eng._tick = AGE                          # jump to the aging horizon
    eng.schedule_tick([])                    # mass expiry of the queue
    for name in eng.tenants():
        eng.close_tenant(name)
    t_close = time.perf_counter() - t0
    tel = eng.transfer_telemetry()
    assert not eng.tenant_queue.items and not eng.tenants()
    return {
        "tenants": n,
        "waiting_peak": waiting,
        "open_per_s": n / max(t_open, 1e-9),
        # admit/tick rates are queue entries processed per second (each
        # drain orders, and each tick ages, the whole waiting queue).
        "admit_per_s": DRAINS * waiting / max(t_admit, 1e-9),
        "tick_per_s": TICKS * remaining / max(t_tick, 1e-9),
        "close_per_s": (remaining + active) / max(t_close, 1e-9),
        "drains": DRAINS,
        "expired": tel.get("tenant_queue_expired", 0),
        "open_s": round(t_open, 4), "admit_s": round(t_admit, 4),
        "tick_s": round(t_tick, 4), "close_s": round(t_close, 4),
        "wall_s": round(time.perf_counter() - wall0, 4),
    }


def _differential() -> dict:
    """Admission-order identity: every registered strategy's vector form
    vs its scalar reference over one permuted random queue, under a
    healthy and a stalled fabric snapshot."""
    rng = np.random.default_rng(42)
    waiters = [(int(rng.integers(0, 64)), AdmissionTicket(
        name=f"d{i}", batch=int(rng.integers(1, 9)),
        klass=f"k{int(rng.integers(0, 5))}",
        priority=float(rng.choice([0.25, 1.0, 2.0, 4.0])),
        deadline=(None if rng.random() < 0.3
                  else int(rng.integers(0, 256))),
        seq=i)) for i in range(DIFF_N)]
    waiters = [waiters[int(i)] for i in rng.permutation(DIFF_N)]
    cols = TicketColumns()
    cols.rebuild(waiters)
    admits = {"k0": 3, "k2": 7}
    out = {}
    for label, fab in (("", {}),
                       ("@stalled", {"stall_cycles": 999, "scheduled": 10})):
        for name in registered_admissions():
            fn = get_admission(name)
            if fn.vector is None:
                continue
            ref = list(fn(waiters, AdmissionContext(37, admits,
                                                    fabric=dict(fab))))
            vec = [int(x) for x in fn.vector(
                cols, AdmissionContext(37, admits, fabric=dict(fab)))]
            out[name + label] = ref == vec
    return out


def run(quick: bool = False):
    sizes = SIZES_QUICK if quick else SIZES
    record = {
        "schema": "nom/bench-engine-scale/v1",
        "quick": quick,
        "engine": {"mesh": [4, 4, 2], "strategy": STRATEGY,
                   "deadline_ticks": AGE},
        "sizes": {},
        "soak": {},
        "differential": _differential(),
    }
    rows = []
    for n in sizes:
        entry = {"vector": _measure("vector", n)}
        if n <= SCALAR_CAP:
            entry["scalar"] = _measure("scalar", n)
            entry["speedup"] = {
                k: round(entry["vector"][f"{k}_per_s"]
                         / max(entry["scalar"][f"{k}_per_s"], 1e-9), 2)
                for k in ("open", "admit", "tick", "close")}
        record["sizes"][str(n)] = entry
        for plane in ("vector", "scalar"):
            if plane not in entry:
                continue
            e = entry[plane]
            rows.append((f"engine_scale/{plane}/{n}",
                         e["wall_s"] * 1e6,
                         f"open={e['open_per_s']:.0f}/s"
                         f";admit={e['admit_per_s']:.0f}/s"
                         f";tick={e['tick_per_s']:.0f}/s"
                         f";close={e['close_per_s']:.0f}/s"))
    big = record["sizes"][str(sizes[-1])]["vector"]
    record["soak"] = {"tenants": sizes[-1], "completed": True,
                      "expired": big["expired"],
                      "wall_s": big["wall_s"]}
    all_match = all(record["differential"].values())
    rows.append(("engine_scale/differential", 0.0,
                 f"strategies_identical={all_match}"))
    RECORD_PATH.write_text(json.dumps(record, indent=1, sort_keys=True))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
