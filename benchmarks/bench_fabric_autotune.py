"""`policy="auto"` vs the static packing policies, across workload mixes.

Three traffic shapes (the same families as `bench_sched_policies.py`)
are each driven through a fabric *session* — N_FLUSHES batches against
one `NomFabric` — under every static policy and under `"auto"`.  The
static winner differs by mix (the skewed MoE a2a favors `"arrival"`:
longest-first defers the many short blocks, which then queue; the
serving edge-fan favors `"longest_first"`: packing the long fans first
collapses the makespan), which is exactly why a per-workload auto pick
earns its keep.  Headline columns:

* ``vs_best`` — auto's total cost / the best static total (≈1.0: the
  probe flushes are the only overhead; steady state *is* the winner);
* ``vs_worst`` — auto's total / the worst static total (must stay well
  under the 1.05 acceptance bound);
* ``steady`` — the policy auto settles on after probing.

Cost per flush is ``stall_cycles + n_windows`` (queueing delay plus
makespan, both in scheduler time units) — the same signal the fabric's
auto mode minimizes.  The final row drives a bank-level fabric's
admission queue through a burst-then-trickle pattern and reports the
auto-adapted queue-depth trajectory (grow on overflow backpressure,
shrink on sustained under-filled drains)."""
import time

import numpy as np

from repro.core.fabric import NomFabric
from repro.core.scheduler import TransferRequest
from repro.core.topology import Mesh3D

STATIC = ("arrival", "longest_first")
N_FLUSHES = 12


def _reshard_mix():
    """Uniform long shard moves, 2x4 -> 4x4 row-major (policy-neutral:
    the statics tie, auto must simply not lose)."""
    shape = (4, 4)
    coords = lambda i: tuple(int(x) for x in np.unravel_index(i % 16, shape))
    reqs = []
    for i in range(40):
        src, dst = coords(i % 8), coords(i % 16)
        if src != dst:
            reqs.append(TransferRequest(src=src, dst=dst,
                                        nbytes=(1 + i % 5) << 18,
                                        tag=f"p{i:02d}"))
    return "reshard_2x4_to_4x4", shape, True, reqs


def _moe_mix():
    """Skewed EP-ring a2a (hot experts get 3x): many short blocks —
    arrival-order wins (longest-first makes the short tail queue)."""
    rng = np.random.default_rng(7)
    ep, reqs = 8, []
    for r in range(ep):
        for q in range(ep):
            if r == q:
                continue
            tokens = int(rng.integers(1, 9)) * (3 if q < 2 else 1)
            nbytes = tokens * 128 * 4
            reqs.append(TransferRequest((r,), (q,), nbytes,
                                        tag=("dispatch", r, q)))
            reqs.append(TransferRequest((q,), (r,), nbytes,
                                        tag=("combine", q, r)))
    return f"moe_ep{ep}_a2a", (ep,), True, reqs


def _serving_mix():
    """Edge-staging fan-out on an 8x4 grid: a few long fans dominate —
    longest-first wins (packing them first collapses the makespan)."""
    reqs = [TransferRequest((0, i % 4), ((1 + (i * 3) % 7), i % 4),
                            nbytes=(i % 3 + 1) * 2048, tag=f"leaf{i}")
            for i in range(24)]
    return "serving_cache_8x4", (8, 4), False, reqs


def _session_cost(shape, torus, reqs, policy):
    """Total + per-flush costs of one N_FLUSHES session, plus the policy
    the fabric ends on."""
    fab = NomFabric(shape=shape, torus=torus, policy=policy)
    costs = []
    for _ in range(N_FLUSHES):
        _plan, rep = fab.schedule(reqs)
        costs.append(rep.stall_cycles + rep.n_windows)
    return sum(costs), costs, fab.effective_policy


def run():
    rows = []
    for name, shape, torus, reqs in (_reshard_mix(), _moe_mix(),
                                     _serving_mix()):
        totals = {}
        t0 = time.perf_counter()
        for policy in STATIC:
            totals[policy], _c, _p = _session_cost(shape, torus, reqs,
                                                   policy)
        auto_total, auto_costs, steady = _session_cost(shape, torus, reqs,
                                                       "auto")
        us = (time.perf_counter() - t0) * 1e6
        best = min(totals.values())
        worst = max(totals.values())
        # Post-probe flushes run the settled policy: the steady-state
        # per-flush cost must match-or-beat the best static's.
        n_probe = len(STATIC)
        steady_cost = float(np.mean(auto_costs[n_probe:]))
        best_per_flush = best / N_FLUSHES
        rows.append((f"fabric_autotune/{name}", us,
                     f"auto={auto_total} best={best} worst={worst} "
                     f"steady_vs_best={steady_cost / best_per_flush:.3f} "
                     f"vs_best={auto_total / best:.3f} "
                     f"vs_worst={auto_total / worst:.3f} "
                     f"steady={steady} "
                     f"static={','.join(f'{p}:{totals[p]}' for p in STATIC)}"))
    # Admission-queue depth auto-tuning on a bank-level fabric: a bursty
    # phase overflows the bounded queue (depth grows — bigger drains pack
    # better), then a trickle phase under-fills it (depth shrinks back).
    t0 = time.perf_counter()
    fab = NomFabric(mesh=Mesh3D(4, 4, 2), n_slots=16, policy="auto",
                    queue_depth=2, overflow="block")
    trajectory = [fab.effective_queue_depth]
    for burst in range(4):
        for i in range(16):
            fab.submit(TransferRequest(src=i % 16, dst=16 + (i * 3) % 16,
                                       nbytes=512))
        fab.flush()
        trajectory.append(fab.effective_queue_depth)
    peak = max(trajectory)
    for _ in range(24):
        fab.submit(TransferRequest(src=0, dst=17, nbytes=64))
        fab.flush()
        trajectory.append(fab.effective_queue_depth)
    us = (time.perf_counter() - t0) * 1e6
    tel = fab.telemetry()
    rows.append(("fabric_autotune/queue_depth_adapt", us,
                 f"depth {trajectory[0]}->{peak}->{trajectory[-1]} "
                 f"full_stalls={tel['full_stalls']} "
                 f"flushes={tel['flushes']}"))
    return rows
