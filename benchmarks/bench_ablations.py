"""Beyond-paper ablations on the NoM design space (not in the paper):

* TDM window size (8 / 16 / 32 slots): more slots = more concurrent
  circuits but each circuit gets a smaller bandwidth share.
* Multi-slot bundling (the paper mentions reserving extra free slots but
  does not quantify it): 1 / 4 / 8 slots per copy.
* CCU service throughput: 1 setup per 3 cycles (paper) vs an idealized
  1/cycle pipelined CCU.
"""
import dataclasses
import time

from repro.core.topology import Mesh3D
from repro.memsim import SimParams, WorkloadSpec, generate, simulate


def run():
    rows = []
    reqs = generate(WorkloadSpec("fileCopy60", n_requests=900, seed=3))

    # --- window size -----------------------------------------------------------
    for n_slots in (8, 16, 32):
        t0 = time.perf_counter()
        r = simulate(reqs, SimParams(config="nom", n_slots=n_slots,
                                     nom_extra_slots=7))
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"ablate/window={n_slots}slots", us,
                     f"ipc={r.ipc:.4f} (paper uses 16)"))

    # --- multi-slot bundling -----------------------------------------------------
    for extra in (0, 3, 7, 15):
        t0 = time.perf_counter()
        r = simulate(reqs, SimParams(config="nom", nom_extra_slots=extra))
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"ablate/bundle={extra + 1}slots", us,
                     f"ipc={r.ipc:.4f} (paper: 'can be accelerated by "
                     f"reserving multiple slots')"))
    return rows
