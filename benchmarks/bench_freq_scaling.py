"""Link-frequency scaling (paper Section 3, "Operating frequency"):
NoM link frequency cut 25% / 50% while the logic layer stays at 1.25 GHz —
IPC degrades sublinearly and NoM still beats RowClone."""
import time

from repro.memsim import SimParams, WorkloadSpec, generate, simulate


def run():
    rows = []
    for wl in ("fork", "fileCopy60"):
        reqs = generate(WorkloadSpec(wl, n_requests=1000, seed=1))
        base = simulate(reqs, SimParams(config="nom")).ipc
        rc = simulate(reqs, SimParams(config="rowclone")).ipc
        for ratio in (1.0, 0.75, 0.5):
            t0 = time.perf_counter()
            r = simulate(reqs, SimParams(config="nom",
                                         nom_link_ratio=ratio))
            us = (time.perf_counter() - t0) * 1e6
            rows.append((f"freq_scaling/{wl}/link={ratio:.2f}", us,
                         f"ipc={r.ipc:.4f} degr={100*(1-r.ipc/base):.1f}%% "
                         f"beats_rowclone={r.ipc > rc}"))
    return rows
