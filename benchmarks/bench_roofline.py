"""Roofline table emitter: reads the dry-run JSON artifacts and prints the
per-cell three-term roofline (EXPERIMENTS.md section source)."""
import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load_cells(mesh="16x16"):
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            c = json.load(f)
        if c.get("mesh") == mesh:
            cells.append(c)
    return cells


def dominant(terms):
    return max(terms, key=lambda k: terms[k])


def run():
    rows = []
    for label, rdir in (("baseline", RESULTS),
                        ("optimized", RESULTS + "_opt")):
        cells = []
        for path in sorted(glob.glob(os.path.join(rdir, "*.json"))):
            with open(path) as f:
                c = json.load(f)
            if c.get("ok") and c.get("mesh") == "16x16":
                cells.append(c)
        if not cells:
            rows.append((f"roofline/{label}/no_artifacts", 0,
                         "run: python -m repro.launch.dryrun --all "
                         "--both-meshes"))
            continue
        for c in cells:
            t = {k: v for k, v in c["terms"].items()
                 if k in ("compute_s", "memory_s", "collective_s")}
            dom = dominant(t)
            step_s = max(t.values())
            rows.append((f"roofline/{label}/{c['arch']}/{c['shape']}", 0,
                         f"compute={t['compute_s']*1e3:.1f}ms "
                         f"memory={t['memory_s']*1e3:.1f}ms "
                         f"collective={t['collective_s']*1e3:.1f}ms "
                         f"dom={dom.split('_')[0]} "
                         f"roofline_frac={t['compute_s']/step_s:.3f} "
                         f"useful_flops_frac="
                         f"{c['model_flops']/256/max(c['flops'],1):.2f}"
                         if step_s else f"tiny cell"))
    return rows
