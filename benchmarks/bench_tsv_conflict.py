"""TSV dual-use conflict probability (Section 2.3) — the observation
motivating NoM-Light: dedicated-Z beats rarely coincide with regular TSV
activity (paper: 0.45% low load, 7.1% high load)."""
import time

from repro.memsim import SimParams, WorkloadSpec, generate, simulate


def run():
    rows = []
    for label, wl, n in (("low_load", "fileCopy20", 800),
                         ("high_load", "fileCopy60", 800)):
        reqs = generate(WorkloadSpec(wl, n_requests=n, seed=2))
        t0 = time.perf_counter()
        r = simulate(reqs, SimParams(config="nom", window=64))
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"tsv_conflict/{label}", us,
                     f"p_conflict={100*r.tsv_conflict_frac:.2f}%% "
                     f"(paper: 0.45%% low / 7.1%% high) "
                     f"inflight_avg={r.extra['nom_inflight_avg']:.2f} "
                     f"inflight_max={r.extra['nom_inflight_max']} "
                     f"ccu_batch_avg={r.extra['nom_batch_avg']:.2f}"))
    return rows
