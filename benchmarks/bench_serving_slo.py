"""Trace-driven SLO serving sweep: arrival mixes x admission strategies.

The serving-at-scale measurement the ROADMAP asks for: every built-in
arrival mix (`repro.serving.loadgen.MIXES` — poisson with a diurnal
ramp, bursty, heavy-tailed, and the overloaded ``deadline_heavy``) is
driven through the standard stub engine (`make_slo_engine`) once per
admission strategy, open loop, for a fixed tick budget.  Each run's
stats record (p50/p99 admission latency in ticks, shed/expiry rates,
deadline-miss rate, circuits-per-window on the fabric underneath) comes
straight from `repro.serving.loadgen.drive`.

Besides the CSV rows, ``run()`` writes ``BENCH_serving.json`` at the
repo root: the full record grid plus the headline ``dominance`` entry —
on the ``deadline_heavy`` mix the ``deadline`` strategy must strictly
reduce the deadline-miss rate vs ``fifo`` (queue *order* is the whole
point of the strategy registry).  ``scripts/ci.sh`` asserts the file's
schema and that dominance gate on every PR; ``run(quick=True)`` (the
``--quick`` harness path) shrinks the tick budget but keeps the full
mix x strategy grid so the gate is always exercised.
"""
import json
import pathlib
import time

from repro.serving.admission import registered_admissions
from repro.serving.loadgen import MIXES, drive, make_slo_engine

RECORD_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_serving.json"

SEED = 7
TICKS = 160
TICKS_QUICK = 48
STRATEGIES = ("fifo", "deadline", "priority", "hybrid", "stall_aware")
DOMINANCE_MIX = "deadline_heavy"


def run(quick: bool = False):
    ticks = TICKS_QUICK if quick else TICKS
    assert all(s in registered_admissions() for s in STRATEGIES)
    rows = []
    record = {
        "schema": "serving-slo-v1",
        "seed": SEED,
        "ticks": ticks,
        "engine": {"mesh": [4, 4, 2], "deadline_ticks": 12,
                   "tenant_queue_depth": 16},
        "records": [],
        "dominance": {},
    }
    miss = {}
    for mix in MIXES:
        for strategy in STRATEGIES:
            eng = make_slo_engine(strategy)
            t0 = time.perf_counter()
            stats = drive(eng, mix, ticks=ticks, seed=SEED)
            us = (time.perf_counter() - t0) * 1e6
            record["records"].append(stats)
            miss[(mix, strategy)] = stats["miss_rate"]
            rows.append((f"serving_slo/{mix}/{strategy}", us,
                         f"miss={stats['miss_rate']:.3f}"
                         f";shed={stats['shed_rate']:.3f}"
                         f";expiry={stats['expiry_rate']:.3f}"
                         f";p50={stats['p50_wait']:.1f}"
                         f";p99={stats['p99_wait']:.1f}"
                         f";cpw={stats['circuits_per_window']:.2f}"))
    record["dominance"] = {
        "mix": DOMINANCE_MIX,
        "fifo_miss_rate": miss[(DOMINANCE_MIX, "fifo")],
        "deadline_miss_rate": miss[(DOMINANCE_MIX, "deadline")],
        "deadline_beats_fifo": (miss[(DOMINANCE_MIX, "deadline")]
                                < miss[(DOMINANCE_MIX, "fifo")]),
    }
    RECORD_PATH.write_text(json.dumps(record, indent=1, sort_keys=True))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
