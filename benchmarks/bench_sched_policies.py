"""Packing-quality comparison of the two registered fabric policies —
"longest_first" (sort by descending route distance, best packing) vs
"arrival" (the CCU's FIFO commit rule) — across the three traffic shapes
that ride `NomFabric` sessions: checkpoint reshard, MoE expert dispatch,
and serving cache movement.  Plus the CCU request-queue saturation sweep:
IPC / backpressure stalls as `nom_ccu_queue_depth` shrinks (the bounded
router buffering made observable).  The `policy="auto"` comparison
against these statics lives in `bench_fabric_autotune.py`."""
import time

import numpy as np

from repro.checkpoint.reshard import reshard_plan_with_report
from repro.core.fabric import NomFabric
from repro.core.scheduler import TransferRequest
from repro.memsim import SimParams, WorkloadSpec, generate, simulate

POLICIES = ("longest_first", "arrival")


def _reshard_topology():
    """Shard migration: a 40-param model moving from a 2x4 to a 4x4 mesh."""
    meta = {f"p{i:02d}": (1 + i % 5) << 18 for i in range(40)}
    return [("reshard_2x4_to_4x4",
             lambda policy: reshard_plan_with_report(
                 meta, (2, 4), (4, 4), policy=policy))]


def _moe_topology():
    """Expert dispatch on an EP ring: skewed token->expert blocks (hot
    experts get 3x traffic), both directions, like MoE.plan_dispatch."""
    rng = np.random.default_rng(7)
    ep = 8
    reqs = []
    for r in range(ep):
        for q in range(ep):
            if r == q:
                continue
            tokens = int(rng.integers(1, 9)) * (3 if q < 2 else 1)
            nbytes = tokens * 128 * 4
            reqs.append(TransferRequest((r,), (q,), nbytes,
                                        tag=("dispatch", r, q)))
            reqs.append(TransferRequest((q,), (r,), nbytes,
                                        tag=("combine", q, r)))
    return [(f"moe_ep{ep}_a2a",
             lambda policy: NomFabric(shape=(ep,), torus=True)
             .schedule(reqs, policy=policy))]


def _serving_topology():
    """Cache flush from the logic-die edge to spread cache homes on a 2D
    device grid — the engine's per-step transfer set, device level."""
    reqs = [TransferRequest((0, i % 4), ((1 + (i * 3) % 7), i % 4),
                            nbytes=(i % 3 + 1) * 2048, tag=f"leaf{i}")
            for i in range(24)]
    return [("serving_cache_8x4",
             lambda policy: NomFabric(shape=(8, 4), torus=False)
             .schedule(reqs, policy=policy))]


def run():
    rows = []
    for name, mk in (_reshard_topology() + _moe_topology()
                     + _serving_topology()):
        for policy in POLICIES:
            t0 = time.perf_counter()
            plan, rep = mk(policy)
            us = (time.perf_counter() - t0) * 1e6
            rows.append((f"sched_policies/{name}/{policy}", us,
                         f"rounds={plan.n_rounds} "
                         f"util={plan.link_utilization():.2f} "
                         f"inflight_avg={rep.avg_inflight:.1f} "
                         f"max={rep.max_inflight} "
                         f"stall={rep.stall_cycles}"))
    # CCU queue saturation: shrinking the bounded request queue serializes
    # circuit setup (smaller batches) and backpressures the core.
    reqs = generate(WorkloadSpec("fileCopy60", n_requests=500, seed=4))
    for depth in (1, 2, 8, 16):
        t0 = time.perf_counter()
        r = simulate(reqs, SimParams(config="nom", nom_ccu_queue_depth=depth,
                                     compute_gap=1, window=64))
        us = (time.perf_counter() - t0) * 1e6
        e = r.extra
        rows.append((f"sched_policies/ccu_queue_depth={depth}", us,
                     f"ipc={r.ipc:.3f} "
                     f"batch_avg={e['nom_batch_avg']:.2f} "
                     f"peak_queue={e['nom_ccu_peak_queue']} "
                     f"full_stalls={e['nom_ccu_full_stalls']} "
                     f"stall_cycles={e['nom_ccu_stall_cycles']}"))
    return rows
