"""Benchmark harness — one module per paper table/figure + the TPU
adaptation and roofline reports.  Prints ``name,us_per_call,derived`` CSV."""
import inspect
import sys
import time
import traceback

from benchmarks import (bench_ablations, bench_energy, bench_engine_scale,
                        bench_fabric_autotune, bench_freq_scaling, bench_ipc,
                        bench_multistack, bench_nom_a2a, bench_reduce,
                        bench_roofline, bench_sched_policies,
                        bench_serving_slo, bench_serving_tenancy,
                        bench_slot_alloc, bench_traffic_mix,
                        bench_tsv_conflict)

ALL = [
    ("traffic_mix(Fig3)", bench_traffic_mix),
    ("ipc(Fig4)", bench_ipc),
    ("freq_scaling", bench_freq_scaling),
    ("tsv_conflict", bench_tsv_conflict),
    ("energy", bench_energy),
    ("slot_alloc", bench_slot_alloc),
    ("nom_a2a", bench_nom_a2a),
    ("sched_policies", bench_sched_policies),
    ("fabric_autotune", bench_fabric_autotune),
    ("serving_tenancy", bench_serving_tenancy),
    ("serving_slo", bench_serving_slo),
    ("engine_scale", bench_engine_scale),
    ("multistack", bench_multistack),
    ("reduce", bench_reduce),
    ("ablations", bench_ablations),
    ("roofline", bench_roofline),
]

# --quick: the CI smoke subset — the scheduler-centric benches that gate
# the concurrent-transfer perf trajectory, fast enough for every PR.
# A bench whose run() accepts a ``quick`` kwarg is told which mode it is
# in (serving_slo shrinks its tick budget but keeps its record grid).
QUICK = ("tsv_conflict", "slot_alloc", "nom_a2a", "sched_policies",
         "fabric_autotune", "serving_tenancy", "serving_slo", "engine_scale",
         "multistack", "reduce")


def main() -> None:
    args = [a for a in sys.argv[1:]]
    quick = "--quick" in args
    args = [a for a in args if a != "--quick"]
    only = args[0] if args else None
    print("name,us_per_call,derived")
    t_start = time.time()
    for label, mod in ALL:
        if only and only not in label:
            continue
        if quick and not any(q in label for q in QUICK):
            continue
        try:
            kw = ({"quick": quick} if "quick"
                  in inspect.signature(mod.run).parameters else {})
            for name, us, derived in mod.run(**kw):
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # keep the harness going
            traceback.print_exc()
            print(f"{label},0,ERROR {type(e).__name__}: {e}")
        sys.stdout.flush()
    print(f"# total {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
