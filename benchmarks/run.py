"""Benchmark harness — one module per paper table/figure + the TPU
adaptation and roofline reports.  Prints ``name,us_per_call,derived`` CSV."""
import sys
import time
import traceback

from benchmarks import (bench_ablations, bench_energy, bench_freq_scaling,
                        bench_ipc, bench_nom_a2a, bench_roofline,
                        bench_slot_alloc, bench_traffic_mix,
                        bench_tsv_conflict)

ALL = [
    ("traffic_mix(Fig3)", bench_traffic_mix),
    ("ipc(Fig4)", bench_ipc),
    ("freq_scaling", bench_freq_scaling),
    ("tsv_conflict", bench_tsv_conflict),
    ("energy", bench_energy),
    ("slot_alloc", bench_slot_alloc),
    ("nom_a2a", bench_nom_a2a),
    ("ablations", bench_ablations),
    ("roofline", bench_roofline),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    t_start = time.time()
    for label, mod in ALL:
        if only and only not in label:
            continue
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # keep the harness going
            traceback.print_exc()
            print(f"{label},0,ERROR {type(e).__name__}: {e}")
        sys.stdout.flush()
    print(f"# total {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
