"""Fig. 3 — memory-traffic breakdown per workload."""
import time

from repro.memsim import WorkloadSpec, generate, traffic_breakdown


def run():
    rows = []
    for wl in ("fork", "fileCopy20", "fileCopy40", "fileCopy60"):
        t0 = time.perf_counter()
        reqs = generate(WorkloadSpec(wl, n_requests=1500, seed=0))
        mix = traffic_breakdown(reqs)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"traffic_mix/{wl}", us,
                     "inter=%.2f intra=%.2f init=%.2f regular=%.2f" % (
                         mix["inter_bank_copy"], mix["intra_bank_copy"],
                         mix["init"], mix["regular"])))
    return rows
