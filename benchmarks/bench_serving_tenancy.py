"""Multi-tenant serving placement sweep: tenants × placement policy on the
paper's 8x8x4 bank mesh, driven through the *real* serving engine (a
model-free cache stub feeds `Engine.open_tenant` / `schedule_tick` /
`close_tenant`, so the benchmark measures exactly the scheduling semantics
the engine ships — per-tenant stall attribution, stall-feedback repacks,
ring-overwrite evictions, teardown scrubs).  The headline column is
`inflight_avg` (circuits in flight per TDM window): it must *grow* with
tenant count — tenants stream concurrently rather than serializing — while
`stall` exposes the contention cost of each policy and `init` the
eviction/INIT share of the traffic."""
import time

import jax.numpy as jnp

from repro.core import Mesh3D
from repro.serving import Engine
from repro.serving.placement import PLACEMENT_POLICIES

N_STEPS = 12
RING = 8            # token slots per ring leaf: steps 8..11 wrap -> INITs


class _CacheStub:
    """Model stub exposing only ``init_caches``: 6 leaves per stream — a
    KV-ring / in-place-state mix, sizes chosen so per-step movement spans
    a few TDM windows (the engine probes the length slope itself)."""

    def init_caches(self, batch, max_len):
        caches = {}
        for i in range(6):
            width = 24 * (1 + i % 3)
            if i % 2 == 0:      # ring leaf: size scales with max_len
                caches[f"kv{i}"] = jnp.zeros((batch, max_len, width),
                                             jnp.int8)
            else:               # state leaf: refreshed in place
                caches[f"state{i}"] = jnp.zeros((batch, 4 * width),
                                                jnp.int8)
        return caches


def _run_one(n_tenants: int, policy: str):
    eng = Engine(model=_CacheStub(), cfg=None, max_len=64,
                 cache_mesh=Mesh3D(8, 8, 4), ring_slots=RING,
                 placement_policy=policy, max_extra_slots=0)
    for k in range(n_tenants):
        eng.open_tenant(f"t{k}", batch=1)
    for _ in range(N_STEPS):
        eng.schedule_tick()
    for k in range(n_tenants):
        eng.close_tenant(f"t{k}")
    return eng.last_report, eng.transfer_telemetry()


def run():
    rows = []
    for policy in PLACEMENT_POLICIES:
        for n in (1, 2, 4, 8):
            t0 = time.perf_counter()
            rep, tel = _run_one(n, policy)
            us = (time.perf_counter() - t0) * 1e6
            rows.append((f"serving_tenancy/{policy}/tenants={n}", us,
                         f"inflight_avg={rep.avg_inflight:.2f} "
                         f"max={rep.max_inflight} "
                         f"stall={rep.stall_cycles} "
                         f"init={rep.n_init}/{rep.n_requests} "
                         f"sched={rep.n_scheduled} "
                         f"repacks={tel['repacks']}"))
    return rows
