"""TPU adaptation benchmark: NOM-scheduled all-to-all vs the XLA opaque
all_to_all — per-link traffic from the analytic schedule plus wall-clock of
both implementations on the host mesh (1 device here; the dry-run exercises
256/512)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.nom_collectives import a2a_link_chunks, plan_transfers, \
    Transfer


def run():
    rows = []
    for n in (8, 16, 32):
        c = a2a_link_chunks(n)
        t0 = time.perf_counter()
        # plan a full all-to-all as explicit point-to-point transfers on a
        # ring (1D torus) — the schedule the MoE dispatch realizes
        transfers = [Transfer((i,), (j,)) for i in range(n)
                     for j in range(n) if i != j]
        plan = plan_transfers((n,), transfers, torus=True)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"nom_a2a/ring_n={n}", us,
                     f"rounds={plan.n_rounds} "
                     f"link_chunks nom={c['nom_right']:.0f}/dir "
                     f"bus={c['bus_serialized']:.0f} "
                     f"util={plan.link_utilization():.2f}"))
    return rows
