"""TPU adaptation benchmark: NOM-scheduled all-to-all vs the XLA opaque
all_to_all — per-link traffic from the analytic schedule plus wall-clock of
both implementations on the host mesh (1 device here; the dry-run exercises
256/512)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fabric import NomFabric
from repro.core.nom_collectives import a2a_link_chunks, plan_transfers, \
    Transfer
from repro.core.topology import Mesh3D

from benchmarks.bench_slot_alloc import _stream


def run():
    rows = []
    for n in (8, 16, 32):
        c = a2a_link_chunks(n)
        t0 = time.perf_counter()
        # plan a full all-to-all as explicit point-to-point transfers on a
        # ring (1D torus) — the schedule the MoE dispatch realizes
        transfers = [Transfer((i,), (j,)) for i in range(n)
                     for j in range(n) if i != j]
        plan = plan_transfers((n,), transfers, torus=True)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"nom_a2a/ring_n={n}", us,
                     f"rounds={plan.n_rounds} "
                     f"link_chunks nom={c['nom_right']:.0f}/dir "
                     f"bus={c['bus_serialized']:.0f} "
                     f"util={plan.link_utilization():.2f}"))
    # arrival-order (CCU FIFO) policy through a device-level fabric session
    n = 16
    transfers = [Transfer((i,), (j,)) for i in range(n)
                 for j in range(n) if i != j]
    t0 = time.perf_counter()
    plan, rep = NomFabric(shape=(n,), torus=True,
                          policy="arrival").schedule(transfers)
    us = (time.perf_counter() - t0) * 1e6
    rows.append((f"nom_a2a/ring_arrival_n={n}", us,
                 f"rounds={plan.n_rounds} "
                 f"inflight_avg={rep.avg_inflight:.1f} "
                 f"max={rep.max_inflight}"))
    # bank-level batched scenario: a random bulk transfer set on the
    # paper's 8x8x4 mesh through a bank-level fabric (TDM circuits)
    mesh = Mesh3D(8, 8, 4)
    reqs = _stream(np.random.default_rng(0), mesh, 64, nbytes=1024)
    NomFabric(mesh=mesh, n_slots=16).schedule(reqs[:2], cycle=0)  # warm jit
    fab = NomFabric(mesh=mesh, n_slots=16)
    t0 = time.perf_counter()
    _results, rep = fab.schedule(reqs, cycle=0)
    us = (time.perf_counter() - t0) * 1e6
    rows.append((f"nom_a2a/tdm_batch_b={len(reqs)}", us,
                 f"committed={rep.n_scheduled}/{rep.n_requests} "
                 f"inflight_avg={rep.avg_inflight:.1f} "
                 f"max={rep.max_inflight} "
                 f"search_rounds={rep.search_rounds}"))
    return rows
