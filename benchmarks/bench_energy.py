"""Energy analysis (Section 3): NoM reduces energy/access up to 3.2x vs the
DDR3 baseline (no off-chip bounce for copies) and costs ~9% more than
RowClone (extra links + router logic)."""
import time

from repro.memsim import (EnergyParams, SimParams, WorkloadSpec, energy_pj,
                          generate, simulate)


def run():
    rows = []
    for wl in ("fork", "fileCopy60"):
        reqs = generate(WorkloadSpec(wl, n_requests=1000, seed=1))
        t0 = time.perf_counter()
        e = {}
        for cfg in ("conventional", "rowclone", "nom"):
            r = simulate(reqs, SimParams(config=cfg), name=wl)
            e[cfg] = energy_pj(r)["per_access"]
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"energy/{wl}", us,
                     "conv/nom=%.2fx (paper <=3.2x) nom/rowclone=%.3fx "
                     "(paper ~1.09x)" % (e["conventional"] / e["nom"],
                                         e["nom"] / e["rowclone"])))
    return rows
