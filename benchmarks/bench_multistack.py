"""Multi-stack fabric: the intra- vs cross-stack gap, and tenant migration.

Two measurements on a 2-cube `StackedTopology` (4x4x2 meshes, ring SerDes
links):

* **circuits/window** — the same random copy stream scheduled once with
  both endpoints in one stack (pure TDM mesh traffic) and once spanning
  the stacks (two-phase SerDes circuits).  The cross column must come in
  *lower*: every cross circuit serializes on the two bridge nodes and the
  shared SerDes channels, and streams at the bottleneck link width — the
  quantified reason placement keeps per-step traffic stack-local
  (`docs/multistack.md`).
* **tenant migration** — a stacked serving `Engine` opens N tenants
  pinned to stack 0, then `migrate_tenant`s every one to stack 1: the
  cross-stack COPY + teardown-INIT batch per tenant, swept over N.

Besides the CSV rows, ``run()`` writes ``BENCH_multistack.json`` at the
repo root (schema, topology, both circuits/window records, the migration
sweep); ``scripts/ci.sh`` asserts the file is produced and well-formed.
"""
import json
import pathlib
import time

import jax.numpy as jnp
import numpy as np

from repro.core.fabric import FabricCluster
from repro.core.scheduler import TransferRequest
from repro.core.topology import make_topology
from repro.serving.engine import Engine

RECORD_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_multistack.json"

MESH = (4, 4, 2)
N_STACKS = 2
LINK_LATENCY = 8
LINK_BYTES = 4
N_REQS = 48
NBYTES = 256


def _topology():
    return make_topology(N_STACKS, mesh=MESH, link="ring",
                         link_latency=LINK_LATENCY, link_bytes=LINK_BYTES)


def _pairs(rng, n_nodes, n):
    out = []
    for _ in range(n):
        s, d = rng.integers(n_nodes, size=2)
        while s == d:
            d = rng.integers(n_nodes)
        out.append((int(s), int(d)))
    return out


def _schedule(topo, pairs, cross: bool):
    """One batch through a fresh cluster; endpoints are (stack, node)
    tuples — same stack 0 for intra, stack 0 -> 1 for cross."""
    cluster = FabricCluster(topology=topo)
    reqs = [TransferRequest(src=s, dst=d, nbytes=NBYTES,
                            src_stack=0, dst_stack=1 if cross else 0)
            for s, d in pairs]
    t0 = time.perf_counter()
    results, report = cluster.schedule(reqs)
    us = (time.perf_counter() - t0) * 1e6
    per_window = (report.n_scheduled / report.n_windows
                  if report.n_windows else 0.0)
    return us, {
        "n_scheduled": report.n_scheduled,
        "n_requests": report.n_requests,
        "n_windows": report.n_windows,
        "n_cross_stack": report.n_cross_stack,
        "circuits_per_window": round(per_window, 4),
        "avg_inflight": round(report.avg_inflight, 4),
        "stall_cycles": report.stall_cycles,
    }


class _CacheStub:
    """Two ring leaves + one state leaf per stream (see
    bench_serving_tenancy for the probing contract)."""

    def init_caches(self, batch, max_len):
        return {"kv0": jnp.zeros((batch, max_len, 16), jnp.int8),
                "kv1": jnp.zeros((batch, max_len, 32), jnp.int8),
                "state": jnp.zeros((batch, 64), jnp.int8)}


def _migrate_sweep(topo, n_tenants: int):
    eng = Engine(model=_CacheStub(), cfg=None, max_len=32,
                 cache_mesh=topo, ring_slots=8, max_extra_slots=0)
    for k in range(n_tenants):
        eng.open_tenant(f"t{k}", batch=1)
        # Pin the tenant's homes to stack 0 so every sweep migration
        # genuinely crosses the SerDes links.
        eng.migrate_tenant(f"t{k}", 0)
    setup_migrations = eng.n_migrations
    eng.schedule_tick()
    t0 = time.perf_counter()
    reports = [eng.migrate_tenant(f"t{k}", 1) for k in range(n_tenants)]
    us = (time.perf_counter() - t0) * 1e6
    cross = sum(r.n_cross_stack for r in reports if r is not None)
    init = sum(r.n_init for r in reports if r is not None)
    tel = eng.transfer_telemetry()
    for k in range(n_tenants):
        eng.close_tenant(f"t{k}")
    return us, {
        "tenants": n_tenants,
        "migrations": tel["migrations"] - setup_migrations,
        "cross_stack_circuits": cross,
        "teardown_inits": init,
        "stall_cycles": tel["stall_cycles"],
    }


def run():
    rows = []
    topo = _topology()
    rng = np.random.default_rng(7)
    record = {
        "schema": "multistack-v1",
        "topology": {"n_stacks": N_STACKS, "mesh": list(MESH),
                     "link": "ring", "link_latency": LINK_LATENCY,
                     "link_bytes": LINK_BYTES},
        "circuits_per_window": {},
        "migration": {},
    }
    n_local = topo.stacks[0].n_nodes
    pairs = _pairs(rng, n_local, N_REQS)
    for label, cross in (("intra", False), ("cross", True)):
        us, stats = _schedule(topo, pairs, cross)
        record["circuits_per_window"][label] = stats
        rows.append((f"multistack_{label}_{N_REQS}req", us,
                     f"cpw={stats['circuits_per_window']}"
                     f";inflight={stats['avg_inflight']}"
                     f";sched={stats['n_scheduled']}"))
    intra = record["circuits_per_window"]["intra"]["circuits_per_window"]
    cross = record["circuits_per_window"]["cross"]["circuits_per_window"]
    record["circuits_per_window"]["cross_over_intra"] = round(
        cross / intra, 4) if intra else 0.0
    for n in (1, 2, 4):
        us, stats = _migrate_sweep(topo, n)
        record["migration"][str(n)] = stats
        rows.append((f"multistack_migrate_{n}t", us,
                     f"cross={stats['cross_stack_circuits']}"
                     f";init={stats['teardown_inits']}"))
    RECORD_PATH.write_text(json.dumps(record, indent=1, sort_keys=True))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
