"""Compute-class reduce vs copy-then-compute on the paper mesh.

The third transfer class moves the merge *into* the fabric: a fan-in
circuit streams every operand to the destination bank's ALU in one
circuit lifetime, so a k-way reduce costs about one transfer's worth of
TDM windows.  The conventional path pays twice — all operands are first
copied to a gather bank, the processor (or gather-bank ALU) sums them,
and the result is copied out to its consumer — two dependent batches
through the same fabric, ~2x the windows at any fan-in.

Sweeps fan-in (2, 4, 8) x 4 KB pages over the paper's 8x8x4 mesh with
the same slot policy on both sides (``max_extra_slots=0``: the fan-in
streams one slot per source, so the copies get one slot too).  Also
records one memsim ``gradAgg40`` run on the ``nom`` config so the
destination-ALU element count and its pJ share land in the record.

Writes ``BENCH_reduce.json`` (schema ``nom/bench-reduce/v1``);
``scripts/ci.sh`` gates the schema and the dominance claim
(``reduce_windows < baseline_windows`` at fan-in >= 4).
"""
import json
import pathlib
import time

import numpy as np

from repro.core.fabric import NomFabric
from repro.core.scheduler import TransferRequest, reduce_request
from repro.core.topology import make_topology
from repro.memsim.energy import energy_pj
from repro.memsim.simulator import SimParams, simulate
from repro.memsim.workloads import WorkloadSpec, generate

RECORD_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_reduce.json"

NBYTES = 4096          # one page per operand, as in the memsim workloads
FANINS = (2, 4, 8)
TRIALS = 4


def _endpoints(rng, n_nodes: int, k: int):
    """k distinct sources + destination + downstream consumer bank."""
    banks = rng.choice(n_nodes, size=k + 2, replace=False)
    return [int(b) for b in banks[:k]], int(banks[k]), int(banks[k + 1])


def _fabric() -> NomFabric:
    return NomFabric(mesh=make_topology(1))


def _reduce_windows(srcs, dst) -> int | None:
    """In-fabric fan-in: one circuit lifetime, merge at the dst ALU.
    Returns None when the fan-in is unroutable at cycle 0 (wide fan-ins
    on boundary destinations can exhaust the slot window — the caller
    redraws endpoints and counts the denial)."""
    fabric = _fabric()
    _res, rep = fabric.schedule([reduce_request(srcs, dst, nbytes=NBYTES)])
    assert rep.n_reduce == 1
    return rep.n_windows if rep.n_scheduled == 1 else None


def _baseline_windows(srcs, dst, consumer) -> int:
    """Copy-then-compute: gather every operand at ``dst``, sum there,
    copy the result out to ``consumer``.  The copy-out depends on the
    gather, so the two batch spans add."""
    fabric = _fabric()
    _res, rep1 = fabric.schedule(
        [TransferRequest(src=s, dst=dst, nbytes=NBYTES) for s in srcs])
    assert rep1.n_scheduled == len(srcs)
    _res, rep2 = fabric.schedule(
        [TransferRequest(src=dst, dst=consumer, nbytes=NBYTES)])
    assert rep2.n_scheduled == 1
    return rep1.n_windows + rep2.n_windows


def _memsim_record() -> dict:
    reqs = generate(WorkloadSpec("gradAgg40", n_requests=400))
    res = simulate(reqs, SimParams(config="nom"), name="gradAgg40")
    energy = energy_pj(res)
    return {
        "workload": "gradAgg40",
        "n_requests": 400,
        "nom_reduce_elems": res.extra.get("nom_reduce_elems", 0),
        "nom_reduce_stalls": res.extra.get("nom_reduce_stalls", 0),
        "reduce_alu_pj": round(energy["reduce_alu"], 2),
        "total_pj": round(energy["total"], 2),
    }


def run():
    rows = []
    rng = np.random.default_rng(11)
    mesh = make_topology(1)
    record = {
        "schema": "nom/bench-reduce/v1",
        "mesh": [mesh.X, mesh.Y, mesh.Z],
        "nbytes": NBYTES,
        "trials": TRIALS,
        "fanin": {},
        "memsim": {},
    }
    for k in FANINS:
        red = base = denied = 0
        t0 = time.perf_counter()
        for _ in range(TRIALS):
            for _attempt in range(16):
                srcs, dst, consumer = _endpoints(rng, mesh.n_nodes, k)
                w = _reduce_windows(srcs, dst)
                if w is not None:
                    break
                denied += 1
            else:
                raise RuntimeError(f"fan-in {k} unroutable 16x in a row")
            red += w
            base += _baseline_windows(srcs, dst, consumer)
        us = (time.perf_counter() - t0) * 1e6 / TRIALS
        speedup = base / red if red else 0.0
        record["fanin"][str(k)] = {
            "fanin": k,
            "reduce_windows": red,
            "baseline_windows": base,
            "denied_draws": denied,
            "speedup": round(speedup, 4),
        }
        rows.append((f"reduce_fanin{k}", us,
                     f"red_w={red};base_w={base};x={speedup:.2f}"))
    t0 = time.perf_counter()
    record["memsim"] = _memsim_record()
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("reduce_memsim_gradAgg40", us,
                 f"elems={record['memsim']['nom_reduce_elems']}"
                 f";alu_pj={record['memsim']['reduce_alu_pj']}"))
    RECORD_PATH.write_text(json.dumps(record, indent=1, sort_keys=True))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
