"""Slot-allocation accelerator throughput: the paper's PE matrix finds a
path in one 500ps cycle; here we measure the JAX implementation's batched
search throughput and the Pallas kernel (interpret mode) equivalence."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.slot_alloc import TdmAllocator, wavefront_search_batch
from repro.core.topology import Mesh3D


def run():
    rows = []
    mesh = Mesh3D(8, 8, 4)
    alloc = TdmAllocator(mesh, 16)
    rng = np.random.default_rng(0)
    for i in range(32):
        s, d = rng.integers(mesh.n_nodes, size=2)
        if s != d:
            alloc.allocate(int(s), int(d), 512, cycle=i)
    occ = jnp.asarray(alloc.table.busy_masks(0))
    for batch in (1, 16, 64):
        srcs = jnp.asarray(rng.integers(mesh.n_nodes, size=batch), jnp.int32)
        dsts = jnp.asarray((np.asarray(srcs) + 1 + rng.integers(
            mesh.n_nodes - 1, size=batch)) % mesh.n_nodes, jnp.int32)
        inits = jnp.zeros(batch, jnp.uint32)
        fn = jax.jit(lambda o, s, d, iv: wavefront_search_batch(
            o, s, d, iv, mesh=mesh, n_slots=16))
        fn(occ, srcs, dsts, inits).block_until_ready()   # warm
        t0 = time.perf_counter()
        reps = 20
        for _ in range(reps):
            out = fn(occ, srcs, dsts, inits)
        out.block_until_ready()
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append((f"slot_alloc/search_batch={batch}", us,
                     f"{us/batch:.1f}us/request (hw target: 1 cycle)"))
    # end-to-end allocation rate (search + traceback + reserve)
    alloc2 = TdmAllocator(mesh, 16)
    t0 = time.perf_counter()
    n = 100
    done = 0
    for i in range(n):
        s, d = rng.integers(mesh.n_nodes, size=2)
        if s != d and alloc2.allocate(int(s), int(d), 512,
                                      cycle=i * 8).circuit:
            done += 1
    us = (time.perf_counter() - t0) / n * 1e6
    rows.append(("slot_alloc/allocate_e2e", us, f"alloc_rate={done}/{n}"))
    return rows
