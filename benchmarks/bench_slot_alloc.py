"""Slot-allocation accelerator throughput: the paper's PE matrix finds a
path in one 500ps cycle; here we measure the JAX implementation's batched
search throughput, plus the end-to-end allocation rate of the concurrent
batched scheduler (``allocate_batch``) against the serial one-request-at-
a-time CCU loop — the paper's "many circuits per setup" claim as a
benchmark."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.slot_alloc import (CopyRequest, TdmAllocator,
                                   wavefront_search_batch)
from repro.core.topology import Mesh3D


def _stream(rng, mesh, n, nbytes=512):
    reqs = []
    for _ in range(n):
        s, d = rng.integers(mesh.n_nodes, size=2)
        while s == d:
            d = rng.integers(mesh.n_nodes)
        reqs.append(CopyRequest(int(s), int(d), nbytes))
    return reqs


def run():
    rows = []
    mesh = Mesh3D(8, 8, 4)
    alloc = TdmAllocator(mesh, 16)
    rng = np.random.default_rng(0)
    for i in range(32):
        s, d = rng.integers(mesh.n_nodes, size=2)
        if s != d:
            alloc.allocate(int(s), int(d), 512, cycle=i)
    occ = jnp.asarray(alloc.table.busy_masks(0))
    for batch in (1, 16, 64):
        srcs = jnp.asarray(rng.integers(mesh.n_nodes, size=batch), jnp.int32)
        dsts = jnp.asarray((np.asarray(srcs) + 1 + rng.integers(
            mesh.n_nodes - 1, size=batch)) % mesh.n_nodes, jnp.int32)
        inits = jnp.zeros(batch, jnp.uint32)
        fn = jax.jit(lambda o, s, d, iv: wavefront_search_batch(
            o, s, d, iv, mesh=mesh, n_slots=16))
        fn(occ, srcs, dsts, inits).block_until_ready()   # warm
        t0 = time.perf_counter()
        reps = 20
        for _ in range(reps):
            out = fn(occ, srcs, dsts, inits)
        out.block_until_ready()
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append((f"slot_alloc/search_batch={batch}", us,
                     f"{us/batch:.1f}us/request (hw target: 1 cycle)"))
    # end-to-end allocation rate (search + traceback + reserve)
    alloc2 = TdmAllocator(mesh, 16)
    t0 = time.perf_counter()
    n = 100
    done = 0
    for i in range(n):
        s, d = rng.integers(mesh.n_nodes, size=2)
        if s != d and alloc2.allocate(int(s), int(d), 512,
                                      cycle=i * 8).circuit:
            done += 1
    us = (time.perf_counter() - t0) / n * 1e6
    rows.append(("slot_alloc/allocate_e2e", us, f"alloc_rate={done}/{n}"))

    # batched vs serial end-to-end rate on identical request streams: one
    # vectorized wavefront pass + arrival-order commit vs one search per
    # request.  Fresh allocator per rep so table state is comparable.
    batch = 64
    reqs = _stream(np.random.default_rng(1), mesh, batch)
    TdmAllocator(mesh, 16).allocate_batch(reqs, cycle=0)       # warm jit
    a = TdmAllocator(mesh, 16)
    for r in reqs[:4]:
        a.allocate(r.src, r.dst, r.nbytes, 0)                  # warm B=1
    reps = 5
    t0 = time.perf_counter()
    for _ in range(reps):
        a = TdmAllocator(mesh, 16)
        for i, r in enumerate(reqs):
            a.allocate(r.src, r.dst, r.nbytes, cycle=0)
    us_serial = (time.perf_counter() - t0) / (reps * batch) * 1e6
    t0 = time.perf_counter()
    committed = rounds = 0
    for _ in range(reps):
        a = TdmAllocator(mesh, 16)
        res = a.allocate_batch(reqs, cycle=0)
        committed = sum(r.circuit is not None for r in res)
        rounds = a.last_report.search_rounds
    us_batch = (time.perf_counter() - t0) / (reps * batch) * 1e6
    rows.append((f"slot_alloc/allocate_serial_b={batch}", us_serial,
                 f"{1e6/us_serial:.0f} alloc/s"))
    rows.append((f"slot_alloc/allocate_batch_b={batch}", us_batch,
                 f"batched_vs_serial={us_serial/us_batch:.1f}x "
                 f"committed={committed}/{batch} rounds={rounds}"))
    return rows
