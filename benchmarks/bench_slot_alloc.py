"""Slot-allocation accelerator throughput: the paper's PE matrix finds a
path in one 500ps cycle; here we measure the JAX implementation's batched
search throughput, plus the end-to-end allocation rate of the concurrent
batched scheduler (``allocate_batch``) against the serial one-request-at-
a-time CCU loop — the paper's "many circuits per setup" claim as a
benchmark.

Besides the CSV rows, ``run()`` writes ``BENCH_alloc.json`` at the repo
root — the machine-readable perf record tracked across PRs (alloc rate by
batch size under the compiled and host backends, circuits/window, CCU
stall cycles, and the conflict-scoped re-search evidence: one conflict
costs one extra search, independent of how many requests trail it).
``scripts/ci.sh`` asserts the file is produced, well-formed, and that
the compiled pipeline actually served the big batches.  ``run(quick=
True)`` (the ``run.py --quick`` smoke) keeps the full schema with fewer
timing reps.
"""
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fabric import NomFabric
from repro.core.scheduler import TransferRequest
from repro.core.slot_alloc import (CopyRequest, TdmAllocator,
                                   wavefront_search_batch)
from repro.core.topology import Mesh3D

RECORD_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_alloc.json"


def _stream(rng, mesh, n, nbytes=512):
    reqs = []
    for _ in range(n):
        s, d = rng.integers(mesh.n_nodes, size=2)
        while s == d:
            d = rng.integers(mesh.n_nodes)
        reqs.append(CopyRequest(int(s), int(d), nbytes))
    return reqs


def _median(fn, reps):
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return sorted(samples)[len(samples) // 2]


def _bench_search(rows, mesh, alloc, rng):
    occ = jnp.asarray(alloc.table.busy_masks(0))
    for batch in (1, 16, 64):
        srcs = jnp.asarray(rng.integers(mesh.n_nodes, size=batch), jnp.int32)
        dsts = jnp.asarray((np.asarray(srcs) + 1 + rng.integers(
            mesh.n_nodes - 1, size=batch)) % mesh.n_nodes, jnp.int32)
        inits = jnp.zeros(batch, jnp.uint32)
        fn = jax.jit(lambda o, s, d, iv: wavefront_search_batch(
            o, s, d, iv, mesh=mesh, n_slots=16))
        fn(occ, srcs, dsts, inits).block_until_ready()   # warm
        t0 = time.perf_counter()
        reps = 20
        for _ in range(reps):
            out = fn(occ, srcs, dsts, inits)
        out.block_until_ready()
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append((f"slot_alloc/search_batch={batch}", us,
                     f"{us/batch:.1f}us/request (hw target: 1 cycle)"))


# Pre-PR (tail-wide re-search, per-request Python commit) allocate_batch
# cost, measured on the PR-5 development container: the perf target the
# PR-5 pipeline was tracked against.  Absolute microseconds are container-
# specific — on other hardware read `batched_vs_serial` / `fused_vs_host`
# (measured in-run), or re-measure the baseline at the old commit on that
# machine.
_PR4_BASELINE_US = {"64": 123.6, "128": 202.2, "256": 239.9}
# The PR-5 host pipeline's recorded us_batch (its own container), plus a
# re-measurement of the PR-5 code on the PR-8 development machine — the
# honest same-machine denominator for the compiled pipeline's speedup.
_PR5_RECORD_US = {"64": 73.0, "128": 81.8, "256": 81.0}
_PR5_SAME_MACHINE_US = {"256": 135.5}
_BASELINE_NOTE = (
    "pr4_baseline_us / pr5_record_us were measured on earlier (faster) "
    "containers; pr5_same_machine_us re-ran the PR-5 commit on this "
    "machine. In-run ratios (batched_vs_serial, fused_vs_host) are the "
    "portable metrics.")


def _bench_e2e(rows, mesh, record, quick=False):
    """Serial one-at-a-time CCU loop vs one concurrent batched setup —
    under the host backend and under the compiled (auto/fused) backend —
    on identical request streams (fresh allocator per rep so table state
    is comparable; results are bit-identical by construction)."""
    reps_serial, reps_batch = (2, 5) if quick else (5, 11)
    for batch in (64, 128, 256):
        reqs = _stream(np.random.default_rng(1), mesh, batch)
        # Warm every jit/compile path (fused program included) + B=1.
        TdmAllocator(mesh, 16, backend="auto").allocate_batch(reqs, cycle=0)
        TdmAllocator(mesh, 16, backend="host").allocate_batch(reqs, cycle=0)
        a = TdmAllocator(mesh, 16)
        for r in reqs[:4]:
            a.allocate(r.src, r.dst, r.nbytes, 0)

        def serial():
            a = TdmAllocator(mesh, 16)
            for r in reqs:
                a.allocate(r.src, r.dst, r.nbytes, cycle=0)
        us_serial = _median(serial, reps_serial) / batch * 1e6

        def batched(backend, state):
            def fn():
                a = TdmAllocator(mesh, 16, backend=backend)
                res = a.allocate_batch(reqs, cycle=0)
                state["committed"] = sum(r.circuit is not None for r in res)
                state["report"] = a.last_report
            return fn

        st_auto, st_host = {}, {}
        us_batch = _median(batched("auto", st_auto), reps_batch) / batch * 1e6
        us_host = _median(batched("host", st_host), reps_batch) / batch * 1e6
        rep = st_auto["report"]
        assert st_auto["committed"] == st_host["committed"]
        speed = us_serial / us_batch
        fused_vs_host = us_host / us_batch
        vs_pr5 = _PR5_RECORD_US[str(batch)] / us_batch
        rows.append((f"slot_alloc/allocate_serial_b={batch}", us_serial,
                     f"{1e6/us_serial:.0f} alloc/s"))
        rows.append((f"slot_alloc/allocate_batch_b={batch}", us_batch,
                     f"batched_vs_serial={speed:.1f}x "
                     f"fused_vs_host={fused_vs_host:.2f}x "
                     f"vs_pr5_record={vs_pr5:.1f}x "
                     f"committed={st_auto['committed']}/{batch} "
                     f"fused_waves={rep.fused_waves} "
                     f"rounds={rep.search_rounds} "
                     f"searched={rep.n_searched}"))
        entry = {
            "backend": "auto",
            "us_serial": round(us_serial, 1),
            "us_batch": round(us_batch, 1),
            "us_batch_host": round(us_host, 1),
            "batched_vs_serial": round(speed, 2),
            "fused_vs_host": round(fused_vs_host, 2),
            "pr4_baseline_us": _PR4_BASELINE_US[str(batch)],
            "speedup_vs_pr4": round(_PR4_BASELINE_US[str(batch)] / us_batch,
                                    2),
            "pr5_record_us": _PR5_RECORD_US[str(batch)],
            "speedup_vs_pr5_record": round(vs_pr5, 2),
            "alloc_rate_per_s": round(1e6 / us_batch),
            "search_rounds": rep.search_rounds,
            "conflicts": rep.conflicts,
            "n_searched": rep.n_searched,
            "fused_waves": rep.fused_waves,
            "host_waves": rep.host_waves,
        }
        if str(batch) in _PR5_SAME_MACHINE_US:
            pr5_here = _PR5_SAME_MACHINE_US[str(batch)]
            entry["pr5_same_machine_us"] = pr5_here
            entry["speedup_vs_pr5_same_machine"] = round(pr5_here / us_batch,
                                                         2)
        record["alloc"][str(batch)] = entry


def _bench_single_conflict(rows, mesh, record):
    """One contended pair in front of a growing tail of link-disjoint
    row transfers: conflict-scoped re-search must pay exactly one extra
    search (rounds - base waves == 1) no matter the tail length — the
    old tail-wide retry re-searched the whole remainder."""
    wave = TdmAllocator.search_wave
    for tail in (7, 14, 28):      # 28 = every disjoint row lane of the mesh
        reqs = [CopyRequest(mesh.node_id(0, 0, 0), mesh.node_id(1, 0, 0), 256),
                CopyRequest(mesh.node_id(0, 0, 0), mesh.node_id(1, 0, 0), 256)]
        lanes = [(y, z) for z in range(mesh.Z) for y in range(1, mesh.Y)]
        for y, z in lanes[:tail]:
            reqs.append(CopyRequest(mesh.node_id(0, y, z),
                                    mesh.node_id(mesh.X - 1, y, z), 256))
        a = TdmAllocator(mesh, 16)
        res = a.allocate_batch(reqs, cycle=0)
        rep = a.last_report
        base = -(-len(reqs) // wave)          # search waves sans conflicts
        extra = rep.search_rounds - base
        rows.append((f"slot_alloc/single_conflict_tail={tail}", 0.0,
                     f"rounds={rep.search_rounds} extra_rounds={extra} "
                     f"conflicts={rep.conflicts} "
                     f"searched={rep.n_searched} "
                     f"committed={sum(r.circuit is not None for r in res)}"))
        record["single_conflict"][str(tail)] = {
            "search_rounds": rep.search_rounds,
            "extra_rounds_beyond_waves": extra,
            "conflicts": rep.conflicts,
            "n_searched": rep.n_searched,
        }


def _bench_fabric(rows, mesh, record):
    """Circuits per TDM window + CCU queue stalls through a fabric
    session — the controller-side arbitration telemetry."""
    fab = NomFabric(mesh=mesh, n_slots=16)
    reqs = [TransferRequest(src=r.src, dst=r.dst, nbytes=r.nbytes)
            for r in _stream(np.random.default_rng(3), mesh, 128)]
    _res, rep = fab.schedule(reqs)
    rows.append(("slot_alloc/circuits_per_window", rep.avg_inflight,
                 f"max_inflight={rep.max_inflight} over "
                 f"{rep.n_windows} windows"))
    record["circuits_per_window"] = {
        "avg_inflight": round(rep.avg_inflight, 2),
        "max_inflight": rep.max_inflight,
    }
    qfab = NomFabric(mesh=mesh, n_slots=16, queue_depth=4, overflow="block")
    for r in _stream(np.random.default_rng(4), mesh, 48):
        qfab.submit(TransferRequest(src=r.src, dst=r.dst, nbytes=2048))
    qfab.flush()
    tel = qfab.telemetry()
    rows.append(("slot_alloc/ccu_stall_cycles",
                 float(tel["queue_stall_cycles"]),
                 f"full_stalls={tel['full_stalls']} depth=4"))
    record["ccu"] = {
        "stall_cycles": tel["queue_stall_cycles"],
        "full_stalls": tel["full_stalls"],
        "queue_depth": 4,
    }


def run(quick: bool = False):
    rows = []
    mesh = Mesh3D(8, 8, 4)
    alloc = TdmAllocator(mesh, 16)
    rng = np.random.default_rng(0)
    for i in range(32):
        s, d = rng.integers(mesh.n_nodes, size=2)
        if s != d:
            alloc.allocate(int(s), int(d), 512, cycle=i)
    record = {
        "schema": "nom/bench-alloc/v2",
        "mesh": [mesh.X, mesh.Y, mesh.Z],
        "n_slots": 16,
        "search_wave": TdmAllocator.search_wave,
        "quick": quick,
        "baseline_note": _BASELINE_NOTE,
        "alloc": {},
        "single_conflict": {},
    }
    _bench_search(rows, mesh, alloc, rng)
    _bench_e2e(rows, mesh, record, quick=quick)
    _bench_single_conflict(rows, mesh, record)
    _bench_fabric(rows, mesh, record)
    RECORD_PATH.write_text(json.dumps(record, indent=2) + "\n")
    rows.append(("slot_alloc/perf_record", 0.0,
                 f"wrote {RECORD_PATH.name}"))
    return rows
