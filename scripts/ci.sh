#!/usr/bin/env bash
# Tier-1 gate: the full offline test suite (JAX 0.4.37, no network, no
# hypothesis — see tests/_hypothesis_shim.py) plus a quick benchmark smoke
# so the batched-scheduler perf numbers are exercised on every PR.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

echo "== docs: suite present + README blocks compile =="
python scripts/check_docs.py

echo "== api: no legacy scheduler call sites outside core/ =="
python scripts/check_api.py

echo "== tier-1: pytest =="
python -m pytest -q "$@"

echo "== smoke: benchmarks (quick subset) =="
python benchmarks/run.py --quick
