#!/usr/bin/env bash
# Tier-1 gate: the full offline test suite (JAX 0.4.37, no network, no
# hypothesis — see tests/_hypothesis_shim.py) plus a quick benchmark smoke
# so the batched-scheduler perf numbers are exercised on every PR.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

echo "== docs: suite present + README blocks compile =="
python scripts/check_docs.py

echo "== api: no legacy scheduler call sites outside core/ =="
python scripts/check_api.py

echo "== tier-1: pytest =="
python -m pytest -q "$@"

echo "== multidevice lane: 8 faked XLA devices =="
python -m pytest -q -m multidevice tests/test_multidevice_alloc.py

echo "== smoke: benchmarks (quick subset) =="
# the gates below must see THIS run's records
rm -f BENCH_alloc.json BENCH_multistack.json BENCH_serving.json \
      BENCH_reduce.json BENCH_engine_scale.json
python benchmarks/run.py --quick

echo "== perf record: BENCH_alloc.json =="
python - <<'EOF'
import json, pathlib, sys
path = pathlib.Path("BENCH_alloc.json")
if not path.is_file():
    sys.exit("BENCH_alloc.json missing: benchmarks/run.py --quick must write it")
rec = json.loads(path.read_text())
if rec.get("schema") != "nom/bench-alloc/v2":
    sys.exit(f"BENCH_alloc.json schema {rec.get('schema')!r}: expected "
             "nom/bench-alloc/v2 (compiled-pipeline record)")
required = ("schema", "mesh", "n_slots", "alloc", "single_conflict",
            "circuits_per_window", "ccu")
missing = [k for k in required if k not in rec]
if missing:
    sys.exit(f"BENCH_alloc.json missing keys: {missing}")
for batch, entry in rec["alloc"].items():
    for k in ("backend", "us_serial", "us_batch", "us_batch_host",
              "batched_vs_serial", "fused_vs_host", "speedup_vs_pr4",
              "pr5_record_us", "speedup_vs_pr5_record", "alloc_rate_per_s",
              "search_rounds", "conflicts", "n_searched", "fused_waves",
              "host_waves"):
        if k not in entry:
            sys.exit(f"BENCH_alloc.json alloc[{batch}] missing {k}")
big = rec["alloc"]["256"]
if big["fused_waves"] < 1:
    sys.exit("BENCH_alloc.json alloc[256]: compiled pipeline served no "
             "waves (fused_waves=0) — the fused backend is not engaging")
if big["us_batch"] > big["pr5_record_us"]:
    sys.exit(f"BENCH_alloc.json alloc[256]: us_batch={big['us_batch']} "
             f"regressed past the PR-5 record {big['pr5_record_us']}")
for tail, entry in rec["single_conflict"].items():
    if entry["extra_rounds_beyond_waves"] > entry["conflicts"]:
        sys.exit(f"single_conflict[{tail}]: re-search not conflict-scoped")
print(f"BENCH_alloc.json OK: batches={sorted(rec['alloc'])} "
      f"b256 fused={big['us_batch']}us host={big['us_batch_host']}us "
      f"({big['fused_vs_host']}x, fused_waves={big['fused_waves']}) "
      f"tails={sorted(rec['single_conflict'])}")
EOF

echo "== perf record: BENCH_multistack.json =="
python - <<'EOF'
import json, pathlib, sys
path = pathlib.Path("BENCH_multistack.json")
if not path.is_file():
    sys.exit("BENCH_multistack.json missing: benchmarks/run.py --quick "
             "must write it")
rec = json.loads(path.read_text())
required = ("schema", "topology", "circuits_per_window", "migration")
missing = [k for k in required if k not in rec]
if missing:
    sys.exit(f"BENCH_multistack.json missing keys: {missing}")
cpw = rec["circuits_per_window"]
for side in ("intra", "cross"):
    if side not in cpw:
        sys.exit(f"BENCH_multistack.json circuits_per_window missing {side}")
    for k in ("n_scheduled", "n_windows", "circuits_per_window",
              "n_cross_stack"):
        if k not in cpw[side]:
            sys.exit(f"BENCH_multistack.json {side} missing {k}")
if cpw["cross"]["n_cross_stack"] == 0:
    sys.exit("BENCH_multistack.json: cross record scheduled no "
             "cross-stack circuits")
if not rec["migration"]:
    sys.exit("BENCH_multistack.json: migration sweep is empty")
for n, entry in rec["migration"].items():
    for k in ("tenants", "migrations", "cross_stack_circuits"):
        if k not in entry:
            sys.exit(f"BENCH_multistack.json migration[{n}] missing {k}")
print(f"BENCH_multistack.json OK: cross/intra="
      f"{cpw.get('cross_over_intra')} "
      f"migration_sweep={sorted(rec['migration'])}")
EOF

echo "== perf record: BENCH_serving.json =="
python - <<'EOF'
import json, pathlib, sys
path = pathlib.Path("BENCH_serving.json")
if not path.is_file():
    sys.exit("BENCH_serving.json missing: benchmarks/run.py --quick "
             "must write it")
rec = json.loads(path.read_text())
required = ("schema", "seed", "ticks", "engine", "records", "dominance")
missing = [k for k in required if k not in rec]
if missing:
    sys.exit(f"BENCH_serving.json missing keys: {missing}")
per_record = ("mix", "strategy", "arrivals", "admitted", "shed", "expired",
              "waiting", "shed_rate", "expiry_rate", "p50_wait", "p99_wait",
              "deadline_misses", "miss_rate", "circuits_per_window")
mixes, strategies = set(), set()
for entry in rec["records"]:
    bad = [k for k in per_record if k not in entry]
    if bad:
        sys.exit(f"BENCH_serving.json record {entry.get('mix')}/"
                 f"{entry.get('strategy')} missing {bad}")
    mixes.add(entry["mix"])
    strategies.add(entry["strategy"])
if len(mixes) < 3 or len(strategies) < 2:
    sys.exit(f"BENCH_serving.json grid too small: {len(mixes)} mixes x "
             f"{len(strategies)} strategies (need >=3 x >=2)")
dom = rec["dominance"]
for k in ("mix", "fifo_miss_rate", "deadline_miss_rate",
          "deadline_beats_fifo"):
    if k not in dom:
        sys.exit(f"BENCH_serving.json dominance missing {k}")
if dom["deadline_miss_rate"] >= dom["fifo_miss_rate"]:
    sys.exit(f"BENCH_serving.json: deadline strategy did not beat fifo on "
             f"{dom['mix']} (deadline={dom['deadline_miss_rate']:.3f} vs "
             f"fifo={dom['fifo_miss_rate']:.3f})")
print(f"BENCH_serving.json OK: {len(mixes)} mixes x "
      f"{len(strategies)} strategies, dominance on {dom['mix']}: "
      f"deadline={dom['deadline_miss_rate']:.3f} < "
      f"fifo={dom['fifo_miss_rate']:.3f}")
EOF

echo "== perf record: BENCH_engine_scale.json =="
python - <<'EOF'
import json, pathlib, sys
path = pathlib.Path("BENCH_engine_scale.json")
if not path.is_file():
    sys.exit("BENCH_engine_scale.json missing: benchmarks/run.py --quick "
             "must write it")
rec = json.loads(path.read_text())
if rec.get("schema") != "nom/bench-engine-scale/v1":
    sys.exit(f"BENCH_engine_scale.json schema {rec.get('schema')!r}: "
             "expected nom/bench-engine-scale/v1")
required = ("schema", "engine", "sizes", "soak", "differential")
missing = [k for k in required if k not in rec]
if missing:
    sys.exit(f"BENCH_engine_scale.json missing keys: {missing}")
bad = [k for k, ok in rec["differential"].items() if not ok]
if bad:
    sys.exit(f"BENCH_engine_scale.json: vectorized admission order "
             f"diverged from the scalar reference for {bad}")
if not rec["differential"]:
    sys.exit("BENCH_engine_scale.json: differential section is empty")
per_plane = ("open_per_s", "admit_per_s", "tick_per_s", "close_per_s")
gated = 0
for n, entry in rec["sizes"].items():
    if "vector" not in entry:
        sys.exit(f"BENCH_engine_scale.json sizes[{n}] missing vector plane")
    for plane in ("vector", "scalar"):
        for k in per_plane:
            if plane in entry and k not in entry[plane]:
                sys.exit(f"BENCH_engine_scale.json sizes[{n}][{plane}] "
                         f"missing {k}")
    # Dominance: the vector plane must beat scalar >= 10x on the three
    # control-plane phases wherever both are measured at 10k+ tenants.
    if int(n) >= 10_000 and "speedup" in entry:
        gated += 1
        for k in ("open", "admit", "tick"):
            if entry["speedup"][k] < 10.0:
                sys.exit(f"BENCH_engine_scale.json: vector plane only "
                         f"{entry['speedup'][k]}x scalar on {k} at {n} "
                         f"tenants (gate: >=10x)")
if not gated:
    sys.exit("BENCH_engine_scale.json: no 10k+ size with both planes "
             "measured — the dominance gate never ran")
if not rec["soak"].get("completed"):
    sys.exit("BENCH_engine_scale.json: soak did not complete")
sizes = sorted(int(n) for n in rec["sizes"])
big = rec["sizes"][str(sizes[-1])]["vector"]
print(f"BENCH_engine_scale.json OK: sizes={sizes} "
      f"soak={rec['soak']['tenants']} tenants in {rec['soak']['wall_s']}s, "
      f"10k speedups={rec['sizes'].get('10000', {}).get('speedup')} "
      f"top open={big['open_per_s']:.0f}/s")
EOF

echo "== perf record: BENCH_reduce.json =="
python - <<'EOF'
import json, pathlib, sys
path = pathlib.Path("BENCH_reduce.json")
if not path.is_file():
    sys.exit("BENCH_reduce.json missing: benchmarks/run.py --quick "
             "must write it")
rec = json.loads(path.read_text())
if rec.get("schema") != "nom/bench-reduce/v1":
    sys.exit(f"BENCH_reduce.json schema {rec.get('schema')!r}: expected "
             "nom/bench-reduce/v1")
required = ("schema", "mesh", "nbytes", "trials", "fanin", "memsim")
missing = [k for k in required if k not in rec]
if missing:
    sys.exit(f"BENCH_reduce.json missing keys: {missing}")
for k, entry in rec["fanin"].items():
    for key in ("fanin", "reduce_windows", "baseline_windows", "speedup"):
        if key not in entry:
            sys.exit(f"BENCH_reduce.json fanin[{k}] missing {key}")
    # Dominance: the in-fabric fan-in must beat copy-then-compute (fewer
    # total TDM windows) at every measured fan-in >= 4 on the paper mesh.
    if entry["fanin"] >= 4 and \
            entry["reduce_windows"] >= entry["baseline_windows"]:
        sys.exit(f"BENCH_reduce.json: in-fabric reduce lost to "
                 f"copy-then-compute at fan-in {k} "
                 f"({entry['reduce_windows']} >= "
                 f"{entry['baseline_windows']} windows)")
if not any(e["fanin"] >= 4 for e in rec["fanin"].values()):
    sys.exit("BENCH_reduce.json: no fan-in >= 4 measured")
if rec["memsim"].get("nom_reduce_elems", 0) <= 0:
    sys.exit("BENCH_reduce.json: memsim record merged no elements at the "
             "destination ALU (nom_reduce_elems=0)")
dom = {k: round(e["speedup"], 2) for k, e in sorted(rec["fanin"].items())}
print(f"BENCH_reduce.json OK: windows speedup per fan-in {dom}, "
      f"memsim elems={rec['memsim']['nom_reduce_elems']}")
EOF
