#!/usr/bin/env python
"""API gate for CI: all NoM traffic goes through `NomFabric` sessions.

`schedule_transfers` is a deprecated shim and `TdmAllocator.allocate` is
the serial baseline the batched scheduler is compared against — neither
may gain new call sites outside `src/repro/core/` (production code,
benchmarks, examples).  The deliberate exceptions are allowlisted with
the reason they exist; everything else fails the build.

Usage: python scripts/check_api.py [root]   (exit 1 on violations)
"""
import pathlib
import re
import sys

SCAN_DIRS = ("src/repro", "benchmarks", "examples")
EXCLUDE_PREFIXES = ("src/repro/core/",)
# path -> why the legacy spelling is allowed to stay
ALLOWLIST = {
    "benchmarks/bench_slot_alloc.py":
        "the serial-vs-batched baseline: TdmAllocator.allocate *is* the "
        "one-request-at-a-time CCU being benchmarked against",
}
# (name, regex, extra exempt path prefixes, remedy) — a pattern's exempt
# prefixes stack on top of the global EXCLUDE_PREFIXES / ALLOWLIST.
PATTERNS = (
    # The deprecated one-shot shim.
    ("schedule_transfers", re.compile(r"\bschedule_transfers\s*\("),
     (), "route through NomFabric"),
    # The serial allocator spelling (allocate_batch via a fabric is fine;
    # `.allocate(` does not match `.allocate_batch(`).
    ("TdmAllocator.allocate", re.compile(r"\.allocate\s*\("),
     (), "route through NomFabric"),
    # Production code builds topologies through the one factory, so the
    # single-stack/multi-stack choice stays a config knob; benchmarks may
    # pin exact meshes to keep their measured shapes stable.
    ("bare Mesh3D/StackedTopology construction",
     re.compile(r"\b(?:Mesh3D|StackedTopology)\s*\("),
     ("benchmarks/",), "construct topologies via repro.core.make_topology"),
    # Compute-class fan-ins are validated by reduce_request (distinct
    # sources, dst not among them) — raw op="reduce" construction skips
    # that.  memsim's simulator is the one translator allowed to lower
    # its Op.REDUCE requests onto allocator-level CopyRequests itself.
    ("raw multi-source reduce construction",
     re.compile(r"op\s*=\s*[\"']reduce[\"']"),
     ("src/repro/memsim/simulator.py",),
     "build fan-ins via repro.core.reduce_request / nom_reduce"),
)


def violations(root: pathlib.Path) -> list[str]:
    out = []
    for rel_dir in SCAN_DIRS:
        base = root / rel_dir
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            if rel.startswith(EXCLUDE_PREFIXES) or rel in ALLOWLIST:
                continue
            for lineno, line in enumerate(path.read_text().splitlines(), 1):
                code = line.split("#", 1)[0]
                for name, pat, exempt, remedy in PATTERNS:
                    if exempt and rel.startswith(exempt):
                        continue
                    if pat.search(code):
                        out.append(f"{rel}:{lineno}: direct {name} "
                                   f"({remedy})")
    return out


def main() -> None:
    root = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 else \
        pathlib.Path(__file__).resolve().parent.parent
    bad = violations(root)
    if bad:
        print("check_api: FAIL — legacy scheduler call sites outside core/:")
        for v in bad:
            print(f"  {v}")
        print("(hold a repro.core.fabric.NomFabric session instead; "
              "deliberate baselines go in the ALLOWLIST with a reason)")
        sys.exit(1)
    print(f"check_api: OK ({len(ALLOWLIST)} allowlisted baseline file(s))")


if __name__ == "__main__":
    main()
