#!/usr/bin/env python
"""Docs gate for CI: the documentation suite must exist, README /
architecture python blocks must compile, docs/serving.md and
docs/fabric.md blocks must actually *run* (imports included), every path
a doc references must exist in the tree, and every public method of the
serving + fabric API (`Engine`, `BankPool`, `NomFabric`) must be
mentioned in a doc page (stale docs fail the build)."""
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
REQUIRED = ("README.md", "docs/architecture.md", "docs/serving.md",
            "docs/fabric.md", "docs/multistack.md", "PAPER.md",
            "ROADMAP.md", "CHANGES.md")
DOC_PAGES = ("README.md", "docs/architecture.md", "docs/serving.md",
             "docs/fabric.md", "docs/multistack.md")
# Pages whose python blocks must execute end to end, not just compile.
EXEC_PAGES = ("docs/serving.md", "docs/fabric.md", "docs/multistack.md")


def fail(msg: str) -> None:
    print(f"check_docs: FAIL — {msg}")
    sys.exit(1)


def python_blocks(text: str) -> list[str]:
    return re.findall(r"```python\n(.*?)```", text, re.S)


def referenced_paths(text: str) -> set[str]:
    """Backtick/link-referenced repo paths (files or dirs) in a doc."""
    pat = re.compile(r"[`(]((?:src|docs|tests|benchmarks|examples|scripts)"
                     r"/[\w./-]+?)[`)]")
    return {m.rstrip(".,") for m in pat.findall(text)}


def public_methods(cls) -> list[str]:
    return sorted(name for name, val in vars(cls).items()
                  if callable(val) and not name.startswith("_"))


def check_serving_api_documented() -> None:
    """Every public Engine/BankPool/NomFabric/StackedTopology/
    FabricCluster method must appear in some doc page (the fabric and
    the two-level topology are the API every subsystem now holds) — and
    likewise every public name of the SLO-serving surface: the loadgen
    module (mixes, generator, drive harness) and the admission-strategy
    registry."""
    from repro.core.fabric import FabricCluster, NomFabric
    from repro.core.topology import StackedTopology
    from repro.serving import BankPool, Engine
    from repro.serving import admission, loadgen
    corpus = "\n".join((ROOT / rel).read_text() for rel in DOC_PAGES)
    for cls in (Engine, BankPool, NomFabric, StackedTopology, FabricCluster,
                loadgen.LoadGen, admission.AdmissionContext,
                admission.TicketColumns):
        for m in public_methods(cls):
            # Word-boundary match: "release" must not satisfy "lease".
            if not re.search(rf"\b{re.escape(m)}\b", corpus):
                fail(f"{cls.__name__}.{m} is public but mentioned in no "
                     f"doc page ({', '.join(DOC_PAGES)})")
    for mod in (loadgen, admission):
        for name in mod.__all__:
            if not re.search(rf"\b{re.escape(name)}\b", corpus):
                fail(f"{mod.__name__}.{name} is public but mentioned in "
                     f"no doc page ({', '.join(DOC_PAGES)})")
    check_compiled_pipeline_documented(corpus)
    check_reduce_documented(corpus)
    check_control_plane_documented(corpus)


def check_control_plane_documented(corpus: str) -> None:
    """The batched control-plane surface (PR 10): the plane knob and its
    vocabulary, the stall-coupled strategy and its threshold/signal, and
    the closed-loop retry ledger must each appear in a doc page."""
    names = ["CONTROL_PLANES", "control_plane", "TicketColumns",
             "STALL_PRESSURE", "stall_aware", "stall_pressure",
             "retry_budget", "retries", "retry_admitted", "backoff_ticks",
             "retrying"]
    for name in names:
        if not re.search(rf"\b{re.escape(name)}\b", corpus):
            fail(f"control-plane name {name} is mentioned in no doc "
                 f"page ({', '.join(DOC_PAGES)})")


def check_compiled_pipeline_documented(corpus: str) -> None:
    """The compiled commit pipeline's public surface (PR 8): every
    non-module export of the slot-alloc kernel package, the backend knob
    and the backend-split telemetry counters must appear in a doc page."""
    import inspect

    import repro.kernels.slot_alloc as slot_kernels
    names = [n for n in slot_kernels.__all__
             if not inspect.ismodule(getattr(slot_kernels, n))]
    names += ["alloc_backend", "fused_waves", "host_waves"]
    for name in names:
        if not re.search(rf"\b{re.escape(name)}\b", corpus):
            fail(f"compiled-pipeline name {name} is mentioned in no doc "
                 f"page ({', '.join(DOC_PAGES)})")


def check_reduce_documented(corpus: str) -> None:
    """The compute-class reduce surface (PR 9): the planners, the
    request constructor, the report/telemetry counters and the energy
    knob must each appear in a doc page."""
    names = ["plan_combine", "nom_allreduce", "nom_reduce",
             "nom_allreduce_banks", "reduce_request", "ReduceTree",
             "n_reduce", "e_reduce_elem", "reduce_dwell",
             "nom_reduce_elems", "nom_extra_slots"]
    for name in names:
        if not re.search(rf"\b{re.escape(name)}\b", corpus):
            fail(f"compute-class reduce name {name} is mentioned in no "
                 f"doc page ({', '.join(DOC_PAGES)})")


def main() -> None:
    sys.path.insert(0, str(ROOT / "src"))   # for doc-block exec + API import
    for rel in REQUIRED:
        if not (ROOT / rel).is_file():
            fail(f"missing {rel}")
    for rel in DOC_PAGES:
        text = (ROOT / rel).read_text()
        for i, block in enumerate(python_blocks(text)):
            where = f"{rel}[python block {i}]"
            try:
                code = compile(block, where, "exec")
            except SyntaxError as e:
                fail(f"{where} does not compile: {e}")
            if rel in EXEC_PAGES:
                try:
                    exec(code, {"__name__": "__check_docs__"})
                except Exception as e:
                    fail(f"{where} does not run: {type(e).__name__}: {e}")
        for path in sorted(referenced_paths(text)):
            p = ROOT / path
            if not (p.exists() or p.with_suffix("").exists()):
                fail(f"{rel} references missing path {path}")
    check_serving_api_documented()
    print("check_docs: OK")


if __name__ == "__main__":
    main()
