#!/usr/bin/env python
"""Docs gate for CI: the documentation suite must exist, README python
blocks must at least compile, and every path README/architecture.md
reference must exist in the tree (stale docs fail the build)."""
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
REQUIRED = ("README.md", "docs/architecture.md", "PAPER.md", "ROADMAP.md",
            "CHANGES.md")


def fail(msg: str) -> None:
    print(f"check_docs: FAIL — {msg}")
    sys.exit(1)


def python_blocks(text: str) -> list[str]:
    return re.findall(r"```python\n(.*?)```", text, re.S)


def referenced_paths(text: str) -> set[str]:
    """Backtick/link-referenced repo paths (files or dirs) in a doc."""
    pat = re.compile(r"[`(]((?:src|docs|tests|benchmarks|examples|scripts)"
                     r"/[\w./-]+?)[`)]")
    return {m.rstrip(".,") for m in pat.findall(text)}


def main() -> None:
    for rel in REQUIRED:
        if not (ROOT / rel).is_file():
            fail(f"missing {rel}")
    for rel in ("README.md", "docs/architecture.md"):
        text = (ROOT / rel).read_text()
        for i, block in enumerate(python_blocks(text)):
            try:
                compile(block, f"{rel}[python block {i}]", "exec")
            except SyntaxError as e:
                fail(f"{rel} python block {i} does not compile: {e}")
        for path in sorted(referenced_paths(text)):
            p = ROOT / path
            if not (p.exists() or p.with_suffix("").exists()):
                fail(f"{rel} references missing path {path}")
    print("check_docs: OK")


if __name__ == "__main__":
    main()
