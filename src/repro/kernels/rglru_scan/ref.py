"""Oracle: associative-scan linear recurrence (same combine as the model)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(a, b):
    """a, b: (B, S, W) -> h trajectory via lax.associative_scan."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def combine(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return h.astype(a.dtype)
