from . import ops, ref
from .rglru_scan import rglru_scan_fwd

__all__ = ["ops", "ref", "rglru_scan_fwd"]
