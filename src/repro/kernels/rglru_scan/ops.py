"""jit'd wrapper (+ the gate computation helper mirroring models.rglru)."""
from __future__ import annotations

from functools import partial

import jax

from .rglru_scan import rglru_scan_fwd


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def rglru_scan(a, b, *, chunk: int = 128, interpret: bool | None = None):
    """a, b: (B, S, W); pads S to the chunk multiple and slices back."""
    import jax.numpy as jnp
    bsz, s, w = a.shape
    pad = (-s) % chunk
    if pad:
        # padded steps: a=1, b=0 leaves the state untouched
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    y = rglru_scan_fwd(a, b, chunk=chunk, interpret=interpret)
    return y[:, :s]
