"""RG-LRU gated-linear-recurrence Pallas TPU kernel (RecurrentGemma).

h_t = a_t * h_{t-1} + b_t, with gates a/b precomputed (pointwise) outside.
Tiling: grid (batch, n_chunks) with the chunk axis sequential; the (1, W)
state is VMEM scratch.  Inside a chunk the recurrence is a time-step fori
over width-vectorized VPU ops — the same structure as the reference
RecurrentGemma TPU kernel: the op is bandwidth-bound, each step touching
3W floats, so the MXU has nothing to contribute and the win is keeping
h resident in VMEM across the whole sequence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.interpret import resolve_interpret


def _kernel(a_ref, b_ref, y_ref, h_scr, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)    # (q, w)
    b = b_ref[0].astype(jnp.float32)

    def step(t, carry):
        h, y = carry
        h = a[t] * h + b[t]
        y = jax.lax.dynamic_update_index_in_dim(y, h, t, axis=0)
        return h, y

    h0 = h_scr[0]                        # (w,)
    y0 = jnp.zeros_like(a)
    h, y = jax.lax.fori_loop(0, chunk, step, (h0, y0))
    h_scr[0] = h
    y_ref[0] = y.astype(y_ref.dtype)


def rglru_scan_fwd(a, b, *, chunk: int = 128, interpret: bool | None = None):
    """a, b: (B, S, W) with S % chunk == 0 -> h-trajectory (B, S, W)."""
    bsz, s, w = a.shape
    nc = s // chunk
    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bsz, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, w), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, w), lambda i, c: (i, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, w), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, w), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(a, b)
