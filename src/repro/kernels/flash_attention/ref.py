"""Pure-jnp oracle: dense GQA attention with causal/window masks."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D). fp32 softmax."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, g, sq, d)
    s = jnp.einsum("bkgqd,bktd->bkgqt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok, s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgqt,bktd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)
