"""jit'd public wrapper: model-layout in, padding + layout handled here."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .flash_attention import flash_attention_fwd


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """Model layout: q (B, Sq, Hq, D), k/v (B, Sk, Hkv, D).
    Pads sequences to block multiples (padding keys are masked inside the
    kernel; padded query rows are sliced off)."""
    b, sq, hq, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    qt = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kt = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    o = flash_attention_fwd(qt, kt, vt, causal=causal, window=window,
                            scale=scale, block_q=block_q, block_k=block_k,
                            interpret=interpret)
    return o.transpose(0, 2, 1, 3)[:, :sq]
