"""Flash attention (fwd) Pallas TPU kernel: causal / sliding-window GQA.

Standard online-softmax tiling for the MXU/VMEM hierarchy:
* grid (batch, q_heads, q_blocks, kv_blocks), kv minor and "arbitrary"
  (sequential) so VMEM scratch (m, l, acc) accumulates across kv steps;
* q tile (block_q, head_dim) stays resident; k/v tiles (block_k, head_dim)
  stream through VMEM; all matmul dims padded to MXU-friendly multiples
  by ops.py;
* GQA without materializing repeated KV: the k/v BlockSpec index_map sends
  q-head h to kv-head h // group_size;
* causal + sliding-window masks from global block offsets (iota), so no
  (S, S) mask tensor ever exists;
* out-of-range kv blocks are masked (structural skipping is a documented
  §Perf follow-up; the dry-run path uses the XLA scan variant anyway).

Validated in interpret mode against ref.py over shape/dtype sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.interpret import resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_q: int, block_k: int, seq_k: int, causal: bool,
            window: int | None, scale: float, n_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0] * scale                       # (bq, d)
    k = k_ref[0, 0]                               # (bk, d)
    v = v_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    ok = kpos < seq_k                              # padding
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                            # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                         # (bq, bk)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)

    @pl.when(ik == n_kv_blocks - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window: int | None = None, scale: float,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool | None = None):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D) — already padded so
    Sq % block_q == Sk % block_k == 0.  Returns (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    nq, nk = sq // block_q, sk // block_k
    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, seq_k=sk, causal=causal,
        window=window, scale=scale, n_kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v)
