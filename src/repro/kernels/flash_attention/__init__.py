from . import ops, ref
from .flash_attention import flash_attention_fwd

__all__ = ["ops", "ref", "flash_attention_fwd"]
