"""Pallas TPU kernels for the compute hot-spots, each with ops.py (jit'd
wrapper) and ref.py (pure-jnp oracle), validated in interpret mode:

* slot_alloc       — the paper's PE-matrix TDM slot-search accelerator
* flash_attention  — causal/sliding-window GQA flash attention (fwd)
* ssd_scan         — Mamba-2 SSD chunked scan
* rglru_scan       — RecurrentGemma RG-LRU linear recurrence

The model layers route to jnp reference paths on CPU backends (dry-run)
and to these kernels on TPU (`interpret=False`)."""
