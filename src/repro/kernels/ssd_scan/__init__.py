from . import ops, ref
from .ssd_scan import ssd_scan_fwd

__all__ = ["ops", "ref", "ssd_scan_fwd"]
