"""jit'd wrapper: model layout (B, S, H, hd) + per-head A, shared B/C."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ssd_scan import ssd_scan_fwd


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, B, C, A, *, chunk: int = 128,
             interpret: bool | None = None):
    """x: (B, S, H, hd); dt: (B, S, H); B/C: (B, S, n) (ngroups=1, shared
    across heads); A: (H,).  Returns (B, S, H, hd)."""
    b, s, h, hd = x.shape
    n = B.shape[-1]
    xr = x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    dtr = dt.transpose(0, 2, 1).reshape(b * h, s, 1)
    Br = jnp.broadcast_to(B[:, None], (b, h, s, n)).reshape(b * h, s, n)
    Cr = jnp.broadcast_to(C[:, None], (b, h, s, n)).reshape(b * h, s, n)
    Ar = jnp.broadcast_to(A[None, :], (b, h)).reshape(b * h, 1)
    y = ssd_scan_fwd(xr, dtr, Br, Cr, Ar, chunk=chunk, interpret=interpret)
    return y.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
