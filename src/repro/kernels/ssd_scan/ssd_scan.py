"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

Tiling: grid (batch*heads, n_chunks), chunk axis "arbitrary" (sequential)
so the (head_dim, d_state) recurrent state lives in VMEM scratch across
chunk steps.  Within a chunk the dual quadratic form runs on the MXU:
three (q x q)/(q x n)/(q x hd) matmuls per chunk — this is the paper's
"attention-like" intra-chunk path; the inter-chunk path is the O(1) state
recurrence.  All math in fp32 (decays are exponentials of cumulative sums;
bf16 would lose the tail).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.interpret import resolve_interpret

NEG_INF = -1e30


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, state_scr, *,
            chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)        # (q, hd)
    dt = dt_ref[0].astype(jnp.float32)      # (q, 1)
    B = b_ref[0].astype(jnp.float32)        # (q, n)
    C = c_ref[0].astype(jnp.float32)        # (q, n)
    A = a_ref[0, 0]                         # scalar (negative)

    dA = dt[:, 0] * A                       # (q,)
    cum = jnp.cumsum(dA)                    # (q,)
    seg = cum[:, None] - cum[None, :]       # (q, q)
    tri = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32)
    M = scores * L * dt[:, 0][None, :]
    y = jnp.dot(M, x, preferred_element_type=jnp.float32)
    # inter-chunk: contribution of the carried state
    state = state_scr[...]                  # (hd, n)
    y += jnp.exp(cum)[:, None] * jnp.dot(C, state.T,
                                         preferred_element_type=jnp.float32)
    # state update: decay to end-of-chunk + new outer products
    w = dt[:, 0] * jnp.exp(cum[-1] - cum)   # (q,)
    new_state = state * jnp.exp(cum[-1]) + jnp.dot(
        (x * w[:, None]).T, B, preferred_element_type=jnp.float32)
    state_scr[...] = new_state
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan_fwd(x, dt, B, C, A, *, chunk: int = 128,
                 interpret: bool | None = None):
    """x: (BH, S, hd); dt: (BH, S, 1); B/C: (BH, S, n); A: (BH, 1).
    S % chunk == 0.  Returns y (BH, S, hd)."""
    bh, s, hd = x.shape
    n = B.shape[-1]
    nc = s // chunk
    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((hd, n), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(x, dt, B, C, A)
