"""Sequential-scan oracle for SSD: h_t = exp(dt_t A) h_{t-1} +
dt_t * (B_t outer x_t);  y_t = C_t . h_t  — exact, O(S) jnp scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, B, C, A):
    """x: (BH, S, hd); dt: (BH, S, 1); B/C: (BH, S, n); A: (BH, 1)."""
    xf = x.astype(jnp.float32)
    dtf = dt[..., 0].astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A[:, 0].astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                     # (bh,hd),(bh,),(bh,n),(bh,n)
        decay = jnp.exp(dtt * Af)                 # (bh,)
        h = h * decay[:, None, None] + \
            (xt * dtt[:, None])[:, :, None] * bt[:, None, :]
        y = jnp.einsum("bn,bpn->bp", ct, h)
        return h, y

    bh, s, hd = x.shape
    n = B.shape[-1]
    h0 = jnp.zeros((bh, hd, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
                                    Bf.swapaxes(0, 1), Cf.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype)
