"""Backend-aware default for the Pallas ``interpret`` flag.

Every kernel in this package takes ``interpret: bool | None = None`` and
resolves ``None`` through :func:`default_interpret`: compiled Pallas
(``interpret=False``) on accelerator backends, interpreter mode on CPU —
where JAX 0.4.x Pallas raises ``ValueError: Only interpret mode is
supported on CPU backend.`` for compiled calls.  Passing an explicit
``True``/``False`` always wins (the compiled/interpret parity tests pass
``False`` on purpose and record the skip reason when the backend refuses).
"""
from __future__ import annotations

import jax

__all__ = ["default_interpret", "resolve_interpret"]


def default_interpret() -> bool:
    """True iff the default JAX backend needs Pallas interpreter mode."""
    return jax.default_backend() == "cpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a kernel's ``interpret`` argument: explicit values pass
    through; ``None`` picks the backend-aware default."""
    return default_interpret() if interpret is None else bool(interpret)
