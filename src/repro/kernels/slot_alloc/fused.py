"""The fused CCU prepare program: one compiled XLA program per search wave.

PR 5 vectorized the commit pipeline but left it split across three
host/device round trips per wave: the wavefront search on device, then
slot scoring and trace-back in numpy against the pulled-back (B, n)
vectors.  This module fuses all three stages into a single jit program —
the paper's claim that circuit setup happens at line rate inside the
memory controller, restated as "one program dispatch per wave":

* **wavefront fixpoint** — the same packed-uint32 formulation as
  ``repro.core.slot_alloc.wavefront_search`` (vmapped), or the Pallas
  bit-plane kernel (``kernel="pallas"``) for allocators built with
  ``use_pallas=True``;
* **slot scoring** — the int32 twin of ``_best_slots_np`` over the
  availability vectors at each destination (Pallas lane kernel in
  ``kernel="pallas"`` mode, plain jnp otherwise);
* **trace-back** — a ``lax.scan`` lockstep walk (one step per hop, whole
  batch at once) whose per-step outputs are assembled into forward hop
  arrays by one vectorized gather, still inside the program.

Only the chosen arrival slot is traced on device; extra-slot bundles
(``max_extra_slots``) are rare and ride the existing host trace-back
against the returned vectors.  Everything the host commit loop needs
comes back as small arrays — the (B, n) vectors stay on device unless a
caller actually asks for them.

Bit-identity to the host pipeline (and hence to serial ``allocate``) is
by construction: same tie-breaks (argmin first occurrence = ascending
scan), same x->y->z upstream priority (argmax on the candidate mask =
first free dimension), same slot arithmetic — costs are int32 here
(int64 on host), so callers must guard ``t_ready < 2**31 - 2*n_slots``
(``repro.core.slot_alloc.TdmAllocator`` does).  The property harness in
``tests/test_fused_alloc.py`` proves it across randomized topologies,
wave sizes and conflict densities; ``ref.fused_prepare_ref`` is the
numpy oracle twin.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.bitvec import UINT, full_mask
from repro.core.topology import PORT_LOCAL, Mesh3D
from repro.kernels.interpret import resolve_interpret

from .ops import pack_bits, unpack_bits
from .slot_alloc import LANES, wavefront_search_planes

__all__ = ["FusedPrepare", "fused_prepare", "fused_prepare_program",
           "slot_score_planes", "FAR32"]

# int32 "infeasible" sentinel — the host twin (`_best_slots_np`) uses
# int64 2**62; any feasible start cycle is strictly below either, so the
# argmin choice is identical whenever t_ready fits int32 (guarded by the
# caller).
FAR32 = np.int32(2 ** 31 - 1)


# ---------------------------------------------------------------------------
# Slot scoring
# ---------------------------------------------------------------------------
def _score_kernel(avail_ref, dists_ref, tready_ref, cost_ref, *,
                  n_slots: int):
    """Pallas lane kernel: per-(request, arrival-slot) start-cycle cost.

    avail: (B, LANES) int32 0/1 busy planes of ``vec[dst] | occ[dst,
    LOCAL]``; cost[b, s] = earliest injection cycle >= t_ready that
    arrives at slot s (FAR32 when s is busy or beyond n_slots).
    """
    avail = avail_ref[...]
    dists = dists_ref[...]                 # (B, 1)
    t = tready_ref[...]                    # (B, 1)
    lanes = jax.lax.broadcasted_iota(jnp.int32, avail.shape, 1)
    s_inj = (lanes - dists) % n_slots
    c = t + ((s_inj - t) % n_slots)
    free = (avail == 0) & (lanes < n_slots)
    cost_ref[...] = jnp.where(free, c, jnp.int32(FAR32))


@partial(jax.jit, static_argnames=("n_slots", "interpret"))
def slot_score_planes(avail_planes: jax.Array, dists: jax.Array,
                      t_readys: jax.Array, *, n_slots: int,
                      interpret: bool | None = None) -> jax.Array:
    """Pallas slot scoring over availability bit-planes.

    avail_planes: (B, LANES) int32 0/1 (busy); dists, t_readys: (B,)
    int32.  Returns the (B, LANES) int32 cost matrix; argmin over it is
    the chosen arrival slot (ties resolve to the lowest slot, same as
    the serial ascending scan).  Oracle: ``ref.slot_score_ref``.
    """
    B = avail_planes.shape[0]
    kernel = partial(_score_kernel, n_slots=n_slots)
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec((B, LANES), lambda: (0, 0)),
                  pl.BlockSpec((B, 1), lambda: (0, 0)),
                  pl.BlockSpec((B, 1), lambda: (0, 0))],
        out_specs=pl.BlockSpec((B, LANES), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, LANES), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(avail_planes, dists[:, None], t_readys[:, None])


def _score_jnp(avail: jax.Array, dists: jax.Array, t_readys: jax.Array,
               n_slots: int) -> jax.Array:
    """jnp twin of :func:`slot_score_planes` on packed uint32 vectors:
    (B, n_slots) int32 cost matrix."""
    slots = jnp.arange(n_slots, dtype=jnp.int32)
    free = ((avail[:, None] >> slots[None].astype(UINT)) & 1) == 0
    s_inj = (slots[None] - dists[:, None]) % n_slots
    c = t_readys[:, None] + ((s_inj - t_readys[:, None]) % n_slots)
    return jnp.where(free, c, jnp.int32(FAR32))


# ---------------------------------------------------------------------------
# Wavefront (Pallas bit-plane route, traced geometry)
# ---------------------------------------------------------------------------
def _wavefront_planes(occ, srcs, dsts, init_vecs, *, mesh: Mesh3D,
                      n_slots: int, interpret: bool | None) -> jax.Array:
    """The Pallas plane kernel with trace-safe (jnp) geometry, so it can
    live inside the fused program; contract of ``wavefront_search_batch``."""
    coords = jnp.asarray(mesh.coord_array)
    sc = coords[srcs]
    dc = coords[dsts]
    sign = jnp.sign(dc - sc).astype(jnp.int32)
    lo = jnp.minimum(sc, dc)[:, None, :]
    hi = jnp.maximum(sc, dc)[:, None, :]
    in_box = ((coords[None] >= lo) & (coords[None] <= hi)).all(-1)
    moved = coords[None, :, :] != sc[:, None, :]
    valid = (in_box[:, :, None] & moved & (sign[:, None, :] != 0)) \
        .transpose(0, 2, 1).astype(jnp.int32)
    occ_planes = unpack_bits(occ.T[:6], n_slots)
    B = srcs.shape[0]
    fm = jnp.asarray(full_mask(n_slots), UINT)
    init_packed = jnp.full((B, mesh.n_nodes), fm, UINT) \
        .at[jnp.arange(B), srcs].set(init_vecs.astype(UINT))
    out = wavefront_search_planes(
        sign, valid, unpack_bits(init_packed, n_slots), occ_planes,
        mesh_shape=(mesh.X, mesh.Y, mesh.Z), n_slots=n_slots,
        interpret=interpret)
    return pack_bits(out, n_slots)


# ---------------------------------------------------------------------------
# Trace-back (lax.scan lockstep walk)
# ---------------------------------------------------------------------------
def _traceback_scan(vecs, occ, jreq, jsrc, jdst, a0, *, mesh: Mesh3D,
                    n_slots: int):
    """Scan twin of ``traceback_batch`` for one job per request: per-step
    (J,) outputs, assembled into (J, max_dist+1) forward hop arrays by a
    single vectorized gather — all inside the program."""
    coords = jnp.asarray(mesh.coord_array)
    strides = jnp.asarray([1, mesh.X, mesh.X * mesh.Y], jnp.int32)
    n = mesh.n_nodes
    J = jreq.shape[0]
    rows = jnp.arange(J)
    src_c = coords[jsrc]
    sign = jnp.sign(coords[jdst] - src_c).astype(jnp.int32)        # (J, 3)
    dists = jnp.abs(coords[jdst] - src_c).sum(1)
    dims = jnp.arange(3)
    ports = jnp.where(sign < 0, 2 * dims + 1, 2 * dims)            # (J, 3)
    if mesh.max_dist == 0:
        # 1x1x1 mesh: every circuit is the zero-hop (dst, LOCAL, slot).
        hop_n = jdst[:, None].astype(jnp.int32)
        hop_p = jnp.full((J, 1), PORT_LOCAL, jnp.int32)
        hop_s = a0[:, None].astype(jnp.int32)
        return hop_n, hop_p, hop_s, jnp.ones(J, bool), dists

    def step(carry, _):
        v, j, active, ok = carry
        jp = (j - 1) % n_slots
        u = jnp.clip(v[:, None] - sign * strides[None], 0, n - 1)
        valid = (sign != 0) & (coords[v] != src_c)
        busy = vecs[jreq[:, None], u] | occ[u, ports]
        cand = valid & (((busy >> jp[:, None].astype(UINT)) & 1) == 0)
        has = cand.any(1)
        d = jnp.argmax(cand, 1)          # first free dim: x -> y -> z
        mask = active & has
        ok = ok & ~(active & ~has)
        v2 = jnp.where(mask, u[rows, d], v)
        j2 = jnp.where(mask, jp, j)
        return (v2, j2, mask & (v2 != jsrc), ok), (v2, ports[rows, d], jp)

    v0 = jdst.astype(jnp.int32)
    carry0 = (v0, a0.astype(jnp.int32), v0 != jsrc, jnp.ones(J, bool))
    (_, _, _, ok), (sv, sp, ss) = jax.lax.scan(
        step, carry0, None, length=mesh.max_dist)
    # Forward hop t (t < dist) was produced at scan step (dist-1-t); the
    # final entry (t == dist) is (dst, LOCAL, arrival_slot).
    L = mesh.max_dist + 1
    tpos = jnp.arange(L)[None, :]
    sidx = jnp.clip(dists[:, None] - 1 - tpos, 0, mesh.max_dist - 1)
    mid = tpos < dists[:, None]
    last = tpos == dists[:, None]
    hop_n = jnp.where(mid, sv[sidx, rows[:, None]],
                      jnp.where(last, jdst[:, None], 0)).astype(jnp.int32)
    hop_p = jnp.where(mid, sp[sidx, rows[:, None]],
                      jnp.where(last, PORT_LOCAL, 0)).astype(jnp.int32)
    hop_s = jnp.where(mid, ss[sidx, rows[:, None]],
                      jnp.where(last, a0[:, None], 0)).astype(jnp.int32)
    return hop_n, hop_p, hop_s, ok, dists


# ---------------------------------------------------------------------------
# The fused program
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("mesh", "n_slots", "kernel", "interpret"))
def fused_prepare_program(occ, srcs, dsts, t_readys, *, mesh: Mesh3D,
                          n_slots: int, kernel: str = "jnp",
                          interpret: bool | None = None):
    """One compiled program: wavefront + slot scoring + trace-back.

    Args:
      occ: (n, N_PORTS) uint32 device occupancy (``device_busy_masks``,
        version-keyed and reused across waves).
      srcs, dsts, t_readys: (B,) int32 per-call request buffers.
      kernel: "jnp" (packed-uint32 vmapped wavefront + jnp scoring) or
        "pallas" (bit-plane wavefront kernel + lane scoring kernel).
      interpret: Pallas interpret flag, only meaningful for "pallas".

    Returns ``(ints, flags, vecs)``: ``ints`` is (B, 3 + 3*(max_dist+1))
    int32 — columns [starts, arr, dists, hop_n..., hop_p..., hop_s...];
    ``flags`` is (B, 2 + n_slots) bool — columns [denied, ok, free...];
    ``vecs`` the converged (B, n) uint32 vectors for extra-slot
    trace-backs.  :func:`fused_prepare` unpacks them into a
    :class:`FusedPrepare`.
    """
    # Local import: core.slot_alloc lazily imports this package for
    # use_pallas allocators, so the top level must stay one-directional.
    from repro.core.slot_alloc import wavefront_search_batch

    B = srcs.shape[0]
    rows = jnp.arange(B)
    init = jnp.zeros(B, UINT)
    if kernel == "pallas":
        vecs = _wavefront_planes(occ, srcs, dsts, init, mesh=mesh,
                                 n_slots=n_slots, interpret=interpret)
    else:
        vecs = wavefront_search_batch(occ, srcs, dsts, init, mesh=mesh,
                                      n_slots=n_slots)
    coords = jnp.asarray(mesh.coord_array)
    dists = jnp.abs(coords[dsts] - coords[srcs]).sum(1)
    avail = vecs[rows, dsts] | occ[dsts, PORT_LOCAL]
    if kernel == "pallas":
        cost = slot_score_planes(
            unpack_bits(avail, n_slots), dists.astype(jnp.int32),
            t_readys.astype(jnp.int32), n_slots=n_slots,
            interpret=interpret)[:, :n_slots]
    else:
        cost = _score_jnp(avail, dists, t_readys, n_slots)
    arr = jnp.argmin(cost, 1).astype(jnp.int32)
    starts = cost[rows, arr]
    free = cost != jnp.int32(FAR32)
    denied = ~free.any(1)
    hop_n, hop_p, hop_s, ok, _ = _traceback_scan(
        vecs, occ, rows, srcs, dsts, arr, mesh=mesh, n_slots=n_slots)
    # Pack everything bound for the host into two arrays (one int32, one
    # bool): two device->host pulls per wave instead of nine.
    ints = jnp.concatenate(
        [starts[:, None], arr[:, None], dists[:, None].astype(jnp.int32),
         hop_n, hop_p, hop_s], axis=1)
    flags = jnp.concatenate([denied[:, None], ok[:, None], free], axis=1)
    return ints, flags, vecs


@dataclasses.dataclass
class FusedPrepare:
    """Host-side view of one fused wave: small numpy arrays, trimmed to
    the true batch size; the (B, n) vectors stay on device until
    :meth:`vecs_np` is called (extra-slot bundles only)."""
    starts: np.ndarray        # (B,) int32 chosen start cycles
    arr: np.ndarray           # (B,) int32 chosen arrival slots
    denied: np.ndarray        # (B,) bool — no free arrival slot
    free: np.ndarray          # (B, n_slots) bool
    hop_n: np.ndarray         # (B, max_dist+1) int32 forward hop nodes
    hop_p: np.ndarray         # (B, max_dist+1) int32 forward hop ports
    hop_s: np.ndarray         # (B, max_dist+1) int32 forward hop slots
    ok: np.ndarray            # (B,) bool — trace-back reached the source
    dists: np.ndarray         # (B,) int32 manhattan distances
    _vecs_dev: jax.Array = dataclasses.field(repr=False, default=None)
    _batch: int = 0

    def vecs_np(self) -> np.ndarray:
        """(B, n) uint32 converged busy vectors (device pull, lazy)."""
        return np.asarray(self._vecs_dev)[:self._batch]


def _pow2_pad(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def fused_prepare_start(occ, srcs, dsts, t_readys, *, mesh: Mesh3D,
                        n_slots: int, kernel: str = "jnp",
                        interpret: bool | None = None):
    """Dispatch the fused program for one wave without blocking.

    JAX dispatch is asynchronous: the returned token holds in-flight
    device arrays, so the host can overlap bookkeeping (the previous
    wave's circuit emission) with the device's search.  Pass the token
    to :func:`fused_prepare_wait` to pull the outputs.
    """
    B = len(srcs)
    pad = _pow2_pad(B)
    s = np.zeros(pad, np.int32)
    d = np.zeros(pad, np.int32)
    t = np.zeros(pad, np.int32)
    s[:B] = srcs
    d[:B] = dsts
    t[:B] = t_readys
    outs = fused_prepare_program(
        jnp.asarray(occ), s, d, t, mesh=mesh, n_slots=n_slots,
        kernel=kernel, interpret=resolve_interpret(interpret))
    return outs, B, mesh


def fused_prepare_wait(token) -> FusedPrepare:
    """Block on a :func:`fused_prepare_start` token and unpack it."""
    (ints, flags, vecs), B, mesh = token
    ints = np.asarray(ints)[:B]
    flags = np.asarray(flags)[:B]
    L = mesh.max_dist + 1
    return FusedPrepare(
        starts=ints[:, 0], arr=ints[:, 1],
        denied=flags[:, 0], free=flags[:, 2:],
        hop_n=ints[:, 3:3 + L], hop_p=ints[:, 3 + L:3 + 2 * L],
        hop_s=ints[:, 3 + 2 * L:3 + 3 * L], ok=flags[:, 1],
        dists=ints[:, 2], _vecs_dev=vecs, _batch=B)


def fused_prepare(occ, srcs, dsts, t_readys, *, mesh: Mesh3D, n_slots: int,
                  kernel: str = "jnp",
                  interpret: bool | None = None) -> FusedPrepare:
    """Run the fused program for one wave and pull the host-side outputs.

    ``srcs``/``dsts``/``t_readys`` are host arrays of any int dtype (the
    batch is padded to a power of two so jit retraces stay rare);
    ``t_readys`` must fit int32 — callers guard.  ``occ`` may be a
    device array (``SlotTable.device_busy_masks``) or host uint32 masks.
    """
    return fused_prepare_wait(fused_prepare_start(
        occ, srcs, dsts, t_readys, mesh=mesh, n_slots=n_slots,
        kernel=kernel, interpret=interpret))
