"""jit'd wrapper matching the core allocator contract: packed-uint32 in,
packed-uint32 out; the kernel works on int32 bit-planes internally.

Post-search contract (PR 5): ``TdmAllocator(use_pallas=True)`` feeds this
batch entry the same inputs as the jit path — ``occ_packed`` may be the
table's *device-resident* occupancy (`SlotTable.device_busy_masks`), and
the returned vectors flow through the same vectorized commit pipeline
(batch slot choice, ``traceback_batch``, conflict-scoped re-search).
With ``use_pallas=True`` every search rides the kernel — the host
small-batch shortcut is disabled so kernel tests exercise it end to end.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitvec import full_mask
from repro.core.topology import Mesh3D

from .slot_alloc import LANES, wavefront_search_planes


def unpack_bits(packed: jax.Array, n_slots: int) -> jax.Array:
    """uint32 (..., ) -> int32 (..., LANES) 0/1 planes (pad lanes busy)."""
    shifts = jnp.arange(LANES, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.astype(jnp.int32)
    pad_busy = (jnp.arange(LANES) >= n_slots).astype(jnp.int32)
    return jnp.maximum(bits, pad_busy)


def pack_bits(planes: jax.Array, n_slots: int) -> jax.Array:
    """int32 (..., LANES) 0/1 -> uint32 packed over the first n_slots."""
    weights = jnp.where(jnp.arange(LANES) < n_slots,
                        jnp.uint32(1) << jnp.arange(LANES, dtype=jnp.uint32),
                        jnp.uint32(0))
    return (planes.astype(jnp.uint32) * weights).sum(axis=-1,
                                                     dtype=jnp.uint32)


def _geometry(mesh: Mesh3D, srcs: np.ndarray, dsts: np.ndarray):
    """Host-side per-request masks: sign (B,3), valid (B,3,n)."""
    coords = mesh.coord_array                      # (n, 3)
    sc = coords[srcs]                              # (B, 3)
    dc = coords[dsts]
    sign = np.sign(dc - sc).astype(np.int32)       # (B, 3)
    lo = np.minimum(sc, dc)[:, None, :]            # (B, 1, 3)
    hi = np.maximum(sc, dc)[:, None, :]
    in_box = ((coords[None] >= lo) & (coords[None] <= hi)).all(-1)  # (B, n)
    moved = coords[None, :, :] != sc[:, None, :]   # (B, n, 3)
    valid = (in_box[:, :, None] & moved
             & (sign[:, None, :] != 0)).transpose(0, 2, 1)          # (B,3,n)
    return sign, valid.astype(np.int32), in_box


def wavefront_search_pallas_batch(occ_packed, srcs, dsts, init_vecs, *,
                                  mesh: Mesh3D, n_slots: int,
                                  interpret: bool | None = None):
    """Batch contract of ``repro.core.slot_alloc.wavefront_search_batch``.

    occ_packed: (n, N_PORTS) uint32; srcs/dsts: (B,) int node ids;
    init_vecs: (B,) uint32.  Returns (B, n) packed busy vectors.
    """
    srcs = np.asarray(srcs)
    dsts = np.asarray(dsts)
    n = mesh.n_nodes
    B = srcs.shape[0]
    sign, valid, _ = _geometry(mesh, srcs, dsts)
    occ_planes = unpack_bits(jnp.asarray(occ_packed).T[:6], n_slots)
    fm = np.uint32(full_mask(n_slots))
    init_packed = np.full((B, n), fm, np.uint32)
    init_packed[np.arange(B), srcs] = np.asarray(init_vecs, np.uint32)
    init_planes = unpack_bits(jnp.asarray(init_packed), n_slots)
    out = wavefront_search_planes(
        jnp.asarray(sign), jnp.asarray(valid), init_planes, occ_planes,
        mesh_shape=(mesh.X, mesh.Y, mesh.Z), n_slots=n_slots,
        interpret=interpret)
    return pack_bits(out, n_slots)


def wavefront_search_pallas(occ, src, dst, init_vec, *, mesh: Mesh3D,
                            n_slots: int, interpret: bool | None = None):
    """Single-request contract of ``core.slot_alloc.wavefront_search``
    (drop-in for TdmAllocator(use_pallas=True))."""
    out = wavefront_search_pallas_batch(
        occ, np.asarray([int(src)]), np.asarray([int(dst)]),
        np.asarray([int(init_vec)], np.uint32), mesh=mesh, n_slots=n_slots,
        interpret=interpret)
    return out[0]
