"""Pallas TPU kernel for the paper's PE-matrix TDM slot allocator.

Hardware adaptation (DESIGN.md): the paper's accelerator is a mesh of
bit-serial PEs that rotate-and-OR n-bit busy vectors along all shortest
paths.  On TPU the layout is re-thought for the VPU/VMEM:

* busy vectors are int32 0/1 *bit-planes*: a (n_nodes, 128) tile with the
  slot index on the lane axis (n_slots <= 128; unused lanes held busy);
* "fetch from the upstream neighbour in dim d" is a *static roll* of the
  node axis by the linearized stride (sign-selected) — no gathers;
* the TDM rotate-right is a lane-axis roll restricted to the first
  n_slots lanes;
* per-dim output-port occupancy is a sign-selected static slice of the
  (6, n_nodes, 128) occupancy planes;
* OR = max, AND(converging paths) = min, on 0/1 ints;
* one program instance per request (grid over the batch): the CCU
  searches a whole batch of pending copy requests in one shot.

The fixed-point sweep runs ``max_dist`` times (the monotone lattice is a
DAG of that depth).  Oracle: ``ref.py`` (the packed-uint32 jnp search from
``repro.core.slot_alloc``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.interpret import resolve_interpret

LANES = 128


def _rotr_lanes(v: jax.Array, n_slots: int) -> jax.Array:
    """Rotate the first n_slots lanes right by one (TDM slot re-index)."""
    return jnp.concatenate(
        [v[:, n_slots - 1:n_slots], v[:, :n_slots - 1], v[:, n_slots:]],
        axis=1)


def _kernel(sign_ref, valid_ref, init_ref, occ_ref, out_ref,
            *, mesh_shape: tuple[int, int, int], n_slots: int):
    X, Y, Z = mesh_shape
    strides = (1, X, X * Y)
    occ = occ_ref[...]             # (6, n, LANES) int32 0/1
    sign = sign_ref[...]           # (1, 3)
    valid = valid_ref[0]           # (3, n) — upstream-exists mask per dim
    vec0 = init_ref[0]             # (n, LANES); src row = init bits, else 1
    ones = jnp.ones_like(vec0)
    # src rows keep their injected vector through every sweep (they are the
    # only rows with any free lane at init).
    src_row = vec0.min(axis=1, keepdims=True) == 0

    def body(_, vec):
        cand = ones
        for d in range(3):
            s = sign[0, d]
            occ_d = jnp.where(s < 0, occ[2 * d + 1], occ[2 * d])
            merged = jnp.maximum(vec, occ_d)          # OR busy bits
            up_p = jnp.roll(merged, strides[d], axis=0)
            up_m = jnp.roll(merged, -strides[d], axis=0)
            up = jnp.where(s > 0, up_p, jnp.where(s < 0, up_m, ones))
            c_d = _rotr_lanes(up, n_slots)
            c_d = jnp.maximum(c_d, 1 - valid[d][:, None])  # invalid: busy
            cand = jnp.minimum(cand, c_d)             # AND converging paths
        return jnp.where(src_row, vec0, cand)

    out = jax.lax.fori_loop(0, X + Y + Z - 3, body, vec0)
    out_ref[0] = out


@partial(jax.jit, static_argnames=("mesh_shape", "n_slots", "interpret"))
def wavefront_search_planes(sign: jax.Array, valid: jax.Array,
                            init: jax.Array, occ_planes: jax.Array,
                            *, mesh_shape: tuple[int, int, int],
                            n_slots: int,
                            interpret: bool | None = None) -> jax.Array:
    """Batched PE-matrix search on bit-planes.

    sign: (B, 3) int32; valid: (B, 3, n) int32 (upstream-exists per dim);
    init: (B, n, LANES) int32 (all-ones except the source row);
    occ_planes: (6, n, LANES) int32.  Returns (B, n, LANES) busy planes.
    """
    B, _, n = valid.shape
    kernel = partial(_kernel, mesh_shape=mesh_shape, n_slots=n_slots)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, 3), lambda b: (b, 0)),
            pl.BlockSpec((1, 3, n), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, n, LANES), lambda b: (b, 0, 0)),
            pl.BlockSpec((6, n, LANES), lambda b: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, LANES), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n, LANES), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(sign, valid, init, occ_planes)
