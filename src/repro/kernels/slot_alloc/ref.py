"""Pure-jnp oracle for the slot-allocator kernel: the packed-uint32
wavefront search from the core library (the paper-faithful implementation)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.slot_alloc import wavefront_search
from repro.core.topology import Mesh3D


def wavefront_search_ref_batch(occ_packed, srcs, dsts, init_vecs, *,
                               mesh: Mesh3D, n_slots: int):
    outs = []
    for s, d, iv in zip(np.asarray(srcs), np.asarray(dsts),
                        np.asarray(init_vecs)):
        outs.append(np.asarray(wavefront_search(
            jnp.asarray(occ_packed), jnp.int32(int(s)), jnp.int32(int(d)),
            jnp.uint32(int(iv)), mesh=mesh, n_slots=n_slots)))
    return np.stack(outs)
