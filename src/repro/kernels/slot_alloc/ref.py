"""Pure-host oracles for the slot-allocator kernels.

``wavefront_search_ref_batch`` is the packed-uint32 jnp search from the
core library (the paper-faithful implementation), evaluated one request
at a time.  ``fused_prepare_ref`` and ``slot_score_ref`` are the numpy
twins of the fused prepare program (``fused.fused_prepare``) — the
differential harness (``tests/test_fused_alloc.py``) holds the compiled
program bit-identical to these.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.slot_alloc import (_best_slots_np, _wavefront_host,
                                   traceback_batch, wavefront_search)
from repro.core.topology import PORT_LOCAL, Mesh3D

from .fused import FAR32, FusedPrepare


def wavefront_search_ref_batch(occ_packed, srcs, dsts, init_vecs, *,
                               mesh: Mesh3D, n_slots: int):
    outs = []
    for s, d, iv in zip(np.asarray(srcs), np.asarray(dsts),
                        np.asarray(init_vecs)):
        outs.append(np.asarray(wavefront_search(
            jnp.asarray(occ_packed), jnp.int32(int(s)), jnp.int32(int(d)),
            jnp.uint32(int(iv)), mesh=mesh, n_slots=n_slots)))
    return np.stack(outs)


def slot_score_ref(avail: np.ndarray, dists: np.ndarray,
                   t_readys: np.ndarray, n_slots: int) -> np.ndarray:
    """numpy twin of ``fused.slot_score_planes`` on packed uint32
    availability vectors: the (B, n_slots) int32 cost matrix."""
    slots = np.arange(n_slots, dtype=np.int64)
    free = ((avail.astype(np.int64)[:, None] >> slots[None]) & 1) == 0
    s_inj = (slots[None] - dists[:, None]) % n_slots
    c = t_readys[:, None] + ((s_inj - t_readys[:, None]) % n_slots)
    return np.where(free, c, np.int64(FAR32)).astype(np.int32)


def fused_prepare_ref(occ: np.ndarray, srcs, dsts, t_readys, *,
                      mesh: Mesh3D, n_slots: int) -> FusedPrepare:
    """Host oracle of ``fused.fused_prepare``: scalar topological
    wavefront, int64 slot choice, lockstep numpy trace-back."""
    srcs = np.asarray(srcs, np.int64)
    dsts = np.asarray(dsts, np.int64)
    t_readys = np.asarray(t_readys, np.int64)
    B = len(srcs)
    occ = np.asarray(occ, np.uint32)
    vecs = np.stack([_wavefront_host(occ, mesh, n_slots, int(s), int(d), 0)
                     for s, d in zip(srcs, dsts)]) if B else \
        np.zeros((0, mesh.n_nodes), np.uint32)
    coords = mesh.coord_array
    dists = np.abs(coords[srcs] - coords[dsts]).sum(1)
    avail = vecs[np.arange(B), dsts] | occ[dsts, PORT_LOCAL]
    starts, arr, free, denied = _best_slots_np(avail, dists, t_readys,
                                               n_slots)
    starts = np.where(denied, np.int64(FAR32), starts)  # int32-safe sentinel
    hop_n, hop_p, hop_s, _, ok = traceback_batch(
        vecs, np.arange(B), occ, mesh, n_slots, srcs, dsts, arr)
    L = mesh.max_dist + 1
    hn = np.zeros((B, L), np.int32)
    hp = np.zeros((B, L), np.int32)
    hs = np.zeros((B, L), np.int32)
    hn[:, :hop_n.shape[1]] = hop_n
    hp[:, :hop_p.shape[1]] = hop_p
    hs[:, :hop_s.shape[1]] = hop_s
    return FusedPrepare(
        starts=starts.astype(np.int32), arr=arr.astype(np.int32),
        denied=denied, free=free, hop_n=hn, hop_p=hp, hop_s=hs, ok=ok,
        dists=dists.astype(np.int32), _vecs_dev=None, _batch=B)
