from . import ops, ref
from .slot_alloc import wavefront_search_planes

__all__ = ["ops", "ref", "wavefront_search_planes"]
