from . import fused, ops, ref
from .fused import (fused_prepare, fused_prepare_program, fused_prepare_start,
                    fused_prepare_wait, slot_score_planes)
from .slot_alloc import wavefront_search_planes

__all__ = ["fused", "ops", "ref", "wavefront_search_planes",
           "fused_prepare", "fused_prepare_program", "fused_prepare_start",
           "fused_prepare_wait", "slot_score_planes"]
