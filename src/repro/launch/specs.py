"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No device allocation — the dry-run lowers against these (the
shannon/kernels pattern): weak-type-correct, shardable specs for tokens,
stub-frontend embeddings, KV/recurrent caches, and the train state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ArchConfig
from repro.models.common import COMPUTE_DTYPE
from repro.models.lm import make_model
from repro.optim import adamw
from repro.parallel.sharding import (ShardingRules, default_rules,
                                     spec_for_cache, tree_cache_shardings,
                                     tree_param_shardings)

SDS = jax.ShapeDtypeStruct


def dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _dp_for(batch: int, mesh, rules: ShardingRules):
    """Batch mesh axes, dropped when the batch doesn't divide (e.g. the
    batch=1 long-context decode leaves the data axis to the KV sequence)."""
    dp = rules.act_axis("batch")
    if dp is None:
        return None
    axes = (dp,) if isinstance(dp, str) else tuple(dp)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return dp if total and batch % total == 0 else None


def batch_specs(cfg: ArchConfig, shape_name: str, mesh,
                rules: ShardingRules):
    """Token/frontend input specs for train/prefill."""
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    dp = _dp_for(b, mesh, rules)
    tok = SDS((b, s), jnp.int32, sharding=NamedSharding(mesh, P(dp, None)))
    out = {"tokens": tok}
    if cfg.arch_type == "encdec":
        out["enc_emb"] = SDS((b, cfg.enc_seq, cfg.d_model), COMPUTE_DTYPE,
                             sharding=NamedSharding(mesh, P(dp, None, None)))
    if cfg.arch_type == "vlm":
        out["prefix_emb"] = SDS((b, cfg.enc_seq, cfg.d_model), COMPUTE_DTYPE,
                                sharding=NamedSharding(mesh,
                                                       P(dp, None, None)))
    return out


def model_state_specs(cfg: ArchConfig, mesh, rules: ShardingRules,
                      with_opt: bool = True):
    """(state_sds, state_shardings) via eval_shape — zero allocation."""
    model = make_model(cfg)
    p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    axes = model.axes()
    p_sh = tree_param_shardings(mesh, rules, axes, p_shapes)
    if not with_opt:
        return model, p_shapes, p_sh
    opt_shapes = {"m": p_shapes, "v": p_shapes,
                  "count": SDS((), jnp.int32)}
    opt_sh = {"m": p_sh, "v": p_sh,
              "count": NamedSharding(mesh, P())}
    return model, {"params": p_shapes, "opt_state": opt_shapes,
                   "step": SDS((), jnp.int32)}, \
        {"params": p_sh, "opt_state": opt_sh,
         "step": NamedSharding(mesh, P())}


def cache_specs(cfg: ArchConfig, shape_name: str, mesh,
                rules: ShardingRules):
    """(cache_sds, cache_shardings) for decode shapes."""
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    model = make_model(cfg)
    c_shapes = jax.eval_shape(lambda: model.init_caches(b, s))
    c_axes = model.cache_axes()
    c_sh = tree_cache_shardings(mesh, rules, c_axes, c_shapes)
    return c_shapes, c_sh


def serve_input_specs(cfg: ArchConfig, shape_name: str, mesh,
                      rules: ShardingRules):
    sh = SHAPES[shape_name]
    b = sh["batch"]
    dp = _dp_for(b, mesh, rules)
    tok = SDS((b, 1), jnp.int32, sharding=NamedSharding(mesh, P(dp, None)))
    pos = SDS((), jnp.int32, sharding=NamedSharding(mesh, P()))
    extras = {}
    if cfg.arch_type == "encdec":
        extras["memory"] = SDS((b, cfg.enc_seq, cfg.d_model), COMPUTE_DTYPE,
                               sharding=NamedSharding(mesh,
                                                      P(dp, None, None)))
    return tok, pos, extras
