"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (device counts are locked at first jax init — see dryrun.py, which
must set XLA_FLAGS before any jax import).

``make_mesh`` is the version-portable helper every mesh construction in
the repo (launchers, tests, examples) must go through: the pinned offline
toolchain is JAX 0.4.37, which has neither ``axis_types`` nor
``jax.sharding.set_mesh`` (see repro.parallel.compat)."""
from __future__ import annotations

import jax

from repro.parallel.compat import (abstract_mesh, make_mesh,
                                   set_ambient_mesh)

__all__ = ["abstract_mesh", "make_mesh", "make_host_mesh",
           "make_production_mesh", "set_ambient_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2 pods x 256 = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever the current process actually has (tests / examples)."""
    n = len(jax.devices())
    return make_mesh((n // model_axis, model_axis), ("data", "model"))
