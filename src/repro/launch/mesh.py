"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (device counts are locked at first jax init — see dryrun.py, which
must set XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2 pods x 256 = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_axis: int = 1):
    """Whatever the current process actually has (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh(
        (n // model_axis, model_axis), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
