"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``."""
import argparse

import jax

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_mesh, set_ambient_mesh
from repro.models import make_model
from repro.serving import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    set_ambient_mesh(mesh)
    cfg = get_config(args.arch, smoke=args.smoke)
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, cfg, max_len=args.prompt_len + args.new_tokens + 8)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    memory = None
    if cfg.arch_type == "encdec":
        memory = model.encode(params, jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.enc_seq, cfg.d_model)))
    out = eng.generate(params, prompt, args.new_tokens, memory=memory)
    print(f"[serve] arch={cfg.name} generated {out.shape}")
    print(out[:, args.prompt_len:])


if __name__ == "__main__":
    main()
