"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

On this CPU container use ``--smoke`` (reduced config); on a real pod the
same driver runs the full config under ``make_production_mesh()``.
"""
import argparse

import jax

from repro.configs import ARCHS, get_config
from repro.data import DataConfig
from repro.launch.mesh import make_mesh, set_ambient_mesh
from repro.models import count_params, make_model
from repro.optim.adamw import AdamWConfig
from repro.parallel.context import set_ctx
from repro.train import LoopConfig, TrainState, make_train_step, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev // args.model_axis, args.model_axis),
                     ("data", "model"))
    set_ambient_mesh(mesh)
    cfg = get_config(args.arch, smoke=args.smoke)
    set_ctx(mesh=mesh, dp=("data",), tp="model",
            cp_attention=bool(cfg.n_heads
                              and cfg.n_heads % args.model_axis))
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[train] arch={cfg.name} params={count_params(params):,} "
          f"mesh={dict(mesh.shape)}")
    state = TrainState.create(params)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                      total_steps=args.steps)
    step = jax.jit(make_train_step(model, cfg, opt,
                                   microbatches=args.microbatches,
                                   cast_bf16_gather=True),
                   donate_argnums=(0,))
    data = DataConfig(vocab=cfg.vocab, batch=args.batch, seq=args.seq)

    def extra(step_i):
        import jax.numpy as jnp
        out = {}
        if cfg.arch_type == "encdec":
            out["enc_emb"] = jax.random.normal(
                jax.random.PRNGKey(step_i), (args.batch, cfg.enc_seq,
                                             cfg.d_model), jnp.bfloat16)
        if cfg.arch_type == "vlm":
            out["prefix_emb"] = jax.random.normal(
                jax.random.PRNGKey(step_i), (args.batch, cfg.enc_seq,
                                             cfg.d_model), jnp.bfloat16)
        return out

    loop = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir)
    state, hist = train_loop(step, state, data, loop,
                             extra_batch_fn=extra
                             if cfg.arch_type != "decoder" else None)
    print(f"[train] done: loss {hist[0]['loss']:.4f} -> "
          f"{hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
