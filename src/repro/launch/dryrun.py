import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production mesh and extract the roofline terms.

MUST be run as its own process (``python -m repro.launch.dryrun``): the
XLA_FLAGS line above executes before any jax import so the 512 placeholder
devices exist; smoke tests and benches run in normal processes and see 1
device.

Per cell this prints/records:
  * compiled.memory_analysis()  — proves the state fits per device,
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective bytes parsed from the optimized HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute),
  * the three roofline terms for TPU v5e constants.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cells, get_config
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import default_rules
from repro.train.state import (TrainState, make_prefill_step,
                               make_serve_step, make_train_step)

# --- TPU v5e roofline constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device link-bytes by collective kind.

    Weights: all-reduce 2x operand (bidirectional ring), others 1x
    result/operand.  CPU-backend correction: XLA promotes bf16 all-reduce
    accumulation to f32 on host backends (``to_apply=%add..._promoted``) —
    on a real TPU those reductions move bf16, so promoted all-reduces are
    counted at half their f32 size (documented in EXPERIMENTS.md)."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "total": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        w = 2.0 if kind == "all-reduce" else 1.0
        if kind == "all-reduce" and "promoted" in line:
            w *= 0.5
        out[kind] += int(w * b)
        out["total"] += int(w * b)
    return out


def _cost(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def _memory(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ["generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes",
            "alias_size_in_bytes", "peak_memory_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    seconds: float
    error: str = ""
    flops: float = 0.0
    hlo_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    memory: dict = dataclasses.field(default_factory=dict)
    terms: dict = dataclasses.field(default_factory=dict)
    model_flops: float = 0.0


def roofline_terms(flops, hbm_bytes, coll_bytes, score_bytes=0.0):
    t = {"compute_s": flops / PEAK_FLOPS,
         "memory_s": hbm_bytes / HBM_BW,
         "collective_s": coll_bytes / ICI_BW}
    # Kernel-adjusted memory: the validated Pallas flash kernel holds
    # attention score tiles in VMEM; the XLA twin (used for CPU lowering)
    # streams them through HBM.  score_bytes is the analytic estimate of
    # that double-counted traffic (EXPERIMENTS.md §Perf H8).
    t["memory_s_kernel_adj"] = max(hbm_bytes - score_bytes, 0.0) / HBM_BW
    return t


def attention_score_bytes(cfg, shape, mesh) -> float:
    """Per-device HBM bytes the XLA-scan attention spends on score
    tensors (fwd + remat fwd + bwd ~ 4 passes), which the Pallas kernel
    keeps in VMEM.  Causal full attention halves the visited area."""
    if shape["kind"] == "decode" or not cfg.n_heads:
        return 0.0
    b_loc = max(shape["batch"] // (mesh.shape.get("data", 1)
                                   * mesh.shape.get("pod", 1)), 1)
    s = shape["seq"]
    passes = 4 if shape["kind"] == "train" else 1
    total = 0.0
    for k in cfg.pattern:
        if k.mixer != "attn":
            continue
        s_eff = min(s, (k.window + 512)) if k.window else s * 0.5
        # f32 logits + compute-dtype probs ~ 6 bytes per score element
        total += b_loc * cfg.n_heads * s * s_eff * 6 * passes
    total *= cfg.n_layers / len(cfg.pattern)
    if cfg.arch_type == "encdec":
        total += (b_loc * cfg.n_heads * cfg.enc_seq * cfg.enc_seq * 6
                  * passes * cfg.enc_layers)
    return total


def lower_group_cost(cfg, shape_name: str, mesh, rules, kind: str,
                     cast_bf16: bool = False):
    """HLO-measure ONE scan-group body (XLA cost_analysis counts while-loop
    bodies once, so per-cell totals are composed as
    full + (n_groups - 1) * group_body; see EXPERIMENTS.md §Dry-run)."""
    from repro.models.blocks import LayerStack
    from repro.models.common import COMPUTE_DTYPE
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    stack = LayerStack(cfg, len(cfg.pattern),
                       with_cross=cfg.arch_type == "encdec")
    gp_shapes = jax.eval_shape(stack.init, jax.random.PRNGKey(0))
    gp_sh = jax.tree.map(lambda _: None, gp_shapes)
    from repro.parallel.sharding import tree_param_shardings
    gp_sh = tree_param_shardings(mesh, rules, stack.axes(), gp_shapes)
    dp = S._dp_for(b, mesh, rules)
    if kind == "decode":
        x_sds = SDSX((b, 1, cfg.d_model), COMPUTE_DTYPE,
                     NamedSharding(mesh, P(dp, None, None)))
        c_shapes = jax.eval_shape(lambda: stack.init_caches(b, s))
        from repro.parallel.sharding import tree_cache_shardings
        c_sh = tree_cache_shardings(mesh, rules, stack.cache_axes(),
                                    c_shapes)
        pos = SDSX((), jnp.int32, NamedSharding(mesh, P()))
        mem_args, mem_sh = (), ()
        if cfg.arch_type == "encdec":
            mem_args = (SDSX((b, cfg.enc_seq, cfg.d_model), COMPUTE_DTYPE,
                             NamedSharding(mesh, P(dp, None, None))),)
            mem_sh = (mem_args[0].sharding,)

        def fn(p, x, c, pos, *mem):
            return stack.decode(p, x, c, pos,
                                memory=mem[0] if mem else None)
        jitted = jax.jit(fn, in_shardings=(gp_sh, x_sds.sharding, c_sh,
                                           pos.sharding, *mem_sh),
                         donate_argnums=(2,))
        lowered = jitted.lower(gp_shapes, x_sds, c_shapes, pos, *mem_args)
    else:
        seq = cfg.enc_seq if False else s
        x_sds = SDSX((b, s, cfg.d_model), COMPUTE_DTYPE,
                     NamedSharding(mesh, P(dp, None, None)))
        mem_args, mem_sh = (), ()
        if cfg.arch_type == "encdec":
            mem_args = (SDSX((b, cfg.enc_seq, cfg.d_model), COMPUTE_DTYPE,
                             NamedSharding(mesh, P(dp, None, None))),)
            mem_sh = (mem_args[0].sharding,)

        def group_apply(p, x, *mem):
            if cast_bf16:
                p = jax.tree.map(
                    lambda v: v.astype(COMPUTE_DTYPE)
                    if v.dtype == jnp.float32 else v, p)
            y, aux = stack.apply(p, x, memory=mem[0] if mem else None,
                                 remat=True)
            return y, aux

        if kind == "train":
            def fn(p, x, *mem):
                def loss(p):
                    y, aux = group_apply(p, x, *mem)
                    return jnp.sum(y.astype(jnp.float32)) * 0 + \
                        jnp.mean(jnp.square(y.astype(jnp.float32))) + aux
                return jax.grad(loss)(p)
        else:
            fn = group_apply
        jitted = jax.jit(fn, in_shardings=(gp_sh, x_sds.sharding, *mem_sh))
        lowered = jitted.lower(gp_shapes, x_sds, *mem_args)
    comp = lowered.compile()
    ca = _cost(comp)
    coll = collective_bytes(comp.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)), coll)


def SDSX(shape, dtype, sharding):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               dispatch: str | None = None, fsdp: bool | None = None,
               remat: bool = True, microbatches: int = 1,
               compose_groups: bool = True,
               cast_bf16: bool = False) -> CellResult:
    t0 = time.time()
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    try:
        cfg = get_config(arch)
        if dispatch is not None and cfg.n_experts:
            cfg = dataclasses.replace(cfg, moe_dispatch=dispatch)
        sh = SHAPES[shape_name]
        mesh = make_production_mesh(multi_pod=multi_pod)
        kv_seq_axis = None
        if shape_name == "long_500k":
            kv_seq_axis = "data"
        elif sh["kind"] == "decode" and cfg.n_kv \
                and cfg.n_kv % mesh.shape["model"] != 0:
            kv_seq_axis = "model"
        use_fsdp = cfg.fsdp if fsdp is None else fsdp
        rules = default_rules(mesh, fsdp=use_fsdp, kv_seq_axis=kv_seq_axis)
        from repro.parallel.compat import set_ambient_mesh
        set_ambient_mesh(mesh)   # ambient mesh for shard_map(MoE)
        from repro.parallel.context import set_ctx
        tp_size = mesh.shape["model"]
        set_ctx(mesh=mesh,
                dp=tuple(a for a in ("pod", "data") if a in mesh.axis_names),
                tp="model",
                cp_attention=bool(cfg.n_heads and cfg.n_heads % tp_size),
                seq_parallel=bool(int(os.environ.get("REPRO_SP", "0"))))
        kind = sh["kind"]
        if kind == "train":
            model, st_sds, st_sh = S.model_state_specs(cfg, mesh, rules)
            state_sds = TrainState(params=st_sds["params"],
                                   opt_state=st_sds["opt_state"],
                                   step=st_sds["step"])
            state_sh = TrainState(params=st_sh["params"],
                                  opt_state=st_sh["opt_state"],
                                  step=st_sh["step"])
            binp = S.batch_specs(cfg, shape_name, mesh, rules)
            b_sh = jax.tree.map(lambda s: s.sharding, binp)
            step_fn = make_train_step(
                model, cfg, AdamWConfig(), microbatches=microbatches,
                cast_bf16_gather=cast_bf16,
                param_shardings=st_sh["params"] if cast_bf16 else None)
            rep = NamedSharding(mesh, P())
            metrics_sh = {"loss": rep, "grad_norm": rep, "lr": rep,
                          "finite": rep}
            jitted = jax.jit(step_fn,
                             in_shardings=(state_sh, b_sh),
                             out_shardings=(state_sh, metrics_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_sds, binp)
        elif kind == "prefill":
            model, p_sds, p_sh = S.model_state_specs(cfg, mesh, rules,
                                                     with_opt=False)
            binp = S.batch_specs(cfg, shape_name, mesh, rules)
            step_fn = make_prefill_step(model, cfg)
            args = [binp["tokens"]]
            arg_sh = [binp["tokens"].sharding]
            if cfg.arch_type == "encdec":
                args.append(binp["enc_emb"])
                arg_sh.append(binp["enc_emb"].sharding)
            elif cfg.arch_type == "vlm":
                args.append(binp["prefix_emb"])
                arg_sh.append(binp["prefix_emb"].sharding)
            dp = S._dp_for(sh["batch"], mesh, rules)
            out_sh = NamedSharding(mesh, P(dp, None, "model"))
            jitted = jax.jit(step_fn, in_shardings=(p_sh, *arg_sh),
                             out_shardings=out_sh)
            lowered = jitted.lower(p_sds, *args)
        else:  # decode
            model, p_sds, p_sh = S.model_state_specs(cfg, mesh, rules,
                                                     with_opt=False)
            c_sds, c_sh = S.cache_specs(cfg, shape_name, mesh, rules)
            tok, pos, extras = S.serve_input_specs(cfg, shape_name, mesh,
                                                   rules)
            step_fn = make_serve_step(model, cfg)
            dp = S._dp_for(sh["batch"], mesh, rules)
            logits_sh = NamedSharding(mesh, P(dp, None, "model"))
            in_sh = [p_sh, tok.sharding, c_sh, pos.sharding]
            args = [p_sds, tok, c_sds, pos]
            if cfg.arch_type == "encdec":
                in_sh.append(extras["memory"].sharding)
                args.append(extras["memory"])
            jitted = jax.jit(step_fn, in_shardings=tuple(in_sh),
                             out_shardings=(logits_sh, c_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(*args)

        compiled = lowered.compile()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        ca = _cost(compiled)
        flops = float(ca.get("flops", 0.0))
        byts = float(ca.get("bytes accessed", 0.0))
        mem = _memory(compiled)
        # Compose scan-body costs: XLA counts while-loop bodies once.
        n_groups = cfg.n_layers // len(cfg.pattern)
        extra_reps = max(0, n_groups - 1)
        if cfg.arch_type == "encdec" and kind != "decode":
            extra_reps += max(0, cfg.enc_layers // len(cfg.pattern) - 1)
        if compose_groups and extra_reps:
            gf, gb, gc = lower_group_cost(cfg, shape_name, mesh, rules,
                                          kind, cast_bf16=cast_bf16)
            flops += extra_reps * gf
            byts += extra_reps * gb
            for k in coll:
                coll[k] += extra_reps * gc.get(k, 0)
        terms = roofline_terms(flops, byts, coll["total"],
                               attention_score_bytes(cfg, sh, mesh))
        # MODEL_FLOPS: 6*N_active*D (D = tokens for train; batch for decode)
        n_act = cfg.active_param_count_estimate()
        d_tokens = (sh["batch"] * sh["seq"] if kind != "decode"
                    else sh["batch"])
        model_flops = (6 if kind == "train" else 2) * n_act * d_tokens
        return CellResult(arch=arch, shape=shape_name, mesh=mesh_tag,
                          ok=True, seconds=time.time() - t0, flops=flops,
                          hlo_bytes=byts, collectives=coll, memory=mem,
                          terms=terms, model_flops=model_flops)
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        return CellResult(arch=arch, shape=shape_name, mesh=mesh_tag,
                          ok=False, seconds=time.time() - t0,
                          error=f"{type(e).__name__}: {e}\n"
                          + traceback.format_exc()[-2000:])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--dispatch", default=None,
                    choices=[None, "nom", "xla", "einsum"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--cast-bf16", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    todo = []
    if args.all:
        for arch, shape, skip in cells():
            todo.append((arch, shape))
    else:
        todo.append((args.arch, args.shape))
    meshes = [True, False] if args.both_meshes else [args.multipod]

    for arch, shape in todo:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            if args.dispatch:
                tag += f"__{args.dispatch}"
            if args.cast_bf16:
                tag += "__bf16g"
            if args.tag:
                tag += f"__{args.tag}"
            path = os.path.join(args.out, tag + ".json")
            if args.resume and os.path.exists(path):
                print(f"[skip] {tag}")
                continue
            print(f"[lower+compile] {tag} ...", flush=True)
            res = lower_cell(arch, shape, mp, dispatch=args.dispatch,
                             fsdp=None if not args.no_fsdp else False,
                             microbatches=args.microbatches,
                             cast_bf16=args.cast_bf16)
            with open(path, "w") as f:
                json.dump(dataclasses.asdict(res), f, indent=1)
            if res.ok:
                t = res.terms
                print(f"  OK {res.seconds:.0f}s flops={res.flops:.3e} "
                      f"bytes={res.hlo_bytes:.3e} "
                      f"coll={res.collectives['total']:.3e} | "
                      f"compute={t['compute_s']*1e3:.2f}ms "
                      f"memory={t['memory_s']*1e3:.2f}ms "
                      f"collective={t['collective_s']*1e3:.2f}ms",
                      flush=True)
                if res.memory:
                    print(f"  memory_analysis: {res.memory}", flush=True)
            else:
                print(f"  FAIL {res.seconds:.0f}s {res.error.splitlines()[0] if res.error else ''}",
                      flush=True)


if __name__ == "__main__":
    main()
