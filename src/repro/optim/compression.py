"""Gradient compression for the data-parallel axis: int8 quantization with
error feedback (1-bit-Adam-family trick), exposed as a ``compressed_psum``
for shard_map DP loops and tested for contraction of the residual."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g: jax.Array, residual: jax.Array):
    """Error-feedback compression: quantize (g + residual); the rounding
    error becomes the next residual — guarantees the accumulated error
    stays bounded (contraction)."""
    x = g.astype(jnp.float32) + residual
    q, scale = quantize_int8(x)
    deq = dequantize_int8(q, scale)
    return q, scale, x - deq


def compressed_psum(g: jax.Array, residual: jax.Array, axis_name: str):
    """int8 all-reduce with error feedback. Returns (mean_g, new_residual).

    Inside shard_map: each rank quantizes locally, psums the int32-cast
    payload (bandwidth model: 1/4 of fp32), dequantizes with the psum'd
    scale."""
    q, scale, new_res = compress_with_feedback(g, residual)
    n = lax.psum(1, axis_name)
    summed = lax.psum(q.astype(jnp.int32) * 1, axis_name).astype(jnp.float32)
    scale_sum = lax.psum(scale, axis_name)
    # Use the mean scale (per-rank scales differ slightly).
    mean = summed * (scale_sum / n) / n
    return mean, new_res


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
