from . import adamw, compression
from .adamw import AdamWConfig, global_norm, schedule

__all__ = ["adamw", "compression", "AdamWConfig", "global_norm", "schedule"]
