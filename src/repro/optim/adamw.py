"""AdamW with global-norm clipping and warmup-cosine schedule (no optax
dependency — the optimizer is part of the substrate)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(np.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


def init(params) -> dict:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros(), "v": zeros(), "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    count = opt_state["count"] + 1
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m_new / b1c
        vh = v_new / b2c
        step = lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                     + cfg.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - step).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
