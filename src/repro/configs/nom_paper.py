"""The paper's own evaluation configuration (Section 3) — the memory
system rather than an LM architecture, so it lives beside ARCHS rather
than in it.  Used by memsim defaults, quickstart, and the benchmarks."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class NomSystemConfig:
    # geometry: 4GB HMC-like stack, 32 vaults, 4 DRAM layers, 2 banks/slice
    mesh_x: int = 8
    mesh_y: int = 8
    mesh_z: int = 4              # => 256 banks, topology 8x8x4
    vault_span_y: int = 2        # 32 vaults, 8 banks each
    # TDM circuit switching
    n_slots: int = 16            # 16-slot windows
    link_bits: int = 64          # internal datapath width
    setup_cycles: int = 3        # find path / program tables / issue read
    # clocks
    logic_ghz: float = 1.25
    nom_link_ghz: float = 1.25   # scaled in the frequency experiments
    # sideband slot-table programming bus (Section 2.3): 12 bits =
    # 3 (bank) + 4 (slot) + 6 (in/out ports) per vault per cycle
    sideband_bits: int = 12


PAPER_SYSTEM = NomSystemConfig()
