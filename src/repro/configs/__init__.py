"""Architecture registry: the 10 assigned architectures + the paper's own
NoM memory-system config.  ``--arch <id>`` resolves through ARCHS."""
from __future__ import annotations

from . import (command_r_plus, gemma3_27b, mamba2_130m, paligemma_3b,
               phi35_moe, qwen15_4b, qwen25_32b, qwen3_moe,
               recurrentgemma_9b, whisper_small)
from .base import ArchConfig, LayerKind

ARCHS = {
    "whisper-small": whisper_small,
    "phi3.5-moe-42b-a6.6b": phi35_moe,
    "qwen3-moe-235b-a22b": qwen3_moe,
    "recurrentgemma-9b": recurrentgemma_9b,
    "mamba2-130m": mamba2_130m,
    "qwen2.5-32b": qwen25_32b,
    "qwen1.5-4b": qwen15_4b,
    "command-r-plus-104b": command_r_plus,
    "gemma3-27b": gemma3_27b,
    "paligemma-3b": paligemma_3b,
}

# The four assigned input shapes (seq_len, global_batch, kind).
SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32_768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32_768, batch=128, kind="decode"),
    "long_500k": dict(seq=524_288, batch=1, kind="decode"),
}


def get_config(arch: str, smoke: bool = False) -> ArchConfig:
    mod = ARCHS[arch]
    return mod.smoke_config() if smoke else mod.config()


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells with skip annotations."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape, spec in SHAPES.items():
            skip = None
            if shape == "long_500k" and not cfg.sub_quadratic:
                skip = "pure full-attention arch (see DESIGN.md skips)"
            if skip is None or include_skipped:
                out.append((arch, shape, skip))
    return out


__all__ = ["ARCHS", "SHAPES", "ArchConfig", "LayerKind", "get_config",
           "cells"]
