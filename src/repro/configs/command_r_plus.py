"""command-r-plus-104b [dense] — 64L d_model=12288 96H (kv=8, head_dim=128)
d_ff=33792, vocab=256000, no bias, tied embeddings.
[hf:CohereForAI/c4ai-command-r family]

Adaptation note: Cohere's parallel attn+FFN block is implemented as the
standard sequential residual block (see DESIGN.md §arch)."""
from .base import ArchConfig, LayerKind


def config() -> ArchConfig:
    return ArchConfig(
        name="command-r-plus-104b", family="dense",
        n_layers=64, d_model=12_288, n_heads=96, n_kv=8, head_dim=128,
        d_ff=33_792, vocab=256_000, pattern=(LayerKind("attn"),),
        fsdp=True,
        tie_embeddings=True, rope_theta=75_000_000.0, use_rope=True,
        max_seq=131_072, sub_quadratic=False)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="command-r-plus-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv=2, head_dim=8,
        d_ff=128, vocab=256, pattern=(LayerKind("attn"),),
        tie_embeddings=True, max_seq=128, sub_quadratic=False)
