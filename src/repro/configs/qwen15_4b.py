"""qwen1.5-4b [dense] — 40L d_model=2560 20H (kv=20) d_ff=6912,
vocab=151936, QKV bias.  [hf:Qwen/Qwen1.5 family]"""
from .base import ArchConfig, LayerKind


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-4b", family="dense",
        n_layers=40, d_model=2560, n_heads=20, n_kv=20, head_dim=128,
        d_ff=6912, vocab=151_936, pattern=(LayerKind("attn"),),
        qkv_bias=True, tie_embeddings=False, max_seq=32_768,
        sub_quadratic=False)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=128, vocab=256, pattern=(LayerKind("attn"),),
        qkv_bias=True, tie_embeddings=False, max_seq=128,
        sub_quadratic=False)
