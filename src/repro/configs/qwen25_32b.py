"""qwen2.5-32b [dense] — 64L d_model=5120 40H (kv=8, head_dim=128)
d_ff=27648, vocab=152064, QKV bias.  [hf:Qwen/Qwen2.5 family]"""
from .base import ArchConfig, LayerKind


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv=8, head_dim=128,
        d_ff=27_648, vocab=152_064, pattern=(LayerKind("attn"),),
        fsdp=True,
        qkv_bias=True, tie_embeddings=False, rope_theta=1_000_000.0,
        max_seq=131_072, sub_quadratic=False)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2.5-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256, pattern=(LayerKind("attn"),),
        qkv_bias=True, tie_embeddings=False, max_seq=128,
        sub_quadratic=False)
