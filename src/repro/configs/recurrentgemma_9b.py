"""recurrentgemma-9b [hybrid] — 38L d_model=4096, RG-LRU + local attention
1:2 (pattern rec,rec,attn window=2048), 16H MQA (kv=1), d_ff=12288 GeGLU,
vocab=256000.  [arXiv:2402.19427]"""
from .base import ArchConfig, LayerKind


def config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv=1, head_dim=256,
        d_ff=12_288, vocab=256_000,
        pattern=(LayerKind("rglru"), LayerKind("rglru"),
                 LayerKind("attn", window=2048)),
        lru_width=4096, zero_centered_norm=True, scale_embed_sqrt_d=True,
        act="gelu_tanh", tie_embeddings=True, max_seq=1 << 20,
        sub_quadratic=True)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv=1, head_dim=16,
        d_ff=128, vocab=256,
        pattern=(LayerKind("rglru"), LayerKind("rglru"),
                 LayerKind("attn", window=32)),
        lru_width=64, zero_centered_norm=True, scale_embed_sqrt_d=True,
        act="gelu_tanh", tie_embeddings=True, max_seq=256,
        sub_quadratic=True)
