"""paligemma-3b [vlm] — 18L gemma backbone d_model=2048 8H (kv=1,
head_dim=256) d_ff=16384 vocab=257216; SigLIP frontend STUBBED as 256
precomputed patch embeddings forming a bidirectional prefix (prefix-LM).
[arXiv:2407.07726]"""
from .base import ArchConfig, LayerKind


def config() -> ArchConfig:
    return ArchConfig(
        name="paligemma-3b", family="vlm", arch_type="vlm",
        n_layers=18, d_model=2048, n_heads=8, n_kv=1, head_dim=256,
        d_ff=16_384, vocab=257_216, pattern=(LayerKind("attn"),),
        enc_seq=256, zero_centered_norm=True, scale_embed_sqrt_d=True,
        act="gelu_tanh", tie_embeddings=True, max_seq=8192,
        sub_quadratic=False)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="paligemma-smoke", family="vlm", arch_type="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv=1, head_dim=16,
        d_ff=128, vocab=256, pattern=(LayerKind("attn"),),
        enc_seq=8, zero_centered_norm=True, scale_embed_sqrt_d=True,
        act="gelu_tanh", tie_embeddings=True, max_seq=128,
        sub_quadratic=False)
