"""whisper-small [audio] — enc-dec, conv frontend stubbed (precomputed
1500-frame embeddings). 12L/12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865.  [arXiv:2212.04356]

Adaptation note (DESIGN.md §arch): learned/sinusoidal positions are
substituted with RoPE on the backbone (parameter-neutral stand-in)."""
from .base import ArchConfig, LayerKind


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-small", family="audio", arch_type="encdec",
        n_layers=12, enc_layers=12, enc_seq=1500,
        d_model=768, n_heads=12, n_kv=12, d_ff=3072, vocab=51865,
        pattern=(LayerKind("attn"),),
        norm_type="layer", act="gelu", gated_mlp=False, mlp_bias=True,
        qkv_bias=True, tie_embeddings=True, max_seq=32_768,
        sub_quadratic=False)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="whisper-small-smoke", family="audio", arch_type="encdec",
        n_layers=2, enc_layers=2, enc_seq=16,
        d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=256,
        pattern=(LayerKind("attn"),),
        norm_type="layer", act="gelu", gated_mlp=False, mlp_bias=True,
        qkv_bias=True, tie_embeddings=True, max_seq=128,
        sub_quadratic=False)
