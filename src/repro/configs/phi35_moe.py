"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (kv=8) MoE 16e top-2,
expert d_ff=6400, vocab=32064.  [hf:microsoft/Phi-3.5-MoE-instruct]"""
from .base import ArchConfig, LayerKind


def config() -> ArchConfig:
    return ArchConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=6400,
        vocab=32064, pattern=(LayerKind("attn", ffn="moe"),),
        fsdp=True,
        n_experts=16, top_k=2, moe_dff=6400, tie_embeddings=False,
        max_seq=131_072, sub_quadratic=False)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="phi3.5-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=256, pattern=(LayerKind("attn", ffn="moe"),),
        n_experts=4, top_k=2, moe_dff=128, tie_embeddings=False,
        moe_dispatch="einsum", max_seq=128, sub_quadratic=False)
