"""Architecture config schema shared by all 10 assigned architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LayerKind:
    """One position in the repeating layer pattern."""
    mixer: str = "attn"          # attn | ssm | rglru
    window: int | None = None    # sliding-window size for local attention
    rope_theta: float | None = None   # override per layer kind (gemma3 global)
    ffn: str = "mlp"             # mlp | moe | none


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    pattern: tuple[LayerKind, ...] = (LayerKind(),)

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    logit_softcap: float | None = None
    rope_theta: float = 10_000.0
    use_rope: bool = True

    # norms / activations
    norm_type: str = "rms"       # rms | layer
    zero_centered_norm: bool = False   # gemma (1+g) RMSNorm
    post_norms: bool = False     # gemma3 sandwich norms
    act: str = "silu"
    gated_mlp: bool = True
    mlp_bias: bool = False

    # embeddings
    tie_embeddings: bool = True
    scale_embed_sqrt_d: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0
    norm_topk: bool = True
    moe_dispatch: str = "nom"    # nom | xla | einsum

    # SSM / RG-LRU
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    lru_width: int | None = None

    # structure
    # Shard params/optimizer over the data axis too (ZeRO-3 analogue) —
    # required when 12 bytes/param does not fit 16-way TP alone (>~20B).
    fsdp: bool = False

    arch_type: str = "decoder"   # decoder | encdec | vlm
    enc_layers: int = 0
    enc_seq: int = 0             # stub frontend length (whisper 1500 frames,
                                 # paligemma 256 patches)
    max_seq: int = 131_072
    sub_quadratic: bool = False  # eligible for long_500k

    # vocab padding: embedding/LM-head tables are padded so the vocab dim
    # shards evenly over the model axis (MaxText-style); targets never hit
    # pad ids, the softmax simply carries dead classes.
    pad_vocab_multiple: int = 256

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def param_count_estimate(self) -> int:
        """Rough 6N sanity numbers for MODEL_FLOPS (see EXPERIMENTS.md)."""
        hd = self.resolved_head_dim
        attn = self.d_model * hd * (2 * self.n_heads + 2 * self.n_kv)
        mlp = self.d_model * self.d_ff * (3 if self.gated_mlp else 2)
        moe = (self.d_model * self.moe_dff * 3 * self.n_experts
               + self.d_model * self.n_experts) if self.n_experts else 0
        per_layer = 0
        for k in self.pattern:
            if k.mixer == "attn":
                per_layer += attn
            elif k.mixer == "ssm":
                d_in = 2 * self.d_model
                per_layer += self.d_model * (2 * d_in + 2 * self.ssm_state
                                             + d_in // self.ssm_head_dim)
                per_layer += d_in * self.d_model
            elif k.mixer == "rglru":
                w = self.lru_width or self.d_model
                per_layer += 3 * self.d_model * w + 2 * w * w
            if k.ffn == "mlp":
                per_layer += mlp
            elif k.ffn == "moe":
                per_layer += moe
        per_layer /= len(self.pattern)
        total = per_layer * (self.n_layers + self.enc_layers)
        total += self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return int(total)

    def active_param_count_estimate(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count_estimate()
        dense = dataclasses.replace(
            self, n_experts=0,
            pattern=tuple(dataclasses.replace(k, ffn="mlp")
                          for k in self.pattern),
            d_ff=self.moe_dff * self.top_k)
        return dense.param_count_estimate()
