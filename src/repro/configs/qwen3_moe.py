"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (kv=4, head_dim=128)
MoE 128e top-8, expert d_ff=1536, vocab=151936, QK-norm, untied.
[hf:Qwen/Qwen3-30B-A3B scaled family]"""
from .base import ArchConfig, LayerKind


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv=4, head_dim=128,
        d_ff=1536, vocab=151_936, pattern=(LayerKind("attn", ffn="moe"),),
        n_experts=128, top_k=8, moe_dff=1536, norm_topk=True,
        fsdp=True,
        qk_norm=True, tie_embeddings=False, rope_theta=1_000_000.0,
        max_seq=131_072, sub_quadratic=False)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=96, vocab=256, pattern=(LayerKind("attn", ffn="moe"),),
        n_experts=8, top_k=2, moe_dff=96, norm_topk=True, qk_norm=True,
        tie_embeddings=False, moe_dispatch="einsum", max_seq=128,
        sub_quadratic=False)
