"""mamba2-130m [ssm] — 24L d_model=768, attention-free SSD blocks,
ssm_state=128, vocab=50280.  [arXiv:2405.21060]"""
from .base import ArchConfig, LayerKind


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv=0, d_ff=0,
        vocab=50_280, pattern=(LayerKind("ssm", ffn="none"),),
        ssm_state=128, ssm_head_dim=64, tie_embeddings=True,
        max_seq=1 << 20, sub_quadratic=True)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv=0, d_ff=0,
        vocab=256, pattern=(LayerKind("ssm", ffn="none"),),
        ssm_state=16, ssm_head_dim=16, ssm_chunk=16, tie_embeddings=True,
        max_seq=256, sub_quadratic=True)
