"""gemma3-27b [dense-hybrid] — 62L d_model=5376 32H (kv=16, head_dim=128)
d_ff=21504, vocab=262144, 5 local (window 1024) : 1 global, QK-norm,
sandwich norms, 128k context.  [hf:google/gemma-3 family]"""
from .base import ArchConfig, LayerKind


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv=16, head_dim=128,
        d_ff=21_504, vocab=262_144,
        pattern=(LayerKind("attn", window=1024, rope_theta=10_000.0),) * 5
        + (LayerKind("attn", rope_theta=1_000_000.0),),
        qk_norm=True, zero_centered_norm=True, post_norms=True,
        fsdp=True,
        scale_embed_sqrt_d=True, act="gelu_tanh", tie_embeddings=True,
        max_seq=131_072,
        # 5:1 local:global — local KV is bounded, global layers decode with
        # sequence-sharded KV => eligible for long_500k (see DESIGN.md).
        sub_quadratic=True)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="gemma3-smoke", family="dense",
        n_layers=8, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256,
        pattern=(LayerKind("attn", window=16),) * 2 + (LayerKind("attn"),),
        qk_norm=True, zero_centered_norm=True, post_norms=True,
        scale_embed_sqrt_d=True, act="gelu_tanh", tie_embeddings=True,
        max_seq=128, sub_quadratic=True)
