"""Ambient parallelization context for activation sharding hints.

Model code stays mesh-agnostic; the launcher installs a ParallelCtx and
modules consult it for with_sharding_constraint hints that GSPMD cannot
infer — chiefly context-parallel (sequence-sharded) attention for archs
whose head count does not divide the model axis (whisper 12H, qwen1.5 20H,
qwen2.5 40H, paligemma 8H on a 16-way axis).
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ParallelCtx:
    mesh: object | None = None
    dp: tuple | str | None = None     # data axes for the batch dim
    tp: str | None = None             # model/tensor axis
    cp_attention: bool = False        # shard attention over query-seq
    seq_parallel: bool = False        # Megatron-SP residual stream


_CTX = ParallelCtx()


def set_ctx(**kw) -> ParallelCtx:
    global _CTX
    _CTX = ParallelCtx(**kw)
    return _CTX


def get_ctx() -> ParallelCtx:
    return _CTX


def reset_ctx():
    global _CTX
    _CTX = ParallelCtx()


def constrain(x, *spec):
    """with_sharding_constraint against the ambient ctx mesh (no-op when
    no ctx mesh installed, e.g. single-device smoke tests)."""
    ctx = get_ctx()
    if ctx.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))
