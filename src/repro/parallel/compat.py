"""Version-portable wrappers over the JAX mesh / shard_map API surface.

The repo targets the modern (>= 0.5) spelling — ``jax.make_mesh(...,
axis_types=...)``, ``jax.sharding.set_mesh`` ambient meshes, and
``jax.shard_map`` without an explicit mesh — but the pinned offline
toolchain ships JAX 0.4.37, where none of those exist.  Everything that
builds a mesh or enters shard_map goes through this module so the rest of
the codebase can use one spelling on either line:

* :func:`make_mesh` — ``jax.make_mesh`` with ``axis_types`` forwarded only
  when the installed JAX accepts it.
* :func:`abstract_mesh` — ``AbstractMesh`` across the 0.4.x
  ``((name, size), ...)`` and the newer ``(shape, names)`` constructors.
* :func:`set_ambient_mesh` / :func:`get_ambient_mesh` — ambient-mesh
  registry; delegates to ``jax.sharding.set_mesh`` when available and keeps
  a process-global fallback otherwise.
* :func:`shard_map` — ``jax.shard_map`` when present, else
  ``jax.experimental.shard_map.shard_map`` with the mesh taken from the
  ambient registry and ``check_vma`` mapped onto ``check_rep``.
"""
from __future__ import annotations

import jax

_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")
_HAS_SET_MESH = hasattr(jax.sharding, "set_mesh")
_HAS_JAX_SHARD_MAP = hasattr(jax, "shard_map")

_AMBIENT_MESH = None


def make_mesh(axis_shapes, axis_names, *, devices=None,
              axis_types=None):
    """``jax.make_mesh`` that works on 0.4.x (no ``axis_types``) and newer.

    ``axis_types=None`` requests Auto on every axis where the concept
    exists; on 0.4.x meshes are implicitly auto, so the kwarg is dropped.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _HAS_AXIS_TYPES:
        if axis_types is None:
            axis_types = (jax.sharding.AxisType.Auto,) * len(tuple(axis_names))
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def abstract_mesh(axis_shapes, axis_names):
    """``AbstractMesh`` across the 0.4.x ((name, size), ...) signature and
    the newer (shape_tuple, names) one."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(tuple(axis_names), tuple(axis_shapes))))


def set_ambient_mesh(mesh):
    """Install ``mesh`` as the ambient mesh for subsequent :func:`shard_map`
    calls (and for ``jax.sharding.set_mesh`` where it exists)."""
    global _AMBIENT_MESH
    _AMBIENT_MESH = mesh
    if _HAS_SET_MESH:
        jax.sharding.set_mesh(mesh)
    return mesh


def get_ambient_mesh():
    return _AMBIENT_MESH


def shard_map(f, *, mesh=None, in_specs, out_specs, check_vma=False):
    """Portable ``shard_map``: modern ambient-mesh spelling on new JAX,
    explicit-mesh ``jax.experimental.shard_map`` on 0.4.x."""
    if _HAS_JAX_SHARD_MAP:
        kwargs = dict(in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if mesh is not None:
            kwargs["mesh"] = mesh
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    if mesh is None:
        mesh = get_ambient_mesh()
    if mesh is None:
        raise ValueError(
            "shard_map on JAX 0.4.x needs a mesh: pass mesh= or install one "
            "with repro.parallel.compat.set_ambient_mesh(...)")
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma))
