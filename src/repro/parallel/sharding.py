"""Logical-axis -> mesh sharding rules.

Each parameter carries a tuple of *logical* axis names (see model modules'
``axes()``).  A :class:`ShardingRules` maps every mesh axis to a priority
list of logical names; for each tensor, each mesh axis is assigned to the
first logical axis in its list that (a) appears in the tensor, (b) has a
divisible dimension, and (c) hasn't been claimed by another mesh axis.
This gives Megatron-style TP with graceful fallbacks (e.g. qwen2.5's 40
heads don't divide a 16-way model axis, so the model axis lands on
head_dim instead) and ZeRO-3-style FSDP by listing "embed" under the data
axis.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """mesh axis -> ordered logical-axis preferences (params)."""
    param_rules: tuple[tuple[str, tuple[str, ...]], ...]
    # activation logical axes -> mesh axes (exact, no fallback)
    act_rules: tuple[tuple[str, tuple[str, ...] | str | None], ...]

    def act_axis(self, name: str):
        for k, v in self.act_rules:
            if k == name:
                return v
        return None


def default_rules(mesh: Mesh, *, fsdp: bool = True,
                  kv_seq_axis: str | None = None) -> ShardingRules:
    """Production rules. data axes: batch; model axis: TP/EP.
    ``kv_seq_axis``: shard decode KV caches along the sequence dim — "data"
    for long_500k (batch=1 frees the data axis), "model" when an arch's
    kv_heads don't divide the model axis (GSPMD then flash-decodes with a
    psum softmax merge)."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # No head_dim fallback: archs whose head count doesn't divide the model
    # axis use context-parallel attention (see parallel.context) instead of
    # sharding inside heads, which would psum full attention logits.
    param = [
        ("model", ("vocab", "experts", "heads", "kv_heads", "mlp")),
    ]
    if fsdp:
        param.append(("data", ("embed",)))
    act = [
        ("batch", dp_axes),
        ("kv_seq", kv_seq_axis),
        ("vocab", "model"),
        ("kv_heads", "model"),
        ("mlp", "model"),
    ]
    return ShardingRules(param_rules=tuple(param), act_rules=tuple(act))


def spec_for_param(axes: tuple, shape: tuple, rules: ShardingRules,
                   mesh: Mesh) -> P:
    assert len(axes) == len(shape), (axes, shape)
    assigned: dict[int, str] = {}
    for mesh_axis, prefs in rules.param_rules:
        if mesh_axis not in mesh.axis_names:
            continue
        size = mesh.shape[mesh_axis]
        for logical in prefs:
            hit = None
            for d, name in enumerate(axes):
                if name == logical and d not in assigned \
                        and shape[d] % size == 0 and shape[d] >= size:
                    hit = d
                    break
            if hit is not None:
                assigned[hit] = mesh_axis
                break
    return P(*[assigned.get(d) for d in range(len(shape))])


def spec_for_cache(axes: tuple, shape: tuple, rules: ShardingRules,
                   mesh: Mesh) -> P:
    """Caches/activations: exact logical->mesh mapping with divisibility
    guard (drop when not divisible)."""
    out = []
    used: set[str] = set()
    for d, name in enumerate(axes):
        m = rules.act_axis(name) if name else None
        if m is None:
            out.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        ms = tuple(a for a in ms if a in mesh.axis_names and a not in used)
        total = int(np.prod([mesh.shape[a] for a in ms])) if ms else 1
        if ms and shape[d] % total == 0 and shape[d] >= total:
            out.append(ms if len(ms) > 1 else ms[0])
            used.update(ms)
        else:
            out.append(None)
    return P(*out)


def tree_param_shardings(mesh: Mesh, rules: ShardingRules, axes_tree,
                         shape_tree):
    """axes_tree / shape_tree: matching pytrees (axes leaves are tuples)."""
    is_axes = lambda t: isinstance(t, tuple) and all(
        isinstance(x, (str, type(None))) for x in t)
    return jax.tree.map(
        lambda a, s: NamedSharding(mesh, spec_for_param(a, s.shape, rules,
                                                        mesh)),
        axes_tree, shape_tree, is_leaf=is_axes)


def tree_cache_shardings(mesh: Mesh, rules: ShardingRules, axes_tree,
                         shape_tree):
    is_axes = lambda t: isinstance(t, tuple) and all(
        isinstance(x, (str, type(None))) for x in t)
    return jax.tree.map(
        lambda a, s: NamedSharding(mesh, spec_for_cache(a, s.shape, rules,
                                                        mesh)),
        axes_tree, shape_tree, is_leaf=is_axes)


def shapes_of(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)


def batch_sharding(mesh: Mesh, rules: ShardingRules, ndim: int = 2):
    dp = rules.act_axis("batch")
    return NamedSharding(mesh, P(dp, *([None] * (ndim - 1))))
