from .sharding import (ShardingRules, batch_sharding, default_rules,
                       shapes_of, spec_for_cache, spec_for_param,
                       tree_cache_shardings, tree_param_shardings)

__all__ = ["ShardingRules", "batch_sharding", "default_rules", "shapes_of",
           "spec_for_cache", "spec_for_param", "tree_cache_shardings",
           "tree_param_shardings"]
