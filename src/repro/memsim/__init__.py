"""Faithful-reproduction substrate: HMC-like DRAM + workloads + simulator."""
from .dram import Timing
from .energy import EnergyParams, energy_pj, init_energy_per_row
from .simulator import CONFIGS, SimParams, SimResult, simulate
from .workloads import (WORKLOADS, Op, Request, TrafficMix, WorkloadSpec,
                        generate, traffic_breakdown)

__all__ = ["Timing", "EnergyParams", "energy_pj", "init_energy_per_row",
           "CONFIGS", "SimParams",
           "SimResult", "simulate", "WORKLOADS", "Op", "Request",
           "TrafficMix", "WorkloadSpec", "generate", "traffic_breakdown"]
