"""Copy-intensive workload generators (paper Section 3, Fig. 3).

Each workload is a deterministic (seeded) request stream with a traffic mix
matching Fig. 3: *fork* (the OS service dominated by page copies on
copy-on-write faults) and *fileCopyXX* (memcached-like object caching with
XX% of memory traffic from inter-bank object copies).  Traffic fractions are
fractions of **bytes moved**, as in the paper's breakdown; copies move 4 KB
pages, regular accesses move 64 B lines.
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np


class Op(enum.Enum):
    READ = 0
    WRITE = 1
    COPY = 2       # src page -> dst page
    INIT = 3       # zero a page
    REDUCE = 4     # combine N source pages at the destination bank


@dataclasses.dataclass(frozen=True)
class Request:
    op: Op
    src_bank: int
    src_row: int
    dst_bank: int = -1
    dst_row: int = -1
    nbytes: int = 64
    intra_bank: bool = False
    same_subarray: bool = False
    # REDUCE fan-in: every source bank whose operand merges at dst_bank
    # (src_bank mirrors src_banks[0]); empty for the other classes.
    src_banks: tuple = ()


@dataclasses.dataclass(frozen=True)
class TrafficMix:
    """Byte-fractions per class; must sum to 1."""
    inter_bank_copy: float
    intra_bank_copy: float
    init: float
    regular: float
    reduce: float = 0.0

    def __post_init__(self):
        total = (self.inter_bank_copy + self.intra_bank_copy + self.init
                 + self.regular + self.reduce)
        assert abs(total - 1.0) < 1e-9, total


# Fig. 3 mixes (inter-bank copy share is the workload's defining number).
# The *Reduce* mixes are ours, not the paper's: optimizer-state
# accumulation / gradient-aggregation services where a compute-class
# fan-in (Op.REDUCE) replaces the copy-then-compute round trip.
WORKLOADS: dict[str, TrafficMix] = {
    "fork":       TrafficMix(0.25, 0.20, 0.15, 0.40),
    "fileCopy20": TrafficMix(0.20, 0.10, 0.10, 0.60),
    "fileCopy40": TrafficMix(0.40, 0.10, 0.08, 0.42),
    "fileCopy60": TrafficMix(0.60, 0.08, 0.05, 0.27),
    "gradAgg20":  TrafficMix(0.10, 0.05, 0.05, 0.60, 0.20),
    "gradAgg40":  TrafficMix(0.10, 0.05, 0.05, 0.40, 0.40),
}

PAGE = 4096
LINE = 64


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    n_requests: int = 2000
    n_banks: int = 256
    rows_per_bank: int = 2048
    seed: int = 0
    locality: float = 0.5   # P(regular access hits the currently open row)
    same_subarray_frac: float = 0.5  # intra-bank copies in the same subarray
    reduce_fanin: int = 4   # operands per Op.REDUCE fan-in


def generate(spec: WorkloadSpec) -> list[Request]:
    mix = WORKLOADS[spec.name]
    rng = np.random.default_rng(spec.seed)
    # Convert byte fractions to request counts: a copy/init request moves a
    # page (PAGE bytes), a regular request moves LINE bytes.  Counts are
    # stratified (not sampled) so the realized byte mix matches Fig. 3
    # exactly up to rounding, then the order is shuffled.
    # A reduce request moves fanin operand pages to one destination.
    w = np.array([mix.inter_bank_copy / PAGE, mix.intra_bank_copy / PAGE,
                  mix.init / PAGE, mix.regular / LINE,
                  mix.reduce / (PAGE * max(1, spec.reduce_fanin))])
    p = w / w.sum()
    counts = np.floor(p * spec.n_requests).astype(int)
    counts[np.argmax(p)] += spec.n_requests - counts.sum()
    kinds = np.repeat(np.arange(5), counts)
    rng.shuffle(kinds)
    reqs: list[Request] = []
    open_rows = np.full(spec.n_banks, -1)
    for k in kinds:
        src = int(rng.integers(spec.n_banks))
        if k == 0:  # inter-bank copy
            dst = int(rng.integers(spec.n_banks - 1))
            dst += dst >= src
            reqs.append(Request(Op.COPY, src, int(rng.integers(spec.rows_per_bank)),
                                dst, int(rng.integers(spec.rows_per_bank)),
                                nbytes=PAGE))
        elif k == 1:  # intra-bank copy
            same_sub = bool(rng.random() < spec.same_subarray_frac)
            reqs.append(Request(Op.COPY, src, int(rng.integers(spec.rows_per_bank)),
                                src, int(rng.integers(spec.rows_per_bank)),
                                nbytes=PAGE, intra_bank=True,
                                same_subarray=same_sub))
        elif k == 2:  # init
            row = int(rng.integers(spec.rows_per_bank))
            reqs.append(Request(Op.INIT, src, row, src, row, nbytes=PAGE))
        elif k == 4:  # compute-class fan-in reduce
            fanin = min(max(1, spec.reduce_fanin), spec.n_banks - 1)
            banks = rng.choice(spec.n_banks, size=fanin + 1, replace=False)
            srcs, dst = banks[:-1], int(banks[-1])
            reqs.append(Request(Op.REDUCE, int(srcs[0]),
                                int(rng.integers(spec.rows_per_bank)),
                                dst, int(rng.integers(spec.rows_per_bank)),
                                nbytes=PAGE,
                                src_banks=tuple(int(b) for b in srcs)))
        else:  # regular read/write
            if open_rows[src] >= 0 and rng.random() < spec.locality:
                row = int(open_rows[src])
            else:
                row = int(rng.integers(spec.rows_per_bank))
            open_rows[src] = row
            is_wr = bool(rng.random() < 0.35)
            reqs.append(Request(Op.WRITE if is_wr else Op.READ, src, row,
                                nbytes=LINE))
    return reqs


def traffic_breakdown(reqs: list[Request]) -> dict[str, float]:
    """Byte-share per class — reproduces the paper's Fig. 3."""
    buckets = {"inter_bank_copy": 0, "intra_bank_copy": 0, "init": 0,
               "regular": 0, "reduce": 0}
    for r in reqs:
        if r.op == Op.COPY and not r.intra_bank:
            buckets["inter_bank_copy"] += r.nbytes
        elif r.op == Op.COPY:
            buckets["intra_bank_copy"] += r.nbytes
        elif r.op == Op.INIT:
            buckets["init"] += r.nbytes
        elif r.op == Op.REDUCE:
            buckets["reduce"] += r.nbytes * max(1, len(r.src_banks))
        else:
            buckets["regular"] += r.nbytes
    total = sum(buckets.values())
    return {k: v / total for k, v in buckets.items()}
