"""DRAMPower-style energy model (paper Section 3, "Energy analysis").

Per-event energies follow the Micron DDR3 power-calculator structure the
paper cites: activate/precharge + read/write column energy per access, I/O
energy per bit for on-chip interconnect, and a large off-chip (SerDes +
board trace) cost per bit for data that leaves the stack.  Values are in pJ
and chosen from the public Micron TN-41-01 / HMC literature ballpark — the
*ratios* (NoM vs DDR3 baseline vs RowClone) are what the paper reports.
"""
from __future__ import annotations

import dataclasses

from .simulator import SimResult
from .workloads import LINE


@dataclasses.dataclass(frozen=True)
class EnergyParams:
    e_act_pre: float = 909.0        # activate+precharge per row op (pJ)
    e_rd_wr: float = 467.0          # column read/write per 64B (pJ)
    e_offchip_bit: float = 10.0     # SerDes + trace per bit (pJ)
    e_tsv_bit: float = 0.05         # TSV per bit
    e_hop_bit: float = 0.10         # NoM link+crossbar per bit per hop
    e_bus_bit: float = 0.60         # long global shared-bus wire per bit
    e_router_static_per_cycle: float = 0.002  # per router (NoM overhead)
    n_routers: int = 256
    # Inter-stack SerDes lane per bit per directed hop (pJ) — cheaper than
    # the full off-chip path (short cube-to-cube traces, no DIMM bus) but
    # an order of magnitude above a TSV; charged per `serdes_bytes` of a
    # multi-stack run (each byte counted once per SerDes hop it crossed).
    e_serdes_bit: float = 4.0
    # In-DRAM bulk initialization (RowClone-FPM zero): one activate of the
    # all-zeros source row pattern + precharge per cleared row — no column
    # I/O leaves the mats, so per-row cost sits at the ACT/PRE energy (the
    # RowClone paper's FPM accounting; LISA adds hops only for *copies*).
    e_init_row: float = 909.0
    # Compute-class reduce: one 64-bit integer/FP merge in the destination
    # bank's logic-die ALU (pJ per merged element) — a near-memory adder
    # operates at a small multiple of a TSV bit crossing, far below any
    # path that moves the operand off-stack.  Charged per
    # ``extra["nom_reduce_elems"]``.
    e_reduce_elem: float = 0.08


def init_energy_per_row(params: EnergyParams = EnergyParams()) -> float:
    """Energy to clear one DRAM row in place (pJ) — the INIT-class unit
    cost charged per ``extra["init_rows"]`` by :func:`energy_pj`."""
    return params.e_init_row


def energy_pj(res: SimResult, params: EnergyParams = EnergyParams()) -> dict:
    """Decompose total energy for a finished simulation.  INIT-class
    in-DRAM zeroing is charged per cleared row (``dram_init``,
    ``extra["init_rows"]`` × ``e_init_row``) on the configs that zero in
    place — and those bytes (``extra["init_bytes"]``) are *excluded*
    from the per-line column-I/O term, since no data leaves the mats.
    The conventional config pays for initialization through its store
    traffic instead (no ``init_bytes`` reported)."""
    p = params
    init_lines = res.extra.get("init_bytes", 0) // LINE
    accesses = max(0, res.copy_bytes // LINE - init_lines) + max(res.reqs, 1)
    dram = accesses * (p.e_act_pre * 0.3 + p.e_rd_wr)
    init = res.extra.get("init_rows", 0) * p.e_init_row
    offchip = res.offchip_bytes * 8 * p.e_offchip_bit
    nom = res.nom_hop_beats * 64 * p.e_hop_bit
    bus = res.bus_busy_cycles * 64 * p.e_bus_bit
    serdes = res.extra.get("serdes_bytes", 0) * 8 * p.e_serdes_bit
    reduce_alu = res.extra.get("nom_reduce_elems", 0) * p.e_reduce_elem
    static = (res.cycles * p.e_router_static_per_cycle * p.n_routers
              if res.config.startswith("nom") else 0.0)
    total = dram + init + offchip + nom + bus + serdes + reduce_alu + static
    return {"dram": dram, "dram_init": init, "offchip": offchip,
            "nom_links": nom, "shared_bus": bus, "serdes_links": serdes,
            "reduce_alu": reduce_alu, "router_static": static,
            "total": total, "per_access": total / max(1, accesses)}
