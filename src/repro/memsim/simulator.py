"""Event/timestamp simulator for the four evaluated memory configurations.

Configurations (paper Section 3):

* ``conventional`` — copies/initialization go through the processor: every
  64 B line is read over the vault TSV + off-chip link and written back.
* ``rowclone``     — RowClone FPM for same-subarray copies, LISA for other
  intra-bank copies, RowClone PSM over the *shared internal bus* for
  inter-bank copies (bus reserved for the whole copy).
* ``nom``          — inter-bank copies ride the TDM circuit-switched 3D mesh
  (full NoM); intra-bank copies still use RowClone/LISA, as the paper
  integrates them.
* ``nom_light``    — NoM with the shared-TSV vertical bus instead of
  dedicated Z links.

The processor is a closed-loop core with a fixed-size window of outstanding
memory operations (memory-level parallelism) — performance is reported as
effective IPC over a common per-workload instruction count, so IPC ratios
equal runtime speedups, matching how Fig. 4 compares configurations.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict

import numpy as np

from repro.core.fabric import (AdmissionQueue, FabricCluster, FabricOverflow,
                               NomFabric)
from repro.core.slot_alloc import (PORT_LOCAL, CopyRequest, TdmAllocator,
                                   TdmAllocatorLight)
from repro.core.topology import Mesh3D, StackedTopology, make_topology

from .dram import OffChipLink, SharedInternalBus, Timing, VaultController
from .workloads import LINE, Op, Request

CONFIGS = ("conventional", "rowclone", "nom", "nom_light")


@dataclasses.dataclass
class SimParams:
    """Simulation knobs.  All time quantities are logic-die cycles.

    The ``nom_*`` fields model the CCU and router provisioning of the
    paper's NoM (Sections 2.1-2.3):

    * ``nom_link_ratio`` (default 1.0): NoM link frequency as a fraction
      of logic frequency (<= 1) — the paper's frequency-scaling study
      (Fig. 6); transfer durations are divided by this ratio.
    * ``nom_extra_slots`` (default 7): extra free TDM slots the CCU may
      bundle onto one circuit to accelerate it (Section 2.1's multi-slot
      circuits); 0 = one slot per circuit.
    * ``nom_ccu_queue_depth`` (default 8): capacity of the CCU's bounded
      request queue, in pending copy requests.  The CCU drains the queue
      with one batched setup pass (``TdmAllocator.allocate_batch``) when
      it fills; a copy issued against a full queue *backpressures* the
      core until the drain's pickup pipeline completes — the bounded
      router/controller buffering that the HMC NoC studies identify as
      the contention bottleneck.  Depth is clamped to
      ``nom_max_inflight`` when that cap is set (a queue deeper than the
      in-flight circuit budget could never drain faster anyway).
    * ``nom_max_inflight`` (default 0 = uncapped): per-TDM-window cap on
      concurrent circuits — the router-buffering calibration knob; an
      admission that would exceed it is pushed to a later window.
    """
    config: str = "nom"
    mesh: Mesh3D = dataclasses.field(default_factory=make_topology)
    n_slots: int = 16
    timing: Timing = dataclasses.field(default_factory=Timing)
    window: int = 32                 # outstanding memory ops (MLP window)
    line_window: int = 8             # in-flight lines inside a processor copy
    compute_gap: int = 2             # compute cycles between memory issues
    nom_link_ratio: float = 1.0      # NoM link freq / logic freq (<=1)
    nom_extra_slots: int = 7         # extra TDM slots the CCU may bundle
    nom_ccu_queue_depth: int = 8     # bounded CCU request queue (see above)
    nom_max_inflight: int = 0        # per-TDM-window circuit cap (0 = off)
    instr_per_line: int = 2          # conventional copy: LD+ST per line
    # Multi-stack: `stacks` > 1 chains that many copies of `mesh` over
    # SerDes links (bank ids become global ids over all stacks); under the
    # NoM configs the CCU becomes a FabricCluster and cross-stack copies
    # ride two-phase segmented circuits.
    stacks: int = 1
    stack_link: str = "ring"         # inter-stack link graph: ring | full
    serdes_latency: int = 8          # per-SerDes-hop beat latency (cycles)
    serdes_link_bytes: int = 4       # bytes per SerDes TDM slot-window


@dataclasses.dataclass
class SimResult:
    name: str
    config: str
    cycles: int
    instructions: int
    ipc: float
    reqs: int
    copy_bytes: int
    offchip_bytes: int
    nom_hop_beats: int
    bus_busy_cycles: int
    tsv_busy_frac: float
    tsv_conflict_frac: float
    row_hit_rate: float
    extra: dict = dataclasses.field(default_factory=dict)


class MemorySystem:
    """Shared geometry + per-config data paths.

    The NoM configs hold a :class:`~repro.core.fabric.NomFabric` session
    (``self.fabric``): its :class:`~repro.core.fabric.AdmissionQueue` *is*
    the CCU's bounded request queue (``self.ccu`` — sim and scheduler
    share one implementation), and every circuit setup goes through
    ``fabric.schedule`` against the config's allocator."""

    def __init__(self, p: SimParams):
        self.p = p
        self.mesh = p.mesh                       # per-stack geometry
        self.topology = (make_topology(p.stacks, p.mesh, link=p.stack_link,
                                       link_latency=p.serdes_latency,
                                       link_bytes=p.serdes_link_bytes)
                         if p.stacks > 1 else p.mesh)
        self.stacked = isinstance(self.topology, StackedTopology)
        t = p.timing
        n_vaults = self.mesh.n_vaults * p.stacks
        banks_per_vault = len(self.mesh.banks_of_vault(0))
        self.vaults = [VaultController(t, banks_per_vault)
                       for _ in range(n_vaults)]
        self.offchip = OffChipLink(t)
        self.shared_bus = SharedInternalBus()
        alloc: TdmAllocator | None = None
        alloc_cls = {"nom": TdmAllocator, "nom_light": TdmAllocatorLight} \
            .get(p.config)
        stack_allocs: list[TdmAllocator] | None = None
        if alloc_cls is not None:
            if self.stacked:
                stack_allocs = [alloc_cls(m, p.n_slots)
                                for m in self.topology.stacks]
                alloc = stack_allocs[0]
            else:
                alloc = alloc_cls(self.mesh, p.n_slots)
        # Calibration against the RowClone-FPM row-cycle timing: an
        # in-bank zero costs t.rowclone_fpm logic cycles per row, i.e.
        # ceil(rowclone_fpm / n_slots) TDM windows — so the zero-hop
        # circuit's occupancy must cover that many windows per row, not
        # the old 1 window/row optimism.
        self.init_windows_per_row = max(1, -(-t.rowclone_fpm // p.n_slots))
        if alloc is not None:
            # ceil so a k-row INIT occupies exactly k * windows_per_row
            # windows (floor would overshoot by one window per row).
            for a in (stack_allocs or [alloc]):
                a.init_row_bytes = max(
                    1, -(-t.row_bytes // self.init_windows_per_row))
        # Bounded CCU request queue, calibrated against the router-buffering
        # cap: a queue deeper than the in-flight circuit budget would only
        # park requests the mesh cannot admit, so the cap clamps the depth.
        depth = max(1, p.nom_ccu_queue_depth)
        if p.nom_max_inflight:
            depth = max(1, min(depth, p.nom_max_inflight))
        self.fabric: NomFabric | FabricCluster | None = None
        if stack_allocs is not None:
            self.fabric = FabricCluster(topology=self.topology,
                                        queue_depth=depth, overflow="block",
                                        allocators=stack_allocs)
            self.ccu = self.fabric.queue
        elif alloc is not None:
            self.fabric = NomFabric(allocator=alloc, queue_depth=depth,
                                    overflow="block")
            self.ccu = self.fabric.queue
        else:
            self.ccu = AdmissionQueue(depth)
        self.nom_hop_beats = 0
        self.nom_init_windows = 0      # TDM windows held by zero-hop INITs
        self.init_rows = 0             # rows cleared in-DRAM (INIT energy)
        self.init_bytes = 0            # bytes zeroed in-DRAM (no column I/O)
        # stats for the TSV dual-use analysis (NoM-Light motivation)
        self.nom_vertical_cycles = 0
        # Concurrent-transfer telemetry: circuits in flight per TDM window.
        # Only windows at or past the live-circuit horizon stay in the
        # dict; fully-past windows are folded into the _inflight_* stats
        # by _prune_inflight so a long run's footprint stays bounded.
        self.window_inflight: dict[int, int] = {}
        self._inflight_sum = 0         # pruned windows: sum of counts
        self._inflight_windows = 0     # pruned windows: non-empty count
        self._inflight_max = 0         # pruned windows: peak count
        self.nom_alloc_conflicts = 0   # stale-search commit retries
        self.nom_setup_retries = 0     # saturated-mesh re-allocations
        # Allocator-backend split: prepare waves served by the fused
        # compiled program vs the host pipeline (ScheduleReport passthrough)
        self.nom_fused_waves = 0
        self.nom_host_waves = 0
        self.nom_batches = 0
        self.nom_batched_reqs = 0
        # SerDes window occupancy (multi-stack): (channel, slot)-windows
        # reserved, bytes that crossed inter-stack links (per directed
        # hop), and how many copies went cross-stack.
        self.serdes_windows = 0
        self.serdes_bytes = 0
        self.nom_cross_stack = 0
        # Compute-class (Op.REDUCE) telemetry: 64-bit merges executed by
        # destination-bank ALUs, and cycles lost to a busy ALU (a second
        # fan-in landing on a bank whose merge pipeline hasn't drained).
        self.nom_reduce_elems = 0
        self.nom_reduce_stalls = 0
        self._reduce_alu_free: dict[int, int] = {}  # dst bank -> ALU free-at

    # -- helpers -------------------------------------------------------------
    @property
    def alloc(self) -> TdmAllocator | None:
        """A representative allocator (None on non-NoM configs): the
        single fabric's, or stack 0's on a cluster — all stacks share the
        same width/slot parameters, which is what the window-estimate and
        telemetry callers need."""
        if self.fabric is None:
            return None
        if isinstance(self.fabric, FabricCluster):
            return self.fabric.fabrics[0].allocator
        return self.fabric.allocator

    def _locate(self, bank: int) -> tuple[int, int]:
        """Global bank id -> (stack, stack-local node id)."""
        return self.topology.locate(bank) if self.stacked else (0, bank)

    def _vault_bank(self, bank: int) -> tuple[VaultController, int]:
        stack, node = self._locate(bank)
        v = stack * self.mesh.n_vaults + self.mesh.vault_of(node)
        local = self.mesh.banks_of_vault(self.mesh.vault_of(node)).index(node)
        return self.vaults[v], local

    # -- window-inflight bookkeeping ------------------------------------------
    def _record_inflight(self, spans: list[tuple[int, int]]) -> None:
        """Fold one batch's ``(start_window, n_windows)`` spans into the
        per-window concurrency map with a single difference-array pass
        instead of one dict update per (circuit, window)."""
        if not spans:
            return
        w0 = min(s for s, _n in spans)
        w1 = max(s + n for s, n in spans)
        diff = np.zeros(w1 - w0 + 1, np.int64)
        for s, n in spans:
            diff[s - w0] += 1
            diff[s - w0 + n] -= 1
        counts = np.cumsum(diff[:-1])
        get = self.window_inflight.get
        for off in np.nonzero(counts)[0].tolist():
            w = w0 + off
            self.window_inflight[w] = get(w, 0) + int(counts[off])

    def _prune_inflight(self, horizon_w: int) -> None:
        """Drop windows strictly before ``horizon_w`` — the CCU pickup
        horizon is monotone, so nothing can increment or query them again
        — folding their counts into the running stats so the reported
        telemetry is unchanged while the map stays bounded."""
        stale = [w for w in self.window_inflight if w < horizon_w]
        for w in stale:
            n = self.window_inflight.pop(w)
            if n > 0:
                self._inflight_sum += n
                self._inflight_windows += 1
                self._inflight_max = max(self._inflight_max, n)

    def inflight_stats(self) -> tuple[float, int]:
        """(mean over non-empty TDM windows, peak) concurrent circuits,
        pruned and live windows combined — exactly what a full
        ``window_inflight`` map would report."""
        live = [n for n in self.window_inflight.values() if n > 0]
        total = self._inflight_sum + sum(live)
        count = self._inflight_windows + len(live)
        peak = max([self._inflight_max] + live)
        return (total / count if count else 0.0), peak

    def line_access(self, at: int, bank: int, row: int, is_write: bool,
                    priority: bool = False, offchip: bool = True) -> int:
        vc, b = self._vault_bank(bank)
        done = vc.access_line(at, b, row, is_write, priority=priority)
        if offchip:
            done = self.offchip.transfer(done, LINE)
        return done

    # -- copy paths ------------------------------------------------------------
    def copy_conventional(self, at: int, r: Request,
                          write_only: bool = False) -> int:
        """Processor-mediated copy/initialize: each 64B line is read over the
        vault TSV + off-chip link into the core and written back.

        The core sustains at most ``line_window`` line-transfers in flight
        (load/store-queue MLP), so a page copy is load-use-latency bound —
        the inefficiency RowClone/NoM eliminate."""
        lines = r.nbytes // LINE
        w = self.p.line_window
        vc, b = self._vault_bank(r.dst_bank)
        done = at
        # The memory controller batches reads then writes per MLP window so
        # same-bank copies don't ping-pong row activations line by line.
        for g in range(0, lines, w):
            batch = min(w, lines - g)
            ready = []
            for _ in range(batch):
                if write_only:
                    ready.append(self.offchip.transfer(at, LINE, down=True))
                else:
                    rd = self.line_access(at, r.src_bank, r.src_row, False)
                    ready.append(self.offchip.transfer(rd, LINE, down=True))
                at += 1
            for rd in ready:
                done = max(done, vc.access_line(rd, b, r.dst_row, True))
            # Next batch's reads overlap this batch's writes (prefetch-style
            # streaming); resource occupancy carries the contention.
            at = max(at, ready[-1] - self.p.timing.offchip_latency)
        return done

    def copy_in_dram_local(self, at: int, r: Request) -> int:
        """RowClone-FPM / LISA intra-bank copy (also used for INIT)."""
        t = self.p.timing
        vc, b = self._vault_bank(r.src_bank)
        rows = max(1, r.nbytes // t.row_bytes)
        if r.op == Op.INIT:
            self.init_rows += rows
            self.init_bytes += r.nbytes
        if r.same_subarray or r.op == Op.INIT:
            per_row = t.rowclone_fpm
        else:
            hops = 4  # average subarray distance for LISA RBM
            per_row = t.rowclone_fpm + hops * t.lisa_hop
        done = at
        for _ in range(rows):
            done = vc.bank_row_op(done, b, per_row)
        return done

    def copy_rowclone_psm(self, at: int, r: Request) -> int:
        """Inter-bank copy over the shared internal bus (bus reserved)."""
        t = self.p.timing
        lines = r.nbytes // LINE
        # src activate + per-line (read beat + write beat on the bus) + dst
        # restore; the row stays open so lines pipeline at burst occupancy.
        per_line = 2 * t.tBURST
        dur = t.tRCD + t.tCL + lines * per_line + t.tWR
        svc, sb = self._vault_bank(r.src_bank)
        dvc, db = self._vault_bank(r.dst_bank)
        ready = max(svc.banks[sb].s.free_at, dvc.banks[db].s.free_at, at)
        start, end = self.shared_bus.reserve(ready, dur)
        svc.banks[sb].s.free_at = end
        dvc.banks[db].s.free_at = end
        # The bus transfer also occupies both vaults' TSVs line by line.
        svc._tsv(start, lines * t.tBURST)
        dvc._tsv(start, lines * t.tBURST)
        return end

    def reduce_processor(self, at: int, r: Request) -> int:
        """Copy-then-compute fallback for Op.REDUCE on the non-NoM
        configs: every operand page round-trips through the processor
        (read over vault TSV + off-chip link, accumulate in the core,
        write the running sum back) — the traffic the compute-class NoM
        op eliminates.  Sequential in the operands: each pass
        read-modify-writes the same destination row."""
        done = at
        for s in r.src_banks:
            step = Request(Op.COPY, int(s), r.src_row, r.dst_bank,
                           r.dst_row, nbytes=r.nbytes)
            done = self.copy_conventional(done, step)
        return done

    def _finish_reduce(self, rq: CopyRequest, r: Request, c,
                       xfer_done: int) -> int:
        """Post-circuit accounting for one committed fan-in: mesh/SerDes
        beat counts, destination-bank ALU occupancy (with backpressure
        when a second fan-in lands on a busy ALU), and the destination
        row write.  Returns the drain cycle."""
        p, t = self.p, self.p.timing
        k = len(rq.srcs)
        beats = max(1, r.nbytes // 8)
        # Each per-source route carries `beats` over its own mesh hops;
        # LOCAL entries (arrival + ALU dwell) are occupancy, not traffic.
        mesh_hops = sum(1 for _n, prt, _s in c.hops if prt != PORT_LOCAL)
        self.nom_hop_beats += beats * mesh_hops
        link_slots = getattr(c, "link_slots", None)
        if link_slots:
            self.serdes_bytes += r.nbytes * len(link_slots)
            self.serdes_windows += c.n_windows * len(link_slots)
            self.nom_cross_stack += 1
        if p.config == "nom":
            d_stack, d_loc = self._locate(r.dst_bank)
            dz = self.mesh.coords(d_loc)[2]
            vert = 0
            for s in rq.srcs:
                s_stack, s_loc = self._locate(int(s))
                sz = self.mesh.coords(s_loc)[2]
                vert += (sz + dz) if s_stack != d_stack else abs(sz - dz)
            self.nom_vertical_cycles += vert * beats
        # Destination-bank ALU: merges k-1 operands into the resident
        # running sum at stream rate (one 64-bit lane), draining one
        # dwell window past the final beat.  A fan-in that lands while
        # the ALU is still draining a previous merge backpressures.
        elems = (k - 1) * beats
        self.nom_reduce_elems += elems
        free = self._reduce_alu_free.get(r.dst_bank, 0)
        if free > c.start_cycle:
            stall = free - c.start_cycle
            self.nom_reduce_stalls += stall
            xfer_done += stall
        dwell = max(0, getattr(self.alloc, "reduce_dwell", 1))
        self._reduce_alu_free[r.dst_bank] = xfer_done + dwell * p.n_slots
        dvc, db = self._vault_bank(r.dst_bank)
        return dvc.bank_row_op(xfer_done, db, t.tRCD + t.tWR)

    def copy_nom(self, at: int, r: Request) -> int:
        """Inter-bank copy over the TDM circuit-switched mesh (batch of 1)."""
        return self.copy_nom_batch([(at, r)])[0]

    def copy_nom_batch(self, items: list[tuple[int, "Request"]],
                       pickup_at: int = 0) -> list[int]:
        """Service a batch of inter-bank copies with one concurrent setup.

        The CCU searches every pending request in a single vectorized
        wavefront pass (``TdmAllocator.allocate_batch``) and programs the
        winning circuits back to back — one per cycle after the 3-cycle
        pipeline fill, versus one setup per 3 cycles when serviced one at a
        time.  The committed circuits are link-disjoint and stream
        concurrently; ``window_inflight`` records how many overlap each TDM
        window, and ``nom_max_inflight`` (if set) caps admissions per
        window, pushing the overflow to the next window (the increasing-
        slot fallback at window granularity)."""
        p, t = self.p, self.p.timing
        # 1) CCU picks up the batch (FIFO; pipelined 1/cycle after fill).
        # The search runs speculatively as requests arrive, so a scheduled
        # drain anchors at the head's arrival; a forced (queue-full) drain
        # passes ``pickup_at`` — it cannot start before the drain decision.
        pick0 = max(min(at for at, _r in items), self.ccu.busy_until,
                    pickup_at)
        self.ccu.busy_until = pick0 + 3 + (len(items) - 1)
        self.nom_batches += 1
        self.nom_batched_reqs += len(items)
        # The pickup horizon is monotone across batches, so every window
        # before it is settled history — fold it out of the live map.
        self._prune_inflight((pick0 + 3) // p.n_slots)
        # 2) source reads (row-granularity into the bank's CS buffer) via
        #    the high-priority copy queue.  An INIT has no source read:
        #    the CCU issues an in-bank RowClone-FPM zero, and its zero-hop
        #    circuit holds only the home bank's LOCAL port.
        reqs: list[CopyRequest] = []
        for i, (at, r) in enumerate(items):
            pick = max(at, pick0 + i)
            if r.op == Op.INIT:
                reqs.append(CopyRequest(r.src_bank, r.src_bank, r.nbytes,
                                        op="init", cycle=pick))
                continue
            if r.op == Op.REDUCE:
                # Every operand bank reads its row into the CS buffer; the
                # fan-in circuit is anchored at the slowest one.
                ready = pick + 3
                for s in r.src_banks:
                    svc, sb = self._vault_bank(int(s))
                    ready = max(ready, svc.bank_row_op(pick + 3, sb,
                                                       t.tRCD + t.tCL))
                reqs.append(CopyRequest(
                    int(r.src_banks[0]), r.dst_bank, r.nbytes, op="reduce",
                    srcs=tuple(int(s) for s in r.src_banks),
                    cycle=max(ready - 3, pick)))
                continue
            svc, sb = self._vault_bank(r.src_bank)
            ready = svc.bank_row_op(pick + 3, sb, t.tRCD + t.tCL)
            # 3) circuit allocation anchored so injection starts when data
            #    is ready (the CCU knows timings deterministically).
            reqs.append(CopyRequest(r.src_bank, r.dst_bank, r.nbytes,
                                    max_extra_slots=p.nom_extra_slots,
                                    cycle=max(ready - 3, pick)))
        batch_cycle = min(rq.cycle for rq in reqs)
        # Per-window concurrency cap: an admission is delayed until every
        # window its circuit could span (conservative slots=1 estimate,
        # +1 for injection rolling into the next window) has headroom over
        # the live circuits plus this batch's earlier admissions — the
        # increasing-slot fallback at window granularity.
        if p.nom_max_inflight:
            planned: dict[int, int] = defaultdict(int)
            bumped = []
            for rq in reqs:
                span = (self.alloc.n_windows_for_init(rq.nbytes)
                        if rq.op == "init"
                        else self.alloc.n_windows_for(rq.nbytes, slots=1)) + 1
                w = (rq.cycle + 3) // p.n_slots
                for _ in range(4096):   # bounded: circuits always expire
                    if all(self.window_inflight.get(u, 0) + planned[u]
                           < p.nom_max_inflight
                           for u in range(w, w + span)):
                        break
                    w += 1
                for u in range(w, w + span):
                    planned[u] += 1
                bumped.append(dataclasses.replace(
                    rq, cycle=max(rq.cycle, w * p.n_slots)))
            reqs = bumped
        results, report = self.fabric.schedule(reqs, cycle=batch_cycle)
        self.nom_alloc_conflicts += report.conflicts
        self.nom_fused_waves += report.fused_waves
        self.nom_host_waves += report.host_waves
        dones = []
        spans: list[tuple[int, int]] = []
        for rq, res, (_at, r) in zip(reqs, results, items):
            tries = 0
            while res.circuit is None and tries < 64:
                tries += 1
                self.nom_setup_retries += 1
                retry = dataclasses.replace(rq, cycle=None)
                (res,), _rep = self.fabric.schedule(
                    [retry], cycle=rq.cycle + tries * p.n_slots)
            c = res.circuit
            if c is None:
                self._record_inflight(spans)
                err = FabricOverflow(
                    f"NoM mesh persistently saturated: no circuit for "
                    f"{r.op.name} {rq.src}->{rq.dst} ({rq.nbytes}B) after "
                    f"{tries} retry windows from cycle {rq.cycle}")
                err.request = r
                err.retries = tries
                err.telemetry = {
                    "queue_depth": self.ccu.depth,
                    "queue_stall_cycles": self.ccu.stall_cycles,
                    "setup_retries": self.nom_setup_retries,
                    "table_utilization": self.alloc.table.utilization(
                        (rq.cycle + 3) // p.n_slots),
                }
                raise err
            w_start = c.start_cycle // p.n_slots   # actual streaming window
            spans.append((w_start, c.n_windows))
            if rq.op == "init":
                # Zero-hop circuit: the bank clears rows internally
                # (RowClone-FPM) while the circuit holds its LOCAL port;
                # nothing streams over mesh links.  The circuit's window
                # count is calibrated (init_windows_per_row windows per
                # row) so occupancy covers the modeled zeroing latency.
                self.nom_init_windows += c.n_windows
                vc, b = self._vault_bank(r.src_bank)
                rows = max(1, -(-r.nbytes // t.row_bytes))
                self.init_rows += rows
                self.init_bytes += r.nbytes
                done = c.start_cycle
                for _ in range(rows):
                    done = vc.bank_row_op(done, b, t.rowclone_fpm)
                dones.append(done)
                continue
            dist = max(c.distance, 1)
            # transfer duration in NoM-link cycles, scaled by link frequency.
            link_cycles = dist + (c.n_windows - 1) * p.n_slots
            xfer_done = c.start_cycle + int(np.ceil(link_cycles
                                                    / p.nom_link_ratio))
            if rq.op == "reduce":
                dones.append(self._finish_reduce(rq, r, c, xfer_done))
                continue
            link_slots = getattr(c, "link_slots", None)
            if link_slots:
                # Cross-stack: only the two mesh segments move beats over
                # TSV/mesh links; the SerDes share is accounted per
                # directed channel hop for the energy model.
                mesh_hops = (len(c.near_hops) - 1) + (len(c.far_hops) - 1)
                self.nom_hop_beats += (r.nbytes // 8) * mesh_hops
                self.serdes_bytes += r.nbytes * len(link_slots)
                self.serdes_windows += c.n_windows * len(link_slots)
                self.nom_cross_stack += 1
            else:
                self.nom_hop_beats += (r.nbytes // 8) * dist
            s_loc = self._locate(r.src_bank)[1]
            d_loc = self._locate(r.dst_bank)[1]
            if self.p.config == "nom":
                # dedicated-Z-link vertical beats (for the TSV dual-use
                # stat); a cross-stack copy descends to the near bridge on
                # layer 0 and climbs to the destination layer far-side.
                sz = self.mesh.coords(s_loc)[2]
                dz = self.mesh.coords(d_loc)[2]
                vert = (sz + dz) if link_slots else abs(sz - dz)
                self.nom_vertical_cycles += vert * (r.nbytes // 8)
            elif c.uses_bus and c.bus_column >= 0:
                # NoM-Light: the vertical hop rides the existing TSV of that
                # column's vault, stealing bandwidth from regular accesses —
                # the bandwidth cost behind the paper's 5-20% gap.
                col_bank = c.bus_column  # a z=0 bank id shares the column idx
                if self.stacked:   # map the stack-local column to its stack
                    col_bank = self.topology.global_id(
                        self._locate(r.src_bank)[0], col_bank)
                vc, _b = self._vault_bank(col_bank)
                vc._tsv(c.start_cycle, r.nbytes // 8)
            # 4) destination write via the copy queue.
            dvc, db = self._vault_bank(r.dst_bank)
            dones.append(dvc.bank_row_op(xfer_done, db, t.tRCD + t.tWR))
        self._record_inflight(spans)
        return dones


def simulate(reqs: list[Request], p: SimParams, name: str = "") -> SimResult:
    """Run the closed-loop core over the request stream.

    Under the NoM configs, inter-bank copies *and* bulk initializations
    accumulate in the CCU's bounded request queue (``sys.ccu``, depth
    ``p.nom_ccu_queue_depth``) and are drained by a single batched
    circuit setup (``copy_nom_batch``) — the paper's concurrent circuit
    establishment, over its mixed copy/INIT workload.  A request issued
    against a full queue backpressures the core until the drain's pickup
    pipeline completes; the lost cycles are reported as
    ``extra["nom_ccu_stall_cycles"]``, and the INIT share of the queue
    and of the TDM windows as ``extra["nom_ccu_init_*"]``."""
    sys = MemorySystem(p)
    t = p.timing
    outstanding: list[int] = []   # completion-time min-heap
    core_time = 0
    total_instr = 0               # config-independent instruction count
    copy_bytes = 0
    nom = p.config in ("nom", "nom_light")

    def flush_copies(pickup_at: int = 0):
        if sys.ccu.items:
            for done in sys.copy_nom_batch(sys.ccu.items, pickup_at):
                heapq.heappush(outstanding, done)
            sys.ccu.items.clear()

    def enqueue_nom(issue: int, r: Request) -> int:
        """Admit a copy/INIT into the bounded CCU queue.  The depth
        bounds both dimensions of the CCU's service budget — at most
        ``depth`` buffered requests, and the head waits at most ``depth``
        TDM windows before its batched pickup pass (the concurrent
        circuit establishment).  A request that finds the buffer at depth
        forces an early drain and backpressures the core until the pickup
        pipeline completes.  Returns the (possibly stalled) issue cycle."""
        q = sys.ccu
        if q.items and (issue // p.n_slots
                        - q.items[0][0] // p.n_slots) >= q.depth:
            flush_copies()
        if q.full():
            flush_copies(pickup_at=issue)
            freed = max(issue, q.busy_until)
            q.stall_cycles += freed - issue
            q.full_stalls += 1
            issue = freed
        q.push(issue, r)
        return issue

    for r in reqs:
        # Respect the MLP window (queued CCU copies count as outstanding).
        while len(outstanding) + len(sys.ccu.items) >= p.window:
            if not outstanding:   # only CCU-queued copies left: materialize
                flush_copies()
                continue
            core_time = max(core_time, heapq.heappop(outstanding))
        issue = core_time = core_time + p.compute_gap
        total_instr += p.compute_gap

        if r.op in (Op.READ, Op.WRITE):
            total_instr += 1
            done = sys.line_access(issue, r.src_bank, r.src_row,
                                   r.op == Op.WRITE)
        elif r.op == Op.INIT:
            total_instr += r.nbytes // LINE * 1  # conventional stores
            copy_bytes += r.nbytes
            if p.config == "conventional":
                done = sys.copy_conventional(issue, r, write_only=True)
            elif not nom:
                done = sys.copy_in_dram_local(issue, r)
            else:
                # INIT rides the CCU queue too: the zeroing is still
                # in-bank (RowClone-FPM), but issue/admission shares the
                # bounded buffer with copies, and the zero-hop circuit's
                # occupancy lands in the nom_ccu_* telemetry.
                core_time = max(core_time, enqueue_nom(issue, r))
                continue
        elif r.op == Op.REDUCE:
            k = max(1, len(r.src_banks))
            # k loads + 1 accumulate-store per line, config-independent.
            total_instr += r.nbytes // LINE * (k + 1)
            copy_bytes += r.nbytes * k
            if nom:
                core_time = max(core_time, enqueue_nom(issue, r))
                continue
            done = sys.reduce_processor(issue, r)
        else:  # COPY
            total_instr += r.nbytes // LINE * p.instr_per_line
            copy_bytes += r.nbytes
            if p.config == "conventional":
                done = sys.copy_conventional(issue, r)
            elif r.intra_bank:
                done = sys.copy_in_dram_local(issue, r)
            elif p.config == "rowclone":
                done = sys.copy_rowclone_psm(issue, r)
            else:
                core_time = max(core_time, enqueue_nom(issue, r))
                continue
        heapq.heappush(outstanding, done)

    flush_copies()
    while outstanding:
        core_time = max(core_time, heapq.heappop(outstanding))
    cycles = max(1, core_time)

    tsv_busy = sum(v.tsv_busy_cycles for v in sys.vaults)
    tsv_frac = tsv_busy / (cycles * len(sys.vaults))
    # Probability that a dedicated-Z NoM beat coincides with TSV activity —
    # the observation motivating NoM-Light (Section 2.3).
    conflict = (sys.nom_vertical_cycles / max(cycles, 1)) * tsv_frac
    hit = float(np.mean([v.row_hit_rate for v in sys.vaults]))
    inflight_avg, inflight_max = sys.inflight_stats()
    extra = {}
    if p.config != "conventional":
        # In-DRAM zeroing (RowClone-FPM): rows cleared (charged e_init_row
        # each by the energy model) and the bytes they covered (excluded
        # from the per-line column-I/O energy — nothing left the mats).
        extra["init_rows"] = sys.init_rows
        extra["init_bytes"] = sys.init_bytes
    if nom:
        extra |= {
            "nom_inflight_avg": inflight_avg,
            "nom_inflight_max": int(inflight_max),
            "nom_alloc_conflicts": sys.nom_alloc_conflicts,
            "nom_setup_retries": sys.nom_setup_retries,
            "nom_fused_waves": sys.nom_fused_waves,
            "nom_host_waves": sys.nom_host_waves,
            "nom_batches": sys.nom_batches,
            "nom_batch_avg": (sys.nom_batched_reqs / sys.nom_batches
                              if sys.nom_batches else 0.0),
            "nom_ccu_queue_depth": sys.ccu.depth,
            "nom_ccu_peak_queue": sys.ccu.peak_occupancy,
            "nom_ccu_full_stalls": sys.ccu.full_stalls,
            "nom_ccu_stall_cycles": sys.ccu.stall_cycles,
            # INIT-class occupancy, separately: how much of the bounded
            # queue and of the TDM windows the initialization traffic eats.
            "nom_ccu_init_reqs": sys.ccu.init_reqs,
            "nom_ccu_init_peak": sys.ccu.peak_init,
            "nom_ccu_init_windows": sys.nom_init_windows,
            # Compute-class occupancy: destination-bank ALU merges and
            # the cycles fan-ins lost to a still-draining ALU.
            "nom_reduce_elems": sys.nom_reduce_elems,
            "nom_reduce_stalls": sys.nom_reduce_stalls,
        }
    if nom and p.stacks > 1:
        seg = sys.fabric.segmented
        extra |= {
            "n_stacks": p.stacks,
            "nom_cross_stack": sys.nom_cross_stack,
            "serdes_windows": sys.serdes_windows,
            "serdes_bytes": sys.serdes_bytes,
            "serdes_rollbacks": seg.rollbacks,
            "serdes_denied": seg.denied,
        }
    return SimResult(
        name=name, config=p.config, cycles=cycles, instructions=total_instr,
        ipc=total_instr / cycles, reqs=len(reqs), copy_bytes=copy_bytes,
        offchip_bytes=sys.offchip.bytes_moved, nom_hop_beats=sys.nom_hop_beats,
        bus_busy_cycles=sys.shared_bus.busy_cycles, tsv_busy_frac=tsv_frac,
        tsv_conflict_frac=conflict, row_hit_rate=hit, extra=extra)
