"""DRAM bank / vault timing model for the HMC-like baseline (Section 3).

Timing is expressed in *logic-layer cycles* at 1.25 GHz (0.8 ns), with
DDR3-1600-derived latencies (paper: "circuit-level parameters and memory
timing parameters are set based on DDR3 DRAM").  The model captures what the
paper's evaluation depends on: row-buffer hits/misses, per-bank service
serialization, per-vault TSV-bus beats, and a priority Copy queue next to the
regular R/W queue in every vault controller (Fig. 2, bottom right).
"""
from __future__ import annotations

import dataclasses

import numpy as np

LOGIC_GHZ = 1.25
NS = LOGIC_GHZ  # cycles per nanosecond


def ns(x: float) -> int:
    return int(round(x * NS))


@dataclasses.dataclass(frozen=True)
class Timing:
    """DDR3-1600-ish latencies in 1.25 GHz logic cycles."""
    tCL: int = ns(13.75)     # CAS
    tRCD: int = ns(13.75)    # activate -> column
    tRP: int = ns(13.75)     # precharge
    tRAS: int = ns(35.0)     # activate -> precharge
    tBURST: int = 8          # 64B over a 64-bit internal bus, 8 beats
    tWR: int = ns(15.0)      # write recovery
    # In-DRAM copy primitives (integrated into all non-conventional configs):
    rowclone_fpm: int = ns(90.0)    # intra-subarray row copy (RowClone FPM)
    lisa_hop: int = ns(8.0)         # per-subarray-hop row relocation (LISA)
    # Off-chip round trip for processor-mediated copies.
    offchip_latency: int = ns(60.0)
    offchip_bytes_per_cycle: float = 16.0   # ~20 GB/s effective per direction

    row_bytes: int = 8192
    line_bytes: int = 64


@dataclasses.dataclass
class BankState:
    free_at: int = 0
    open_row: int = -1


class Bank:
    """Row-buffer-aware single bank."""

    def __init__(self, timing: Timing):
        self.t = timing
        self.s = BankState()
        self.accesses = 0
        self.row_hits = 0

    def access(self, at: int, row: int, is_write: bool) -> tuple[int, int]:
        """Schedule a 64B column access; returns (data_ready, bank_free).

        Row-buffer hits pipeline at burst occupancy (tCCD~tBURST); tCL is
        latency, not occupancy.  Write recovery is charged on the precharge
        path (row change), as in DDR3 bank state machines.
        """
        t = self.t
        start = max(at, self.s.free_at)
        if self.s.open_row == row:
            lat = t.tCL
            self.row_hits += 1
        elif self.s.open_row < 0:
            lat = t.tRCD + t.tCL
        else:
            lat = t.tRP + t.tWR + t.tRCD + t.tCL
        self.s.open_row = row
        ready = start + lat + t.tBURST
        self.s.free_at = start + (lat - t.tCL) + t.tBURST  # occupancy only
        self.accesses += 1
        return ready, self.s.free_at

    def row_op(self, at: int, cycles: int) -> int:
        """Occupy the bank for an in-DRAM row-granularity operation."""
        start = max(at, self.s.free_at)
        self.s.free_at = start + cycles
        self.s.open_row = -1   # row ops end precharged
        self.accesses += 1
        return self.s.free_at


class VaultController:
    """One vault: a TSV data bus shared by its banks, plus two queues.

    Copy-related reads/writes go to a high-priority queue (the paper's Copy
    Q); in this timestamp model priority manifests as copy traffic not
    waiting behind queued regular requests, only behind in-flight bus beats.
    """

    def __init__(self, timing: Timing, n_banks: int):
        self.t = timing
        self.banks = [Bank(timing) for _ in range(n_banks)]
        self.tsv_free_at = 0
        self.tsv_busy_cycles = 0
        self.regular_backlog_at = 0

    def _tsv(self, at: int, beats: int) -> int:
        start = max(at, self.tsv_free_at)
        self.tsv_free_at = start + beats
        self.tsv_busy_cycles += beats
        return self.tsv_free_at

    def access_line(self, at: int, bank: int, row: int, is_write: bool,
                    priority: bool = False) -> int:
        """64B access; returns cycle at which data has crossed the TSV.

        Contention is carried by the bank (burst occupancy, row misses) and
        the TSV bus (beat occupancy); the controller itself pipelines, so no
        additional serialization is imposed here.
        """
        del priority  # priority shows up as not using the TSV at all (row ops)
        ready, _free = self.banks[bank].access(at, row, is_write)
        return self._tsv(ready, self.t.tBURST)

    def bank_row_op(self, at: int, bank: int, cycles: int) -> int:
        return self.banks[bank].row_op(at, cycles)

    @property
    def row_hit_rate(self) -> float:
        a = sum(b.accesses for b in self.banks)
        h = sum(b.row_hits for b in self.banks)
        return h / max(1, a)


class OffChipLink:
    """Processor<->memory SerDes path (full duplex: independent up/down
    lanes, as in HMC SerDes links).  ``transfer`` occupies one lane for the
    serialization time and returns the arrival cycle (occupancy + latency)."""

    def __init__(self, timing: Timing):
        self.t = timing
        self.lane_free = [0, 0]   # 0: memory->cpu (read data), 1: cpu->memory
        self.bytes_moved = 0

    def transfer(self, at: int, nbytes: int, down: bool = False) -> int:
        lane = 1 if down else 0
        start = max(at, self.lane_free[lane])
        dur = int(np.ceil(nbytes / self.t.offchip_bytes_per_cycle))
        self.lane_free[lane] = start + dur
        self.bytes_moved += nbytes
        return start + dur + self.t.offchip_latency

    @property
    def free_at(self) -> int:
        return max(self.lane_free)


class SharedInternalBus:
    """The global internal bus RowClone PSM uses for inter-bank copies.

    It is *reserved* for the whole copy ("other memory requests ... are
    therefore delayed"): one copy at a time, serializing with every other
    inter-bank copy in the chip.
    """

    def __init__(self):
        self.free_at = 0
        self.busy_cycles = 0

    def reserve(self, at: int, cycles: int) -> tuple[int, int]:
        start = max(at, self.free_at)
        self.free_at = start + cycles
        self.busy_cycles += cycles
        return start, self.free_at
