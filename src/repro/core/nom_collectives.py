"""NOM-scheduled collectives — the paper's technique as a TPU feature.

The paper replaces a shared bus with a mesh of neighbour links plus a
*central scheduler* that assigns conflict-free, time-slotted routes to bulk
transfers.  On a TPU pod the ICI fabric is exactly such a mesh (2D/3D
torus); this module applies NoM's scheduling discipline to JAX collectives:

* :func:`nom_all_to_all` — all-to-all (the MoE dispatch pattern) decomposed
  into uniform-shift ``ppermute`` *rounds*.  One round = one TDM slot: every
  directed ring link carries exactly one chunk, so rounds are conflict-free
  by construction, and a shift-by-r round pipelines r neighbour hops exactly
  like the paper's increasing-slot circuits.  Per-link traffic is the ring
  lower bound (sum of r over both directions ~ N^2/8 chunks each way) versus
  whatever opaque schedule ``lax.all_to_all`` compiles to — this is the
  "shared bus vs NoM" comparison, reborn.
* :class:`TransferPlan` — the CCU re-used as a host-side planner for bulk
  shard migration (checkpoint resharding, elastic scaling): arbitrary
  (src, dst) transfer sets are routed DOR over the device mesh and packed
  into link-disjoint rounds via greedy earliest-slot allocation, the same
  increasing-slot invariant as :mod:`repro.core.slot_alloc`.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import jax
import numpy as np
from jax import lax
from jax import numpy as jnp


# ---------------------------------------------------------------------------
# nom_all_to_all: scheduled ppermute rounds (device-side, shard_map body)
# ---------------------------------------------------------------------------
def ring_offsets(n: int) -> list[int]:
    """Shift offsets of the bidirectional ring schedule for axis size n.

    Positive r moves chunks r steps "right", negative r "left"; together
    they cover every non-zero destination distance exactly once."""
    offs: list[int] = []
    for r in range(1, n // 2 + 1):
        offs.append(r)
        if r != n - r:                 # n even: distance n/2 sent one way only
            offs.append(-(r))
    # distances r and n-r coincide for r = n/2 (even n); for odd n the loop
    # above yields 1..n//2 and -(1..n//2) = n-1..ceil covering all.
    return offs


def nom_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """Drop-in for ``lax.all_to_all(x, axis_name, 0, 0)`` on one mesh axis.

    ``x`` has leading dim = axis size N; chunk ``x[j]`` is destined for the
    device at position j on the axis.  Returns ``out`` with ``out[j]`` =
    chunk received from device j.  Must be called inside ``shard_map`` (or
    any context where ``axis_name`` is bound).
    """
    n = lax.psum(1, axis_name)
    if isinstance(n, jax.Array):       # symbolic under some tracers
        n = int(n)
    idx = lax.axis_index(axis_name)
    out = jnp.zeros_like(x)
    # Self chunk stays local (the paper's intra-bank copy short-circuit).
    self_chunk = lax.dynamic_index_in_dim(x, idx, axis=0, keepdims=False)
    out = lax.dynamic_update_index_in_dim(out, self_chunk, idx, axis=0)
    for r in ring_offsets(n):
        perm = [(j, (j + r) % n) for j in range(n)]
        send = lax.dynamic_index_in_dim(x, (idx + r) % n, axis=0,
                                        keepdims=False)
        recv = lax.ppermute(send, axis_name, perm)
        out = lax.dynamic_update_index_in_dim(out, recv, (idx - r) % n,
                                              axis=0)
    return out


def nom_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """All-gather as N-1 single-hop ring rounds (TDM slot per round)."""
    n = lax.psum(1, axis_name)
    if isinstance(n, jax.Array):
        n = int(n)
    idx = lax.axis_index(axis_name)
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = lax.dynamic_update_index_in_dim(out, x, idx, axis=0)
    perm = [(j, (j + 1) % n) for j in range(n)]
    cur = x
    for r in range(1, n):
        cur = lax.ppermute(cur, axis_name, perm)
        out = lax.dynamic_update_index_in_dim(out, cur, (idx - r) % n, axis=0)
    return out


def nom_reduce_scatter(x: jax.Array, axis_name: str) -> jax.Array:
    """Reduce-scatter (sum) as N-1 shift-accumulate ring rounds.

    ``x``: (N, ...) per-device partial sums; returns this device's reduced
    chunk.  Round r forwards the running partial for the chunk that is r
    hops from home, adding the local contribution as it passes through —
    data advances one hop per round, the increasing-slot circuit again.
    """
    n = lax.psum(1, axis_name)
    if isinstance(n, jax.Array):
        n = int(n)
    idx = lax.axis_index(axis_name)
    perm = [(j, (j + 1) % n) for j in range(n)]
    # The partial for destination d starts at its farthest contributor
    # (d+1 mod n) and flows +1, gathering each device's x[d] as it passes;
    # device i therefore seeds the partial for d = i-1.
    acc = lax.dynamic_index_in_dim(x, (idx - 1) % n, axis=0, keepdims=False)
    for k in range(1, n):
        acc = lax.ppermute(acc, axis_name, perm)
        mine = lax.dynamic_index_in_dim(x, (idx - 1 - k) % n, axis=0,
                                        keepdims=False)
        acc = acc + mine
    return acc


def nom_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce (sum) as reduce-scatter + all-gather ring rounds.

    The device-level spelling of the compute-class NoM op: the vector is
    split into N bank-homed shards, each shard's partials flow to its
    home and are merged *in transit* (:func:`nom_reduce_scatter` — the
    fan-in circuit), then the reduced shards are gathered back
    (:func:`nom_all_gather`).  Works on any ``x`` shape (padded
    internally to a multiple of the axis size); must be called inside
    ``shard_map`` with ``axis_name`` bound.  Equals
    ``lax.psum(x, axis_name)`` up to float summation order — the ring
    order is fixed, so results are bitwise-reproducible run to run.
    """
    n = lax.psum(1, axis_name)
    if isinstance(n, jax.Array):
        n = int(n)
    if n == 1:
        return x
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    mine = nom_reduce_scatter(flat.reshape(n, -1), axis_name)
    full = nom_all_gather(mine, axis_name).reshape(-1)
    if pad:
        full = full[:-pad]
    return full.reshape(x.shape)


def nom_reduce(fabric, srcs, dst: int, nbytes: int = 1, cycle=None):
    """One memory-side fan-in on a fabric session: ``nbytes`` operands
    from each bank in ``srcs`` merged at ``dst`` over a compute-class
    circuit.  The planner spelling every subsystem should use (raw
    ``op="reduce"`` construction outside ``core/`` is CI-banned).
    Returns ``(AllocResult, ScheduleReport)``."""
    from .scheduler import reduce_request
    (res,), report = fabric.schedule(
        [reduce_request(srcs, dst, nbytes=nbytes)], cycle=cycle)
    return res, report


def nom_allreduce_banks(fabric, banks, nbytes: int, cycle=None):
    """Memory-side all-reduce of an ``nbytes`` vector replicated across
    ``banks``: a reduce-scatter batch (each bank is the fan-in
    destination of its own shard) followed by an all-gather batch (each
    bank streams its reduced shard to every peer).  Both batches go
    through ``fabric.schedule``, so they pack under the session policy
    and land in its telemetry.  Returns ``(results, report)`` with the
    scatter results first and the two batch reports merged."""
    from .scheduler import TransferRequest, reduce_request
    banks = [int(b) for b in banks]
    if len(set(banks)) != len(banks):
        raise ValueError(f"all-reduce banks must be distinct: {banks}")
    if len(banks) < 2:
        raise ValueError("all-reduce needs at least two banks")
    shard = -(-nbytes // len(banks))
    scatter = [reduce_request([s for s in banks if s != d], d, nbytes=shard,
                              tag=("reduce_scatter", d))
               for d in banks]
    res1, rep1 = fabric.schedule(scatter, cycle=cycle)
    gather = [TransferRequest(src=d, dst=o, nbytes=shard,
                              tag=("allgather", d, o))
              for d in banks for o in banks if o != d]
    res2, rep2 = fabric.schedule(gather)
    return res1 + res2, rep1.merge(rep2)


def a2a_link_chunks(n: int) -> dict[str, float]:
    """Per-link chunk counts for the analysis tables: NoM ring schedule vs
    a naive single-shot schedule that serializes on one 'bus' hop."""
    per_dir = sum(r for r in range(1, n // 2 + 1))
    if n % 2 == 0:
        per_dir_left = sum(r for r in range(1, (n - 1) // 2 + 1))
    else:
        per_dir_left = per_dir
    return {"nom_right": per_dir, "nom_left": per_dir_left,
            "bus_serialized": n * (n - 1) / 2.0}


# ---------------------------------------------------------------------------
# TransferPlan: the CCU as a bulk-reshard scheduler (host-side)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Transfer:
    src: tuple[int, ...]
    dst: tuple[int, ...]
    nbytes: int = 1
    tag: object = None


def _dor_path(src: tuple[int, ...], dst: tuple[int, ...],
              shape: tuple[int, ...], torus: bool) -> list[tuple[tuple, int, int]]:
    """Dimension-ordered route; returns [(node, dim, step), ...] hops."""
    hops = []
    cur = list(src)
    for d in range(len(shape)):
        delta = dst[d] - cur[d]
        if torus and abs(delta) > shape[d] // 2:
            delta -= int(np.sign(delta)) * shape[d]
        step = 1 if delta > 0 else -1
        for _ in range(abs(delta)):
            hops.append((tuple(cur), d, step))
            cur[d] = (cur[d] + step) % shape[d]
    return hops


@dataclasses.dataclass
class TransferPlan:
    """Conflict-free multi-round schedule for a set of point-to-point bulk
    transfers on a device mesh/torus.

    ``rounds[k]`` lists (transfer_index, hop) pairs active in round k; a hop
    is (node, dim, step).  Invariants (tested): within a round every
    directed link appears at most once, and each transfer's i-th hop runs in
    round start_i + i (data advances one hop per round with no buffering —
    the paper's increasing-slot rule).
    """
    shape: tuple[int, ...]
    torus: bool
    transfers: list[Transfer]
    starts: list[int]
    paths: list[list[tuple]]

    @property
    def n_rounds(self) -> int:
        return max((s + len(p) for s, p in zip(self.starts, self.paths)),
                   default=0)

    def rounds(self) -> list[list[tuple[int, tuple]]]:
        out: list[list[tuple[int, tuple]]] = [[] for _ in range(self.n_rounds)]
        for i, (s, path) in enumerate(zip(self.starts, self.paths)):
            for j, hop in enumerate(path):
                out[s + j].append((i, hop))
        return out

    def link_utilization(self) -> float:
        n_links = int(np.prod(self.shape)) * 2 * len(self.shape)
        used = sum(len(p) for p in self.paths)
        return used / max(1, n_links * self.n_rounds)

    def concurrency(self) -> dict[str, float]:
        """In-flight transfers per round — the schedule's concurrency
        profile (a transfer is in flight from its start round until its
        last hop)."""
        active = [0] * self.n_rounds
        for s, path in zip(self.starts, self.paths):
            for j in range(len(path)):
                active[s + j] += 1
        busy = [a for a in active if a]
        return {"max_inflight": float(max(busy, default=0)),
                "avg_inflight": float(np.mean(busy)) if busy else 0.0}


def plan_transfers(shape: tuple[int, ...], transfers: list[Transfer],
                   torus: bool = True, policy: str = "longest_first",
                   order: list[int] | None = None,
                   busy: dict[tuple, set[int]] | None = None,
                   base: int = 0) -> TransferPlan:
    """Greedy TDM scheduling: earliest conflict-free start slot per
    transfer (the unrolled-time version of the CCU's slot allocation — a
    transfer that loses a slot to an earlier reservation retries at the
    next start round, the increasing-slot fallback).

    ``policy``: "longest_first" sorts by descending path length (best
    packing); "arrival" keeps request order (the CCU's FIFO commit rule,
    matching ``TdmAllocator.allocate_batch``).  An explicit ``order``
    (a permutation of the transfer indices — how
    `repro.core.fabric.NomFabric` applies its registered policies)
    overrides ``policy``.

    ``busy`` (link -> set of *absolute* rounds) makes link reservations
    persistent across calls: pass the same mapping again and this batch
    packs around what earlier batches still hold — how ``NomFabric``'s
    rounds backend models back-to-back batches contending like the tdm
    backend does.  The batch is anchored at absolute round ``base`` and
    new reservations are recorded at ``base + start + hop``; the returned
    plan's ``starts`` stay batch-relative.  ``busy=None`` (default) keeps
    the original one-shot behavior (a private map, nothing persists)."""
    paths = [_dor_path(t.src, t.dst, shape, torus) for t in transfers]
    if order is not None:
        order = list(order)
    elif policy == "longest_first":
        order = sorted(range(len(transfers)), key=lambda i: -len(paths[i]))
    elif policy == "arrival":
        order = list(range(len(transfers)))
    else:
        raise ValueError(f"unknown policy {policy!r}")
    if busy is None:
        busy = defaultdict(set)   # link -> set of rounds (this call only)
    starts = [0] * len(transfers)
    for i in order:
        path = paths[i]
        if not path:
            continue
        s = 0
        while True:
            if all(base + s + j not in busy.get(hop, ())
                   for j, hop in enumerate(path)):
                break
            s += 1
        starts[i] = s
        for j, hop in enumerate(path):
            busy.setdefault(hop, set()).add(base + s + j)
    return TransferPlan(shape=shape, torus=torus, transfers=transfers,
                        starts=starts, paths=paths)
