"""`NomFabric`: the stateful session API for all NoM traffic.

The paper's premise is that the memory controller sets up TDM circuits
*centrally*: one authority owns the topology, the slot tables, and the
arbitration policy, and every consumer negotiates with it.  This module
is that authority as a library object.  Where `schedule_transfers` was a
kwargs-heavy free function re-invoked independently by every subsystem,
a :class:`NomFabric` is a long-lived session that owns

* the **topology** and its allocator — a
  :class:`~repro.core.slot_alloc.TdmAllocator` over a
  :class:`~repro.core.topology.Mesh3D` (bank level, ``backend="tdm"``)
  or a device mesh/torus routed by
  :func:`~repro.core.nom_collectives.plan_transfers` (device level,
  ``backend="rounds"``);
* a named **packing-policy registry** (:func:`register_policy`) —
  ``"arrival"`` (the CCU's FIFO commit rule) and ``"longest_first"``
  (descending route distance, best packing) ship registered; new
  policies are addable without touching core;
* a bounded **admission queue** (:class:`AdmissionQueue` — the CCU's
  request buffering, previously private to the memory simulator) with
  configurable ``"shed"`` / ``"block"`` / ``"raise"`` overflow behavior;
* cumulative :class:`~repro.core.scheduler.ScheduleReport` telemetry
  over the session's lifetime, and a ``policy="auto"`` mode that picks
  the packing policy *and* the effective queue depth per workload from
  the observed ``stall_cycles`` history (the controller-side arbitration
  state that the HMC NoC studies identify as what determines throughput
  under concurrency).

Every production subsystem — the serving engine, `BankPool` repack, MoE
dispatch planning, checkpoint reshard, the memory simulator's CCU —
holds or constructs a fabric; ``schedule_transfers`` survives only as a
deprecated one-shot shim over this class (enforced by
``scripts/check_api.py``).  See ``docs/fabric.md``.
"""
from __future__ import annotations

import dataclasses

from .nom_collectives import _dor_path, plan_transfers
from .scheduler import (ScheduleReport, _as_copy_requests, _as_transfers,
                        _tdm_report)
from .slot_alloc import TdmAllocator
from .topology import Mesh3D


class FabricOverflow(RuntimeError):
    """Raised by ``overflow="raise"`` fabrics when an admission would
    exceed the bounded queue (or, via the serving engine, the bank
    pool's tenant capacity)."""


# ---------------------------------------------------------------------------
# Packing-policy registry
# ---------------------------------------------------------------------------
class PolicyContext:
    """What a packing policy may look at besides the requests themselves.

    Attributes:
      backend: ``"tdm"`` or ``"rounds"``.
      distances: per-request route length in hops — Manhattan distance on
        the bank mesh (0 for an in-place INIT), DOR path length on the
        device mesh — the quantity ``longest_first`` sorts by.  Computed
        on first access, so distance-blind policies (``"arrival"``) pay
        nothing for it.
    """

    def __init__(self, backend: str, distance_fn):
        self.backend = backend
        self._distance_fn = distance_fn
        self._distances: tuple[int, ...] | None = None

    @property
    def distances(self) -> tuple[int, ...]:
        if self._distances is None:
            self._distances = tuple(self._distance_fn())
        return self._distances


_POLICIES: dict[str, object] = {}


def register_policy(name: str):
    """Decorator registering a packing policy under ``name``.

    A policy is ``fn(requests, ctx: PolicyContext) -> iterable[int]``
    returning the *commit order* — a permutation of ``range(len(
    requests)))``.  Earlier positions win slot/link contention (the
    batched commit reserves in this order; results always come back in
    request order).  Registering an already-taken name raises
    ``ValueError``; remove experimental policies with
    :func:`unregister_policy`.
    """
    def deco(fn):
        if name in _POLICIES:
            raise ValueError(f"policy {name!r} is already registered")
        _POLICIES[name] = fn
        return fn
    return deco


def unregister_policy(name: str) -> None:
    """Remove a registered policy (the built-ins may not be removed)."""
    if name in ("arrival", "longest_first"):
        raise ValueError(f"built-in policy {name!r} may not be removed")
    if name not in _POLICIES:
        raise ValueError(f"policy {name!r} is not registered")
    del _POLICIES[name]


def registered_policies() -> tuple[str, ...]:
    """Names currently in the registry, registration order."""
    return tuple(_POLICIES)


def get_policy(name: str):
    """Look up a policy by name; unknown names raise ``ValueError``
    listing what is registered (``"auto"`` is a fabric mode, not a
    registry entry)."""
    try:
        return _POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; registered: "
            f"{', '.join(_POLICIES)} (or 'auto')") from None


@register_policy("arrival")
def _arrival(reqs, ctx: PolicyContext):
    """FIFO — the CCU's commit rule (paper Section 2.2)."""
    return range(len(reqs))


@register_policy("longest_first")
def _longest_first(reqs, ctx: PolicyContext):
    """Descending route distance (stable): long circuits reserve first,
    short ones fill the remaining slots — best packing on most mixes."""
    return sorted(range(len(reqs)), key=lambda i: -ctx.distances[i])


# ---------------------------------------------------------------------------
# Bounded admission queue (the CCU's request buffering, shared with memsim)
# ---------------------------------------------------------------------------
def _is_init(payload) -> bool:
    """INIT-class detection across both request vocabularies: the
    scheduler's ``op="init"`` strings and the simulator's ``Op.INIT``
    enum (matched by name so core never imports memsim)."""
    op = getattr(payload, "op", "copy")
    return op == "init" or getattr(op, "name", "") == "INIT"


@dataclasses.dataclass
class AdmissionQueue:
    """The bounded request queue in front of a circuit-setup authority.

    Pending requests sit here (with their arrival cycles) until a drain
    services them in one batched setup pass.  ``depth`` bounds the
    buffer; what happens to an admission that finds it full is the
    ``overflow`` behavior — ``"block"`` (force a drain and stall the
    issuer until the pickup pipeline completes; the memsim CCU's
    backpressure), ``"shed"`` (drop the request, count it), or
    ``"raise"`` (:class:`FabricOverflow`).  INIT-class occupancy is
    accounted separately, as in the simulator's CCU telemetry.
    """
    depth: int
    overflow: str = "block"
    items: list = dataclasses.field(default_factory=list)  # (cycle, payload)
    busy_until: int = 0        # front-end pickup pipeline drain time
    stall_cycles: int = 0      # issuer cycles lost to queue-full blocking
    full_stalls: int = 0       # admissions that hit a full queue
    n_shed: int = 0            # admissions dropped by overflow="shed"
    peak_occupancy: int = 0
    init_reqs: int = 0
    peak_init: int = 0

    def __post_init__(self):
        if self.overflow not in ("block", "shed", "raise"):
            raise ValueError(f"unknown overflow behavior {self.overflow!r}; "
                             "choose from ('block', 'shed', 'raise')")

    def full(self) -> bool:
        return len(self.items) >= self.depth

    def push(self, at: int, payload) -> None:
        assert not self.full(), "push on a full admission queue (drain first)"
        self.items.append((at, payload))
        self.peak_occupancy = max(self.peak_occupancy, len(self.items))
        if _is_init(payload):
            self.init_reqs += 1
            n = sum(1 for _at, q in self.items if _is_init(q))
            self.peak_init = max(self.peak_init, n)


# ---------------------------------------------------------------------------
# The session object
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class NomFabric:
    """One stateful session owning all NoM traffic of a subsystem.

    Exactly one of ``mesh`` / ``allocator`` (bank level) or ``shape``
    (device level) selects the backend.  ``schedule`` is the synchronous
    batch path every migrated call site uses; ``submit`` / ``flush`` is
    the admission-queue path (the CCU discipline: requests buffer up to
    ``queue_depth``, then one batched setup drains them).

    Attributes:
      mesh: bank-level topology; a :class:`TdmAllocator` is built over it
        (``n_slots`` TDM slots) unless ``allocator`` is given directly.
      allocator: pre-built allocator (e.g. a ``TdmAllocatorLight``); the
        fabric adopts it, topology included.
      shape, torus: device-level topology for the rounds backend.
      policy: registered packing-policy name, or ``"auto"`` to pick per
        workload from stall history (see below).
      queue_depth: admission-queue capacity (``"auto"`` adapts the live
        depth between ``min_queue_depth`` and ``max_queue_depth``).
      overflow: full-queue behavior — ``"block"`` | ``"shed"`` |
        ``"raise"``.
      auto_candidates: policies ``"auto"`` chooses among.
      probe_flushes: flushes spent measuring each candidate before
        exploiting; retune_every: exploit flushes between re-probes.
      keep_history: per-flush reports retained on ``history`` (the
        cumulative ``report`` is exact regardless).
    """
    mesh: Mesh3D | None = None
    shape: tuple[int, ...] | None = None
    torus: bool = True
    n_slots: int = 16
    allocator: TdmAllocator | None = None
    policy: str = "arrival"
    queue_depth: int = 8
    overflow: str = "block"
    auto_candidates: tuple[str, ...] = ("arrival", "longest_first")
    probe_flushes: int = 1
    retune_every: int = 32
    min_queue_depth: int = 1
    max_queue_depth: int = 64
    keep_history: int = 256

    def __post_init__(self):
        bank = (self.mesh is not None) or (self.allocator is not None)
        if bank == (self.shape is not None):
            raise ValueError("pass exactly one of mesh=/allocator= (bank "
                             "level) or shape= (device level)")
        if self.allocator is not None:
            self.mesh = self.allocator.mesh
            self.n_slots = self.allocator.n_slots
        elif self.mesh is not None:
            self.allocator = TdmAllocator(self.mesh, self.n_slots)
        self.backend = "tdm" if self.allocator is not None else "rounds"
        if self.policy != "auto":
            get_policy(self.policy)         # fail fast on unknown names
        for name in self.auto_candidates:
            get_policy(name)
        self.queue = AdmissionQueue(self.queue_depth, self.overflow)
        self.clock = 0                 # next batch anchor (tdm backend)
        self.last_cycle = 0            # anchor of the most recent batch
        self.report: ScheduleReport | None = None
        self.history: list[ScheduleReport] = []
        self.n_flushes = 0
        self.n_policy_switches = 0
        # auto-tune state: per-candidate (cost_sum, flushes) + phase
        self._auto_stats = {name: [0.0, 0] for name in self.auto_candidates}
        self._auto_choice = self.auto_candidates[0] if self.auto_candidates \
            else "arrival"
        self._exploit_flushes = 0
        self._last_full_stalls = 0
        self._calm_flushes = 0         # consecutive quiet, under-filled drains

    # -- introspection -------------------------------------------------------
    @property
    def effective_policy(self) -> str:
        """The policy the next flush will commit with (the auto pick when
        ``policy="auto"``, else ``policy``)."""
        return self._auto_choice if self.policy == "auto" else self.policy

    @property
    def effective_queue_depth(self) -> int:
        """Live admission-queue capacity (auto-tuned when
        ``policy="auto"``)."""
        return self.queue.depth

    @property
    def pending(self) -> int:
        """Requests currently buffered in the admission queue."""
        return len(self.queue.items)

    # -- policy application --------------------------------------------------
    def _distances(self, reqs) -> tuple[int, ...]:
        if self.backend == "tdm":
            return tuple(0 if _is_init(r) else
                         self.mesh.manhattan(r.src, r.dst) for r in reqs)
        return tuple(len(_dor_path(t.src, t.dst, self.shape, self.torus))
                     for t in reqs)

    def _order(self, reqs, policy: str) -> list[int]:
        ctx = PolicyContext(self.backend, lambda: self._distances(reqs))
        order = list(get_policy(policy)(reqs, ctx))
        if sorted(order) != list(range(len(reqs))):
            raise ValueError(f"policy {policy!r} returned an invalid "
                             f"commit order {order!r} for {len(reqs)} "
                             "requests (must be a permutation)")
        return order

    # -- the synchronous batch path ------------------------------------------
    def schedule(self, transfers, cycle: int | None = None,
                 policy: str | None = None):
        """Schedule a batch of bulk transfers concurrently.

        The session spelling of the old ``schedule_transfers``: *all*
        requests are searched in one vectorized pass and committed in
        the packing policy's order, so every granted circuit is
        link/slot-disjoint from every other one it overlaps.

        Bank level returns ``(list[AllocResult], ScheduleReport)`` in
        request order; device level returns ``(TransferPlan,
        ScheduleReport)``.  ``cycle`` anchors the batch in allocator
        time (default: the fabric's own ``clock``, which then advances
        past the batch's drain).  ``policy`` overrides the session
        policy for this batch only.  Telemetry folds into ``report`` /
        ``history`` either way.
        """
        transfers = list(transfers)
        for t in transfers:
            if _is_init(t) and t.src != t.dst:
                raise ValueError(f"init requires src == dst, got {t!r}")
        chosen = policy or self.effective_policy
        if self.policy == "auto" and policy is None:
            chosen = self._auto_pick()
        if self.backend == "tdm":
            out = self._schedule_tdm(transfers, cycle, chosen)
        else:
            out = self._schedule_rounds(transfers, chosen)
        self._record(out[1], chosen, auto=self.policy == "auto"
                     and policy is None)
        return out

    def _schedule_tdm(self, transfers, cycle, policy):
        reqs = _as_copy_requests(transfers)
        anchor = self.clock if cycle is None else cycle
        order = self._order(reqs, policy)
        permuted = [reqs[i] for i in order]
        res_p = self.allocator.allocate_batch(permuted, anchor)
        report = _tdm_report(self.allocator, permuted, res_p, anchor)
        results = [None] * len(reqs)
        for i, r in zip(order, res_p):
            results[i] = r
        self.last_cycle = anchor
        if cycle is None:
            end = max((r.circuit.end_cycle for r in results
                       if r.circuit is not None), default=anchor)
            self.clock = ((end // self.n_slots) + 1) * self.n_slots
        return results, report

    def _schedule_rounds(self, transfers, policy):
        n_init = sum(1 for t in transfers if _is_init(t))
        norm = _as_transfers(transfers)
        order = self._order(norm, policy)
        plan = plan_transfers(self.shape, norm, torus=self.torus, order=order)
        conc = plan.concurrency()
        stall = sum(s for s, p in zip(plan.starts, plan.paths) if p)
        report = ScheduleReport(
            backend="rounds", n_requests=len(plan.transfers),
            n_scheduled=sum(1 for t, p in zip(norm, plan.paths)
                            if p or t.src == t.dst),
            n_windows=plan.n_rounds, max_inflight=int(conc["max_inflight"]),
            avg_inflight=conc["avg_inflight"], stall_cycles=stall,
            n_init=n_init)
        return plan, report

    # -- the admission-queue path --------------------------------------------
    def submit(self, request, at: int | None = None) -> bool:
        """Admit one request into the bounded queue (arrival cycle
        ``at``, default the fabric clock).  A full queue applies the
        session's overflow behavior: ``"block"`` flushes inline (the
        stall lands in ``queue.stall_cycles``), ``"shed"`` drops the
        request and returns False, ``"raise"`` raises
        :class:`FabricOverflow`.  Returns True when admitted."""
        at = self.clock if at is None else at
        if self.queue.full():
            if self.overflow == "raise":
                raise FabricOverflow(
                    f"admission queue full ({self.queue.depth} pending) "
                    f"and overflow='raise'")
            if self.overflow == "shed":
                self.queue.n_shed += 1
                return False
            self.flush(cycle=at)
            self.queue.full_stalls += 1
            self.queue.stall_cycles += max(0, self.queue.busy_until - at)
            at = max(at, self.queue.busy_until)
        self.queue.push(at, request)
        return True

    def flush(self, cycle: int | None = None):
        """Drain the admission queue through one batched ``schedule``
        call (anchored at ``cycle``, default the head's arrival) and
        model the CCU's pickup pipeline (3-cycle fill + 1/request) in
        ``queue.busy_until``.  Returns the ``(results, report)`` /
        ``(plan, report)`` pair, or None when the queue is empty."""
        if not self.queue.items:
            return None
        arrivals = [at for at, _r in self.queue.items]
        reqs = [r for _at, r in self.queue.items]
        self.queue.items.clear()
        anchor = min(arrivals) if cycle is None else cycle
        pick = max(anchor, self.queue.busy_until)
        self.queue.busy_until = pick + 3 + (len(reqs) - 1)
        if self.backend == "tdm":
            out = self.schedule(reqs, cycle=pick)
        else:
            out = self.schedule(reqs)
        # Advance the session clock past this drain: later submits with a
        # default arrival must not look like they arrived before it (that
        # would charge them the whole session's elapsed pipeline time as
        # stall on an overflow).
        self.clock = max(self.clock, self.queue.busy_until)
        return out

    # -- telemetry -----------------------------------------------------------
    def _record(self, report: ScheduleReport, policy: str,
                auto: bool) -> None:
        self.n_flushes += 1
        self.history.append(report)
        del self.history[:-self.keep_history]
        self.report = (report if self.report is None
                       else self.report.merge(report))
        if auto:
            self._auto_observe(policy, report)

    def telemetry(self) -> dict:
        """Cumulative session stats: scheduling (``flushes``,
        ``requests``/``scheduled``, ``init_requests``, concurrency,
        ``stall_cycles``, search/conflict counters incl.
        ``searched_requests``), the live knobs
        (``policy``, ``queue_depth``), and admission health
        (``pending``, ``shed``, ``full_stalls``,
        ``queue_stall_cycles``, ``policy_switches``)."""
        agg = self.report
        out = {
            "backend": self.backend,
            "flushes": self.n_flushes,
            "requests": 0 if agg is None else agg.n_requests,
            "scheduled": 0 if agg is None else agg.n_scheduled,
            "init_requests": 0 if agg is None else agg.n_init,
            "max_inflight": 0 if agg is None else agg.max_inflight,
            "avg_inflight": 0.0 if agg is None else agg.avg_inflight,
            "stall_cycles": 0 if agg is None else agg.stall_cycles,
            "search_rounds": 0 if agg is None else agg.search_rounds,
            "conflicts": 0 if agg is None else agg.conflicts,
            "searched_requests": 0 if agg is None else agg.n_searched,
            "policy": self.effective_policy,
            "queue_depth": self.queue.depth,
            "pending": self.pending,
            "shed": self.queue.n_shed,
            "full_stalls": self.queue.full_stalls,
            "queue_stall_cycles": self.queue.stall_cycles,
            "policy_switches": self.n_policy_switches,
        }
        return out

    # -- stall-driven auto-tuning --------------------------------------------
    # Deterministic: the trajectory is a pure function of the submitted
    # traffic.  Probe phase measures each candidate for `probe_flushes`
    # batches; exploit phase commits with the cheapest (mean stall_cycles
    # + makespan per flush); after `retune_every` exploit flushes the
    # stats reset and the fabric re-probes (workloads drift).
    def _auto_pick(self) -> str:
        probing = [n for n in self.auto_candidates
                   if self._auto_stats[n][1] < self.probe_flushes]
        if probing:
            choice = probing[0]
        else:
            choice = min(self.auto_candidates,
                         key=lambda n: (self._auto_stats[n][0]
                                        / self._auto_stats[n][1]))
        if choice != self._auto_choice:
            self.n_policy_switches += 1
        self._auto_choice = choice
        return choice

    def _auto_observe(self, policy: str, report: ScheduleReport) -> None:
        if policy in self._auto_stats:
            cost = report.stall_cycles + report.n_windows
            st = self._auto_stats[policy]
            st[0] += cost
            st[1] += 1
        if all(st[1] >= self.probe_flushes
               for st in self._auto_stats.values()):
            self._exploit_flushes += 1
            if self._exploit_flushes >= self.retune_every:
                self._exploit_flushes = 0
                self._auto_stats = {n: [0.0, 0]
                                    for n in self.auto_candidates}
        self._auto_queue_depth(report)

    def _auto_queue_depth(self, report: ScheduleReport) -> None:
        """Stall feedback on the admission buffer: overflow blocking (or
        heavy in-batch queueing) doubles the depth — bigger drains pack
        better; a sustained run of quiet, under-filled drains halves it
        back toward ``min_queue_depth`` (buffering without benefit)."""
        grew = self.queue.full_stalls > self._last_full_stalls
        self._last_full_stalls = self.queue.full_stalls
        stall_per_req = (report.stall_cycles / report.n_requests
                         if report.n_requests else 0.0)
        if grew or stall_per_req > self.n_slots:
            self.queue.depth = min(self.max_queue_depth,
                                   self.queue.depth * 2)
            self._calm_flushes = 0
        elif report.n_requests <= self.queue.depth // 2 \
                and report.stall_cycles == 0:
            self._calm_flushes += 1
            if self._calm_flushes >= 4:
                self._calm_flushes = 0
                self.queue.depth = max(self.min_queue_depth,
                                       self.queue.depth // 2)
        else:
            self._calm_flushes = 0


__all__ = ["AdmissionQueue", "FabricOverflow", "NomFabric", "PolicyContext",
           "get_policy", "register_policy", "registered_policies",
           "unregister_policy"]
