"""`NomFabric`: the stateful session API for all NoM traffic.

The paper's premise is that the memory controller sets up TDM circuits
*centrally*: one authority owns the topology, the slot tables, and the
arbitration policy, and every consumer negotiates with it.  This module
is that authority as a library object.  Where `schedule_transfers` was a
kwargs-heavy free function re-invoked independently by every subsystem,
a :class:`NomFabric` is a long-lived session that owns

* the **topology** and its allocator — a
  :class:`~repro.core.slot_alloc.TdmAllocator` over a
  :class:`~repro.core.topology.Mesh3D` (bank level, ``backend="tdm"``)
  or a device mesh/torus routed by
  :func:`~repro.core.nom_collectives.plan_transfers` (device level,
  ``backend="rounds"``);
* a named **packing-policy registry** (:func:`register_policy`) —
  ``"arrival"`` (the CCU's FIFO commit rule) and ``"longest_first"``
  (descending route distance, best packing) ship registered; new
  policies are addable without touching core;
* a bounded **admission queue** (:class:`AdmissionQueue` — the CCU's
  request buffering, previously private to the memory simulator) with
  configurable ``"shed"`` / ``"block"`` / ``"raise"`` overflow behavior;
* cumulative :class:`~repro.core.scheduler.ScheduleReport` telemetry
  over the session's lifetime, and a ``policy="auto"`` mode that picks
  the packing policy *and* the effective queue depth per workload from
  the observed ``stall_cycles`` history (the controller-side arbitration
  state that the HMC NoC studies identify as what determines throughput
  under concurrency).

Every production subsystem — the serving engine, `BankPool` repack, MoE
dispatch planning, checkpoint reshard, the memory simulator's CCU —
holds or constructs a fabric; ``schedule_transfers`` survives only as a
deprecated one-shot shim over this class (enforced by
``scripts/check_api.py``).  See ``docs/fabric.md``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .nom_collectives import _dor_path, plan_transfers
from .scheduler import (ScheduleReport, TransferRequest, _as_copy_requests,
                        _as_transfers, _tdm_report)
from .slot_alloc import (AllocResult, Circuit, CopyRequest,
                         SegmentedAllocator, TdmAllocator)
from .topology import Mesh3D, StackedTopology


class FabricOverflow(RuntimeError):
    """Raised by ``overflow="raise"`` fabrics when an admission would
    exceed the bounded queue (or, via the serving engine, the bank
    pool's tenant capacity)."""


# ---------------------------------------------------------------------------
# Packing-policy registry
# ---------------------------------------------------------------------------
class PolicyContext:
    """What a packing policy may look at besides the requests themselves.

    Attributes:
      backend: ``"tdm"`` or ``"rounds"``.
      distances: per-request route length in hops — Manhattan distance on
        the bank mesh (0 for an in-place INIT, the farthest source for a
        fan-in reduce), DOR path length on the device mesh — the quantity
        ``longest_first`` sorts by.  Computed on first access, so
        distance-blind policies (``"arrival"``) pay nothing for it.
      fanin: per-request fan-in width — ``len(srcs)`` for compute-class
        ``op="reduce"`` requests, 1 for copies/inits — so packing
        policies can weigh how many destination-port slots a request
        will pin.  Lazy like ``distances``.
    """

    def __init__(self, backend: str, distance_fn, fanin_fn=None):
        self.backend = backend
        self._distance_fn = distance_fn
        self._distances: tuple[int, ...] | None = None
        self._fanin_fn = fanin_fn
        self._fanin: tuple[int, ...] | None = None

    @property
    def distances(self) -> tuple[int, ...]:
        if self._distances is None:
            self._distances = tuple(self._distance_fn())
        return self._distances

    @property
    def fanin(self) -> tuple[int, ...]:
        if self._fanin is None:
            self._fanin = (tuple(self._fanin_fn())
                           if self._fanin_fn is not None else ())
        return self._fanin


_POLICIES: dict[str, object] = {}


def register_policy(name: str):
    """Decorator registering a packing policy under ``name``.

    A policy is ``fn(requests, ctx: PolicyContext) -> iterable[int]``
    returning the *commit order* — a permutation of ``range(len(
    requests)))``.  Earlier positions win slot/link contention (the
    batched commit reserves in this order; results always come back in
    request order).  Registering an already-taken name raises
    ``ValueError``; remove experimental policies with
    :func:`unregister_policy`.
    """
    def deco(fn):
        if name in _POLICIES:
            raise ValueError(f"policy {name!r} is already registered")
        _POLICIES[name] = fn
        return fn
    return deco


def unregister_policy(name: str) -> None:
    """Remove a registered policy (the built-ins may not be removed)."""
    if name in ("arrival", "longest_first"):
        raise ValueError(f"built-in policy {name!r} may not be removed")
    if name not in _POLICIES:
        raise ValueError(f"policy {name!r} is not registered")
    del _POLICIES[name]


def registered_policies() -> tuple[str, ...]:
    """Names currently in the registry, registration order."""
    return tuple(_POLICIES)


def get_policy(name: str):
    """Look up a policy by name; unknown names raise ``ValueError``
    listing what is registered (``"auto"`` is a fabric mode, not a
    registry entry)."""
    try:
        return _POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; registered: "
            f"{', '.join(_POLICIES)} (or 'auto')") from None


@register_policy("arrival")
def _arrival(reqs, ctx: PolicyContext):
    """FIFO — the CCU's commit rule (paper Section 2.2)."""
    return range(len(reqs))


@register_policy("longest_first")
def _longest_first(reqs, ctx: PolicyContext):
    """Descending route distance (stable): long circuits reserve first,
    short ones fill the remaining slots — best packing on most mixes."""
    return sorted(range(len(reqs)), key=lambda i: -ctx.distances[i])


# ---------------------------------------------------------------------------
# Bounded admission queue (the CCU's request buffering, shared with memsim)
# ---------------------------------------------------------------------------
def _is_init(payload) -> bool:
    """INIT-class detection across both request vocabularies: the
    scheduler's ``op="init"`` strings and the simulator's ``Op.INIT``
    enum (matched by name so core never imports memsim)."""
    op = getattr(payload, "op", "copy")
    return op == "init" or getattr(op, "name", "") == "INIT"


def _is_reduce(payload) -> bool:
    """Compute-class detection across both request vocabularies (the
    scheduler's ``op="reduce"`` and the simulator's ``Op.REDUCE``)."""
    op = getattr(payload, "op", "copy")
    return op == "reduce" or getattr(op, "name", "") == "REDUCE"


def _reduce_srcs(payload) -> tuple:
    """The fan-in source tuple of a reduce-class request (empty for
    copies/inits; memsim requests carry it as ``src_banks``)."""
    srcs = getattr(payload, "srcs", ()) or getattr(payload, "src_banks", ())
    return tuple(srcs)


@dataclasses.dataclass
class AdmissionQueue:
    """The bounded request queue in front of a circuit-setup authority.

    Pending requests sit here (with their arrival cycles) until a drain
    services them in one batched setup pass.  ``depth`` bounds the
    buffer; what happens to an admission that finds it full is the
    ``overflow`` behavior — ``"block"`` (force a drain and stall the
    issuer until the pickup pipeline completes; the memsim CCU's
    backpressure), ``"shed"`` (drop the request, count it), or
    ``"raise"`` (:class:`FabricOverflow`).  INIT-class occupancy is
    accounted separately, as in the simulator's CCU telemetry.

    The queue also owns its *service-latency* record: every admission
    that eventually gets serviced reports its wait (pickup cycle minus
    arrival cycle — the fabric's ``flush`` does this for CCU requests;
    the serving engine does it in engine ticks for tenant admission)
    through :meth:`record_admit`, and :meth:`wait_quantile` answers the
    p50/p99 questions the SLO harness asks.  A bounded reservoir of the
    most recent ``keep_waits`` samples backs the quantiles; the count
    and total (``n_admitted`` / ``wait_total``) are exact regardless.
    """
    depth: int
    overflow: str = "block"
    items: list = dataclasses.field(default_factory=list)  # (cycle, payload)
    busy_until: int = 0        # front-end pickup pipeline drain time
    stall_cycles: int = 0      # issuer cycles lost to queue-full blocking
    full_stalls: int = 0       # admissions that hit a full queue
    n_shed: int = 0            # admissions dropped by overflow="shed"
    peak_occupancy: int = 0
    init_reqs: int = 0
    peak_init: int = 0
    n_admitted: int = 0        # admissions serviced (record_admit calls)
    wait_total: int = 0        # summed service waits (cycles or ticks)
    keep_waits: int = 4096     # recent-wait reservoir for the quantiles
    wait_samples: list = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if self.overflow not in ("block", "shed", "raise"):
            raise ValueError(f"unknown overflow behavior {self.overflow!r}; "
                             "choose from ('block', 'shed', 'raise')")

    def full(self) -> bool:
        return len(self.items) >= self.depth

    def push(self, at: int, payload) -> None:
        assert not self.full(), "push on a full admission queue (drain first)"
        self.items.append((at, payload))
        self.peak_occupancy = max(self.peak_occupancy, len(self.items))
        if _is_init(payload):
            self.init_reqs += 1
            n = sum(1 for _at, q in self.items if _is_init(q))
            self.peak_init = max(self.peak_init, n)

    def record_admit(self, wait: int) -> None:
        """Record one serviced admission that waited ``wait`` time units
        (>= 0) between arrival and pickup."""
        wait = max(0, int(wait))
        self.n_admitted += 1
        self.wait_total += wait
        self.wait_samples.append(wait)
        del self.wait_samples[:-self.keep_waits]

    def wait_quantile(self, q: float) -> float:
        """Service-wait quantile (``q`` in [0, 1]) over the recorded
        reservoir; 0.0 before any admission was recorded."""
        if not self.wait_samples:
            return 0.0
        return float(np.quantile(np.asarray(self.wait_samples, float), q))


# ---------------------------------------------------------------------------
# The session object
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class NomFabric:
    """One stateful session owning all NoM traffic of a subsystem.

    Exactly one of ``mesh`` / ``allocator`` (bank level) or ``shape``
    (device level) selects the backend.  ``schedule`` is the synchronous
    batch path every migrated call site uses; ``submit`` / ``flush`` is
    the admission-queue path (the CCU discipline: requests buffer up to
    ``queue_depth``, then one batched setup drains them).

    Attributes:
      mesh: bank-level topology; a :class:`TdmAllocator` is built over it
        (``n_slots`` TDM slots) unless ``allocator`` is given directly.
      allocator: pre-built allocator (e.g. a ``TdmAllocatorLight``); the
        fabric adopts it, topology included.
      shape, torus: device-level topology for the rounds backend.
      policy: registered packing-policy name, or ``"auto"`` to pick per
        workload from stall history (see below).
      queue_depth: admission-queue capacity (``"auto"`` adapts the live
        depth between ``min_queue_depth`` and ``max_queue_depth``).
      overflow: full-queue behavior — ``"block"`` | ``"shed"`` |
        ``"raise"``.
      auto_candidates: policies ``"auto"`` chooses among.
      probe_flushes: flushes spent measuring each candidate before
        exploiting; retune_every: exploit flushes between re-probes.
      keep_history: per-flush reports retained on ``history`` (the
        cumulative ``report`` is exact regardless).
      alloc_backend: who serves the allocator's prepare rounds when the
        fabric builds its own ``TdmAllocator`` — ``"auto"`` (fused
        compiled program for full waves, host pipeline for tiny rounds),
        ``"fused"``, or ``"host"``.  Ignored when ``allocator=`` is
        passed (the adopted allocator keeps its own backend).  Which
        backend actually served each wave shows up in ``telemetry()``
        as ``fused_waves`` / ``host_waves``.
    """
    mesh: Mesh3D | None = None
    shape: tuple[int, ...] | None = None
    torus: bool = True
    n_slots: int = 16
    allocator: TdmAllocator | None = None
    alloc_backend: str = "auto"
    policy: str = "arrival"
    queue_depth: int = 8
    overflow: str = "block"
    auto_candidates: tuple[str, ...] = ("arrival", "longest_first")
    probe_flushes: int = 1
    retune_every: int = 32
    min_queue_depth: int = 1
    max_queue_depth: int = 64
    keep_history: int = 256

    def __post_init__(self):
        bank = (self.mesh is not None) or (self.allocator is not None)
        if bank == (self.shape is not None):
            raise ValueError("pass exactly one of mesh=/allocator= (bank "
                             "level) or shape= (device level)")
        if self.allocator is not None:
            self.mesh = self.allocator.mesh
            self.n_slots = self.allocator.n_slots
        elif self.mesh is not None:
            self.allocator = TdmAllocator(self.mesh, self.n_slots,
                                          backend=self.alloc_backend)
        self.backend = "tdm" if self.allocator is not None else "rounds"
        if self.policy != "auto":
            get_policy(self.policy)         # fail fast on unknown names
        for name in self.auto_candidates:
            get_policy(name)
        self.queue = AdmissionQueue(self.queue_depth, self.overflow)
        self.clock = 0                 # next batch anchor
        self.last_cycle = 0            # anchor of the most recent batch
        # rounds backend: persistent link -> {absolute rounds} reservations,
        # so consecutive batches contend the way tdm slot tables do.
        self._round_busy: dict[tuple, set[int]] = {}
        self.report: ScheduleReport | None = None
        self.history: list[ScheduleReport] = []
        self.n_flushes = 0
        self.n_policy_switches = 0
        # auto-tune state: per-candidate (cost_sum, flushes) + phase
        self._auto_stats = {name: [0.0, 0] for name in self.auto_candidates}
        self._auto_choice = self.auto_candidates[0] if self.auto_candidates \
            else "arrival"
        self._exploit_flushes = 0
        self._last_full_stalls = 0
        self._calm_flushes = 0         # consecutive quiet, under-filled drains
        # auto-learned per-window slot budget for copies (0 = paper default
        # of one slot/window); grown under sustained conflict-free stalls,
        # shrunk when the wider reservations start colliding.
        self._nom_extra_slots = 0

    # -- introspection -------------------------------------------------------
    @property
    def effective_policy(self) -> str:
        """The policy the next flush will commit with (the auto pick when
        ``policy="auto"``, else ``policy``)."""
        return self._auto_choice if self.policy == "auto" else self.policy

    @property
    def effective_queue_depth(self) -> int:
        """Live admission-queue capacity (auto-tuned when
        ``policy="auto"``)."""
        return self.queue.depth

    @property
    def pending(self) -> int:
        """Requests currently buffered in the admission queue."""
        return len(self.queue.items)

    # -- policy application --------------------------------------------------
    def _distances(self, reqs) -> tuple[int, ...]:
        if self.backend == "tdm":
            return tuple(
                0 if _is_init(r) else
                max(self.mesh.manhattan(int(s), r.dst)
                    for s in _reduce_srcs(r)) if _is_reduce(r) else
                self.mesh.manhattan(r.src, r.dst) for r in reqs)
        return tuple(len(_dor_path(t.src, t.dst, self.shape, self.torus))
                     for t in reqs)

    def _fanins(self, reqs) -> tuple[int, ...]:
        return tuple(max(1, len(_reduce_srcs(r))) if _is_reduce(r) else 1
                     for r in reqs)

    def _order(self, reqs, policy: str) -> list[int]:
        ctx = PolicyContext(self.backend, lambda: self._distances(reqs),
                            lambda: self._fanins(reqs))
        order = list(get_policy(policy)(reqs, ctx))
        if sorted(order) != list(range(len(reqs))):
            raise ValueError(f"policy {policy!r} returned an invalid "
                             f"commit order {order!r} for {len(reqs)} "
                             "requests (must be a permutation)")
        return order

    # -- the synchronous batch path ------------------------------------------
    def schedule(self, transfers, cycle: int | None = None,
                 policy: str | None = None):
        """Schedule a batch of bulk transfers concurrently.

        The session spelling of the old ``schedule_transfers``: *all*
        requests are searched in one vectorized pass and committed in
        the packing policy's order, so every granted circuit is
        link/slot-disjoint from every other one it overlaps.

        Bank level returns ``(list[AllocResult], ScheduleReport)`` in
        request order; device level returns ``(TransferPlan,
        ScheduleReport)``.  ``cycle`` anchors the batch in allocator
        time (default: the fabric's own ``clock``, which then advances
        past the batch's drain).  ``policy`` overrides the session
        policy for this batch only.  Telemetry folds into ``report`` /
        ``history`` either way.
        """
        transfers = list(transfers)
        for t in transfers:
            if _is_init(t) and t.src != t.dst:
                raise ValueError(f"init requires src == dst, got {t!r}")
            if _is_reduce(t):
                if self.backend != "tdm":
                    raise ValueError(
                        "compute-class reduce is a bank-level op (fan-in "
                        "circuits need the tdm slot tables); on the rounds "
                        "backend use the device collectives "
                        "(nom_allreduce) instead")
                srcs = _reduce_srcs(t)
                if not srcs:
                    raise ValueError(f"reduce requires fan-in sources "
                                     f"(srcs), got {t!r}")
                if len(set(srcs)) != len(srcs):
                    raise ValueError(f"reduce sources must be distinct, "
                                     f"got {t!r}")
                if t.dst in srcs:
                    raise ValueError(f"reduce destination {t.dst} is "
                                     f"already a source in {t!r} (resident "
                                     "operands need no transfer)")
        chosen = policy or self.effective_policy
        if self.policy == "auto" and policy is None:
            chosen = self._auto_pick()
        if self.backend == "tdm":
            out = self._schedule_tdm(transfers, cycle, chosen)
        else:
            out = self._schedule_rounds(transfers, chosen, cycle)
        self._record(out[1], chosen, auto=self.policy == "auto"
                     and policy is None)
        return out

    def _schedule_tdm(self, transfers, cycle, policy):
        reqs = _as_copy_requests(transfers)
        if self.policy == "auto" and self._nom_extra_slots:
            # Learned widening: let plain copies claim up to the tuned
            # extra slots per window.  Requests that pin their own budget
            # (max_extra_slots != 0) and non-copy classes keep it.
            reqs = [dataclasses.replace(r,
                                        max_extra_slots=self._nom_extra_slots)
                    if r.op == "copy" and not r.max_extra_slots else r
                    for r in reqs]
        anchor = self.clock if cycle is None else cycle
        order = self._order(reqs, policy)
        permuted = [reqs[i] for i in order]
        res_p = self.allocator.allocate_batch(permuted, anchor)
        report = _tdm_report(self.allocator, permuted, res_p, anchor)
        results = [None] * len(reqs)
        for i, r in zip(order, res_p):
            results[i] = r
        self.last_cycle = anchor
        if cycle is None:
            end = max((r.circuit.end_cycle for r in results
                       if r.circuit is not None), default=anchor)
            self.clock = ((end // self.n_slots) + 1) * self.n_slots
        return results, report

    def _schedule_rounds(self, transfers, policy, cycle=None):
        n_init = sum(1 for t in transfers if _is_init(t))
        norm = _as_transfers(transfers)
        order = self._order(norm, policy)
        base = self.clock if cycle is None else cycle
        # Reservations behind every possible future anchor can never be
        # contended again — drop them so the persistent map stays bounded.
        horizon = min(base, self.clock)
        for hop in list(self._round_busy):
            live = {r for r in self._round_busy[hop] if r >= horizon}
            if live:
                self._round_busy[hop] = live
            else:
                del self._round_busy[hop]
        plan = plan_transfers(self.shape, norm, torus=self.torus, order=order,
                              busy=self._round_busy, base=base)
        self.last_cycle = base
        if cycle is None:
            # Advance past this batch's drain, exactly like the tdm clock:
            # the next default-anchored batch starts on fresh links (so a
            # sequence of default `schedule` calls is identical to the old
            # from-round-0 packing), while an explicitly anchored batch
            # (e.g. a pipelined flush) contends with what still streams.
            self.clock = base + plan.n_rounds
        conc = plan.concurrency()
        stall = sum(s for s, p in zip(plan.starts, plan.paths) if p)
        report = ScheduleReport(
            backend="rounds", n_requests=len(plan.transfers),
            n_scheduled=sum(1 for t, p in zip(norm, plan.paths)
                            if p or t.src == t.dst),
            n_windows=plan.n_rounds, max_inflight=int(conc["max_inflight"]),
            avg_inflight=conc["avg_inflight"], stall_cycles=stall,
            n_init=n_init)
        return plan, report

    # -- the admission-queue path --------------------------------------------
    def submit(self, request, at: int | None = None) -> bool:
        """Admit one request into the bounded queue (arrival cycle
        ``at``, default the fabric clock).  A full queue applies the
        session's overflow behavior: ``"block"`` flushes inline (the
        stall lands in ``queue.stall_cycles``), ``"shed"`` drops the
        request and returns False, ``"raise"`` raises
        :class:`FabricOverflow`.  Returns True when admitted."""
        at = self.clock if at is None else at
        if self.queue.full():
            if self.overflow == "raise":
                raise FabricOverflow(
                    f"admission queue full ({self.queue.depth} pending) "
                    f"and overflow='raise'")
            if self.overflow == "shed":
                self.queue.n_shed += 1
                return False
            self.flush(cycle=at)
            self.queue.full_stalls += 1
            self.queue.stall_cycles += max(0, self.queue.busy_until - at)
            at = max(at, self.queue.busy_until)
        self.queue.push(at, request)
        return True

    def flush(self, cycle: int | None = None):
        """Drain the admission queue through one batched ``schedule``
        call (anchored at ``cycle``, default the head's arrival) and
        model the CCU's pickup pipeline (3-cycle fill + 1/request) in
        ``queue.busy_until``.  Returns the ``(results, report)`` /
        ``(plan, report)`` pair, or None when the queue is empty."""
        if not self.queue.items:
            return None
        arrivals = [at for at, _r in self.queue.items]
        reqs = [r for _at, r in self.queue.items]
        self.queue.items.clear()
        anchor = min(arrivals) if cycle is None else cycle
        pick = max(anchor, self.queue.busy_until)
        self.queue.busy_until = pick + 3 + (len(reqs) - 1)
        for at in arrivals:     # per-request service wait: arrival -> pickup
            self.queue.record_admit(pick - at)
        # Both backends anchor at the pickup cycle: on rounds, the batch
        # packs against reservations still streaming from earlier flushes
        # (persistent `_round_busy`), so back-to-back drains contend the
        # way tdm slot tables always have.
        out = self.schedule(reqs, cycle=pick)
        # Advance the session clock past this drain: later submits with a
        # default arrival must not look like they arrived before it (that
        # would charge them the whole session's elapsed pipeline time as
        # stall on an overflow).
        self.clock = max(self.clock, self.queue.busy_until)
        return out

    # -- telemetry -----------------------------------------------------------
    def _record(self, report: ScheduleReport, policy: str,
                auto: bool) -> None:
        self.n_flushes += 1
        self.history.append(report)
        del self.history[:-self.keep_history]
        self.report = (report if self.report is None
                       else self.report.merge(report))
        if auto:
            self._auto_observe(policy, report)

    def telemetry(self) -> dict:
        """Cumulative session stats: scheduling (``flushes``,
        ``requests``/``scheduled``, ``init_requests`` /
        ``reduce_requests`` op-class counters, concurrency,
        ``stall_cycles``, search/conflict counters incl.
        ``searched_requests``, and the allocator-backend split
        ``fused_waves`` / ``host_waves``), the live knobs
        (``policy``, ``queue_depth``, the learned ``nom_extra_slots``
        copy-widening budget), and admission health
        (``pending``, ``shed``, ``full_stalls``,
        ``queue_stall_cycles``, ``policy_switches``, and the queue's
        service-latency record ``queue_admitted`` /
        ``queue_wait_cycles`` / ``queue_wait_p50`` /
        ``queue_wait_p99``)."""
        agg = self.report
        out = {
            "backend": self.backend,
            "flushes": self.n_flushes,
            "requests": 0 if agg is None else agg.n_requests,
            "scheduled": 0 if agg is None else agg.n_scheduled,
            "init_requests": 0 if agg is None else agg.n_init,
            "reduce_requests": 0 if agg is None else agg.n_reduce,
            "max_inflight": 0 if agg is None else agg.max_inflight,
            "avg_inflight": 0.0 if agg is None else agg.avg_inflight,
            "stall_cycles": 0 if agg is None else agg.stall_cycles,
            "search_rounds": 0 if agg is None else agg.search_rounds,
            "conflicts": 0 if agg is None else agg.conflicts,
            "searched_requests": 0 if agg is None else agg.n_searched,
            "fused_waves": 0 if agg is None else agg.fused_waves,
            "host_waves": 0 if agg is None else agg.host_waves,
            "policy": self.effective_policy,
            "queue_depth": self.queue.depth,
            "nom_extra_slots": self._nom_extra_slots,
            "pending": self.pending,
            "shed": self.queue.n_shed,
            "full_stalls": self.queue.full_stalls,
            "queue_stall_cycles": self.queue.stall_cycles,
            "queue_admitted": self.queue.n_admitted,
            "queue_wait_cycles": self.queue.wait_total,
            "queue_wait_p50": self.queue.wait_quantile(0.5),
            "queue_wait_p99": self.queue.wait_quantile(0.99),
            "policy_switches": self.n_policy_switches,
        }
        return out

    # -- stall-driven auto-tuning --------------------------------------------
    # Deterministic: the trajectory is a pure function of the submitted
    # traffic.  Probe phase measures each candidate for `probe_flushes`
    # batches; exploit phase commits with the cheapest (mean stall_cycles
    # + makespan per flush); after `retune_every` exploit flushes the
    # stats reset and the fabric re-probes (workloads drift).
    def _auto_pick(self) -> str:
        probing = [n for n in self.auto_candidates
                   if self._auto_stats[n][1] < self.probe_flushes]
        if probing:
            choice = probing[0]
        else:
            choice = min(self.auto_candidates,
                         key=lambda n: (self._auto_stats[n][0]
                                        / self._auto_stats[n][1]))
        if choice != self._auto_choice:
            self.n_policy_switches += 1
        self._auto_choice = choice
        return choice

    def _auto_observe(self, policy: str, report: ScheduleReport) -> None:
        if policy in self._auto_stats:
            cost = report.stall_cycles + report.n_windows
            st = self._auto_stats[policy]
            st[0] += cost
            st[1] += 1
        if all(st[1] >= self.probe_flushes
               for st in self._auto_stats.values()):
            self._exploit_flushes += 1
            if self._exploit_flushes >= self.retune_every:
                self._exploit_flushes = 0
                self._auto_stats = {n: [0.0, 0]
                                    for n in self.auto_candidates}
        self._auto_queue_depth(report)
        self._auto_extra_slots(report)

    def _auto_extra_slots(self, report: ScheduleReport) -> None:
        """Conflict feedback on the per-window slot budget: heavy stalls
        with a clean conflict record mean circuits queue behind window
        capacity — widen copies by one extra slot (up to half the TDM
        frame); once the wider reservations start colliding in the
        batched commit (conflict rate over a quarter of the scheduled
        requests), back off.  Deterministic, like the rest of the tuner;
        the live value shows in ``telemetry()["nom_extra_slots"]``."""
        if self.backend != "tdm" or not report.n_requests:
            return
        conflict_rate = report.conflicts / max(1, report.n_scheduled)
        stall_per_req = report.stall_cycles / report.n_requests
        if conflict_rate > 0.25 and self._nom_extra_slots:
            self._nom_extra_slots -= 1
        elif stall_per_req > self.n_slots and conflict_rate <= 0.05:
            self._nom_extra_slots = min(self._nom_extra_slots + 1,
                                        max(0, self.n_slots // 2 - 1))

    def _auto_queue_depth(self, report: ScheduleReport) -> None:
        """Stall feedback on the admission buffer: overflow blocking (or
        heavy in-batch queueing) doubles the depth — bigger drains pack
        better; a sustained run of quiet, under-filled drains halves it
        back toward ``min_queue_depth`` (buffering without benefit)."""
        grew = self.queue.full_stalls > self._last_full_stalls
        self._last_full_stalls = self.queue.full_stalls
        stall_per_req = (report.stall_cycles / report.n_requests
                         if report.n_requests else 0.0)
        if grew or stall_per_req > self.n_slots:
            self.queue.depth = min(self.max_queue_depth,
                                   self.queue.depth * 2)
            self._calm_flushes = 0
        elif report.n_requests <= self.queue.depth // 2 \
                and report.stall_cycles == 0:
            self._calm_flushes += 1
            if self._calm_flushes >= 4:
                self._calm_flushes = 0
                self.queue.depth = max(self.min_queue_depth,
                                       self.queue.depth // 2)
        else:
            self._calm_flushes = 0


# ---------------------------------------------------------------------------
# Multi-stack: one CCU authority per stack + cross-stack negotiation
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ReduceTree:
    """A committed cross-stack compute-class reduce.

    Three kinds of reserved components stream as one logical operation:
    ``partials`` — per-remote-stack fan-in :class:`~repro.core.slot_alloc.
    Circuit`\\ s merging that stack's operands at its bridge bank;
    ``legs`` — one :class:`~repro.core.slot_alloc.StackedCircuit` SerDes
    delivery per remote stack, bridge to destination, anchored at the
    partial's drain (store-and-forward at the bridge's logic-die buffer);
    ``local`` — the destination stack's own fan-in, when it holds
    operands.  Remote partials merge at the destination without extra
    ALU dwell (the SerDes inter-arrival gap already exceeds the merge
    latency — a documented simplification vs the same-stack dwell
    model).  Cycles span the earliest component injection to the last
    component's final beat."""
    dst: tuple[int, int]      # (stack, local node)
    srcs: tuple               # (stack, node) operand endpoints, source order
    start_cycle: int
    arrival_cycle: int        # first beat of the last-arriving component
    end_cycle: int            # last beat landed (reservations drained)
    n_windows: int            # window span of the whole tree
    distance: int             # arrival_cycle - start_cycle
    partials: list            # remote-stack bridge fan-in Circuits
    legs: list                # StackedCircuits bridge -> destination
    local: object | None = None   # destination-stack fan-in Circuit
    slots_per_window: int = 1
    _n_slots_hint: int = 16

    @property
    def cross_stack(self) -> bool:
        return True

    @property
    def hops(self) -> list[tuple[int, int, int]]:
        """Mesh hops of every component (node ids are stack-local);
        SerDes hops are in :attr:`link_slots`."""
        out = []
        for c in (*self.partials, *self.legs,
                  *((self.local,) if self.local is not None else ())):
            out.extend(c.hops)
        return out

    @property
    def link_slots(self) -> list[tuple[int, int]]:
        """(channel, slot) SerDes reservations across all legs."""
        return [ls for leg in self.legs for ls in leg.link_slots]


@dataclasses.dataclass
class FabricCluster:
    """Multi-authority NoM over a :class:`StackedTopology`.

    One :class:`NomFabric` per stack owns that stack's slot tables,
    clock, and policy state — *same-stack traffic is delegated wholesale
    to its stack's fabric* and never takes the cluster's cross-stack
    path.  Cross-stack requests are negotiated between the per-stack CCUs
    by a :class:`~repro.core.slot_alloc.SegmentedAllocator`: the near
    authority reserves its mesh segment plus the SerDes channel slots
    (phase 1), the far authority commits its segment against the pinned
    injection slot (phase 2), and a far-side conflict rolls the near
    reservation back with no slot-table state leaked.

    Requests address banks either as flat global ids (``src``/``dst``
    ints, see :meth:`StackedTopology.global_id`), as ``(stack, node)``
    tuples, or via :class:`TransferRequest`'s ``src_stack``/``dst_stack``
    fields with stack-local node ids.

    With ``n_stacks == 1`` every batch is delegated to the single stack
    fabric with identical arguments — plans, results, and reports are
    bit-identical to holding that :class:`NomFabric` directly.
    """

    topology: StackedTopology
    n_slots: int = 16
    policy: str = "arrival"
    queue_depth: int = 8
    overflow: str = "block"
    allocators: list | None = None   # pre-built per-stack allocators
    alloc_backend: str = "auto"      # per-stack allocator prepare backend

    def __post_init__(self):
        if self.allocators is not None:
            if len(self.allocators) != self.topology.n_stacks:
                raise ValueError(f"{len(self.allocators)} allocators for "
                                 f"{self.topology.n_stacks} stacks")
            self.n_slots = self.allocators[0].n_slots
            self.fabrics = [NomFabric(allocator=a, policy=self.policy,
                                      queue_depth=self.queue_depth,
                                      overflow=self.overflow)
                            for a in self.allocators]
        else:
            self.fabrics = [NomFabric(mesh=m, n_slots=self.n_slots,
                                      policy=self.policy,
                                      queue_depth=self.queue_depth,
                                      overflow=self.overflow,
                                      alloc_backend=self.alloc_backend)
                            for m in self.topology.stacks]
        self.segmented = SegmentedAllocator(
            self.topology, [f.allocator for f in self.fabrics], self.n_slots)
        self.backend = "tdm"
        self.queue = AdmissionQueue(self.queue_depth, self.overflow)
        self.clock = 0
        self.last_cycle = 0
        self.report: ScheduleReport | None = None
        self.n_flushes = 0
        self.cross_requests = 0
        self.cross_committed = 0
        self.cross_reduce_trees = 0    # committed cross-stack reduce trees
        self.reduce_rollbacks = 0      # trees aborted (state restored)

    # -- introspection -------------------------------------------------------
    @property
    def effective_policy(self) -> str:
        return self.policy

    @property
    def pending(self) -> int:
        return len(self.queue.items)

    def fabric_of(self, stack: int) -> NomFabric:
        """The per-stack CCU authority (its slot tables, clock, queue)."""
        if not 0 <= stack < self.topology.n_stacks:
            raise ValueError(f"stack {stack} out of range "
                             f"[0, {self.topology.n_stacks})")
        return self.fabrics[stack]

    # -- two-level address normalization -------------------------------------
    def _endpoint(self, v, stack: int | None) -> tuple[int, int]:
        if stack is not None:
            self.topology.global_id(int(stack), int(v))  # validates ranges
            return int(stack), int(v)
        if isinstance(v, tuple):
            if len(v) != 2:
                raise ValueError(f"stacked endpoint must be (stack, node), "
                                 f"got {v!r}")
            self.topology.global_id(int(v[0]), int(v[1]))
            return int(v[0]), int(v[1])
        return self.topology.locate(int(v))

    def _split(self, transfers):
        """Partition a batch three ways: same-stack requests (localized,
        grouped per stack), cross-stack copies (kept with their
        endpoints), and cross-stack reduces (kept with every operand
        endpoint — they become reduce trees)."""
        groups: dict[int, list] = {}
        cross: list = []
        cross_red: list = []
        for pos, t in enumerate(transfers):
            if not isinstance(t, (TransferRequest, CopyRequest)):
                t = CopyRequest(*t)
            is_tr = isinstance(t, TransferRequest)
            if _is_reduce(t):
                srcs = _reduce_srcs(t)
                if not srcs:
                    raise ValueError(f"reduce requires fan-in sources "
                                     f"(srcs), got {t!r}")
                s_stack = t.src_stack if is_tr else None
                eps = [self._endpoint(s, s_stack) for s in srcs]
                de = self._endpoint(t.dst, t.dst_stack if is_tr else None)
                if len(set(eps)) != len(eps):
                    raise ValueError(f"reduce sources must be distinct, "
                                     f"got {t!r}")
                if de in eps:
                    raise ValueError(f"reduce destination {de} is already "
                                     f"a source in {t!r}")
                if all(st == de[0] for st, _n in eps):
                    locs = tuple(n for _st, n in eps)
                    if is_tr:
                        local = dataclasses.replace(
                            t, src=locs[0], dst=de[1], srcs=locs,
                            src_stack=None, dst_stack=None)
                    else:
                        local = dataclasses.replace(t, src=locs[0],
                                                    dst=de[1], srcs=locs)
                    groups.setdefault(de[0], []).append((pos, local))
                else:
                    cross_red.append((pos, t, eps, de))
                continue
            se = self._endpoint(t.src, t.src_stack if is_tr else None)
            de = self._endpoint(t.dst, t.dst_stack if is_tr else None)
            if _is_init(t) and se != de:
                raise ValueError(f"init requires src == dst, got {t!r}")
            if se[0] == de[0]:
                if is_tr:
                    local = dataclasses.replace(t, src=se[1], dst=de[1],
                                                src_stack=None,
                                                dst_stack=None)
                else:
                    local = dataclasses.replace(t, src=se[1], dst=de[1])
                groups.setdefault(se[0], []).append((pos, local))
            else:
                cross.append((pos, t, se, de))
        return groups, cross, cross_red

    # -- the synchronous batch path ------------------------------------------
    def schedule(self, transfers, cycle: int | None = None,
                 policy: str | None = None):
        """Schedule a batch across the cluster.

        Same-stack requests go to their stack's :class:`NomFabric` (one
        delegated batch per stack, identical ``cycle``/``policy``
        semantics); cross-stack requests are then negotiated one at a
        time through the two-phase :class:`SegmentedAllocator` — an
        uncommittable request is denied (``circuit=None``), exactly like
        a saturated single-stack mesh.  Returns ``(results, report)``
        with results in request order; the merged report counts the
        cross-stack share in ``n_cross_stack``.
        """
        transfers = list(transfers)
        groups, cross, cross_red = self._split(transfers)
        results: list = [None] * len(transfers)
        reports = []
        for stack in sorted(groups):
            positions = [p for p, _r in groups[stack]]
            reqs = [r for _p, r in groups[stack]]
            res, rep = self.fabrics[stack].schedule(reqs, cycle=cycle,
                                                    policy=policy)
            for p, r in zip(positions, res):
                results[p] = r
            reports.append(rep)
        circuits, stalls = [], 0
        for pos, t, se, de in cross:
            self.cross_requests += 1
            anchor = (cycle if cycle is not None
                      else max(self.fabrics[se[0]].clock,
                               self.fabrics[de[0]].clock))
            rq_cycle = getattr(t, "cycle", None)
            if rq_cycle is not None:
                anchor = max(anchor, rq_cycle)
            circ = self.segmented.allocate(se, de, max(1, t.nbytes), anchor)
            results[pos] = AllocResult(circuit=circ, searched_cycle=anchor)
            if circ is None:
                continue
            self.cross_committed += 1
            circuits.append(circ)
            stalls += max(0, circ.start_cycle - (anchor + 3))
            if cycle is None:
                nxt = ((circ.end_cycle // self.n_slots) + 1) * self.n_slots
                for s in (se[0], de[0]):
                    fab = self.fabrics[s]
                    fab.clock = max(fab.clock, nxt)
        for pos, t, eps, de in cross_red:
            self.cross_requests += 1
            involved = sorted({de[0], *(s for s, _n in eps)})
            anchor = (cycle if cycle is not None
                      else max(self.fabrics[s].clock for s in involved))
            rq_cycle = getattr(t, "cycle", None)
            if rq_cycle is not None:
                anchor = max(anchor, rq_cycle)
            tree = self._reduce_tree(t, eps, de, anchor)
            results[pos] = AllocResult(circuit=tree, searched_cycle=anchor)
            if tree is None:
                continue
            self.cross_committed += 1
            self.cross_reduce_trees += 1
            circuits.append(tree)
            stalls += max(0, tree.start_cycle - (anchor + 3))
            if cycle is None:
                nxt = ((tree.end_cycle // self.n_slots) + 1) * self.n_slots
                for s in involved:
                    fab = self.fabrics[s]
                    fab.clock = max(fab.clock, nxt)
        if cross or cross_red:
            reports.append(self._cross_report(
                len(cross) + len(cross_red), circuits, stalls,
                n_reduce=len(cross_red)))
        if not reports:
            reports = [ScheduleReport(backend="tdm", n_requests=0,
                                      n_scheduled=0, n_windows=0,
                                      max_inflight=0, avg_inflight=0.0)]
        report = reports[0]
        for rep in reports[1:]:
            report = report.merge(rep)
        if groups:
            self.last_cycle = (cycle if cycle is not None else
                               min(self.fabrics[s].last_cycle
                                   for s in groups))
        elif cross or cross_red:
            self.last_cycle = min(r.searched_cycle
                                  for r in results if r is not None)
        self.clock = max([self.clock] + [f.clock for f in self.fabrics])
        self.n_flushes += 1
        self.report = (report if self.report is None
                       else self.report.merge(report))
        return results, report

    def _cross_report(self, n_cross: int, circuits, stalls,
                      n_reduce: int = 0) -> ScheduleReport:
        n = self.n_slots
        starts = [c.start_cycle // n for c in circuits]
        w0 = min(starts, default=0)
        span = max((s - w0 + c.n_windows for s, c in zip(starts, circuits)),
                   default=0)
        active = np.zeros(span, np.int64)
        for s, c in zip(starts, circuits):
            active[s - w0:s - w0 + c.n_windows] += 1
        busy = active[active > 0]
        return ScheduleReport(
            backend="tdm", n_requests=n_cross, n_scheduled=len(circuits),
            n_windows=int(span),
            max_inflight=int(busy.max()) if busy.size else 0,
            avg_inflight=float(busy.mean()) if busy.size else 0.0,
            stall_cycles=stalls, n_cross_stack=n_cross, n_reduce=n_reduce)

    # -- cross-stack reduce trees --------------------------------------------
    def _tree_snapshot(self):
        """Every expiry table a reduce tree may touch (per-stack ports +
        SerDes links), copied — the all-or-nothing restore point."""
        tables = [f.allocator.table._ports for f in self.fabrics]
        tables.append(self.segmented.links)
        return ([(pe, pe.expiry.copy()) for pe in tables],
                self.segmented.link_windows)

    def _tree_restore(self, snap) -> None:
        saved, link_windows = snap
        for pe, exp in saved:
            if not np.array_equal(pe.expiry, exp):
                pe.expiry[...] = exp
                pe._recompute(pe.window)
        self.segmented.link_windows = link_windows

    def _commit_local_reduce(self, stack: int, srcs, dst: int, nbytes: int,
                             cycle: int):
        """Reserve one same-stack fan-in (a reduce-tree component)
        directly against the stack's slot table.  Returns the Circuit or
        None when infeasible; the caller owns tree-level rollback."""
        alloc = self.fabrics[stack].allocator
        n = alloc.n_slots
        t_ready = cycle + 3
        window = t_ready // n
        occ = alloc.table._ports.masks_at(window)
        st = alloc._prepare_reduce(
            CopyRequest(src=srcs[0], dst=dst, nbytes=max(1, nbytes),
                        op="reduce", srcs=tuple(srcs)),
            t_ready, occ, window)
        if st.denied:
            return None
        alloc.table._ports.reserve_arrays(st.idx, st.w_res + st.n_win)
        return Circuit(src=st.src, dst=st.dst, start_cycle=st.start_cycle,
                       n_windows=st.n_win, hops=st.hops,
                       distance=st.distance, _n_slots_hint=n, srcs=st.srcs)

    def _reduce_tree(self, t, eps, de, anchor: int) -> ReduceTree | None:
        """Commit one cross-stack reduce as a tree, all-or-nothing.

        Per remote stack: fan-in partial reduction at the bridge bank
        (bridge-resident operands merge for free), then one SerDes leg
        delivering the partial to the destination, anchored at the
        partial's drain (store-and-forward in the bridge's logic-die
        buffer).  Destination-stack operands fan in locally at the
        anchor.  Any infeasible component restores every expiry table
        byte-identically — the :class:`SegmentedAllocator` two-phase
        discipline widened to the whole tree."""
        ds, d_loc = de
        by_stack: dict[int, list[int]] = {}
        for st_, node in eps:
            by_stack.setdefault(st_, []).append(node)
        local_srcs = by_stack.pop(ds, [])
        snap = self._tree_snapshot()
        partials, legs = [], []
        ok = True
        for st_ in sorted(by_stack):
            bridge = self.topology.bridge_of(st_)
            fan = [nd for nd in by_stack[st_] if nd != bridge]
            leg_anchor = anchor
            if fan:
                part = self._commit_local_reduce(st_, fan, bridge,
                                                 t.nbytes, anchor)
                if part is None:
                    ok = False
                    break
                partials.append(part)
                leg_anchor = part.end_cycle
            leg = self.segmented.allocate((st_, bridge), (ds, d_loc),
                                          max(1, t.nbytes), leg_anchor)
            if leg is None:
                ok = False
                break
            legs.append(leg)
        local = None
        if ok and local_srcs:
            local = self._commit_local_reduce(ds, local_srcs, d_loc,
                                              t.nbytes, anchor)
            ok = local is not None
        if not ok:
            self._tree_restore(snap)
            self.reduce_rollbacks += 1
            return None
        comps = partials + legs + ([local] if local is not None else [])
        start = min(c.start_cycle for c in comps)
        arrival = max(c.arrival_cycle for c in comps)
        end = max(c.end_cycle for c in comps)
        return ReduceTree(dst=de, srcs=tuple(eps), start_cycle=start,
                          arrival_cycle=arrival, end_cycle=end,
                          n_windows=(end - start) // self.n_slots + 1,
                          distance=arrival - start, partials=partials,
                          legs=legs, local=local,
                          _n_slots_hint=self.n_slots)

    # -- the admission-queue path --------------------------------------------
    def submit(self, request, at: int | None = None) -> bool:
        """Admit one request into the cluster-level bounded queue — same
        overflow contract as :meth:`NomFabric.submit`."""
        return NomFabric.submit(self, request, at)

    def flush(self, cycle: int | None = None):
        """Drain the cluster queue through one batched :meth:`schedule`
        call — same pickup-pipeline contract as :meth:`NomFabric.flush`."""
        return NomFabric.flush(self, cycle)

    # -- telemetry -----------------------------------------------------------
    def telemetry(self) -> dict:
        """Cluster-wide stats: the merged scheduling counters, the
        cross-stack protocol counters (``cross_requests`` /
        ``cross_committed`` / ``cross_denied`` / ``cross_rollbacks``,
        the reduce-tree counters ``cross_reduce_trees`` /
        ``reduce_rollbacks``, SerDes ``link_windows``), and each
        stack's own fabric telemetry under ``"stacks"``."""
        agg = self.report
        return {
            "backend": self.backend,
            "n_stacks": self.topology.n_stacks,
            "flushes": self.n_flushes,
            "requests": 0 if agg is None else agg.n_requests,
            "scheduled": 0 if agg is None else agg.n_scheduled,
            "init_requests": 0 if agg is None else agg.n_init,
            "reduce_requests": 0 if agg is None else agg.n_reduce,
            "max_inflight": 0 if agg is None else agg.max_inflight,
            "avg_inflight": 0.0 if agg is None else agg.avg_inflight,
            "stall_cycles": 0 if agg is None else agg.stall_cycles,
            "fused_waves": 0 if agg is None else agg.fused_waves,
            "host_waves": 0 if agg is None else agg.host_waves,
            "cross_requests": self.cross_requests,
            "cross_committed": self.cross_committed,
            "cross_denied": self.segmented.denied,
            "cross_rollbacks": self.segmented.rollbacks,
            "cross_reduce_trees": self.cross_reduce_trees,
            "reduce_rollbacks": self.reduce_rollbacks,
            "link_windows": self.segmented.link_windows,
            "policy": self.effective_policy,
            "queue_depth": self.queue.depth,
            "pending": self.pending,
            "shed": self.queue.n_shed,
            "full_stalls": self.queue.full_stalls,
            "queue_stall_cycles": self.queue.stall_cycles,
            "queue_admitted": self.queue.n_admitted,
            "queue_wait_cycles": self.queue.wait_total,
            "queue_wait_p50": self.queue.wait_quantile(0.5),
            "queue_wait_p99": self.queue.wait_quantile(0.99),
            "stacks": [f.telemetry() for f in self.fabrics],
        }


__all__ = ["AdmissionQueue", "FabricCluster", "FabricOverflow", "NomFabric",
           "PolicyContext", "ReduceTree", "get_policy", "register_policy",
           "registered_policies", "unregister_policy"]
