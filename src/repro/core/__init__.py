# The paper's primary contribution: TDM circuit-switched inter-bank
# transfer (slot allocation + CCU) and its TPU adaptation (scheduled
# ppermute collectives + bulk-transfer planner).
from .bitvec import bit_is_free, free_slots, full_mask, rotr, rotr_np
from .fabric import (AdmissionQueue, FabricCluster, FabricOverflow,
                     NomFabric, PolicyContext, ReduceTree, get_policy,
                     register_policy, registered_policies, unregister_policy)
from .nom_collectives import (Transfer, TransferPlan, a2a_link_chunks,
                              nom_all_gather, nom_all_to_all, nom_allreduce,
                              nom_allreduce_banks, nom_reduce,
                              nom_reduce_scatter, plan_transfers,
                              ring_offsets)
from .scheduler import (ScheduleReport, TransferRequest, reduce_request,
                        schedule_transfers)
from .slot_alloc import (AllocResult, BatchReport, Circuit, CopyRequest,
                         SegmentedAllocator, SlotTable, StackedCircuit,
                         TdmAllocator, TdmAllocatorLight, traceback,
                         wavefront_search, wavefront_search_batch)
from .topology import (PAPER_MESH, Mesh3D, N_PORTS, PORT_LOCAL, StackLink,
                       StackedTopology, make_topology, port_for)

__all__ = [
    "AdmissionQueue", "FabricCluster", "FabricOverflow", "NomFabric",
    "PolicyContext", "ReduceTree",
    "get_policy", "register_policy", "registered_policies",
    "unregister_policy",
    "bit_is_free", "free_slots", "full_mask", "rotr", "rotr_np",
    "Transfer", "TransferPlan", "a2a_link_chunks", "nom_all_gather",
    "nom_all_to_all", "nom_allreduce", "nom_allreduce_banks", "nom_reduce",
    "nom_reduce_scatter", "plan_transfers", "ring_offsets",
    "AllocResult", "BatchReport", "Circuit", "CopyRequest", "ScheduleReport",
    "SegmentedAllocator", "SlotTable", "StackedCircuit", "TdmAllocator",
    "TdmAllocatorLight", "TransferRequest", "reduce_request",
    "schedule_transfers",
    "traceback", "wavefront_search", "wavefront_search_batch", "PAPER_MESH",
    "Mesh3D", "N_PORTS", "PORT_LOCAL", "StackLink", "StackedTopology",
    "make_topology", "port_for",
]
