"""3D-mesh topology of the Network-on-Memory.

The paper's evaluation target is an HMC-like stack: 4 DRAM layers, each an
8x8 grid of banks (two banks per slice, 32 slices) => an 8x8x4 mesh of 256
circuit-switched routers, one per bank.  Each router has six network ports
(+/-X, +/-Y, +/-Z) plus a local ejection/injection port into the bank.

A *vault* is a vertical column of banks sharing a TSV bus and a vault
controller on the logic die.  With 32 vaults over an 8x8 plane, one vault
spans a 1x2 column of (x, y) positions across all layers (8 banks/vault),
matching the HMC 2.1 organisation used by the paper.
"""
from __future__ import annotations

import bisect
import dataclasses
from functools import cached_property

import numpy as np

# Port numbering. Dimension d, direction +1 -> port 2*d; direction -1 -> 2*d+1.
PORT_XP, PORT_XM, PORT_YP, PORT_YM, PORT_ZP, PORT_ZM, PORT_LOCAL = range(7)
N_PORTS = 7
_STEP = {PORT_XP: (1, 0, 0), PORT_XM: (-1, 0, 0),
         PORT_YP: (0, 1, 0), PORT_YM: (0, -1, 0),
         PORT_ZP: (0, 0, 1), PORT_ZM: (0, 0, -1)}


def port_for(dim: int, direction: int) -> int:
    """Output-port index for a hop along `dim` (0=x,1=y,2=z) in `direction` (+/-1)."""
    return 2 * dim + (1 if direction < 0 else 0)


@dataclasses.dataclass(frozen=True)
class Mesh3D:
    """An X x Y x Z mesh of NoM routers (paper default: 8 x 8 x 4)."""

    X: int = 8
    Y: int = 8
    Z: int = 4
    vault_span_y: int = 2  # a vault covers (1 x vault_span_y) columns of banks

    def __post_init__(self) -> None:
        if min(self.X, self.Y, self.Z) < 1:
            raise ValueError(f"mesh dims must be >= 1, got "
                             f"{(self.X, self.Y, self.Z)}")
        if self.vault_span_y < 1:
            raise ValueError(f"vault_span_y must be >= 1, got "
                             f"{self.vault_span_y}")
        if self.Y % self.vault_span_y:
            raise ValueError(f"Y={self.Y} is not divisible by "
                             f"vault_span_y={self.vault_span_y}: vaults would "
                             f"not tile the plane")

    @property
    def n_nodes(self) -> int:
        return self.X * self.Y * self.Z

    @property
    def n_vaults(self) -> int:
        return self.X * (self.Y // self.vault_span_y)

    @property
    def max_dist(self) -> int:
        return (self.X - 1) + (self.Y - 1) + (self.Z - 1)

    # --- id <-> coordinate ----------------------------------------------
    def node_id(self, x: int, y: int, z: int) -> int:
        return (z * self.Y + y) * self.X + x

    def coords(self, node: int) -> tuple[int, int, int]:
        x = node % self.X
        y = (node // self.X) % self.Y
        z = node // (self.X * self.Y)
        return x, y, z

    @cached_property
    def coord_array(self) -> np.ndarray:
        """(n_nodes, 3) int32 coordinates, row i = coords(i)."""
        ids = np.arange(self.n_nodes)
        return np.stack([ids % self.X, (ids // self.X) % self.Y,
                         ids // (self.X * self.Y)], axis=1).astype(np.int32)

    # --- adjacency --------------------------------------------------------
    def neighbor(self, node: int, port: int) -> int | None:
        """Node reached through `port`, or None at a mesh boundary."""
        if port == PORT_LOCAL:
            return None
        x, y, z = self.coords(node)
        dx, dy, dz = _STEP[port]
        nx, ny, nz = x + dx, y + dy, z + dz
        if 0 <= nx < self.X and 0 <= ny < self.Y and 0 <= nz < self.Z:
            return self.node_id(nx, ny, nz)
        return None

    def manhattan(self, a: int, b: int) -> int:
        ax, ay, az = self.coords(a)
        bx, by, bz = self.coords(b)
        return abs(ax - bx) + abs(ay - by) + abs(az - bz)

    def dor_path(self, src: int, dst: int) -> list[tuple[int, int]]:
        """Dimension-ordered (X then Y then Z) shortest path.

        Returns [(node, out_port), ...] for every hop; the last element's
        out_port is PORT_LOCAL (ejection at the destination).
        """
        path: list[tuple[int, int]] = []
        x, y, z = self.coords(src)
        dx_, dy_, dz_ = self.coords(dst)
        cur = src
        for dim, (c, t) in enumerate(((x, dx_), (y, dy_), (z, dz_))):
            step = 1 if t > c else -1
            for _ in range(abs(t - c)):
                p = port_for(dim, step)
                path.append((cur, p))
                cur = self.neighbor(cur, p)
        path.append((cur, PORT_LOCAL))
        assert cur == dst
        return path

    # --- vaults (memory-controller domains) --------------------------------
    def vault_of(self, node: int) -> int:
        x, y, _z = self.coords(node)
        return x * (self.Y // self.vault_span_y) + y // self.vault_span_y

    def banks_of_vault(self, vault: int) -> list[int]:
        per_x = self.Y // self.vault_span_y
        x, yg = vault // per_x, vault % per_x
        return [self.node_id(x, yg * self.vault_span_y + dy, z)
                for z in range(self.Z) for dy in range(self.vault_span_y)]

    def column_of(self, node: int) -> int:
        """(x, y) column index — the NoM-Light vertical-bus resource id."""
        x, y, _z = self.coords(node)
        return y * self.X + x

    @cached_property
    def upstream_tables(self) -> dict[str, np.ndarray]:
        """Static gather tables for the vectorized wavefront search.

        For each dimension d and direction s in {+1,-1}, ``prev[d][s]`` maps a
        node to the neighbour *against* travel direction (the upstream node
        when circuits travel along +s), with -1 at boundaries.
        """
        n = self.n_nodes
        prev = np.full((3, 2, n), -1, dtype=np.int32)
        for node in range(n):
            for dim in range(3):
                for si, s in enumerate((1, -1)):
                    nb = self.neighbor(node, port_for(dim, -s))
                    prev[dim, si, node] = -1 if nb is None else nb
        return {"prev": prev}


# Paper-default mesh (Section 3: 8x8x4, 256 banks, 32 vaults).
PAPER_MESH = Mesh3D(8, 8, 4)


@dataclasses.dataclass(frozen=True)
class StackLink:
    """One inter-stack SerDes link between stacks ``a`` and ``b``.

    A link is a point-to-point serial lane pair, so it carries two
    *directed channels* (a->b and b->a) that are reserved independently.
    Its timing is a different class from mesh-hop TSV timing: a beat takes
    ``latency`` extra cycles to cross (flight + SerDes retiming), and one
    TDM slot-window moves ``link_bytes`` bytes (typically narrower than
    the 8-byte intra-stack mesh link).
    """

    a: int
    b: int
    latency: int = 8
    link_bytes: int = 4


@dataclasses.dataclass(frozen=True)
class StackedTopology:
    """N ``Mesh3D`` stacks chained by an inter-stack SerDes link graph.

    Two-level addressing: a bank is named by ``(stack, local node)`` or by
    a flat *global id* (``global_id``/``locate`` convert).  Each stack
    keeps its own slot tables and CCU; traffic between stacks leaves
    through the stack's *bridge bank* — the ``(0, 0, 0)`` logic-die
    landing node — crosses one or more SerDes links, and re-enters the
    destination stack's mesh at its bridge.

    ``link`` picks the inter-stack graph: ``"ring"`` (each stack wired to
    its two neighbours, shortest-direction routing) or ``"full"`` (a
    dedicated link per stack pair).  Heterogeneous stacks are allowed via
    ``meshes``; by default all stacks share ``mesh``.
    """

    n_stacks: int
    mesh: Mesh3D = PAPER_MESH
    link: str = "ring"
    link_latency: int = 8
    link_bytes: int = 4
    meshes: tuple[Mesh3D, ...] | None = None

    def __post_init__(self) -> None:
        if self.n_stacks < 1:
            raise ValueError(f"n_stacks must be >= 1, got {self.n_stacks}")
        if self.link not in ("ring", "full"):
            raise ValueError(f"unknown link topology {self.link!r}; "
                             f"expected 'ring' or 'full'")
        if self.link_latency < 0 or self.link_bytes < 1:
            raise ValueError("link_latency must be >= 0 and link_bytes >= 1")
        if self.meshes is not None:
            object.__setattr__(self, "meshes", tuple(self.meshes))
            if len(self.meshes) != self.n_stacks:
                raise ValueError(f"meshes has {len(self.meshes)} entries for "
                                 f"n_stacks={self.n_stacks}")

    @cached_property
    def stacks(self) -> tuple[Mesh3D, ...]:
        """Per-stack meshes (``meshes`` if given, else ``mesh`` repeated)."""
        return self.meshes if self.meshes else (self.mesh,) * self.n_stacks

    @cached_property
    def offsets(self) -> tuple[int, ...]:
        """Global-id base of each stack (stack s owns offsets[s] .. +n_nodes)."""
        out, acc = [], 0
        for m in self.stacks:
            out.append(acc)
            acc += m.n_nodes
        return tuple(out)

    @property
    def n_nodes(self) -> int:
        return self.offsets[-1] + self.stacks[-1].n_nodes

    # --- two-level addressing ------------------------------------------------
    def global_id(self, stack: int, node: int) -> int:
        """Flat bank id of local ``node`` in ``stack``."""
        if not 0 <= stack < self.n_stacks:
            raise ValueError(f"stack {stack} out of range [0, {self.n_stacks})")
        if not 0 <= node < self.stacks[stack].n_nodes:
            raise ValueError(f"node {node} out of range for stack {stack}")
        return self.offsets[stack] + node

    def locate(self, gid: int) -> tuple[int, int]:
        """Inverse of ``global_id``: flat id -> ``(stack, local node)``."""
        if not 0 <= gid < self.n_nodes:
            raise ValueError(f"global id {gid} out of range [0, {self.n_nodes})")
        stack = bisect.bisect_right(self.offsets, gid) - 1
        return stack, gid - self.offsets[stack]

    def stack_of(self, gid: int) -> int:
        """Stack owning flat bank id ``gid``."""
        return self.locate(gid)[0]

    def bridge_of(self, stack: int) -> int:
        """Local id of the stack's bridge bank — the (0, 0, 0) logic-die
        landing node where SerDes traffic enters/leaves the mesh."""
        if not 0 <= stack < self.n_stacks:
            raise ValueError(f"stack {stack} out of range [0, {self.n_stacks})")
        return self.stacks[stack].node_id(0, 0, 0)

    def is_cross(self, a: int, b: int) -> bool:
        """True when flat ids ``a`` and ``b`` live in different stacks."""
        return self.stack_of(a) != self.stack_of(b)

    # --- the link graph ------------------------------------------------------
    @cached_property
    def links(self) -> tuple[StackLink, ...]:
        n = self.n_stacks
        if n == 1:
            return ()
        if self.link == "full" or n == 2:
            pairs = [(a, b) for a in range(n) for b in range(a + 1, n)]
        else:  # ring
            pairs = [(i, (i + 1) % n) for i in range(n)]
        return tuple(StackLink(a, b, self.link_latency, self.link_bytes)
                     for a, b in pairs)

    @property
    def n_channels(self) -> int:
        """Directed SerDes channels: two (one per direction) per link."""
        return 2 * len(self.links)

    @cached_property
    def _chan(self) -> dict[tuple[int, int], int]:
        out: dict[tuple[int, int], int] = {}
        for k, ln in enumerate(self.links):
            out[(ln.a, ln.b)] = 2 * k
            out[(ln.b, ln.a)] = 2 * k + 1
        return out

    def channel(self, a: int, b: int) -> int:
        """Directed channel id for the ``a -> b`` SerDes hop (adjacent stacks)."""
        try:
            return self._chan[(a, b)]
        except KeyError:
            raise ValueError(f"stacks {a} and {b} are not directly linked "
                             f"under {self.link!r}") from None

    def stack_route(self, src_stack: int, dst_stack: int) -> list[tuple[int, int]]:
        """Directed stack hops ``[(a, b), ...]`` from src to dst stack.

        Empty for same-stack; one hop under ``"full"``; shortest ring
        direction (ties broken towards +1) under ``"ring"``.
        """
        for s in (src_stack, dst_stack):
            if not 0 <= s < self.n_stacks:
                raise ValueError(f"stack {s} out of range [0, {self.n_stacks})")
        if src_stack == dst_stack:
            return []
        if self.link == "full" or self.n_stacks == 2:
            return [(src_stack, dst_stack)]
        n = self.n_stacks
        fwd = (dst_stack - src_stack) % n
        step = 1 if fwd <= (src_stack - dst_stack) % n else -1
        hops, cur = [], src_stack
        while cur != dst_stack:
            nxt = (cur + step) % n
            hops.append((cur, nxt))
            cur = nxt
        return hops

    def route_channels(self, src_stack: int, dst_stack: int) -> list[int]:
        """Directed channel ids along ``stack_route(src_stack, dst_stack)``."""
        return [self.channel(a, b)
                for a, b in self.stack_route(src_stack, dst_stack)]

    def route_cycles(self, src_stack: int, dst_stack: int) -> int:
        """Beat latency of the SerDes leg: each hop costs 1 (slot advance)
        + the link's SerDes latency."""
        return sum(1 + self.links[c // 2].latency
                   for c in self.route_channels(src_stack, dst_stack))


def make_topology(n_stacks: int = 1,
                  mesh: Mesh3D | tuple[int, int, int] = PAPER_MESH,
                  *, link: str = "ring", link_latency: int = 8,
                  link_bytes: int = 4, vault_span_y: int = 2,
                  meshes=None) -> Mesh3D | StackedTopology:
    """The one production constructor for NoM topologies.

    Returns the bare ``Mesh3D`` for ``n_stacks=1`` (so every single-stack
    call site keeps today's exact types and behavior) and a
    ``StackedTopology`` otherwise.  ``mesh`` (or each entry of ``meshes``)
    may be a ``Mesh3D`` or an ``(X, Y, Z)`` tuple.  Production code must
    build topologies here rather than calling ``Mesh3D(...)`` directly —
    enforced by ``scripts/check_api.py``.
    """
    if isinstance(mesh, tuple):
        mesh = Mesh3D(*mesh, vault_span_y=vault_span_y)
    if meshes is not None:
        meshes = tuple(Mesh3D(*m, vault_span_y=vault_span_y)
                       if isinstance(m, tuple) else m for m in meshes)
        n_stacks = len(meshes)
    if n_stacks == 1 and meshes is None:
        return mesh
    return StackedTopology(n_stacks, mesh, link=link,
                           link_latency=link_latency, link_bytes=link_bytes,
                           meshes=meshes)
