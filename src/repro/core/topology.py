"""3D-mesh topology of the Network-on-Memory.

The paper's evaluation target is an HMC-like stack: 4 DRAM layers, each an
8x8 grid of banks (two banks per slice, 32 slices) => an 8x8x4 mesh of 256
circuit-switched routers, one per bank.  Each router has six network ports
(+/-X, +/-Y, +/-Z) plus a local ejection/injection port into the bank.

A *vault* is a vertical column of banks sharing a TSV bus and a vault
controller on the logic die.  With 32 vaults over an 8x8 plane, one vault
spans a 1x2 column of (x, y) positions across all layers (8 banks/vault),
matching the HMC 2.1 organisation used by the paper.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np

# Port numbering. Dimension d, direction +1 -> port 2*d; direction -1 -> 2*d+1.
PORT_XP, PORT_XM, PORT_YP, PORT_YM, PORT_ZP, PORT_ZM, PORT_LOCAL = range(7)
N_PORTS = 7
_STEP = {PORT_XP: (1, 0, 0), PORT_XM: (-1, 0, 0),
         PORT_YP: (0, 1, 0), PORT_YM: (0, -1, 0),
         PORT_ZP: (0, 0, 1), PORT_ZM: (0, 0, -1)}


def port_for(dim: int, direction: int) -> int:
    """Output-port index for a hop along `dim` (0=x,1=y,2=z) in `direction` (+/-1)."""
    return 2 * dim + (1 if direction < 0 else 0)


@dataclasses.dataclass(frozen=True)
class Mesh3D:
    """An X x Y x Z mesh of NoM routers (paper default: 8 x 8 x 4)."""

    X: int = 8
    Y: int = 8
    Z: int = 4
    vault_span_y: int = 2  # a vault covers (1 x vault_span_y) columns of banks

    @property
    def n_nodes(self) -> int:
        return self.X * self.Y * self.Z

    @property
    def n_vaults(self) -> int:
        return self.X * (self.Y // self.vault_span_y)

    @property
    def max_dist(self) -> int:
        return (self.X - 1) + (self.Y - 1) + (self.Z - 1)

    # --- id <-> coordinate ----------------------------------------------
    def node_id(self, x: int, y: int, z: int) -> int:
        return (z * self.Y + y) * self.X + x

    def coords(self, node: int) -> tuple[int, int, int]:
        x = node % self.X
        y = (node // self.X) % self.Y
        z = node // (self.X * self.Y)
        return x, y, z

    @cached_property
    def coord_array(self) -> np.ndarray:
        """(n_nodes, 3) int32 coordinates, row i = coords(i)."""
        ids = np.arange(self.n_nodes)
        return np.stack([ids % self.X, (ids // self.X) % self.Y,
                         ids // (self.X * self.Y)], axis=1).astype(np.int32)

    # --- adjacency --------------------------------------------------------
    def neighbor(self, node: int, port: int) -> int | None:
        """Node reached through `port`, or None at a mesh boundary."""
        if port == PORT_LOCAL:
            return None
        x, y, z = self.coords(node)
        dx, dy, dz = _STEP[port]
        nx, ny, nz = x + dx, y + dy, z + dz
        if 0 <= nx < self.X and 0 <= ny < self.Y and 0 <= nz < self.Z:
            return self.node_id(nx, ny, nz)
        return None

    def manhattan(self, a: int, b: int) -> int:
        ax, ay, az = self.coords(a)
        bx, by, bz = self.coords(b)
        return abs(ax - bx) + abs(ay - by) + abs(az - bz)

    def dor_path(self, src: int, dst: int) -> list[tuple[int, int]]:
        """Dimension-ordered (X then Y then Z) shortest path.

        Returns [(node, out_port), ...] for every hop; the last element's
        out_port is PORT_LOCAL (ejection at the destination).
        """
        path: list[tuple[int, int]] = []
        x, y, z = self.coords(src)
        dx_, dy_, dz_ = self.coords(dst)
        cur = src
        for dim, (c, t) in enumerate(((x, dx_), (y, dy_), (z, dz_))):
            step = 1 if t > c else -1
            for _ in range(abs(t - c)):
                p = port_for(dim, step)
                path.append((cur, p))
                cur = self.neighbor(cur, p)
        path.append((cur, PORT_LOCAL))
        assert cur == dst
        return path

    # --- vaults (memory-controller domains) --------------------------------
    def vault_of(self, node: int) -> int:
        x, y, _z = self.coords(node)
        return x * (self.Y // self.vault_span_y) + y // self.vault_span_y

    def banks_of_vault(self, vault: int) -> list[int]:
        per_x = self.Y // self.vault_span_y
        x, yg = vault // per_x, vault % per_x
        return [self.node_id(x, yg * self.vault_span_y + dy, z)
                for z in range(self.Z) for dy in range(self.vault_span_y)]

    def column_of(self, node: int) -> int:
        """(x, y) column index — the NoM-Light vertical-bus resource id."""
        x, y, _z = self.coords(node)
        return y * self.X + x

    @cached_property
    def upstream_tables(self) -> dict[str, np.ndarray]:
        """Static gather tables for the vectorized wavefront search.

        For each dimension d and direction s in {+1,-1}, ``prev[d][s]`` maps a
        node to the neighbour *against* travel direction (the upstream node
        when circuits travel along +s), with -1 at boundaries.
        """
        n = self.n_nodes
        prev = np.full((3, 2, n), -1, dtype=np.int32)
        for node in range(n):
            for dim in range(3):
                for si, s in enumerate((1, -1)):
                    nb = self.neighbor(node, port_for(dim, -s))
                    prev[dim, si, node] = -1 if nb is None else nb
        return {"prev": prev}


# Paper-default mesh (Section 3: 8x8x4, 256 banks, 32 vaults).
PAPER_MESH = Mesh3D(8, 8, 4)
