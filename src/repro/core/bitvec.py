"""Slot-bitvector math for TDM circuit switching.

The paper's PE-matrix accelerator propagates an n-bit *busy* vector along
all shortest paths: bit j == 1 means "a circuit using slot j at this router
is infeasible".  The two primitive operations are:

* ``rotate_right`` by one (a circuit using slot j upstream uses slot j+1 at
  the current router, so upstream-indexed bits shift right to stay aligned
  with the current router's slot index), and
* bitwise OR with a port's occupancy row (mark busy slots).

Vectors are packed into uint32 (windows up to 32 slots; the paper uses 16).
Both jnp (trace-safe) and numpy variants are provided: the search runs in
JAX (the "hardware accelerator"), the CCU's trace-back runs host-side.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

UINT = jnp.uint32
MAX_SLOTS = 32


def full_mask(n_slots: int) -> int:
    """All-busy mask for an n-slot window."""
    if not (0 < n_slots <= MAX_SLOTS):
        raise ValueError(f"n_slots must be in (0, {MAX_SLOTS}], got {n_slots}")
    return (1 << n_slots) - 1


def rotr(v, n_slots: int):
    """Rotate an n-slot busy-vector right by one (jnp, element-wise).

    Slot j at the upstream router corresponds to slot (j+1) mod n at the
    current router; a right rotation re-indexes upstream bits to the current
    router's slot numbering.
    """
    v = jnp.asarray(v, UINT)
    mask = jnp.asarray(full_mask(n_slots), UINT)
    one = jnp.asarray(1, UINT)
    hi = jnp.asarray(n_slots - 1, UINT)
    return ((v << one) | (v >> hi)) & mask


def rotr_np(v, n_slots: int):
    """numpy twin of :func:`rotr` (host-side trace-back)."""
    v = np.asarray(v, np.uint32)
    mask = np.uint32(full_mask(n_slots))
    return ((v << np.uint32(1)) | (v >> np.uint32(n_slots - 1))) & mask


def rotl_np(v, n_slots: int):
    """Rotate left by one — inverse of :func:`rotr_np`."""
    v = np.asarray(v, np.uint32)
    mask = np.uint32(full_mask(n_slots))
    return ((v >> np.uint32(1)) | (v << np.uint32(n_slots - 1))) & mask


def bit_is_free(vec: int, slot: int) -> bool:
    """True iff `slot` is available (bit clear) in busy-vector `vec`."""
    return (int(vec) >> int(slot)) & 1 == 0


def free_slots(vec: int, n_slots: int) -> list[int]:
    """All available slot indices in a busy-vector."""
    return [s for s in range(n_slots) if bit_is_free(vec, s)]


def set_bit(vec: int, slot: int) -> int:
    return int(vec) | (1 << int(slot))
