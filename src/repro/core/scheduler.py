"""Single entry point for concurrent bulk-transfer setup.

Every subsystem that needs link-disjoint circuits — the memory simulator's
CCU, checkpoint resharding, elastic shard migration, the benchmark
harness — routes through :func:`schedule_transfers`, which dispatches to
one of two backends sharing the same batched-commit discipline (search all
requests at once, reserve in arrival order, retry losers at later slots):

* **bank level** — a :class:`repro.core.slot_alloc.TdmAllocator` (or
  Light variant): TDM circuits on the 3D bank mesh, one vectorized
  wavefront pass per commit round.
* **device level** — :func:`repro.core.nom_collectives.plan_transfers`:
  DOR routes over a device mesh/torus packed into link-disjoint rounds.

Both return a :class:`ScheduleReport` with the concurrency profile (how
many circuits are in flight per TDM window/round) so callers can assert
the paper's headline property — *concurrent* transfer — uniformly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .nom_collectives import Transfer, TransferPlan, plan_transfers
from .slot_alloc import AllocResult, CopyRequest, TdmAllocator


@dataclasses.dataclass
class ScheduleReport:
    backend: str               # "tdm" | "rounds"
    n_requests: int
    n_scheduled: int
    n_windows: int             # TDM windows (tdm) / rounds (rounds) spanned
    max_inflight: int          # peak concurrent circuits in one window
    avg_inflight: float        # mean over non-empty windows
    search_rounds: int = 0     # vectorized search passes (tdm backend)
    conflicts: int = 0         # stale-snapshot retries (tdm backend)


def _tdm_report(alloc: TdmAllocator,
                results: list[AllocResult]) -> ScheduleReport:
    circuits = [r.circuit for r in results if r.circuit is not None]
    # Window-occupancy histogram: a circuit holds its slots for n_windows
    # consecutive windows starting at its reservation window.
    span = max((c.n_windows for c in circuits), default=0)
    active = np.zeros(span, np.int64)
    for c in circuits:
        active[:c.n_windows] += 1
    busy = active[active > 0]
    rep = alloc.last_report
    return ScheduleReport(
        backend="tdm", n_requests=len(results), n_scheduled=len(circuits),
        n_windows=int(span), max_inflight=int(busy.max()) if busy.size else 0,
        avg_inflight=float(busy.mean()) if busy.size else 0.0,
        search_rounds=rep.search_rounds, conflicts=rep.conflicts)


def schedule_transfers(transfers, *, allocator: TdmAllocator | None = None,
                       shape: tuple[int, ...] | None = None,
                       torus: bool = True, cycle: int = 0,
                       policy: str = "arrival"):
    """Schedule a batch of bulk transfers concurrently.

    Bank level (``allocator`` given): ``transfers`` is a list of
    :class:`CopyRequest` (or (src, dst, nbytes) tuples); returns
    ``(list[AllocResult], ScheduleReport)``.

    Device level (``shape`` given): ``transfers`` is a list of
    :class:`Transfer`; returns ``(TransferPlan, ScheduleReport)``.
    """
    if (allocator is None) == (shape is None):
        raise ValueError("pass exactly one of allocator= or shape=")
    if allocator is not None:
        results = allocator.allocate_batch(list(transfers), cycle)
        return results, _tdm_report(allocator, results)
    plan = plan_transfers(shape, list(transfers), torus=torus, policy=policy)
    conc = plan.concurrency()
    report = ScheduleReport(
        backend="rounds", n_requests=len(plan.transfers),
        n_scheduled=sum(1 for p in plan.paths if p),
        n_windows=plan.n_rounds, max_inflight=int(conc["max_inflight"]),
        avg_inflight=conc["avg_inflight"])
    return plan, report


__all__ = ["CopyRequest", "ScheduleReport", "Transfer", "TransferPlan",
           "schedule_transfers"]
