"""Transfer-request vocabulary + the deprecated one-shot entry point.

This module holds the *data layer* of the scheduler: the backend-agnostic
:class:`TransferRequest`, the :class:`ScheduleReport` telemetry record,
and the normalization helpers shared by both backends.  The *authority*
that schedules them is :class:`repro.core.fabric.NomFabric` — a stateful
session owning the topology, the allocator, the packing-policy registry,
and a bounded admission queue; every production subsystem holds one.

:func:`schedule_transfers`, the original kwargs-heavy free function,
survives only as a thin deprecated shim over a one-shot fabric (each call
emits ``DeprecationWarning``; ``scripts/check_api.py`` fails the build on
new call sites outside ``core/``).
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from .nom_collectives import Transfer, TransferPlan, plan_transfers  # noqa: F401  (re-export)
from .slot_alloc import AllocResult, CopyRequest, TdmAllocator


@dataclasses.dataclass(frozen=True)
class TransferRequest:
    """One pending bulk transfer, backend-agnostic.

    This is the lingua franca of :func:`schedule_transfers`: the serving
    engine emits its per-decode-step cache movement as TransferRequests,
    the MoE planner its expert-dispatch blocks, reshard its shard moves.

    Attributes:
      src, dst: endpoint ids.  Bank level (tdm backend): int node ids on
        the :class:`~repro.core.topology.Mesh3D`.  Device level (rounds
        backend): coordinate tuples on the device mesh; a bare int is
        promoted to a 1-D ring coordinate ``(int,)``.
      nbytes: payload size in bytes (default 1).  Determines how many TDM
        windows a bank-level circuit persists (8 bytes/slot-cycle on the
        paper's 64-bit links).
      tag: opaque caller label (cache-leaf path, parameter name, expert
        pair) carried through to the plan for attribution.
      max_extra_slots: bank level only — extra free TDM slots the CCU may
        bundle to accelerate this transfer (paper Section 2.1; default 0).
      cycle: bank level only — anchor this request later than the batch
        cycle (e.g. its source read completes later); default None
        (anchored at the batch cycle).
      op: ``"copy"`` (default) streams ``nbytes`` from ``src`` to ``dst``;
        ``"init"`` is INIT-class bulk initialization *in place* (requires
        ``src == dst``) — ring-buffer overwrites, eviction scrubs, page
        zeroing.  On the tdm backend an INIT becomes a *zero-hop* circuit
        occupying only the bank's LOCAL port while rows clear in-DRAM
        (RowClone-FPM); on the rounds backend it is a local no-route
        transfer.  Either way it shares the batch's admission order and
        shows up in :attr:`ScheduleReport.n_init`.
      src_stack, dst_stack: two-level addressing for a
        :class:`~repro.core.fabric.FabricCluster` — the stack each
        endpoint's (then stack-local) node id lives in.  ``None`` (the
        default) means ``src``/``dst`` are flat ids: plain node ids on a
        single-stack fabric, global ids (see
        :meth:`~repro.core.topology.StackedTopology.global_id`) on a
        cluster.  Single-stack fabrics ignore these fields.
      srcs: compute-class fan-in only (``op="reduce"``): the N source
        banks whose operands are combined at ``dst``.  ``src`` mirrors
        ``srcs[0]`` for backend compatibility.  Build these through
        :func:`reduce_request` (enforced by ``scripts/check_api.py``
        outside ``core/``).
    """
    src: object
    dst: object
    nbytes: int = 1
    tag: object = None
    max_extra_slots: int = 0
    cycle: int | None = None
    op: str = "copy"
    src_stack: int | None = None
    dst_stack: int | None = None
    srcs: tuple = ()


def reduce_request(srcs, dst, nbytes: int = 1, **kw) -> TransferRequest:
    """Build a compute-class fan-in request: combine one ``nbytes``
    operand from each bank in ``srcs`` at ``dst`` (``op="reduce"``).

    This is the one sanctioned constructor for multi-source requests —
    planners (``nom_reduce``/``nom_allreduce_banks``, MoE
    ``plan_combine``) and callers outside ``core/`` must come through
    here (or through those planners); ``scripts/check_api.py`` bans raw
    ``op="reduce"`` spellings elsewhere.  Sources must be pairwise
    distinct and must not include the destination: the destination bank
    holds the accumulator, it contributes its resident operand for free.
    """
    def _endpoint(e):
        # flat bank id, or a tuple endpoint ((stack, node) on a cluster,
        # device coords on the rounds backend — rejected at schedule()).
        return (tuple(int(v) for v in e) if isinstance(e, (tuple, list))
                else int(e))

    srcs = tuple(_endpoint(s) for s in srcs)
    if not srcs:
        raise ValueError("reduce_request needs at least one source bank")
    if len(set(srcs)) != len(srcs):
        raise ValueError(f"reduce sources must be distinct: {srcs}")
    dst = _endpoint(dst)
    dst_stack = kw.get("dst_stack")
    src_stack = kw.get("src_stack")
    if src_stack is None and dst_stack is None:
        if dst in srcs:
            raise ValueError(
                f"reduce destination {dst} is already a source "
                "(the accumulator bank contributes in place)")
    return TransferRequest(src=srcs[0], dst=dst, nbytes=nbytes,
                           op="reduce", srcs=srcs, **kw)


@dataclasses.dataclass
class ScheduleReport:
    """Telemetry of one :func:`schedule_transfers` call.

    Attributes:
      backend: ``"tdm"`` (bank-level :class:`TdmAllocator` circuits) or
        ``"rounds"`` (device-level DOR round packing).
      n_requests: requests submitted in this batch.
      n_scheduled: requests that received a circuit/route (the rest were
        denied — mesh saturated at every retry slot).
      n_windows: TDM windows (tdm) / rounds (rounds) the schedule spans —
        the makespan in scheduler time units.
      max_inflight: peak concurrent circuits in one window/round — the
        paper's "concurrent transfer" evidence; 1 means serialized.
      avg_inflight: mean in-flight circuits over non-empty windows/rounds.
      stall_cycles: total cycles (tdm; TDM-slot cycles) or rounds (rounds
        backend) that requests waited beyond their earliest possible start
        because slots/links were taken — queueing delay under contention.
      search_rounds: vectorized wavefront passes issued (tdm backend).
      conflicts: stale-snapshot commit retries (tdm backend).
      n_searched: per-request searches summed over all passes (tdm
        backend) — with conflict-scoped re-search this stays near
        ``n_requests + conflicts``; tail-wide retries would grow it
        quadratically with the batch.
      n_init: INIT-class requests (``op="init"``) in this batch — the
        eviction/initialization share of the traffic.
      n_reduce: compute-class requests (``op="reduce"``, fan-in
        circuits) in this batch — the in-memory combine share.
      n_cross_stack: requests whose endpoints live in different stacks of
        a :class:`~repro.core.topology.StackedTopology` (scheduled as
        two-phase segmented circuits by a ``FabricCluster``); 0 on every
        single-stack fabric.
      fused_waves: prepare rounds served by the fused compiled program
        (tdm backend) — the allocator's per-wave backend telemetry.
      host_waves: prepare rounds served by the split host pipeline (tiny
        rounds, conflict re-searches, ``backend="host"`` allocators).
    """
    backend: str               # "tdm" | "rounds"
    n_requests: int
    n_scheduled: int
    n_windows: int             # TDM windows (tdm) / rounds (rounds) spanned
    max_inflight: int          # peak concurrent circuits in one window
    avg_inflight: float        # mean over non-empty windows
    stall_cycles: int = 0      # waits beyond the earliest possible start
    search_rounds: int = 0     # vectorized search passes (tdm backend)
    conflicts: int = 0         # stale-snapshot retries (tdm backend)
    n_searched: int = 0        # per-request searches over all passes (tdm)
    n_init: int = 0            # INIT-class (op="init") requests in the batch
    n_reduce: int = 0          # compute-class (op="reduce") requests
    n_cross_stack: int = 0     # cross-stack requests (FabricCluster only)
    fused_waves: int = 0       # prepare rounds served by the fused program
    host_waves: int = 0        # prepare rounds served by the host pipeline
    agg_windows: int = 0       # windows folded into avg_inflight by merge()
    #   (0 on a fresh report: its own n_windows is the weight)

    def merge(self, other: "ScheduleReport") -> "ScheduleReport":
        """Accumulate another report of the same backend (telemetry over a
        sequence of batches, e.g. one serving step after another).
        ``avg_inflight`` stays the mean over all underlying non-empty
        windows (weights tracked in ``agg_windows``); ``n_windows`` keeps
        the largest single-batch makespan."""
        assert self.backend == other.backend, (self.backend, other.backend)
        wa = self.agg_windows or self.n_windows
        wb = other.agg_windows or other.n_windows
        num = self.avg_inflight * wa + other.avg_inflight * wb
        return ScheduleReport(
            backend=self.backend,
            n_requests=self.n_requests + other.n_requests,
            n_scheduled=self.n_scheduled + other.n_scheduled,
            n_windows=max(self.n_windows, other.n_windows),
            max_inflight=max(self.max_inflight, other.max_inflight),
            avg_inflight=num / (wa + wb) if wa + wb else 0.0,
            stall_cycles=self.stall_cycles + other.stall_cycles,
            search_rounds=self.search_rounds + other.search_rounds,
            conflicts=self.conflicts + other.conflicts,
            n_searched=self.n_searched + other.n_searched,
            n_init=self.n_init + other.n_init,
            n_reduce=self.n_reduce + other.n_reduce,
            n_cross_stack=self.n_cross_stack + other.n_cross_stack,
            fused_waves=self.fused_waves + other.fused_waves,
            host_waves=self.host_waves + other.host_waves,
            agg_windows=wa + wb)


def _as_copy_requests(transfers) -> list[CopyRequest]:
    """Normalize bank-level input: CopyRequest | TransferRequest | tuple."""
    out = []
    for t in transfers:
        if isinstance(t, CopyRequest):
            out.append(t)
        elif isinstance(t, TransferRequest):
            out.append(CopyRequest(int(t.src), int(t.dst), t.nbytes,
                                   max_extra_slots=t.max_extra_slots,
                                   cycle=t.cycle, op=t.op,
                                   srcs=tuple(int(s) for s in t.srcs)))
        else:
            out.append(CopyRequest(*t))
    return out


def _coord(v) -> tuple[int, ...]:
    return tuple(v) if isinstance(v, (tuple, list)) else (int(v),)


def _as_transfers(transfers) -> list[Transfer]:
    """Normalize device-level input: Transfer | TransferRequest | tuple."""
    out = []
    for t in transfers:
        if isinstance(t, Transfer):
            out.append(t)
        elif isinstance(t, TransferRequest):
            out.append(Transfer(src=_coord(t.src), dst=_coord(t.dst),
                                nbytes=t.nbytes, tag=t.tag))
        else:
            out.append(Transfer(*t))
    return out


def _tdm_report(alloc: TdmAllocator, reqs: list[CopyRequest],
                results: list[AllocResult], cycle: int) -> ScheduleReport:
    circuits = [r.circuit for r in results if r.circuit is not None]
    # Window-occupancy histogram: a circuit holds its slots for n_windows
    # consecutive windows starting at its streaming window — circuits
    # anchored at different cycles (per-request anchors) must not be
    # stacked onto the same window.
    n = alloc.n_slots
    starts = [c.start_cycle // n for c in circuits]
    w0 = min(starts, default=0)
    span = max((s - w0 + c.n_windows for s, c in zip(starts, circuits)),
               default=0)
    active = np.zeros(span, np.int64)
    for s, c in zip(starts, circuits):
        active[s - w0:s - w0 + c.n_windows] += 1
    busy = active[active > 0]
    # Queueing delay: injection happens at start_cycle; the earliest a
    # request could inject is its anchor + the 3-cycle CCU setup pipeline.
    stall = 0
    for rq, res in zip(reqs, results):
        if res.circuit is None:
            continue
        anchor = max(rq.cycle if rq.cycle is not None else cycle, cycle) + 3
        stall += max(0, res.circuit.start_cycle - anchor)
    rep = alloc.last_report
    return ScheduleReport(
        backend="tdm", n_requests=len(results), n_scheduled=len(circuits),
        n_windows=int(span), max_inflight=int(busy.max()) if busy.size else 0,
        avg_inflight=float(busy.mean()) if busy.size else 0.0,
        stall_cycles=stall,
        search_rounds=rep.search_rounds, conflicts=rep.conflicts,
        n_searched=rep.n_searched,
        n_init=sum(1 for rq in reqs if rq.op == "init"),
        n_reduce=sum(1 for rq in reqs if rq.op == "reduce"),
        fused_waves=rep.fused_waves, host_waves=rep.host_waves)


def schedule_transfers(transfers, *, allocator: TdmAllocator | None = None,
                       shape: tuple[int, ...] | None = None,
                       torus: bool = True, cycle: int = 0,
                       policy: str = "arrival"):
    """Deprecated: schedule a batch of bulk transfers through a one-shot
    :class:`~repro.core.fabric.NomFabric`.

    Construct a session fabric instead — ``NomFabric(mesh=...)`` /
    ``NomFabric(allocator=...)`` (bank level) or ``NomFabric(shape=...)``
    (device level) — and call its ``schedule``: same return shapes
    (``(list[AllocResult], ScheduleReport)`` / ``(TransferPlan,
    ScheduleReport)``), plus session telemetry, the policy registry, and
    admission control.  This shim exists for out-of-tree callers and
    emits ``DeprecationWarning``; production call sites are gated by
    ``scripts/check_api.py``.
    """
    warnings.warn(
        "schedule_transfers is deprecated; hold a repro.core.fabric."
        "NomFabric session and call fabric.schedule(...) instead",
        DeprecationWarning, stacklevel=2)
    from .fabric import NomFabric
    if (allocator is None) == (shape is None):
        raise ValueError("pass exactly one of allocator= or shape=")
    if allocator is not None:
        fab = NomFabric(allocator=allocator)
        return fab.schedule(transfers, cycle=cycle)
    fab = NomFabric(shape=shape, torus=torus)
    return fab.schedule(transfers, policy=policy)


__all__ = ["CopyRequest", "ScheduleReport", "Transfer", "TransferPlan",
           "TransferRequest", "reduce_request", "schedule_transfers"]
