"""TDM slot allocation — the paper's core algorithm (Section 2.1).

The CCU services copy requests by finding a *circuit*: a sequence of
increasingly-numbered TDM slots along a shortest path, so data advances one
hop per cycle with no buffering/arbitration.  The paper implements the
search with a matrix of PEs (one per router) that propagate an n-bit busy
vector along all shortest paths: at each PE the vector is OR-ed with the
output-port occupancy and rotated right (slot j upstream -> slot j+1 here);
zero bits surviving at the destination are feasible circuits.

Implementation layout (mirrors the hardware split):

* :func:`wavefront_search` — the PE-matrix accelerator, vectorized JAX
  (``vmap``-able over a batch of requests; the Pallas TPU kernel in
  ``repro.kernels.slot_alloc`` implements the same contract).
* :class:`SlotTable` — the CCU's occupancy bookkeeping (host-side numpy):
  per (router, port, slot) reservation expiry in TDM-window units, with
  *incrementally maintained* packed busy masks (reservations set bits
  eagerly, an expiry-bucket map clears them lazily as the query window
  advances) and a device-resident copy for the search.
* :func:`traceback` / :func:`traceback_batch` — walk the converged
  vectors backwards to extract the hop lists, as the paper's "tracing
  back the path towards the source PE"; the batch variant steps every
  requested (request, arrival-slot) job in lockstep with vectorized
  per-dimension upstream selection.

Slot/cycle accounting (paper Fig. 2): a circuit of distance D injected at
source slot ``s`` uses slot ``s+i (mod n)`` at the i-th router on the path
and ejects through the destination's LOCAL port at slot ``s+D (mod n)`` —
e.g. 5 routers / slots 3..7 for the A->B example.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .bitvec import UINT, bit_is_free, full_mask, rotr, rotr_np
from .topology import (Mesh3D, N_PORTS, PORT_LOCAL, StackedTopology,
                       port_for)

_STRIDES = ("X", "XY")  # doc only


# ---------------------------------------------------------------------------
# The PE-matrix search (pure JAX; jit + vmap friendly)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("mesh", "n_slots"))
def wavefront_search(occ: jax.Array, src: jax.Array, dst: jax.Array,
                     init_vec: jax.Array, *, mesh: Mesh3D,
                     n_slots: int) -> jax.Array:
    """Propagate busy-vectors from ``src`` to every node of the shortest-path
    lattice toward ``dst``.

    Args:
      occ: (n_nodes, N_PORTS) uint32 — busy mask per output port.
      src, dst: scalar int32 node ids (traced; may come from a vmapped batch).
      init_vec: uint32 scalar — initial busy vector at the source (0 for a
        fresh search; non-zero when composing multi-phase NoM-Light routes).

    Returns:
      (n_nodes,) uint32: converged busy vector per node, indexed by the slot
      at which that node's *output* crossbar would be used.  Out-of-lattice
      nodes hold the all-busy mask.  ``vec[dst] | occ[dst, LOCAL]`` is the
      availability vector of arrival slots.
    """
    n = mesh.n_nodes
    fm = jnp.asarray(full_mask(n_slots), UINT)
    coords = jnp.asarray(mesh.coord_array)          # (n, 3)
    src_c = coords[src]                             # (3,)
    dst_c = coords[dst]
    sign = jnp.sign(dst_c - src_c)                  # (3,) in {-1,0,1}
    lo = jnp.minimum(src_c, dst_c)
    hi = jnp.maximum(src_c, dst_c)
    in_box = jnp.all((coords >= lo) & (coords <= hi), axis=1)  # (n,)

    strides = jnp.asarray([1, mesh.X, mesh.X * mesh.Y], jnp.int32)
    node_ids = jnp.arange(n, dtype=jnp.int32)

    # Per-dimension upstream node id and validity.
    # upstream_d(v) = v - sign_d * stride_d ; valid iff we have moved >=1 step
    # in dimension d away from the source and d is a travel dimension.
    ups = node_ids[None, :] - sign[:, None] * strides[:, None]      # (3, n)
    moved = coords.T != src_c[:, None]                              # (3, n)
    valid = in_box[None, :] & moved & (sign[:, None] != 0)          # (3, n)
    ups = jnp.clip(ups, 0, n - 1)

    # Output port used at the upstream node for a hop along dim d, dir sign_d.
    ports = jnp.where(sign < 0, 2 * jnp.arange(3) + 1, 2 * jnp.arange(3))

    vec0 = jnp.full((n,), fm, UINT).at[src].set(jnp.asarray(init_vec, UINT))
    is_src = node_ids == src

    def body(_, vec):
        def cand(d):
            up = ups[d]
            v = vec[up] | occ[up, ports[d]]
            v = rotr(v, n_slots)
            return jnp.where(valid[d], v, fm)
        new = cand(0) & cand(1) & cand(2)
        # Source keeps its injected vector; out-of-lattice nodes stay busy.
        return jnp.where(in_box & ~is_src, new, vec0)

    # The lattice is a DAG of depth <= max_dist, so max_dist sweeps converge.
    vec = jax.lax.fori_loop(0, mesh.max_dist, body, vec0)
    return vec


def wavefront_search_batch(occ, srcs, dsts, init_vecs, *, mesh, n_slots):
    """vmap over a batch of (src, dst) requests sharing one occupancy state.

    This is the paper's "explore all possible paths ... in parallel" taken one
    step further: concurrent request *searches* also run in parallel (the CCU
    still reserves sequentially, in FIFO order).
    """
    fn = partial(wavefront_search, mesh=mesh, n_slots=n_slots)
    return jax.vmap(lambda s, d, iv: fn(occ, s, d, iv))(srcs, dsts, init_vecs)


@partial(jax.jit, static_argnames=("mesh", "n_slots"))
def _search_batch_jit(occ, srcs, dsts, init_vecs, *, mesh, n_slots):
    """Module-level jit of the batched search so the compile cache is shared
    across allocator instances (static over mesh geometry + window size)."""
    return wavefront_search_batch(occ, srcs, dsts, init_vecs, mesh=mesh,
                                  n_slots=n_slots)


def _pow2_pad(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


_SMALL_SEARCH = 8     # at/below this batch, the host evaluation wins


# offset enumeration of a shortest-path box, keyed by its spans — shared
# across calls/instances (the box geometry is position-independent).
_BOX_OFFSETS: dict[tuple[int, int, int], list] = {}


def _wavefront_host(occ: np.ndarray, mesh: Mesh3D, n_slots: int, src: int,
                    dst: int, init_vec: int) -> np.ndarray:
    """Scalar twin of :func:`wavefront_search` for tiny batches.

    The shortest-path lattice is a DAG ordered by distance from the
    source, so one pass in topological (upstream-first) order computes
    the exact fixpoint the accelerator reaches after ``max_dist`` sweeps
    — bit-identical, without a device round-trip.  Used for
    conflict-scoped re-search rounds and small serial batches, where the
    dispatch overhead of the vectorized path dwarfs its compute.
    """
    fm = full_mask(n_slots)
    vec = np.full(mesh.n_nodes, fm, np.uint32)
    vec[src] = np.uint32(init_vec & fm)
    if src == dst:
        return vec
    coords = mesh.coord_array
    sx, sy, sz = (int(c) for c in coords[src])
    dx, dy, dz = (int(c) for c in coords[dst])
    spans = (abs(dx - sx), abs(dy - sy), abs(dz - sz))
    sgn = (1 if dx >= sx else -1, 1 if dy >= sy else -1,
           1 if dz >= sz else -1)
    strides = (1, mesh.X, mesh.X * mesh.Y)
    step = tuple(sgn[d] * strides[d] for d in range(3))
    ports = tuple(2 * d + (1 if sgn[d] < 0 else 0) for d in range(3))
    n1 = n_slots - 1
    offsets = _BOX_OFFSETS.get(spans)
    if offsets is None:
        offsets = sorted(
            ((ox, oy, oz) for ox in range(spans[0] + 1)
             for oy in range(spans[1] + 1) for oz in range(spans[2] + 1)
             if ox or oy or oz), key=lambda o: o[0] + o[1] + o[2])
        _BOX_OFFSETS[spans] = offsets
    vals = {src: int(init_vec) & fm}
    nodes, out = [], []
    for off in offsets:
        v = src + off[0] * step[0] + off[1] * step[1] + off[2] * step[2]
        acc = fm
        first = True
        for d in range(3):
            if not off[d]:
                continue
            u = v - step[d]
            val = vals[u] | int(occ[u, ports[d]])
            val = ((val << 1) | (val >> n1)) & fm
            acc = val if first else acc & val
            first = False
        vals[v] = acc
        nodes.append(v)
        out.append(acc)
    vec[nodes] = out
    return vec


# ---------------------------------------------------------------------------
# Host-side CCU bookkeeping
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Circuit:
    """A reserved circuit: ``hops[i] = (node, out_port, slot)`` in forward
    order; the last hop is (dst, PORT_LOCAL, arrival_slot)."""
    src: int
    dst: int
    start_cycle: int          # absolute cycle of source injection
    n_windows: int            # TDM windows the reservation persists
    hops: list[tuple[int, int, int]]
    slots_per_window: int = 1
    uses_bus: bool = False    # NoM-Light vertical bus hop present
    bus_column: int = -1      # (x, y) column whose TSV the bus hop rides
    distance: int = 0         # hops traversed by one beat (src -> dst)

    @property
    def arrival_cycle(self) -> int:
        return self.start_cycle + self.distance

    @property
    def end_cycle(self) -> int:
        """Cycle at which the last beat has arrived at the destination."""
        return self.arrival_cycle + (self.n_windows - 1) * self._n_slots_hint

    _n_slots_hint: int = 16
    # Compute-class fan-in (op="reduce"): the N source banks whose
    # operands this circuit merges at ``dst``.  ``hops`` then holds every
    # per-source route (in source order — the fixed summation tree) plus
    # the ALU-dwell slots on the destination's LOCAL port; ``src`` mirrors
    # ``srcs[0]``; ``distance`` spans injection of the first beat to
    # arrival of the last operand.  Empty for copy/init circuits.
    srcs: tuple = ()


class _PackedExpiry:
    """Expiry table with incrementally maintained packed busy masks.

    ``expiry[*prefix, slot]`` is the TDM window until which the slot is
    reserved (exclusive).  ``masks_at(w)`` returns the packed uint32 busy
    masks (bit s set iff ``expiry[..., s] > w``) *without* recomputing the
    full reduction each call: reservations set bits eagerly, and an
    expiry-bucket map clears them lazily as the query window advances.
    A backward window jump (rare: re-anchored benchmarks/tests) falls back
    to a from-scratch rebuild.  ``version`` bumps on every mask change —
    the device-resident occupancy re-uploads only when it moved.
    """

    def __init__(self, prefix_shape: tuple[int, ...], n_slots: int):
        self.n_slots = n_slots
        self.expiry = np.zeros((*prefix_shape, n_slots), np.int64)
        self.masks = np.zeros(prefix_shape, np.uint32)
        self.window = 0                     # the window `masks` is valid for
        self._weights = np.uint32(1) << np.arange(n_slots, dtype=np.uint32)
        self._buckets: dict[int, list] = {}  # until -> [tuple of idx arrays]
        self.version = 0

    def _recompute(self, window: int) -> None:
        live = self.expiry > window
        self.masks = (live * self._weights).sum(-1, dtype=np.uint64) \
            .astype(np.uint32)
        idx = np.nonzero(live)
        untils = self.expiry[idx]
        self._buckets = {}
        for u in np.unique(untils).tolist():
            m = untils == u
            self._buckets[int(u)] = [tuple(a[m] for a in idx)]
        self.window = window
        self.version += 1

    def masks_at(self, window: int) -> np.ndarray:
        """Packed busy masks as of ``window`` (the live cache — callers
        must treat the returned array as read-only)."""
        if window == self.window:
            return self.masks
        if window < self.window:
            self._recompute(window)
            return self.masks
        changed = False
        for u in [u for u in self._buckets if u <= window]:
            for idx in self._buckets.pop(u):
                still = self.expiry[idx] <= window
                if not still.any():      # re-reserved: lives in a later bucket
                    continue
                pidx = tuple(a[still] for a in idx[:-1])
                np.bitwise_and.at(self.masks, pidx,
                                  ~self._weights[idx[-1][still]])
                changed = True
        self.window = window
        if changed:
            self.version += 1
        return self.masks

    def reserve_arrays(self, idx: tuple[np.ndarray, ...], until: int,
                       unique: bool = False) -> None:
        """Reserve every ``(*prefix, slot)`` in the index arrays until
        ``until`` (exclusive), keeping the packed masks in sync.

        ``unique=True`` asserts the prefix tuples are pairwise distinct
        (true for a single-slot circuit: one hop per node), allowing the
        buffered fancy ``|=`` instead of ``np.bitwise_or.at``."""
        self.expiry[idx] = until
        if until > self.window:
            if unique:
                self.masks[idx[:-1]] |= self._weights[idx[-1]]
            else:
                np.bitwise_or.at(self.masks, idx[:-1],
                                 self._weights[idx[-1]])
        self._buckets.setdefault(int(until), []).append(idx)
        self.version += 1

    def reserve_run(self, idxs: list, cat: tuple[np.ndarray, ...],
                    untils: list[int]) -> None:
        """Batch spelling of :meth:`reserve_arrays` for a *run* of
        reservations whose full ``(*prefix, slot)`` entries are pairwise
        distinct across the whole run (the pending-run commit).  ``cat``
        is the pre-concatenated index tuple of every entry in ``idxs``;
        ``untils`` is per-reservation.  Prefix tuples may still repeat
        (two circuits on the same link at different slots), in which case
        the buffered fancy ``|=`` would drop bits — detect and fall back
        to ``np.bitwise_or.at``."""
        reps = np.fromiter((len(ix[-1]) for ix in idxs), np.int64,
                           len(idxs))
        u = np.repeat(np.asarray(untils, np.int64), reps)
        self.expiry[cat] = u
        live = u > self.window
        flat = cat[0]
        for d, c in enumerate(cat[1:-1], 1):
            flat = flat * self.expiry.shape[d] + c
        if live.all() and np.unique(flat).size == flat.size:
            self.masks[cat[:-1]] |= self._weights[cat[-1]]
        else:
            np.bitwise_or.at(self.masks, tuple(c[live] for c in cat[:-1]),
                             self._weights[cat[-1][live]])
        for ix, until in zip(idxs, untils):
            self._buckets.setdefault(int(until), []).append(ix)
        self.version += 1

    def reserve_flat(self, ent: np.ndarray, until_ent: np.ndarray,
                     idx_untils: list) -> None:
        """Flat-index spelling of :meth:`reserve_run` for the fused wave
        commit: ``ent`` holds raveled ``(*prefix, slot)`` entry ids
        (pairwise distinct across the run), ``until_ent`` the per-entry
        expiry, ``idx_untils`` the ``(idx_tuple, until)`` pairs for the
        lazy-expiry bucket bookkeeping.  Prefixes may repeat (two
        circuits on one link at different slots) — detected, falling
        back to ``np.bitwise_or.at``."""
        self.expiry.reshape(-1)[ent] = until_ent
        live = until_ent > self.window
        if not live.all():  # pragma: no cover - hot path reserves ahead
            ent = ent[live]
        # Entries are pairwise distinct, so each (prefix, slot) bit is
        # contributed at most once — summing the single-bit weights per
        # prefix (bincount) IS their bitwise OR, with no dup-prefix
        # detection needed.
        mf = self.masks.reshape(-1)
        mf |= np.bincount(ent // self.n_slots,
                          weights=self._weights[ent % self.n_slots],
                          minlength=mf.size).astype(np.uint32)
        for ix, until in idx_untils:
            self._buckets.setdefault(until, []).append(ix)
        self.version += 1

    def release_arrays(self, idx: tuple[np.ndarray, ...],
                       prev: np.ndarray) -> None:
        """Roll back a :meth:`reserve_arrays` call: restore the exact prior
        expiries ``prev`` (captured before reserving) for ``idx`` and
        rebuild masks + buckets.  This is the two-phase commit abort path
        (cross-stack far-side conflict) — rollbacks are rare, so a full
        rebuild is cheaper than keeping an undo log in the hot path."""
        self.expiry[idx] = prev
        self._recompute(self.window)


class SlotTable:
    """Occupancy state of every router port (and NoM-Light vertical buses).

    ``expiry[node, port, slot]`` is the TDM-window index until which the slot
    is reserved (exclusive).  A slot is busy for a search anchored at window
    ``w`` iff ``expiry > w`` — conservative for circuits that would start
    after an existing reservation expires, which matches the paper's CCU (it
    services requests in FIFO order against current state).

    The packed busy masks are maintained *incrementally* (bits set on
    ``reserve``, cleared lazily as the query window advances past each
    reservation's expiry — see :class:`_PackedExpiry`) and mirrored into a
    device-resident array (:meth:`device_busy_masks`) that the vectorized
    wavefront search consumes without a host->device upload per pass.
    """

    def __init__(self, mesh: Mesh3D, n_slots: int = 16):
        self.mesh = mesh
        self.n_slots = n_slots
        self._ports = _PackedExpiry((mesh.n_nodes, N_PORTS), n_slots)
        # One vertical bus resource per (x, y) column (NoM-Light).
        self._bus = _PackedExpiry((mesh.X * mesh.Y,), n_slots)
        self._dev: jax.Array | None = None
        self._dev_version = -1

    # The underlying expiry arrays stay addressable under their original
    # names (tests and telemetry read them directly).
    @property
    def expiry(self) -> np.ndarray:
        return self._ports.expiry

    @property
    def bus_expiry(self) -> np.ndarray:
        return self._bus.expiry

    # -- masks ---------------------------------------------------------------
    def busy_masks(self, window: int) -> np.ndarray:
        """(n_nodes, N_PORTS) uint32 busy masks as of TDM window `window`."""
        return self._ports.masks_at(window).copy()

    def bus_busy_masks(self, window: int) -> np.ndarray:
        return self._bus.masks_at(window).copy()

    def device_busy_masks(self, window: int) -> jax.Array:
        """Device-resident twin of :meth:`busy_masks`.

        The occupancy stays on device across search rounds and is
        re-uploaded only when the incremental cache's version moved — a
        run of searches against an unchanged table (or with only window
        advances that expired nothing) pays no host->device transfer at
        all.  (At this table size — a few KB — one full upload beats a
        scatter of the changed rows, so a version bump re-uploads.)"""
        masks = self._ports.masks_at(window)
        if self._dev is None or self._dev_version != self._ports.version:
            # device_put is async — the transfer overlaps the host-side
            # wave bookkeeping that runs before the next dispatch.
            self._dev = jax.device_put(masks.copy())
            self._dev_version = self._ports.version
        return self._dev

    # -- validation -----------------------------------------------------------
    def can_reserve(self, hops: list[tuple[int, int, int]],
                    window: int) -> bool:
        """True iff every (node, port, slot) in ``hops`` is free as of
        ``window`` and the hop list itself is internally disjoint — the
        batched scheduler's commit check against circuits reserved after
        the search snapshot was taken."""
        seen: set[tuple[int, int, int]] = set()
        expiry = self._ports.expiry
        for hop in hops:
            node, port, slot = hop
            if hop in seen or expiry[node, port, slot] > window:
                return False
            seen.add(hop)
        return True

    def can_reserve_bus(self, column: int, slot: int, window: int) -> bool:
        return bool(self._bus.expiry[column, slot] <= window)

    # -- reservation ----------------------------------------------------------
    @staticmethod
    def _hops_idx(hops) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        h = np.asarray(hops, np.int64).reshape(-1, 3)
        return h[:, 0], h[:, 1], h[:, 2]

    def reserve(self, circuit: Circuit, window: int) -> None:
        idx = self._hops_idx(circuit.hops)
        assert (self.expiry[idx] <= window).all() \
            and len(circuit.hops) == len(set(circuit.hops)), "double booking"
        self._ports.reserve_arrays(idx, window + circuit.n_windows)

    def reserve_bus(self, column: int, slot: int, window: int,
                    n_windows: int) -> None:
        assert self._bus.expiry[column, slot] <= window, "bus double booking"
        self._bus.reserve_arrays((np.asarray([column]), np.asarray([slot])),
                                 window + n_windows)

    def utilization(self, window: int) -> float:
        return float((self.expiry > window).mean())


# ---------------------------------------------------------------------------
# Trace-back (paper: "reserved by tracing back the path towards the source")
# ---------------------------------------------------------------------------
def traceback(vec: np.ndarray, occ: np.ndarray, mesh: Mesh3D, n_slots: int,
              src: int, dst: int, arrival_slot: int) -> list[tuple[int, int, int]]:
    """Extract one feasible hop list ending at ``dst`` on ``arrival_slot``.

    ``vec`` is the converged busy-vector array from :func:`wavefront_search`
    (numpy), ``occ`` the (n_nodes, N_PORTS) busy masks used for the search.
    """
    coords = mesh.coord_array
    hops: list[tuple[int, int, int]] = [(dst, PORT_LOCAL, arrival_slot)]
    v, j = int(dst), int(arrival_slot)
    strides = (1, mesh.X, mesh.X * mesh.Y)
    sign = np.sign(coords[dst] - coords[src])
    guard = 0
    while v != src:
        guard += 1
        if guard > mesh.max_dist + 2:
            raise RuntimeError("traceback failed to reach source")
        jp = (j - 1) % n_slots
        placed = False
        for d in range(3):
            if sign[d] == 0 or coords[v][d] == coords[src][d]:
                continue
            u = v - int(sign[d]) * strides[d]
            p = port_for(d, int(sign[d]))
            if bit_is_free(int(vec[u]) | int(occ[u, p]), jp):
                hops.append((u, p, jp))
                v, j = u, jp
                placed = True
                break
        if not placed:
            raise RuntimeError(
                f"no free upstream at node {v} slot {j} (inconsistent search)")
    hops.reverse()
    return hops


def traceback_batch(vecs: np.ndarray, vec_rows: np.ndarray, occ: np.ndarray,
                    mesh: Mesh3D, n_slots: int, srcs: np.ndarray,
                    dsts: np.ndarray, arrival_slots: np.ndarray):
    """Vectorized :func:`traceback` over a batch of (request, slot) jobs.

    Every job walks upstream in lockstep: one iteration per remaining hop,
    with the per-dimension candidate masks (validity: still displaced from
    the source along d; feasibility: the upstream busy bit is clear)
    evaluated for the whole batch at once and the first free dimension
    selected in the same x->y->z priority order as the serial walk.

    Args:
      vecs: (R, n_nodes) uint32 converged busy vectors.
      vec_rows: (J,) row of ``vecs`` each job reads.
      occ: (n_nodes, N_PORTS) uint32 busy masks the search ran against.
      srcs, dsts, arrival_slots: (J,) per-job endpoints + arrival slot.

    Returns:
      ``(hop_nodes, hop_ports, hop_slots, dists, ok)`` where the hop arrays
      are (J, max_dist+1) with job j's forward hop list in ``[:dists[j]+1]``
      (last entry = (dst, LOCAL, arrival)), and ``ok[j]`` is False when the
      walk found no free upstream (infeasible arrival slot — the batched
      twin of the serial walk's RuntimeError).
    """
    J = srcs.size
    coords = mesh.coord_array
    dists = np.abs(coords[srcs] - coords[dsts]).sum(1)
    L = int(dists.max()) + 1 if J else 1
    hop_n = np.zeros((J, L), np.int64)
    hop_p = np.zeros((J, L), np.int64)
    hop_s = np.zeros((J, L), np.int64)
    rows = np.arange(J)
    hop_n[rows, dists] = dsts
    hop_p[rows, dists] = PORT_LOCAL
    hop_s[rows, dists] = arrival_slots
    src_c = coords[srcs]                                        # (J, 3)
    sign = np.sign(coords[dsts] - src_c).astype(np.int64)       # (J, 3)
    strides = np.asarray([1, mesh.X, mesh.X * mesh.Y], np.int64)
    dims = np.arange(3)
    ports = np.where(sign < 0, 2 * dims + 1, 2 * dims)          # (J, 3)
    v = dsts.astype(np.int64).copy()
    j = np.asarray(arrival_slots, np.int64).copy()
    widx = dists - 1                    # next (backward) write position
    ok = np.ones(J, bool)
    active = v != srcs
    while active.any():
        jp = (j - 1) % n_slots
        u = np.clip(v[:, None] - sign * strides[None], 0, mesh.n_nodes - 1)
        valid = (sign != 0) & (coords[v] != src_c)              # (J, 3)
        busy = vecs[vec_rows[:, None], u] | occ[u, ports]
        cand = valid & (((busy >> jp[:, None]) & 1) == 0)
        has = cand.any(1)
        ok[active & ~has] = False
        move = np.nonzero(active & has)[0]
        d = cand[move].argmax(1)        # first free dim: x -> y -> z priority
        uu = u[move, d]
        hop_n[move, widx[move]] = uu
        hop_p[move, widx[move]] = ports[move, d]
        hop_s[move, widx[move]] = jp[move]
        v[move] = uu
        j[move] = jp[move]
        widx[move] -= 1
        active = np.zeros(J, bool)
        active[move] = v[move] != srcs[move]
    return hop_n, hop_p, hop_s, dists, ok


def _hops_list(hop_n, hop_p, hop_s, job: int, length: int):
    """Forward hop-tuple list of one traceback job (Python ints)."""
    return list(zip(hop_n[job, :length].tolist(), hop_p[job, :length].tolist(),
                    hop_s[job, :length].tolist()))


_SMALL_TRACE = 24     # below this many jobs the scalar walk wins


def _traceback_jobs(vecs, vec_rows, occ, mesh, n_slots, srcs, dsts,
                    arrival_slots):
    """Hop lists + feasibility for a batch of (request, slot) jobs.

    Dispatches between the scalar walk (per-job Python, cheaper below
    ~:data:`_SMALL_TRACE` jobs — e.g. a conflict-scoped re-search round)
    and :func:`traceback_batch` (lockstep numpy, amortizes over large
    rounds).  Both produce identical paths: same x->y->z upstream
    priority, same slot arithmetic.

    Returns ``(hops, ok)`` — per job the forward hop-tuple list (None
    when infeasible) and the feasibility flag.
    """
    J = len(srcs)
    if J < _SMALL_TRACE:
        hops: list = []
        ok = np.ones(J, bool)
        for k in range(J):
            try:
                hops.append(traceback(vecs[vec_rows[k]], occ, mesh, n_slots,
                                      int(srcs[k]), int(dsts[k]),
                                      int(arrival_slots[k])))
            except RuntimeError:
                hops.append(None)
                ok[k] = False
        return hops, ok
    hop_n, hop_p, hop_s, dists, ok = traceback_batch(
        vecs, vec_rows, occ, mesh, n_slots, srcs, dsts, arrival_slots)
    return [_hops_list(hop_n, hop_p, hop_s, k, int(dists[k]) + 1)
            if ok[k] else None for k in range(J)], ok


_FAR = np.int64(2 ** 62)


def _best_slots_np(avail: np.ndarray, dists: np.ndarray,
                   t_readys: np.ndarray, n_slots: int):
    """Vectorized slot choice: earliest (start_cycle, arrival_slot) over
    the free arrival slots of each row's availability vector, for circuits
    of ``dists`` hops ready at ``t_readys``.

    Returns ``(start_cycles, arrival_slots, free, denied)``; ties on the
    start cycle resolve to the lowest arrival slot, exactly like the
    serial ascending scan."""
    slots = np.arange(n_slots, dtype=np.int64)
    free = ((avail.astype(np.int64)[:, None] >> slots[None, :]) & 1) == 0
    s_inj = (slots[None, :] - dists[:, None]) % n_slots
    c = t_readys[:, None] + ((s_inj - t_readys[:, None]) % n_slots)
    cost = np.where(free, c, _FAR)
    a = cost.argmin(1)
    rows = np.arange(len(avail))
    return cost[rows, a], a, free, ~free.any(1)


# ---------------------------------------------------------------------------
# Full allocation: batched search + slot choice + trace-back + reserve
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class AllocResult:
    circuit: Circuit | None
    searched_cycle: int


@dataclasses.dataclass(frozen=True)
class CopyRequest:
    """One pending inter-bank copy for the batched CCU pipeline.

    ``cycle`` optionally anchors this request later than the batch cycle
    (e.g. its source read completes later); the occupancy snapshot is still
    taken at the batch cycle, which is conservative.

    ``op`` selects the operation class: ``"copy"`` (default) streams
    ``nbytes`` over a circuit from ``src`` to ``dst``; ``"init"`` is
    bulk initialization *in place* (``src == dst``) — the CCU sets up a
    zero-hop circuit that occupies only the bank's LOCAL port while the
    bank clears rows internally (RowClone-FPM style), so INIT traffic
    shares the CCU's admission/telemetry pipeline without consuming mesh
    links; ``"reduce"`` is the compute-class fan-in — one ``nbytes``
    operand from every bank in ``srcs`` is combined at ``dst`` over
    per-source circuits sharing the destination port under the ALU-dwell
    occupancy model (``src`` mirrors ``srcs[0]``)."""
    src: int
    dst: int
    nbytes: int
    max_extra_slots: int = 0
    cycle: int | None = None
    op: str = "copy"
    srcs: tuple = ()


@dataclasses.dataclass
class BatchReport:
    """Telemetry of the last ``allocate_batch`` call."""
    n_requests: int = 0
    n_committed: int = 0
    n_denied: int = 0          # no feasible circuit even after re-search
    search_rounds: int = 0     # vectorized wavefront passes issued
    conflicts: int = 0         # stale-snapshot commits that forced a re-search
    n_searched: int = 0        # per-request searches summed over all passes
    #   (conflict-scoped re-search keeps this near n_requests; the old
    #   tail-wide retry made it grow ~quadratically with the tail length)
    fused_waves: int = 0       # prepare rounds served by the fused program
    host_waves: int = 0        # prepare rounds served by the host pipeline


_CONFLICT = object()   # sentinel: stale search, re-run against fresh state


@dataclasses.dataclass
class _Prepared:
    """One request's fully prepared commit: slot choice, traced hop
    bundle and reservation indices, derived from a (possibly stale)
    converged search.  Everything here is a pure function of the search
    snapshot, so committing only needs the live-table freshness check."""
    denied: bool = False
    conflict: bool = False     # prepared state is unusable: force re-search
    dup: bool = False          # bundle internally double-books (defensive)
    src: int = 0
    dst: int = 0
    start_cycle: int = 0
    w_res: int = 0
    n_win: int = 1
    slots_per_window: int = 1
    distance: int = 0
    hops: list | None = None
    idx: tuple | None = None           # (nodes, ports, slots) index arrays
    flat: set | None = None            # flat (node,port,slot) entry ids —
    #   the pending-run membership key (single-slot mesh circuits only)
    uses_bus: bool = False
    bus_column: int = -1
    bus_slots: list | None = None      # [(column, slot)] (NoM-Light)
    reduce: bool = False               # compute-class fan-in bundle: the
    #   (dst, LOCAL) prefix repeats across arrival + dwell slots, so the
    #   commit must take the duplicate-prefix-safe reservation path
    srcs: tuple = ()                   # fan-in sources (reduce only)


class TdmAllocator:
    """The CCU's allocation pipeline for the *full 3D mesh* NoM.

    The paper's CCU sets up *many* link-disjoint circuits that stream
    concurrently; :meth:`allocate_batch` is the corresponding entry point:
    one vectorized :func:`wavefront_search_batch` pass over every pending
    request, a *vectorized* post-search pipeline (batch slot choice +
    :func:`traceback_batch` over every needed arrival slot, extra-slot
    bundles included), then a host-side commit loop that reserves circuits
    in arrival order.  A commit can discover that an earlier circuit from
    the *same* batch claimed one of its hops (the search snapshot is
    per-round, not per-request); the loser is re-searched against fresh
    state together with only the still-pending requests whose
    shortest-path boxes intersect the resources claimed so far —
    everything else commits from its existing converged vectors — so the
    results are bit-identical to servicing the stream through
    :meth:`allocate` one request at a time.

    ``allocate`` (the serial spelling) implements the paper's 3-cycle
    setup: the request picked at cycle t searches at t (1 cycle), programs
    slot tables (1 cycle), issues the read (1 cycle), so the earliest
    injection is t+3.  It is a batch of one.
    """

    def __init__(self, mesh: Mesh3D, n_slots: int = 16,
                 link_bytes: int = 8, use_pallas: bool = False,
                 backend: str = "auto"):
        if backend not in ("auto", "host", "fused"):
            raise ValueError(f"backend must be auto|host|fused, "
                             f"got {backend!r}")
        self.mesh = mesh
        self.n_slots = n_slots
        self.link_bytes = link_bytes  # 64-bit links => 8 bytes/slot-cycle
        self.table = SlotTable(mesh, n_slots)
        self.last_report = BatchReport()
        # backend picks who serves a prepare round (search + slot choice +
        # trace-back): "fused" = always the single compiled program,
        # "host" = always the split host pipeline, "auto" = fused for full
        # waves, host for tiny rounds (serial allocate, conflict-scoped
        # re-search) where dispatch overhead dwarfs the compute.
        self.backend = backend
        self._last_prepare_backend = "host"
        # use_pallas routes every search through the kernel (no host
        # small-batch shortcut), so kernel tests exercise it end to end;
        # the fused program then runs its Pallas wavefront/scoring route.
        self._host_small = not use_pallas
        self._fused_kernel = "pallas" if use_pallas else "jnp"
        if use_pallas:  # pragma: no cover - exercised in kernel tests
            from repro.kernels.slot_alloc import ops as _ops
            self._search_batch = partial(_ops.wavefront_search_pallas_batch,
                                         mesh=mesh, n_slots=n_slots)
        else:
            self._search_batch = partial(_search_batch_jit, mesh=mesh,
                                         n_slots=n_slots)

    # An in-place INIT clears one DRAM row per TDM window (RowClone-FPM in
    # the bank; no bytes cross the mesh), so its zero-hop circuit holds the
    # LOCAL port for ceil(nbytes / init_row_bytes) windows.
    init_row_bytes: int = 8192

    # Compute-class fan-in (op="reduce"): extra TDM slot(s) the
    # destination bank's ALU holds on its LOCAL port per merged operand
    # (every operand after the first) — the dwell the fold into the
    # accumulator costs before the port can accept the next arrival.
    reduce_dwell: int = 1

    # Requests searched per vectorized wavefront pass.  The accelerator's
    # cost is linear in the wave size, so waves cost no extra search time,
    # and a fresher snapshot per wave keeps stale-commit conflicts flat as
    # the batch grows (results are bit-identical regardless of the value).
    search_wave: int = 64

    def n_windows_for(self, nbytes: int, slots: int = 1) -> int:
        per_window = self.link_bytes * slots
        return max(1, -(-nbytes // per_window))

    def n_windows_for_init(self, nbytes: int) -> int:
        return max(1, -(-nbytes // self.init_row_bytes))

    # -- public API -----------------------------------------------------------
    def allocate(self, src: int, dst: int, nbytes: int, cycle: int,
                 max_extra_slots: int = 0) -> AllocResult:
        """Find + reserve the earliest circuit for a copy of ``nbytes``.

        Returns AllocResult with circuit=None if the lattice is fully busy
        (caller retries next cycle, as the CCU would)."""
        return self.allocate_batch(
            [CopyRequest(src, dst, nbytes, max_extra_slots)], cycle)[0]

    def allocate_batch(self, requests: list, cycle: int) -> list[AllocResult]:
        """Service a batch of pending copy requests concurrently.

        This is the CCU's concurrent circuit establishment (paper Section
        2.2): every request of the batch is searched in one vectorized
        wavefront pass, prepared by the vectorized commit pipeline (batch
        slot choice + batched trace-back), then committed in arrival
        (FIFO) order against the live slot table, so each granted circuit
        is (router, port, slot)-disjoint from every other circuit live in
        its TDM windows.  A commit that finds its hops claimed by an
        earlier commit of the same batch triggers a fresh search for it —
        plus, in the same vectorized pass, any still-pending request whose
        shortest-path box intersects the claimed resources (the
        conflict-scoped invalidation); the rest of the batch commits from
        its existing converged vectors — results are bit-identical to
        streaming the requests through :meth:`allocate` one at a time.

        Args:
          requests: list of :class:`CopyRequest` (or bare
            ``(src, dst, nbytes)`` tuples).  ``src``/``dst`` are int bank
            ids on the mesh; ``nbytes`` is the payload in bytes — with the
            paper's 64-bit links one TDM slot moves ``link_bytes`` (8) per
            window, so the circuit persists
            ``ceil(nbytes / (8 * slots))`` windows.
          cycle: absolute allocator cycle at which the batch is picked up;
            injection starts no earlier than ``cycle + 3`` (the 3-cycle
            search/program/read setup pipeline).  Requests carrying their
            own ``cycle`` anchor are validated against this batch cycle
            (conservative) but reserved at their own window.

        Returns:
          One :class:`AllocResult` per request, in request order.
          ``circuit is None`` means the lattice was saturated at every
          candidate slot.  ``self.last_report`` holds the
          :class:`BatchReport` (search passes, conflicts, denials).
        """
        reqs = [r if isinstance(r, CopyRequest) else CopyRequest(*r)
                for r in requests]
        report = BatchReport(n_requests=len(reqs))
        results: list[AllocResult | None] = [None] * len(reqs)
        if not reqs:
            self.last_report = report
            return results
        window = (cycle + 3) // self.n_slots
        t_readys = np.fromiter(
            (max(r.cycle if r.cycle is not None else cycle, cycle) + 3
             for r in reqs), np.int64, len(reqs))
        # The batch is searched in *waves* (one vectorized pass each): the
        # accelerator's cost is linear in the wave size, so splitting
        # costs nothing, while each wave's snapshot already contains every
        # earlier commit — stale-snapshot conflicts only arise *within* a
        # wave, which keeps their count flat as the batch grows.
        #
        # Within a wave, conflict-scoped invalidation: bitmaps of the
        # nodes / bus columns claimed by commits since the wave's search.
        # A pending request whose shortest-path box contains no claimed
        # resource is *clean*: its converged vectors are provably
        # identical to a fresh search's, so it commits without even
        # touching the live table.  A box-hit state is validated against
        # the live table, and only an actual claim of one of its chosen
        # hops forces a re-search — of that request alone, on the host
        # fast path, not the whole tail.  (A state re-searched after a
        # conflict commits immediately, so the bitmaps never need
        # per-state sequencing.)
        # Deferred circuit emission of the last fused wave: its
        # reservations are final but its Circuit objects are built
        # overlapped with the *next* wave's device program.
        pending = None
        for lo in range(0, len(reqs), self.search_wave):
            hi = min(lo + self.search_wave, len(reqs))
            wave = reqs[lo:hi]
            self._last_prepare_backend = "host"
            if (self._wave_fast
                    and self._fused_eligible(len(wave), t_readys[lo:hi])
                    and all(r.op == "copy" and not r.max_extra_slots
                            for r in wave)):
                # All-simple fused wave: skip per-state materialization
                # entirely — the struct-of-arrays commit below.
                token = self._dispatch_wave_fused(wave, t_readys[lo:hi],
                                                  window)
                if pending is not None:
                    self._emit_wave_fused(pending, results, cycle)
                report.search_rounds += 1
                report.n_searched += len(wave)
                report.fused_waves += 1
                pending = self._commit_wave_fused(
                    token, wave, t_readys[lo:hi], lo, window, cycle,
                    results, report)
                continue
            if pending is not None:
                self._emit_wave_fused(pending, results, cycle)
                pending = None
            states = self._prepare_states(wave, t_readys[lo:hi], window)
            report.search_rounds += 1
            report.n_searched += len(wave)
            if self._last_prepare_backend == "fused":
                report.fused_waves += 1
            else:
                report.host_waves += 1
            # Pending *run*: consecutive single-slot states whose chosen
            # (node, port, slot) reservation entries are pairwise
            # disjoint.  Entry disjointness makes their commits
            # order-independent and keeps each member's live-table
            # validation independent of the others' (a commit only writes
            # its own entries), so the whole run is validated with ONE
            # vectorized expiry gather and committed with one vectorized
            # reservation — outcome-identical to committing each
            # serially.  A state that cannot join (bus route, extra-slot
            # bundle, entry overlap with a pending member) flushes the
            # run first, so the serial path always sees exactly the live
            # table it would have seen.
            run: list[int] = []
            run_claims: set = set()  # entry ids of pending members
            work = list(range(len(wave)))
            i = 0
            while True:
                if i >= len(work):
                    if not run:
                        break
                    redo = self._flush_pending(states, run, wave,
                                               t_readys[lo:hi], results,
                                               lo, window, cycle, report)
                    run = []
                    run_claims = set()
                    if redo:
                        work[i:i] = redo
                    continue
                k = work[i]
                st = states[k]
                if st.denied:
                    report.n_denied += 1
                    results[lo + k] = AllocResult(None, cycle)
                    i += 1
                    continue
                if st.flat is not None and not st.conflict:
                    if run_claims.isdisjoint(st.flat):
                        run.append(k)
                        run_claims |= st.flat
                        i += 1
                        continue
                # k cannot ride the pending run: flush, then retry k (it
                # may start the next run, or fall through to the serial
                # path below once the run is empty).
                if run:
                    redo = self._flush_pending(states, run, wave,
                                               t_readys[lo:hi], results,
                                               lo, window, cycle, report)
                    run = []
                    run_claims = set()
                    if redo:
                        work[i:i] = redo
                    continue
                out = self._commit_prepared(st, window, validate=True)
                if out is _CONFLICT:
                    st, out = self._handle_conflict(
                        wave[k], t_readys[lo + k:lo + k + 1], window,
                        report)
                if out is None:
                    report.n_denied += 1
                else:
                    report.n_committed += 1
                results[lo + k] = AllocResult(out, cycle)
                i += 1
        if pending is not None:
            self._emit_wave_fused(pending, results, cycle)
        self.last_report = report
        return results

    def _handle_conflict(self, req: CopyRequest, t_ready: np.ndarray,
                         window: int, report: BatchReport):
        """Stale-snapshot conflict: re-search ``req`` alone against the
        live table (the conflict-scoped re-search) and commit the fresh
        state, counter bookkeeping included.  Returns ``(state,
        circuit_or_None)``."""
        report.conflicts += 1
        self._last_prepare_backend = "host"
        st = self._reprepare_conflict(req, t_ready, window)
        report.search_rounds += 1
        report.n_searched += 1
        if self._last_prepare_backend == "fused":
            report.fused_waves += 1
        else:
            report.host_waves += 1
        out = self._commit_prepared(st, window, validate=False)
        assert out is not _CONFLICT, "fresh search conflicted with itself"
        return st, out

    def _flush_pending(self, states: list[_Prepared], ks: list[int],
                       wave: list[CopyRequest], t_readys_w: np.ndarray,
                       results, lo: int, window: int, cycle: int,
                       report: BatchReport) -> list[int]:
        """Validate + commit a pending run of entry-disjoint single-slot
        states in one vectorized pass.

        The run's expiry gather against the live table is element-wise
        identical to the serial loop's per-state validations: members'
        (node, port, slot) entry sets are pairwise disjoint, so
        committing one never changes another's check.  All pass => one
        batch reservation.  On the
        first failure — exactly the state the serial loop would bounce —
        the passing prefix commits, the loser re-searches fresh (the
        conflict-scoped re-search), and the not-yet-committed tail is
        handed back for another pass, where its members' validations see
        the loser's fresh claims.  Returns that tail."""
        table = self.table
        if len(ks) == 1:
            st = states[ks[0]]
            out = self._commit_prepared(st, window, validate=True)
            if out is _CONFLICT:
                st, out = self._handle_conflict(
                    wave[ks[0]], t_readys_w[ks[0]:ks[0] + 1], window,
                    report)
            if out is None:
                report.n_denied += 1
            else:
                report.n_committed += 1
            results[lo + ks[0]] = AllocResult(out, cycle)
            return []
        idxs = [states[k].idx for k in ks]
        cat = tuple(np.concatenate([ix[j] for ix in idxs])
                    for j in range(3))
        bad = table.expiry[cat] > window
        j = len(ks)
        if bad.any():
            # first member the serial loop would bounce
            lens = np.fromiter((len(ix[0]) for ix in idxs), np.int64,
                               len(idxs))
            pos = int(np.flatnonzero(bad)[0])
            j = int(np.searchsorted(np.cumsum(lens), pos, side="right"))
            idxs = idxs[:j]
            if j:
                upto = int(lens[:j].sum())
                cat = tuple(c[:upto] for c in cat)
        if j:
            table._ports.reserve_run(
                idxs, cat, [states[k].w_res + states[k].n_win
                            for k in ks[:j]])
            n_hint = self.n_slots
            for k in ks[:j]:
                st = states[k]
                report.n_committed += 1
                results[lo + k] = AllocResult(
                    Circuit(src=st.src, dst=st.dst,
                            start_cycle=st.start_cycle,
                            n_windows=st.n_win, hops=st.hops,
                            slots_per_window=st.slots_per_window,
                            uses_bus=st.uses_bus, bus_column=st.bus_column,
                            distance=st.distance, _n_slots_hint=n_hint),
                    cycle)
        if j == len(ks):
            return []
        kbad = ks[j]
        _st, out = self._handle_conflict(
            wave[kbad], t_readys_w[kbad:kbad + 1], window, report)
        if out is None:
            report.n_denied += 1
        else:
            report.n_committed += 1
        results[lo + kbad] = AllocResult(out, cycle)
        return ks[j + 1:]

    # Route all-simple fused waves (plain copies, no extra-slot bundles)
    # through the struct-of-arrays commit — _Prepared objects exist only
    # for conflict re-searches.  NoM-Light waves can carry bus hops, so
    # they keep the generic per-state loop.
    _wave_fast: bool = True

    def _dispatch_wave_fused(self, wave: list[CopyRequest],
                             t_w: np.ndarray, window: int):
        """Launch the fused program for a wave without blocking (JAX
        async dispatch) — the caller emits the previous wave's circuits
        while the device searches this one."""
        from repro.kernels.slot_alloc import fused as _fused
        B = len(wave)
        srcs = np.fromiter((r.src for r in wave), np.int64, B)
        dsts = np.fromiter((r.dst for r in wave), np.int64, B)
        return _fused.fused_prepare_start(
            self.table.device_busy_masks(window), srcs, dsts, t_w,
            mesh=self.mesh, n_slots=self.n_slots,
            kernel=self._fused_kernel)

    def _emit_wave_fused(self, pending, results, cycle: int) -> None:
        """Deferred circuit emission for a fused wave's clean commits:
        pure bookkeeping (no table access), so it runs overlapped with
        the next wave's device program."""
        wave, lo, rows, fp, n_win, dists_l = pending
        n = self.n_slots
        starts_l = fp.starts.tolist()
        nwin_l = n_win.tolist()
        hn_l = fp.hop_n.tolist()
        hp_l = fp.hop_p.tolist()
        hs_l = fp.hop_s.tolist()
        for i in rows:
            ln = dists_l[i] + 1
            r = wave[i]
            results[lo + i] = AllocResult(
                Circuit(src=r.src, dst=r.dst, start_cycle=starts_l[i],
                        n_windows=nwin_l[i],
                        hops=list(zip(hn_l[i][:ln], hp_l[i][:ln],
                                      hs_l[i][:ln])),
                        distance=dists_l[i], _n_slots_hint=n), cycle)

    def _commit_wave_fused(self, token, wave: list[CopyRequest],
                           t_w: np.ndarray, lo: int, window: int,
                           cycle: int, results, report: BatchReport):
        """Fused-program wave commit without per-state materialization.

        The wave's hop bundles stay in the program's (B, L) output
        arrays.  Rows are cut into *segments* — maximal runs of rows
        whose flat ``(node, port, slot)`` reservation entries are
        pairwise disjoint — by one python scan over the raveled entry
        ids.  Entry disjointness makes a segment's commits
        order-independent and its members' live-table validations
        independent of each other, so each segment is validated with a
        single flat expiry gather and reserved with a single vectorized
        write.  The first failing row of a segment is exactly the state
        the serial loop would bounce: the passing prefix commits, the
        loser re-searches against the live table (the conflict-scoped
        re-search, scalar fast path), and the remainder is requeued as
        its own segment — still pairwise disjoint — whose validation
        then sees the loser's fresh claims.  Bit-identical to streaming
        the wave through :meth:`allocate`.

        Returns the deferred emission record for
        :meth:`_emit_wave_fused` — reservations and conflict results are
        final when this returns, but clean commits' Circuit objects are
        not yet built."""
        from repro.kernels.slot_alloc import fused as _fused
        n = self.n_slots
        B = len(wave)
        fp = _fused.fused_prepare_wait(token)
        self._last_prepare_backend = "fused"
        denied = fp.denied
        if (~denied & ~fp.ok).any():
            i = int(np.flatnonzero(~denied & ~fp.ok)[0])
            raise RuntimeError(
                f"no free upstream for request "
                f"{wave[i].src}->{wave[i].dst} slot {int(fp.arr[i])} "
                f"(inconsistent search)")
        hop_n, hop_p, hop_s = fp.hop_n, fp.hop_p, fp.hop_s
        L = hop_n.shape[1]
        lens = np.where(denied, 0, fp.dists.astype(np.int64) + 1)
        valid = np.arange(L)[None, :] < lens[:, None]
        # int32 throughout: flat ids top out at n_nodes*N_PORTS*n_slots.
        ent = ((hop_n * N_PORTS + hop_p) * n + hop_s)[valid]
        offs = np.zeros(B + 1, np.int64)
        np.cumsum(lens, out=offs[1:])
        nbytes = np.fromiter((r.nbytes for r in wave), np.int64, B)
        n_win = np.maximum(1, -(-nbytes // self.link_bytes))
        untils = t_w // n + n_win
        ent_l = ent.tolist()
        offs_l = offs.tolist()
        denied_l = denied.tolist()
        dists_l = fp.dists.tolist()
        untils_l = untils.tolist()
        for i in np.flatnonzero(denied).tolist():
            report.n_denied += 1
            results[lo + i] = AllocResult(None, cycle)
        # Segment scan: a row whose entries hit the current segment's
        # claims starts the next segment.  (Denied rows are zero-width:
        # they never clash and commit nothing.)
        segs: list[tuple[int, int]] = []
        seen: dict[int, int] = {}
        sid = 0
        a = 0
        for i in range(B):
            row = ent_l[offs_l[i]:offs_l[i + 1]]
            for e in row:
                if seen.get(e, -1) == sid:
                    segs.append((a, i))
                    sid += 1
                    a = i
                    break
            for e in row:
                seen[e] = sid
        segs.append((a, B))
        ports = self.table._ports
        ef = ports.expiry.reshape(-1)
        emit_rows: list[int] = []
        p = 0
        while p < len(segs):
            a, b = segs[p]
            p += 1
            pa, pb = offs_l[a], offs_l[b]
            if pa == pb:       # all-denied segment: results already out
                continue
            bad = ef[ent[pa:pb]] > window
            if not bad.any():
                j = b
            else:
                pos = pa + int(np.flatnonzero(bad)[0])
                j = int(np.searchsorted(offs, pos, side="right")) - 1
            if offs_l[j] > pa:
                u_ent = np.repeat(untils[a:j], lens[a:j])
                idx_untils = []
                for i in range(a, j):
                    if denied_l[i]:
                        continue
                    ln = dists_l[i] + 1
                    idx_untils.append(
                        ((hop_n[i, :ln], hop_p[i, :ln], hop_s[i, :ln]),
                         untils_l[i]))
                    report.n_committed += 1
                    emit_rows.append(i)
                ports.reserve_flat(ent[pa:offs_l[j]], u_ent, idx_untils)
            if j >= b:
                continue
            _st, out = self._handle_conflict(wave[j], t_w[j:j + 1],
                                             window, report)
            if out is None:
                report.n_denied += 1
            else:
                report.n_committed += 1
            results[lo + j] = AllocResult(out, cycle)
            if j + 1 < b:
                segs[p:p] = [(j + 1, b)]
        return wave, lo, emit_rows, fp, n_win, dists_l

    # -- search + vectorized post-search pipeline -----------------------------
    def _run_search(self, occ, window, srcs, dsts, inits) -> np.ndarray:
        """One wavefront pass over ``srcs``/``dsts``/``inits`` (numpy
        arrays) against the host busy masks ``occ`` valid at ``window``.

        Large batches ride the accelerator (one vectorized pass over the
        device-resident occupancy, padded to a power of two so jit
        retraces stay rare); at or below :data:`_SMALL_SEARCH` requests —
        a conflict-scoped re-search round, a serial ``allocate`` — the
        host topological evaluation is cheaper than the dispatch.
        Returns (len(srcs), n_nodes) uint32 busy vectors (numpy)."""
        m = len(srcs)
        if self._host_small and m <= _SMALL_SEARCH:
            return np.stack([
                _wavefront_host(occ, self.mesh, self.n_slots, int(s),
                                int(d), int(iv))
                for s, d, iv in zip(srcs, dsts, inits)])
        occ_dev = self.table.device_busy_masks(window)
        pad = _pow2_pad(m)
        s = np.zeros(pad, np.int32)
        d = np.zeros(pad, np.int32)
        iv = np.zeros(pad, np.uint32)
        s[:m], d[:m], iv[:m] = srcs, dsts, inits
        vecs = self._search_batch(occ_dev, s, d, iv)
        return np.asarray(vecs)[:m]

    def _prepare_states(self, reqs: list[CopyRequest], t_readys: np.ndarray,
                        window: int) -> list[_Prepared]:
        """Prepare one wave: compute-class fan-ins through the scalar
        :meth:`_prepare_reduce` (identical on every backend), the rest
        through the copy/init pipeline — all against the same occupancy
        snapshot, reassembled in request order."""
        if not reqs:
            return []
        red_ix = {i for i, r in enumerate(reqs) if r.op == "reduce"}
        if not red_ix:
            return self._prepare_copy_states(reqs, t_readys, window)
        occ = self.table._ports.masks_at(window)
        red = {i: self._prepare_reduce(reqs[i], int(t_readys[i]), occ,
                                       window)
               for i in sorted(red_ix)}
        rest_ix = [i for i in range(len(reqs)) if i not in red_ix]
        rest = iter(self._prepare_copy_states(
            [reqs[i] for i in rest_ix], t_readys[rest_ix], window)
            if rest_ix else [])
        return [red[i] if i in red_ix else next(rest)
                for i in range(len(reqs))]

    def _prepare_reduce(self, r: CopyRequest, t_ready: int, occ: np.ndarray,
                        window: int) -> _Prepared:
        """Prepare a fan-in reduce bundle: one single-slot circuit per
        source bank, chosen in *request source order* (the fixed
        summation tree), each searched against the snapshot plus the
        bundle's own earlier reservations.  Every operand after the
        first additionally holds ``reduce_dwell`` ALU-dwell slot(s) on
        the destination's LOCAL port right after its arrival slot — the
        cycles the bank ALU needs to fold the operand into the
        accumulator — so the destination port carries
        ``k + (k-1)*reduce_dwell`` reservations for a fan-in of k.

        The routine is scalar and snapshot-pure on every backend
        (host == fused by construction); serial-vs-batch bit-identity
        follows from the same monotone feasible-set argument as copies:
        commits validate the whole bundle against the live table and a
        stale bundle re-prepares fresh.
        """
        n = self.n_slots
        mesh = self.mesh
        dwell = max(0, int(self.reduce_dwell))
        occ2 = occ.copy()
        hops_all: list[tuple[int, int, int]] = []
        start = last_arrival = None
        for j, s in enumerate(r.srcs):
            s = int(s)
            if s == r.dst:
                return _Prepared(denied=True, src=r.src, dst=r.dst)
            vec = _wavefront_host(occ2, mesh, n, s, r.dst, 0)
            avail = int(vec[r.dst]) | int(occ2[r.dst, PORT_LOCAL])
            local = int(occ2[r.dst, PORT_LOCAL])
            dist = mesh.manhattan(s, r.dst)
            best = None
            for a in range(n):
                if (avail >> a) & 1:
                    continue
                if j and dwell and any((local >> ((a + q) % n)) & 1
                                       for q in range(1, dwell + 1)):
                    continue        # ALU busy right after this arrival
                s_inj = (a - dist) % n
                c = t_ready + ((s_inj - t_ready) % n)
                if best is None or c < best[0]:
                    best = (c, a)
            if best is None:
                return _Prepared(denied=True, src=r.src, dst=r.dst)
            c, a = best
            hops = traceback(vec, occ2, mesh, n, s, r.dst, a)
            if j and dwell:
                hops = hops + [(r.dst, PORT_LOCAL, (a + q) % n)
                               for q in range(1, dwell + 1)]
            for hn, hp, hs in hops:
                occ2[hn, hp] |= np.uint32(1) << np.uint32(hs)
            hops_all += hops
            start = c if start is None else min(start, c)
            last_arrival = (c + dist if last_arrival is None
                            else max(last_arrival, c + dist))
        return _Prepared(
            src=r.src, dst=r.dst, start_cycle=start, w_res=t_ready // n,
            n_win=self.n_windows_for(r.nbytes), slots_per_window=1,
            distance=last_arrival - start, hops=hops_all,
            idx=SlotTable._hops_idx(hops_all), flat=None, reduce=True,
            srcs=tuple(int(s) for s in r.srcs))

    def _prepare_copy_states(self, reqs: list[CopyRequest],
                             t_readys: np.ndarray,
                             window: int) -> list[_Prepared]:
        if not reqs:
            return []
        if self._fused_eligible(len(reqs), t_readys):
            return self._prepare_fused(reqs, t_readys, window)
        occ = self.table._ports.masks_at(window)
        srcs = np.fromiter((r.src for r in reqs), np.int64, len(reqs))
        dsts = np.fromiter((r.dst for r in reqs), np.int64, len(reqs))
        vecs = self._run_search(occ, window, srcs, dsts,
                                np.zeros(len(reqs), np.uint32))
        return self._prepare_full(reqs, t_readys, vecs,
                                  np.arange(len(reqs)), occ, window,
                                  srcs=srcs, dsts=dsts)

    def _reprepare_conflict(self, req: CopyRequest, t_ready: np.ndarray,
                            window: int) -> _Prepared:
        """Fresh single-request prepare after a stale-snapshot conflict.

        On the host backends this skips the batch plumbing entirely: one
        scalar topological wavefront against the refreshed masks, then
        the scalar slot choice / trace-back — the conflict fast path the
        wave structure was designed around.  A forced-fused allocator
        re-prepares through the compiled program instead, so the
        differential harness exercises it end to end.  Fan-in bundles
        always re-prepare through the scalar reduce routine (their one
        prepare path on every backend)."""
        if req.op == "reduce":
            occ = self.table._ports.masks_at(window)
            return self._prepare_reduce(req, int(t_ready[0]), occ, window)
        if self._host_small and self.backend != "fused":
            occ = self.table._ports.masks_at(window)
            vec = _wavefront_host(occ, self.mesh, self.n_slots, req.src,
                                  req.dst, 0)
            return self._prepare_one(req, int(t_ready[0]), vec, occ, window)
        return self._prepare_states([req], t_ready, window)[0]

    # -- the fused compiled backend -------------------------------------------
    def _fused_eligible(self, batch: int, t_readys: np.ndarray) -> bool:
        """Route this prepare round through the fused program?  "auto"
        keeps the host scalar path for tiny rounds; every backend falls
        back to host when a start cycle could overflow the program's
        int32 cost arithmetic (the host pipeline scores in int64)."""
        if self.backend == "host":
            return False
        if self.backend == "auto" and batch <= _SMALL_SEARCH:
            return False
        return int(t_readys.max()) < 2 ** 31 - 2 * self.n_slots

    def _prepare_fused(self, reqs: list[CopyRequest], t_readys: np.ndarray,
                       window: int) -> list[_Prepared]:
        """One wave through the fused program (wavefront + slot choice +
        trace-back in a single compiled dispatch), then the same bundle
        assembly as :meth:`_prepare_full` — identical denial semantics,
        extra-slot order, and reservation indices."""
        from repro.kernels.slot_alloc import fused as _fused
        n = self.n_slots
        B = len(reqs)
        srcs = np.fromiter((r.src for r in reqs), np.int64, B)
        dsts = np.fromiter((r.dst for r in reqs), np.int64, B)
        fp = _fused.fused_prepare(
            self.table.device_busy_masks(window), srcs, dsts, t_readys,
            mesh=self.mesh, n_slots=n, kernel=self._fused_kernel)
        self._last_prepare_backend = "fused"
        denied, arr, ok = fp.denied, fp.arr, fp.ok
        want = np.fromiter(
            (0 if (r.op == "init" or denied[k]) else r.max_extra_slots
             for k, r in enumerate(reqs)), np.int64, B)
        er = ec = extra_hops = extra_ok = None
        if want.any():
            # Extra-slot bundles are rare: trace them on host against the
            # program's converged vectors (bit-identical walks).
            slots_ix = np.arange(n, dtype=np.int64)
            er, ec = np.nonzero(fp.free & (want > 0)[:, None]
                                & (slots_ix[None, :] != arr[:, None]))
            occ = self.table._ports.masks_at(window)
            extra_hops, extra_ok = _traceback_jobs(
                fp.vecs_np(), er, occ, self.mesh, n, srcs[er], dsts[er], ec)
        # One bulk .tolist() per column keeps the per-request assembly in
        # plain-python territory (per-element numpy indexing is ~10x the
        # cost of a list index at this size).
        denied_l = denied.tolist()
        ok_l = ok.tolist()
        dists_l = fp.dists.tolist()
        starts_l = fp.starts.tolist()
        tr_l = t_readys.tolist()
        hn_l = fp.hop_n.tolist()
        hp_l = fp.hop_p.tolist()
        hs_l = fp.hop_s.tolist()
        fl_l = ((fp.hop_n.astype(np.int64) * N_PORTS + fp.hop_p) * n
                + fp.hop_s).tolist()
        states: list[_Prepared] = []
        epos = 0
        for i, r in enumerate(reqs):
            if denied_l[i]:
                states.append(_Prepared(denied=True, src=r.src, dst=r.dst))
                continue
            if not ok_l[i]:
                raise RuntimeError(
                    f"no free upstream for request {r.src}->{r.dst} "
                    f"slot {int(arr[i])} (inconsistent search)")
            dist = dists_l[i]
            ln = dist + 1
            hops = list(zip(hn_l[i][:ln], hp_l[i][:ln], hs_l[i][:ln]))
            k = 1
            if er is not None:
                while epos < len(er) and er[epos] == i:
                    if k < 1 + want[i] and extra_ok[epos]:
                        hops = hops + extra_hops[epos]
                        k += 1
                    epos += 1
            n_win = (self.n_windows_for_init(r.nbytes) if r.op == "init"
                     else self.n_windows_for(r.nbytes, slots=k))
            states.append(_Prepared(
                src=r.src, dst=r.dst, start_cycle=starts_l[i],
                w_res=tr_l[i] // n, n_win=n_win,
                slots_per_window=k, distance=dist, hops=hops,
                idx=(fp.hop_n[i, :ln], fp.hop_p[i, :ln], fp.hop_s[i, :ln])
                if k == 1 else SlotTable._hops_idx(hops),
                flat=set(fl_l[i][:ln]) if k == 1 else None))
        return states

    def _prepare_one(self, r: CopyRequest, t_ready: int, vec: np.ndarray,
                     occ: np.ndarray, window: int) -> _Prepared:
        """Scalar spelling of :meth:`_prepare_full` for a single request —
        the conflict re-search / serial-allocate fast path (same slot
        choice, same trace-back order, same bundle assembly)."""
        n = self.n_slots
        avail = int(vec[r.dst]) | int(occ[r.dst, PORT_LOCAL])
        dist = self.mesh.manhattan(r.src, r.dst)
        best = None
        for a in range(n):
            if (avail >> a) & 1:
                continue
            s = (a - dist) % n
            c = t_ready + ((s - t_ready) % n)
            if best is None or c < best[0]:
                best = (c, a)
        if best is None:
            return _Prepared(denied=True, src=r.src, dst=r.dst)
        start, a = best
        hops = traceback(vec, occ, self.mesh, n, r.src, r.dst, a)
        k = 1
        if r.max_extra_slots and r.op != "init":
            for a2 in range(n):
                if k >= 1 + r.max_extra_slots:
                    break
                if a2 == a or not bit_is_free(avail, a2):
                    continue
                try:
                    hops = hops + traceback(vec, occ, self.mesh, n, r.src,
                                            r.dst, a2)
                except RuntimeError:
                    continue
                k += 1
        n_win = (self.n_windows_for_init(r.nbytes) if r.op == "init"
                 else self.n_windows_for(r.nbytes, slots=k))
        return _Prepared(
            src=r.src, dst=r.dst, start_cycle=start, w_res=t_ready // n,
            n_win=n_win, slots_per_window=k, distance=dist, hops=hops,
            idx=SlotTable._hops_idx(hops),
            flat={(hn * N_PORTS + hp) * n + hs for hn, hp, hs in hops}
            if k == 1 else None)

    def _prepare_full(self, reqs, t_readys, vecs, rows, occ, window,
                      srcs=None, dsts=None) -> list[_Prepared]:
        """The full-mesh post-search pipeline over one round's converged
        vectors: vectorized slot choice, batched trace-back of the chosen
        arrival slot *and* every extra-slot candidate, bundle assembly."""
        n = self.n_slots
        B = len(reqs)
        if B == 1:
            return [self._prepare_one(reqs[0], int(t_readys[0]),
                                      vecs[int(rows[0])], occ, window)]
        coords = self.mesh.coord_array
        if srcs is None:
            srcs = np.fromiter((r.src for r in reqs), np.int64, B)
            dsts = np.fromiter((r.dst for r in reqs), np.int64, B)
        dists = np.abs(coords[srcs] - coords[dsts]).sum(1)
        avail = vecs[rows, dsts] | occ[dsts, PORT_LOCAL]
        starts, arr, free, denied = _best_slots_np(avail, dists, t_readys, n)
        want = np.fromiter(
            (0 if (r.op == "init" or denied[k]) else r.max_extra_slots
             for k, r in enumerate(reqs)), np.int64, B)
        main_rows = np.nonzero(~denied)[0]
        slots_ix = np.arange(n, dtype=np.int64)
        er, ec = np.nonzero(free & (want > 0)[:, None]
                            & (slots_ix[None, :] != arr[:, None]))
        job_req = np.concatenate([main_rows, er])
        job_slot = np.concatenate([arr[main_rows], ec])
        jobs_hops, ok = _traceback_jobs(
            vecs, rows[job_req], occ, self.mesh, n,
            srcs[job_req], dsts[job_req], job_slot)
        main_pos = {int(r): k for k, r in enumerate(main_rows)}
        states: list[_Prepared] = []
        n_main = len(main_rows)
        epos = 0                   # cursor into the extra jobs (row-major)
        for i, r in enumerate(reqs):
            if denied[i]:
                states.append(_Prepared(denied=True, src=r.src, dst=r.dst))
                continue
            mj = main_pos[i]
            if not ok[mj]:
                raise RuntimeError(
                    f"no free upstream for request {r.src}->{r.dst} "
                    f"slot {int(arr[i])} (inconsistent search)")
            hops = jobs_hops[mj]
            k = 1
            while epos < len(er) and er[epos] == i:
                jid = n_main + epos
                if k < 1 + want[i] and ok[jid]:
                    hops = hops + jobs_hops[jid]
                    k += 1
                epos += 1
            # A shortest-path bundle cannot double-book itself: nodes are
            # distinct along one path, and two paths at the same (node,
            # port) sit at the same distance from dst, so distinct arrival
            # slots give distinct slots there — no dup check needed.
            n_win = (self.n_windows_for_init(r.nbytes) if r.op == "init"
                     else self.n_windows_for(r.nbytes, slots=k))
            states.append(_Prepared(
                src=r.src, dst=r.dst, start_cycle=int(starts[i]),
                w_res=int(t_readys[i]) // n, n_win=n_win, slots_per_window=k,
                distance=int(dists[i]), hops=hops,
                idx=SlotTable._hops_idx(hops),
                flat={(hn * N_PORTS + hp) * n + hs for hn, hp, hs in hops}
                if k == 1 else None))
        return states

    # -- commit (host-side, arrival order) ------------------------------------
    def _commit_prepared(self, st: _Prepared, window: int,
                         validate: bool = True):
        """Reserve one prepared circuit against the live table.  Returns
        the Circuit, None (mesh saturated), or _CONFLICT when a commit
        made after the state's search claimed one of its resources.

        ``validate=False`` skips the live-table freshness check — sound
        when the state is *clean* (no resource claimed since its search
        intersects its shortest-path box, so its chosen hops are
        untouched) or freshly re-searched.  Validation runs against the
        snapshot ``window`` (conservative: it is never later than the
        request's own window), but the reservation anchors at the
        request's ready window (``w_res``) so a cycle-anchored request
        holds its slots for its actual streaming interval — exactly what
        serial ``allocate`` at that cycle would reserve."""
        if st.denied:
            return None
        if st.conflict or st.dup:
            return _CONFLICT
        table = self.table
        if validate:
            if (table.expiry[st.idx] > window).any():
                return _CONFLICT
            if st.bus_slots:
                for col, bslot in st.bus_slots:
                    if table.bus_expiry[col, bslot] > window:
                        return _CONFLICT
        else:
            # Backstop for the analytical clean-commit invariant: a chosen
            # hop outside a request's shortest-path box (impossible today)
            # must fail loudly, not silently double-book.
            assert (table.expiry[st.idx] <= window).all(), "double booking"
        # A reduce bundle repeats the (dst, LOCAL) prefix across its
        # arrival + dwell slots — unique=True's buffered fancy |= would
        # drop bits there, so fan-ins take the duplicate-safe path.
        table._ports.reserve_arrays(st.idx, st.w_res + st.n_win,
                                    unique=(st.slots_per_window == 1
                                            and not st.reduce))
        if st.bus_slots:
            for col, bslot in st.bus_slots:
                table.reserve_bus(col, bslot, st.w_res, st.n_win)
        return Circuit(src=st.src, dst=st.dst, start_cycle=st.start_cycle,
                       n_windows=st.n_win, hops=st.hops,
                       slots_per_window=st.slots_per_window,
                       uses_bus=st.uses_bus, bus_column=st.bus_column,
                       distance=st.distance, _n_slots_hint=self.n_slots,
                       srcs=st.srcs)


class TdmAllocatorLight(TdmAllocator):
    """NoM-Light: no dedicated Z links; vertical movement rides the existing
    per-vault TSV bus — single-cycle multi-hop, but one transfer per column
    per slot (Section 2.3).

    Routes are XY-monotone on one layer plus at most one bus hop.  We search
    both phase orders (XY-then-bus, bus-then-XY) — both ride the same
    vectorized pass as the rest of the batch — and keep the earlier.  The
    post-search pipeline is shared with the full-mesh allocator: same-layer
    requests go through :meth:`_prepare_full` unchanged, and cross-layer
    requests batch every candidate arrival slot of both phase orders
    through the same :func:`traceback_batch` call."""

    # Cross-layer routes carry bus hops the struct-of-arrays wave commit
    # does not model — every NoM-Light wave takes the generic loop.
    _wave_fast = False

    def _reprepare_conflict(self, req, t_ready, window):
        # Cross-layer routes need the bus-aware two-phase prepare; the
        # full-mesh scalar fast path does not apply here.  (The shared
        # _prepare_states split still routes fan-ins to _prepare_reduce.)
        return self._prepare_states([req], t_ready, window)[0]

    def _prepare_reduce(self, r, t_ready, occ, window):
        # Fan-in routes are XY-monotone single-layer circuits; a
        # cross-layer operand would need a bus hop the reduce search does
        # not model — reject loudly rather than route over absent Z links.
        coords = self.mesh.coord_array
        if any(int(coords[int(s)][2]) != int(coords[r.dst][2])
               for s in r.srcs):
            raise ValueError(
                "NoM-Light reduce requires same-layer sources (vertical "
                "operands must ride the TSV bus as explicit copies first)")
        return super()._prepare_reduce(r, t_ready, occ, window)

    def _prepare_copy_states(self, reqs, t_readys, window):
        if not reqs:
            return []
        mesh, n = self.mesh, self.n_slots
        occ = self.table._ports.masks_at(window)
        bus = self.table._bus.masks_at(window)
        coords = mesh.coord_array
        # One search entry per same-layer request; two (order A: src->w on
        # the source layer; order B: w2->dst on the dest layer, injected
        # through the source column's bus availability) per cross-layer one.
        e_src, e_dst, e_init = [], [], []
        meta = []                 # per request: row (same-layer) | (rowA, rowB)
        for r in reqs:
            sx, sy, sz = coords[r.src]
            dx, dy, dz = coords[r.dst]
            if sz == dz:
                meta.append(int(len(e_src)))
                e_src.append(r.src)
                e_dst.append(r.dst)
                e_init.append(0)
            else:
                w = mesh.node_id(int(dx), int(dy), int(sz))   # A: XY first
                w2 = mesh.node_id(int(sx), int(sy), int(dz))  # B: bus first
                init = rotr_np(np.uint32(int(bus[mesh.column_of(r.src)])), n)
                meta.append((len(e_src), w, w2))
                e_src += [r.src, w2]
                e_dst += [w, r.dst]
                e_init += [0, int(init)]
        vecs = self._run_search(occ, window, np.asarray(e_src, np.int64),
                                np.asarray(e_dst, np.int64),
                                np.asarray(e_init, np.uint32))
        # Same-layer subset: the full-mesh pipeline on its own vec rows.
        same_ix = [i for i, m in enumerate(meta) if isinstance(m, int)]
        same_states = iter(self._prepare_full(
            [reqs[i] for i in same_ix], t_readys[same_ix], vecs,
            np.asarray([meta[i] for i in same_ix], np.int64), occ, window,
            ) if same_ix else [])

        # Cross-layer subset, vectorized over requests.
        cross_ix = [i for i, m in enumerate(meta) if not isinstance(m, int)]
        cross = self._prepare_cross(reqs, t_readys, meta, cross_ix, vecs,
                                    occ, bus, window)
        return [next(same_states) if isinstance(m, int) else cross[i]
                for i, m in enumerate(meta)]

    def _prepare_cross(self, reqs, t_readys, meta, cross_ix, vecs, occ, bus,
                       window) -> dict[int, _Prepared]:
        mesh, n = self.mesh, self.n_slots
        out: dict[int, _Prepared] = {}
        if not cross_ix:
            return out
        coords = mesh.coord_array
        B = len(cross_ix)
        srcs = np.fromiter((reqs[i].src for i in cross_ix), np.int64, B)
        dsts = np.fromiter((reqs[i].dst for i in cross_ix), np.int64, B)
        rowsA = np.fromiter((meta[i][0] for i in cross_ix), np.int64, B)
        w_nodes = np.fromiter((meta[i][1] for i in cross_ix), np.int64, B)
        w2_nodes = np.fromiter((meta[i][2] for i in cross_ix), np.int64, B)
        dist_xy = (np.abs(coords[srcs][:, :2] - coords[dsts][:, :2])).sum(1)
        total = dist_xy + 1       # bus = one slot regardless of layer count
        colw = np.fromiter((mesh.column_of(int(w)) for w in w_nodes),
                           np.int64, B)
        cols = np.fromiter((mesh.column_of(int(s)) for s in srcs),
                           np.int64, B)
        t_sub = t_readys[cross_ix]
        availA = (rotr_np(vecs[rowsA, w_nodes] | bus[colw], n)
                  | occ[dsts, PORT_LOCAL])
        availB = vecs[rowsA + 1, dsts] | occ[dsts, PORT_LOCAL]
        cA, aA, freeA, denA = _best_slots_np(availA, total, t_sub, n)
        cB, aB, freeB, denB = _best_slots_np(availB, total, t_sub, n)
        useB = cB < cA            # strict: order A wins ties, as the serial scan
        a0 = np.where(useB, aB, aA)
        starts = np.where(useB, cB, cA)
        denied = denA & denB
        free = np.where(useB[:, None], freeB, freeA)
        # Candidate arrival slots per request: the chosen slot first, then
        # every other free slot ascending (the serial bundle order);
        # trace-back jobs only exist for XY distance > 0.
        jobs_src, jobs_dst, jobs_slot, jobs_row = [], [], [], []
        cand_jobs: list[list] = []   # per request: [(slot, job_id | None)]
        for k in range(B):
            cands = []
            if not denied[k]:
                # Every free slot stays a candidate (chosen slot first, the
                # rest ascending): a trace-back can fail on any of them, and
                # the bundle takes the first 1+max_extra that succeed.
                order = [int(a0[k])] + [s for s in range(n)
                                        if s != a0[k] and free[k, s]]
                for a in order:
                    jid = None
                    if dist_xy[k]:
                        jid = len(jobs_src)
                        if useB[k]:
                            jobs_src.append(int(w2_nodes[k]))
                            jobs_dst.append(int(dsts[k]))
                            jobs_slot.append(a)
                            jobs_row.append(int(rowsA[k] + 1))
                        else:
                            jobs_src.append(int(srcs[k]))
                            jobs_dst.append(int(w_nodes[k]))
                            jobs_slot.append((a - 1) % n)
                            jobs_row.append(int(rowsA[k]))
                    cands.append((a, jid))
            cand_jobs.append(cands)
        jobs_hops, ok = _traceback_jobs(
            vecs, np.asarray(jobs_row, np.int64), occ, mesh, n,
            np.asarray(jobs_src, np.int64), np.asarray(jobs_dst, np.int64),
            np.asarray(jobs_slot, np.int64))
        for k, i in enumerate(cross_ix):
            r = reqs[i]
            if denied[k]:
                out[i] = _Prepared(denied=True, src=r.src, dst=r.dst)
                continue
            picked = []           # [(hops, (bus_col, bus_slot))]
            for a, jid in cand_jobs[k]:
                if len(picked) >= 1 + r.max_extra_slots:
                    break
                if jid is not None and not ok[jid]:
                    continue
                if useB[k]:
                    hops = (jobs_hops[jid] if jid is not None
                            else [(int(dsts[k]), PORT_LOCAL, a)])
                    buspair = (int(cols[k]), (a - int(total[k])) % n)
                else:
                    hops_xy = (jobs_hops[jid][:-1] if jid is not None else [])
                    hops = hops_xy + [(int(dsts[k]), PORT_LOCAL, a)]
                    buspair = (int(colw[k]), (a - 1) % n)
                picked.append((hops, buspair))
            if not picked:
                out[i] = _Prepared(conflict=True, src=r.src, dst=r.dst)
                continue
            hops = [h for hs, _b in picked for h in hs]
            bus_slots = [b for _h, b in picked]
            idx = SlotTable._hops_idx(hops)
            keys = (idx[0] * N_PORTS + idx[1]) * n + idx[2]
            dup = (np.unique(keys).size < keys.size
                   or len({b for b in bus_slots}) < len(bus_slots))
            out[i] = _Prepared(
                src=r.src, dst=r.dst, start_cycle=int(starts[k]),
                w_res=int(t_sub[k]) // n,
                n_win=self.n_windows_for(r.nbytes, slots=len(picked)),
                slots_per_window=len(picked), distance=int(total[k]),
                hops=hops, idx=idx, dup=dup, uses_bus=True,
                bus_column=picked[0][1][0], bus_slots=bus_slots)
        return out


# ---------------------------------------------------------------------------
# Cross-stack circuits (two-phase segmented allocation)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StackedCircuit:
    """A committed cross-stack circuit through a :class:`StackedTopology`.

    Three reserved segments stream in lock step: the *near* segment
    (``src`` to the near stack's bridge bank, ejecting into the SerDes TX
    buffer through the bridge's LOCAL port), one TDM slot on every
    directed SerDes channel along the stack route, and the *far* segment
    (far bridge to ``dst``).  Intermediate stacks forward bridge-to-bridge
    on the logic die — their meshes are never traversed.  All segments
    hold their slots for the same ``n_windows`` (the stream runs at the
    bottleneck link's byte rate end to end).
    """

    src: tuple[int, int]      # (stack, local node)
    dst: tuple[int, int]
    start_cycle: int          # absolute cycle of source injection
    n_windows: int
    near_hops: list[tuple[int, int, int]]   # (node, port, slot), near stack
    far_hops: list[tuple[int, int, int]]    # (node, port, slot), far stack
    link_slots: list[tuple[int, int]]       # (channel, slot) per SerDes hop
    distance: int             # beat latency src -> dst, SerDes legs included
    slots_per_window: int = 1
    uses_bus: bool = False
    bus_column: int = -1
    _n_slots_hint: int = 16

    @property
    def cross_stack(self) -> bool:
        return True

    @property
    def hops(self) -> list[tuple[int, int, int]]:
        """Mesh hops of both segments (near then far) — SerDes hops are in
        ``link_slots``; node ids are stack-local."""
        return list(self.near_hops) + list(self.far_hops)

    @property
    def arrival_cycle(self) -> int:
        return self.start_cycle + self.distance

    @property
    def end_cycle(self) -> int:
        return self.arrival_cycle + (self.n_windows - 1) * self._n_slots_hint


class SegmentedAllocator:
    """Two-phase cross-stack circuit allocation over per-stack allocators.

    Phase 1 (the near stack's authority): wavefront-search ``src`` to the
    near bridge, walk candidate bridge-arrival slots in earliest-start
    order, and for the first whose SerDes channel chain is free reserve
    the near hops *and* the channel slots.  Phase 2 (the far authority):
    search far bridge -> ``dst`` with the injection slot pinned to the one
    the link chain delivers; a conflict on the far side *rolls back* the
    near-side reservation (restoring the exact prior expiries) and the
    next candidate slot is tried.  Either the whole segmented circuit
    commits or no slot-table state changes at all.

    Slot arithmetic: a beat arriving at the near bridge on slot ``a``
    enters the first channel on ``(a + 1) % n``; each SerDes hop advances
    the slot by ``1 + latency``; the far injection slot is therefore
    ``(a + T) % n`` with ``T = sum(1 + latency_k)``.
    """

    def __init__(self, topology: StackedTopology, allocators: list,
                 n_slots: int = 16):
        if len(allocators) != topology.n_stacks:
            raise ValueError(f"{len(allocators)} allocators for "
                             f"{topology.n_stacks} stacks")
        self.topology = topology
        self.allocators = list(allocators)
        self.n_slots = n_slots
        # One TDM slot resource per directed SerDes channel, same expiry
        # discipline as router ports.
        self.links = _PackedExpiry((max(1, topology.n_channels),), n_slots)
        self.rollbacks = 0        # phase-2 aborts (near side rolled back)
        self.denied = 0           # requests with no committable candidate
        self.link_windows = 0     # SerDes (channel, slot)-windows reserved

    def bottleneck_bytes(self, src_stack: int, dst_stack: int) -> int:
        """Bytes one circuit moves per TDM window src -> dst: the minimum
        of the two mesh link widths and every SerDes link on the route."""
        widths = [self.allocators[src_stack].link_bytes,
                  self.allocators[dst_stack].link_bytes]
        widths += [self.topology.links[c // 2].link_bytes
                   for c in self.topology.route_channels(src_stack, dst_stack)]
        return min(widths)

    def allocate(self, src: tuple[int, int], dst: tuple[int, int],
                 nbytes: int, cycle: int) -> StackedCircuit | None:
        """Reserve the earliest cross-stack circuit, or None (no leaked
        state) when every candidate slot fails phase 2."""
        topo, n = self.topology, self.n_slots
        (sa, s_loc), (sb, d_loc) = src, dst
        if sa == sb:
            raise ValueError("SegmentedAllocator is for cross-stack traffic; "
                             "same-stack requests go to the stack's own CCU")
        near, far = self.allocators[sa], self.allocators[sb]
        mesh_a, mesh_b = topo.stacks[sa], topo.stacks[sb]
        bridge_a, bridge_b = topo.bridge_of(sa), topo.bridge_of(sb)
        chans = topo.route_channels(sa, sb)
        lats = [topo.links[c // 2].latency for c in chans]
        t_ready = cycle + 3                      # the CCU's 3-cycle setup
        window = t_ready // n
        n_win = max(1, -(-nbytes // self.bottleneck_bytes(sa, sb)))
        fm = full_mask(n)
        # Snapshot (copy) the masks: reserve_arrays mutates the live cache
        # in place, and a phase-2 rollback must leave the candidate loop
        # reading the pre-reservation availability.
        occ_a = near.table._ports.masks_at(window).copy()
        dist_a = mesh_a.manhattan(s_loc, bridge_a)
        if s_loc == bridge_a:
            vec_a = None
            avail_a = int(occ_a[bridge_a, PORT_LOCAL])
        else:
            vec_a = _wavefront_host(occ_a, mesh_a, n, s_loc, bridge_a, 0)
            avail_a = int(vec_a[bridge_a]) | int(occ_a[bridge_a, PORT_LOCAL])
        link_masks = self.links.masks_at(window).copy()
        dist_b = mesh_b.manhattan(bridge_b, d_loc)
        T = sum(1 + lat for lat in lats)
        # Bridge-arrival candidates in earliest-injection order (same
        # (start, slot) order the single-stack slot choice uses).
        def _start(a: int) -> int:
            s_inj = (a - dist_a) % n
            return t_ready + ((s_inj - t_ready) % n)
        cands = sorted((a for a in range(n) if bit_is_free(avail_a, a)),
                       key=lambda a: (_start(a), a))
        committed = False
        for a in cands:
            chain, s, free = [], (a + 1) % n, True
            for c, lat in zip(chans, lats):
                if not bit_is_free(int(link_masks[c]), s):
                    free = False
                    break
                chain.append((c, s))
                s = (s + 1 + lat) % n
            if not free:
                continue
            s_far = (a + T) % n
            # -- phase 1: the near authority reserves hops + channel slots.
            near_hops = ([(bridge_a, PORT_LOCAL, a)] if s_loc == bridge_a
                         else traceback(vec_a, occ_a, mesh_a, n, s_loc,
                                        bridge_a, a))
            idx_a = SlotTable._hops_idx(near_hops)
            prev_a = near.table._ports.expiry[idx_a].copy()
            near.table._ports.reserve_arrays(idx_a, window + n_win)
            idx_l = (np.fromiter((c for c, _ in chain), np.int64, len(chain)),
                     np.fromiter((sl for _, sl in chain), np.int64,
                                 len(chain)))
            prev_l = self.links.expiry[idx_l].copy()
            self.links.reserve_arrays(idx_l, window + n_win)
            # -- phase 2: the far authority tries to commit.  Injection is
            # pinned: only s_far is free in the init vector, so any circuit
            # the search finds leaves the far bridge exactly when the link
            # chain delivers the beat.
            occ_b = far.table._ports.masks_at(window)
            far_hops = None
            if d_loc == bridge_b:
                if bit_is_free(int(occ_b[bridge_b, PORT_LOCAL]), s_far):
                    far_hops = [(bridge_b, PORT_LOCAL, s_far)]
            else:
                init = fm ^ (1 << s_far)
                vec_b = _wavefront_host(occ_b, mesh_b, n, bridge_b, d_loc,
                                        init)
                a_far = (s_far + dist_b) % n
                if bit_is_free(int(vec_b[d_loc]) | int(occ_b[d_loc,
                                                             PORT_LOCAL]),
                               a_far):
                    far_hops = traceback(vec_b, occ_b, mesh_b, n, bridge_b,
                                         d_loc, a_far)
            if far_hops is None:
                near.table._ports.release_arrays(idx_a, prev_a)
                self.links.release_arrays(idx_l, prev_l)
                self.rollbacks += 1
                continue
            idx_b = SlotTable._hops_idx(far_hops)
            far.table._ports.reserve_arrays(idx_b, window + n_win)
            self.link_windows += n_win * len(chain)
            committed = True
            return StackedCircuit(
                src=src, dst=dst, start_cycle=_start(a), n_windows=n_win,
                near_hops=near_hops, far_hops=far_hops, link_slots=chain,
                distance=dist_a + T + dist_b, _n_slots_hint=n)
        if not committed:
            self.denied += 1
        return None
