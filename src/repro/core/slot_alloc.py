"""TDM slot allocation — the paper's core algorithm (Section 2.1).

The CCU services copy requests by finding a *circuit*: a sequence of
increasingly-numbered TDM slots along a shortest path, so data advances one
hop per cycle with no buffering/arbitration.  The paper implements the
search with a matrix of PEs (one per router) that propagate an n-bit busy
vector along all shortest paths: at each PE the vector is OR-ed with the
output-port occupancy and rotated right (slot j upstream -> slot j+1 here);
zero bits surviving at the destination are feasible circuits.

Implementation layout (mirrors the hardware split):

* :func:`wavefront_search` — the PE-matrix accelerator, vectorized JAX
  (``vmap``-able over a batch of requests; the Pallas TPU kernel in
  ``repro.kernels.slot_alloc`` implements the same contract).
* :class:`SlotTable` — the CCU's occupancy bookkeeping (host-side numpy):
  per (router, port, slot) reservation expiry in TDM-window units.
* :func:`traceback` — walks the converged vectors backwards to extract the
  hop list, as the paper's "tracing back the path towards the source PE".

Slot/cycle accounting (paper Fig. 2): a circuit of distance D injected at
source slot ``s`` uses slot ``s+i (mod n)`` at the i-th router on the path
and ejects through the destination's LOCAL port at slot ``s+D (mod n)`` —
e.g. 5 routers / slots 3..7 for the A->B example.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .bitvec import UINT, bit_is_free, full_mask, rotr, rotr_np
from .topology import Mesh3D, N_PORTS, PORT_LOCAL, port_for

_STRIDES = ("X", "XY")  # doc only


# ---------------------------------------------------------------------------
# The PE-matrix search (pure JAX; jit + vmap friendly)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("mesh", "n_slots"))
def wavefront_search(occ: jax.Array, src: jax.Array, dst: jax.Array,
                     init_vec: jax.Array, *, mesh: Mesh3D,
                     n_slots: int) -> jax.Array:
    """Propagate busy-vectors from ``src`` to every node of the shortest-path
    lattice toward ``dst``.

    Args:
      occ: (n_nodes, N_PORTS) uint32 — busy mask per output port.
      src, dst: scalar int32 node ids (traced; may come from a vmapped batch).
      init_vec: uint32 scalar — initial busy vector at the source (0 for a
        fresh search; non-zero when composing multi-phase NoM-Light routes).

    Returns:
      (n_nodes,) uint32: converged busy vector per node, indexed by the slot
      at which that node's *output* crossbar would be used.  Out-of-lattice
      nodes hold the all-busy mask.  ``vec[dst] | occ[dst, LOCAL]`` is the
      availability vector of arrival slots.
    """
    n = mesh.n_nodes
    fm = jnp.asarray(full_mask(n_slots), UINT)
    coords = jnp.asarray(mesh.coord_array)          # (n, 3)
    src_c = coords[src]                             # (3,)
    dst_c = coords[dst]
    sign = jnp.sign(dst_c - src_c)                  # (3,) in {-1,0,1}
    lo = jnp.minimum(src_c, dst_c)
    hi = jnp.maximum(src_c, dst_c)
    in_box = jnp.all((coords >= lo) & (coords <= hi), axis=1)  # (n,)

    strides = jnp.asarray([1, mesh.X, mesh.X * mesh.Y], jnp.int32)
    node_ids = jnp.arange(n, dtype=jnp.int32)

    # Per-dimension upstream node id and validity.
    # upstream_d(v) = v - sign_d * stride_d ; valid iff we have moved >=1 step
    # in dimension d away from the source and d is a travel dimension.
    ups = node_ids[None, :] - sign[:, None] * strides[:, None]      # (3, n)
    moved = coords.T != src_c[:, None]                              # (3, n)
    valid = in_box[None, :] & moved & (sign[:, None] != 0)          # (3, n)
    ups = jnp.clip(ups, 0, n - 1)

    # Output port used at the upstream node for a hop along dim d, dir sign_d.
    ports = jnp.where(sign < 0, 2 * jnp.arange(3) + 1, 2 * jnp.arange(3))

    vec0 = jnp.full((n,), fm, UINT).at[src].set(jnp.asarray(init_vec, UINT))
    is_src = node_ids == src

    def body(_, vec):
        def cand(d):
            up = ups[d]
            v = vec[up] | occ[up, ports[d]]
            v = rotr(v, n_slots)
            return jnp.where(valid[d], v, fm)
        new = cand(0) & cand(1) & cand(2)
        # Source keeps its injected vector; out-of-lattice nodes stay busy.
        return jnp.where(in_box & ~is_src, new, vec0)

    # The lattice is a DAG of depth <= max_dist, so max_dist sweeps converge.
    vec = jax.lax.fori_loop(0, mesh.max_dist, body, vec0)
    return vec


def wavefront_search_batch(occ, srcs, dsts, init_vecs, *, mesh, n_slots):
    """vmap over a batch of (src, dst) requests sharing one occupancy state.

    This is the paper's "explore all possible paths ... in parallel" taken one
    step further: concurrent request *searches* also run in parallel (the CCU
    still reserves sequentially, in FIFO order).
    """
    fn = partial(wavefront_search, mesh=mesh, n_slots=n_slots)
    return jax.vmap(lambda s, d, iv: fn(occ, s, d, iv))(srcs, dsts, init_vecs)


@partial(jax.jit, static_argnames=("mesh", "n_slots"))
def _search_batch_jit(occ, srcs, dsts, init_vecs, *, mesh, n_slots):
    """Module-level jit of the batched search so the compile cache is shared
    across allocator instances (static over mesh geometry + window size)."""
    return wavefront_search_batch(occ, srcs, dsts, init_vecs, mesh=mesh,
                                  n_slots=n_slots)


# ---------------------------------------------------------------------------
# Host-side CCU bookkeeping
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Circuit:
    """A reserved circuit: ``hops[i] = (node, out_port, slot)`` in forward
    order; the last hop is (dst, PORT_LOCAL, arrival_slot)."""
    src: int
    dst: int
    start_cycle: int          # absolute cycle of source injection
    n_windows: int            # TDM windows the reservation persists
    hops: list[tuple[int, int, int]]
    slots_per_window: int = 1
    uses_bus: bool = False    # NoM-Light vertical bus hop present
    bus_column: int = -1      # (x, y) column whose TSV the bus hop rides
    distance: int = 0         # hops traversed by one beat (src -> dst)

    @property
    def arrival_cycle(self) -> int:
        return self.start_cycle + self.distance

    @property
    def end_cycle(self) -> int:
        """Cycle at which the last beat has arrived at the destination."""
        return self.arrival_cycle + (self.n_windows - 1) * self._n_slots_hint

    _n_slots_hint: int = 16


class SlotTable:
    """Occupancy state of every router port (and NoM-Light vertical buses).

    ``expiry[node, port, slot]`` is the TDM-window index until which the slot
    is reserved (exclusive).  A slot is busy for a search anchored at window
    ``w`` iff ``expiry > w`` — conservative for circuits that would start
    after an existing reservation expires, which matches the paper's CCU (it
    services requests in FIFO order against current state).
    """

    def __init__(self, mesh: Mesh3D, n_slots: int = 16):
        self.mesh = mesh
        self.n_slots = n_slots
        self.expiry = np.zeros((mesh.n_nodes, N_PORTS, n_slots), np.int64)
        # One vertical bus resource per (x, y) column (NoM-Light).
        self.bus_expiry = np.zeros((mesh.X * mesh.Y, n_slots), np.int64)

    # -- masks ---------------------------------------------------------------
    def busy_masks(self, window: int) -> np.ndarray:
        """(n_nodes, N_PORTS) uint32 busy masks as of TDM window `window`."""
        busy = self.expiry > window
        weights = (np.uint32(1) << np.arange(self.n_slots, dtype=np.uint32))
        return (busy * weights).sum(axis=2).astype(np.uint32)

    def bus_busy_masks(self, window: int) -> np.ndarray:
        busy = self.bus_expiry > window
        weights = (np.uint32(1) << np.arange(self.n_slots, dtype=np.uint32))
        return (busy * weights).sum(axis=1).astype(np.uint32)

    # -- validation -----------------------------------------------------------
    def can_reserve(self, hops: list[tuple[int, int, int]],
                    window: int) -> bool:
        """True iff every (node, port, slot) in ``hops`` is free as of
        ``window`` and the hop list itself is internally disjoint — the
        batched scheduler's commit check against circuits reserved after
        the search snapshot was taken."""
        seen: set[tuple[int, int, int]] = set()
        for hop in hops:
            node, port, slot = hop
            if hop in seen or self.expiry[node, port, slot] > window:
                return False
            seen.add(hop)
        return True

    def can_reserve_bus(self, column: int, slot: int, window: int) -> bool:
        return bool(self.bus_expiry[column, slot] <= window)

    # -- reservation ----------------------------------------------------------
    def reserve(self, circuit: Circuit, window: int) -> None:
        until = window + circuit.n_windows
        for node, port, slot in circuit.hops:
            assert self.expiry[node, port, slot] <= window, "double booking"
            self.expiry[node, port, slot] = until

    def reserve_bus(self, column: int, slot: int, window: int,
                    n_windows: int) -> None:
        assert self.bus_expiry[column, slot] <= window, "bus double booking"
        self.bus_expiry[column, slot] = window + n_windows

    def utilization(self, window: int) -> float:
        return float((self.expiry > window).mean())


# ---------------------------------------------------------------------------
# Trace-back (paper: "reserved by tracing back the path towards the source")
# ---------------------------------------------------------------------------
def traceback(vec: np.ndarray, occ: np.ndarray, mesh: Mesh3D, n_slots: int,
              src: int, dst: int, arrival_slot: int) -> list[tuple[int, int, int]]:
    """Extract one feasible hop list ending at ``dst`` on ``arrival_slot``.

    ``vec`` is the converged busy-vector array from :func:`wavefront_search`
    (numpy), ``occ`` the (n_nodes, N_PORTS) busy masks used for the search.
    """
    coords = mesh.coord_array
    sx, sy, sz = coords[src]
    hops: list[tuple[int, int, int]] = [(dst, PORT_LOCAL, arrival_slot)]
    v, j = int(dst), int(arrival_slot)
    strides = (1, mesh.X, mesh.X * mesh.Y)
    sign = np.sign(coords[dst] - coords[src])
    guard = 0
    while v != src:
        guard += 1
        if guard > mesh.max_dist + 2:
            raise RuntimeError("traceback failed to reach source")
        jp = (j - 1) % n_slots
        placed = False
        for d in range(3):
            if sign[d] == 0 or coords[v][d] == coords[src][d]:
                continue
            u = v - int(sign[d]) * strides[d]
            p = port_for(d, int(sign[d]))
            if bit_is_free(int(vec[u]) | int(occ[u, p]), jp):
                hops.append((u, p, jp))
                v, j = u, jp
                placed = True
                break
        if not placed:
            raise RuntimeError(
                f"no free upstream at node {v} slot {j} (inconsistent search)")
    hops.reverse()
    return hops


# ---------------------------------------------------------------------------
# Full allocation: batched search + slot choice + trace-back + reserve
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class AllocResult:
    circuit: Circuit | None
    searched_cycle: int


@dataclasses.dataclass(frozen=True)
class CopyRequest:
    """One pending inter-bank copy for the batched CCU pipeline.

    ``cycle`` optionally anchors this request later than the batch cycle
    (e.g. its source read completes later); the occupancy snapshot is still
    taken at the batch cycle, which is conservative.

    ``op`` selects the operation class: ``"copy"`` (default) streams
    ``nbytes`` over a circuit from ``src`` to ``dst``; ``"init"`` is
    bulk initialization *in place* (``src == dst``) — the CCU sets up a
    zero-hop circuit that occupies only the bank's LOCAL port while the
    bank clears rows internally (RowClone-FPM style), so INIT traffic
    shares the CCU's admission/telemetry pipeline without consuming mesh
    links."""
    src: int
    dst: int
    nbytes: int
    max_extra_slots: int = 0
    cycle: int | None = None
    op: str = "copy"


@dataclasses.dataclass
class BatchReport:
    """Telemetry of the last ``allocate_batch`` call."""
    n_requests: int = 0
    n_committed: int = 0
    n_denied: int = 0          # no feasible circuit even after re-search
    search_rounds: int = 0     # vectorized wavefront passes issued
    conflicts: int = 0         # stale-snapshot commits that forced a re-search


_CONFLICT = object()   # sentinel: stale search, re-run against fresh state


@dataclasses.dataclass
class _Search:
    """Converged search state for one request (full-mesh NoM)."""
    occ: np.ndarray
    vec: np.ndarray


def _pow2_pad(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class TdmAllocator:
    """The CCU's allocation pipeline for the *full 3D mesh* NoM.

    The paper's CCU sets up *many* link-disjoint circuits that stream
    concurrently; :meth:`allocate_batch` is the corresponding entry point:
    one vectorized :func:`wavefront_search_batch` pass over every pending
    request, then a host-side commit loop that reserves circuits in arrival
    order.  A commit can discover that an earlier circuit from the *same*
    batch claimed one of its hops (the search snapshot is per-round, not
    per-request); the loser and everything after it are retried against a
    fresh search — at later source slots, the paper's increasing-slot
    fallback — so the results are bit-identical to servicing the stream
    through :meth:`allocate` one request at a time.

    ``allocate`` (the serial spelling) implements the paper's 3-cycle
    setup: the request picked at cycle t searches at t (1 cycle), programs
    slot tables (1 cycle), issues the read (1 cycle), so the earliest
    injection is t+3.  It is a batch of one.
    """

    def __init__(self, mesh: Mesh3D, n_slots: int = 16,
                 link_bytes: int = 8, use_pallas: bool = False):
        self.mesh = mesh
        self.n_slots = n_slots
        self.link_bytes = link_bytes  # 64-bit links => 8 bytes/slot-cycle
        self.table = SlotTable(mesh, n_slots)
        self.last_report = BatchReport()
        if use_pallas:  # pragma: no cover - exercised in kernel tests
            from repro.kernels.slot_alloc import ops as _ops
            self._search_batch = partial(_ops.wavefront_search_pallas_batch,
                                         mesh=mesh, n_slots=n_slots)
        else:
            self._search_batch = partial(_search_batch_jit, mesh=mesh,
                                         n_slots=n_slots)

    # An in-place INIT clears one DRAM row per TDM window (RowClone-FPM in
    # the bank; no bytes cross the mesh), so its zero-hop circuit holds the
    # LOCAL port for ceil(nbytes / init_row_bytes) windows.
    init_row_bytes: int = 8192

    def n_windows_for(self, nbytes: int, slots: int = 1) -> int:
        per_window = self.link_bytes * slots
        return max(1, -(-nbytes // per_window))

    def n_windows_for_init(self, nbytes: int) -> int:
        return max(1, -(-nbytes // self.init_row_bytes))

    # -- public API -----------------------------------------------------------
    def allocate(self, src: int, dst: int, nbytes: int, cycle: int,
                 max_extra_slots: int = 0) -> AllocResult:
        """Find + reserve the earliest circuit for a copy of ``nbytes``.

        Returns AllocResult with circuit=None if the lattice is fully busy
        (caller retries next cycle, as the CCU would)."""
        return self.allocate_batch(
            [CopyRequest(src, dst, nbytes, max_extra_slots)], cycle)[0]

    def allocate_batch(self, requests: list, cycle: int) -> list[AllocResult]:
        """Service a batch of pending copy requests concurrently.

        This is the CCU's concurrent circuit establishment (paper Section
        2.2): every request of the batch is searched in one vectorized
        wavefront pass, then committed in arrival (FIFO) order against the
        live slot table, so each granted circuit is (router, port, slot)-
        disjoint from every other circuit live in its TDM windows.  A
        commit that finds its hops claimed by an earlier commit of the
        same batch triggers a fresh search for it and everything after it
        (the paper's increasing-slot fallback) — results are bit-identical
        to streaming the requests through :meth:`allocate` one at a time.

        Args:
          requests: list of :class:`CopyRequest` (or bare
            ``(src, dst, nbytes)`` tuples).  ``src``/``dst`` are int bank
            ids on the mesh; ``nbytes`` is the payload in bytes — with the
            paper's 64-bit links one TDM slot moves ``link_bytes`` (8) per
            window, so the circuit persists
            ``ceil(nbytes / (8 * slots))`` windows.
          cycle: absolute allocator cycle at which the batch is picked up;
            injection starts no earlier than ``cycle + 3`` (the 3-cycle
            search/program/read setup pipeline).  Requests carrying their
            own ``cycle`` anchor are validated against this batch cycle
            (conservative) but reserved at their own window.

        Returns:
          One :class:`AllocResult` per request, in request order.
          ``circuit is None`` means the lattice was saturated at every
          candidate slot.  ``self.last_report`` holds the
          :class:`BatchReport` (search passes, conflicts, denials).
        """
        reqs = [r if isinstance(r, CopyRequest) else CopyRequest(*r)
                for r in requests]
        report = BatchReport(n_requests=len(reqs))
        results: list[AllocResult | None] = [None] * len(reqs)
        window = (cycle + 3) // self.n_slots
        pending = list(range(len(reqs)))
        while pending:
            report.search_rounds += 1
            states = self._search_states([reqs[i] for i in pending], window)
            stalled: int | None = None
            for k, i in enumerate(pending):
                req = reqs[i]
                t_ready = max(req.cycle if req.cycle is not None else cycle,
                              cycle) + 3
                out = self._commit_one(req, states[k], window, t_ready)
                if out is _CONFLICT:
                    # The snapshot this round searched against went stale
                    # (an earlier commit claimed a hop).  The very first
                    # commit of a round can never conflict, so the loop
                    # always makes progress.
                    assert k > 0, "fresh search conflicted with itself"
                    report.conflicts += 1
                    stalled = k
                    break
                results[i] = AllocResult(out, cycle)
                report.n_committed += out is not None
                report.n_denied += out is None
            pending = pending[stalled:] if stalled is not None else []
        self.last_report = report
        return results

    # -- search (one vectorized pass per round) -------------------------------
    def _run_search(self, occ: np.ndarray,
                    entries: list[tuple[int, int, int]]) -> np.ndarray:
        """Run ``entries`` = [(src, dst, init_vec), ...] through one batched
        wavefront pass, padded to a power of two so jit retraces stay rare.
        Returns (len(entries), n_nodes) uint32 busy vectors (numpy)."""
        pad = _pow2_pad(len(entries))
        srcs = np.zeros(pad, np.int32)
        dsts = np.zeros(pad, np.int32)
        inits = np.zeros(pad, np.uint32)
        for j, (s, d, iv) in enumerate(entries):
            srcs[j], dsts[j], inits[j] = s, d, iv
        vecs = self._search_batch(jnp.asarray(occ), srcs, dsts, inits)
        return np.asarray(vecs)[:len(entries)]

    def _search_states(self, reqs: list[CopyRequest],
                       window: int) -> list[_Search]:
        occ = self.table.busy_masks(window)
        vecs = self._run_search(occ, [(r.src, r.dst, 0) for r in reqs])
        return [_Search(occ=occ, vec=vecs[j]) for j in range(len(reqs))]

    # -- commit (host-side, arrival order) ------------------------------------
    def _best_slot(self, avail: int, dist: int, t_ready: int):
        """Earliest (start_cycle, arrival_slot) over the free arrival slots
        of ``avail`` for a circuit of ``dist`` hops."""
        best = None
        for a in range(self.n_slots):
            if not bit_is_free(avail, a):
                continue
            s = (a - dist) % self.n_slots
            # earliest injection cycle >= t_ready with cycle % n == s
            c = t_ready + ((s - t_ready) % self.n_slots)
            if best is None or c < best[0]:
                best = (c, a)
        return best

    def _commit_one(self, req: CopyRequest, st: _Search, window: int,
                    t_ready: int):
        """Reserve the earliest circuit for ``req`` from its search state.
        Returns the Circuit, None (mesh saturated), or _CONFLICT when the
        state predates a commit that claimed one of the chosen hops.

        Validation runs against the snapshot ``window`` (conservative: it
        is never later than the request's own window), but the reservation
        anchors at the request's ``t_ready`` window so a cycle-anchored
        request holds its slots for its actual streaming interval — exactly
        what serial ``allocate`` at that cycle would reserve."""
        occ, vec = st.occ, st.vec
        w_res = t_ready // self.n_slots
        avail = int(vec[req.dst]) | int(occ[req.dst, PORT_LOCAL])
        dist = self.mesh.manhattan(req.src, req.dst)
        best = self._best_slot(avail, dist, t_ready)
        if best is None:
            return None
        start_cycle, a = best
        hops = traceback(vec, occ, self.mesh, self.n_slots, req.src, req.dst,
                         a)
        # Optionally accelerate with extra free slots (paper Section 2.1).
        # INIT never streams over links, so extra slots cannot help it.
        extra = 0
        if req.max_extra_slots and req.op != "init":
            for a2 in range(self.n_slots):
                if extra >= req.max_extra_slots:
                    break
                if a2 != a and bit_is_free(avail, a2):
                    try:
                        hops2 = traceback(vec, occ, self.mesh, self.n_slots,
                                          req.src, req.dst, a2)
                    except RuntimeError:
                        continue
                    hops = hops + hops2
                    extra += 1
        if not self.table.can_reserve(hops, window):
            return _CONFLICT
        n_win = (self.n_windows_for_init(req.nbytes) if req.op == "init"
                 else self.n_windows_for(req.nbytes, slots=1 + extra))
        circ = Circuit(src=req.src, dst=req.dst, start_cycle=start_cycle,
                       n_windows=n_win, hops=hops, slots_per_window=1 + extra,
                       distance=dist, _n_slots_hint=self.n_slots)
        self.table.reserve(circ, w_res)
        return circ


@dataclasses.dataclass
class _SearchLight(_Search):
    """Cross-layer NoM-Light search state: two phase orders, shared bus."""
    bus: np.ndarray = None
    w: int = -1                # order A: XY target on the source layer
    w2: int = -1               # order B: bus landing on the dest layer
    vec_b: np.ndarray = None   # order B converged vectors (vec is order A)


class TdmAllocatorLight(TdmAllocator):
    """NoM-Light: no dedicated Z links; vertical movement rides the existing
    per-vault TSV bus — single-cycle multi-hop, but one transfer per column
    per slot (Section 2.3).

    Routes are XY-monotone on one layer plus at most one bus hop.  We search
    both phase orders (XY-then-bus, bus-then-XY) — both ride the same
    vectorized pass as the rest of the batch — and keep the earlier."""

    def _search_states(self, reqs, window):
        mesh, n = self.mesh, self.n_slots
        occ = self.table.busy_masks(window)
        bus = self.table.bus_busy_masks(window)
        entries: list[tuple[int, int, int]] = []
        metas = []
        for r in reqs:
            sx, sy, sz = mesh.coords(r.src)
            dx, dy, dz = mesh.coords(r.dst)
            if sz == dz:
                metas.append((len(entries), None, None))
                entries.append((r.src, r.dst, 0))
            else:
                w = mesh.node_id(dx, dy, sz)     # order A: XY first
                w2 = mesh.node_id(sx, sy, dz)    # order B: bus first
                init = rotr_np(np.uint32(int(bus[mesh.column_of(r.src)])), n)
                metas.append((len(entries), w, w2))
                entries.append((r.src, w, 0))
                entries.append((w2, r.dst, int(init)))
        vecs = self._run_search(occ, entries)
        states = []
        for j, w, w2 in metas:
            if w is None:
                states.append(_Search(occ=occ, vec=vecs[j]))
            else:
                states.append(_SearchLight(occ=occ, vec=vecs[j], bus=bus,
                                           w=w, w2=w2, vec_b=vecs[j + 1]))
        return states

    def _commit_one(self, req, st, window, t_ready):
        if not isinstance(st, _SearchLight):   # same-layer: full-mesh rules
            return super()._commit_one(req, st, window, t_ready)
        mesh, n = self.mesh, self.n_slots
        w_res = t_ready // n
        occ, bus = st.occ, st.bus
        vecA, vecB, w, w2 = st.vec, st.vec_b, st.w, st.w2
        sx, sy, _sz = mesh.coords(req.src)
        dx, dy, _dz = mesh.coords(req.dst)
        dist_xy = abs(sx - dx) + abs(sy - dy)

        availA = rotr_np(np.uint32(int(vecA[w]) | int(bus[mesh.column_of(w)])),
                         n)
        availA = int(availA) | int(occ[req.dst, PORT_LOCAL])
        availB = int(vecB[req.dst]) | int(occ[req.dst, PORT_LOCAL])

        total_hops = dist_xy + 1  # bus counts as one slot regardless of layers
        best = None  # (start_cycle, arrival_slot, order)
        for order, avail in (("A", availA), ("B", availB)):
            got = self._best_slot(avail, total_hops, t_ready)
            if got is not None and (best is None or got[0] < best[0]):
                best = (got[0], got[1], order)
        if best is None:
            return None
        start_cycle, a0, order = best

        def hops_for(order: str, a: int):
            """Hop list + bus (column, slot) for an arrival slot, or None."""
            if order == "A":
                bus_slot = (a - 1) % n
                try:
                    hops_xy = (traceback(vecA, occ, mesh, n, req.src, w,
                                         bus_slot)[:-1] if dist_xy else [])
                except RuntimeError:
                    return None
                return (hops_xy + [(req.dst, PORT_LOCAL, a)],
                        (mesh.column_of(w), bus_slot))
            s = (a - total_hops) % n              # injection slot = bus slot
            try:
                hops_xy = (traceback(vecB, occ, mesh, n, w2, req.dst, a)
                           if dist_xy else [(req.dst, PORT_LOCAL, a)])
            except RuntimeError:
                return None
            return hops_xy, (mesh.column_of(req.src), s)

        # Bundle extra free slots to accelerate the transfer (Section 2.1).
        picked = []
        avail = availA if order == "A" else availB
        for a in [a0] + [x for x in range(n) if x != a0]:
            if len(picked) >= 1 + req.max_extra_slots:
                break
            if not bit_is_free(avail, a):
                continue
            got = hops_for(order, a)
            if got is not None:
                picked.append(got)
        if not picked:
            return _CONFLICT
        hops = [h for hs, _bus in picked for h in hs]
        bus_slots = [b for _h, b in picked]
        if (not self.table.can_reserve(hops, window)
                or len({b for b in bus_slots}) < len(bus_slots)
                or not all(self.table.can_reserve_bus(col, bslot, window)
                           for col, bslot in bus_slots)):
            return _CONFLICT
        n_win = self.n_windows_for(req.nbytes, slots=len(picked))
        circ = Circuit(src=req.src, dst=req.dst, start_cycle=start_cycle,
                       n_windows=n_win, hops=hops,
                       slots_per_window=len(picked), uses_bus=True,
                       bus_column=picked[0][1][0], distance=total_hops,
                       _n_slots_hint=n)
        self.table.reserve(circ, w_res)
        for col, bslot in bus_slots:
            self.table.reserve_bus(col, bslot, w_res, n_win)
        return circ
