"""TDM slot allocation — the paper's core algorithm (Section 2.1).

The CCU services copy requests by finding a *circuit*: a sequence of
increasingly-numbered TDM slots along a shortest path, so data advances one
hop per cycle with no buffering/arbitration.  The paper implements the
search with a matrix of PEs (one per router) that propagate an n-bit busy
vector along all shortest paths: at each PE the vector is OR-ed with the
output-port occupancy and rotated right (slot j upstream -> slot j+1 here);
zero bits surviving at the destination are feasible circuits.

Implementation layout (mirrors the hardware split):

* :func:`wavefront_search` — the PE-matrix accelerator, vectorized JAX
  (``vmap``-able over a batch of requests; the Pallas TPU kernel in
  ``repro.kernels.slot_alloc`` implements the same contract).
* :class:`SlotTable` — the CCU's occupancy bookkeeping (host-side numpy):
  per (router, port, slot) reservation expiry in TDM-window units.
* :func:`traceback` — walks the converged vectors backwards to extract the
  hop list, as the paper's "tracing back the path towards the source PE".

Slot/cycle accounting (paper Fig. 2): a circuit of distance D injected at
source slot ``s`` uses slot ``s+i (mod n)`` at the i-th router on the path
and ejects through the destination's LOCAL port at slot ``s+D (mod n)`` —
e.g. 5 routers / slots 3..7 for the A->B example.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .bitvec import UINT, bit_is_free, full_mask, rotr, rotr_np
from .topology import Mesh3D, N_PORTS, PORT_LOCAL, port_for

_STRIDES = ("X", "XY")  # doc only


# ---------------------------------------------------------------------------
# The PE-matrix search (pure JAX; jit + vmap friendly)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("mesh", "n_slots"))
def wavefront_search(occ: jax.Array, src: jax.Array, dst: jax.Array,
                     init_vec: jax.Array, *, mesh: Mesh3D,
                     n_slots: int) -> jax.Array:
    """Propagate busy-vectors from ``src`` to every node of the shortest-path
    lattice toward ``dst``.

    Args:
      occ: (n_nodes, N_PORTS) uint32 — busy mask per output port.
      src, dst: scalar int32 node ids (traced; may come from a vmapped batch).
      init_vec: uint32 scalar — initial busy vector at the source (0 for a
        fresh search; non-zero when composing multi-phase NoM-Light routes).

    Returns:
      (n_nodes,) uint32: converged busy vector per node, indexed by the slot
      at which that node's *output* crossbar would be used.  Out-of-lattice
      nodes hold the all-busy mask.  ``vec[dst] | occ[dst, LOCAL]`` is the
      availability vector of arrival slots.
    """
    n = mesh.n_nodes
    fm = jnp.asarray(full_mask(n_slots), UINT)
    coords = jnp.asarray(mesh.coord_array)          # (n, 3)
    src_c = coords[src]                             # (3,)
    dst_c = coords[dst]
    sign = jnp.sign(dst_c - src_c)                  # (3,) in {-1,0,1}
    lo = jnp.minimum(src_c, dst_c)
    hi = jnp.maximum(src_c, dst_c)
    in_box = jnp.all((coords >= lo) & (coords <= hi), axis=1)  # (n,)

    strides = jnp.asarray([1, mesh.X, mesh.X * mesh.Y], jnp.int32)
    node_ids = jnp.arange(n, dtype=jnp.int32)

    # Per-dimension upstream node id and validity.
    # upstream_d(v) = v - sign_d * stride_d ; valid iff we have moved >=1 step
    # in dimension d away from the source and d is a travel dimension.
    ups = node_ids[None, :] - sign[:, None] * strides[:, None]      # (3, n)
    moved = coords.T != src_c[:, None]                              # (3, n)
    valid = in_box[None, :] & moved & (sign[:, None] != 0)          # (3, n)
    ups = jnp.clip(ups, 0, n - 1)

    # Output port used at the upstream node for a hop along dim d, dir sign_d.
    ports = jnp.where(sign < 0, 2 * jnp.arange(3) + 1, 2 * jnp.arange(3))

    vec0 = jnp.full((n,), fm, UINT).at[src].set(jnp.asarray(init_vec, UINT))
    is_src = node_ids == src

    def body(_, vec):
        def cand(d):
            up = ups[d]
            v = vec[up] | occ[up, ports[d]]
            v = rotr(v, n_slots)
            return jnp.where(valid[d], v, fm)
        new = cand(0) & cand(1) & cand(2)
        # Source keeps its injected vector; out-of-lattice nodes stay busy.
        return jnp.where(in_box & ~is_src, new, vec0)

    # The lattice is a DAG of depth <= max_dist, so max_dist sweeps converge.
    vec = jax.lax.fori_loop(0, mesh.max_dist, body, vec0)
    return vec


def wavefront_search_batch(occ, srcs, dsts, init_vecs, *, mesh, n_slots):
    """vmap over a batch of (src, dst) requests sharing one occupancy state.

    This is the paper's "explore all possible paths ... in parallel" taken one
    step further: concurrent request *searches* also run in parallel (the CCU
    still reserves sequentially, in FIFO order).
    """
    fn = partial(wavefront_search, mesh=mesh, n_slots=n_slots)
    return jax.vmap(lambda s, d, iv: fn(occ, s, d, iv))(srcs, dsts, init_vecs)


# ---------------------------------------------------------------------------
# Host-side CCU bookkeeping
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Circuit:
    """A reserved circuit: ``hops[i] = (node, out_port, slot)`` in forward
    order; the last hop is (dst, PORT_LOCAL, arrival_slot)."""
    src: int
    dst: int
    start_cycle: int          # absolute cycle of source injection
    n_windows: int            # TDM windows the reservation persists
    hops: list[tuple[int, int, int]]
    slots_per_window: int = 1
    uses_bus: bool = False    # NoM-Light vertical bus hop present
    bus_column: int = -1      # (x, y) column whose TSV the bus hop rides
    distance: int = 0         # hops traversed by one beat (src -> dst)

    @property
    def arrival_cycle(self) -> int:
        return self.start_cycle + self.distance

    @property
    def end_cycle(self) -> int:
        """Cycle at which the last beat has arrived at the destination."""
        return self.arrival_cycle + (self.n_windows - 1) * self._n_slots_hint

    _n_slots_hint: int = 16


class SlotTable:
    """Occupancy state of every router port (and NoM-Light vertical buses).

    ``expiry[node, port, slot]`` is the TDM-window index until which the slot
    is reserved (exclusive).  A slot is busy for a search anchored at window
    ``w`` iff ``expiry > w`` — conservative for circuits that would start
    after an existing reservation expires, which matches the paper's CCU (it
    services requests in FIFO order against current state).
    """

    def __init__(self, mesh: Mesh3D, n_slots: int = 16):
        self.mesh = mesh
        self.n_slots = n_slots
        self.expiry = np.zeros((mesh.n_nodes, N_PORTS, n_slots), np.int64)
        # One vertical bus resource per (x, y) column (NoM-Light).
        self.bus_expiry = np.zeros((mesh.X * mesh.Y, n_slots), np.int64)

    # -- masks ---------------------------------------------------------------
    def busy_masks(self, window: int) -> np.ndarray:
        """(n_nodes, N_PORTS) uint32 busy masks as of TDM window `window`."""
        busy = self.expiry > window
        weights = (np.uint32(1) << np.arange(self.n_slots, dtype=np.uint32))
        return (busy * weights).sum(axis=2).astype(np.uint32)

    def bus_busy_masks(self, window: int) -> np.ndarray:
        busy = self.bus_expiry > window
        weights = (np.uint32(1) << np.arange(self.n_slots, dtype=np.uint32))
        return (busy * weights).sum(axis=1).astype(np.uint32)

    # -- reservation ----------------------------------------------------------
    def reserve(self, circuit: Circuit, window: int) -> None:
        until = window + circuit.n_windows
        for node, port, slot in circuit.hops:
            assert self.expiry[node, port, slot] <= window, "double booking"
            self.expiry[node, port, slot] = until

    def reserve_bus(self, column: int, slot: int, window: int,
                    n_windows: int) -> None:
        assert self.bus_expiry[column, slot] <= window, "bus double booking"
        self.bus_expiry[column, slot] = window + n_windows

    def utilization(self, window: int) -> float:
        return float((self.expiry > window).mean())


# ---------------------------------------------------------------------------
# Trace-back (paper: "reserved by tracing back the path towards the source")
# ---------------------------------------------------------------------------
def traceback(vec: np.ndarray, occ: np.ndarray, mesh: Mesh3D, n_slots: int,
              src: int, dst: int, arrival_slot: int) -> list[tuple[int, int, int]]:
    """Extract one feasible hop list ending at ``dst`` on ``arrival_slot``.

    ``vec`` is the converged busy-vector array from :func:`wavefront_search`
    (numpy), ``occ`` the (n_nodes, N_PORTS) busy masks used for the search.
    """
    coords = mesh.coord_array
    sx, sy, sz = coords[src]
    hops: list[tuple[int, int, int]] = [(dst, PORT_LOCAL, arrival_slot)]
    v, j = int(dst), int(arrival_slot)
    strides = (1, mesh.X, mesh.X * mesh.Y)
    sign = np.sign(coords[dst] - coords[src])
    guard = 0
    while v != src:
        guard += 1
        if guard > mesh.max_dist + 2:
            raise RuntimeError("traceback failed to reach source")
        jp = (j - 1) % n_slots
        placed = False
        for d in range(3):
            if sign[d] == 0 or coords[v][d] == coords[src][d]:
                continue
            u = v - int(sign[d]) * strides[d]
            p = port_for(d, int(sign[d]))
            if bit_is_free(int(vec[u]) | int(occ[u, p]), jp):
                hops.append((u, p, jp))
                v, j = u, jp
                placed = True
                break
        if not placed:
            raise RuntimeError(
                f"no free upstream at node {v} slot {j} (inconsistent search)")
    hops.reverse()
    return hops


# ---------------------------------------------------------------------------
# Full allocation: search + slot choice + trace-back + reserve
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class AllocResult:
    circuit: Circuit | None
    searched_cycle: int


class TdmAllocator:
    """The CCU's allocation pipeline for the *full 3D mesh* NoM.

    ``allocate`` implements the paper's 3-cycle setup: the request picked at
    cycle t searches at t (1 cycle), programs slot tables (1 cycle), issues
    the read (1 cycle), so the earliest injection is t+3.
    """

    def __init__(self, mesh: Mesh3D, n_slots: int = 16,
                 link_bytes: int = 8, use_pallas: bool = False):
        self.mesh = mesh
        self.n_slots = n_slots
        self.link_bytes = link_bytes  # 64-bit links => 8 bytes/slot-cycle
        self.table = SlotTable(mesh, n_slots)
        self._search = partial(wavefront_search, mesh=mesh, n_slots=n_slots)
        if use_pallas:  # pragma: no cover - exercised in kernel tests
            from repro.kernels.slot_alloc import ops as _ops
            self._search = partial(_ops.wavefront_search_pallas, mesh=mesh,
                                   n_slots=n_slots)

    def n_windows_for(self, nbytes: int, slots: int = 1) -> int:
        per_window = self.link_bytes * slots
        return max(1, -(-nbytes // per_window))

    def allocate(self, src: int, dst: int, nbytes: int, cycle: int,
                 max_extra_slots: int = 0) -> AllocResult:
        """Find + reserve the earliest circuit for a copy of ``nbytes``.

        Returns AllocResult with circuit=None if the lattice is fully busy
        (caller retries next cycle, as the CCU would)."""
        t_ready = cycle + 3                       # paper's 3-cycle setup
        window = t_ready // self.n_slots
        occ = self.table.busy_masks(window)
        vec = np.asarray(self._search(jnp.asarray(occ), jnp.int32(src),
                                      jnp.int32(dst), jnp.uint32(0)))
        avail = int(vec[dst]) | int(occ[dst, PORT_LOCAL])
        dist = self.mesh.manhattan(src, dst)
        best = None  # (start_cycle, arrival_slot)
        for a in range(self.n_slots):
            if not bit_is_free(avail, a):
                continue
            s = (a - dist) % self.n_slots
            # earliest injection cycle >= t_ready with cycle % n == s
            c = t_ready + ((s - t_ready) % self.n_slots)
            if best is None or c < best[0]:
                best = (c, a)
        if best is None:
            return AllocResult(None, cycle)
        start_cycle, a = best
        hops = traceback(vec, occ, self.mesh, self.n_slots, src, dst, a)
        # Optionally accelerate with extra free slots (paper Section 2.1).
        extra = 0
        if max_extra_slots:
            for a2 in range(self.n_slots):
                if extra >= max_extra_slots:
                    break
                if a2 != a and bit_is_free(avail, a2):
                    try:
                        hops2 = traceback(vec, occ, self.mesh, self.n_slots,
                                          src, dst, a2)
                    except RuntimeError:
                        continue
                    hops = hops + hops2
                    extra += 1
        n_win = self.n_windows_for(nbytes, slots=1 + extra)
        circ = Circuit(src=src, dst=dst, start_cycle=start_cycle,
                       n_windows=n_win, hops=hops, slots_per_window=1 + extra,
                       distance=dist, _n_slots_hint=self.n_slots)
        self.table.reserve(circ, window)
        return AllocResult(circ, cycle)


class TdmAllocatorLight(TdmAllocator):
    """NoM-Light: no dedicated Z links; vertical movement rides the existing
    per-vault TSV bus — single-cycle multi-hop, but one transfer per column
    per slot (Section 2.3).

    Routes are XY-monotone on one layer plus at most one bus hop.  We search
    both phase orders (XY-then-bus, bus-then-XY) and keep the earlier.
    """

    def allocate(self, src: int, dst: int, nbytes: int, cycle: int,
                 max_extra_slots: int = 0) -> AllocResult:
        mesh, n = self.mesh, self.n_slots
        sx, sy, sz = mesh.coords(src)
        dx, dy, dz = mesh.coords(dst)
        t_ready = cycle + 3
        window = t_ready // n
        occ = self.table.busy_masks(window)
        bus = self.table.bus_busy_masks(window)
        if sz == dz:
            return super().allocate(src, dst, nbytes, cycle, max_extra_slots)

        dist_xy = abs(sx - dx) + abs(sy - dy)
        cands = []  # (start_cycle, order, arrival_slot, vec, anchor nodes)

        # Order A: XY on the source layer, then bus down/up to dst.
        w = mesh.node_id(dx, dy, sz)
        vecA = np.asarray(self._search(jnp.asarray(occ), jnp.int32(src),
                                       jnp.int32(w), jnp.uint32(0)))
        availA = rotr_np(np.uint32(int(vecA[w]) | int(bus[mesh.column_of(w)])),
                         n)
        availA = int(availA) | int(occ[dst, PORT_LOCAL])
        # Order B: bus first, then XY on the destination layer.
        w2 = mesh.node_id(sx, sy, dz)
        init = rotr_np(np.uint32(int(bus[mesh.column_of(src)])), n)
        vecB = np.asarray(self._search(jnp.asarray(occ), jnp.int32(w2),
                                       jnp.int32(dst), jnp.asarray(init, np.uint32)))
        availB = int(vecB[dst]) | int(occ[dst, PORT_LOCAL])

        total_hops = dist_xy + 1  # bus counts as one slot regardless of layers
        best = None  # (start_cycle, arrival_slot, order)
        for order, avail in (("A", availA), ("B", availB)):
            for a in range(n):
                if not bit_is_free(avail, a):
                    continue
                s = (a - total_hops) % n
                c = t_ready + ((s - t_ready) % n)
                if best is None or c < best[0]:
                    best = (c, a, order)
        if best is None:
            return AllocResult(None, cycle)
        start_cycle, a0, order = best

        def hops_for(order: str, a: int):
            """Hop list + bus (column, slot) for an arrival slot, or None."""
            if order == "A":
                bus_slot = (a - 1) % n
                try:
                    hops_xy = (traceback(vecA, occ, mesh, n, src, w, bus_slot)
                               [:-1] if dist_xy else [])
                except RuntimeError:
                    return None
                return (hops_xy + [(dst, PORT_LOCAL, a)],
                        (mesh.column_of(w), bus_slot))
            s = (a - total_hops) % n              # injection slot = bus slot
            try:
                hops_xy = (traceback(vecB, occ, mesh, n, w2, dst, a)
                           if dist_xy else [(dst, PORT_LOCAL, a)])
            except RuntimeError:
                return None
            return hops_xy, (mesh.column_of(src), s)

        # Bundle extra free slots to accelerate the transfer (Section 2.1).
        picked = []
        avail = availA if order == "A" else availB
        for a in [a0] + [x for x in range(n) if x != a0]:
            if len(picked) >= 1 + max_extra_slots:
                break
            if not bit_is_free(avail, a):
                continue
            got = hops_for(order, a)
            if got is not None:
                picked.append(got)
        hops = [h for hs, _bus in picked for h in hs]
        n_win = self.n_windows_for(nbytes, slots=len(picked))
        circ = Circuit(src=src, dst=dst, start_cycle=start_cycle,
                       n_windows=n_win, hops=hops,
                       slots_per_window=len(picked), uses_bus=True,
                       bus_column=picked[0][1][0], distance=total_hops,
                       _n_slots_hint=n)
        self.table.reserve(circ, window)
        for col, bslot in (bus for _h, bus in picked):
            self.table.reserve_bus(col, bslot, window, n_win)
        return AllocResult(circ, cycle)
