"""Production train loop: checkpoint/restart, NaN-guard, straggler
monitor, elastic re-mesh hook.

Fault-tolerance model (designed for 1000+ nodes, exercised at CPU scale in
tests):
* periodic atomic checkpoints + resume from latest on (re)start — a
  SIGKILL at any point loses at most ``ckpt_every`` steps;
* deterministic data pipeline keyed by (seed, step) — resumed runs replay
  the exact token stream;
* non-finite gradients skip the optimizer update inside the compiled step;
* a straggler monitor EMAs per-step wall time and flags outliers (on a real
  pod this feeds the re-shard/elastic controller; here it drives tests and
  logs);
* ``on_remesh`` hook: when the device set changes, reload the latest
  checkpoint under the new mesh (shardings recomputed) and continue — the
  shard-migration schedule is NOM-planned (see checkpoint.reshard).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro import checkpoint as ckpt
from repro.data import DataConfig, batch_at

from .state import TrainState


@dataclasses.dataclass
class StragglerMonitor:
    """EMA step-time tracker; flags steps slower than ratio * EMA."""
    alpha: float = 0.2
    ratio: float = 2.0
    ema: float | None = None
    flagged: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = self.ema is not None and dt > self.ratio * self.ema
        self.ema = dt if self.ema is None else \
            (1 - self.alpha) * self.ema + self.alpha * dt
        if is_straggler:
            self.flagged.append((step, dt))
        return is_straggler


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10


def train_loop(train_step: Callable, state: TrainState, data_cfg: DataConfig,
               loop_cfg: LoopConfig, *, shardings=None,
               extra_batch_fn: Callable | None = None,
               fail_at_step: int | None = None,
               log: Callable = print) -> tuple[TrainState, list]:
    """Run (or resume) training.  ``fail_at_step`` raises mid-run to let
    tests exercise the crash/restore path."""
    start = int(jax.device_get(state.step))
    restored, manifest = ckpt.restore(loop_cfg.ckpt_dir)
    if restored is not None and manifest["step"] > start:
        state = TrainState(params=restored["params"],
                           opt_state=restored["opt_state"],
                           step=jax.numpy.asarray(manifest["step"],
                                                  jax.numpy.int32))
        start = manifest["step"]
        log(f"[loop] resumed from step {start}")
    monitor = StragglerMonitor()
    history = []
    for step in range(start, loop_cfg.total_steps):
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch = batch_at(data_cfg, step)
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        if extra_batch_fn is not None:
            batch.update(extra_batch_fn(step))
        t0 = time.perf_counter()
        state, metrics = train_step(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        straggle = monitor.observe(step, dt)
        m = {k: float(jax.device_get(v)) for k, v in metrics.items()}
        history.append({"step": step, **m, "dt": dt,
                        "straggler": straggle})
        if step % loop_cfg.log_every == 0:
            log(f"[loop] step {step} loss={m['loss']:.4f} "
                f"gnorm={m['grad_norm']:.3f} dt={dt*1e3:.0f}ms"
                + (" STRAGGLER" if straggle else ""))
        if (step + 1) % loop_cfg.ckpt_every == 0 \
                or step + 1 == loop_cfg.total_steps:
            ckpt.save(loop_cfg.ckpt_dir, step + 1,
                      {"params": state.params, "opt_state": state.opt_state})
            ckpt.prune(loop_cfg.ckpt_dir, loop_cfg.keep)
    return state, history
