from .loop import LoopConfig, StragglerMonitor, train_loop
from .state import TrainState, make_prefill_step, make_serve_step, \
    make_train_step

__all__ = ["LoopConfig", "StragglerMonitor", "train_loop", "TrainState",
           "make_prefill_step", "make_serve_step", "make_train_step"]
