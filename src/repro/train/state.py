"""Train state pytree + step functions (train / prefill / serve)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import COMPUTE_DTYPE
from repro.models.lm import lm_loss
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array

    @classmethod
    def create(cls, params, opt_cfg: AdamWConfig | None = None):
        return cls(params=params, opt_state=adamw.init(params),
                   step=jnp.zeros((), jnp.int32))


def make_train_step(model, cfg, opt_cfg: AdamWConfig,
                    microbatches: int = 1, cast_bf16_gather: bool = False,
                    param_shardings=None):
    """Build the jit-able train_step(state, batch) -> (state, metrics).

    Gradient accumulation runs as a ``lax.scan`` over microbatches
    (compute/comm overlap: each microbatch's backward all-reduces overlap
    the next microbatch's forward under XLA latency-hiding scheduling).
    A non-finite-gradient guard skips the optimizer update (fault
    tolerance at the numerics level).

    ``cast_bf16_gather`` (beyond-paper §Perf optimization): cast fp32
    master weights to bf16 *shard-side* before use, so FSDP weight
    all-gathers and the gathered working set move half the bytes; the
    optimizer still updates fp32 masters."""

    def _maybe_cast(params):
        if not cast_bf16_gather:
            return params
        if param_shardings is not None:
            # Anchor the cast shard-side: constraining the bf16 copy to the
            # same (FSDP) sharding forces XLA to cast before gathering, so
            # weight all-gathers move half the bytes (§Perf H9).
            return jax.tree.map(
                lambda p, s: jax.lax.with_sharding_constraint(
                    p.astype(COMPUTE_DTYPE), s)
                if p.dtype == jnp.float32 else p, params, param_shardings)
        return jax.tree.map(
            lambda p: p.astype(COMPUTE_DTYPE)
            if p.dtype == jnp.float32 else p, params)

    def loss_fn(params, tokens, extra):
        params = _maybe_cast(params)
        if cfg.arch_type == "encdec":
            logits, aux = model.apply(params, extra["enc_emb"], tokens)
        elif cfg.arch_type == "vlm":
            logits, aux = model.apply(params, tokens,
                                      prefix_emb=extra["prefix_emb"])
        else:
            logits, aux = model.apply(params, tokens)
        loss, parts = lm_loss(logits, tokens, aux)
        return loss, parts

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict):
        tokens = batch["tokens"]
        extra = {k: v for k, v in batch.items() if k != "tokens"}
        if microbatches > 1:
            b = tokens.shape[0] // microbatches
            toks = tokens.reshape(microbatches, b, *tokens.shape[1:])
            extras = jax.tree.map(
                lambda v: v.reshape(microbatches, b, *v.shape[1:]), extra)

            def acc_fn(carry, xs):
                g_acc, l_acc = carry
                t, e = xs
                (loss, parts), g = grad_fn(state.params, t, e)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss), parts

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            (grads, loss), _ = jax.lax.scan(acc_fn, (g0, 0.0),
                                            (toks, extras))
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        else:
            (loss, parts), grads = grad_fn(state.params, tokens, extra)

        finite = jnp.isfinite(adamw.global_norm(grads))
        new_params, new_opt, om = adamw.update(opt_cfg, grads,
                                               state.opt_state, state.params)
        # NaN/inf guard: keep old state, still advance step counter.
        new_params = jax.tree.map(
            lambda n, o: jnp.where(finite, n, o), new_params, state.params)
        new_opt = jax.tree.map(
            lambda n, o: jnp.where(finite, n, o), new_opt, state.opt_state)
        metrics = {"loss": loss, "grad_norm": om["grad_norm"],
                   "lr": om["lr"], "finite": finite.astype(jnp.int32)}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_serve_step(model, cfg):
    """serve_step(params, token, caches, pos[, memory]) — one decode step."""
    if cfg.arch_type == "encdec":
        def serve_step(params, token, caches, pos, memory):
            return model.decode_step(params, token, caches, pos, memory)
    else:
        def serve_step(params, token, caches, pos):
            return model.decode_step(params, token, caches, pos)
    return serve_step


def make_prefill_step(model, cfg):
    """prefill_step = full forward at inference (logits only)."""
    def prefill_step(params, tokens, *extra_args):
        if cfg.arch_type == "encdec":
            logits, _ = model.apply(params, extra_args[0], tokens,
                                    remat=False)
        elif cfg.arch_type == "vlm":
            logits, _ = model.apply(params, tokens, prefix_emb=extra_args[0],
                                    remat=False)
        else:
            logits, _ = model.apply(params, tokens, remat=False)
        return logits
    return prefill_step
