"""Deterministic synthetic LM data pipeline.

Framework-shaped: sharded per host, deterministic in (seed, step) so a
restarted job resumes mid-epoch bit-identically (required by the
fault-tolerance tests), with background prefetch.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def batch_at(cfg: DataConfig, step: int) -> dict:
    """The global batch for `step`, restricted to this host's rows.

    Philox counter-style: tokens are a pure function of (seed, step, row),
    so any host can regenerate any step (elastic re-sharding of the data
    pipeline is a no-op)."""
    rows = cfg.batch // cfg.n_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
    toks = rng.integers(1, cfg.vocab, size=(rows, cfg.seq), dtype=np.int32)
    # Plant learnable structure: next-token = f(current) on half the stream
    # so tiny-model training loss visibly drops.
    toks[:, 1::2] = (toks[:, 0::2] * 7 + 13) % cfg.vocab
    return {"tokens": toks}


class Prefetcher:
    """Background-thread prefetch of batch_at, depth-bounded."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        s = self._step
        while not self._stop.is_set():
            try:
                self.q.put((s, batch_at(self.cfg, s)), timeout=0.1)
                s += 1
            except queue.Full:
                continue

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        self._t.join(timeout=1.0)
