from .pipeline import DataConfig, Prefetcher, batch_at

__all__ = ["DataConfig", "Prefetcher", "batch_at"]
