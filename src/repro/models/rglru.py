"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence: a_t = exp(-c * softplus(Lambda) * sigmoid(W_a x_t)),
h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(W_x x_t) * x_t).

Training/prefill uses ``lax.associative_scan`` over (a, b) pairs; decode is
the O(1) per-token update.  The full recurrent block wraps the LRU with the
RecurrentGemma structure: dual linear branches, short causal conv on the
recurrent branch, GeLU gating on the other.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .common import AxesTree, Params, dense_init

_C = 8.0   # the paper's fixed scalar


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    lru_width: int | None = None
    conv_width: int = 4
    n_heads: int = 1   # block-diagonal input gates (per-head), paper uses heads

    @property
    def width(self) -> int:
        return self.lru_width or self.d_model


@dataclasses.dataclass(frozen=True)
class RGLRU:
    """The bare RG-LRU layer over pre-projected inputs (B, S, W)."""
    cfg: RGLRUConfig

    def init(self, key) -> Params:
        c = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        # Lambda init so that a^c in [0.9, 0.999] (paper appendix).
        u = jax.random.uniform(k3, (c.width,), minval=0.9 ** 2,
                               maxval=0.999 ** 2)
        a_param = jnp.log(jnp.expm1(-(1.0 / _C) * jnp.log(u)))  # softplus^-1
        return {"w_a": dense_init(k1, (c.width, c.width)),
                "b_a": jnp.zeros((c.width,)),
                "w_x": dense_init(k2, (c.width, c.width)),
                "b_x": jnp.zeros((c.width,)),
                "a_param": a_param}

    def axes(self) -> AxesTree:
        return {"w_a": ("mlp", "mlp_out"), "b_a": ("mlp_out",),
                "w_x": ("mlp", "mlp_out"), "b_x": ("mlp_out",),
                "a_param": ("mlp_out",)}

    def _gates(self, p: Params, x: jax.Array):
        r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x,
                                      p["w_a"].astype(x.dtype))
                           + p["b_a"].astype(x.dtype))
        i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x,
                                      p["w_x"].astype(x.dtype))
                           + p["b_x"].astype(x.dtype))
        log_a = (-_C * jax.nn.softplus(p["a_param"].astype(jnp.float32))
                 * r.astype(jnp.float32))
        a = jnp.exp(log_a)
        mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
        b = mult * (i.astype(jnp.float32) * x.astype(jnp.float32))
        return a, b

    def apply(self, p: Params, x: jax.Array, h0=None) -> jax.Array:
        """x: (B, S, W) -> (y, h_last)."""
        a, b = self._gates(p, x)
        if h0 is not None:
            b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a1 * a2, a2 * b1 + b2

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        return h.astype(x.dtype), h[:, -1]

    def step(self, p: Params, x: jax.Array, h: jax.Array):
        """x: (B, 1, W), h: (B, W) -> (y (B,1,W), h_new)."""
        a, b = self._gates(p, x)
        h_new = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
        return h_new[:, None].astype(x.dtype), h_new


@dataclasses.dataclass(frozen=True)
class RecurrentBlock:
    """RecurrentGemma mixer: x/y branches, conv1d + RG-LRU on x, GeLU(y) gate."""
    cfg: RGLRUConfig

    def init(self, key) -> Params:
        c = self.cfg
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        return {
            "proj_x": dense_init(k1, (c.d_model, c.width)),
            "proj_y": dense_init(k2, (c.d_model, c.width)),
            "conv_w": dense_init(k3, (c.conv_width, c.width)),
            "lru": RGLRU(c).init(k4),
            "proj_out": dense_init(k5, (c.width, c.d_model)),
        }

    def axes(self) -> AxesTree:
        return {"proj_x": ("embed", "mlp"), "proj_y": ("embed", "mlp"),
                "conv_w": (None, "mlp"), "lru": RGLRU(self.cfg).axes(),
                "proj_out": ("mlp", "embed")}

    def _conv(self, p, x, conv_state=None):
        c = self.cfg
        w = p["conv_w"].astype(x.dtype)
        pad = (jnp.zeros((x.shape[0], c.conv_width - 1, x.shape[2]), x.dtype)
               if conv_state is None else conv_state.astype(x.dtype))
        xp = jnp.concatenate([pad, x], axis=1)
        out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(c.conv_width))
        return out, xp[:, -(c.conv_width - 1):]

    def apply(self, p: Params, u: jax.Array) -> jax.Array:
        x = jnp.einsum("bsd,dw->bsw", u, p["proj_x"].astype(u.dtype))
        y = jnp.einsum("bsd,dw->bsw", u, p["proj_y"].astype(u.dtype))
        x, _ = self._conv(p, x)
        x, _ = RGLRU(self.cfg).apply(p["lru"], x)
        out = x * jax.nn.gelu(y)
        return jnp.einsum("bsw,wd->bsd", out, p["proj_out"].astype(u.dtype))

    def init_cache(self, batch: int, dtype=None) -> dict:
        from .common import COMPUTE_DTYPE
        c = self.cfg
        return {"conv": jnp.zeros((batch, c.conv_width - 1, c.width),
                                  dtype or COMPUTE_DTYPE),
                "h": jnp.zeros((batch, c.width), jnp.float32)}

    def cache_axes(self) -> dict:
        return {"conv": ("batch", None, "mlp"), "h": ("batch", "mlp")}

    def decode(self, p: Params, u: jax.Array, cache: dict):
        x = jnp.einsum("bsd,dw->bsw", u, p["proj_x"].astype(u.dtype))
        y = jnp.einsum("bsd,dw->bsw", u, p["proj_y"].astype(u.dtype))
        x, conv_state = self._conv(p, x, cache["conv"])
        x, h = RGLRU(self.cfg).step(p["lru"], x, cache["h"])
        out = x * jax.nn.gelu(y)
        out = jnp.einsum("bsw,wd->bsd", out, p["proj_out"].astype(u.dtype))
        return out, {"conv": conv_state, "h": h}
