"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Chunked SSD: within a chunk the recurrence is computed in its quadratic
"attention-like" dual form; across chunks a compact (heads, head_dim,
d_state) state is carried — this is the structure the Pallas ``ssd_scan``
kernel tiles for VMEM; this module is the jnp implementation used for
training/dry-run lowering, plus the O(1) single-token decode step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .common import AxesTree, Params, RMSNorm, dense_init


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k in (j, i]} x[..., k]
    for j < i else -inf (lower-triangular cumulative decay)."""
    t = x.shape[-1]
    xc = jnp.cumsum(x, axis=-1)
    diff = xc[..., :, None] - xc[..., None, :]
    mask = np.tril(np.ones((t, t), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


@dataclasses.dataclass(frozen=True)
class Mamba2:
    cfg: SSMConfig

    def init(self, key) -> Params:
        c = self.cfg
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        d_in_proj = 2 * c.d_inner + 2 * c.d_state + c.n_heads
        dt = np.exp(np.random.RandomState(0).uniform(
            np.log(c.dt_min), np.log(c.dt_max), c.n_heads)).astype(np.float32)
        dt_bias = dt + np.log(-np.expm1(-dt))   # inv softplus
        return {
            "in_proj": dense_init(k1, (c.d_model, d_in_proj)),
            "conv_w": dense_init(k2, (c.conv_width,
                                      c.d_inner + 2 * c.d_state)),
            "A_log": jnp.log(jnp.arange(1, c.n_heads + 1, dtype=jnp.float32)),
            "D": jnp.ones((c.n_heads,), jnp.float32),
            "dt_bias": jnp.asarray(dt_bias),
            "norm": RMSNorm(c.d_inner).init(k4),
            "out_proj": dense_init(k5, (c.d_inner, c.d_model)),
        }

    def axes(self) -> AxesTree:
        return {"in_proj": ("embed", "mlp"),
                "conv_w": (None, "mlp"),
                "A_log": ("heads_unsharded",),
                "D": ("heads_unsharded",),
                "dt_bias": ("heads_unsharded",),
                "norm": {"scale": (None,)},
                "out_proj": ("mlp", "embed")}

    # -- projections shared by scan and step --------------------------------------
    def _project(self, p: Params, u: jax.Array):
        c = self.cfg
        zxbcdt = jnp.einsum("bsd,de->bse", u, p["in_proj"].astype(u.dtype))
        z, xbc, dt = jnp.split(
            zxbcdt, [c.d_inner, 2 * c.d_inner + 2 * c.d_state], axis=-1)
        dt = jax.nn.softplus(dt.astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
        return z, xbc, dt

    def _conv(self, p: Params, xbc: jax.Array, conv_state=None):
        """Causal depthwise conv; returns (out, new_conv_state)."""
        c = self.cfg
        w = p["conv_w"].astype(xbc.dtype)                    # (W, ch)
        if conv_state is None:
            pad = jnp.zeros((xbc.shape[0], c.conv_width - 1, xbc.shape[2]),
                            xbc.dtype)
        else:
            pad = conv_state.astype(xbc.dtype)
        xp = jnp.concatenate([pad, xbc], axis=1)
        out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(c.conv_width))
        new_state = xp[:, -(c.conv_width - 1):]
        return jax.nn.silu(out), new_state

    # -- chunked SSD over a full sequence ------------------------------------------
    def _ssd(self, x, dt, B, C, A):
        """x:(b,s,h,p) dt:(b,s,h) B,C:(b,s,n) A:(h,) -> y, final_state."""
        c = self.cfg
        b, s, h, pdim = x.shape
        q = c.chunk
        nc = s // q
        xb = x.reshape(b, nc, q, h, pdim)
        dtb = dt.reshape(b, nc, q, h)
        Bb = B.reshape(b, nc, q, -1)
        Cb = C.reshape(b, nc, q, -1)
        dA = dtb * A.astype(jnp.float32)                      # (b,nc,q,h) <0
        dAc = jnp.cumsum(dA, axis=2)
        # Intra-chunk (dual quadratic form).
        L = jnp.exp(segsum(dA.transpose(0, 1, 3, 2)))          # (b,nc,h,q,q)
        scores = jnp.einsum("bcqn,bckn->bcqk", Cb, Bb)         # (b,nc,q,q)
        M = scores[:, :, None] * L                             # (b,nc,h,q,q)
        y_intra = jnp.einsum("bchqk,bckh,bckhp->bcqhp", M, dtb,
                             xb.astype(jnp.float32))
        # Chunk states: decay-weighted outer products.
        decay_to_end = jnp.exp(dAc[:, :, -1:, :] - dAc)        # (b,nc,q,h)
        states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                            Bb, dtb * decay_to_end,
                            xb.astype(jnp.float32))            # (b,nc,h,p,n)
        # Inter-chunk recurrence over nc (sequential scan, nc is small).
        chunk_decay = jnp.exp(dAc[:, :, -1, :])                # (b,nc,h)

        def step(carry, inp):
            st, = (carry,)
            s_c, dec = inp
            new = st * dec[..., None, None] + s_c
            return new, st                                     # emit prior state

        init = jnp.zeros((b, h, pdim, Bb.shape[-1]), jnp.float32)
        final, prior = jax.lax.scan(
            step, init, (states.transpose(1, 0, 2, 3, 4),
                         chunk_decay.transpose(1, 0, 2)))
        prior = prior.transpose(1, 0, 2, 3, 4)                 # (b,nc,h,p,n)
        y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                             Cb, jnp.exp(dAc), prior)
        y = (y_intra + y_inter).reshape(b, s, h, pdim)
        return y, final

    def apply(self, p: Params, u: jax.Array) -> jax.Array:
        """Training / prefill: u (B, S, D); S is padded to the chunk
        multiple internally (trailing pad — causal, so outputs for real
        positions are unaffected)."""
        c = self.cfg
        s0 = u.shape[1]
        pad = (-s0) % c.chunk
        if pad:
            u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        z, xbc, dt = self._project(p, u)
        xbc, _ = self._conv(p, xbc)
        x, B, C = jnp.split(xbc, [c.d_inner, c.d_inner + c.d_state], axis=-1)
        x = x.reshape(*x.shape[:2], c.n_heads, c.head_dim)
        y, _ = self._ssd(x, dt, B, C, -jnp.exp(p["A_log"]))
        y = y.astype(u.dtype) + x * p["D"].astype(u.dtype)[:, None]
        y = y.reshape(*u.shape[:2], c.d_inner)
        y = RMSNorm(c.d_inner).apply(p["norm"], y * jax.nn.silu(z))
        out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(u.dtype))
        return out[:, :s0] if pad else out

    # -- O(1) decode ------------------------------------------------------------
    def init_cache(self, batch: int, dtype=None) -> dict:
        from .common import COMPUTE_DTYPE
        c = self.cfg
        return {
            "conv": jnp.zeros((batch, c.conv_width - 1,
                               c.d_inner + 2 * c.d_state),
                              dtype or COMPUTE_DTYPE),
            "ssm": jnp.zeros((batch, c.n_heads, c.head_dim, c.d_state),
                             jnp.float32),
        }

    def cache_axes(self) -> dict:
        return {"conv": ("batch", None, "mlp"),
                "ssm": ("batch", None, None, None)}

    def decode(self, p: Params, u: jax.Array, cache: dict):
        """u: (B, 1, D) -> (y, new_cache)."""
        c = self.cfg
        z, xbc, dt = self._project(p, u)
        xbc, conv_state = self._conv(p, xbc, cache["conv"])
        x, B, C = jnp.split(xbc, [c.d_inner, c.d_inner + c.d_state], axis=-1)
        x = x.reshape(-1, 1, c.n_heads, c.head_dim)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        dA = jnp.exp(dt[:, 0] * A)                              # (B,h)
        dBx = jnp.einsum("bn,bh,bhp->bhpn", B[:, 0].astype(jnp.float32),
                         dt[:, 0], x[:, 0].astype(jnp.float32))
        h = cache["ssm"] * dA[..., None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(jnp.float32), h)
        y = y[:, None].astype(u.dtype) + x * p["D"].astype(u.dtype)[:, None]
        y = y.reshape(-1, 1, c.d_inner)
        y = RMSNorm(c.d_inner).apply(p["norm"], y * jax.nn.silu(z))
        out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(u.dtype))
        return out, {"conv": conv_state, "ssm": h}
