"""Top-level models: decoder-only CausalLM (incl. VLM prefix-LM variant)
and EncDecLM (Whisper-style), with losses and decode steps.

Modality frontends are stubs per the assignment: ``[audio]``/``[vlm]``
configs take precomputed frame/patch embeddings as inputs
(``enc_emb`` / ``prefix_emb``); only the transformer backbone is real.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

from .blocks import LayerStack, _norm
from .common import COMPUTE_DTYPE, AxesTree, Embed, Params, dense_init


def _final_head_axes(cfg: ArchConfig):
    return ("embed", "vocab")


@dataclasses.dataclass(frozen=True)
class CausalLM:
    cfg: ArchConfig

    @property
    def stack(self) -> LayerStack:
        return LayerStack(self.cfg, self.cfg.n_layers)

    def init(self, key) -> Params:
        c = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        emb = Embed(c.padded_vocab, c.d_model, scale_by_sqrt_dim=c.scale_embed_sqrt_d)
        p = {"embed": emb.init(k1),
             "stack": self.stack.init(k2),
             "final_norm": _norm(c).init(k3)}
        if not c.tie_embeddings:
            p["lm_head"] = {"kernel": dense_init(k4, (c.d_model, c.padded_vocab))}
        return p

    def axes(self) -> AxesTree:
        c = self.cfg
        emb = Embed(c.padded_vocab, c.d_model)
        a = {"embed": emb.axes(),
             "stack": self.stack.axes(),
             "final_norm": _norm(c).axes()}
        if not c.tie_embeddings:
            a["lm_head"] = {"kernel": _final_head_axes(c)}
        return a

    def _logits(self, p: Params, x: jax.Array) -> jax.Array:
        c = self.cfg
        if c.tie_embeddings:
            return Embed(c.padded_vocab, c.d_model).attend(p["embed"], x)
        return jnp.einsum("...d,dv->...v", x.astype(jnp.float32),
                          p["lm_head"]["kernel"].astype(jnp.float32))

    def apply(self, p: Params, tokens: jax.Array, *, prefix_emb=None,
              remat: bool = True):
        """tokens: (B, S) int32; prefix_emb: (B, P, D) for VLM prefixes.
        Returns (logits over the token positions, aux_loss)."""
        c = self.cfg
        emb = Embed(c.padded_vocab, c.d_model, scale_by_sqrt_dim=c.scale_embed_sqrt_d)
        x = emb.apply(p["embed"], tokens)
        prefix_len = None
        if prefix_emb is not None:
            x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
            prefix_len = prefix_emb.shape[1]
        x, aux = self.stack.apply(p["stack"], x, prefix_len=prefix_len,
                                  remat=remat)
        x = _norm(c).apply(p["final_norm"], x)
        if prefix_emb is not None:
            x = x[:, prefix_len:]
        return self._logits(p, x), aux

    # -- decode -------------------------------------------------------------------
    def init_caches(self, batch: int, max_len: int):
        return self.stack.init_caches(batch, max_len)

    def cache_axes(self):
        return self.stack.cache_axes()

    def decode_step(self, p: Params, token: jax.Array, caches,
                    pos: jax.Array):
        """token: (B, 1) -> (logits (B,1,V) fp32, new caches)."""
        c = self.cfg
        emb = Embed(c.padded_vocab, c.d_model, scale_by_sqrt_dim=c.scale_embed_sqrt_d)
        x = emb.apply(p["embed"], token)
        x, caches = self.stack.decode(p["stack"], x, caches, pos)
        x = _norm(c).apply(p["final_norm"], x)
        return self._logits(p, x), caches


@dataclasses.dataclass(frozen=True)
class EncDecLM:
    """Whisper-style: bidirectional encoder over stub frame embeddings,
    causal decoder with cross-attention."""
    cfg: ArchConfig

    @property
    def encoder(self) -> LayerStack:
        return LayerStack(self.cfg, self.cfg.enc_layers, causal=False)

    @property
    def decoder(self) -> LayerStack:
        return LayerStack(self.cfg, self.cfg.n_layers, with_cross=True)

    def init(self, key) -> Params:
        c = self.cfg
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        return {"embed": Embed(c.padded_vocab, c.d_model).init(k1),
                "encoder": self.encoder.init(k2),
                "enc_norm": _norm(c).init(k3),
                "decoder": self.decoder.init(k4),
                "final_norm": _norm(c).init(k5)}

    def axes(self) -> AxesTree:
        c = self.cfg
        return {"embed": Embed(c.padded_vocab, c.d_model).axes(),
                "encoder": self.encoder.axes(),
                "enc_norm": _norm(c).axes(),
                "decoder": self.decoder.axes(),
                "final_norm": _norm(c).axes()}

    def encode(self, p: Params, enc_emb: jax.Array, remat: bool = True):
        x, _ = self.encoder.apply(p["encoder"], enc_emb.astype(COMPUTE_DTYPE),
                                  remat=remat)
        return _norm(self.cfg).apply(p["enc_norm"], x)

    def apply(self, p: Params, enc_emb: jax.Array, tokens: jax.Array,
              remat: bool = True):
        c = self.cfg
        memory = self.encode(p, enc_emb, remat=remat)
        x = Embed(c.padded_vocab, c.d_model).apply(p["embed"], tokens)
        x, aux = self.decoder.apply(p["decoder"], x, memory=memory,
                                    remat=remat)
        x = _norm(c).apply(p["final_norm"], x)
        logits = Embed(c.padded_vocab, c.d_model).attend(p["embed"], x)
        return logits, aux

    def init_caches(self, batch: int, max_len: int):
        return self.decoder.init_caches(batch, max_len)

    def cache_axes(self):
        return self.decoder.cache_axes()

    def decode_step(self, p: Params, token: jax.Array, caches,
                    pos: jax.Array, memory: jax.Array):
        c = self.cfg
        x = Embed(c.padded_vocab, c.d_model).apply(p["embed"], token)
        x, caches = self.decoder.decode(p["decoder"], x, caches, pos,
                                        memory=memory.astype(x.dtype))
        x = _norm(c).apply(p["final_norm"], x)
        return Embed(c.padded_vocab, c.d_model).attend(p["embed"], x), caches


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def lm_loss(logits: jax.Array, tokens: jax.Array, aux: jax.Array,
            z_loss: float = 1e-4):
    """Next-token cross-entropy (+ router aux + z-loss).  logits fp32."""
    targets = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    tgt = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    nll = (logz - tgt).mean()
    zl = z_loss * jnp.square(logz).mean()
    return nll + zl + aux, {"nll": nll, "z_loss": zl, "aux": aux}


def make_model(cfg: ArchConfig):
    if cfg.arch_type == "encdec":
        return EncDecLM(cfg)
    return CausalLM(cfg)
