"""Dense FFN variants: SwiGLU / GeGLU / GELU (+bias) — LLaMA/Qwen/Gemma/
Whisper styles."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import AxesTree, Params, dense_init


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    activation: str = "silu"     # silu | gelu | gelu_tanh
    gated: bool = True           # SwiGLU/GeGLU when True
    use_bias: bool = False


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=False),
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True)}[name]


@dataclasses.dataclass(frozen=True)
class MLP:
    cfg: MLPConfig

    def init(self, key) -> Params:
        c = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        p = {"w_up": dense_init(k1, (c.d_model, c.d_ff)),
             "w_down": dense_init(k2, (c.d_ff, c.d_model))}
        if c.gated:
            p["w_gate"] = dense_init(k3, (c.d_model, c.d_ff))
        if c.use_bias:
            p["b_up"] = jnp.zeros((c.d_ff,))
            p["b_down"] = jnp.zeros((c.d_model,))
        return p

    def axes(self) -> AxesTree:
        c = self.cfg
        a = {"w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
        if c.gated:
            a["w_gate"] = ("embed", "mlp")
        if c.use_bias:
            a.update({"b_up": ("mlp",), "b_down": ("embed",)})
        return a

    def apply(self, p: Params, x: jax.Array) -> jax.Array:
        c = self.cfg
        up = jnp.einsum("...d,df->...f", x, p["w_up"].astype(x.dtype))
        if c.use_bias:
            up = up + p["b_up"].astype(up.dtype)
        if c.gated:
            gate = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
            h = _act(c.activation)(gate) * up
        else:
            h = _act(c.activation)(up)
        y = jnp.einsum("...f,fd->...d", h, p["w_down"].astype(x.dtype))
        if c.use_bias:
            y = y + p["b_down"].astype(y.dtype)
        return y
