"""Model substrate: params-as-pytrees, logical sharding axes, norms, RoPE.

No flax — modules are plain dataclasses with ``init(key) -> params`` and
``apply(params, ...)``; a parallel ``axes()`` tree carries *logical* axis
names per parameter dimension (e.g. ("embed", "mlp")), mapped to mesh axes
by :mod:`repro.parallel.sharding` at lowering time.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any        # nested dict of jnp arrays
AxesTree = Any      # same structure, leaves = tuple[str | None, ...]

# Compute dtype policy: params live in fp32, compute runs in bf16 (matmuls
# accumulate fp32 on the MXU), logits/losses in fp32.
COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


def dense_init(key, shape, in_axis: int = 0, scale: float = 1.0,
               dtype=PARAM_DTYPE):
    """Truncated-normal fan-in init (variance-scaling, as in T5/MaxText)."""
    fan_in = shape[in_axis]
    std = scale / np.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)
            ).astype(dtype)


def embed_init(key, shape, dtype=PARAM_DTYPE):
    return (jax.random.normal(key, shape) * 1.0).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RMSNorm:
    dim: int
    eps: float = 1e-6
    zero_centered: bool = False   # gemma-style (1 + g) scaling

    def init(self, key) -> Params:
        del key
        return {"scale": jnp.zeros((self.dim,), PARAM_DTYPE)
                if self.zero_centered else jnp.ones((self.dim,), PARAM_DTYPE)}

    def axes(self) -> AxesTree:
        return {"scale": ("embed",)}

    def apply(self, p: Params, x: jax.Array) -> jax.Array:
        # dtype discipline (§Perf H2): only the reduced statistic runs in
        # fp32; the full tensor stays in its compute dtype so TP
        # all-reduces / CP all-gathers around norms move bf16, not fp32.
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        inv = jax.lax.rsqrt(var + self.eps).astype(x.dtype)
        scale = p["scale"].astype(jnp.float32)
        if self.zero_centered:
            scale = 1.0 + scale
        return x * inv * scale.astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class LayerNorm:
    dim: int
    eps: float = 1e-5

    def init(self, key) -> Params:
        del key
        return {"scale": jnp.ones((self.dim,), PARAM_DTYPE),
                "bias": jnp.zeros((self.dim,), PARAM_DTYPE)}

    def axes(self) -> AxesTree:
        return {"scale": ("embed",), "bias": ("embed",)}

    def apply(self, p: Params, x: jax.Array) -> jax.Array:
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + self.eps)
        # stats fp32, tensor stays in compute dtype (see RMSNorm note)
        y = (x - mu.astype(x.dtype)) * inv.astype(x.dtype)
        return (y * p["scale"].astype(x.dtype)
                + p["bias"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Embed:
    vocab: int
    dim: int
    scale_by_sqrt_dim: bool = False   # gemma family scales embeddings

    def init(self, key) -> Params:
        return {"embedding": embed_init(key, (self.vocab, self.dim))}

    def axes(self) -> AxesTree:
        return {"embedding": ("vocab", "embed")}

    def apply(self, p: Params, ids: jax.Array) -> jax.Array:
        x = jnp.take(p["embedding"].astype(COMPUTE_DTYPE), ids, axis=0)
        if self.scale_by_sqrt_dim:
            x = x * jnp.asarray(np.sqrt(self.dim), COMPUTE_DTYPE)
        return x

    def attend(self, p: Params, x: jax.Array) -> jax.Array:
        """Tied-embedding logits (fp32)."""
        return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                          p["embedding"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# Dense layers
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Dense:
    in_dim: int
    out_dim: int
    use_bias: bool = False
    in_axis_name: str | None = "embed"
    out_axis_name: str | None = "mlp"

    def init(self, key) -> Params:
        p = {"kernel": dense_init(key, (self.in_dim, self.out_dim))}
        if self.use_bias:
            p["bias"] = jnp.zeros((self.out_dim,), PARAM_DTYPE)
        return p

    def axes(self) -> AxesTree:
        a = {"kernel": (self.in_axis_name, self.out_axis_name)}
        if self.use_bias:
            a["bias"] = (self.out_axis_name,)
        return a

    def apply(self, p: Params, x: jax.Array) -> jax.Array:
        y = jnp.einsum("...d,df->...f", x, p["kernel"].astype(x.dtype))
        if self.use_bias:
            y = y + p["bias"].astype(y.dtype)
        return y


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float = 10000.0) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim), positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(head_dim, theta))          # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    sin, cos = jnp.sin(angles), jnp.cos(angles)               # (..., s, 1, hd/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(logits: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return logits
    return jnp.tanh(logits / cap) * cap


# ---------------------------------------------------------------------------
# tree utilities
# ---------------------------------------------------------------------------
def stack_layers(param_list: list[Params]) -> Params:
    """Stack per-layer param trees along a new leading 'layers' axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *param_list)


def prepend_layer_axis(axes: AxesTree) -> AxesTree:
    """Add the scanned 'layers' dimension to every axes tuple."""
    return jax.tree.map(lambda t: ("layers",) + tuple(t), axes,
                        is_leaf=lambda t: isinstance(t, tuple))


def count_params(params: Params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
