"""Attention: GQA/MQA, causal / sliding-window / prefix-LM / cross, RoPE,
QK-norm, logit soft-capping, decode with (optionally ring-buffered) KV cache.

Two execution paths, selected by size and backend:

* plain einsum attention (small S, decode) — XLA fuses the iota-derived
  masks, no S x S bool tensor is ever materialized explicitly;
* chunked online-softmax attention (``lax.scan`` over KV blocks) for long
  prefills — O(S_q * block) live memory, the XLA-level analogue of the
  Pallas flash kernel in ``repro.kernels.flash_attention`` (which is used
  on real TPU backends; the scan path keeps CPU dry-runs compilable).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .common import (COMPUTE_DTYPE, AxesTree, Dense, Params, RMSNorm,
                     apply_rope, dense_init, softcap)

NEG_INF = -2.3819763e38   # == float32 min-ish; matches common flash impls


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 10000.0
    use_rope: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False
    logit_softcap: float | None = None
    window: int | None = None          # sliding-window size (None = global)
    causal: bool = True                # False: encoder (bidirectional)
    cross: bool = False                # cross-attention (enc-dec decoder)
    query_scale: float | None = None   # default 1/sqrt(head_dim)

    @property
    def groups(self) -> int:
        return self.n_heads // self.n_kv


def _mask(q_pos, k_pos, cfg: AttentionConfig, prefix_len=None):
    """Additive mask from position vectors (no S x S bool materialized
    before fusion).  q_pos: (Sq,), k_pos: (Sk,) int32."""
    q = q_pos[:, None]
    k = k_pos[None, :]
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if cfg.causal and not cfg.cross:
        ok &= k <= q
        if prefix_len is not None:   # prefix-LM: bidirectional over prefix
            ok |= (k < prefix_len) & (q < prefix_len)
    if cfg.window is not None and not cfg.cross:
        ok &= (q - k) < cfg.window
    return jnp.where(ok, 0.0, NEG_INF)


@dataclasses.dataclass(frozen=True)
class Attention:
    cfg: AttentionConfig

    # -- params ---------------------------------------------------------------
    def init(self, key) -> Params:
        c = self.cfg
        kq, kk, kv, ko, kn = jax.random.split(key, 5)
        p = {
            "wq": dense_init(kq, (c.d_model, c.n_heads, c.head_dim)),
            "wk": dense_init(kk, (c.d_model, c.n_kv, c.head_dim)),
            "wv": dense_init(kv, (c.d_model, c.n_kv, c.head_dim)),
            "wo": dense_init(ko, (c.n_heads, c.head_dim, c.d_model),
                             in_axis=0),
        }
        if c.qkv_bias:
            p["bq"] = jnp.zeros((c.n_heads, c.head_dim))
            p["bk"] = jnp.zeros((c.n_kv, c.head_dim))
            p["bv"] = jnp.zeros((c.n_kv, c.head_dim))
        if c.qk_norm:
            p["q_norm"] = RMSNorm(c.head_dim).init(kn)
            p["k_norm"] = RMSNorm(c.head_dim).init(kn)
        return p

    def axes(self) -> AxesTree:
        c = self.cfg
        a = {
            "wq": ("embed", "heads", "head_dim"),
            "wk": ("embed", "kv_heads", "head_dim"),
            "wv": ("embed", "kv_heads", "head_dim"),
            "wo": ("heads", "head_dim", "embed"),
        }
        if c.qkv_bias:
            a.update({"bq": ("heads", "head_dim"),
                      "bk": ("kv_heads", "head_dim"),
                      "bv": ("kv_heads", "head_dim")})
        if c.qk_norm:
            a["q_norm"] = {"scale": ("head_dim",)}
            a["k_norm"] = {"scale": ("head_dim",)}
        return a

    # -- qkv -------------------------------------------------------------------
    def _qkv(self, p: Params, x, kv_x, positions, kv_positions):
        c = self.cfg
        q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(x.dtype))
        src = x if kv_x is None else kv_x
        k = jnp.einsum("bsd,dnh->bsnh", src, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dnh->bsnh", src, p["wv"].astype(x.dtype))
        if c.qkv_bias:
            q = q + p["bq"].astype(q.dtype)
            k = k + p["bk"].astype(k.dtype)
            v = v + p["bv"].astype(v.dtype)
        if c.qk_norm:
            qn, kn = RMSNorm(c.head_dim), RMSNorm(c.head_dim)
            q = qn.apply(p["q_norm"], q)
            k = kn.apply(p["k_norm"], k)
        if c.use_rope and not c.cross:
            q = apply_rope(q, positions, c.rope_theta)
            k = apply_rope(k, kv_positions, c.rope_theta)
        scale = c.query_scale or (1.0 / np.sqrt(c.head_dim))
        return q * jnp.asarray(scale, q.dtype), k, v

    # -- core attention ---------------------------------------------------------
    def _attend_dense(self, q, k, v, mask):
        """q: (B,Sq,Hq,hd) k/v: (B,Sk,Hkv,hd) mask: (Sq,Sk) additive."""
        c = self.cfg
        b, sq, _, hd = q.shape
        qg = q.reshape(b, sq, c.n_kv, c.groups, hd)
        logits = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
        logits = softcap(logits, c.logit_softcap) + mask
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
        return out.reshape(b, sq, c.n_heads, hd)

    def _attend_windowed(self, q, k, v, q_pos, k_pos,
                         block_q: int = 256):
        """Sliding-window attention with static KV slicing (§Perf H6).

        Each q-block attends to a fixed-width KV span (window + block_q,
        lane-aligned) gathered with a dynamic slice — masked-out blocks are
        never computed, so local layers cost O(S * window) instead of
        O(S^2) (21x less logit volume for gemma3 local layers at 32k).
        The Pallas flash kernel performs the same structural skipping on
        TPU; this is its XLA twin."""
        c = self.cfg
        b, sq, _, hd = q.shape
        sk = k.shape[1]
        pad_q = (-sq) % block_q
        if pad_q:
            q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
            q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=-(10 ** 9))
        nqb = q.shape[1] // block_q
        span = c.window + block_q
        span = min(-(-span // 128) * 128, sk)       # lane-align, cap at S
        qb4 = q.reshape(b, nqb, block_q, c.n_kv, c.groups * hd)
        qpb = q_pos.reshape(nqb, block_q)

        def one(args):
            qb, qp, idx = args
            qs = idx * block_q
            ks = jnp.clip(qs + block_q - span, 0, sk - span)
            kb = jax.lax.dynamic_slice_in_dim(k, ks, span, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, ks, span, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(k_pos, ks, span, axis=0)
            qg = qb.reshape(b, block_q, c.n_kv, c.groups, hd)
            logits = jnp.einsum("bskgh,btkh->bkgst", qg, kb
                                ).astype(jnp.float32)
            logits = softcap(logits, c.logit_softcap) + _mask(qp, kp, c)
            probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
            o = jnp.einsum("bkgst,btkh->bskgh", probs, vb)
            return o.reshape(b, block_q, c.n_kv, c.groups * hd)

        out = jax.lax.map(one, (qb4.swapaxes(0, 1), qpb,
                                jnp.arange(nqb)))
        out = out.swapaxes(0, 1).reshape(b, nqb * block_q, c.n_heads, hd)
        return out[:, :sq]

    def _attend_chunked(self, q, k, v, q_pos, k_pos, prefix_len,
                        block_k: int = 512):
        """Online-softmax over KV blocks; O(Sq*d) live memory."""
        c = self.cfg
        b, sq, _, hd = q.shape
        sk = k.shape[1]
        nblk = -(-sk // block_k)
        pad = nblk * block_k - sk
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            k_pos = jnp.pad(k_pos, (0, pad), constant_values=-(10 ** 9))
        qg = q.reshape(b, sq, c.n_kv, c.groups, hd)
        kb = k.reshape(b, nblk, block_k, c.n_kv, hd)
        vb = v.reshape(b, nblk, block_k, c.n_kv, hd)
        pb = k_pos.reshape(nblk, block_k)

        def step(carry, blk):
            m, l, acc = carry
            kc, vc, pc = blk
            logits = jnp.einsum("bskgh,btkh->bkgst", qg, kc
                                ).astype(jnp.float32)
            logits = softcap(logits, c.logit_softcap)
            logits = logits + _mask(q_pos, pc, c, prefix_len)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            # §Perf H7: probabilities in compute dtype after the fp32
            # max-subtraction — halves the dominant score-tensor traffic
            # of this XLA twin (the Pallas kernel keeps them in VMEM).
            pexp = jnp.exp(logits - m_new[..., None]).astype(q.dtype)
            l_new = l * alpha + pexp.sum(axis=-1, dtype=jnp.float32)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgst,btkh->bkgsh", pexp, vc).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, c.n_kv, c.groups, sq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, c.n_kv, c.groups, sq), jnp.float32)
        a0 = jnp.zeros((b, c.n_kv, c.groups, sq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), pb))
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        out = out.astype(q.dtype).transpose(0, 3, 1, 2, 4)
        return out.reshape(b, sq, c.n_heads, hd)

    # -- public entry points ------------------------------------------------------
    def apply(self, p: Params, x, *, positions=None, kv_x=None,
              kv_positions=None, prefix_len=None,
              chunked_threshold: int = 2048) -> jax.Array:
        """Training / prefill attention over full sequences."""
        c = self.cfg
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.arange(s)[None, :].repeat(b, 0)
        if kv_positions is None:
            kv_positions = (positions if kv_x is None else
                            jnp.arange(kv_x.shape[1])[None, :].repeat(b, 0))
        q, k, v = self._qkv(p, x, kv_x, positions, kv_positions)
        from repro.parallel.context import constrain, get_ctx
        ctx = get_ctx()
        tp_size = ctx.mesh.shape[ctx.tp] if ctx.mesh is not None else 1
        cp = ctx.cp_attention and q.shape[1] % max(tp_size, 1) == 0
        if cp:
            # Context-parallel attention: query-seq over the model axis,
            # K/V replicated — head-count-agnostic TP for attention.
            q = constrain(q, ctx.dp, ctx.tp, None, None)
            k = constrain(k, ctx.dp, None, None, None)
            v = constrain(v, ctx.dp, None, None, None)
        q_pos1, k_pos1 = positions[0], kv_positions[0]
        sk = k.shape[1]
        # Windowed slicing only pays once the window is a small fraction of
        # the sequence (measured crossover ~4x; at S=4k the chunked scan is
        # cheaper, at 32k the static slice is 3x on memory+collectives).
        if (c.window is not None and not c.cross and prefix_len is None
                and sk >= 4 * (c.window + 512)):
            out = self._attend_windowed(q, k, v, q_pos1, k_pos1)
        elif sk > chunked_threshold:
            out = self._attend_chunked(q, k, v, q_pos1, k_pos1, prefix_len)
        else:
            mask = _mask(q_pos1, k_pos1, c, prefix_len)
            out = self._attend_dense(q, k, v, mask)
        if cp:
            out = constrain(out, ctx.dp, None, None, None)
        return jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(out.dtype))

    def decode(self, p: Params, x, cache: dict, pos: jax.Array,
               kv_memory=None) -> tuple[jax.Array, dict]:
        """Single-token decode.  x: (B,1,D); cache {'k','v'}: (B,Smax,Hkv,hd);
        pos: scalar int32 — absolute position of the new token.

        Sliding-window layers pass caches with Smax == window (ring buffer);
        cross-attention layers pass ``kv_memory`` (already projected memory
        is not cached here — simplicity over decode speed for the stub)."""
        c = self.cfg
        b = x.shape[0]
        positions = jnp.full((b, 1), pos, jnp.int32)
        if c.cross:
            kv_pos = jnp.arange(kv_memory.shape[1])[None].repeat(b, 0)
            q, k, v = self._qkv(p, x, kv_memory, positions, kv_pos)
            logits_mask = 0.0
            k_cache, v_cache = k, v
            k_pos = kv_pos[0]
        else:
            q, k, v = self._qkv(p, x, None, positions, positions)
            smax = cache["k"].shape[1]
            slot = pos % smax if c.window is not None else pos
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
            cache = {"k": k_cache, "v": v_cache}
            # positions stored in the cache: ring for window layers
            idx = jnp.arange(smax)
            if c.window is not None:
                wrap = (pos // smax) * smax
                k_pos = jnp.where(idx <= pos % smax, wrap + idx,
                                  wrap - smax + idx)
            else:
                k_pos = idx
            valid = (k_pos >= 0) & (k_pos <= pos)
            if c.window is not None:
                valid &= (pos - k_pos) < c.window
            logits_mask = jnp.where(valid, 0.0, NEG_INF)[None, None, None,
                                                         None, :]
        qg = q.reshape(b, 1, c.n_kv, c.groups, c.head_dim)
        logits = jnp.einsum("bskgh,btkh->bkgst", qg, k_cache
                            ).astype(jnp.float32)
        logits = softcap(logits, c.logit_softcap) + logits_mask
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgst,btkh->bskgh", probs, v_cache)
        out = out.reshape(b, 1, c.n_heads, c.head_dim)
        y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(out.dtype))
        return y, cache

    def init_cache(self, batch: int, max_len: int,
                   dtype=COMPUTE_DTYPE) -> dict:
        c = self.cfg
        n = min(max_len, c.window) if c.window is not None else max_len
        shape = (batch, n, c.n_kv, c.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    def cache_axes(self) -> dict:
        kv = ("batch", "kv_seq", "kv_heads", "head_dim")
        return {"k": kv, "v": kv}
