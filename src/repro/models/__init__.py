from .attention import Attention, AttentionConfig
from .blocks import DecoderLayer, LayerStack
from .common import COMPUTE_DTYPE, Embed, LayerNorm, RMSNorm, count_params
from .lm import CausalLM, EncDecLM, lm_loss, make_model
from .mlp import MLP, MLPConfig
from .moe import MoE, MoEConfig, bucket_by
from .rglru import RGLRU, RecurrentBlock, RGLRUConfig
from .ssm import Mamba2, SSMConfig

__all__ = ["Attention", "AttentionConfig", "DecoderLayer", "LayerStack",
           "COMPUTE_DTYPE", "Embed", "LayerNorm", "RMSNorm", "count_params",
           "CausalLM", "EncDecLM", "lm_loss", "make_model", "MLP",
           "MLPConfig", "MoE", "MoEConfig", "bucket_by", "RGLRU",
           "RecurrentBlock", "RGLRUConfig", "Mamba2", "SSMConfig"]
