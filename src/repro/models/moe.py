"""Mixture-of-Experts with expert parallelism — the end-to-end showcase of
the paper's technique.

Token dispatch to expert owners is a bulk all-to-all over the "model" mesh
axis; exactly the communication pattern NoM schedules.  Three dispatch
implementations are selectable per config / CLI:

* ``"nom"``   — NOM-scheduled ``ppermute`` rounds (conflict-free TDM slots
                over the ICI ring; see ``repro.core.nom_collectives``),
* ``"xla"``   — opaque ``lax.all_to_all`` (the "shared bus" baseline),
* ``"einsum"``— GSPMD-auto dense one-hot dispatch (no shard_map; used for
                tiny smoke configs and as a compiler-managed reference).

Routing is top-k softmax with capacity-factor token dropping (GShard
style); tokens are bucketed *by expert* at the source so the receive side
gets contiguous per-expert blocks and runs plain per-expert GEMMs.

Sharding contract (shard_map paths): expert weights enter the body already
sharded over the EP axis (each device holds its n_experts/ep slice); the
router is replicated.  Prefill/train shards the sequence dim over the EP
axis; decode (S == 1) uses replicated dispatch — every EP rank runs its own
experts over all local tokens and contributions are psum-combined, avoiding
an all-to-all that a single token cannot feed.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.fabric import FabricCluster, NomFabric
from repro.core.nom_collectives import nom_all_to_all
from repro.core.scheduler import TransferRequest, reduce_request
from repro.core.topology import StackedTopology, make_topology
from repro.parallel.compat import get_ambient_mesh, shard_map

from .common import AxesTree, Params, dense_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.5
    norm_topk: bool = True          # renormalize top-k probs (Qwen-style)
    dispatch: str = "nom"           # nom | xla | einsum
    ep_axis: str = "model"
    dp_axes: tuple = ("data",)
    aux_loss_weight: float = 0.01


def bucket_by(ids: jax.Array, n_buckets: int, capacity: int):
    """Order-preserving bucket positions with capacity dropping.

    ids: (N,) int32 in [0, n_buckets). Returns (pos, keep): pos[i] is the
    slot of item i within bucket ids[i]; keep[i] False if it overflowed.
    """
    onehot = jax.nn.one_hot(ids, n_buckets, dtype=jnp.int32)   # (N, B)
    pos_all = jnp.cumsum(onehot, axis=0) - 1                   # (N, B)
    pos = jnp.take_along_axis(pos_all, ids[:, None], axis=1)[:, 0]
    keep = pos < capacity
    return pos, keep


def _expert_ffn(h, wg, wu, wd):
    """h: (E_loc, C, D); weights: (E_loc, D, F) / (E_loc, F, D)."""
    act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, wg.astype(h.dtype)))
    act = act * jnp.einsum("ecd,edf->ecf", h, wu.astype(h.dtype))
    return jnp.einsum("ecf,efd->ecd", act, wd.astype(h.dtype))


@dataclasses.dataclass(frozen=True)
class MoE:
    cfg: MoEConfig

    def init(self, key) -> Params:
        c = self.cfg
        kr, kg, ku, kd = jax.random.split(key, 4)
        return {
            "router": dense_init(kr, (c.d_model, c.n_experts)),
            "w_gate": dense_init(kg, (c.n_experts, c.d_model, c.d_ff),
                                 in_axis=1),
            "w_up": dense_init(ku, (c.n_experts, c.d_model, c.d_ff),
                               in_axis=1),
            "w_down": dense_init(kd, (c.n_experts, c.d_ff, c.d_model),
                                 in_axis=1),
        }

    def axes(self) -> AxesTree:
        return {"router": ("embed", None),
                "w_gate": ("experts", "embed", "mlp"),
                "w_up": ("experts", "embed", "mlp"),
                "w_down": ("experts", "mlp", "embed")}

    def _param_specs(self):
        c = self.cfg
        return {"router": P(None, None),
                "w_gate": P(c.ep_axis, None, None),
                "w_up": P(c.ep_axis, None, None),
                "w_down": P(c.ep_axis, None, None)}

    # -- routing ----------------------------------------------------------------
    def _route(self, router_w, x2d: jax.Array):
        """x2d: (T, D) -> (weights (T,k), experts (T,k), aux_loss)."""
        c = self.cfg
        logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                            router_w.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        w, e = lax.top_k(probs, c.top_k)                       # (T,k)
        if c.norm_topk:
            w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        # GShard load-balancing auxiliary loss.
        me = probs.mean(axis=0)                                # (E,)
        ce = jnp.zeros((c.n_experts,)).at[e.reshape(-1)].add(
            jnp.ones_like(e.reshape(-1), jnp.float32))
        ce = ce / jnp.maximum(ce.sum(), 1.0)
        aux = c.n_experts * jnp.sum(me * ce) * c.aux_loss_weight
        return w.astype(x2d.dtype), e, aux

    # -- shared bucketing ----------------------------------------------------------
    def _bucketize(self, x2d, flat_e, cap):
        c = self.cfg
        t = x2d.shape[0]
        pos, keep = bucket_by(flat_e, c.n_experts, cap)
        tok = jnp.repeat(jnp.arange(t), c.top_k)
        send = jnp.zeros((c.n_experts, cap + 1, x2d.shape[1]), x2d.dtype)
        slot = jnp.where(keep, pos, cap)
        send = send.at[flat_e, slot].set(x2d[tok], mode="drop")
        return send[:, :cap], pos, keep, tok

    def _combine(self, buf, flat_e, pos, keep, tok, w, t, d, cap, dtype):
        gathered = buf[flat_e, jnp.minimum(pos, cap - 1)]       # (t*k, D)
        contrib = gathered * (w.reshape(-1, 1)
                              * keep[:, None]).astype(gathered.dtype)
        return jnp.zeros((t, d), dtype).at[tok].add(contrib)

    # -- host-side dispatch transfer planning ---------------------------------------
    def _axis_size(self, name: str) -> int:
        """Ambient-mesh axis size (1 when no mesh / unknown axis)."""
        mesh = get_ambient_mesh()
        try:
            return int(dict(mesh.shape)[name])
        except Exception:
            return 1

    def _ep_size(self) -> int:
        """EP axis size from the ambient mesh (1 when none installed)."""
        return self._axis_size(self.cfg.ep_axis)

    def plan_dispatch(self, p: Params, x: jax.Array, ep: int | None = None,
                      policy: str = "arrival"):
        """Expert-dispatch transfer plan from the bucketized routing.

        Mirrors what :meth:`_ep_body` puts on the wire: the router runs
        eagerly on the host, tokens are bucketed per source EP rank with
        the same capacity rule, and every non-empty (src_rank, dst_rank)
        block becomes a :class:`TransferRequest` — dispatch direction plus
        the combine return path — scheduled through the MoE's
        :class:`~repro.core.fabric.NomFabric` session on the ``(ep,)``
        EP ring, the same discipline as reshard.  Returns
        ``(TransferPlan, ScheduleReport)`` and stores them for
        :attr:`last_dispatch_report`.

        This is the *standalone* planner (plan without running the
        model); eager :meth:`apply` calls do NOT come through here — they
        reuse the traced routing via the block-counts aux output of
        ``_ep_body`` (:meth:`_plan_from_blocks`), so the router runs
        exactly once per forward.

        The plan covers one data-parallel replica's EP ring (each dp
        replica runs an identical, independent a2a): the batch dim is
        divided by the dp axis size so per-rank token counts and the
        capacity match what each device's ``_ep_body`` actually sends.

        Requires concrete (non-traced) inputs; ``ep`` defaults to the
        ambient mesh's EP-axis size.
        """
        ep = self._ep_size() if ep is None else int(ep)
        blocks, d, itemsize = self._dispatch_blocks(p, x, ep)
        return self._plan_from_blocks(blocks, d, itemsize, policy)

    def _dispatch_blocks(self, p: Params, x: jax.Array,
                         ep: int) -> tuple[np.ndarray, int, int]:
        """Host-side routing shared by the standalone planners: run the
        router per EP rank with the body's capacity rule and return the
        ``(ep, ep)`` kept-token block matrix plus the token feature dim
        and itemsize — the wire description both :meth:`plan_dispatch`
        (device ring) and :meth:`plan_dispatch_stacked` (bank level,
        multi-stack) schedule from."""
        c = self.cfg
        if isinstance(x, jax.core.Tracer):
            raise TypeError("dispatch planning needs concrete inputs "
                            "(host-side planning cannot run under jit)")
        e_loc = max(1, c.n_experts // ep)
        dp = 1
        for ax in c.dp_axes:
            dp *= self._axis_size(ax)
        b, s, d = x.shape
        b_loc = max(1, b // dp)
        x = x[:b_loc]
        s_loc = max(1, s // ep)
        itemsize = jnp.dtype(x.dtype).itemsize
        blocks = np.zeros((ep, ep), np.int64)   # kept tokens per (src, dst)
        for r in range(ep):
            x_loc = np.asarray(x[:, r * s_loc:(r + 1) * s_loc]
                               ).reshape(-1, d)
            t_loc = x_loc.shape[0]
            _w, e, _aux = self._route(p["router"], jnp.asarray(x_loc))
            flat_e = np.asarray(e).reshape(-1)
            cap = max(1, int(c.capacity_factor * t_loc * c.top_k
                             / c.n_experts))
            _pos, keep = bucket_by(jnp.asarray(flat_e), c.n_experts, cap)
            kept = np.bincount(flat_e[np.asarray(keep)],
                               minlength=c.n_experts)
            for expert, n_tok in enumerate(kept):
                blocks[r, expert // e_loc] += int(n_tok)
        return blocks, d, itemsize

    def plan_dispatch_stacked(self, p: Params, x: jax.Array,
                              topology: StackedTopology,
                              ep: int | None = None,
                              policy: str = "arrival"):
        """Expert-dispatch plan when the EP ring spans a multi-stack NoM.

        Same host-side routing as :meth:`plan_dispatch`, but instead of
        the abstract ``(ep,)`` device ring each EP rank is homed on a
        bank of a :class:`~repro.core.topology.StackedTopology` — rank
        ``r`` on stack ``r % n_stacks`` (ranks striped across cubes, the
        expert-placement a capacity-balanced deployment uses), bank
        ``r // n_stacks`` within the stack's mesh.  Every non-empty
        (src, dst) block then becomes a bank-level request through a
        per-topology :class:`~repro.core.fabric.FabricCluster`:
        same-stack blocks ride that stack's TDM mesh, cross-stack blocks
        negotiate two-phase circuits over the SerDes links.  Returns
        ``(results, report)``; ``report.n_cross_stack`` counts the
        inter-cube share, and :attr:`last_dispatch_report` is updated."""
        ep = self._ep_size() if ep is None else int(ep)
        blocks, d, itemsize = self._dispatch_blocks(p, x, ep)
        ns = topology.n_stacks

        def home(r: int) -> tuple[int, int]:
            stack = r % ns
            return stack, (r // ns) % topology.stacks[stack].n_nodes

        reqs = []
        for r in range(ep):
            for q in range(ep):
                if r == q or not blocks[r, q]:
                    continue
                (rs, rn), (qs, qn) = home(r), home(q)
                if (rs, rn) == (qs, qn):
                    continue         # two ranks folded onto one bank
                nbytes = int(blocks[r, q]) * d * itemsize
                reqs.append(TransferRequest(
                    src=rn, dst=qn, nbytes=nbytes, tag=("dispatch", r, q),
                    src_stack=rs, dst_stack=qs))
                reqs.append(TransferRequest(
                    src=qn, dst=rn, nbytes=nbytes, tag=("combine", q, r),
                    src_stack=qs, dst_stack=rs))
        results, report = self._stacked_cluster(topology).schedule(
            reqs, policy=policy)
        object.__setattr__(self, "_last_dispatch", (results, report))
        return results, report

    def plan_combine(self, p: Params, x: jax.Array, ep: int | None = None,
                     policy: str = "arrival"):
        """Expert-output combine as compute-class reduce traffic.

        The return leg of the a2a is a *sum*: destination rank ``r`` adds
        the expert outputs coming back from every rank it dispatched
        tokens to.  :meth:`plan_dispatch` models that leg as plain
        ``("combine", q, r)`` copies; this planner instead emits one
        fan-in :func:`~repro.core.scheduler.reduce_request` per
        destination rank — sources are the ranks with a non-empty
        ``blocks[r, q]`` block, the merge happens in the destination
        bank's ALU, and no copy-then-compute round trip touches the
        processor.

        Wire model: the fan-in streams every operand through the shared
        destination port, so the request is sized to the *widest*
        incoming block (``max_q blocks[r, q] * d * itemsize``) — slot
        occupancy is set by the longest operand stream, narrower
        operands ride the same circuit windows.

        Ranks are homed identity-mapped onto a square single-stack mesh
        (rank ``r`` = bank ``r``) and scheduled through a per-``ep``
        bank-level TDM fabric (:meth:`_combine_fabric` — the
        rounds-backend :meth:`_dispatch_fabric` cannot carry reduce, by
        design).  Returns ``(results, report)`` with
        ``report.n_reduce`` counting the fan-ins, and updates
        :attr:`last_dispatch_report`.
        """
        ep = self._ep_size() if ep is None else int(ep)
        blocks, d, itemsize = self._dispatch_blocks(p, x, ep)
        reqs = []
        for r in range(ep):
            srcs = [q for q in range(ep) if q != r and blocks[r, q]]
            if not srcs:
                continue
            widest = int(max(blocks[r, q] for q in srcs)) * d * itemsize
            reqs.append(reduce_request(srcs, r, nbytes=widest,
                                       tag=("combine_reduce", r)))
        results, report = self._combine_fabric(ep).schedule(
            reqs, policy=policy)
        object.__setattr__(self, "_last_dispatch", (results, report))
        return results, report

    def _combine_fabric(self, ep: int) -> NomFabric:
        """Per-EP-size bank-level fabric for :meth:`plan_combine`: a
        square mesh just large enough to home every rank on its own
        bank, kept across forwards like :meth:`_dispatch_fabric`."""
        fabrics = getattr(self, "_reduce_fabrics", None)
        if fabrics is None:
            fabrics = {}
            object.__setattr__(self, "_reduce_fabrics", fabrics)
        if ep not in fabrics:
            side = 1
            while side * side < ep:
                side += 1
            mesh = make_topology(1, mesh=(side, side, 1), vault_span_y=1)
            fabrics[ep] = NomFabric(mesh=mesh)
        return fabrics[ep]

    def _stacked_cluster(self, topology: StackedTopology) -> FabricCluster:
        """Per-topology :class:`FabricCluster` session for
        :meth:`plan_dispatch_stacked`, kept across forwards (same
        lifetime discipline as :meth:`_dispatch_fabric`)."""
        clusters = getattr(self, "_clusters", None)
        if clusters is None:
            clusters = {}
            object.__setattr__(self, "_clusters", clusters)
        if topology not in clusters:
            clusters[topology] = FabricCluster(topology=topology)
        return clusters[topology]

    def _dispatch_fabric(self, ep: int) -> NomFabric:
        """The MoE's dispatch-planning session: one rounds-backend
        :class:`NomFabric` per EP-ring size, kept across forwards so the
        dispatch telemetry accumulates (``fabric.telemetry()``)."""
        fabrics = getattr(self, "_fabrics", None)
        if fabrics is None:
            fabrics = {}
            object.__setattr__(self, "_fabrics", fabrics)
        if ep not in fabrics:
            fabrics[ep] = NomFabric(shape=(ep,), torus=True)
        return fabrics[ep]

    def _plan_from_blocks(self, blocks: np.ndarray, d: int, itemsize: int,
                          policy: str = "arrival"):
        """Schedule the EP-ring a2a from a (ep, ep) kept-token block
        matrix — the shared back half of :meth:`plan_dispatch` and of the
        traced-routing reuse path in :meth:`apply`."""
        ep = blocks.shape[0]
        reqs = []
        for r in range(ep):
            for q in range(ep):
                if r == q or not blocks[r, q]:
                    continue
                nbytes = int(blocks[r, q]) * d * itemsize
                reqs.append(TransferRequest(src=(r,), dst=(q,), nbytes=nbytes,
                                            tag=("dispatch", r, q)))
                reqs.append(TransferRequest(src=(q,), dst=(r,), nbytes=nbytes,
                                            tag=("combine", q, r)))
        plan, report = self._dispatch_fabric(ep).schedule(reqs, policy=policy)
        object.__setattr__(self, "_last_dispatch", (plan, report))
        return plan, report

    @property
    def last_dispatch_report(self):
        """ScheduleReport of the most recent dispatch plan (None before)."""
        last = getattr(self, "_last_dispatch", None)
        return None if last is None else last[1]

    # -- expert-parallel dispatch via all-to-all (train / prefill) -----------------
    def _ep_body(self, p: Params, x: jax.Array):
        """Per-device body; weights pre-sharded: w_* (E/ep, D, F).
        x: (b_loc, s_loc, D) — sequence sharded on the EP axis.

        Besides (y, aux_loss) the body returns its *dispatch block
        counts* — kept tokens per destination EP rank, shape (1, 1, ep) —
        as a third output: the traced routing made reusable, so eager
        ``apply`` refreshes the NoM dispatch plan without re-running the
        router on host (the double-routing ROADMAP item)."""
        c = self.cfg
        ep = lax.psum(1, c.ep_axis)
        if isinstance(ep, jax.Array):
            ep = int(ep)
        e_loc = c.n_experts // ep
        b, s, d = x.shape
        t = b * s
        x2d = x.reshape(t, d)
        w, e, aux = self._route(p["router"], x2d)
        flat_e = e.reshape(-1)
        cap = max(1, int(c.capacity_factor * t * c.top_k / c.n_experts))
        send, pos, keep, tok = self._bucketize(x2d, flat_e, cap)
        # Kept tokens per destination rank — the (src=me, dst) row of the
        # block matrix plan_dispatch would compute host-side.
        blocks = jnp.zeros((ep,), jnp.int32).at[flat_e // e_loc].add(
            keep.astype(jnp.int32))
        send = send.reshape(ep, e_loc * cap, d)
        a2a = (nom_all_to_all if c.dispatch == "nom" else
               lambda v, ax: lax.all_to_all(v, ax, 0, 0))
        recv = a2a(send, c.ep_axis)
        # recv[j]: tokens from rank j, bucketed for my e_loc experts.
        # (§Perf H5 refuted: contracting directly on a (ep, e_loc, cap, d)
        # layout regressed bytes 22% — XLA fuses these transposes into the
        # surrounding ops, the explicit einsum forced worse layouts.)
        h = recv.reshape(ep, e_loc, cap, d).transpose(1, 0, 2, 3)
        h = h.reshape(e_loc, ep * cap, d)
        y = _expert_ffn(h, p["w_gate"], p["w_up"], p["w_down"])
        y = y.reshape(e_loc, ep, cap, d).transpose(1, 0, 2, 3)
        y = y.reshape(ep, e_loc * cap, d)
        back = a2a(y, c.ep_axis).reshape(c.n_experts, cap, d)
        y_tok = self._combine(back, flat_e, pos, keep, tok, w, t, d, cap,
                              x.dtype)
        axes = tuple(c.dp_axes) + (c.ep_axis,)
        return (y_tok.reshape(b, s, d), lax.pmean(aux, axes),
                blocks.reshape(1, 1, ep))

    # -- replicated dispatch (decode: S == 1, batch < devices) ----------------------
    def _ep_body_replicated(self, p: Params, x: jax.Array):
        c = self.cfg
        ep = lax.psum(1, c.ep_axis)
        if isinstance(ep, jax.Array):
            ep = int(ep)
        e_loc = c.n_experts // ep
        b, s, d = x.shape
        t = b * s
        x2d = x.reshape(t, d)
        w, e, aux = self._route(p["router"], x2d)
        flat_e = e.reshape(-1)
        cap = max(1, int(c.capacity_factor * t * c.top_k
                         / max(1, c.n_experts // 4)))
        send, pos, keep, tok = self._bucketize(x2d, flat_e, cap)
        # Process only my expert slice; other ranks handle theirs.
        eid0 = lax.axis_index(c.ep_axis) * e_loc
        h = lax.dynamic_slice_in_dim(send, eid0, e_loc, axis=0)
        y = _expert_ffn(h, p["w_gate"], p["w_up"], p["w_down"])
        buf = jnp.zeros((c.n_experts, cap, d), x.dtype)
        buf = lax.dynamic_update_slice_in_dim(buf, y, eid0, axis=0)
        y_tok = self._combine(buf, flat_e, pos, keep, tok, w, t, d, cap,
                              x.dtype)
        y_tok = lax.psum(y_tok, c.ep_axis)
        axes = tuple(c.dp_axes) + (c.ep_axis,)
        return y_tok.reshape(b, s, d), lax.pmean(aux, axes)

    # -- GSPMD dense dispatch (reference / smoke path) -------------------------------
    def _einsum_body(self, p: Params, x: jax.Array):
        c = self.cfg
        b, s, d = x.shape
        t = b * s
        x2d = x.reshape(t, d)
        w, e, aux = self._route(p["router"], x2d)
        flat_e = e.reshape(-1)
        cap = max(1, int(c.capacity_factor * t * c.top_k / c.n_experts))
        buf, pos, keep, tok = self._bucketize(x2d, flat_e, cap)
        y = _expert_ffn(buf, p["w_gate"], p["w_up"], p["w_down"])
        y_tok = self._combine(y, flat_e, pos, keep, tok, w, t, d, cap,
                              x.dtype)
        return y_tok.reshape(b, s, d), aux

    def apply(self, p: Params, x: jax.Array) -> tuple[jax.Array, jax.Array]:
        """x: (B, S, D) global. Returns (y, aux_loss).

        Eager (non-traced) expert-parallel calls also refresh the NoM
        dispatch plan / :class:`ScheduleReport` — from the *traced*
        routing: ``_ep_body`` returns its dispatch block counts as an aux
        output, so the router runs exactly once per forward (no host-side
        re-route; skipped under jit, where the counts are not concrete).
        """
        c = self.cfg
        if c.dispatch == "einsum":
            return self._einsum_body(p, x)
        decode = x.shape[1] == 1
        x_spec = (P(c.dp_axes, None, None) if decode
                  else P(c.dp_axes, c.ep_axis, None))
        if decode:
            fn = shard_map(
                self._ep_body_replicated,
                in_specs=(self._param_specs(), x_spec),
                out_specs=(x_spec, P()),
                check_vma=False)
            return fn(p, x)
        fn = shard_map(
            self._ep_body,
            in_specs=(self._param_specs(), x_spec),
            out_specs=(x_spec, P(), P(c.dp_axes, c.ep_axis, None)),
            check_vma=False)
        y, aux, blocks = fn(p, x)
        if not isinstance(blocks, jax.core.Tracer) and self._ep_size() > 1:
            # blocks: (dp, ep, ep); dp replicas run identical independent
            # a2a rings — plan the first, as plan_dispatch does.
            self._plan_from_blocks(np.asarray(blocks[0], np.int64),
                                   d=x.shape[-1],
                                   itemsize=jnp.dtype(x.dtype).itemsize)
        return y, aux
