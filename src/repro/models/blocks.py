"""Layer composition: decoder layers (attn/SSM/RG-LRU mixers x MLP/MoE
ffns), sandwich norms, and the scanned layer stack.

Stacks are ``lax.scan``-over-groups: the repeating layer pattern (e.g.
gemma3's 5 local + 1 global, recurrentgemma's 2 recurrent + 1 local-attn)
forms one *group*; parameters are stacked along a leading "layers" axis and
the group body is remat-ed — one HLO body regardless of depth, which keeps
512-device dry-run compiles tractable and is the standard production trick
(MaxText does the same).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerKind

from .attention import Attention, AttentionConfig
from .common import (AxesTree, LayerNorm, Params, RMSNorm, prepend_layer_axis,
                     stack_layers)
from .mlp import MLP, MLPConfig
from .moe import MoE, MoEConfig
from .rglru import RecurrentBlock, RGLRUConfig
from .ssm import Mamba2, SSMConfig


def _prepend_none(axes):
    return jax.tree.map(lambda t: (None,) + tuple(t), axes,
                        is_leaf=lambda t: isinstance(t, tuple))


def _norm(cfg: ArchConfig):
    if cfg.norm_type == "layer":
        return LayerNorm(cfg.d_model)
    return RMSNorm(cfg.d_model, zero_centered=cfg.zero_centered_norm)


def make_mixer(cfg: ArchConfig, kind: LayerKind, causal: bool = True,
               cross: bool = False):
    if kind.mixer == "attn":
        return Attention(AttentionConfig(
            d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            head_dim=cfg.resolved_head_dim,
            rope_theta=kind.rope_theta or cfg.rope_theta,
            use_rope=cfg.use_rope, qkv_bias=cfg.qkv_bias,
            qk_norm=cfg.qk_norm, logit_softcap=cfg.logit_softcap,
            window=kind.window, causal=causal, cross=cross))
    if kind.mixer == "ssm":
        return Mamba2(SSMConfig(d_model=cfg.d_model, d_state=cfg.ssm_state,
                                head_dim=cfg.ssm_head_dim,
                                chunk=cfg.ssm_chunk))
    if kind.mixer == "rglru":
        return RecurrentBlock(RGLRUConfig(d_model=cfg.d_model,
                                          lru_width=cfg.lru_width))
    raise ValueError(kind.mixer)


def make_ffn(cfg: ArchConfig, kind: LayerKind):
    if kind.ffn == "mlp":
        return MLP(MLPConfig(cfg.d_model, cfg.d_ff, activation=cfg.act,
                             gated=cfg.gated_mlp, use_bias=cfg.mlp_bias))
    if kind.ffn == "moe":
        return MoE(MoEConfig(cfg.d_model, cfg.moe_dff, cfg.n_experts,
                             cfg.top_k, norm_topk=cfg.norm_topk,
                             dispatch=cfg.moe_dispatch))
    return None


@dataclasses.dataclass(frozen=True)
class DecoderLayer:
    cfg: ArchConfig
    kind: LayerKind
    causal: bool = True
    with_cross: bool = False     # enc-dec decoder layers

    def _mods(self):
        mixer = make_mixer(self.cfg, self.kind, causal=self.causal)
        ffn = make_ffn(self.cfg, self.kind)
        cross = (make_mixer(self.cfg, LayerKind("attn"), cross=True)
                 if self.with_cross else None)
        return mixer, ffn, cross

    def init(self, key) -> Params:
        mixer, ffn, cross = self._mods()
        keys = jax.random.split(key, 8)
        n = _norm(self.cfg)
        p = {"ln1": n.init(keys[0]), "mixer": mixer.init(keys[1])}
        if cross is not None:
            p["ln_cross"] = n.init(keys[2])
            p["cross"] = cross.init(keys[3])
        if ffn is not None:
            p["ln2"] = n.init(keys[4])
            p["ffn"] = ffn.init(keys[5])
        if self.cfg.post_norms:
            p["ln1_post"] = n.init(keys[6])
            if ffn is not None:
                p["ln2_post"] = n.init(keys[7])
        return p

    def axes(self) -> AxesTree:
        mixer, ffn, cross = self._mods()
        n = _norm(self.cfg)
        a = {"ln1": n.axes(), "mixer": mixer.axes()}
        if cross is not None:
            a["ln_cross"] = n.axes()
            a["cross"] = cross.axes()
        if ffn is not None:
            a["ln2"] = n.axes()
            a["ffn"] = ffn.axes()
        if self.cfg.post_norms:
            a["ln1_post"] = n.axes()
            if ffn is not None:
                a["ln2_post"] = n.axes()
        return a

    # -- full-sequence (train / prefill) -------------------------------------
    def apply(self, p: Params, x, *, positions=None, memory=None,
              prefix_len=None):
        from repro.parallel.context import constrain, get_ctx
        ctx = get_ctx()
        tp_size = ctx.mesh.shape[ctx.tp] if ctx.mesh is not None else 1
        sp = (ctx.seq_parallel and x.shape[1] % max(tp_size, 1) == 0
              and x.shape[1] > 1)

        def _sp(t):
            # Megatron-SP (§Perf H4): the residual stream lives
            # sequence-sharded over the TP axis, so norms/residual adds
            # touch 1/tp of the tokens and GSPMD lowers the TP psum into
            # reduce-scatter + later all-gather at the next matmul.
            return constrain(t, ctx.dp, ctx.tp, None) if sp else t

        n = _norm(self.cfg)
        aux = jnp.zeros((), jnp.float32)
        x = _sp(x)
        mixer, ffn, cross = self._mods()
        h = n.apply(p["ln1"], x)
        if isinstance(mixer, Attention):
            h = mixer.apply(p["mixer"], h, positions=positions,
                            prefix_len=prefix_len)
        else:
            h = mixer.apply(p["mixer"], h)
        if self.cfg.post_norms:
            h = _sp(n.apply(p["ln1_post"], h))
        x = x + _sp(h)
        if self.with_cross:
            h = n.apply(p["ln_cross"], x)
            h = cross.apply(p["cross"], h, kv_x=memory)
            x = x + _sp(h)
        if ffn is not None:
            h = n.apply(p["ln2"], x)
            if isinstance(ffn, MoE):
                h, aux = ffn.apply(p["ffn"], h)
            else:
                h = ffn.apply(p["ffn"], h)
            if self.cfg.post_norms:
                h = _sp(n.apply(p["ln2_post"], h))
            x = x + _sp(h)
        return x, aux

    # -- decode ----------------------------------------------------------------
    def decode(self, p: Params, x, cache, pos, *, memory=None):
        n = _norm(self.cfg)
        mixer, ffn, cross = self._mods()
        h = n.apply(p["ln1"], x)
        if isinstance(mixer, Attention):
            h, cache = mixer.decode(p["mixer"], h, cache, pos)
        else:
            h, cache = mixer.decode(p["mixer"], h, cache)
        if self.cfg.post_norms:
            h = n.apply(p["ln1_post"], h)
        x = x + h
        if self.with_cross:
            h = n.apply(p["ln_cross"], x)
            h, _ = cross.decode(p["cross"], h, {}, pos, kv_memory=memory)
            x = x + h
        if ffn is not None:
            h = n.apply(p["ln2"], x)
            if isinstance(ffn, MoE):
                h, _ = ffn.apply(p["ffn"], h)
            else:
                h = ffn.apply(p["ffn"], h)
            if self.cfg.post_norms:
                h = n.apply(p["ln2_post"], h)
            x = x + h
        return x, cache

    def init_cache(self, batch: int, max_len: int):
        mixer = self._mods()[0]
        if isinstance(mixer, Attention):
            return mixer.init_cache(batch, max_len)
        return mixer.init_cache(batch)

    def cache_axes(self):
        return self._mods()[0].cache_axes()


@dataclasses.dataclass(frozen=True)
class LayerStack:
    """n_layers arranged as scan-groups of the repeating pattern + tail."""
    cfg: ArchConfig
    n_layers: int
    causal: bool = True
    with_cross: bool = False

    @property
    def group_size(self) -> int:
        return len(self.cfg.pattern)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.group_size

    @property
    def n_tail(self) -> int:
        return self.n_layers % self.group_size

    def _layers(self) -> list[DecoderLayer]:
        return [DecoderLayer(self.cfg, k, causal=self.causal,
                             with_cross=self.with_cross)
                for k in self.cfg.pattern]

    # -- params ------------------------------------------------------------------
    def init(self, key) -> Params:
        layers = self._layers()
        gkeys = jax.random.split(key, self.n_groups + 1)
        groups = []
        for g in range(self.n_groups):
            lkeys = jax.random.split(gkeys[g], self.group_size)
            groups.append({f"l{i}": layers[i].init(lkeys[i])
                           for i in range(self.group_size)})
        p = {"groups": stack_layers(groups) if groups else {}}
        tkeys = jax.random.split(gkeys[-1], max(self.n_tail, 1))
        p["tail"] = {f"l{i}": layers[i].init(tkeys[i])
                     for i in range(self.n_tail)}
        return p

    def axes(self) -> AxesTree:
        layers = self._layers()
        group = {f"l{i}": layers[i].axes() for i in range(self.group_size)}
        return {"groups": prepend_layer_axis(group) if self.n_groups else {},
                "tail": {f"l{i}": layers[i].axes()
                         for i in range(self.n_tail)}}

    # -- forward -------------------------------------------------------------------
    def apply(self, p: Params, x, *, positions=None, memory=None,
              prefix_len=None, remat: bool = True):
        layers = self._layers()

        def group_fn(x, gp):
            aux = jnp.zeros((), jnp.float32)
            for i, layer in enumerate(layers):
                x, a = layer.apply(gp[f"l{i}"], x, positions=positions,
                                   memory=memory, prefix_len=prefix_len)
                aux = aux + a
            return x, aux

        body = jax.checkpoint(group_fn) if remat else group_fn

        if self.n_groups:
            def scan_fn(carry, gp):
                x, aux = carry
                x, a = body(x, gp)
                return (x, aux + a), None
            (x, aux), _ = jax.lax.scan(scan_fn,
                                       (x, jnp.zeros((), jnp.float32)),
                                       p["groups"])
        else:
            aux = jnp.zeros((), jnp.float32)
        for i in range(self.n_tail):
            x, a = layers[i].apply(p["tail"][f"l{i}"], x,
                                   positions=positions, memory=memory,
                                   prefix_len=prefix_len)
            aux = aux + a
        return x, aux

    # -- decode ----------------------------------------------------------------------
    def decode(self, p: Params, x, caches, pos, *, memory=None):
        layers = self._layers()

        def group_fn(x, gp, gc):
            new_c = {}
            for i, layer in enumerate(layers):
                x, c = layer.decode(gp[f"l{i}"], x, gc[f"l{i}"], pos,
                                    memory=memory)
                new_c[f"l{i}"] = c
            return x, new_c

        if self.n_groups:
            def scan_fn(x, inp):
                gp, gc = inp
                x, nc = group_fn(x, gp, gc)
                return x, nc
            x, new_groups = jax.lax.scan(scan_fn, x,
                                         (p["groups"], caches["groups"]))
        else:
            new_groups = caches["groups"]
        new_tail = {}
        for i in range(self.n_tail):
            x, c = layers[i].decode(p["tail"][f"l{i}"], x,
                                    caches["tail"][f"l{i}"], pos,
                                    memory=memory)
            new_tail[f"l{i}"] = c
        return x, {"groups": new_groups, "tail": new_tail}

    def init_caches(self, batch: int, max_len: int):
        layers = self._layers()
        group_c = {f"l{i}": layers[i].init_cache(batch, max_len)
                   for i in range(self.group_size)}
        if self.n_groups:
            groups = jax.tree.map(
                lambda v: jnp.broadcast_to(v, (self.n_groups,) + v.shape),
                group_c)
        else:
            groups = {}
        tail = {f"l{i}": layers[i].init_cache(batch, max_len)
                for i in range(self.n_tail)}
        return {"groups": groups, "tail": tail}

    def cache_axes(self):
        layers = self._layers()
        group_a = {f"l{i}": layers[i].cache_axes()
                   for i in range(self.group_size)}
        groups = (prepend_layer_axis(group_a) if self.n_groups else {})
        tail = {f"l{i}": layers[i].cache_axes() for i in range(self.n_tail)}
        return {"groups": groups, "tail": tail}
