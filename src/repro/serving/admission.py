"""Utility-aware tenant-admission strategies for the serving engine.

The engine's tenant queue used to drain strictly FIFO (with aging).
Under sustained overload that is the wrong discipline for almost every
real serving contract: a latency-class stream with a 3-tick admission
deadline rots behind a bulk stream that would be equally happy admitted
a hundred ticks from now.  This module makes the *order* in which the
engine drains its waiting streams a first-class, registered strategy —
the same registry idiom as the fabric's packing policies
(:func:`repro.core.fabric.register_policy`), applied one layer up, to
*admission* instead of slot packing.

Vocabulary:

* an :class:`AdmissionTicket` is one admission attempt — the stream's
  name and batch plus its utility annotations (``klass``, ``priority``,
  absolute-tick ``deadline``) and the arrival sequence number ``seq``
  that every strategy uses as the final tie-break (stable FIFO among
  equals, independent of any dict/set iteration order);
* a strategy is ``fn(waiters, ctx) -> iterable[int]`` returning the
  *admission order* — a permutation of ``range(len(waiters))`` over the
  queued ``(arrival_tick, ticket)`` pairs.  Earlier positions get first
  claim on freed bank capacity;
* :class:`AdmissionContext` is what a strategy may consult besides the
  waiters themselves (the engine tick, per-class admission frequencies).

Shipped strategies (:func:`registered_admissions`):

``"fifo"``
    Arrival order, head-blocking: a stream that does not fit blocks
    everything behind it, exactly the engine's pre-registry behavior.
``"deadline"``
    Strictest-deadline-first: ticketed waiters by ascending absolute
    deadline, then the deadline-less ones FIFO.  The Icarus
    ``StrictestDeadlineFirst`` discipline applied to tenant admission.
``"priority"``
    Frequency/priority-weighted: descending ``priority *
    (1 + admitted_so_far(klass))`` — a class that keeps being admitted
    is a class the operator keeps paying for (the ``MostFrequentlyUsed``
    analogue), with the static priority as the base utility.
``"hybrid"``
    Deadline waiters inside the urgency window (:data:`HYBRID_SLACK`
    ticks of slack) go first, strictest-first; everything else falls
    back to the priority weighting — urgent SLOs preempt, bulk traffic
    is otherwise utility-ordered.

New strategies register with :func:`register_admission` without touching
the engine; :func:`unregister_admission` removes experiments (built-ins
are protected).  ``Engine(admission_strategy=...)`` selects per engine;
per-class outcomes land in ``Engine.transfer_telemetry()``.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

#: Slack (in engine ticks) under which the hybrid strategy treats a
#: deadline waiter as urgent and lets it preempt the priority ordering.
HYBRID_SLACK = 8


@dataclasses.dataclass(frozen=True)
class AdmissionTicket:
    """One tenant-admission attempt and its utility annotations.

    Attributes:
      name: tenant name (the ``generate`` stream / ``open_tenant`` name).
      batch: batch size the leaf footprints are built for.
      klass: service-class label for per-class telemetry and the
        frequency weighting (``"default"`` when the caller is classless).
      priority: static utility weight; higher admits earlier under the
        ``priority``/``hybrid`` strategies (1.0 = neutral).
      deadline: absolute engine tick by which admission is still useful;
        ``None`` means no SLO.  A waiter still queued *after* its
        deadline is expired (one terminal ``"expired"`` event) and a
        waiter admitted late counts as a deadline miss.
      seq: global arrival sequence number — the universal tie-break, so
        equal-utility waiters always admit in stable FIFO order.
    """
    name: str
    batch: int
    klass: str = "default"
    priority: float = 1.0
    deadline: int | None = None
    seq: int = 0


class AdmissionContext:
    """What an admission strategy may look at besides the waiters.

    Attributes:
      tick: the engine tick the drain runs at (slack = deadline - tick).
      klass_admits: admissions granted so far per service class — the
        frequency signal the ``priority`` strategy weights by.
    """

    def __init__(self, tick: int, klass_admits: Mapping[str, int]):
        self.tick = tick
        self.klass_admits = klass_admits

    def frequency(self, klass: str) -> int:
        """Admissions granted to ``klass`` so far (0 for a new class)."""
        return self.klass_admits.get(klass, 0)


_ADMISSIONS: dict[str, object] = {}
_BUILTINS = ("fifo", "deadline", "priority", "hybrid")


def register_admission(name: str, *, head_blocking: bool = False):
    """Decorator registering an admission strategy under ``name``.

    A strategy is ``fn(waiters, ctx: AdmissionContext) -> iterable[int]``
    over the queued ``(arrival_tick, AdmissionTicket)`` pairs, returning
    a permutation of ``range(len(waiters))`` — the order freed capacity
    is offered in.  ``head_blocking=True`` keeps strict queue semantics:
    the first waiter that does not fit blocks the rest of the drain
    (``fifo`` uses this to preserve exact arrival order); the default is
    best-effort — a waiter that does not fit is skipped and keeps its
    place for the next drain.  Registering a taken name raises
    ``ValueError``.
    """
    def deco(fn):
        if name in _ADMISSIONS:
            raise ValueError(f"admission strategy {name!r} is already "
                             "registered")
        fn.head_blocking = head_blocking
        _ADMISSIONS[name] = fn
        return fn
    return deco


def unregister_admission(name: str) -> None:
    """Remove a registered strategy (the built-ins may not be removed)."""
    if name in _BUILTINS:
        raise ValueError(f"built-in admission strategy {name!r} may not "
                         "be removed")
    if name not in _ADMISSIONS:
        raise ValueError(f"admission strategy {name!r} is not registered")
    del _ADMISSIONS[name]


def registered_admissions() -> tuple[str, ...]:
    """Strategy names currently registered, registration order."""
    return tuple(_ADMISSIONS)


def get_admission(name: str):
    """Look up a strategy by name; unknown names raise ``ValueError``
    listing what is registered."""
    try:
        return _ADMISSIONS[name]
    except KeyError:
        raise ValueError(
            f"unknown admission strategy {name!r}; registered: "
            f"{', '.join(_ADMISSIONS)}") from None


def _seq(waiters, i: int) -> int:
    return waiters[i][1].seq


@register_admission("fifo", head_blocking=True)
def _fifo(waiters, ctx: AdmissionContext):
    """Stable arrival order (by ticket ``seq``, never list position),
    head-blocking — the engine's legacy discipline."""
    return sorted(range(len(waiters)), key=lambda i: _seq(waiters, i))


@register_admission("deadline")
def _deadline(waiters, ctx: AdmissionContext):
    """Strictest-deadline-first; deadline-less waiters trail in FIFO
    order.  Ties (equal deadlines) break by arrival ``seq``."""
    def key(i):
        tk = waiters[i][1]
        has = tk.deadline is not None
        return (0 if has else 1, tk.deadline if has else 0, tk.seq)
    return sorted(range(len(waiters)), key=key)


def _weight(tk: AdmissionTicket, ctx: AdmissionContext) -> float:
    return tk.priority * (1.0 + ctx.frequency(tk.klass))


@register_admission("priority")
def _priority(waiters, ctx: AdmissionContext):
    """Descending frequency-weighted priority
    (``priority * (1 + admitted_so_far(klass))``), FIFO among equals."""
    return sorted(range(len(waiters)),
                  key=lambda i: (-_weight(waiters[i][1], ctx),
                                 _seq(waiters, i)))


@register_admission("hybrid")
def _hybrid(waiters, ctx: AdmissionContext):
    """Urgent deadlines first, utility-weighted otherwise: a deadline
    waiter with slack <= :data:`HYBRID_SLACK` preempts (strictest
    first); the rest order by the ``priority`` weighting.  Every tie
    breaks by arrival ``seq``."""
    def key(i):
        tk = waiters[i][1]
        slack = None if tk.deadline is None else tk.deadline - ctx.tick
        if slack is not None and slack <= HYBRID_SLACK:
            return (0, slack, 0.0, tk.seq)
        return (1, 0, -_weight(tk, ctx), tk.seq)
    return sorted(range(len(waiters)), key=key)


__all__ = ["HYBRID_SLACK", "AdmissionContext", "AdmissionTicket",
           "get_admission", "register_admission", "registered_admissions",
           "unregister_admission"]
