"""Utility-aware tenant-admission strategies for the serving engine.

The engine's tenant queue used to drain strictly FIFO (with aging).
Under sustained overload that is the wrong discipline for almost every
real serving contract: a latency-class stream with a 3-tick admission
deadline rots behind a bulk stream that would be equally happy admitted
a hundred ticks from now.  This module makes the *order* in which the
engine drains its waiting streams a first-class, registered strategy —
the same registry idiom as the fabric's packing policies
(:func:`repro.core.fabric.register_policy`), applied one layer up, to
*admission* instead of slot packing.

Vocabulary:

* an :class:`AdmissionTicket` is one admission attempt — the stream's
  name and batch plus its utility annotations (``klass``, ``priority``,
  absolute-tick ``deadline``) and the arrival sequence number ``seq``
  that every strategy uses as the final tie-break (stable FIFO among
  equals, independent of any dict/set iteration order);
* a strategy is ``fn(waiters, ctx) -> iterable[int]`` returning the
  *admission order* — a permutation of ``range(len(waiters))`` over the
  queued ``(arrival_tick, ticket)`` pairs.  Earlier positions get first
  claim on freed bank capacity;
* :class:`AdmissionContext` is what a strategy may consult besides the
  waiters themselves (the engine tick, per-class admission frequencies,
  and — new — the fabric's stall/queue-wait telemetry).

Shipped strategies (:func:`registered_admissions`):

``"fifo"``
    Arrival order, head-blocking: a stream that does not fit blocks
    everything behind it, exactly the engine's pre-registry behavior.
``"deadline"``
    Strictest-deadline-first: ticketed waiters by ascending absolute
    deadline, then the deadline-less ones FIFO.  The Icarus
    ``StrictestDeadlineFirst`` discipline applied to tenant admission.
``"priority"``
    Frequency/priority-weighted: descending ``priority *
    (1 + admitted_so_far(klass))`` — a class that keeps being admitted
    is a class the operator keeps paying for (the ``MostFrequentlyUsed``
    analogue), with the static priority as the base utility.
``"hybrid"``
    Deadline waiters inside the urgency window (:data:`HYBRID_SLACK`
    ticks of slack) go first, strictest-first; everything else falls
    back to the priority weighting — urgent SLOs preempt, bulk traffic
    is otherwise utility-ordered.
``"stall_aware"``
    Telemetry-coupled: while the fabric underneath is healthy
    (:meth:`AdmissionContext.stall_pressure` at or below
    :data:`STALL_PRESSURE` stall cycles per scheduled circuit) it is
    exactly the ``deadline`` discipline; once the fabric is stalling,
    the lightest waiters (smallest ``batch`` — the fewest new circuits
    per tick) admit first, so admission stops feeding a congested
    fabric its heaviest streams.

New strategies register with :func:`register_admission` without touching
the engine; :func:`unregister_admission` removes experiments (built-ins
are protected).  ``Engine(admission_strategy=...)`` selects per engine;
per-class outcomes land in ``Engine.transfer_telemetry()``.

Vectorized control plane
------------------------

At the scale the ROADMAP aims for (millions of tenant arrivals per run)
a per-waiter ``sorted(..., key=lambda)`` is the control plane's
bottleneck, not the fabric.  Every built-in therefore ships a second,
*vectorized* form operating on :class:`TicketColumns` — the packed
structure-of-arrays mirror of the queue (``seq`` / ``deadline`` /
``priority`` / klass-id / ``batch`` / arrival tick as numpy columns) —
computing the identical permutation as one ``numpy.lexsort`` per drain.
``register_admission(name, vector=...)`` attaches the vector form;
strategies without one (experiments) simply fall back to the scalar
function.  Bit-identity of every built-in's two forms is pinned by the
differential harness in ``tests/test_serving_slo.py`` and recorded in
``BENCH_engine_scale.json``.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

#: Slack (in engine ticks) under which the hybrid strategy treats a
#: deadline waiter as urgent and lets it preempt the priority ordering.
HYBRID_SLACK = 8

#: Fabric stall pressure (stall cycles per scheduled circuit) above
#: which the ``stall_aware`` strategy switches from deadline order to
#: lightest-first admission.
STALL_PRESSURE = 2.0


@dataclasses.dataclass(frozen=True)
class AdmissionTicket:
    """One tenant-admission attempt and its utility annotations.

    Attributes:
      name: tenant name (the ``generate`` stream / ``open_tenant`` name).
      batch: batch size the leaf footprints are built for.
      klass: service-class label for per-class telemetry and the
        frequency weighting (``"default"`` when the caller is classless).
      priority: static utility weight; higher admits earlier under the
        ``priority``/``hybrid`` strategies (1.0 = neutral).
      deadline: absolute engine tick by which admission is still useful;
        ``None`` means no SLO.  A waiter still queued *after* its
        deadline is expired (one terminal ``"expired"`` event) and a
        waiter admitted late counts as a deadline miss.
      seq: global arrival sequence number — the universal tie-break, so
        equal-utility waiters always admit in stable FIFO order.
    """
    name: str
    batch: int
    klass: str = "default"
    priority: float = 1.0
    deadline: int | None = None
    seq: int = 0


class AdmissionContext:
    """What an admission strategy may look at besides the waiters.

    Attributes:
      tick: the engine tick the drain runs at (slack = deadline - tick).
      klass_admits: admissions granted so far per service class — the
        frequency signal the ``priority`` strategy weights by.
      fabric: the engine's fabric telemetry snapshot (the dict
        ``NomFabric.telemetry()`` / ``FabricCluster.telemetry()``
        returns), resolved lazily on first access so strategies that
        never look pay nothing; ``{}`` when the engine runs without a
        fabric.
    """

    def __init__(self, tick: int, klass_admits: Mapping[str, int],
                 fabric=None):
        self.tick = tick
        self.klass_admits = klass_admits
        self._fabric = fabric

    def frequency(self, klass: str) -> int:
        """Admissions granted to ``klass`` so far (0 for a new class)."""
        return self.klass_admits.get(klass, 0)

    @property
    def fabric(self) -> Mapping:
        """The fabric telemetry mapping (lazily resolved; ``{}`` when
        the engine has no fabric)."""
        if callable(self._fabric):
            self._fabric = self._fabric()
        return self._fabric or {}

    def stall_pressure(self) -> float:
        """Fabric stall cycles per scheduled circuit — the congestion
        signal ``stall_aware`` switches on (0.0 without a fabric)."""
        tel = self.fabric
        return tel.get("stall_cycles", 0) / max(1, tel.get("scheduled", 0))


class TicketColumns:
    """Packed structure-of-arrays mirror of a tenant admission queue.

    One row per queued ``(arrival_tick, AdmissionTicket)`` pair, in
    queue-list order; columns are numpy arrays (``at`` arrival tick,
    ``seq``, ``deadline`` with ``-1`` for deadline-less, ``priority``,
    ``klass`` id, ``batch``), capacity-doubled so :meth:`append` is
    amortized O(1) and :meth:`compact` is one boolean-mask pass.  Klass
    labels are interned to small ints (``klass_names`` maps back);
    :meth:`frequencies` expands a per-klass admission count mapping to a
    per-row vector.  This is what the vector form of a strategy sorts —
    one ``numpy.lexsort`` over columns instead of a Python ``sorted``
    over tickets.
    """

    _FIELDS = (("at", np.int64), ("seq", np.int64), ("deadline", np.int64),
               ("priority", np.float64), ("klass", np.int32),
               ("batch", np.int64))

    def __init__(self, capacity: int = 64):
        self.n = 0
        self._cap = max(1, capacity)
        for name, dt in self._FIELDS:
            setattr(self, "_" + name, np.zeros(self._cap, dt))
        self._klass_ids: dict[str, int] = {}
        self.klass_names: list[str] = []

    def __len__(self) -> int:
        return self.n

    def __getattr__(self, name):
        # Column views: cols.seq is the live prefix of the backing array.
        if any(name == f for f, _dt in self._FIELDS):
            return getattr(self, "_" + name)[:self.n]
        raise AttributeError(name)

    def klass_id(self, klass: str) -> int:
        """Intern a klass label to its small-int column value."""
        kid = self._klass_ids.get(klass)
        if kid is None:
            kid = self._klass_ids[klass] = len(self.klass_names)
            self.klass_names.append(klass)
        return kid

    def _grow(self, need: int) -> None:
        while self._cap < need:
            self._cap *= 2
        for name, _dt in self._FIELDS:
            old = getattr(self, "_" + name)
            fresh = np.zeros(self._cap, old.dtype)
            fresh[:self.n] = old[:self.n]
            setattr(self, "_" + name, fresh)

    def append(self, at: int, tk: AdmissionTicket) -> None:
        """Add one queued waiter's row (amortized O(1))."""
        if self.n == self._cap:
            self._grow(self.n + 1)
        i = self.n
        self._at[i] = at
        self._seq[i] = tk.seq
        self._deadline[i] = -1 if tk.deadline is None else tk.deadline
        self._priority[i] = tk.priority
        self._klass[i] = self.klass_id(tk.klass)
        self._batch[i] = tk.batch
        self.n = i + 1

    def compact(self, keep: np.ndarray) -> None:
        """Drop the rows where boolean ``keep`` is False (one mask pass)."""
        kept = int(np.count_nonzero(keep))
        if kept == self.n:
            return
        for name, _dt in self._FIELDS:
            col = getattr(self, "_" + name)
            col[:kept] = col[:self.n][keep]
        self.n = kept

    def rebuild(self, items) -> None:
        """Resynchronize from the queue's backing list (used after an
        external mutation of ``AdmissionQueue.items`` is detected)."""
        self.n = 0
        if len(items) > self._cap:
            self._grow(len(items))
        for at, tk in items:
            self.append(at, tk)

    def frequencies(self, klass_admits: Mapping[str, int]) -> np.ndarray:
        """Per-row admitted-so-far counts for the rows' klasses."""
        table = np.array([klass_admits.get(k, 0)
                          for k in self.klass_names], np.float64)
        if not len(table):
            return np.zeros(self.n, np.float64)
        return table[self.klass]


_ADMISSIONS: dict[str, object] = {}
_BUILTINS = ("fifo", "deadline", "priority", "hybrid", "stall_aware")


def register_admission(name: str, *, head_blocking: bool = False,
                       vector=None):
    """Decorator registering an admission strategy under ``name``.

    A strategy is ``fn(waiters, ctx: AdmissionContext) -> iterable[int]``
    over the queued ``(arrival_tick, AdmissionTicket)`` pairs, returning
    a permutation of ``range(len(waiters))`` — the order freed capacity
    is offered in.  ``head_blocking=True`` keeps strict queue semantics:
    the first waiter that does not fit blocks the rest of the drain
    (``fifo`` uses this to preserve exact arrival order); the default is
    best-effort — a waiter that does not fit is skipped and keeps its
    place for the next drain.  ``vector`` optionally attaches the
    batched form ``vec(cols: TicketColumns, ctx) -> numpy permutation``
    that a vectorized engine uses instead of the scalar function — it
    must compute the *identical* order.  Registering a taken name raises
    ``ValueError``.
    """
    def deco(fn):
        if name in _ADMISSIONS:
            raise ValueError(f"admission strategy {name!r} is already "
                             "registered")
        fn.head_blocking = head_blocking
        fn.vector = vector
        _ADMISSIONS[name] = fn
        return fn
    return deco


def unregister_admission(name: str) -> None:
    """Remove a registered strategy (the built-ins may not be removed)."""
    if name in _BUILTINS:
        raise ValueError(f"built-in admission strategy {name!r} may not "
                         "be removed")
    if name not in _ADMISSIONS:
        raise ValueError(f"admission strategy {name!r} is not registered")
    del _ADMISSIONS[name]


def registered_admissions() -> tuple[str, ...]:
    """Strategy names currently registered, registration order."""
    return tuple(_ADMISSIONS)


def get_admission(name: str):
    """Look up a strategy by name; unknown names raise ``ValueError``
    listing what is registered."""
    try:
        return _ADMISSIONS[name]
    except KeyError:
        raise ValueError(
            f"unknown admission strategy {name!r}; registered: "
            f"{', '.join(_ADMISSIONS)}") from None


def _seq(waiters, i: int) -> int:
    return waiters[i][1].seq


# -- vector forms ------------------------------------------------------------
# Each computes the exact permutation its scalar twin returns.  lexsort
# orders by the LAST key first, so every form passes ``cols.seq`` as the
# first (least-significant) key — the universal FIFO tie-break.

def _fifo_vec(cols: TicketColumns, ctx: AdmissionContext) -> np.ndarray:
    return np.argsort(cols.seq, kind="stable")


def _deadline_keys(cols: TicketColumns):
    has = cols.deadline >= 0
    return np.where(has, cols.deadline, 0), (~has).astype(np.int64)


def _deadline_vec(cols: TicketColumns, ctx: AdmissionContext) -> np.ndarray:
    dl, no_dl = _deadline_keys(cols)
    return np.lexsort((cols.seq, dl, no_dl))


def _weight_vec(cols: TicketColumns, ctx: AdmissionContext) -> np.ndarray:
    return cols.priority * (1.0 + cols.frequencies(ctx.klass_admits))


def _priority_vec(cols: TicketColumns, ctx: AdmissionContext) -> np.ndarray:
    return np.lexsort((cols.seq, -_weight_vec(cols, ctx)))


def _hybrid_vec(cols: TicketColumns, ctx: AdmissionContext) -> np.ndarray:
    has = cols.deadline >= 0
    slack = cols.deadline - ctx.tick
    urgent = has & (slack <= HYBRID_SLACK)
    k1 = (~urgent).astype(np.int64)                 # urgent first
    k2 = np.where(urgent, slack, 0)                 # strictest first
    k3 = np.where(urgent, 0.0, -_weight_vec(cols, ctx))
    return np.lexsort((cols.seq, k3, k2, k1))


def _stall_aware_vec(cols: TicketColumns,
                     ctx: AdmissionContext) -> np.ndarray:
    if ctx.stall_pressure() <= STALL_PRESSURE:
        return _deadline_vec(cols, ctx)
    dl, no_dl = _deadline_keys(cols)
    return np.lexsort((cols.seq, dl, no_dl, cols.batch))


# -- scalar forms (the reference semantics) ----------------------------------

@register_admission("fifo", head_blocking=True, vector=_fifo_vec)
def _fifo(waiters, ctx: AdmissionContext):
    """Stable arrival order (by ticket ``seq``, never list position),
    head-blocking — the engine's legacy discipline."""
    return sorted(range(len(waiters)), key=lambda i: _seq(waiters, i))


@register_admission("deadline", vector=_deadline_vec)
def _deadline(waiters, ctx: AdmissionContext):
    """Strictest-deadline-first; deadline-less waiters trail in FIFO
    order.  Ties (equal deadlines) break by arrival ``seq``."""
    def key(i):
        tk = waiters[i][1]
        has = tk.deadline is not None
        return (0 if has else 1, tk.deadline if has else 0, tk.seq)
    return sorted(range(len(waiters)), key=key)


def _weight(tk: AdmissionTicket, ctx: AdmissionContext) -> float:
    return tk.priority * (1.0 + ctx.frequency(tk.klass))


@register_admission("priority", vector=_priority_vec)
def _priority(waiters, ctx: AdmissionContext):
    """Descending frequency-weighted priority
    (``priority * (1 + admitted_so_far(klass))``), FIFO among equals."""
    return sorted(range(len(waiters)),
                  key=lambda i: (-_weight(waiters[i][1], ctx),
                                 _seq(waiters, i)))


@register_admission("hybrid", vector=_hybrid_vec)
def _hybrid(waiters, ctx: AdmissionContext):
    """Urgent deadlines first, utility-weighted otherwise: a deadline
    waiter with slack <= :data:`HYBRID_SLACK` preempts (strictest
    first); the rest order by the ``priority`` weighting.  Every tie
    breaks by arrival ``seq``."""
    def key(i):
        tk = waiters[i][1]
        slack = None if tk.deadline is None else tk.deadline - ctx.tick
        if slack is not None and slack <= HYBRID_SLACK:
            return (0, slack, 0.0, tk.seq)
        return (1, 0, -_weight(tk, ctx), tk.seq)
    return sorted(range(len(waiters)), key=key)


@register_admission("stall_aware", vector=_stall_aware_vec)
def _stall_aware(waiters, ctx: AdmissionContext):
    """Fabric-coupled admission: deadline order while the fabric is
    healthy; lightest-first (ascending ``batch``, then strictest
    deadline, then ``seq``) once :meth:`AdmissionContext.stall_pressure`
    exceeds :data:`STALL_PRESSURE` — a congested fabric should not be
    fed its heaviest waiters first."""
    if ctx.stall_pressure() <= STALL_PRESSURE:
        return _deadline(waiters, ctx)

    def key(i):
        tk = waiters[i][1]
        has = tk.deadline is not None
        return (tk.batch, 0 if has else 1,
                tk.deadline if has else 0, tk.seq)
    return sorted(range(len(waiters)), key=key)


__all__ = ["HYBRID_SLACK", "STALL_PRESSURE", "AdmissionContext",
           "AdmissionTicket", "TicketColumns", "get_admission",
           "register_admission", "registered_admissions",
           "unregister_admission"]
