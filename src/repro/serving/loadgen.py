"""Trace-driven open-loop load generation for the serving engine.

The paper's headline claim is throughput under *concurrent* transfer
load; the serving analogue is admission behavior under sustained tenant
churn.  This module drives an :class:`~repro.serving.engine.Engine`
tick-by-tick with a seeded open-loop arrival process — arrivals do not
wait for completions, exactly like real traffic — and measures what the
ROADMAP's "millions of users" story needs measured: p50/p99 admission
latency, shed and expiry rates, deadline-miss rates per admission
strategy, and circuits-per-window on the NoM fabric underneath.

Three building blocks:

* :class:`ArrivalMix` — a declarative traffic description: the arrival
  *process* (``"poisson"`` | ``"bursty"`` | ``"heavy_tail"``), the mean
  rate, an optional diurnal ramp, and the service-class table
  (:class:`ClassSpec`: share, priority, deadline slack, lifetime) each
  arrival is drawn from.  :func:`get_mix` serves the built-ins
  (:data:`MIXES`): ``poisson``, ``bursty``, ``heavy_tail``, and the
  overloaded ``deadline_heavy`` mix the SLO benchmark gates on.
* :class:`LoadGen` — the seeded generator: ``arrivals(tick)`` yields the
  tick's :class:`Arrival` records deterministically (one stream of
  draws, consumed in tick order, so a fixed ``(mix, seed)`` pair always
  produces the identical trace).
* :func:`drive` — the harness: feeds a generator into an engine
  (``open_tenant`` with the arrival's ticket annotations,
  ``schedule_tick`` every tick, ``close_tenant`` when a tenant's
  lifetime lapses), observes every terminal admission event through the
  engine's ``waiter_callback``, and returns the stats record
  ``benchmarks/bench_serving_slo.py`` writes into ``BENCH_serving.json``.
  Each record carries the per-tick conservation ledger
  (``arrivals == admitted + shed + expired + waiting`` at every tick)
  that ``tests/test_serving_slo.py`` asserts.

The default engine under test is model-free: :class:`CacheStub` exposes
only ``init_caches`` (a KV-ring + state-leaf pair per stream), so the
harness measures admission and scheduling, not matmuls —
:func:`make_slo_engine` builds the standard stub engine the tests and
the benchmark share.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.core.topology import make_topology
from repro.serving.engine import Engine


@dataclasses.dataclass(frozen=True)
class ClassSpec:
    """One service class of an :class:`ArrivalMix`.

    Attributes:
      klass: class label (lands in per-class telemetry).
      weight: relative share of arrivals drawn from this class.
      priority: static utility weight for the ``priority``/``hybrid``
        admission strategies.
      deadline_slack: ``(lo, hi)`` inclusive tick range — each arrival's
        admission deadline is ``tick + U[lo, hi]``; ``None`` means the
        class carries no admission SLO.
      lifetime: ``(lo, hi)`` inclusive range of service ticks an
        admitted tenant stays open before the driver closes it.
    """
    klass: str
    weight: float
    priority: float = 1.0
    deadline_slack: tuple[int, int] | None = None
    lifetime: tuple[int, int] = (2, 5)


@dataclasses.dataclass(frozen=True)
class ArrivalMix:
    """A reproducible open-loop traffic description.

    Attributes:
      name: mix label (keys the benchmark record).
      process: ``"poisson"`` (memoryless), ``"bursty"`` (a low poisson
        baseline plus a large burst every ``burst_every`` ticks), or
        ``"heavy_tail"`` (poisson baseline plus Pareto-sized arrival
        clumps with probability ``tail_prob`` per tick).
      rate: mean arrivals per tick before the diurnal ramp.
      classes: the service-class table arrivals are drawn from.
      burst_every / burst_mult: bursty-process shape.
      tail_prob / tail_alpha / tail_cap: heavy-tail shape (Pareto index
        ``tail_alpha``, clump size capped at ``tail_cap``).
      diurnal_period / diurnal_amp: sinusoidal rate ramp — the rate at
        tick t is ``rate * (1 + amp * sin(2 pi t / period))``; period 0
        disables the ramp.
    """
    name: str
    process: str
    rate: float
    classes: tuple[ClassSpec, ...]
    burst_every: int = 16
    burst_mult: float = 6.0
    tail_prob: float = 0.08
    tail_alpha: float = 1.3
    tail_cap: int = 24
    diurnal_period: int = 0
    diurnal_amp: float = 0.0

    def __post_init__(self):
        if self.process not in ("poisson", "bursty", "heavy_tail"):
            raise ValueError(f"unknown arrival process {self.process!r}; "
                             "choose from ('poisson', 'bursty', "
                             "'heavy_tail')")
        if not self.classes:
            raise ValueError("an ArrivalMix needs at least one ClassSpec")


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One generated stream arrival (the loadgen's trace unit)."""
    name: str
    tick: int
    klass: str
    priority: float
    deadline: int | None
    lifetime: int
    batch: int = 1


_STANDARD_CLASSES = (
    ClassSpec("latency", weight=0.3, priority=4.0, deadline_slack=(2, 6),
              lifetime=(1, 3)),
    ClassSpec("standard", weight=0.5, priority=1.0, deadline_slack=None,
              lifetime=(2, 5)),
    ClassSpec("bulk", weight=0.2, priority=0.25, deadline_slack=None,
              lifetime=(4, 8)),
)

#: Built-in arrival mixes (get_mix).  The first three are the paper-style
#: traffic shapes; ``deadline_heavy`` is the sustained-overload mix the
#: benchmark's fifo-vs-deadline dominance gate runs on: most arrivals
#: carry tight admission deadlines, so queue *order* decides the miss
#: rate.
MIXES: dict[str, ArrivalMix] = {
    "poisson": ArrivalMix("poisson", "poisson", rate=2.0,
                          classes=_STANDARD_CLASSES,
                          diurnal_period=64, diurnal_amp=0.5),
    "bursty": ArrivalMix("bursty", "bursty", rate=2.0,
                         classes=_STANDARD_CLASSES,
                         burst_every=16, burst_mult=6.0),
    "heavy_tail": ArrivalMix("heavy_tail", "heavy_tail", rate=1.5,
                             classes=_STANDARD_CLASSES,
                             tail_prob=0.1, tail_alpha=1.3, tail_cap=24),
    "deadline_heavy": ArrivalMix(
        "deadline_heavy", "poisson", rate=3.0,
        classes=(
            ClassSpec("urgent", weight=0.6, priority=4.0,
                      deadline_slack=(2, 5), lifetime=(1, 3)),
            ClassSpec("bulk", weight=0.4, priority=0.5,
                      deadline_slack=None, lifetime=(4, 9)),
        )),
}


def get_mix(name: str) -> ArrivalMix:
    """Look up a built-in mix; unknown names raise ``ValueError``
    listing what exists."""
    try:
        return MIXES[name]
    except KeyError:
        raise ValueError(f"unknown arrival mix {name!r}; built-ins: "
                         f"{', '.join(MIXES)}") from None


class LoadGen:
    """Seeded open-loop arrival generator over an :class:`ArrivalMix`.

    One private RNG stream, consumed strictly in tick order: call
    :meth:`arrivals` once per tick, ticks ascending (enforced), and a
    fixed ``(mix, seed)`` pair replays the identical trace — the
    determinism property ``tests/test_serving_slo.py`` pins.
    """

    def __init__(self, mix: ArrivalMix, seed: int = 0):
        self.mix = mix
        self.seed = seed
        self._rng = np.random.default_rng(
            (int(seed), zlib.crc32(mix.name.encode())))
        self._seq = 0
        self._next_tick = 0
        w = np.array([c.weight for c in mix.classes], float)
        self._class_p = w / w.sum()

    def rate_at(self, tick: int) -> float:
        """Instantaneous mean arrival rate at ``tick`` (diurnal ramp
        applied; never negative)."""
        mix = self.mix
        if not mix.diurnal_period:
            return mix.rate
        phase = 2.0 * np.pi * tick / mix.diurnal_period
        return max(0.0, mix.rate * (1.0 + mix.diurnal_amp * np.sin(phase)))

    def _count(self, tick: int) -> int:
        mix, rng = self.mix, self._rng
        rate = self.rate_at(tick)
        if mix.process == "poisson":
            return int(rng.poisson(rate))
        if mix.process == "bursty":
            n = int(rng.poisson(rate * 0.4))
            if mix.burst_every and tick % mix.burst_every == 0:
                n += int(rng.poisson(rate * mix.burst_mult))
            return n
        # heavy_tail: light baseline + occasional Pareto-sized clump.
        n = int(rng.poisson(rate * 0.5))
        if rng.random() < mix.tail_prob:
            n += min(mix.tail_cap, 1 + int(rng.pareto(mix.tail_alpha)
                                           * mix.rate))
        return n

    def arrivals(self, tick: int) -> list[Arrival]:
        """The arrivals landing at ``tick`` (possibly empty).  Must be
        called with strictly increasing ticks — the draw stream is the
        determinism contract."""
        if tick < self._next_tick:
            raise ValueError(f"arrivals() must be called in tick order "
                             f"(got {tick} after {self._next_tick - 1})")
        self._next_tick = tick + 1
        rng = self._rng
        out = []
        for _ in range(self._count(tick)):
            c = self.mix.classes[int(rng.choice(len(self.mix.classes),
                                                p=self._class_p))]
            deadline = None
            if c.deadline_slack is not None:
                lo, hi = c.deadline_slack
                deadline = tick + int(rng.integers(lo, hi + 1))
            lo, hi = c.lifetime
            out.append(Arrival(
                name=f"{self.mix.name}-{self._seq}", tick=tick,
                klass=c.klass, priority=c.priority, deadline=deadline,
                lifetime=int(rng.integers(lo, hi + 1))))
            self._seq += 1
        return out


class CacheStub:
    """Model stub exposing only ``init_caches``: one KV ring leaf (size
    scales with ``max_len``) plus one in-place state leaf per stream —
    the smallest footprint that still exercises ring evictions and
    teardown scrubs (2 leased banks per tenant)."""

    def init_caches(self, batch, max_len):
        import jax.numpy as jnp
        return {"kv": jnp.zeros((batch, max_len, 16), jnp.int8),
                "state": jnp.zeros((batch, 32), jnp.int8)}


def make_slo_engine(admission_strategy: str = "fifo", *,
                    mesh: tuple[int, int, int] = (4, 4, 2),
                    deadline_ticks: int = 12, tenant_queue_depth: int = 16,
                    **kw) -> Engine:
    """The standard harness engine: a :class:`CacheStub` model over a
    small bank mesh (capacity ~``X*Y*(Z-1)/2`` concurrent tenants, so
    the built-in mixes genuinely overload it), queue admission with
    aging, and the given admission strategy.  Extra kwargs pass through
    to :class:`~repro.serving.engine.Engine`."""
    kw.setdefault("ring_slots", 4)
    kw.setdefault("idle_evict_ticks", 0)
    return Engine(model=CacheStub(), cfg=None, max_len=16,
                  cache_mesh=make_topology(mesh=mesh),
                  admission="queue", admission_strategy=admission_strategy,
                  deadline_ticks=deadline_ticks,
                  tenant_queue_depth=tenant_queue_depth, **kw)


def _quantile(samples: list[int], q: float) -> float:
    if not samples:
        return 0.0
    return float(np.quantile(np.asarray(samples, float), q))


def drive(engine: Engine, mix: ArrivalMix | str, ticks: int,
          seed: int = 0, trace: bool = False) -> dict:
    """Drive ``engine`` with ``mix`` for ``ticks`` engine ticks.

    Open loop: every generated arrival is offered to ``open_tenant``
    with its ticket annotations (deadline/priority/klass) regardless of
    how loaded the engine is; admitted tenants run for their drawn
    lifetime (their cache traffic scheduled by the engine's per-tick
    batch) and are then closed, freeing capacity for queued waiters.
    The engine's ``waiter_callback`` is borrowed for the run (the prior
    callback is restored on exit) to observe the terminal admission
    events.

    Returns the stats record: totals (``arrivals`` / ``admitted`` /
    ``shed`` / ``expired`` / ``waiting`` / ``completed``), rates
    (``shed_rate`` / ``expiry_rate``), admission-latency percentiles in
    ticks (``p50_wait`` / ``p99_wait``), the SLO ledger
    (``deadline_arrivals`` / ``deadline_misses`` / ``miss_rate``), and
    fabric-side concurrency (``circuits_per_window`` = average circuits
    in flight per TDM window, ``max_inflight``, ``stall_cycles``,
    ``requests`` / ``scheduled``).  With ``trace=True`` the record also
    carries ``per_tick`` — the conservation ledger
    ``(tick, arrivals, admitted, shed, expired, waiting)`` the property
    suite asserts ``arrivals == admitted + shed + expired + waiting``
    over.
    """
    if isinstance(mix, str):
        mix = get_mix(mix)
    gen = LoadGen(mix, seed)
    by_name: dict[str, Arrival] = {}
    admitted: dict[str, int] = {}      # name -> tick admitted
    remaining: dict[str, int] = {}     # name -> service ticks left
    shed: set[str] = set()
    expired: set[str] = set()
    waits: list[int] = []
    completed = 0
    events: list[tuple[str, str]] = []
    prior_cb = engine.waiter_callback

    def recorder(name, ev):
        events.append((name, ev))
        if prior_cb is not None:
            prior_cb(name, ev)

    engine.waiter_callback = recorder
    per_tick = []
    try:
        for t in range(ticks):
            for a in gen.arrivals(t):
                by_name[a.name] = a
                leases = engine.open_tenant(
                    a.name, a.batch, deadline=a.deadline,
                    priority=a.priority, klass=a.klass)
                if leases is not None:           # admitted on the spot
                    admitted[a.name] = t
                    remaining[a.name] = a.lifetime
                    waits.append(0)
            engine.schedule_tick()               # ages + drains the queue
            # Fold the tick's terminal events into the ledger.
            for name, ev in events:
                if ev == "admitted" and name not in admitted:
                    a = by_name[name]
                    admitted[name] = t
                    remaining[name] = a.lifetime
                    waits.append(t - a.tick)
                elif ev == "shed":
                    shed.add(name)
                elif ev == "expired":
                    expired.add(name)
            events.clear()
            # Retire tenants whose service lifetime has lapsed (tenants
            # admitted this tick start counting down next tick).
            for name in list(remaining):
                if admitted.get(name) != t:      # admitted before this tick
                    remaining[name] -= 1
            for name in [n for n, left in remaining.items() if left <= 0]:
                del remaining[name]
                engine.close_tenant(name)        # may admit waiters ...
                completed += 1
            for name, ev in events:              # ... observed here
                if ev == "admitted" and name not in admitted:
                    a = by_name[name]
                    admitted[name] = t
                    remaining[name] = a.lifetime
                    waits.append(t - a.tick)
            events.clear()
            if trace:
                per_tick.append({
                    "tick": t, "arrivals": len(by_name),
                    "admitted": len(admitted), "shed": len(shed),
                    "expired": len(expired),
                    "waiting": len(engine.tenant_queue.items)})
    finally:
        engine.waiter_callback = prior_cb
    tel = engine.transfer_telemetry()
    rep = engine.last_report
    n_arr = len(by_name)
    n_dead = sum(1 for a in by_name.values() if a.deadline is not None)
    misses = tel.get("deadline_misses", 0) if tel else 0
    out = {
        "mix": mix.name, "strategy": engine.admission_strategy,
        "seed": seed, "ticks": ticks,
        "arrivals": n_arr, "admitted": len(admitted), "shed": len(shed),
        "expired": len(expired),
        "waiting": len(engine.tenant_queue.items),
        "completed": completed,
        "shed_rate": len(shed) / n_arr if n_arr else 0.0,
        "expiry_rate": len(expired) / n_arr if n_arr else 0.0,
        "p50_wait": _quantile(waits, 0.5),
        "p99_wait": _quantile(waits, 0.99),
        "deadline_arrivals": n_dead,
        "deadline_misses": misses,
        "miss_rate": misses / n_dead if n_dead else 0.0,
        "circuits_per_window": 0.0 if rep is None else rep.avg_inflight,
        "max_inflight": 0 if rep is None else rep.max_inflight,
        "stall_cycles": 0 if rep is None else rep.stall_cycles,
        "requests": 0 if rep is None else rep.n_requests,
        "scheduled": 0 if rep is None else rep.n_scheduled,
    }
    if trace:
        out["per_tick"] = per_tick
    return out


__all__ = ["MIXES", "Arrival", "ArrivalMix", "CacheStub", "ClassSpec",
           "LoadGen", "drive", "get_mix", "make_slo_engine"]
