"""Trace-driven open-loop load generation for the serving engine.

The paper's headline claim is throughput under *concurrent* transfer
load; the serving analogue is admission behavior under sustained tenant
churn.  This module drives an :class:`~repro.serving.engine.Engine`
tick-by-tick with a seeded open-loop arrival process — arrivals do not
wait for completions, exactly like real traffic — and measures what the
ROADMAP's "millions of users" story needs measured: p50/p99 admission
latency, shed and expiry rates, deadline-miss rates per admission
strategy, and circuits-per-window on the NoM fabric underneath.

Three building blocks:

* :class:`ArrivalMix` — a declarative traffic description: the arrival
  *process* (``"poisson"`` | ``"bursty"`` | ``"heavy_tail"``), the mean
  rate, an optional diurnal ramp, and the service-class table
  (:class:`ClassSpec`: share, priority, deadline slack, lifetime) each
  arrival is drawn from.  :func:`get_mix` serves the built-ins
  (:data:`MIXES`): ``poisson``, ``bursty``, ``heavy_tail``, and the
  overloaded ``deadline_heavy`` mix the SLO benchmark gates on.
* :class:`LoadGen` — the seeded generator: ``arrivals(tick)`` yields the
  tick's :class:`Arrival` records deterministically (one stream of
  draws, consumed in tick order, so a fixed ``(mix, seed)`` pair always
  produces the identical trace).
* :func:`drive` — the harness: feeds a generator into an engine
  (``open_tenant`` with the arrival's ticket annotations,
  ``schedule_tick`` every tick, ``close_tenant`` when a tenant's
  lifetime lapses), observes every terminal admission event through the
  engine's ``waiter_callback``, and returns the stats record
  ``benchmarks/bench_serving_slo.py`` writes into ``BENCH_serving.json``.
  ``retry_budget > 0`` closes the loop: shed arrivals re-enter after a
  seeded exponential backoff (bounded attempts, refreshed deadlines),
  with the retry/backoff counts in the record.  Each record carries the
  per-tick conservation ledger (``arrivals == admitted + shed +
  expired + waiting + retrying`` at every tick) that
  ``tests/test_serving_slo.py`` asserts.

The default engine under test is model-free: :class:`CacheStub` exposes
only ``init_caches`` (a KV-ring + state-leaf pair per stream), so the
harness measures admission and scheduling, not matmuls —
:func:`make_slo_engine` builds the standard stub engine the tests and
the benchmark share.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.core.topology import make_topology
from repro.serving.engine import Engine


@dataclasses.dataclass(frozen=True)
class ClassSpec:
    """One service class of an :class:`ArrivalMix`.

    Attributes:
      klass: class label (lands in per-class telemetry).
      weight: relative share of arrivals drawn from this class.
      priority: static utility weight for the ``priority``/``hybrid``
        admission strategies.
      deadline_slack: ``(lo, hi)`` inclusive tick range — each arrival's
        admission deadline is ``tick + U[lo, hi]``; ``None`` means the
        class carries no admission SLO.
      lifetime: ``(lo, hi)`` inclusive range of service ticks an
        admitted tenant stays open before the driver closes it.
    """
    klass: str
    weight: float
    priority: float = 1.0
    deadline_slack: tuple[int, int] | None = None
    lifetime: tuple[int, int] = (2, 5)


@dataclasses.dataclass(frozen=True)
class ArrivalMix:
    """A reproducible open-loop traffic description.

    Attributes:
      name: mix label (keys the benchmark record).
      process: ``"poisson"`` (memoryless), ``"bursty"`` (a low poisson
        baseline plus a large burst every ``burst_every`` ticks), or
        ``"heavy_tail"`` (poisson baseline plus Pareto-sized arrival
        clumps with probability ``tail_prob`` per tick).
      rate: mean arrivals per tick before the diurnal ramp.
      classes: the service-class table arrivals are drawn from.
      burst_every / burst_mult: bursty-process shape.
      tail_prob / tail_alpha / tail_cap: heavy-tail shape (Pareto index
        ``tail_alpha``, clump size capped at ``tail_cap``).
      diurnal_period / diurnal_amp: sinusoidal rate ramp — the rate at
        tick t is ``rate * (1 + amp * sin(2 pi t / period))``; period 0
        disables the ramp.
    """
    name: str
    process: str
    rate: float
    classes: tuple[ClassSpec, ...]
    burst_every: int = 16
    burst_mult: float = 6.0
    tail_prob: float = 0.08
    tail_alpha: float = 1.3
    tail_cap: int = 24
    diurnal_period: int = 0
    diurnal_amp: float = 0.0

    def __post_init__(self):
        if self.process not in ("poisson", "bursty", "heavy_tail"):
            raise ValueError(f"unknown arrival process {self.process!r}; "
                             "choose from ('poisson', 'bursty', "
                             "'heavy_tail')")
        if not self.classes:
            raise ValueError("an ArrivalMix needs at least one ClassSpec")


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One generated stream arrival (the loadgen's trace unit)."""
    name: str
    tick: int
    klass: str
    priority: float
    deadline: int | None
    lifetime: int
    batch: int = 1


_STANDARD_CLASSES = (
    ClassSpec("latency", weight=0.3, priority=4.0, deadline_slack=(2, 6),
              lifetime=(1, 3)),
    ClassSpec("standard", weight=0.5, priority=1.0, deadline_slack=None,
              lifetime=(2, 5)),
    ClassSpec("bulk", weight=0.2, priority=0.25, deadline_slack=None,
              lifetime=(4, 8)),
)

#: Built-in arrival mixes (get_mix).  The first three are the paper-style
#: traffic shapes; ``deadline_heavy`` is the sustained-overload mix the
#: benchmark's fifo-vs-deadline dominance gate runs on: most arrivals
#: carry tight admission deadlines, so queue *order* decides the miss
#: rate.
MIXES: dict[str, ArrivalMix] = {
    "poisson": ArrivalMix("poisson", "poisson", rate=2.0,
                          classes=_STANDARD_CLASSES,
                          diurnal_period=64, diurnal_amp=0.5),
    "bursty": ArrivalMix("bursty", "bursty", rate=2.0,
                         classes=_STANDARD_CLASSES,
                         burst_every=16, burst_mult=6.0),
    "heavy_tail": ArrivalMix("heavy_tail", "heavy_tail", rate=1.5,
                             classes=_STANDARD_CLASSES,
                             tail_prob=0.1, tail_alpha=1.3, tail_cap=24),
    "deadline_heavy": ArrivalMix(
        "deadline_heavy", "poisson", rate=3.0,
        classes=(
            ClassSpec("urgent", weight=0.6, priority=4.0,
                      deadline_slack=(2, 5), lifetime=(1, 3)),
            ClassSpec("bulk", weight=0.4, priority=0.5,
                      deadline_slack=None, lifetime=(4, 9)),
        )),
}


def get_mix(name: str) -> ArrivalMix:
    """Look up a built-in mix; unknown names raise ``ValueError``
    listing what exists."""
    try:
        return MIXES[name]
    except KeyError:
        raise ValueError(f"unknown arrival mix {name!r}; built-ins: "
                         f"{', '.join(MIXES)}") from None


class LoadGen:
    """Seeded open-loop arrival generator over an :class:`ArrivalMix`.

    One private RNG stream, consumed strictly in tick order: call
    :meth:`arrivals` once per tick, ticks ascending (enforced), and a
    fixed ``(mix, seed)`` pair replays the identical trace — the
    determinism property ``tests/test_serving_slo.py`` pins.
    """

    def __init__(self, mix: ArrivalMix, seed: int = 0):
        self.mix = mix
        self.seed = seed
        self._rng = np.random.default_rng(
            (int(seed), zlib.crc32(mix.name.encode())))
        self._seq = 0
        self._next_tick = 0
        w = np.array([c.weight for c in mix.classes], float)
        self._class_p = w / w.sum()
        # Per-class lookup tables for the batched draws: one fancy-index
        # per annotation instead of one rng call per arrival.
        self._dl_has = np.array([c.deadline_slack is not None
                                 for c in mix.classes])
        self._dl_lo = np.array([c.deadline_slack[0] if c.deadline_slack
                                else 0 for c in mix.classes], np.int64)
        self._dl_span = np.array(
            [c.deadline_slack[1] - c.deadline_slack[0] + 1
             if c.deadline_slack else 1 for c in mix.classes], np.int64)
        self._life_lo = np.array([c.lifetime[0] for c in mix.classes],
                                 np.int64)
        self._life_span = np.array(
            [c.lifetime[1] - c.lifetime[0] + 1 for c in mix.classes],
            np.int64)

    def rate_at(self, tick: int) -> float:
        """Instantaneous mean arrival rate at ``tick`` (diurnal ramp
        applied; never negative)."""
        mix = self.mix
        if not mix.diurnal_period:
            return mix.rate
        phase = 2.0 * np.pi * tick / mix.diurnal_period
        return max(0.0, mix.rate * (1.0 + mix.diurnal_amp * np.sin(phase)))

    def _count(self, tick: int) -> int:
        mix, rng = self.mix, self._rng
        rate = self.rate_at(tick)
        if mix.process == "poisson":
            return int(rng.poisson(rate))
        if mix.process == "bursty":
            n = int(rng.poisson(rate * 0.4))
            if mix.burst_every and tick % mix.burst_every == 0:
                n += int(rng.poisson(rate * mix.burst_mult))
            return n
        # heavy_tail: light baseline + occasional Pareto-sized clump.
        n = int(rng.poisson(rate * 0.5))
        if rng.random() < mix.tail_prob:
            n += min(mix.tail_cap, 1 + int(rng.pareto(mix.tail_alpha)
                                           * mix.rate))
        return n

    def arrivals(self, tick: int) -> list[Arrival]:
        """The arrivals landing at ``tick`` (possibly empty).  Must be
        called with strictly increasing ticks — the draw stream is the
        determinism contract.

        The tick's annotations are drawn as three batched RNG calls
        (class indices, deadline uniforms, lifetime uniforms) plus
        per-class table lookups — not one rng round-trip per arrival —
        so a burst of hundreds of arrivals costs the same number of
        generator calls as a quiet tick.  A fixed ``(mix, seed)`` pair
        still replays the identical trace."""
        if tick < self._next_tick:
            raise ValueError(f"arrivals() must be called in tick order "
                             f"(got {tick} after {self._next_tick - 1})")
        self._next_tick = tick + 1
        n = self._count(tick)
        if not n:
            return []
        rng, mix = self._rng, self.mix
        kidx = rng.choice(len(mix.classes), size=n, p=self._class_p)
        # lo + floor(u * span) is uniform over [lo, hi] inclusive.
        deadlines = tick + self._dl_lo[kidx] + (
            rng.random(n) * self._dl_span[kidx]).astype(np.int64)
        lifetimes = self._life_lo[kidx] + (
            rng.random(n) * self._life_span[kidx]).astype(np.int64)
        has_dl = self._dl_has[kidx]
        seq0 = self._seq
        self._seq += n
        return [Arrival(
            name=f"{mix.name}-{seq0 + j}", tick=tick,
            klass=mix.classes[k].klass, priority=mix.classes[k].priority,
            deadline=int(deadlines[j]) if has_dl[j] else None,
            lifetime=int(lifetimes[j]))
            for j, k in enumerate(kidx)]


class CacheStub:
    """Model stub exposing only ``init_caches``: one KV ring leaf (size
    scales with ``max_len``) plus one in-place state leaf per stream —
    the smallest footprint that still exercises ring evictions and
    teardown scrubs (2 leased banks per tenant)."""

    def init_caches(self, batch, max_len):
        import jax.numpy as jnp
        return {"kv": jnp.zeros((batch, max_len, 16), jnp.int8),
                "state": jnp.zeros((batch, 32), jnp.int8)}


def make_slo_engine(admission_strategy: str = "fifo", *,
                    mesh: tuple[int, int, int] = (4, 4, 2),
                    deadline_ticks: int = 12, tenant_queue_depth: int = 16,
                    **kw) -> Engine:
    """The standard harness engine: a :class:`CacheStub` model over a
    small bank mesh (capacity ~``X*Y*(Z-1)/2`` concurrent tenants, so
    the built-in mixes genuinely overload it), queue admission with
    aging, and the given admission strategy.  Extra kwargs pass through
    to :class:`~repro.serving.engine.Engine`."""
    kw.setdefault("ring_slots", 4)
    kw.setdefault("idle_evict_ticks", 0)
    return Engine(model=CacheStub(), cfg=None, max_len=16,
                  cache_mesh=make_topology(mesh=mesh),
                  admission="queue", admission_strategy=admission_strategy,
                  deadline_ticks=deadline_ticks,
                  tenant_queue_depth=tenant_queue_depth, **kw)


def _quantile(samples: list[int], q: float) -> float:
    if not samples:
        return 0.0
    return float(np.quantile(np.asarray(samples, float), q))


def drive(engine: Engine, mix: ArrivalMix | str, ticks: int,
          seed: int = 0, trace: bool = False, retry_budget: int = 0,
          backoff_base: int = 1, backoff_cap: int = 16) -> dict:
    """Drive ``engine`` with ``mix`` for ``ticks`` engine ticks.

    Open loop by default: every generated arrival is offered to
    ``open_tenant`` with its ticket annotations (deadline/priority/
    klass) regardless of how loaded the engine is; admitted tenants run
    for their drawn lifetime (their cache traffic scheduled by the
    engine's per-tick batch) and are then closed, freeing capacity for
    queued waiters.  The engine's ``waiter_callback`` is borrowed for
    the run (the prior callback is restored on exit) to observe the
    terminal admission events.

    ``retry_budget > 0`` closes the loop: a *shed* arrival re-enters
    after a seeded exponential backoff — attempt ``k`` waits a uniform
    ``1..min(backoff_cap, backoff_base * 2**k)`` ticks, drawn from a
    dedicated RNG stream so enabling retries never perturbs the arrival
    trace — up to ``retry_budget`` re-attempts before the shed is
    final.  A retried ticket's admission deadline is refreshed by the
    arrival's original slack; queue expiries never retry (the client's
    deadline has passed — there is nothing left to serve).

    The per-tick bookkeeping is O(events), not O(live tenants): admitted
    streams land in a due-tick completion bucket (closed when their
    lifetime lapses) instead of a per-tenant countdown scan, and
    terminal outcomes are counters — the harness itself stays off the
    profile at the tenant counts the vectorized control plane serves.

    Returns the stats record: totals (``arrivals`` / ``admitted`` /
    ``shed`` / ``expired`` / ``waiting`` / ``completed``), rates
    (``shed_rate`` / ``expiry_rate``), admission-latency percentiles in
    ticks (``p50_wait`` / ``p99_wait``, measured from the *original*
    arrival tick, so a retried admit reports the client-experienced
    wait), the SLO ledger (``deadline_arrivals`` / ``deadline_misses``
    / ``miss_rate``), the closed-loop ledger (``retry_budget`` /
    ``retries`` — backoff re-entries scheduled — / ``retry_admitted``
    — streams admitted only after retrying — / ``backoff_ticks`` —
    total ticks spent in backoff — / ``retrying`` — still in backoff at
    run end), and fabric-side concurrency (``circuits_per_window`` =
    average circuits in flight per TDM window, ``max_inflight``,
    ``stall_cycles``, ``requests`` / ``scheduled``).  With
    ``trace=True`` the record also carries ``per_tick`` — the
    conservation ledger ``(tick, arrivals, admitted, shed, expired,
    waiting, retrying)`` the property suite asserts ``arrivals ==
    admitted + shed + expired + waiting + retrying`` over.
    """
    if isinstance(mix, str):
        mix = get_mix(mix)
    gen = LoadGen(mix, seed)
    retry_rng = np.random.default_rng(
        (int(seed), zlib.crc32(mix.name.encode()), 0xB0FF))
    pending: dict[str, Arrival] = {}   # queued or in backoff
    attempts: dict[str, int] = {}      # retries used so far
    in_backoff: set[str] = set()
    due: dict[int, list[str]] = {}     # close tick -> admitted names
    retry_at: dict[int, list[str]] = {}
    n_arrivals = n_admitted = n_shed = n_expired = completed = 0
    n_dead = n_retries = n_retry_admitted = backoff_ticks = 0
    waits: list[int] = []
    events: list[tuple[str, str]] = []
    prior_cb = engine.waiter_callback

    def recorder(name, ev):
        events.append((name, ev))
        if prior_cb is not None:
            prior_cb(name, ev)

    def admit(name: str, t: int) -> None:
        nonlocal n_admitted, n_retry_admitted
        a = pending.pop(name, None)
        if a is None:
            return
        n_admitted += 1
        if attempts.pop(name, 0):
            n_retry_admitted += 1
        waits.append(t - a.tick)
        due.setdefault(t + a.lifetime, []).append(name)

    def fold(t: int) -> None:
        nonlocal n_shed, n_expired, n_retries, backoff_ticks
        for name, ev in events:
            if ev == "admitted":
                admit(name, t)
            elif ev == "shed":
                used = attempts.get(name, 0)
                if used < retry_budget:
                    window = min(backoff_cap, backoff_base * 2 ** used)
                    delay = 1 + int(retry_rng.integers(0, max(1, window)))
                    attempts[name] = used + 1
                    n_retries += 1
                    backoff_ticks += delay
                    retry_at.setdefault(t + delay, []).append(name)
                    in_backoff.add(name)
                else:
                    n_shed += 1
                    pending.pop(name, None)
                    attempts.pop(name, None)
            elif ev == "expired":
                n_expired += 1
                pending.pop(name, None)
                attempts.pop(name, None)
        events.clear()

    engine.waiter_callback = recorder
    per_tick = []
    try:
        for t in range(ticks):
            # Backed-off sheds re-enter first (deadline refreshed by the
            # arrival's original slack), then the tick's fresh arrivals.
            for name in retry_at.pop(t, ()):
                in_backoff.discard(name)
                a = pending[name]
                deadline = (None if a.deadline is None
                            else t + (a.deadline - a.tick))
                if engine.open_tenant(name, a.batch, deadline=deadline,
                                      priority=a.priority,
                                      klass=a.klass) is not None:
                    admit(name, t)
            for a in gen.arrivals(t):
                n_arrivals += 1
                n_dead += a.deadline is not None
                pending[a.name] = a
                if engine.open_tenant(
                        a.name, a.batch, deadline=a.deadline,
                        priority=a.priority, klass=a.klass) is not None:
                    admit(a.name, t)         # admitted on the spot
            engine.schedule_tick()           # ages + drains the queue
            fold(t)
            # Retire tenants whose service lifetime lapsed this tick
            # (admitted at t with lifetime L -> closed at t + L).
            for name in due.pop(t, ()):
                engine.close_tenant(name)    # may admit waiters ...
                completed += 1
            fold(t)                          # ... observed here
            if trace:
                per_tick.append({
                    "tick": t, "arrivals": n_arrivals,
                    "admitted": n_admitted, "shed": n_shed,
                    "expired": n_expired,
                    "waiting": len(engine.tenant_queue.items),
                    "retrying": len(in_backoff)})
    finally:
        engine.waiter_callback = prior_cb
    tel = engine.transfer_telemetry()
    rep = engine.last_report
    misses = tel.get("deadline_misses", 0) if tel else 0
    out = {
        "mix": mix.name, "strategy": engine.admission_strategy,
        "seed": seed, "ticks": ticks,
        "arrivals": n_arrivals, "admitted": n_admitted, "shed": n_shed,
        "expired": n_expired,
        "waiting": len(engine.tenant_queue.items),
        "completed": completed,
        "shed_rate": n_shed / n_arrivals if n_arrivals else 0.0,
        "expiry_rate": n_expired / n_arrivals if n_arrivals else 0.0,
        "p50_wait": _quantile(waits, 0.5),
        "p99_wait": _quantile(waits, 0.99),
        "deadline_arrivals": n_dead,
        "deadline_misses": misses,
        "miss_rate": misses / n_dead if n_dead else 0.0,
        "retry_budget": retry_budget,
        "retries": n_retries,
        "retry_admitted": n_retry_admitted,
        "backoff_ticks": backoff_ticks,
        "retrying": len(in_backoff),
        "circuits_per_window": 0.0 if rep is None else rep.avg_inflight,
        "max_inflight": 0 if rep is None else rep.max_inflight,
        "stall_cycles": 0 if rep is None else rep.stall_cycles,
        "requests": 0 if rep is None else rep.n_requests,
        "scheduled": 0 if rep is None else rep.n_scheduled,
    }
    if trace:
        out["per_tick"] = per_tick
    return out


__all__ = ["MIXES", "Arrival", "ArrivalMix", "CacheStub", "ClassSpec",
           "LoadGen", "drive", "get_mix", "make_slo_engine"]
