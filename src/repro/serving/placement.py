"""Multi-tenant cache placement and eviction for the serving engine.

The engine's decode caches live on the NoM bank mesh: every cache leaf is
*homed* on a DRAM bank, and its per-step updates stream from the logic-die
staging bank of the home column up to the home (see ``docs/serving.md``).
This module owns the *where*: a :class:`BankPool` leases bank homes to
tenants — one tenant per concurrent ``generate`` stream — under a
placement policy, and turns cache-lifecycle events into scheduler traffic:

* **per-step flush** (:func:`step_requests`) — one ``copy`` transfer per
  leaf, staging → home, exactly the engine's previous static behaviour;
* **ring-buffer overwrite** — once a ring leaf's write position wraps its
  capacity, the incoming line lands on an occupied slot; the overwritten
  slot is scrubbed *in place* first, an INIT-class transfer
  (:class:`~repro.core.scheduler.TransferRequest` with ``op="init"``,
  ``src == dst``) that the TDM backend realizes as a zero-hop circuit;
* **tenant teardown** (:func:`teardown_requests`) — releasing a tenant
  scrubs every leased home with one INIT covering the leaf's full
  footprint (the OS-service bulk-initialization class that RowClone
  accelerates in-DRAM);
* **stall-driven repacking** (:meth:`BankPool.repack`) — the engine feeds
  ``ScheduleReport.stall_cycles`` back; a tenant whose circuits queue too
  long is re-homed onto the least-loaded columns, and the vacated homes
  are scrubbed with INITs (eviction traffic through the same scheduler).

All of it rides the same batched :class:`~repro.core.fabric.NomFabric`
session (``Engine.fabric``) as the copy traffic, so copy and INIT
circuits compete for (and are reported over) one TDM fabric — the
paper's mixed copy/initialization workload.  On exhaustion the engine
routes tenant admission through the fabric's overflow semantics
(queue/shed/raise with idle-lease reclaim) rather than surfacing this
module's ``RuntimeError``.

Placement policies (:data:`PLACEMENT_POLICIES`):

* ``"spread"`` — the classic strided spread: homes stride over the
  DRAM-layer pool with a step coprime to the pool size, so consecutive
  leaves land on different columns.  Tenants interleave freely; isolation
  is probabilistic.
* ``"partition"`` — per-tenant column partitioning: each tenant owns a
  disjoint set of (x, y) columns and its homes cycle through them.
  Cache-flush circuits are purely vertical (staging at z=0 → home in the
  same column), so *tenants' circuits are link-disjoint by construction*.
  On a single-layer mesh, where circuits run horizontally from the row's
  edge staging bank, the partitioned unit is the *row* — the guarantee
  holds with rows as the isolation groups.
* ``"stall_feedback"`` — places like ``"spread"`` but repacks: when the
  engine observes accumulated ``stall_cycles`` above its threshold it
  calls :meth:`BankPool.repack`, which re-leases the tenant onto the
  least-loaded columns and returns the vacated leases for scrubbing.
"""
from __future__ import annotations

import dataclasses

from repro.core.scheduler import TransferRequest
from repro.core.topology import Mesh3D, StackedTopology

PLACEMENT_POLICIES = ("spread", "partition", "stall_feedback")


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Placement-relevant description of one cache leaf.

    Attributes:
      tag: caller label (the engine uses the pytree key path).
      step_bytes: bytes the leaf moves per decode step (the size slope for
        ring leaves; the whole state for in-place leaves).
      lease_bytes: full footprint scrubbed at teardown (>= step_bytes;
        0 falls back to step_bytes).
      ring_slots: ring capacity in token slots — writes at positions >=
        ring_slots overwrite live slots and emit eviction INITs; 0 marks
        an in-place state leaf (SSM / RG-LRU) that never wraps.
    """
    tag: str
    step_bytes: int
    lease_bytes: int = 0
    ring_slots: int = 0


@dataclasses.dataclass(frozen=True)
class Lease:
    """One leased bank home: ``tenant`` holds ``home`` for ``leaf``;
    per-step traffic stages at ``staging`` (the z=0 bank of the home
    column, i.e. the vault controller's landing bank)."""
    tenant: str
    leaf: LeafSpec
    home: int
    staging: int


class BankPool:
    """Leases bank homes on a :class:`~repro.core.topology.Mesh3D` to
    tenants under a placement policy — the multi-tenant replacement for
    the engine's old static per-leaf spread.

    The leasable pool is the DRAM layers (z >= 1); on a single-layer mesh
    the whole plane is leasable and staging sits at the row's edge bank.
    A bank is leased to at most one tenant at a time (never double-leased;
    asserted on every grant), and :meth:`release` must free it before it
    can be re-leased.

    The pool also accepts a :class:`~repro.core.topology.StackedTopology`:
    homes are then *global* bank ids spanning every stack, placement
    groups are per-stack columns (a tenant partitioned into stack 0 never
    shares a group with one in stack 1), :meth:`lease` can pin a tenant
    to a subset of stacks, and :meth:`migrate` re-homes a whole tenant
    onto another stack (the engine turns the move into cross-stack COPY
    circuits plus teardown INITs over the vacated homes).
    """

    def __init__(self, mesh: Mesh3D | StackedTopology,
                 policy: str = "spread"):
        if policy not in PLACEMENT_POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {PLACEMENT_POLICIES}")
        self.topology = mesh
        self._stacked = isinstance(mesh, StackedTopology)
        self._meshes = mesh.stacks if self._stacked else (mesh,)
        self.mesh = self._meshes[0]
        self.policy = policy
        self._pool: list[int] = []
        self._single: list[bool] = []           # per stack: Z == 1 fallback
        self._group_off: list[int] = []         # per stack: first group id
        groups = 0
        for s, m in enumerate(self._meshes):
            off = mesh.offsets[s] if self._stacked else 0
            plane = m.X * m.Y
            dram = list(range(plane, m.n_nodes))
            self._single.append(not dram)
            self._pool.extend(off + b for b in (dram or range(plane)))
            self._group_off.append(groups)
            # multi-layer stacks have X*Y column groups, single-layer Y rows
            groups += m.X * m.Y if dram else m.Y
        self._single_layer = self._single[0]
        self._owner: dict[int, str] = {}        # bank -> tenant
        self._leased: dict[str, list[Lease]] = {}
        self._col_owner: dict[int, str] = {}    # group -> tenant (partition)
        self._lease_seq = 0                     # rotates spread start points

    # -- geometry helpers -------------------------------------------------
    def _locate(self, bank: int) -> tuple[int, int]:
        return self.topology.locate(bank) if self._stacked else (0, bank)

    def _gid(self, stack: int, local: int) -> int:
        return self.topology.global_id(stack, local) if self._stacked \
            else local

    def stack_of(self, bank: int) -> int:
        """Stack index owning global bank id ``bank`` (0 on a bare mesh)."""
        return self._locate(bank)[0]

    def _staging_for(self, home: int) -> int:
        stack, local = self._locate(home)
        m = self._meshes[stack]
        x, y, _z = m.coords(local)
        if self._single[stack]:
            return self._gid(stack, m.node_id(0, y, 0))
        return self._gid(stack, m.node_id(x, y, 0))

    def _column(self, bank: int) -> int:
        """Path-confining placement group of a bank: the (x, y) column on
        a multi-layer mesh (cache-flush circuits are vertical), the *row*
        on a single-layer mesh (circuits run along the row from the edge
        staging bank) — the unit the partition policy isolates by and
        :meth:`column_load` counts over.  Groups never span stacks: each
        stack gets a disjoint group-id range."""
        stack, local = self._locate(bank)
        m = self._meshes[stack]
        g = m.coords(local)[1] if self._single[stack] else m.column_of(local)
        return self._group_off[stack] + g

    def _n_groups(self) -> int:
        last = len(self._meshes) - 1
        m = self._meshes[last]
        n = m.Y if self._single[last] else m.X * m.Y
        return self._group_off[last] + n

    def _free_in_column(self, col: int,
                        allowed: set[int] | None = None) -> list[int]:
        return [b for b in self._pool
                if self._column(b) == col and b not in self._owner
                and (allowed is None or b in allowed)]

    # -- candidate orders per policy ---------------------------------------
    def _spread_order(self, seq: int, i: int):
        # Lazy: callers take the first free candidate, so materializing
        # the full rotation per leaf is O(pool) work for nothing.
        n = len(self._pool)
        start = (seq * 13 + i * 37 + 11) % n
        return (self._pool[(start + k) % n] for k in range(n))

    def _partition_candidate(self, tenant: str,
                             allowed: set[int] | None = None) -> int | None:
        """Next home in the tenant's owned groups, acquiring a fresh
        unowned group when the owned ones are exhausted."""
        owned = [c for c, t in self._col_owner.items() if t == tenant]
        # Prefer the owned group with the most free banks (fill evenly).
        for col in sorted(owned,
                          key=lambda c: -len(self._free_in_column(c,
                                                                  allowed))):
            free = self._free_in_column(col, allowed)
            if free:
                return free[0]
        for col in range(self._n_groups()):
            if col not in self._col_owner and self._free_in_column(col,
                                                                   allowed):
                self._col_owner[col] = tenant
                return self._free_in_column(col, allowed)[0]
        return None

    def _least_loaded_order(self, avoid: set[int],
                            allowed: set[int] | None = None) -> list[int]:
        load = self.column_load()
        return sorted((b for b in self._pool if b not in self._owner
                       and (allowed is None or b in allowed)),
                      key=lambda b: (self._column(b) in avoid,
                                     load.get(self._column(b), 0),
                                     b))

    def _pick_home(self, tenant: str, i: int, policy: str, seq: int,
                   avoid: set[int] | None = None,
                   allowed: set[int] | None = None) -> int:
        if policy == "partition":
            home = self._partition_candidate(tenant, allowed)
        elif avoid is not None:     # repack: prefer away from hot columns
            order = self._least_loaded_order(avoid, allowed)
            home = order[0] if order else None
        else:                       # spread / stall_feedback initial
            home = next((b for b in self._spread_order(seq, i)
                         if b not in self._owner
                         and (allowed is None or b in allowed)), None)
        if home is None:
            raise RuntimeError(f"bank pool exhausted leasing for {tenant!r} "
                               f"({len(self._owner)}/{len(self._pool)} "
                               f"banks leased)")
        return home

    # -- public API ---------------------------------------------------------
    def lease(self, tenant: str, leaves: list[LeafSpec],
              _avoid: set[int] | None = None,
              stacks: set[int] | None = None) -> list[Lease]:
        """Lease one home bank per leaf to ``tenant`` under the pool's
        policy.  Returns the leases in leaf order; raises ``RuntimeError``
        when the pool is exhausted.  A tenant may lease repeatedly (e.g.
        after :meth:`release`); banks are never double-leased.  On a
        stacked topology ``stacks`` pins the grant to those stack indices
        (every home drawn from them); ``None`` means any stack."""
        seq = self._lease_seq
        self._lease_seq = (self._lease_seq + 1) % max(1, len(self._pool))
        cols_before = {c for c, t in self._col_owner.items() if t == tenant}
        allowed = None
        if stacks is not None:
            want = set(stacks)
            bad = want - set(range(len(self._meshes)))
            if bad:
                raise ValueError(f"unknown stack indices {sorted(bad)} "
                                 f"(pool has {len(self._meshes)} stacks)")
            allowed = {b for b in self._pool if self.stack_of(b) in want}
        # Exhaustion short-circuit: success needs one free bank per leaf
        # (necessary under every policy — the all-or-nothing rollback
        # below would fire anyway), so an infeasible lease fails in O(1)
        # instead of scanning the pool per leaf first.
        if allowed is None and self.free_banks() < len(leaves):
            raise RuntimeError(f"bank pool exhausted leasing for "
                               f"{tenant!r} ({len(self._owner)}/"
                               f"{len(self._pool)} banks leased)")
        out = []
        try:
            for i, leaf in enumerate(leaves):
                home = self._pick_home(tenant, i, self.policy, seq,
                                       avoid=_avoid, allowed=allowed)
                assert home not in self._owner, "double lease"
                self._owner[home] = tenant
                out.append(Lease(tenant=tenant, leaf=leaf, home=home,
                                 staging=self._staging_for(home)))
        except RuntimeError:
            # All-or-nothing admission: a failed lease must not shrink
            # the pool — roll back this call's grants (banks and any
            # partition groups acquired along the way).
            for ls in out:
                del self._owner[ls.home]
            for col in [c for c, t in self._col_owner.items()
                        if t == tenant and c not in cols_before]:
                del self._col_owner[col]
            raise
        self._leased.setdefault(tenant, []).extend(out)
        return out

    def release(self, tenant: str) -> list[Lease]:
        """Free every bank leased to ``tenant`` and return the vacated
        leases — the caller turns them into teardown INIT scrubs via
        :func:`teardown_requests`."""
        out = self._leased.pop(tenant, [])
        for ls in out:
            self._owner.pop(ls.home, None)
        for col in [c for c, t in self._col_owner.items() if t == tenant]:
            del self._col_owner[col]
        return out

    def repack(self, tenant: str,
               stall_cycles: int, threshold: int = 0
               ) -> tuple[list[Lease], list[Lease]]:
        """Stall-feedback repacking: when ``stall_cycles`` exceeds
        ``threshold``, re-home ``tenant``'s leaves onto the least-loaded
        columns (avoiding its current, contended columns).  Returns
        ``(evicted, fresh)``: the vacated leases (scrub them with INITs)
        and the replacement leases.  Below the threshold returns
        ``([], [])`` and changes nothing.  Under the ``"partition"``
        policy placement is static by design — a tenant's contention is
        confined to its own groups, so re-homing cannot relieve it — and
        repack is a no-op."""
        if (stall_cycles <= threshold or tenant not in self._leased
                or self.policy == "partition"):
            return [], []
        old = self.release(tenant)
        hot = {self._column(ls.home) for ls in old}
        fresh = self.lease(tenant, [ls.leaf for ls in old], _avoid=hot)
        if {ls.home for ls in fresh} & {ls.home for ls in old}:
            # Pool pressure: the "least-loaded" order fell back onto the
            # just-vacated banks — there is nowhere better to go.  Revert
            # to the old placement and report no repack, so the caller
            # never scrubs homes that are still (again) live.
            self.release(tenant)
            for ls in old:
                assert ls.home not in self._owner
                self._owner[ls.home] = tenant
            self._leased[tenant] = list(old)
            return [], []
        return old, fresh

    def _group_stack(self, group: int) -> int:
        """Stack whose group-id range contains ``group``."""
        s = 0
        while s + 1 < len(self._group_off) and group >= self._group_off[s + 1]:
            s += 1
        return s

    def migrate(self, tenant: str,
                dst_stack: int) -> tuple[list[Lease], list[Lease]]:
        """Re-home ``tenant``'s off-stack leases onto stack ``dst_stack``.

        Leases already on ``dst_stack`` stay exactly where they are (no
        pointless copy, and their homes are never at risk of a teardown
        scrub).  Returns ``(old, fresh)`` in matched leaf order for the
        leases that moved: the engine copies each ``old[i].home`` →
        ``fresh[i].home`` (cross-stack COPY circuits through the SerDes
        links) and scrubs the vacated homes with teardown INITs.
        Returns ``([], [])`` — with placement unchanged — when the
        tenant holds nothing, already lives entirely on ``dst_stack``,
        or the destination stack cannot fit the moving leases
        (all-or-nothing: a failed migration rolls back every grant and
        group acquisition, leaving the original placement intact)."""
        if not (0 <= dst_stack < len(self._meshes)):
            raise ValueError(f"stack {dst_stack} out of range "
                             f"[0, {len(self._meshes)})")
        held = self.leases(tenant)
        moving = [ls for ls in held
                  if self.stack_of(ls.home) != dst_stack]
        if not moving:
            return [], []
        owner_snap = dict(self._owner)
        leased_snap = {t: list(v) for t, v in self._leased.items()}
        col_snap = dict(self._col_owner)
        # Partially release: only the moving homes, and only the
        # partition groups on stacks the tenant is leaving.
        for ls in moving:
            self._owner.pop(ls.home, None)
        self._leased[tenant] = [ls for ls in held if ls not in moving]
        for col in [c for c, t in self._col_owner.items()
                    if t == tenant and self._group_stack(c) != dst_stack]:
            del self._col_owner[col]
        try:
            fresh = self.lease(tenant, [ls.leaf for ls in moving],
                               stacks={dst_stack})
        except RuntimeError:
            self._owner = owner_snap
            self._leased = leased_snap
            self._col_owner = col_snap
            return [], []
        return moving, fresh

    def leases(self, tenant: str) -> list[Lease]:
        """Current leases held by ``tenant`` (empty list when none)."""
        return list(self._leased.get(tenant, []))

    def stack_load(self) -> dict[int, int]:
        """Leased banks per stack index — the coarse map
        :meth:`migrate` balances against (``{0: n}`` on a bare mesh)."""
        load: dict[int, int] = {}
        for bank in self._owner:
            s = self.stack_of(bank)
            load[s] = load.get(s, 0) + 1
        return load

    def column_load(self) -> dict[int, int]:
        """Leased banks per placement group — the (x, y) column on a
        multi-layer mesh, the row on a single-layer one — the contention
        map the stall-feedback policy packs against."""
        load: dict[int, int] = {}
        for bank in self._owner:
            col = self._column(bank)
            load[col] = load.get(col, 0) + 1
        return load

    def free_banks(self) -> int:
        """Number of leasable banks not currently under lease."""
        return len(self._pool) - len(self._owner)


# ---------------------------------------------------------------------------
# Lifecycle events -> TransferRequests (all through the engine's NomFabric)
# ---------------------------------------------------------------------------
def step_requests(leases: list[Lease], pos: int,
                  max_extra_slots: int = 0) -> list[TransferRequest]:
    """One decode step's transfer set for ``leases`` at write position
    ``pos``: a staging → home ``copy`` per leaf, preceded — once a ring
    leaf has wrapped (``pos >= ring_slots``) — by an in-place INIT that
    scrubs the slot being overwritten (``pos % ring_slots``), the
    eviction made visible as an ``op="init"`` zero-hop circuit.  A leaf
    homed on its own staging bank is a controller-local write: no copy
    is emitted (its ring evictions still are)."""
    reqs = []
    for ls in leases:
        leaf = ls.leaf
        if leaf.ring_slots and pos >= leaf.ring_slots:
            reqs.append(TransferRequest(
                src=ls.home, dst=ls.home, nbytes=leaf.step_bytes, op="init",
                tag=(ls.tenant, leaf.tag, "evict", pos % leaf.ring_slots)))
        if ls.staging != ls.home:
            reqs.append(TransferRequest(
                src=ls.staging, dst=ls.home, nbytes=leaf.step_bytes,
                tag=(ls.tenant, leaf.tag, "copy"),
                max_extra_slots=max_extra_slots))
    return reqs


def teardown_requests(leases: list[Lease]) -> list[TransferRequest]:
    """Tenant teardown as INIT-class traffic: one in-place scrub per
    vacated home covering the leaf's full leased footprint."""
    return [TransferRequest(
        src=ls.home, dst=ls.home,
        nbytes=max(ls.leaf.lease_bytes, ls.leaf.step_bytes, 1), op="init",
        tag=(ls.tenant, ls.leaf.tag, "teardown")) for ls in leases]


__all__ = ["PLACEMENT_POLICIES", "BankPool", "LeafSpec", "Lease",
           "step_requests", "teardown_requests"]
