from .admission import (HYBRID_SLACK, STALL_PRESSURE, AdmissionContext,
                        AdmissionTicket, TicketColumns, get_admission,
                        register_admission, registered_admissions,
                        unregister_admission)
from .engine import CONTROL_PLANES, Engine
from .loadgen import (MIXES, Arrival, ArrivalMix, ClassSpec, LoadGen,
                      drive, get_mix, make_slo_engine)
from .placement import (PLACEMENT_POLICIES, BankPool, Lease, LeafSpec,
                        step_requests, teardown_requests)

__all__ = ["CONTROL_PLANES", "Engine", "BankPool", "Lease", "LeafSpec",
           "PLACEMENT_POLICIES", "step_requests", "teardown_requests",
           "HYBRID_SLACK", "STALL_PRESSURE", "AdmissionContext",
           "AdmissionTicket", "TicketColumns", "get_admission",
           "register_admission", "registered_admissions",
           "unregister_admission",
           "MIXES", "Arrival", "ArrivalMix", "ClassSpec", "LoadGen",
           "drive", "get_mix", "make_slo_engine"]
