from .admission import (HYBRID_SLACK, AdmissionContext, AdmissionTicket,
                        get_admission, register_admission,
                        registered_admissions, unregister_admission)
from .engine import Engine
from .loadgen import (MIXES, Arrival, ArrivalMix, ClassSpec, LoadGen,
                      drive, get_mix, make_slo_engine)
from .placement import (PLACEMENT_POLICIES, BankPool, Lease, LeafSpec,
                        step_requests, teardown_requests)

__all__ = ["Engine", "BankPool", "Lease", "LeafSpec", "PLACEMENT_POLICIES",
           "step_requests", "teardown_requests",
           "HYBRID_SLACK", "AdmissionContext", "AdmissionTicket",
           "get_admission", "register_admission", "registered_admissions",
           "unregister_admission",
           "MIXES", "Arrival", "ArrivalMix", "ClassSpec", "LoadGen",
           "drive", "get_mix", "make_slo_engine"]
