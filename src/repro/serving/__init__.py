from .engine import Engine

__all__ = ["Engine"]
