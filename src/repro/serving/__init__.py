from .engine import Engine
from .placement import (PLACEMENT_POLICIES, BankPool, Lease, LeafSpec,
                        step_requests, teardown_requests)

__all__ = ["Engine", "BankPool", "Lease", "LeafSpec", "PLACEMENT_POLICIES",
           "step_requests", "teardown_requests"]
