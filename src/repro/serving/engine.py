"""Serving engine: batched prefill + decode with per-arch caches.

``generate`` runs greedy decoding with a jit'd single-token step; prefill
feeds prompt tokens through the same step (cache-filling), which keeps one
compiled program for both phases — the large-scale serving shapes
(decode_32k / long_500k) are exercised via the dry-run on the production
mesh, this engine is the functional path used by tests and examples.

Decode-cache movement rides the NoM scheduler: each step's cache updates
(the new KV lines / refreshed recurrent states, one transfer per cache
leaf) are emitted as :class:`~repro.core.scheduler.TransferRequest`s and
scheduled in one batched :func:`~repro.core.scheduler.schedule_transfers`
call against the engine's bank mesh — the serving analogue of the paper's
bulk inter-bank copies.  Per-step :class:`ScheduleReport`s accumulate on
``Engine.reports`` and aggregate into ``Engine.last_report``
(circuits/window, batch sizes, stall cycles).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.scheduler import (ScheduleReport, TransferRequest,
                                  schedule_transfers)
from repro.core.slot_alloc import TdmAllocator
from repro.core.topology import Mesh3D
from repro.models.lm import CausalLM, EncDecLM


@dataclasses.dataclass
class Engine:
    model: object
    cfg: ArchConfig
    max_len: int = 256
    # NoM cache-transfer scheduling (set track_transfers=False to opt out).
    track_transfers: bool = True
    cache_mesh: Mesh3D = dataclasses.field(
        default_factory=lambda: Mesh3D(8, 8, 4))
    n_slots: int = 16
    max_extra_slots: int = 3
    keep_reports: int = 256    # recent per-step reports retained for
    #   inspection; the aggregate (last_report / n_sched_steps) is exact
    #   regardless, so a long-lived engine stays bounded

    def __post_init__(self):
        self._step = jax.jit(self._decode_one)
        self._alloc = (TdmAllocator(self.cache_mesh, self.n_slots)
                       if self.track_transfers else None)
        self._placement = None     # [(tag, src, dst, step_bytes)] per leaf
        self._next_cycle = 0       # scheduler-time anchor of the next step
        self.reports: list[ScheduleReport] = []
        self.last_report: ScheduleReport | None = None
        self.n_sched_steps = 0

    def _decode_one(self, params, token, caches, pos, memory=None):
        if isinstance(self.model, EncDecLM):
            logits, caches = self.model.decode_step(params, token, caches,
                                                    pos, memory)
        else:
            logits, caches = self.model.decode_step(params, token, caches,
                                                    pos)
        return logits, caches

    # -- cache placement / transfer planning -----------------------------------
    def _step_nbytes(self, batch: int) -> list[int]:
        """Per-decode-step movement of every cache leaf, in bytes.

        Probed by abstract evaluation at two cache lengths: a leaf whose
        size scales with ``max_len`` (KV / ring buffers) moves one
        token-slot per step (the size slope); a length-independent leaf
        (SSM / RG-LRU state) is refreshed in place every step."""
        full = jax.eval_shape(
            lambda: self.model.init_caches(batch, self.max_len))
        half_len = max(1, self.max_len // 2)
        half = jax.eval_shape(
            lambda: self.model.init_caches(batch, half_len))
        out = []
        for lf, lh in zip(jax.tree_util.tree_leaves(full),
                          jax.tree_util.tree_leaves(half)):
            nb_full = lf.size * jnp.dtype(lf.dtype).itemsize
            nb_half = lh.size * jnp.dtype(lh.dtype).itemsize
            if nb_full != nb_half and self.max_len != half_len:
                out.append(max(1, (nb_full - nb_half)
                               // (self.max_len - half_len)))
            else:
                out.append(max(1, nb_full))
        return out

    def _plan_placement(self, caches, batch: int) -> None:
        """Home every cache leaf on a bank of the 3D mesh.

        The vault controller stages incoming lines on the logic die (the
        z=0 bank of the home column); NoM carries them up/across to the
        leaf's home bank.  Homes spread over the DRAM layers (z >= 1)
        with a stride coprime to the pool size, so consecutive leaves
        land on different columns and their circuits can stream
        concurrently.  On a single-layer mesh, homes spread over the
        plane and stage at the row's edge bank; a leaf homed on its own
        staging bank is a controller-local write — no inter-bank hop.
        """
        mesh = self.cache_mesh
        flat, _ = jax.tree_util.tree_flatten_with_path(caches)
        step_bytes = self._step_nbytes(batch)
        placement = []
        plane = mesh.X * mesh.Y
        pool = mesh.n_nodes - plane
        for i, (path, _leaf) in enumerate(flat):
            if pool:
                home = plane + (i * 37 + 11) % pool
                x, y, _z = mesh.coords(home)
                staging = mesh.node_id(x, y, 0)
            else:       # single-layer mesh: all banks sit on the logic die
                home = (i * 37 + 11) % mesh.n_nodes
                _x, y, _z = mesh.coords(home)
                staging = mesh.node_id(0, y, 0)
            if staging == home:
                continue
            placement.append((jax.tree_util.keystr(path), staging, home,
                              step_bytes[i]))
        self._placement = placement

    def _schedule_step(self) -> None:
        """Schedule this step's cache transfer set as one concurrent batch."""
        if not self._placement:
            return
        reqs = [TransferRequest(src=s, dst=d, nbytes=n, tag=t,
                                max_extra_slots=self.max_extra_slots)
                for t, s, d, n in self._placement]
        results, report = schedule_transfers(reqs, allocator=self._alloc,
                                             cycle=self._next_cycle)
        self.reports.append(report)
        del self.reports[:-self.keep_reports]
        self.n_sched_steps += 1
        self.last_report = (report if self.last_report is None
                            else self.last_report.merge(report))
        # The next decode step starts after this step's circuits drained
        # (a model-forward pass dwarfs the cache-flush streaming time).
        end = max((r.circuit.end_cycle for r in results
                   if r.circuit is not None), default=self._next_cycle)
        self._next_cycle = ((end // self.n_slots) + 1) * self.n_slots

    def generate(self, params, prompt: jax.Array, n_new: int,
                 memory: jax.Array | None = None,
                 greedy: bool = True) -> jax.Array:
        """prompt: (B, P) int32 -> (B, P+n_new).

        Every prefill/decode step also emits its cache-movement transfer
        set through the NoM scheduler (unless ``track_transfers=False``);
        telemetry lands on ``self.reports`` / ``self.last_report``.
        """
        b, plen = prompt.shape
        caches = self.model.init_caches(b, self.max_len)
        if self._alloc is not None:
            self._plan_placement(caches, b)
        # Prefill token by token (single compiled program for both phases).
        logits = None
        for i in range(plen):
            logits, caches = self._step(params, prompt[:, i:i + 1], caches,
                                        jnp.int32(i), memory)
            if self._alloc is not None:
                self._schedule_step()
        out = [prompt]
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
        for i in range(plen, plen + n_new - 1):
            logits, caches = self._step(params, tok, caches, jnp.int32(i),
                                        memory)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(tok)
            if self._alloc is not None:
                self._schedule_step()
        return jnp.concatenate(out, axis=1)

    def transfer_telemetry(self) -> dict:
        """Aggregate cache-transfer scheduling stats over ``generate``."""
        if not self.n_sched_steps:
            return {}
        agg = self.last_report
        return {
            "steps": self.n_sched_steps,
            "requests": agg.n_requests,
            "scheduled": agg.n_scheduled,
            "batch_avg": agg.n_requests / self.n_sched_steps,
            "max_inflight": agg.max_inflight,
            "avg_inflight": agg.avg_inflight,
            "stall_cycles": agg.stall_cycles,
            "search_rounds": agg.search_rounds,
            "conflicts": agg.conflicts,
        }
