"""Serving engine: batched prefill + decode with per-arch caches.

``generate`` runs greedy decoding with a jit'd single-token step; prefill
feeds prompt tokens through the same step (cache-filling), which keeps one
compiled program for both phases — the large-scale serving shapes
(decode_32k / long_500k) are exercised via the dry-run on the production
mesh, this engine is the functional path used by tests and examples.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.lm import CausalLM, EncDecLM


@dataclasses.dataclass
class Engine:
    model: object
    cfg: ArchConfig
    max_len: int = 256

    def __post_init__(self):
        self._step = jax.jit(self._decode_one)

    def _decode_one(self, params, token, caches, pos, memory=None):
        if isinstance(self.model, EncDecLM):
            logits, caches = self.model.decode_step(params, token, caches,
                                                    pos, memory)
        else:
            logits, caches = self.model.decode_step(params, token, caches,
                                                    pos)
        return logits, caches

    def generate(self, params, prompt: jax.Array, n_new: int,
                 memory: jax.Array | None = None,
                 greedy: bool = True) -> jax.Array:
        """prompt: (B, P) int32 -> (B, P+n_new)."""
        b, plen = prompt.shape
        caches = self.model.init_caches(b, self.max_len)
        # Prefill token by token (single compiled program for both phases).
        logits = None
        for i in range(plen):
            logits, caches = self._step(params, prompt[:, i:i + 1], caches,
                                        jnp.int32(i), memory)
        out = [prompt]
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
        for i in range(plen, plen + n_new - 1):
            logits, caches = self._step(params, tok, caches, jnp.int32(i),
                                        memory)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(tok)
        return jnp.concatenate(out, axis=1)
