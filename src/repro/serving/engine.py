"""Serving engine: batched prefill + decode with per-arch caches.

``generate`` runs greedy decoding with a jit'd single-token step; prefill
feeds prompt tokens through the same step (cache-filling), which keeps one
compiled program for both phases — the large-scale serving shapes
(decode_32k / long_500k) are exercised via the dry-run on the production
mesh, this engine is the functional path used by tests and examples.

Decode-cache movement rides one :class:`~repro.core.fabric.NomFabric`
session, multi-tenant: each ``generate`` stream is a *tenant* that leases
bank homes from a :class:`~repro.serving.placement.BankPool` (placement
policies: strided spread, per-tenant column partitioning, stall-feedback
repacking).  Every step's cache updates are emitted as
:class:`~repro.core.scheduler.TransferRequest`s and scheduled in one
batched ``fabric.schedule`` call; ring-buffer overwrites, stall-driven
evictions, and tenant teardown ride the same batches as INIT-class
requests (``op="init"``, zero-hop circuits) — the serving analogue of the
paper's mixed copy/initialization traffic.  Tenant admission shares the
fabric's overflow semantics: a stream that finds the pool exhausted is
queued or shed (after idle-lease reclaim) instead of surfacing
``BankPool.lease``'s RuntimeError.  Per-batch :class:`ScheduleReport`s
accumulate on ``Engine.reports`` and aggregate into
``Engine.last_report``; ``Engine.transfer_telemetry()`` summarizes both,
including the INIT share and admission health.  See ``docs/serving.md``
and ``docs/fabric.md``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.fabric import AdmissionQueue, FabricCluster, NomFabric
from repro.core.scheduler import ScheduleReport, TransferRequest
from repro.core.topology import Mesh3D, StackedTopology, make_topology
from repro.models.lm import CausalLM, EncDecLM
from repro.serving.admission import (AdmissionContext, AdmissionTicket,
                                     TicketColumns, get_admission)
from repro.serving.placement import (BankPool, LeafSpec, step_requests,
                                     teardown_requests)

# Engine admission mode -> fabric/queue overflow behavior.
_ADMISSION = {"queue": "block", "shed": "shed", "raise": "raise"}

CONTROL_PLANES = ("vector", "scalar")


class _ObservedList(list):
    """The tenant queue's backing list, instrumented: any mutation made
    *outside* the engine's own helpers (tests shuffle / filter
    ``tenant_queue.items`` directly as a stand-in for arbitrary queue
    states) fires the hook, so the engine's packed ticket columns and
    queued-name index know to resynchronize before their next use."""

    __slots__ = ("_hook",)

    def __init__(self, iterable, hook):
        super().__init__(iterable)
        self._hook = hook

    def _make(name):
        base = getattr(list, name)

        def method(self, *args, **kw):
            self._hook()
            return base(self, *args, **kw)
        method.__name__ = name
        return method

    for _name in ("append", "extend", "insert", "pop", "remove", "clear",
                  "sort", "reverse", "__setitem__", "__delitem__",
                  "__iadd__", "__imul__"):
        locals()[_name] = _make(_name)
    del _make, _name


@dataclasses.dataclass
class _Tenant:
    """Live state of one serving stream's lease on the bank mesh."""
    name: str
    leases: list
    pos: int = 0               # write position (ring wrap -> evictions)
    stall_mark: int = 0        # tenant's attributed stalls at last repack
    last_active: int = 0       # engine tick of the last scheduled step
    slot: int = -1             # row in the engine's SoA tenant table


class _TenantTable:
    """Structure-of-arrays mirror of the active-tenant set.

    One row per admitted tenant (rows are recycled through a free list),
    columns ``last_active`` (engine tick of the last scheduled step) and
    ``lease_count`` (banks held).  Idle detection — previously a Python
    scan over every ``_Tenant`` per exhausted admission — becomes one
    boolean mask over the ``last_active`` column; only the (typically
    tiny) idle candidate set is ever touched per-element again, to apply
    the scalar path's exact ``(last_active, name)`` victim tie-break.
    """

    def __init__(self, capacity: int = 64):
        self._cap = max(1, capacity)
        self.last_active = np.zeros(self._cap, np.int64)
        self.lease_count = np.zeros(self._cap, np.int64)
        self.used = np.zeros(self._cap, bool)
        self.names: list[str | None] = [None] * self._cap
        self._free: list[int] = list(range(self._cap - 1, -1, -1))

    def add(self, name: str, last_active: int, lease_count: int) -> int:
        if not self._free:
            old = self._cap
            self._cap *= 2
            for col in ("last_active", "lease_count", "used"):
                arr = getattr(self, col)
                fresh = np.zeros(self._cap, arr.dtype)
                fresh[:old] = arr
                setattr(self, col, fresh)
            self.names.extend([None] * old)
            self._free.extend(range(self._cap - 1, old - 1, -1))
        slot = self._free.pop()
        self.last_active[slot] = last_active
        self.lease_count[slot] = lease_count
        self.used[slot] = True
        self.names[slot] = name
        return slot

    def drop(self, slot: int) -> None:
        self.used[slot] = False
        self.names[slot] = None
        self._free.append(slot)

    def touch(self, slot: int, tick: int) -> None:
        self.last_active[slot] = tick

    def idle_slots(self, tick: int, idle_ticks: int) -> np.ndarray:
        """Rows whose tenants have not scheduled for ``idle_ticks``."""
        mask = self.used & (tick - self.last_active >= idle_ticks)
        return np.flatnonzero(mask)

    def leases_active(self) -> int:
        return int(self.lease_count[self.used].sum())


@dataclasses.dataclass
class Engine:
    """Multi-tenant serving engine over a NoM bank mesh.

    Functional path: ``generate`` (batched greedy prefill+decode with one
    jit'd step).  Scheduling path (``track_transfers=True``): every stream
    is a tenant of ``self.pool``; per-step cache movement and INIT-class
    eviction traffic go through ``self.fabric`` — one
    :class:`~repro.core.fabric.NomFabric` session — so concurrent
    tenants' circuits genuinely compete for (and share) TDM windows, the
    quantity ``benchmarks/bench_serving_tenancy.py`` sweeps.

    Attributes:
      placement_policy: ``"spread"`` | ``"partition"`` |
        ``"stall_feedback"`` (see ``repro/serving/placement.py``).
      sched_policy: fabric packing policy for the per-step batches — a
        registered name or ``"auto"`` (stall-driven pick).
      admission: what happens when ``open_tenant`` finds the bank pool
        exhausted *after* idle-lease reclaim — ``"queue"`` (park the
        stream on a bounded admission queue; it is admitted when
        capacity frees), ``"shed"`` (decline it, counted), or
        ``"raise"`` (surface ``BankPool.lease``'s RuntimeError, the
        pre-fabric behavior).
      admission_strategy: registered admission-strategy name (see
        ``repro/serving/admission.py``) deciding the *order* queued
        streams are offered freed capacity in — ``"fifo"`` (arrival
        order, head-blocking; the legacy discipline), ``"deadline"``
        (strictest-deadline-first), ``"priority"`` (frequency/priority-
        weighted), ``"hybrid"`` (urgent deadlines preempt, utility
        otherwise), or ``"stall_aware"`` (deadline order while the
        fabric is healthy, lightest-first once its stall pressure
        crosses ``STALL_PRESSURE``).  Every strategy breaks ties by
        arrival sequence, so equal-utility waiters admit in stable FIFO
        order.
      control_plane: ``"vector"`` (default) runs admission, expiry, and
        idle eviction over packed structure-of-arrays state — one numpy
        lexsort per drain, boolean-mask expiry, an indexed duplicate
        check — and is bit-identical to ``"scalar"``, the original
        per-tenant Python path kept as the differential reference
        (``benchmarks/bench_engine_scale.py`` measures the two against
        each other; ``tests/test_serving_slo.py`` pins the identity).
      idle_evict_ticks: a tenant with no scheduled step for this many
        engine ticks is *idle*; exhausted admissions reclaim idle
        tenants' leases (teardown INIT scrubs ride the fabric) before
        queueing or shedding.  0 disables reclaim.
      deadline_ticks: how many engine ticks a *queued* stream may wait
        for admission.  ``schedule_tick`` sheds waiters older than this
        (counted in ``transfer_telemetry()["tenant_queue_expired"]``,
        with a ``waiter_callback`` notification) — a production engine
        must age out streams whose client has long since timed out
        instead of parking them forever.  0 disables aging.  A stream
        whose ``open_tenant`` ticket carries its own absolute
        ``deadline`` additionally expires once the engine tick passes
        it (and counts a deadline miss), whatever ``deadline_ticks``
        says.
      waiter_callback: optional ``fn(name, event)`` observer for queued
        streams — called with ``"admitted"`` when a waiter gets its
        lease, ``"expired"`` when aged out by ``deadline_ticks`` or its
        own ticket deadline, and ``"shed"`` when a stream is declined
        without ever queueing (admission ``"shed"`` or a full tenant
        queue).  Every admission attempt sees **exactly one** terminal
        event: ``admitted`` xor ``expired`` xor ``shed`` — never both
        of the failure events, even when the stream was declined only
        after a partial idle-lease reclaim.
      ring_slots: ring capacity per KV/ring leaf in token slots for the
        traffic model; ``None`` means ``max_len`` (no wrap within one
        ``generate``).  Smaller values exercise overwrite evictions.
      repack_stall_threshold: accumulated ``stall_cycles`` above which a
        ``stall_feedback`` engine re-homes a tenant (ignored otherwise).
      keep_reports: recent per-batch reports retained for inspection; the
        aggregate (``last_report`` / ``n_sched_steps``) is exact
        regardless, so a long-lived engine stays bounded.
    """
    model: object
    cfg: ArchConfig
    max_len: int = 256
    # NoM cache-transfer scheduling (set track_transfers=False to opt out).
    track_transfers: bool = True
    # A Mesh3D runs the single-stack NomFabric path; a StackedTopology
    # (from make_topology(n_stacks>1, ...)) swaps in a FabricCluster and
    # global bank ids, enabling cross-stack placement and migrate_tenant.
    cache_mesh: Mesh3D | StackedTopology = dataclasses.field(
        default_factory=make_topology)
    n_slots: int = 16
    max_extra_slots: int = 3
    keep_reports: int = 256
    placement_policy: str = "spread"
    sched_policy: str = "arrival"
    admission: str = "queue"
    admission_strategy: str = "fifo"
    control_plane: str = "vector"
    tenant_queue_depth: int = 8
    idle_evict_ticks: int = 4
    deadline_ticks: int = 0
    waiter_callback: object = None
    ring_slots: int | None = None
    repack_stall_threshold: int = 64

    def __post_init__(self):
        if self.admission not in _ADMISSION:
            raise ValueError(f"unknown admission mode {self.admission!r}; "
                             f"choose from {tuple(_ADMISSION)}")
        if self.control_plane not in CONTROL_PLANES:
            raise ValueError(
                f"unknown control plane {self.control_plane!r}; "
                f"choose from {CONTROL_PLANES}")
        self._vec = self.control_plane == "vector"
        # Resolve the drain-order strategy up front so a typo fails at
        # construction, not at the first overloaded tick.
        self._admission_fn = get_admission(self.admission_strategy)
        self._step = jax.jit(self._decode_one)
        stacked = isinstance(self.cache_mesh, StackedTopology)
        self.fabric = None
        if self.track_transfers:
            if stacked:
                self.fabric = FabricCluster(
                    topology=self.cache_mesh, n_slots=self.n_slots,
                    policy=self.sched_policy,
                    overflow=_ADMISSION[self.admission])
            else:
                self.fabric = NomFabric(
                    mesh=self.cache_mesh, n_slots=self.n_slots,
                    policy=self.sched_policy,
                    overflow=_ADMISSION[self.admission])
        self.pool = (BankPool(self.cache_mesh, self.placement_policy)
                     if self.track_transfers else None)
        # Waiting streams, under the same bounded-queue semantics as the
        # fabric's request admission (shed when this queue is full too).
        self.tenant_queue = AdmissionQueue(
            depth=self.tenant_queue_depth,
            overflow=_ADMISSION[self.admission])
        # Vectorized control-plane state: the packed SoA mirror of the
        # tenant queue (rebuilt lazily when the backing list is mutated
        # from outside the engine), the O(1) queued-name index, and the
        # SoA table of active tenants.  All engine-internal mutations go
        # through _q_push/_q_compact, which keep the mirrors exact.
        self._q_dirty = False
        self._q_guard = False
        self._cols = TicketColumns()
        self._queued_names: set[str] = set()
        self._table = _TenantTable()
        self.tenant_queue.items = _ObservedList(
            self.tenant_queue.items, self._queue_mutated_externally)
        self._tenants: dict[str, _Tenant] = {}
        self._tenant_stalls: dict[str, int] = {}   # per-tenant stall cycles
        self._reclaimed: set[str] = set()  # idle-evicted, owner not yet told
        self._gen_seq = 0
        self._tick = 0             # schedule_tick counter (idle detection)
        self._admit_seq = 0        # arrival order: the universal tie-break
        self._klass_admits: dict[str, int] = {}  # frequency signal
        self._class_stats: dict[str, dict] = {}  # per-klass outcome counts
        self._leaf_cache: dict[int, list] = {}   # batch -> leaf specs
        self.reports: list[ScheduleReport] = []
        self.last_report: ScheduleReport | None = None
        self.n_sched_steps = 0
        self.n_repacks = 0
        self.n_migrations = 0
        self.n_idle_evictions = 0
        self.n_queue_expired = 0
        self.n_deadline_misses = 0
        self.n_admitted_late = 0
        self.peak_tenants = 0

    def _decode_one(self, params, token, caches, pos, memory=None):
        if isinstance(self.model, EncDecLM):
            logits, caches = self.model.decode_step(params, token, caches,
                                                    pos, memory)
        else:
            logits, caches = self.model.decode_step(params, token, caches,
                                                    pos)
        return logits, caches

    # -- cache leaf inventory ----------------------------------------------
    def _leaf_specs(self, batch: int) -> list[LeafSpec]:
        """Describe every cache leaf for placement.

        Probed by abstract evaluation at two cache lengths: a leaf whose
        size scales with ``max_len`` (KV / ring buffers) moves one
        token-slot per step (the size slope) and wraps at ``ring_slots``;
        a length-independent leaf (SSM / RG-LRU state) is refreshed in
        place every step and never wraps.  ``lease_bytes`` is the full
        footprint, scrubbed at teardown.  Specs depend only on ``batch``
        (model and ``max_len`` are fixed per engine), so they are cached —
        the load generator re-probes the same batch sizes thousands of
        times per run."""
        cached = self._leaf_cache.get(batch)
        if cached is not None:
            return cached
        full = jax.eval_shape(
            lambda: self.model.init_caches(batch, self.max_len))
        half_len = max(1, self.max_len // 2)
        half = jax.eval_shape(
            lambda: self.model.init_caches(batch, half_len))
        flat_full = jax.tree_util.tree_flatten_with_path(full)[0]
        flat_half = jax.tree_util.tree_leaves(half)
        ring = self.ring_slots if self.ring_slots is not None else self.max_len
        out = []
        for (path, lf), lh in zip(flat_full, flat_half):
            nb_full = lf.size * jnp.dtype(lf.dtype).itemsize
            nb_half = lh.size * jnp.dtype(lh.dtype).itemsize
            tag = jax.tree_util.keystr(path)
            if nb_full != nb_half and self.max_len != half_len:
                step = max(1, (nb_full - nb_half)
                           // (self.max_len - half_len))
                out.append(LeafSpec(tag=tag, step_bytes=step,
                                    lease_bytes=nb_full, ring_slots=ring))
            else:
                out.append(LeafSpec(tag=tag, step_bytes=max(1, nb_full),
                                    lease_bytes=nb_full, ring_slots=0))
        self._leaf_cache[batch] = out
        return out

    # -- queue mirrors (vectorized control plane) ---------------------------
    def _queue_mutated_externally(self) -> None:
        if not self._q_guard:
            self._q_dirty = True

    def _q_refresh(self) -> None:
        """Resynchronize the packed columns and the queued-name index
        from the queue's backing list after an external mutation."""
        if not self._q_dirty:
            return
        self._cols.rebuild(self.tenant_queue.items)
        self._queued_names = {tk.name for _at, tk
                              in self.tenant_queue.items}
        self._q_dirty = False

    def _q_push(self, at: int, tk: AdmissionTicket) -> None:
        """Queue one waiter, keeping the SoA mirrors exact."""
        self._q_guard = True
        try:
            self.tenant_queue.push(at, tk)
        finally:
            self._q_guard = False
        if self._vec and not self._q_dirty:
            self._cols.append(at, tk)
            self._queued_names.add(tk.name)

    def _q_compact(self, keep: np.ndarray, removed_names) -> None:
        """Drop the queue rows where ``keep`` is False (one mask pass
        over the columns, one rebuild of the backing list)."""
        items = self.tenant_queue.items
        self._q_guard = True
        try:
            items[:] = [it for it, k in zip(items, keep) if k]
        finally:
            self._q_guard = False
        if self._vec and not self._q_dirty:
            self._cols.compact(keep)
            self._queued_names.difference_update(removed_names)

    def _queued(self, name: str) -> bool:
        """Is ``name`` already waiting for admission?  The vector plane
        answers from the name index; the scalar reference scans the
        queue (the O(queue)-per-open cost the index replaces)."""
        if self._vec:
            self._q_refresh()
            return name in self._queued_names
        return any(tk.name == name for _at, tk in self.tenant_queue.items)

    def _context(self) -> AdmissionContext:
        telemetry = self.fabric.telemetry if self.fabric is not None \
            else None
        return AdmissionContext(self._tick, self._klass_admits,
                                fabric=telemetry)

    # -- tenancy ------------------------------------------------------------
    def _evict_idle_tenant(self) -> bool:
        """Reclaim the most-idle tenant's leases (eviction machinery:
        the vacated homes are scrubbed by an INIT batch through the
        fabric).  Returns False when no tenant qualifies as idle."""
        if not self.idle_evict_ticks:
            return False
        if self._vec:
            # One mask over the SoA table; only the idle candidates are
            # touched per-element (for the exact scalar tie-break).
            slots = self._table.idle_slots(self._tick,
                                           self.idle_evict_ticks)
            if not len(slots):
                return False
            idle = [self._tenants[self._table.names[s]] for s in slots]
        else:
            idle = [t for t in self._tenants.values()
                    if self._tick - t.last_active >= self.idle_evict_ticks]
        if not idle:
            return False
        victim = min(idle, key=lambda t: (t.last_active, t.name))
        self.n_idle_evictions += 1
        self.close_tenant(victim.name)
        # The owner still holds the name: its next close_tenant must be
        # a quiet no-op (and schedule_tick must skip it), not an error.
        self._reclaimed.add(victim.name)
        return True

    def _lease_with_reclaim(self, name: str, specs: list[LeafSpec]) -> list:
        """``pool.lease`` with idle-lease reclaim on exhaustion: evict
        one idle tenant at a time (scrubbing its homes) and retry until
        the lease fits or no idle tenant remains."""
        while True:
            try:
                return self.pool.lease(name, specs)
            except RuntimeError:
                if not self._evict_idle_tenant():
                    raise

    def open_tenant(self, name: str, batch: int, queue: bool = True,
                    deadline: int | None = None, priority: float = 1.0,
                    klass: str = "default") -> list | None:
        """Lease bank homes for a new serving stream.

        One tenant per concurrent ``generate`` stream; ``batch`` sizes the
        leaf footprints.  Returns the leases (also kept internally until
        :meth:`close_tenant`).  Raises ``ValueError`` if the name is
        already active or already queued.

        ``deadline`` (absolute engine tick), ``priority``, and ``klass``
        annotate the stream's :class:`AdmissionTicket` — the utility
        signals the engine's ``admission_strategy`` orders waiters by,
        and the axes the per-class telemetry is bucketed on.  A ticket
        still queued after its ``deadline`` expires (one terminal
        ``"expired"`` event); one admitted late counts a deadline miss.

        When the pool is exhausted (after reclaiming idle tenants'
        leases), the engine's ``admission`` mode decides: ``"queue"``
        parks the stream on ``tenant_queue`` and returns None — it is
        admitted automatically when :meth:`close_tenant` frees capacity;
        ``"shed"`` counts the decline and returns None; ``"raise"``
        surfaces the pool's RuntimeError.  ``queue=False`` downgrades
        ``"queue"`` to shed-on-full for callers (like ``generate``) that
        cannot come back for a deferred admission."""
        if self.pool is None:
            raise RuntimeError("track_transfers=False engine has no pool")
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already active")
        if self._queued(name):
            raise ValueError(f"tenant {name!r} already queued for admission")
        self._reclaimed.discard(name)      # the name is being reused afresh
        tk = AdmissionTicket(
            name=name, batch=batch, klass=klass, priority=float(priority),
            deadline=None if deadline is None else int(deadline),
            seq=self._admit_seq)
        self._admit_seq += 1
        self._class_bucket(klass)["arrivals"] += 1
        try:
            leases = self._lease_with_reclaim(name, self._leaf_specs(batch))
        except RuntimeError:
            if self.admission == "raise":
                raise
            if (self.admission == "shed" or not queue
                    or self.tenant_queue.full()):
                self._finish(tk, self._tick, "shed")
                return None
            self._q_push(self._tick, tk)
            return None
        self._register_tenant(name, leases)
        # Immediate admissions are not waiter events: the caller holds
        # the leases already, so no "admitted" callback fires.
        self._finish(tk, self._tick, "admitted", notify=False)
        return leases

    def _register_tenant(self, name: str, leases: list) -> None:
        slot = self._table.add(name, self._tick, len(leases))
        self._tenants[name] = _Tenant(name=name, leases=leases,
                                      last_active=self._tick, slot=slot)
        self._tenant_stalls[name] = 0
        self.peak_tenants = max(self.peak_tenants, len(self._tenants))

    def _notify_waiter(self, name: str, event: str) -> None:
        if self.waiter_callback is not None:
            self.waiter_callback(name, event)

    def _class_bucket(self, klass: str) -> dict:
        return self._class_stats.setdefault(klass, {
            "arrivals": 0, "admitted": 0, "shed": 0, "expired": 0,
            "deadline_misses": 0, "wait_ticks": 0})

    def _finish(self, tk: AdmissionTicket, at: int, event: str,
                notify: bool = True) -> None:
        """Terminal accounting for one admission attempt — called exactly
        once per ticket, with its single outcome (``admitted`` xor
        ``expired`` xor ``shed``).  Folds the outcome into the per-class
        stats and deadline-miss counters, records the admission wait, and
        (when ``notify``) emits the one ``waiter_callback`` event."""
        stats = self._class_bucket(tk.klass)
        wait = max(0, self._tick - at)
        missed = False
        if event == "admitted":
            stats["admitted"] += 1
            stats["wait_ticks"] += wait
            self._klass_admits[tk.klass] = (
                self._klass_admits.get(tk.klass, 0) + 1)
            self.tenant_queue.record_admit(wait)
            if tk.deadline is not None and self._tick > tk.deadline:
                self.n_admitted_late += 1
                missed = True
        elif event == "expired":
            stats["expired"] += 1
            self.n_queue_expired += 1
            missed = tk.deadline is not None
        elif event == "shed":
            stats["shed"] += 1
            self.tenant_queue.n_shed += 1
            missed = tk.deadline is not None
        if missed:
            self.n_deadline_misses += 1
            stats["deadline_misses"] += 1
        if notify:
            self._notify_waiter(tk.name, event)

    def _drain_order(self, items, ctx: AdmissionContext) -> list | np.ndarray:
        """The strategy's admission order over the queued waiters.  The
        vector plane uses the strategy's attached batched form (one
        numpy lexsort over the packed columns) when it has one; scalar
        engines — and strategies registered without a vector form —
        compute it ticket by ticket.  Either way the permutation is
        validated before any capacity is offered."""
        vec = getattr(self._admission_fn, "vector", None)
        if self._vec and vec is not None:
            self._q_refresh()
            order = np.asarray(vec(self._cols, ctx))
            if (len(order) != len(items)
                    or not np.array_equal(np.sort(order),
                                          np.arange(len(items)))):
                raise ValueError(
                    f"admission strategy {self.admission_strategy!r} "
                    f"returned {order!r}, not a permutation of "
                    f"range({len(items)})")
            return order
        order = list(self._admission_fn(items, ctx))
        if sorted(order) != list(range(len(items))):
            raise ValueError(
                f"admission strategy {self.admission_strategy!r} returned "
                f"{order!r}, not a permutation of range({len(items)})")
        return order

    def _admit_waiting(self) -> None:
        """Offer freed capacity to the waiting streams in strategy order.

        The registered ``admission_strategy`` returns a permutation of
        the queued waiters (every strategy tie-breaks on the ticket's
        arrival ``seq``, so equal-utility streams admit in stable FIFO
        order no matter how the queue list got shuffled).  A waiter that
        does not fit is skipped and keeps its place — unless the strategy
        is ``head_blocking`` (``fifo``), where it ends the drain to
        preserve strict arrival order.

        The vector plane short-circuits the fit test: a lease can only
        succeed with at least ``len(leaf_specs)`` free banks, so waiters
        needing more than the live free count are skipped without a
        ``pool.lease`` exception round-trip, and the drain ends as soon
        as no remaining waiter could possibly fit — identical outcomes,
        O(admitted) pool calls instead of O(queue)."""
        items = self.tenant_queue.items
        if not items:
            return
        ctx = self._context()
        order = self._drain_order(items, ctx)
        head_blocking = getattr(self._admission_fn, "head_blocking", False)
        taken = set()
        if self._vec:
            # Per-waiter bank demand from the packed batch column: probe
            # the leaf specs once per distinct batch size, not per row.
            self._q_refresh()
            uniq, inv = np.unique(self._cols.batch, return_inverse=True)
            counts = np.array([len(self._leaf_specs(int(b)))
                               for b in uniq], np.int64)
            needed = counts[inv]
            min_needed = int(needed.min())
            free = self.pool.free_banks()
            for i in order:
                i = int(i)
                if free < min_needed and not head_blocking:
                    break              # nothing left can possibly fit
                at, tk = items[i]
                if needed[i] > free:
                    # pool.lease would raise: success needs one free
                    # bank per leaf.  Same outcome, no exception.
                    if head_blocking:
                        break
                    continue
                try:
                    leases = self.pool.lease(tk.name,
                                             self._leaf_specs(tk.batch))
                except RuntimeError:
                    if head_blocking:
                        break
                    continue
                free -= len(leases)
                taken.add(i)
                self._register_tenant(tk.name, leases)
                self._finish(tk, at, "admitted")
        else:
            for i in order:
                at, tk = items[i]
                try:
                    leases = self.pool.lease(tk.name,
                                             self._leaf_specs(tk.batch))
                except RuntimeError:
                    if head_blocking:
                        break
                    continue
                taken.add(i)
                self._register_tenant(tk.name, leases)
                self._finish(tk, at, "admitted")
        if taken:
            keep = np.ones(len(items), bool)
            keep[list(taken)] = False
            self._q_compact(keep, {items[i][1].name for i in taken})

    def _expire_waiters(self) -> None:
        """Age the tenant queue: shed streams that waited longer than
        ``deadline_ticks`` (their client has given up; holding a place
        would only block younger arrivals behind a corpse) and ticketed
        streams whose own absolute ``deadline`` has passed — each with
        its one terminal ``"expired"`` event.  The vector plane finds
        the expired set with one boolean mask over the packed columns
        and only touches those rows per-element."""
        items = self.tenant_queue.items
        if not items:
            return
        if self._vec:
            self._q_refresh()
            cols = self._cols
            aged = np.zeros(len(items), bool)
            if self.deadline_ticks:
                aged = self._tick - cols.at >= self.deadline_ticks
            late = (cols.deadline >= 0) & (self._tick > cols.deadline)
            gone = aged | late
            if not gone.any():
                return
            for i in np.flatnonzero(gone):
                at, tk = items[int(i)]
                self._finish(tk, at, "expired")
            self._q_compact(~gone, {items[int(i)][1].name
                                    for i in np.flatnonzero(gone)})
            # An expired head may have been the only thing blocking a
            # smaller waiter that already fits the pool.
            self._admit_waiting()
            return
        kept = []
        for at, tk in items:
            aged = (self.deadline_ticks
                    and self._tick - at >= self.deadline_ticks)
            late = tk.deadline is not None and self._tick > tk.deadline
            if aged or late:
                self._finish(tk, at, "expired")
            else:
                kept.append((at, tk))
        if len(kept) < len(items):
            items[:] = kept
            self._admit_waiting()

    def tenants(self) -> list[str]:
        """Names of the currently active (admitted) tenants."""
        return list(self._tenants)

    def close_tenant(self, name: str) -> ScheduleReport | None:
        """Tear a stream down: schedule one INIT scrub per vacated home
        (through the same fabric batch), release the leases, admit any
        waiting streams that now fit, and return that final batch's
        report.  A tenant whose leases were already reclaimed by idle
        eviction closes as a quiet no-op (returns None) — the revocation
        happened behind the owner's back."""
        if name in self._reclaimed:
            self._reclaimed.discard(name)
            return None
        if name not in self._tenants:
            raise ValueError(f"tenant {name!r} is not active "
                             "(never opened, or already closed)")
        ten = self._tenants.pop(name)
        self._table.drop(ten.slot)
        self._tenant_stalls.pop(name, None)
        reqs = teardown_requests(ten.leases)
        self.pool.release(name)
        report = self._schedule_batch(reqs) if reqs else None
        self._admit_waiting()
        return report

    def migrate_tenant(self, name: str,
                       dst_stack: int) -> ScheduleReport | None:
        """Move a live tenant's cache homes onto another stack.

        Requires a :class:`~repro.core.topology.StackedTopology` engine.
        The pool re-homes every lease onto ``dst_stack``
        (:meth:`BankPool.migrate`), then one fabric batch carries the
        tenant's state across: a cross-stack COPY per leaf (old home →
        new home, full leased footprint, streamed through the SerDes
        links) followed by teardown INIT scrubs of the vacated homes —
        the paper's bulk-transfer + initialization mix at tenant
        granularity.  Returns that batch's report, or None when the
        migration was a no-op (already on ``dst_stack``, or the
        destination cannot fit the tenant — placement is then
        unchanged)."""
        if self.pool is None:
            raise RuntimeError("track_transfers=False engine has no pool")
        if name not in self._tenants:
            raise ValueError(f"tenant {name!r} is not active "
                             "(never opened, or already closed)")
        ten = self._tenants[name]
        old, fresh = self.pool.migrate(name, dst_stack)
        if not fresh:
            return None
        # Leases already on dst_stack were kept in place by the pool.
        ten.leases = self.pool.leases(name)
        self._table.lease_count[ten.slot] = len(ten.leases)
        reqs = [TransferRequest(
            src=o.home, dst=f.home,
            nbytes=max(o.leaf.lease_bytes, o.leaf.step_bytes, 1),
            tag=(name, o.leaf.tag, "migrate"),
            max_extra_slots=self.max_extra_slots)
            for o, f in zip(old, fresh)]
        reqs += teardown_requests(old)
        self.n_migrations += 1
        return self._schedule_batch(reqs)

    def schedule_tick(self, tenants: list[str] | None = None
                      ) -> ScheduleReport | None:
        """Schedule one step's transfer set for the named tenants (default:
        all active) as a single concurrent batch, advancing each tenant's
        write position.  This is the scheduler-side heartbeat: ``generate``
        calls it once per model step for its own tenant; the tenancy
        benchmark drives many tenants through it without a model."""
        names = list(self._tenants) if tenants is None else tenants
        self._tick += 1
        self._expire_waiters()
        reqs = []
        for name in names:
            if name in self._reclaimed:
                continue               # idle-evicted: nothing left to move
            if name not in self._tenants:
                raise ValueError(f"tenant {name!r} is not active "
                                 "(never opened, or already closed)")
            ten = self._tenants[name]
            reqs += step_requests(ten.leases, ten.pos,
                                  max_extra_slots=self.max_extra_slots)
            ten.pos += 1
            ten.last_active = self._tick
            self._table.touch(ten.slot, self._tick)
        if not reqs:
            return None
        report = self._schedule_batch(reqs)
        for name in names:
            if name in self._tenants:      # reclaimed names have no state
                self._maybe_repack(self._tenants[name])
        return report

    def _maybe_repack(self, ten: _Tenant) -> None:
        """Stall feedback: re-home a tenant whose *own* circuits queue too
        long (per-tenant stall attribution, accumulated in
        ``_schedule_batch``).  The vacated homes are scrubbed by an INIT
        batch scheduled *immediately* — the pool has already freed those
        banks, so the scrub must land before anyone can re-lease them."""
        if self.placement_policy != "stall_feedback":
            return
        stalls = self._tenant_stalls.get(ten.name, 0) - ten.stall_mark
        evicted, fresh = self.pool.repack(ten.name, stalls,
                                          self.repack_stall_threshold)
        if evicted:
            ten.leases = fresh
            ten.stall_mark = self._tenant_stalls.get(ten.name, 0)
            self.n_repacks += 1
            self._schedule_batch(teardown_requests(evicted))

    # -- scheduling ----------------------------------------------------------
    def _schedule_batch(self, reqs) -> ScheduleReport:
        """Run one transfer batch through the fabric session and fold
        its report into the aggregates; per-request queueing delay is
        attributed to the owning tenant (the first tag element) for the
        stall-feedback policy.  The fabric's clock advances past the
        batch's drain (a model-forward pass dwarfs the cache-flush
        streaming time)."""
        results, report = self.fabric.schedule(reqs)
        cycle = self.fabric.last_cycle
        for rq, res in zip(reqs, results):
            if res.circuit is None or not isinstance(rq.tag, tuple):
                continue
            name = rq.tag[0]
            if name in self._tenant_stalls:
                self._tenant_stalls[name] += max(
                    0, res.circuit.start_cycle - (cycle + 3))
        self.reports.append(report)
        del self.reports[:-self.keep_reports]
        self.n_sched_steps += 1
        self.last_report = (report if self.last_report is None
                            else self.last_report.merge(report))
        return report

    # -- decoding -------------------------------------------------------------
    def generate(self, params, prompt: jax.Array, n_new: int,
                 memory: jax.Array | None = None,
                 greedy: bool = True, tenant: str | None = None) -> jax.Array:
        """prompt: (B, P) int32 -> (B, P+n_new).

        The stream runs as a tenant of the bank pool (name ``tenant``,
        auto-generated when None): leases open before prefill, every
        prefill/decode step emits its cache movement through
        :meth:`schedule_tick`, and completion tears the tenant down with
        INIT scrubs (unless ``track_transfers=False``).  A stream the
        pool cannot admit (exhausted even after idle-lease reclaim) is
        *shed from tracking* — tokens still stream, but its cache
        movement is not scheduled (counted in ``shed_tenants``); under
        ``admission="raise"`` the exhaustion raises instead.  Telemetry
        lands on ``self.reports`` / ``self.last_report`` /
        :meth:`transfer_telemetry`.
        """
        b, plen = prompt.shape
        caches = self.model.init_caches(b, self.max_len)
        name = None
        if self.fabric is not None:
            name = tenant or f"gen{self._gen_seq}"
            self._gen_seq += 1
            # queue=False: generate cannot return for a deferred
            # admission, so "queue" mode degrades to shed-on-full here.
            if self.open_tenant(name, b, queue=False) is None:
                name = None
        logits = None
        try:
            # Prefill token by token (one compiled program for both phases).
            for i in range(plen):
                logits, caches = self._step(params, prompt[:, i:i + 1],
                                            caches, jnp.int32(i), memory)
                if name is not None:
                    self.schedule_tick([name])
            out = [prompt]
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(tok)
            for i in range(plen, plen + n_new - 1):
                logits, caches = self._step(params, tok, caches,
                                            jnp.int32(i), memory)
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
                out.append(tok)
                if name is not None:
                    self.schedule_tick([name])
        finally:
            if name is not None and (name in self._tenants
                                     or name in self._reclaimed):
                self.close_tenant(name)
        return jnp.concatenate(out, axis=1)

    def transfer_telemetry(self) -> dict:
        """Aggregate transfer-scheduling stats over the engine's lifetime.

        Keys: ``steps`` (scheduled batches, incl. teardown), ``requests``
        / ``scheduled`` / ``batch_avg``, ``init_requests`` (eviction +
        teardown INITs), concurrency (``max_inflight`` /
        ``avg_inflight``), ``stall_cycles``, ``search_rounds`` /
        ``conflicts``, tenancy (``active_tenants`` / ``peak_tenants`` /
        ``repacks`` / ``migrations`` / ``cross_stack`` — scheduled
        cross-stack circuits, nonzero only on a stacked engine), and
        admission health (``admission`` / ``admission_strategy`` /
        ``control_plane`` — ``"vector"`` or ``"scalar"`` —
        ``sched_policy`` — the fabric's live policy pick —
        ``queued_tenants`` / ``shed_tenants`` / ``tenant_queue_expired``
        / ``idle_evictions`` / ``deadline_misses`` — expired, shed, or
        late-admitted ticketed streams — / ``admitted_late`` /
        ``admission_wait_p50`` / ``admission_wait_p99`` — admission-wait
        quantiles in engine ticks — / ``admission_classes`` — per-
        service-class outcome counts)."""
        if not self.n_sched_steps:
            return {}
        agg = self.last_report
        return {
            "steps": self.n_sched_steps,
            "requests": agg.n_requests,
            "scheduled": agg.n_scheduled,
            "batch_avg": agg.n_requests / self.n_sched_steps,
            "init_requests": agg.n_init,
            "max_inflight": agg.max_inflight,
            "avg_inflight": agg.avg_inflight,
            "stall_cycles": agg.stall_cycles,
            "search_rounds": agg.search_rounds,
            "conflicts": agg.conflicts,
            "active_tenants": len(self._tenants),
            "peak_tenants": self.peak_tenants,
            "repacks": self.n_repacks,
            "migrations": self.n_migrations,
            "cross_stack": getattr(agg, "n_cross_stack", 0),
            "admission": self.admission,
            "admission_strategy": self.admission_strategy,
            "control_plane": self.control_plane,
            "sched_policy": self.fabric.effective_policy,
            "queued_tenants": len(self.tenant_queue.items),
            "shed_tenants": self.tenant_queue.n_shed,
            "tenant_queue_expired": self.n_queue_expired,
            "idle_evictions": self.n_idle_evictions,
            "deadline_misses": self.n_deadline_misses,
            "admitted_late": self.n_admitted_late,
            "admission_wait_p50": self.tenant_queue.wait_quantile(0.50),
            "admission_wait_p99": self.tenant_queue.wait_quantile(0.99),
            "admission_classes": {k: dict(v) for k, v
                                  in sorted(self._class_stats.items())},
        }
