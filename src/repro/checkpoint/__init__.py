from .checkpoint import latest_step, prune, restore, save
from .reshard import cross_stack_reshard_plan, reshard_plan, shard_owners

__all__ = ["latest_step", "prune", "restore", "save", "reshard_plan",
           "cross_stack_reshard_plan", "shard_owners"]
