from .checkpoint import latest_step, prune, restore, save
from .reshard import reshard_plan

__all__ = ["latest_step", "prune", "restore", "save", "reshard_plan"]
