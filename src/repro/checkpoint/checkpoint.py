"""Sharded checkpointing: atomic save, manifest, elastic restore.

Leaves are saved as one ``.npy`` per parameter (flattened key path) plus a
JSON manifest (step, tree structure, mesh shape, config fingerprint).
Writes go to a temp dir + atomic rename, so a crash mid-save never
corrupts the latest checkpoint — the restart path picks the newest
*complete* checkpoint.  Restore is mesh-agnostic: arrays are re-sharded to
whatever mesh/sharding the caller provides (elastic scaling); the
host-side shard-migration schedule for that reshard can be planned with
``repro.core.plan_transfers`` (see tests/benchmarks).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        if not tree:
            out[prefix[:-1] + "{}"] = None   # empty-dict marker
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{SEP}"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    tree: dict = {}
    for path, v in flat.items():
        node = tree
        parts = path.split(SEP)
        if parts[-1].endswith("{}"):      # empty-dict marker
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            if parts[-1] != "{}":
                node.setdefault(parts[-1][:-2], {})
            continue
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save(ckpt_dir: str, step: int, state_tree, extra_meta: dict | None = None):
    """Atomic checkpoint of a pytree-of-dicts (params/opt/step...)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state_tree)
    manifest = {"step": int(step), "keys": {}, **(extra_meta or {})}
    for path, arr in flat.items():
        if path.endswith("{}"):           # empty-dict structure marker
            manifest["keys"][path] = {"empty": True}
            continue
        arr = np.asarray(jax.device_get(arr))
        fname = path.replace(SEP, "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["keys"][path] = {"file": fname, "shape": list(arr.shape),
                                  "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int | None = None, shardings=None):
    """Load a checkpoint; optionally place leaves with `shardings` (a
    matching pytree of NamedSharding) — this is the elastic-rescale path:
    the target mesh may differ from the one that saved."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            return None, None
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for path, meta in manifest["keys"].items():
        flat[path] = (None if meta.get("empty")
                      else np.load(os.path.join(d, meta["file"])))
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(jnp.asarray(a), s), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, manifest


def prune(ckpt_dir: str, keep: int = 3):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted([int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                    if d.startswith("step_") and not d.endswith(".tmp")])
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
