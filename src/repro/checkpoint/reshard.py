"""Elastic resharding: when the mesh changes (node failure, scale-up), plan
the bulk shard migration with the NOM transfer scheduler.

``reshard_plan`` computes, for every parameter shard, which device held
the bytes under the old mesh and which device needs them under the new
mesh, and packs the resulting (src, dst, bytes) set into conflict-free
NOM rounds over the device torus — the checkpoint/elastic analogue of the
paper's bulk inter-bank copies.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.fabric import FabricCluster, NomFabric
from repro.core.nom_collectives import Transfer, TransferPlan
from repro.core.scheduler import ScheduleReport, TransferRequest
from repro.core.topology import StackedTopology


@dataclasses.dataclass(frozen=True)
class ShardMove:
    param: str
    src_device: tuple
    dst_device: tuple
    nbytes: int


def shard_owners(shape, spec_axes, mesh_shape, axis_names):
    """Ownership map of a sharded array: device coords -> index ranges.

    ``shape`` is the array shape; ``spec_axes`` names, per array dim, the
    mesh axis it is sharded over (``None`` = replicated along that dim —
    every device owns the full extent), PartitionSpec-style;
    ``mesh_shape`` / ``axis_names`` describe the device mesh.  Returns
    ``{device_coords: ((start, stop), ...)}`` with one half-open range
    per array dim — the slice of the array that device holds, the
    granularity :func:`cross_stack_reshard_plan` moves shards at.

    Raises ``ValueError`` when a spec names an unknown mesh axis, reuses
    a mesh axis across dims, or shards a dim that the mesh axis size
    does not divide evenly (partial shards are not modeled)."""
    if len(mesh_shape) != len(axis_names):
        raise ValueError(f"mesh_shape {mesh_shape} and axis_names "
                         f"{axis_names} disagree on rank")
    if len(spec_axes) != len(shape):
        raise ValueError(f"spec_axes {spec_axes} must name one mesh axis "
                         f"(or None) per dim of shape {shape}")
    sizes = dict(zip(axis_names, mesh_shape))
    used = [a for a in spec_axes if a is not None]
    if len(used) != len(set(used)):
        raise ValueError(f"mesh axis reused across dims in {spec_axes}")
    for dim, ax in zip(shape, spec_axes):
        if ax is None:
            continue
        if ax not in sizes:
            raise ValueError(f"unknown mesh axis {ax!r}; "
                             f"mesh has {tuple(axis_names)}")
        if dim % sizes[ax]:
            raise ValueError(f"dim of size {dim} not divisible by mesh "
                             f"axis {ax!r} of size {sizes[ax]}")
    owners = {}
    for dev in np.ndindex(*tuple(mesh_shape)):
        coord = dict(zip(axis_names, dev))
        ranges = []
        for dim, ax in zip(shape, spec_axes):
            if ax is None:
                ranges.append((0, int(dim)))
            else:
                chunk = dim // sizes[ax]
                ranges.append((int(coord[ax] * chunk),
                               int((coord[ax] + 1) * chunk)))
        owners[tuple(int(x) for x in dev)] = tuple(ranges)
    return owners


def reshard_plan(params_meta: dict[str, int], old_mesh: tuple,
                 new_mesh: tuple, torus: bool = True,
                 policy: str = "longest_first") -> TransferPlan:
    """params_meta: name -> nbytes (per-param total).  Devices are laid out
    row-major on both meshes; each param's bytes move from its old owner
    set to its new owner set, round-robin.  Returns the NOM round plan
    (used by tests and the elastic example; actual array placement is done
    by jax.device_put — this plan is the *schedule* evidence)."""
    plan, _report = reshard_plan_with_report(params_meta, old_mesh, new_mesh,
                                             torus=torus, policy=policy)
    return plan


def reshard_plan_with_report(
        params_meta: dict[str, int], old_mesh: tuple, new_mesh: tuple,
        torus: bool = True,
        policy: str = "longest_first") -> tuple[TransferPlan, ScheduleReport]:
    """Like :func:`reshard_plan` but routed through a one-shot
    :class:`~repro.core.fabric.NomFabric` session (device level),
    returning the concurrency report alongside the plan."""
    old_n = int(np.prod(old_mesh))
    new_n = int(np.prod(new_mesh))
    shape = new_mesh if new_n >= old_n else old_mesh
    coords = lambda i, mesh: tuple(
        int(x) for x in np.unravel_index(i % int(np.prod(mesh)), mesh))
    transfers = []
    for i, (name, nbytes) in enumerate(sorted(params_meta.items())):
        src = coords(i % old_n, shape)
        dst = coords(i % new_n, shape)
        if src != dst:
            transfers.append(Transfer(src=src, dst=dst, nbytes=nbytes,
                                      tag=name))
    fabric = NomFabric(shape=shape, torus=torus, policy=policy)
    return fabric.schedule(transfers)


def cross_stack_reshard_plan(
        params_meta: dict[str, int], topology: StackedTopology,
        old_stacks: tuple, new_stacks: tuple,
        policy: str = "arrival") -> tuple[list, ScheduleReport]:
    """Plan a checkpoint reshard across the stacks of a multi-stack NoM.

    The memory-side analogue of :func:`reshard_plan`: parameters laid
    out round-robin over ``old_stacks`` move to their round-robin owner
    in ``new_stacks`` (stack shrink/grow after failure or scale-up).
    Each move becomes one bank-level request — stack-local node chosen
    by strided spread — scheduled through a one-shot
    :class:`~repro.core.fabric.FabricCluster`: same-stack moves stay on
    that stack's TDM mesh, cross-stack moves negotiate two-phase
    circuits through the SerDes links.  Returns ``(results, report)``
    in sorted-param order; ``report.n_cross_stack`` counts the
    inter-stack share."""
    if not old_stacks or not new_stacks:
        raise ValueError("old_stacks and new_stacks must be non-empty")
    for s in (*old_stacks, *new_stacks):
        if not (0 <= s < topology.n_stacks):
            raise ValueError(f"stack {s} out of range "
                             f"[0, {topology.n_stacks})")
    reqs = []
    for i, (name, nbytes) in enumerate(sorted(params_meta.items())):
        so = old_stacks[i % len(old_stacks)]
        sn = new_stacks[i % len(new_stacks)]
        src = (i * 13 + 5) % topology.stacks[so].n_nodes
        dst = (i * 13 + 5) % topology.stacks[sn].n_nodes
        if so == sn and src == dst:
            continue                 # already where it belongs
        reqs.append(TransferRequest(src=src, dst=dst, nbytes=nbytes,
                                    tag=name, src_stack=so, dst_stack=sn))
    cluster = FabricCluster(topology=topology, policy=policy)
    return cluster.schedule(reqs)
