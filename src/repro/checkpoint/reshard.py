"""Elastic resharding: when the mesh changes (node failure, scale-up), plan
the bulk shard migration with the NOM transfer scheduler.

``reshard_plan`` computes, for every parameter shard, which device held
the bytes under the old mesh and which device needs them under the new
mesh, and packs the resulting (src, dst, bytes) set into conflict-free
NOM rounds over the device torus — the checkpoint/elastic analogue of the
paper's bulk inter-bank copies.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.fabric import NomFabric
from repro.core.nom_collectives import Transfer, TransferPlan
from repro.core.scheduler import ScheduleReport


@dataclasses.dataclass(frozen=True)
class ShardMove:
    param: str
    src_device: tuple
    dst_device: tuple
    nbytes: int


def shard_owners(shape, spec_axes, mesh_shape, axis_names):
    """Yield (device_coords, slice_id) ownership for a 1-axis-sharded dim
    model (sufficient for planning granularity)."""
    n_dev = int(np.prod(mesh_shape))
    grid = np.arange(n_dev).reshape(mesh_shape)
    return grid


def reshard_plan(params_meta: dict[str, int], old_mesh: tuple,
                 new_mesh: tuple, torus: bool = True,
                 policy: str = "longest_first") -> TransferPlan:
    """params_meta: name -> nbytes (per-param total).  Devices are laid out
    row-major on both meshes; each param's bytes move from its old owner
    set to its new owner set, round-robin.  Returns the NOM round plan
    (used by tests and the elastic example; actual array placement is done
    by jax.device_put — this plan is the *schedule* evidence)."""
    plan, _report = reshard_plan_with_report(params_meta, old_mesh, new_mesh,
                                             torus=torus, policy=policy)
    return plan


def reshard_plan_with_report(
        params_meta: dict[str, int], old_mesh: tuple, new_mesh: tuple,
        torus: bool = True,
        policy: str = "longest_first") -> tuple[TransferPlan, ScheduleReport]:
    """Like :func:`reshard_plan` but routed through a one-shot
    :class:`~repro.core.fabric.NomFabric` session (device level),
    returning the concurrency report alongside the plan."""
    old_n = int(np.prod(old_mesh))
    new_n = int(np.prod(new_mesh))
    shape = new_mesh if new_n >= old_n else old_mesh
    coords = lambda i, mesh: tuple(
        int(x) for x in np.unravel_index(i % int(np.prod(mesh)), mesh))
    transfers = []
    for i, (name, nbytes) in enumerate(sorted(params_meta.items())):
        src = coords(i % old_n, shape)
        dst = coords(i % new_n, shape)
        if src != dst:
            transfers.append(Transfer(src=src, dst=dst, nbytes=nbytes,
                                      tag=name))
    fabric = NomFabric(shape=shape, torus=torus, policy=policy)
    return fabric.schedule(transfers)
