"""Elastic scaling walk-through: checkpoint under mesh A, lose a node,
restore under mesh B with a NOM-planned shard-migration schedule.

Run:  PYTHONPATH=src python examples/elastic_reshard.py
"""
import os
import tempfile

import jax
import numpy as np

from repro import checkpoint as ckpt
from repro.checkpoint.reshard import reshard_plan
from repro.configs import get_config
from repro.models import count_params, make_model


def main():
    cfg = get_config("mamba2-130m", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 100, {"params": params},
                  extra_meta={"mesh": [4, 4], "config": cfg.name})
        print(f"saved step 100 ({count_params(params):,} params) on a "
              f"4x4 mesh")
        # a node died: re-plan onto 3x4 and restore.  Planning granularity
        # is one entry per (param, shard): each owner change is a transfer.
        sizes = {}
        for i, leaf in enumerate(jax.tree.leaves(params)):
            per_shard = int(np.prod(leaf.shape)) * 4 // 16
            for s in range(16):
                sizes[f"leaf{i}/shard{s}"] = max(per_shard, 1)
        plan = reshard_plan(sizes, old_mesh=(4, 4), new_mesh=(3, 4))
        moved = sum(len(p) for p in plan.paths)
        print(f"NOM reshard plan: {len(plan.transfers)} shard moves, "
              f"{plan.n_rounds} conflict-free rounds, {moved} link-hops")
        tree, manifest = ckpt.restore(d)
        ok = all(np.array_equal(np.asarray(a), np.asarray(b))
                 for a, b in zip(jax.tree.leaves(tree["params"]),
                                 jax.tree.leaves(params)))
        print(f"restored step {manifest['step']} bit-identical: {ok}")


if __name__ == "__main__":
    main()
