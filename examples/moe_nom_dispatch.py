"""The paper's technique end-to-end: MoE expert-parallel token dispatch via
NOM-scheduled ppermute rounds vs the opaque XLA all_to_all, on 8 fake
devices (this example MUST set XLA_FLAGS before importing jax).

Run:  PYTHONPATH=src python examples/moe_nom_dispatch.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np          # noqa: E402
import jax                  # noqa: E402
import jax.numpy as jnp     # noqa: E402

from repro.core.nom_collectives import a2a_link_chunks  # noqa: E402
from repro.launch.mesh import make_mesh, set_ambient_mesh  # noqa: E402
from repro.models.moe import MoE, MoEConfig             # noqa: E402


def main():
    mesh = make_mesh((1, 8), ("data", "model"))
    set_ambient_mesh(mesh)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 64, 128), jnp.float32)

    outs = {}
    for dispatch in ("nom", "xla", "einsum"):
        cfg = MoEConfig(d_model=128, d_ff=256, n_experts=16, top_k=2,
                        dispatch=dispatch, capacity_factor=4.0)
        moe = MoE(cfg)
        params = moe.init(key)
        y, aux = jax.jit(moe.apply)(params, x)
        outs[dispatch] = np.asarray(y)
        print(f"dispatch={dispatch:7s} |y|={np.abs(outs[dispatch]).mean():.4f} "
              f"aux={float(aux):.4f}")
    print("nom == xla:", np.allclose(outs["nom"], outs["xla"], atol=1e-5))
    print("nom ~= einsum:", np.allclose(outs["nom"], outs["einsum"],
                                        atol=1e-4))
    c = a2a_link_chunks(8)
    print(f"\nper-link chunks for an 8-ring all-to-all: "
          f"NOM schedule {c['nom_right']:.0f}/dir vs bus-serialized "
          f"{c['bus_serialized']:.0f} — the paper's Fig. 4 gap, on ICI")

    # The dispatch plan the nom path realizes, scheduled host-side from
    # the live bucketized routing through schedule_transfers.
    moe = MoE(MoEConfig(d_model=128, d_ff=256, n_experts=16, top_k=2,
                        dispatch="nom", capacity_factor=4.0))
    plan, rep = moe.plan_dispatch(moe.init(key), x, ep=8)
    print(f"\nexpert-dispatch ScheduleReport (EP ring of 8):")
    print(f"  {rep.n_scheduled}/{rep.n_requests} blocks in "
          f"{rep.n_windows} conflict-free rounds, "
          f"link util {plan.link_utilization():.2f}")
    print(f"  concurrency: max {rep.max_inflight} in flight/round, "
          f"avg {rep.avg_inflight:.2f}; stall_rounds={rep.stall_cycles}")


if __name__ == "__main__":
    main()
