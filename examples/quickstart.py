"""Quickstart: the paper's NoM in 60 seconds.

1. Open a `NomFabric` session on the 8x8x4 mesh, schedule a TDM circuit,
   and print its slot schedule.
2. Run the four memory configurations on a copy-heavy workload and
   reproduce the paper's IPC ordering.
3. Plan a NOM-scheduled bulk transfer set (the TPU adaptation).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (NomFabric, Transfer, TransferRequest,
                        make_topology, plan_transfers)
from repro.memsim import SimParams, WorkloadSpec, generate, simulate


def main():
    # --- 1. circuits ---------------------------------------------------------
    mesh = make_topology(mesh=(8, 8, 4))
    fabric = NomFabric(mesh=mesh, n_slots=16)
    src, dst = mesh.node_id(0, 0, 0), mesh.node_id(5, 3, 2)
    results, report = fabric.schedule(
        [TransferRequest(src, dst, nbytes=4096, max_extra_slots=3)],
        cycle=0)
    c = results[0].circuit
    print(f"circuit {mesh.coords(src)} -> {mesh.coords(dst)}: "
          f"start cycle {c.start_cycle}, {c.slots_per_window} slots/window, "
          f"{c.n_windows} windows (stall_cycles={report.stall_cycles})")
    print("  first hops:", [(mesh.coords(n), f"port{p}", f"slot{s}")
                            for n, p, s in c.hops[:4]])

    # --- 2. the paper's comparison --------------------------------------------
    reqs = generate(WorkloadSpec("fileCopy40", n_requests=600, seed=0))
    print("\nIPC on fileCopy40 (paper Fig. 4 ordering):")
    for cfg in ("conventional", "rowclone", "nom", "nom_light"):
        r = simulate(reqs, SimParams(config=cfg))
        print(f"  {cfg:13s} ipc={r.ipc:.3f}")

    # --- 3. NOM as a TPU collective scheduler -----------------------------------
    transfers = [Transfer((i, 0), ((i + 3) % 8, 3), nbytes=1 << 20)
                 for i in range(8)]
    plan = plan_transfers((8, 4), transfers)
    print(f"\nNOM bulk-transfer plan on an 8x4 device torus: "
          f"{len(transfers)} transfers in {plan.n_rounds} conflict-free "
          f"rounds (link util {plan.link_utilization():.2f})")


if __name__ == "__main__":
    main()
